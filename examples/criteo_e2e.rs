//! END-TO-END DRIVER (the EXPERIMENTS.md §E2E run).
//!
//! Exercises the full three-layer stack on a real small workload:
//!
//!   * L3: rust streaming coordinator — synthetic Criteo-shaped stream
//!     (1M-symbol alphabet), sharded Bloom encode workers, backpressure.
//!   * L2/L1: the AOT-compiled `fused_train_sign_concat` artifact (Pallas
//!     sign-projection kernel + concat + logistic SGD step) executed via
//!     PJRT — python never runs here.
//!
//! Trains a d_total = 10,240-parameter model (default profile: 2048
//! numeric + 8192 categorical) for several hundred PJRT steps, logging
//! the loss curve, then reports validation/test AUC and throughput, and
//! repeats the same workload on the pure-rust sparse-SGD backend as a
//! cross-check.
//!
//! ```bash
//! make artifacts && cargo run --release --example criteo_e2e
//! ```

use shdc::coordinator::{CatCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::encoding::BundleMethod;
use shdc::pipeline::{train, TrainBackend, TrainCfg};

fn main() -> anyhow::Result<()> {
    let records: u64 = std::env::var("E2E_RECORDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120_000); // ~470 PJRT steps at b=256

    let data = SyntheticConfig {
        alphabet_size: 1_000_000,
        noise: 0.5,
        positive_rate: 0.25,
        ..SyntheticConfig::sampled(2026)
    };

    // ---- PJRT fused path (profile "default": b=256, 2048+8192) ----------
    println!("=== criteo_e2e: PJRT fused backend (default profile) ===");
    let cfg = TrainCfg {
        encoder: EncoderCfg {
            cat: CatCfg::Bloom { d: 8_192, k: 4 },
            num: NumCfg::DenseSign { d: 2_048 }, // computed on-device
            bundle: BundleMethod::Concat,
            n_numeric: data.n_numeric,
            seed: 2026,
        },
        backend: TrainBackend::PjrtFused { profile: "default".into() },
        lr: 0.1,
        batch_size: 256,
        n_workers: 4,
        train_records: records,
        val_records: 10_000,
        test_records: 30_000,
        validate_every: 20_000, // loss logged at each validation round
        patience: 5,
        auc_chunk: 5_000,
        seed: 2026,
    };
    let rep = train(&cfg, &data)?;
    println!("records trained     : {}", rep.records_trained);
    println!("PJRT steps          : ~{}", rep.records_trained / 256);
    println!("final train loss    : {:.4}", rep.final_train_loss);
    println!("final val loss      : {:.4}", rep.final_val_loss);
    println!("validation AUC      : {:.4}", rep.val_auc);
    println!("test AUC (5k chunks): {}", rep.auc_box().row());
    println!("trainable params    : {}", rep.trainable_params);
    println!("wall time           : {:.2?}", rep.wall);
    println!(
        "throughput          : {:.0} rec/s end-to-end ({:.0} rec/s in PJRT train step)",
        rep.records_trained as f64 / rep.wall.as_secs_f64(),
        rep.stats.train_throughput()
    );

    // ---- rust sparse-SGD cross-check ------------------------------------
    println!("\n=== criteo_e2e: rust sparse-SGD backend (same workload) ===");
    let cfg_rust = TrainCfg { backend: TrainBackend::RustSgd, ..cfg.clone() };
    let rep2 = train(&cfg_rust, &data)?;
    println!("validation AUC      : {:.4}", rep2.val_auc);
    println!("test AUC (5k chunks): {}", rep2.auc_box().row());
    println!("wall time           : {:.2?}", rep2.wall);
    println!(
        "throughput          : {:.0} rec/s end-to-end",
        rep2.records_trained as f64 / rep2.wall.as_secs_f64(),
    );

    let gap = (rep.val_auc - rep2.val_auc).abs();
    println!("\nbackend AUC agreement: |Δ| = {gap:.4} (different numeric encoders/batching; expect < 0.08)");
    if gap > 0.08 {
        eprintln!("WARNING: backends diverge more than expected");
    }
    Ok(())
}
