//! Scalability demo (Fig. 7A in miniature): stream an ever-growing
//! categorical alphabet through (a) the classical random-codebook
//! encoder and (b) the paper's Bloom hash encoder, printing latency and
//! encoder memory as the alphabet grows — until the codebook trips its
//! memory budget while the hash encoder cruises along in constant space.
//!
//! ```bash
//! cargo run --release --example scaling
//! ```

use std::time::Instant;

use shdc::data::synthetic::SyntheticConfig;
use shdc::data::{RecordStream, SyntheticStream};
use shdc::encoding::{BloomEncoder, CategoricalEncoder, CodebookEncoder};
use shdc::util::rng::Rng;

fn main() {
    let d = 10_000;
    let batch = 20_000usize;
    let n_batches = 10;
    let mut stream = SyntheticStream::new(SyntheticConfig {
        alphabet_size: 100_000_000, // effectively unbounded
        zipf_alpha: 1.02,           // long tail: new symbols keep arriving
        ..SyntheticConfig::sampled(5)
    });

    let mut bloom = BloomEncoder::new(d, 4, &mut Rng::new(5));
    let mut codebook = CodebookEncoder::with_budget(d, 5, 400_000_000); // 400 MB budget
    println!(
        "{:>7} {:>14} {:>14} {:>14} {:>16}",
        "batch", "bloom ms", "codebook ms", "codebook MB", "symbols seen"
    );
    let mut oom = false;
    for b in 1..=n_batches {
        let records: Vec<_> = (0..batch).map(|_| stream.next_record().unwrap()).collect();

        let t = Instant::now();
        for r in &records {
            std::hint::black_box(bloom.encode_set(&r.symbols));
        }
        let bloom_ms = t.elapsed().as_secs_f64() * 1e3;

        let (code_ms, mb) = if oom {
            (None, None)
        } else {
            let t = Instant::now();
            let mut failed = false;
            for r in &records {
                if codebook.try_encode(&r.symbols).is_err() {
                    failed = true;
                    break;
                }
            }
            let ms = t.elapsed().as_secs_f64() * 1e3;
            if failed {
                oom = true;
            }
            (Some(ms), Some(codebook.memory_bytes() as f64 / 1e6))
        };
        println!(
            "{:>7} {:>14.1} {:>14} {:>14} {:>16}{}",
            b,
            bloom_ms,
            code_ms.map(|v| format!("{v:.1}")).unwrap_or("OOM".into()),
            mb.map(|v| format!("{v:.1}")).unwrap_or("-".into()),
            codebook.symbols_seen(),
            if oom && code_ms.is_some() { "   <-- budget exceeded" } else { "" }
        );
    }
    println!(
        "\nbloom encoder state after {} records: {} bytes (4 x 32-bit seeds).",
        batch * n_batches,
        CategoricalEncoder::memory_bytes(&mut bloom)
    );
    println!("The codebook's item memory scales linearly with the alphabet; hashing doesn't.");
}
