//! Quickstart: encode a high-cardinality categorical stream with the
//! paper's sparse Bloom hashing and train a streaming logistic model.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use shdc::coordinator::{CatCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::encoding::BundleMethod;
use shdc::pipeline::{train, TrainBackend, TrainCfg};

fn main() -> anyhow::Result<()> {
    // 1. A Criteo-shaped stream: 13 numeric + 26 categorical features
    //    drawn from a 1M-symbol alphabet, with a planted ground truth.
    let data = SyntheticConfig {
        alphabet_size: 1_000_000,
        noise: 0.4,
        ..SyntheticConfig::sampled(/*seed=*/ 7)
    };

    // 2. The paper's streaming encoder: Bloom hashing for categorical
    //    features (k=4 hash functions, nothing stored per symbol) +
    //    a signed random projection for the numeric features.
    let encoder = EncoderCfg {
        cat: CatCfg::Bloom { d: 10_000, k: 4 },
        num: NumCfg::DenseSign { d: 2_048 },
        bundle: BundleMethod::Concat,
        n_numeric: data.n_numeric,
        seed: 7,
    };
    println!("encoder state: {} bytes — independent of the 1M-symbol alphabet", 16);

    // 3. Stream-train a logistic regression with 4 encode workers.
    let cfg = TrainCfg {
        encoder,
        backend: TrainBackend::RustSgd,
        lr: 0.5,
        batch_size: 256,
        n_workers: 4,
        train_records: 100_000,
        val_records: 10_000,
        test_records: 20_000,
        validate_every: 25_000,
        patience: 3,
        auc_chunk: 5_000,
        seed: 7,
    };
    let report = train(&cfg, &data)?;

    println!("trained on {} records in {:.2?}", report.records_trained, report.wall);
    println!("validation AUC: {:.4}", report.val_auc);
    println!("test AUC (per 5k chunk): {}", report.auc_box().row());
    println!(
        "throughput: {:.0} rec/s/worker encode, {:.0} rec/s train",
        report.stats.encode_throughput(),
        report.stats.train_throughput()
    );
    Ok(())
}
