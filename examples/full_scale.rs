//! Sec. 7.5 analog: the "1 TB" configuration — a 4M-symbol alphabet with
//! heavy 96/4 label imbalance, encoded with the paper's best streaming
//! architecture (SJLT numeric + Bloom categorical, d_cat = 20,000) and
//! trained on a longer stream. Row count is scaled down (the paper
//! itself notes scalability depends only on (n, s, m), not row count).
//!
//! ```bash
//! cargo run --release --example full_scale
//! ```

use shdc::coordinator::{CatCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::encoding::BundleMethod;
use shdc::pipeline::{train, TrainBackend, TrainCfg};

fn main() -> anyhow::Result<()> {
    let records: u64 = std::env::var("FULL_RECORDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300_000);
    let data = SyntheticConfig::full(99); // m = 4M, P(y=1) = 0.04
    let cfg = TrainCfg {
        encoder: EncoderCfg {
            // Paper Sec. 7.5: SJLT numeric encoder (d_count = 10,000),
            // Bloom categorical (d_cat = 20,000), k = 4.
            cat: CatCfg::Bloom { d: 20_000, k: 4 },
            num: NumCfg::RelaxedSjlt { d: 10_000, p: 0.4, quantize: true },
            bundle: BundleMethod::Concat,
            n_numeric: data.n_numeric,
            seed: 99,
        },
        backend: TrainBackend::RustSgd,
        lr: 0.3,
        batch_size: 256,
        n_workers: 4,
        train_records: records,
        val_records: 20_000,
        test_records: 40_000,
        validate_every: 50_000,
        patience: 3,
        auc_chunk: 10_000,
        seed: 99,
    };
    println!("training the Sec 7.5 configuration on m = 4e6, 96/4 imbalance, {records} records...");
    let rep = train(&cfg, &data)?;
    println!("records trained : {}", rep.records_trained);
    println!("validation AUC  : {:.4} (paper on real 1TB Criteo: 0.731)", rep.val_auc);
    println!("test AUC chunks : {}", rep.auc_box().row());
    println!("final val loss  : {:.4}", rep.final_val_loss);
    println!("params          : {}", rep.trainable_params);
    println!("wall            : {:.2?}", rep.wall);
    println!("\nnote: absolute AUC is not comparable (planted synthetic vs real ads);");
    println!("the point is the pipeline handles the full-scale (m, skew) regime unchanged.");
    Ok(())
}
