//! Hardware evaluation walk-through: regenerate the paper's FPGA and PIM
//! results (Tables 2-4) from the cycle-level simulators and compare with
//! a measured CPU baseline (Figs. 12-13 shapes).
//!
//! ```bash
//! cargo run --release --example hardware_sim
//! ```

use shdc::encoding::BundleMethod;
use shdc::hw::cpu;
use shdc::hw::fpga::{self, FpgaConfig};
use shdc::hw::pim::{self, PimWorkload};
use shdc::hw::{comparison_table, PlatformRow};

fn main() {
    println!("## FPGA (Table 2)\n");
    for rep in fpga::table2() {
        println!(
            "  {:<9} {:>4.0} MHz  cat={:<4} num={:<4} score={:<4} grad={:<4} -> {:>6.2} M inputs/s, {:>4.1} W",
            rep.config.label(),
            rep.config.freq_mhz,
            rep.cycles.cat_encode,
            rep.cycles.num_encode.map(|c| c.to_string()).unwrap_or("-".into()),
            rep.cycles.score,
            rep.cycles.gradient,
            rep.throughput / 1e6,
            rep.power_watts,
        );
    }
    let shift =
        fpga::simulate_shift_baseline(&FpgaConfig::paper(BundleMethod::ThresholdedSum, false));
    println!(
        "  shift-materialization baseline: {:.1}k inputs/s (hash encoding is ~100x faster)",
        shift.throughput / 1e3
    );

    println!("\n## PIM (Tables 3-4)\n");
    let (xbar, cluster, tile, chip) = pim::hierarchy();
    println!(
        "  hierarchy: crossbar {:.0} um^2 / {:.2} mW -> cluster {:.0} um^2 -> tile {:.3} mm^2 -> chip {:.0} mm^2 / {:.0} W",
        xbar.area_mm2 * 1e6,
        xbar.power_w * 1e3,
        cluster.area_mm2 * 1e6,
        tile.area_mm2,
        chip.area_mm2,
        chip.power_w
    );
    for (label, numeric) in [("OR/SUM", true), ("No-Count", false)] {
        let rep = pim::simulate(&PimWorkload::paper(numeric));
        println!(
            "  {:<9} xbars/input: num={:?} cat={} | cycles num={:?} cat={} | {:>7.2} M inputs/s",
            label,
            rep.numeric_xbars,
            rep.cat_xbars,
            rep.numeric_cycles,
            rep.cat_cycles,
            rep.throughput / 1e6
        );
    }

    println!("\n## Cross-platform encode throughput (Fig. 12 shape)\n");
    let cpu_m = cpu::measure_encode(&cpu::paper_workload(false, 3), 2_000, 3);
    let f = fpga::simulate(&FpgaConfig::paper(BundleMethod::ThresholdedSum, false));
    let enc_cycles = f.cycles.cat_encode + f.cycles.num_encode.unwrap_or(0);
    let p = pim::simulate(&PimWorkload::paper(true));
    let rows = vec![
        PlatformRow {
            platform: "CPU (ours)".into(),
            throughput: cpu_m.records_per_sec,
            watts: cpu::PAPER_CPU_WATTS,
        },
        PlatformRow {
            platform: "FPGA (sim)".into(),
            throughput: f.config.freq_mhz * 1e6 / (enc_cycles as f64 * 1.12),
            watts: f.power_watts,
        },
        PlatformRow {
            platform: "PIM (sim)".into(),
            throughput: p.throughput,
            watts: p.chip_power_w,
        },
    ];
    print!("{}", comparison_table(&rows));
    println!("\n(paper: FPGA 81x and PIM 1177x over its TF+C CPU baseline; our rust CPU");
    println!(" encoder is far faster than that baseline, so measured ratios are smaller.)");
}
