//! Shared pipeline counters (lock-free; read by the reporting thread
//! while workers run).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

#[derive(Debug, Default)]
pub struct PipelineStats {
    pub records_read: AtomicU64,
    pub records_encoded: AtomicU64,
    pub records_trained: AtomicU64,
    pub batches_trained: AtomicU64,
    /// Nanoseconds spent inside encode calls (summed across workers).
    pub encode_ns: AtomicU64,
    /// Nanoseconds spent inside the trainer (SGD or PJRT execute).
    pub train_ns: AtomicU64,
    /// Times a bounded channel send blocked (backpressure events).
    pub backpressure_events: AtomicU64,
    /// Batches a worker took from a sibling's deque (work stealing).
    pub batches_stolen: AtomicU64,
    /// Batches routed through the global injector because the round-robin
    /// target deque was full (skew overflow).
    pub injector_batches: AtomicU64,
    /// Encoding buffers returned to a worker's scratch pool through the
    /// consumer→worker recycle channel.
    pub buffers_recycled: AtomicU64,
    /// Consumed batches whose buffers were dropped instead of recycled
    /// (recycle channel full or already closed).
    pub recycle_misses: AtomicU64,
    /// Encode-body panics caught at the worker loop boundary (each one
    /// fails exactly its batch; the worker rebuilds its encoder from the
    /// seed and keeps serving).
    pub worker_panics: AtomicU64,
    /// Workers that exceeded [`super::CoordinatorCfg::max_worker_panics`]
    /// and retired from the pool.
    pub workers_retired: AtomicU64,
    /// Batches delivered with [`super::EncodedBatch::failed`] set (their
    /// requests/records were not encoded).
    pub batches_failed: AtomicU64,
    /// Encoder instances constructed across the worker pool: lazy
    /// per-(worker × model) cache fills plus post-panic respawns. With
    /// hash-defined encoders a build is cheap (seeds, not codebooks) —
    /// this counter is how the multi-tenant registry proves per-model
    /// encoder state stays nearly free.
    pub encoder_builds: AtomicU64,
    /// Workers currently in the pool: set to the worker count when the
    /// pipeline starts, decremented when a worker retires past its
    /// panic budget. A gauge (not a monotone counter) — meaningful
    /// while the pipeline runs, mirrored into `obs::Tracer` for
    /// mid-run observability snapshots.
    pub live_workers: AtomicU64,
}

impl PipelineStats {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&self, counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            records_read: self.records_read.load(Ordering::Relaxed),
            records_encoded: self.records_encoded.load(Ordering::Relaxed),
            records_trained: self.records_trained.load(Ordering::Relaxed),
            batches_trained: self.batches_trained.load(Ordering::Relaxed),
            encode_ns: self.encode_ns.load(Ordering::Relaxed),
            train_ns: self.train_ns.load(Ordering::Relaxed),
            backpressure_events: self.backpressure_events.load(Ordering::Relaxed),
            batches_stolen: self.batches_stolen.load(Ordering::Relaxed),
            injector_batches: self.injector_batches.load(Ordering::Relaxed),
            buffers_recycled: self.buffers_recycled.load(Ordering::Relaxed),
            recycle_misses: self.recycle_misses.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            workers_retired: self.workers_retired.load(Ordering::Relaxed),
            batches_failed: self.batches_failed.load(Ordering::Relaxed),
            encoder_builds: self.encoder_builds.load(Ordering::Relaxed),
            live_workers: self.live_workers.load(Ordering::Relaxed),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub records_read: u64,
    pub records_encoded: u64,
    pub records_trained: u64,
    pub batches_trained: u64,
    pub encode_ns: u64,
    pub train_ns: u64,
    pub backpressure_events: u64,
    pub batches_stolen: u64,
    pub injector_batches: u64,
    pub buffers_recycled: u64,
    pub recycle_misses: u64,
    pub worker_panics: u64,
    pub workers_retired: u64,
    pub batches_failed: u64,
    pub encoder_builds: u64,
    pub live_workers: u64,
}

impl StatsSnapshot {
    pub fn encode_throughput(&self) -> f64 {
        if self.encode_ns == 0 {
            return 0.0;
        }
        self.records_encoded as f64 * 1e9 / self.encode_ns as f64
    }

    pub fn train_throughput(&self) -> f64 {
        if self.train_ns == 0 {
            return 0.0;
        }
        self.records_trained as f64 * 1e9 / self.train_ns as f64
    }
}

/// Scope timer that adds its elapsed nanoseconds to a counter on drop.
pub struct ScopeTimer<'a> {
    counter: &'a AtomicU64,
    start: Instant,
}

impl<'a> ScopeTimer<'a> {
    pub fn new(counter: &'a AtomicU64) -> Self {
        ScopeTimer { counter, start: Instant::now() }
    }
}

impl Drop for ScopeTimer<'_> {
    fn drop(&mut self) {
        self.counter
            .fetch_add(self.start.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let s = PipelineStats::new();
        s.add(&s.records_read, 10);
        s.add(&s.records_read, 5);
        s.add(&s.records_encoded, 7);
        s.add(&s.batches_stolen, 2);
        s.add(&s.buffers_recycled, 9);
        s.add(&s.injector_batches, 1);
        s.add(&s.recycle_misses, 3);
        s.add(&s.worker_panics, 4);
        s.add(&s.workers_retired, 1);
        s.add(&s.batches_failed, 4);
        let snap = s.snapshot();
        assert_eq!(snap.records_read, 15);
        assert_eq!(snap.records_encoded, 7);
        assert_eq!(snap.batches_stolen, 2);
        assert_eq!(snap.buffers_recycled, 9);
        assert_eq!(snap.injector_batches, 1);
        assert_eq!(snap.recycle_misses, 3);
        assert_eq!(snap.worker_panics, 4);
        assert_eq!(snap.workers_retired, 1);
        assert_eq!(snap.batches_failed, 4);
    }

    #[test]
    fn scope_timer_records_time() {
        let s = PipelineStats::new();
        {
            let _t = ScopeTimer::new(&s.encode_ns);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(s.snapshot().encode_ns >= 4_000_000);
    }

    #[test]
    fn throughput_math() {
        let snap = StatsSnapshot {
            records_read: 0,
            records_encoded: 1000,
            records_trained: 500,
            batches_trained: 2,
            encode_ns: 1_000_000_000,
            train_ns: 500_000_000,
            backpressure_events: 0,
            batches_stolen: 0,
            injector_batches: 0,
            buffers_recycled: 0,
            recycle_misses: 0,
            worker_panics: 0,
            workers_retired: 0,
            batches_failed: 0,
            encoder_builds: 0,
            live_workers: 0,
        };
        assert!((snap.encode_throughput() - 1000.0).abs() < 1e-9);
        assert!((snap.train_throughput() - 1000.0).abs() < 1e-9);
    }
}
