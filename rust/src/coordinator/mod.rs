//! Layer-3 streaming coordinator.
//!
//! The paper's setting is a continuous stream of mixed-type records
//! (Sec. 3); the coordination work is: shard the stream across encoder
//! workers, keep every worker's hash-defined encoder state identical,
//! apply backpressure so a slow trainer throttles readers instead of
//! buffering unboundedly, and deliver encoded batches to the learner
//! in deterministic order.
//!
//! Implementation: std threads + bounded `sync_channel`s (tokio is not
//! available offline; the pipeline is CPU-bound so threads are the right
//! tool anyway). Stages:
//!
//! ```text
//!           ┌─► raw channel 0 (bounded) ─► worker 0 ─┐
//!  reader ──┼─► raw channel 1 (bounded) ─► worker 1 ─┼─► encoded channel
//!           └─► raw channel N (bounded) ─► worker N ─┘   └► reorderer ─► consumer
//! ```
//!
//! Each worker owns a private bounded channel and the reader dispatches
//! batches round-robin (§Perf): the previous design funneled all workers
//! through one `Arc<Mutex<Receiver>>`, so every batch handoff serialized
//! on the mutex and worker scaling flattened right where the paper
//! promises linearity. With per-worker channels the handoff is
//! contention-free; `queue_depth` bounds each worker's private queue, so
//! backpressure still propagates to the reader when any worker falls
//! behind (round-robin means the stream can't run ahead of the slowest
//! worker by more than `n_workers * queue_depth` batches).
//!
//! Batches carry sequence numbers; the tail reorders them so the
//! consumer sees stream order regardless of worker scheduling — making
//! multi-worker runs bit-identical to single-worker runs.

pub mod encoder;
pub mod stats;

pub use encoder::{CatCfg, EncoderCfg, NumCfg, RecordEncoder};
pub use stats::{PipelineStats, ScopeTimer, StatsSnapshot};

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread;

use crate::data::{Record, RecordStream};
use crate::encoding::Encoding;

/// A batch of encoded records plus labels, tagged with its stream order.
#[derive(Debug)]
pub struct EncodedBatch {
    pub seq: u64,
    pub encodings: Vec<Encoding>,
    pub labels: Vec<bool>,
    /// Raw records retained when the consumer needs them (PJRT fused path
    /// encodes numerics on-device and needs the raw features).
    pub records: Option<Vec<Record>>,
}

#[derive(Clone, Debug)]
pub struct CoordinatorCfg {
    pub batch_size: usize,
    pub n_workers: usize,
    /// Bounded-queue depth (in batches) between stages.
    pub queue_depth: usize,
    /// Retain raw records in the output batches.
    pub keep_records: bool,
    /// Stop after this many records (None = until stream end).
    pub max_records: Option<u64>,
}

impl Default for CoordinatorCfg {
    fn default() -> Self {
        CoordinatorCfg {
            batch_size: 256,
            n_workers: 4,
            queue_depth: 8,
            keep_records: false,
            max_records: None,
        }
    }
}

struct RawBatch {
    seq: u64,
    records: Vec<Record>,
}

/// Blocking send that counts backpressure events.
fn send_counted<T>(tx: &SyncSender<T>, mut v: T, stats: &PipelineStats) -> Result<(), ()> {
    loop {
        match tx.try_send(v) {
            Ok(()) => return Ok(()),
            Err(TrySendError::Full(back)) => {
                stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
                v = back;
                // Fall back to the blocking path once counted.
                return tx.send(v).map_err(|_| ());
            }
            Err(TrySendError::Disconnected(_)) => return Err(()),
        }
    }
}

/// Run the coordinated encode pipeline, invoking `consume` for each
/// encoded batch in stream order; `consume` returns `false` to stop the
/// pipeline early (early stopping, record budgets). Returns the shared
/// stats.
///
/// `encoder_cfg.build()` is called once per worker; because encoders are
/// deterministic from the seed, every worker holds an identical encoder
/// (the paper's "no codebook to synchronize" property makes this free
/// for hash-based encoders — only the codebook baseline pays per-worker
/// duplication, which is itself part of the scalability story).
pub fn run_pipeline<S, F>(
    mut stream: S,
    encoder_cfg: &EncoderCfg,
    cfg: &CoordinatorCfg,
    mut consume: F,
) -> Arc<PipelineStats>
where
    S: RecordStream + 'static,
    F: FnMut(EncodedBatch) -> bool,
{
    let stats = Arc::new(PipelineStats::new());
    let n_workers = cfg.n_workers.max(1);
    // Per-worker private bounded channels — no shared-receiver mutex.
    let mut raw_txs = Vec::with_capacity(n_workers);
    let mut raw_rxs = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        let (tx, rx) = sync_channel::<RawBatch>(cfg.queue_depth);
        raw_txs.push(tx);
        raw_rxs.push(rx);
    }
    let (enc_tx, enc_rx) = sync_channel::<EncodedBatch>(cfg.queue_depth);

    // --- reader ---------------------------------------------------------
    let reader_stats = Arc::clone(&stats);
    let reader_cfg = cfg.clone();
    let reader = thread::spawn(move || {
        let mut seq = 0u64;
        let mut emitted = 0u64;
        loop {
            let budget = match reader_cfg.max_records {
                Some(maxn) if emitted >= maxn => break,
                Some(maxn) => ((maxn - emitted) as usize).min(reader_cfg.batch_size),
                None => reader_cfg.batch_size,
            };
            let mut batch = Vec::with_capacity(budget);
            if stream.next_batch(&mut batch, budget) == 0 {
                break;
            }
            emitted += batch.len() as u64;
            reader_stats
                .records_read
                .fetch_add(batch.len() as u64, Ordering::Relaxed);
            // Round-robin dispatch: seq mod N picks the worker, so batch
            // assignment is deterministic (the reorderer makes output
            // order-independent anyway, but determinism keeps per-worker
            // encoder state — the codebook baseline — reproducible too).
            let tx = &raw_txs[(seq % raw_txs.len() as u64) as usize];
            if send_counted(tx, RawBatch { seq, records: batch }, &reader_stats).is_err() {
                // A worker disappeared: only happens on early stop (or a
                // worker panic); stop reading.
                break;
            }
            seq += 1;
        }
        // raw_txs drop here -> each worker drains its queue and exits.
    });

    // --- encode workers --------------------------------------------------
    let mut workers = Vec::new();
    for rx in raw_rxs {
        let tx = enc_tx.clone();
        let wstats = Arc::clone(&stats);
        let ecfg = encoder_cfg.clone();
        let keep = cfg.keep_records;
        workers.push(thread::spawn(move || {
            let mut enc = ecfg.build();
            // The encoder's internal scratch recycles all intermediate
            // buffers; the output buffers are owned by the consumer once
            // the batch crosses the channel.
            let mut encodings = Vec::new();
            for raw in rx {
                let n = raw.records.len() as u64;
                let labels: Vec<bool> = raw.records.iter().map(|r| r.label).collect();
                {
                    let _t = ScopeTimer::new(&wstats.encode_ns);
                    enc.encode_batch_into(&raw.records, &mut encodings);
                }
                wstats.records_encoded.fetch_add(n, Ordering::Relaxed);
                let out = EncodedBatch {
                    seq: raw.seq,
                    encodings: std::mem::take(&mut encodings),
                    labels,
                    records: if keep { Some(raw.records) } else { None },
                };
                if send_counted(&tx, out, &wstats).is_err() {
                    break;
                }
            }
            // rx drops here; a reader blocked on this worker's full
            // queue sees the disconnect and stops.
        }));
    }
    drop(enc_tx); // consumers see EOF when all workers finish

    // --- in-order consumption -------------------------------------------
    consume_in_order(enc_rx, &mut consume);

    reader.join().expect("reader panicked");
    for w in workers {
        w.join().expect("worker panicked");
    }
    stats
}

/// Reorder batches by sequence number before invoking the consumer.
/// Returns early (dropping the receiver, which unwinds the upstream
/// stages via send errors) if the consumer asks to stop.
fn consume_in_order<F: FnMut(EncodedBatch) -> bool>(rx: Receiver<EncodedBatch>, consume: &mut F) {
    let mut next = 0u64;
    let mut pending: BTreeMap<u64, EncodedBatch> = BTreeMap::new();
    for batch in rx {
        pending.insert(batch.seq, batch);
        while let Some(b) = pending.remove(&next) {
            if !consume(b) {
                return; // rx drops; workers/reader see disconnects
            }
            next += 1;
        }
    }
    // Channel closed: drain whatever is contiguous (should be everything).
    while let Some(b) = pending.remove(&next) {
        if !consume(b) {
            return;
        }
        next += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic::SyntheticConfig, SyntheticStream};
    use crate::encoding::BundleMethod;

    fn small_cfg() -> EncoderCfg {
        EncoderCfg {
            cat: CatCfg::Bloom { d: 256, k: 2 },
            num: NumCfg::None,
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 1,
        }
    }

    #[test]
    fn processes_exactly_max_records_in_order() {
        let stream = SyntheticStream::new(SyntheticConfig::sampled(3));
        let mut seen = Vec::new();
        let stats = run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg {
                batch_size: 32,
                n_workers: 4,
                max_records: Some(1000),
                ..Default::default()
            },
            |b| { seen.push((b.seq, b.encodings.len())); true },
        );
        let total: usize = seen.iter().map(|(_, n)| n).sum();
        assert_eq!(total, 1000);
        let seqs: Vec<u64> = seen.iter().map(|(s, _)| *s).collect();
        let mut sorted = seqs.clone();
        sorted.sort();
        assert_eq!(seqs, sorted, "batches must arrive in stream order");
        assert_eq!(stats.snapshot().records_encoded, 1000);
        assert_eq!(stats.snapshot().records_read, 1000);
    }

    #[test]
    fn multi_worker_equals_single_worker() {
        let collect = |workers: usize| {
            let stream = SyntheticStream::new(SyntheticConfig::sampled(4));
            let mut encs = Vec::new();
            run_pipeline(
                stream,
                &small_cfg(),
                &CoordinatorCfg {
                    batch_size: 16,
                    n_workers: workers,
                    max_records: Some(200),
                    ..Default::default()
                },
                |b| { encs.extend(b.encodings); true },
            );
            encs
        };
        assert_eq!(collect(1), collect(6));
    }

    #[test]
    fn multi_worker_equals_single_worker_with_numeric_branch() {
        // Exercises the per-worker-channel dispatch with both encoder
        // branches live (numeric batch path + categorical scratch path).
        let enc_cfg = EncoderCfg {
            cat: CatCfg::Bloom { d: 256, k: 2 },
            num: NumCfg::Sjlt { d: 128, k: 4 },
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 9,
        };
        let collect = |workers: usize| {
            let stream = SyntheticStream::new(SyntheticConfig::sampled(9));
            let mut encs = Vec::new();
            run_pipeline(
                stream,
                &enc_cfg,
                &CoordinatorCfg {
                    batch_size: 16,
                    n_workers: workers,
                    max_records: Some(300),
                    ..Default::default()
                },
                |b| {
                    encs.extend(b.encodings);
                    true
                },
            );
            encs
        };
        assert_eq!(collect(1), collect(4));
    }

    #[test]
    fn more_workers_than_batches() {
        // Idle workers (empty private queues) must drain and join cleanly.
        let stream = SyntheticStream::new(SyntheticConfig::sampled(10));
        let mut total = 0usize;
        let stats = run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg {
                batch_size: 32,
                n_workers: 8,
                max_records: Some(64),
                ..Default::default()
            },
            |b| {
                total += b.encodings.len();
                true
            },
        );
        assert_eq!(total, 64);
        assert_eq!(stats.snapshot().records_encoded, 64);
    }

    #[test]
    fn keep_records_carries_raw_data() {
        let stream = SyntheticStream::new(SyntheticConfig::sampled(5));
        let mut n_rec = 0usize;
        run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg {
                batch_size: 10,
                n_workers: 2,
                keep_records: true,
                max_records: Some(50),
                ..Default::default()
            },
            |b| {
                let recs = b.records.expect("records kept");
                assert_eq!(recs.len(), b.encodings.len());
                n_rec += recs.len();
                true
            },
        );
        assert_eq!(n_rec, 50);
    }

    #[test]
    fn backpressure_counted_with_slow_consumer() {
        let stream = SyntheticStream::new(SyntheticConfig::sampled(6));
        let stats = run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg {
                batch_size: 8,
                n_workers: 4,
                queue_depth: 1,
                max_records: Some(400),
                ..Default::default()
            },
            |_| { std::thread::sleep(std::time::Duration::from_micros(500)); true },
        );
        assert!(
            stats.snapshot().backpressure_events > 0,
            "tiny queue + slow consumer must trigger backpressure"
        );
    }

    #[test]
    fn consumer_can_stop_early() {
        let stream = SyntheticStream::new(SyntheticConfig::sampled(8));
        let mut batches = 0usize;
        run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg { batch_size: 8, n_workers: 3, max_records: Some(10_000), ..Default::default() },
            |_| {
                batches += 1;
                batches < 5
            },
        );
        assert_eq!(batches, 5, "pipeline must halt when consumer returns false");
    }

    #[test]
    fn labels_align_with_encodings() {
        let stream = SyntheticStream::new(SyntheticConfig::sampled(7));
        run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg { batch_size: 64, max_records: Some(128), ..Default::default() },
            |b| { assert_eq!(b.labels.len(), b.encodings.len()); true },
        );
    }
}
