//! Layer-3 streaming coordinator.
//!
//! The paper's setting is a continuous stream of mixed-type records
//! (Sec. 3); the coordination work is: shard the stream across encoder
//! workers, keep every worker's hash-defined encoder state identical,
//! apply backpressure so a slow trainer throttles readers instead of
//! buffering unboundedly, and deliver encoded batches to the learner
//! in deterministic order.
//!
//! Implementation: std threads + work-stealing deques + bounded
//! `sync_channel`s (tokio is not available offline; the pipeline is
//! CPU-bound so threads are the right tool anyway). Stages:
//!
//! ```text
//!           ┌─► deque 0 (bounded) ──► worker 0 ─┐
//!  reader ──┼─► deque 1 (bounded) ──► worker 1 ─┼─► encoded channel
//!     ▲     ├─► deque N (bounded) ──► worker N ─┤    └► seq reorderer ─► consumer
//!     │     └─► injector  (bounded overflow) ───┘         │ &mut batch
//!     │              ▲         idle workers steal          ▼
//!     │              └── siblings' deque backs ◄── recycle channel
//!     └──────── record-spine returns ◄─────────────  (consumer → workers)
//!
//!  The serving subsystem plugs into both ends of the same pipeline
//!  (`crate::serve`): the reader's stream is the request micro-batcher
//!  and the consumer is the AM scorer — no serving-specific dispatch:
//!
//!  clients ─► submission queue ─► RequestStream ──► reader (above)
//!     ▲        (bounded)           (size/idle/deadline cut; one
//!     │                             `Pending` per request, in order)
//!     └── completion slots ◄── consumer: `am::AmStore` top-1 over
//!         (responses + recycled      f32 / int8 / binarized prototypes
//!          record buffers)           + latency/queue-depth stats
//!
//!  Span edges (sampled requests, `crate::obs`): the same seams carry
//!  the stage-span timestamps —
//!
//!    submit ─[admission]─ t_enqueue ─[queue]─ t_cut ─[dispatch incl.
//!    t_pop/steal]─ t_encode_start ─[encode = the catch_unwind body]─
//!    t_encode_end ─[reorder]─ t_scan_start ─[scan]─ t_scan_end
//!    ─[complete]─ t_complete
//!
//!  Workers stamp pop/encode edges onto `EncodedBatch::stamps` when
//!  `CoordinatorCfg::obs` is wired with tracing enabled; the serve
//!  consumer assembles the full trace per sampled request.
//!
//!  Monitoring (`crate::obs::export`) taps the same counters from the
//!  outside — nothing on this diagram waits on it:
//!
//!  serve counters + tracer gauges ─► MetricsPublisher (interval tick)
//!      ─► sample ring ─► windowed rates + SLO verdict + event ring
//!      ─► GET /metrics · /health · /snapshot  (exporter listener)
//! ```
//!
//! **Dispatch (§Perf).** The reader round-robins batches onto per-worker
//! bounded deques (`Mutex<VecDeque>`, one per worker: the mutex guards a
//! single push/pop — nanoseconds against a millisecond-scale batch
//! encode, so the data path stays effectively contention-free, which is
//! what the previous per-worker-channel design bought). Unlike static
//! round-robin, a worker that runs dry does not idle behind a whale
//! batch elsewhere: it pops the global injector (fed when a target deque
//! overflows) and then *steals* from the back of the longest sibling
//! deque. Skewed streams (ragged categorical sets) therefore keep every
//! worker busy instead of letting one stalled worker gate the stream.
//! Total in-flight work stays bounded by the deques plus the injector,
//! so backpressure still propagates to the reader when all workers fall
//! behind. Parking/wakeup goes through one small control mutex (`ctl`)
//! locked only on the notify edge of a push/pop — never across an
//! encode.
//!
//! **Determinism.** Batches carry sequence numbers; the tail reorders
//! them so the consumer sees stream order regardless of which worker
//! encoded what. Because every worker builds an identical encoder from
//! the seed and encoding is a pure function of the record (codebook
//! codewords are keyed by (seed, symbol), not arrival order), any steal
//! interleaving yields bit-identical output to a single-worker run —
//! enforced by `tests/coordinator_stealing.rs` under adversarial skew.
//!
//! **Buffer recycling (§Perf).** Consumers receive `&mut EncodedBatch`;
//! whatever buffers they leave in the batch are shipped back to the
//! workers over a bounded recycle channel and returned to each worker's
//! [`crate::encoding::EncodeScratch`] pool, and the raw-record spines
//! flow further back to the reader, which refills them in place
//! ([`RecordStream::next_batch_into`]). After warmup the whole
//! reader → encode → consume loop runs with **zero steady-state
//! allocations** (pinned by `tests/alloc_regression.rs`); a consumer
//! that takes ownership (`drain(..)`) simply opts those buffers out.
//!
//! **Fault tolerance (§Robustness).** A panic inside the encode body is
//! caught at the worker loop boundary (`catch_unwind`), so one bad batch
//! cannot strand the pipeline:
//!
//! ```text
//!  worker wid: pop batch seq=s ──► catch_unwind { encode }   ──ok──► EncodedBatch{s}
//!                                      │ panic                          (normal path)
//!                                      ▼
//!                    EncodedBatch { seq: s, failed: true, encodings: [],
//!                                   labels: one per record }
//!                                      │  (the reorderer still sees seq s,
//!                                      ▼   so stream order never stalls)
//!                    consumer observes `failed` and fails that batch's
//!                    requests explicitly (serve: ServeError::Internal)
//!
//!  after the failed send: worker_panics += 1, the worker rebuilds its
//!  encoder from the seed (hash-defined state — "respawn" is free) and
//!  keeps serving; past `max_worker_panics` it *retires* instead
//!  (workers_retired += 1). When the last live worker retires the
//!  scheduler stops the pipeline (stop flag + condvar broadcast) so the
//!  reader and consumer unwind instead of parking forever.
//! ```
//!
//! Every lock in the pool follows the uniform poisoned-lock recovery
//! policy of [`crate::util::sync`] (recover the guard, never cascade a
//! `PoisonError`); deterministic fault injection for all of the above is
//! driven by [`FaultPlan`] and exercised by `tests/fault_injection.rs`.

pub mod encoder;
pub mod stats;

pub use encoder::{CatCfg, EncoderCfg, NumCfg, RecordEncoder};
pub use stats::{PipelineStats, ScopeTimer, StatsSnapshot};

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

use crate::data::{Record, RecordStream};
use crate::encoding::Encoding;
use crate::util::sync::{lock_unpoisoned, wait_unpoisoned};

/// A batch of encoded records plus labels, tagged with its stream order.
#[derive(Debug)]
pub struct EncodedBatch {
    pub seq: u64,
    /// Which encoder config (index into the set passed to
    /// [`run_pipeline_multi`]) encoded this batch — the routing key the
    /// multi-tenant serve consumer uses to pick the matching class
    /// store. Always `0` for [`run_pipeline`] (single-model) runs.
    pub model: u32,
    pub encodings: Vec<Encoding>,
    pub labels: Vec<bool>,
    /// Raw records retained when the consumer needs them (PJRT fused path
    /// encodes numerics on-device and needs the raw features).
    pub records: Option<Vec<Record>>,
    /// Index of the worker that encoded the batch; consumed shells are
    /// recycled back to this worker, so under skew (stealing) each pool
    /// receives returns in proportion to what that worker actually
    /// encoded — round-robin returns would starve fast workers' pools.
    pub(crate) origin: usize,
    /// The encode body panicked: `encodings` is empty, `labels` still
    /// holds one entry per record of the batch (so consumers know how
    /// many requests to fail), and the batch still occupies its sequence
    /// slot so the reorderer never stalls. Consumers that score or train
    /// must skip failed batches; the serve consumer completes each of
    /// their requests with an explicit `ServeError::Internal`.
    pub failed: bool,
    /// Batch-level observability stamps (pop / encode start / encode
    /// end, steal provenance), captured by the worker when
    /// [`CoordinatorCfg::obs`] is wired and tracing is enabled;
    /// all-zeros otherwise. Failed batches are stamped too (the encode
    /// span then covers entry→panic).
    pub stamps: crate::obs::BatchStamps,
}

/// Deterministic fault-injection plan — the test hook behind
/// `tests/fault_injection.rs` and the CI fault leg. All fields default
/// to "inject nothing"; production configs never set them. Faults key on
/// *stream state* (sequence numbers), not thread timing, so every
/// injected run is reproducible under any steal interleaving.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Panic inside the encode body of these stream sequence numbers
    /// (whichever worker picks the batch up). Each listed seq panics
    /// exactly once: the batch is failed downstream, never re-encoded.
    pub panic_on_seq: Vec<u64>,
    /// Worker `wid` sleeps for the duration once, before its first
    /// encode — a transient hard stall (distinct from
    /// [`CoordinatorCfg::slow_worker`], the per-batch drag used by the
    /// stealing tests): queued work must be stolen or must wait, and
    /// serve-side deadlines must expire instead of hanging.
    pub stall_once: Option<(usize, Duration)>,
    /// Discard every consumed batch shell instead of recycling it
    /// (simulates a lost/full recycle channel): the pipeline must fall
    /// back to the allocator and stay correct, counting
    /// `recycle_misses`.
    pub drop_recycle: bool,
    /// (Serve-side) the request micro-batcher sleeps once, before its
    /// first cut, so the bounded submission queue saturates: admission
    /// control must shed/timeout instead of wedging the clients.
    pub stall_batcher: Option<Duration>,
}

#[derive(Clone, Debug)]
pub struct CoordinatorCfg {
    pub batch_size: usize,
    pub n_workers: usize,
    /// Bounded-queue depth (in batches) between stages.
    pub queue_depth: usize,
    /// Retain raw records in the output batches.
    pub keep_records: bool,
    /// Stop after this many records (None = until stream end).
    pub max_records: Option<u64>,
    /// Test hook for forced-steal scenarios: worker `i` sleeps for the
    /// given duration before encoding each batch, so its deque backs up
    /// and siblings must steal. Leave `None` outside scheduler tests.
    pub slow_worker: Option<(usize, Duration)>,
    /// Raised (stored `true`) by the scheduler whenever the pipeline
    /// stops abnormally — a worker panic, or the consumer dropping out —
    /// so a *blocking* [`RecordStream`] (e.g. the serve subsystem's
    /// request batcher, which can park indefinitely waiting for traffic)
    /// has a flag to poll and unblock on instead of stranding the reader
    /// thread forever. Streams that never block (all the data-layer
    /// streams) can ignore it; leave `None` when unused.
    pub stop_flag: Option<Arc<std::sync::atomic::AtomicBool>>,
    /// Encode-body panics a single worker absorbs (fail the batch,
    /// rebuild the encoder from the seed, keep serving) before it
    /// *retires* from the pool. When the last live worker retires the
    /// scheduler stops the pipeline. Panics are per-worker, so the pool
    /// survives up to `n_workers * (max_worker_panics + 1)` of them.
    pub max_worker_panics: u32,
    /// Deterministic fault injection (tests/CI only); default injects
    /// nothing.
    pub fault: FaultPlan,
    /// Stage-span tracer shared with the serving layer. When present
    /// (and enabled) workers stamp each batch's pop/encode-start/
    /// encode-end edges and steal provenance into
    /// [`EncodedBatch::stamps`] *when the tracer has sampling enabled*,
    /// and worker retirement always moves the tracer's live-worker
    /// gauge (the serve monitoring publisher reads it even with tracing
    /// off, so serving wires this unconditionally). `None` (the default
    /// — training pipelines) costs one `Option` check per batch.
    pub obs: Option<Arc<crate::obs::Tracer>>,
}

impl Default for CoordinatorCfg {
    fn default() -> Self {
        CoordinatorCfg {
            batch_size: 256,
            n_workers: 4,
            queue_depth: 8,
            keep_records: false,
            max_records: None,
            slow_worker: None,
            stop_flag: None,
            max_worker_panics: 3,
            fault: FaultPlan::default(),
            obs: None,
        }
    }
}

struct RawBatch {
    seq: u64,
    /// Encoder-config index the stream routed this batch to
    /// ([`RecordStream::batch_model`]); batches are model-homogeneous.
    model: u32,
    records: Vec<Record>,
}

/// Work-stealing dispatch state shared by the reader and the workers.
///
/// Lock order is `ctl` → deque (the parking paths hold `ctl` while
/// peeking deques); no path ever acquires `ctl` while holding a deque
/// lock, so the order is acyclic. Every state change that can unblock a
/// parked thread notifies the matching condvar *while holding `ctl`*,
/// and every thread that parks re-checks its condition under `ctl`
/// before waiting — the classic recipe that makes lost wakeups
/// impossible (the notifier serializes behind the parker's critical
/// section or the parker sees the new state).
struct StealScheduler {
    /// Per-worker bounded deques: the owner pops the front, thieves take
    /// the back.
    queues: Vec<Mutex<VecDeque<RawBatch>>>,
    /// Global bounded overflow ring, popped by any worker.
    injector: Mutex<VecDeque<RawBatch>>,
    queue_depth: usize,
    injector_cap: usize,
    ctl: Mutex<Ctl>,
    /// Workers park here when no queue holds work.
    work_cv: Condvar,
    /// The reader parks here when its target deque and the injector are
    /// both full.
    space_cv: Condvar,
    /// Mirror of [`CoordinatorCfg::stop_flag`]: raised on [`Self::stop`]
    /// so blocking streams can observe abnormal termination.
    stop_flag: Option<Arc<std::sync::atomic::AtomicBool>>,
}

#[derive(Default)]
struct Ctl {
    /// The reader is done; no further pushes will ever arrive.
    eof: bool,
    /// The consumer stopped early; every stage unwinds.
    stopped: bool,
    /// Workers still pulling from the deques. Decremented only by
    /// retirement ([`StealScheduler::retire`]); when it reaches zero the
    /// scheduler stops the pipeline, because batches left in the deques
    /// can never be encoded and the reader/consumer must not park
    /// behind them forever.
    live_workers: usize,
}

/// What `try_take` popped: the batch, whether it came from a sibling's
/// deque (a steal), and whether the source queue was full before the pop
/// (i.e. the pop may have unblocked a parked reader).
type Taken = (RawBatch, bool, bool);

impl StealScheduler {
    fn new(
        n_workers: usize,
        queue_depth: usize,
        stop_flag: Option<Arc<std::sync::atomic::AtomicBool>>,
    ) -> StealScheduler {
        let queues = (0..n_workers)
            .map(|_| Mutex::new(VecDeque::with_capacity(queue_depth)))
            .collect();
        let injector_cap = (n_workers * queue_depth).max(1);
        StealScheduler {
            queues,
            injector: Mutex::new(VecDeque::with_capacity(injector_cap)),
            queue_depth,
            injector_cap,
            ctl: Mutex::new(Ctl { live_workers: n_workers, ..Ctl::default() }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            stop_flag,
        }
    }

    /// Non-blocking push: `target`'s deque first, overflowing into the
    /// injector. Returns the batch when both are full.
    fn try_push(
        &self,
        target: usize,
        batch: RawBatch,
        stats: &PipelineStats,
    ) -> Result<(), RawBatch> {
        {
            let mut q = lock_unpoisoned(&self.queues[target]);
            if q.len() < self.queue_depth {
                q.push_back(batch);
                return Ok(());
            }
        }
        let mut inj = lock_unpoisoned(&self.injector);
        if inj.len() < self.injector_cap {
            inj.push_back(batch);
            drop(inj);
            stats.injector_batches.fetch_add(1, Ordering::Relaxed);
            Ok(())
        } else {
            Err(batch)
        }
    }

    /// Blocking push with backpressure accounting. `Err(())` when the
    /// pipeline stopped early.
    fn push(&self, target: usize, batch: RawBatch, stats: &PipelineStats) -> Result<(), ()> {
        let mut batch = match self.try_push(target, batch, stats) {
            Ok(()) => {
                self.notify_work();
                return Ok(());
            }
            Err(b) => b,
        };
        stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
        let mut ctl = lock_unpoisoned(&self.ctl);
        loop {
            if ctl.stopped {
                return Err(());
            }
            match self.try_push(target, batch, stats) {
                Ok(()) => {
                    // Holding ctl, so a worker cannot slip into a park
                    // between this push and the notify.
                    self.work_cv.notify_one();
                    return Ok(());
                }
                Err(b) => batch = b,
            }
            ctl = wait_unpoisoned(&self.space_cv, ctl);
        }
    }

    fn notify_work(&self) {
        let _ctl = lock_unpoisoned(&self.ctl);
        self.work_cv.notify_one();
    }

    fn notify_space(&self) {
        let _ctl = lock_unpoisoned(&self.ctl);
        self.space_cv.notify_all();
    }

    /// One batch for worker `wid`: own deque front, else injector front,
    /// else the back of the longest sibling deque (a steal).
    fn try_take(&self, wid: usize) -> Option<Taken> {
        {
            let mut q = lock_unpoisoned(&self.queues[wid]);
            let was_full = q.len() == self.queue_depth;
            if let Some(b) = q.pop_front() {
                return Some((b, false, was_full));
            }
        }
        {
            let mut inj = lock_unpoisoned(&self.injector);
            let was_full = inj.len() == self.injector_cap;
            if let Some(b) = inj.pop_front() {
                return Some((b, false, was_full));
            }
        }
        // Pick the most backed-up victim, then re-lock and take from the
        // back (the victim keeps its cheap front-pop path; output order
        // is irrelevant here — the seq reorderer restores stream order).
        let mut victim = None;
        let mut best = 0usize;
        for (i, q) in self.queues.iter().enumerate() {
            if i == wid {
                continue;
            }
            let len = lock_unpoisoned(q).len();
            if len > best {
                best = len;
                victim = Some(i);
            }
        }
        if let Some(v) = victim {
            let mut q = lock_unpoisoned(&self.queues[v]);
            let was_full = q.len() == self.queue_depth;
            if let Some(b) = q.pop_back() {
                return Some((b, true, was_full));
            }
        }
        None
    }

    /// Blocking pop for worker `wid`. `None` once the stream is fully
    /// drained after EOF, or immediately on early stop. The flag in the
    /// pair is the steal provenance: `true` when the batch came off a
    /// sibling's deque (also counted in `batches_stolen`), surfaced so
    /// the tracer can tag spans with it.
    fn pop(&self, wid: usize, stats: &PipelineStats) -> Option<(RawBatch, bool)> {
        let taken = self.try_take(wid).or_else(|| {
            let mut ctl = lock_unpoisoned(&self.ctl);
            loop {
                if ctl.stopped {
                    return None;
                }
                if let Some(t) = self.try_take(wid) {
                    return Some(t);
                }
                if ctl.eof {
                    return None;
                }
                ctl = wait_unpoisoned(&self.work_cv, ctl);
            }
        });
        let (batch, stolen, was_full) = taken?;
        if stolen {
            stats.batches_stolen.fetch_add(1, Ordering::Relaxed);
        }
        if was_full {
            // Freed a slot in a queue that was at capacity — the reader
            // may be parked on exactly that condition.
            self.notify_space();
        }
        Some((batch, stolen))
    }

    fn set_eof(&self) {
        let mut ctl = lock_unpoisoned(&self.ctl);
        ctl.eof = true;
        self.work_cv.notify_all();
    }

    fn stop(&self) {
        let ctl = lock_unpoisoned(&self.ctl);
        self.stop_locked(ctl);
    }

    fn stop_locked(&self, mut ctl: std::sync::MutexGuard<'_, Ctl>) {
        ctl.stopped = true;
        if let Some(flag) = &self.stop_flag {
            // Visible to blocking streams (which poll it with a bounded
            // wait), so a dead pipeline can never strand the reader
            // inside the stream's own park.
            flag.store(true, Ordering::Release);
        }
        self.work_cv.notify_all();
        self.space_cv.notify_all();
    }

    /// A worker leaves the pool after exhausting its panic budget. The
    /// last live worker to retire stops the pipeline: batches still in
    /// the deques can never be encoded, so the reader and the consumer
    /// must unwind instead of parking behind them.
    fn retire(&self) {
        let mut ctl = lock_unpoisoned(&self.ctl);
        ctl.live_workers = ctl.live_workers.saturating_sub(1);
        if ctl.live_workers == 0 && !ctl.stopped {
            self.stop_locked(ctl);
        }
    }
}

/// Marks EOF when the reader thread exits — normally *or* by panic — so
/// workers never park forever behind a dead reader.
struct EofOnDrop(Arc<StealScheduler>);

impl Drop for EofOnDrop {
    fn drop(&mut self) {
        self.0.set_eof();
    }
}

/// Stops the pipeline if a worker thread unwinds, so the reader and its
/// siblings never park behind a dead worker. Normal exits do nothing.
struct StopOnPanic(Arc<StealScheduler>);

impl Drop for StopOnPanic {
    fn drop(&mut self) {
        if thread::panicking() {
            self.0.stop();
        }
    }
}

/// Blocking send that counts backpressure events.
fn send_counted<T>(tx: &SyncSender<T>, mut v: T, stats: &PipelineStats) -> Result<(), ()> {
    loop {
        match tx.try_send(v) {
            Ok(()) => return Ok(()),
            Err(TrySendError::Full(back)) => {
                stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
                v = back;
                // Fall back to the blocking path once counted.
                return tx.send(v).map_err(|_| ());
            }
            Err(TrySendError::Disconnected(_)) => return Err(()),
        }
    }
}

/// Run the coordinated encode pipeline, invoking `consume` for each
/// encoded batch in stream order; `consume` returns `false` to stop the
/// pipeline early (early stopping, record budgets). Returns the shared
/// stats.
///
/// The consumer borrows each batch (`&mut EncodedBatch`): buffers it
/// leaves in place are recycled back into the worker pools, closing the
/// allocation loop across the thread boundary. Take ownership with
/// `batch.encodings.drain(..)` (etc.) when the contents must outlive the
/// call — those buffers are then simply replaced by fresh allocations.
///
/// `encoder_cfg.build()` is called once per worker; because encoders are
/// deterministic from the seed, every worker holds an identical encoder
/// (the paper's "no codebook to synchronize" property makes this free
/// for hash-based encoders — only the codebook baseline pays per-worker
/// duplication, which is itself part of the scalability story).
pub fn run_pipeline<S, F>(
    stream: S,
    encoder_cfg: &EncoderCfg,
    cfg: &CoordinatorCfg,
    consume: F,
) -> Arc<PipelineStats>
where
    S: RecordStream + 'static,
    F: FnMut(&mut EncodedBatch) -> bool,
{
    run_pipeline_multi(stream, std::slice::from_ref(encoder_cfg), cfg, consume)
}

/// Multi-model variant of [`run_pipeline`]: one worker pool serves any
/// number of encoder configurations. The stream routes each batch via
/// [`RecordStream::batch_model`] (an index into `encoder_cfgs`; batches
/// must be model-homogeneous — the serve micro-batcher cuts them that
/// way), and every worker holds a **lazy per-model encoder cache**: an
/// encoder is built from its seed the first time that worker encodes a
/// batch for that model (counted in `StatsSnapshot::encoder_builds`).
/// This is the paper's scalability claim made operational — hash-defined
/// encoder state is just seeds, so serving N tenants from one pool costs
/// N small encoder rebuilds per worker, not N synchronized codebooks.
/// Panic recovery is per model: a worker that panics mid-encode respawns
/// only the routed model's encoder and keeps serving every other tenant
/// untouched.
pub fn run_pipeline_multi<S, F>(
    mut stream: S,
    encoder_cfgs: &[EncoderCfg],
    cfg: &CoordinatorCfg,
    mut consume: F,
) -> Arc<PipelineStats>
where
    S: RecordStream + 'static,
    F: FnMut(&mut EncodedBatch) -> bool,
{
    assert!(!encoder_cfgs.is_empty(), "run_pipeline_multi needs at least one encoder config");
    let n_models = encoder_cfgs.len() as u32;
    let stats = Arc::new(PipelineStats::new());
    let n_workers = cfg.n_workers.max(1);
    // Live-worker gauge: full pool at start, decremented at retirement
    // (mirrored into the tracer, which serving can read mid-run).
    stats.live_workers.store(n_workers as u64, Ordering::Relaxed);
    if let Some(obs) = &cfg.obs {
        obs.set_live_workers(n_workers as u64);
    }
    let queue_depth = cfg.queue_depth.max(1);
    let sched = Arc::new(StealScheduler::new(n_workers, queue_depth, cfg.stop_flag.clone()));
    let (enc_tx, enc_rx) = sync_channel::<EncodedBatch>(queue_depth);
    // Recycle path (consumer → workers): consumed batch shells return to
    // a worker, which drains the encoding buffers into its scratch pool.
    // Bounded + try_send so a stalled worker can never block the
    // consumer; overflow just falls back to the allocator. Capacity
    // covers a full reorder-backlog burst landing on one worker, so in
    // steady state nothing is ever dropped.
    let mut ret_txs = Vec::with_capacity(n_workers);
    let mut ret_rxs = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        let (tx, rx) = sync_channel::<EncodedBatch>(4 * queue_depth + 8);
        ret_txs.push(tx);
        ret_rxs.push(rx);
    }
    // Record-spine path (workers → reader): raw-record vectors go back to
    // be refilled in place. Capacity covers every spine that can be in
    // flight at once (deques + injector + one per worker + slack) so
    // steady state never drops one.
    let spine_cap = (2 * n_workers + 2) * (queue_depth + 2);
    let (spine_tx, spine_rx) = sync_channel::<Vec<Record>>(spine_cap);

    // --- reader ---------------------------------------------------------
    let reader_stats = Arc::clone(&stats);
    let reader_cfg = cfg.clone();
    let reader_sched = Arc::clone(&sched);
    let reader = thread::spawn(move || {
        let eof_guard = EofOnDrop(Arc::clone(&reader_sched));
        let mut seq = 0u64;
        let mut emitted = 0u64;
        loop {
            let budget = match reader_cfg.max_records {
                Some(maxn) if emitted >= maxn => break,
                Some(maxn) => ((maxn - emitted) as usize).min(reader_cfg.batch_size),
                None => reader_cfg.batch_size,
            };
            // Reuse a recycled spine (and the records inside it) when one
            // has made it back around the loop.
            let mut batch = spine_rx.try_recv().unwrap_or_default();
            if stream.next_batch_into(&mut batch, budget) == 0 {
                break;
            }
            // The stream reports which model the batch it just cut routes
            // to (always 0 for plain data streams); the worker picks its
            // encoder by this index, so it must be in range.
            let model = stream.batch_model();
            assert!(
                model < n_models,
                "stream routed batch seq {seq} to model {model}, but only {n_models} encoder config(s) were registered"
            );
            emitted += batch.len() as u64;
            reader_stats
                .records_read
                .fetch_add(batch.len() as u64, Ordering::Relaxed);
            // Round-robin target: deterministic dispatch keeps per-worker
            // load even in the common case; stealing handles the skewed
            // tail. (Output is order-independent either way — the seq
            // reorderer and pure encoders guarantee it.)
            let target = (seq % n_workers as u64) as usize;
            let raw = RawBatch { seq, model, records: batch };
            if reader_sched.push(target, raw, &reader_stats).is_err() {
                break; // early stop
            }
            seq += 1;
        }
        drop(eof_guard); // set_eof: workers drain the queues and exit
    });

    // --- encode workers --------------------------------------------------
    let mut workers = Vec::new();
    for (wid, ret_rx) in ret_rxs.into_iter().enumerate() {
        let tx = enc_tx.clone();
        let wstats = Arc::clone(&stats);
        let ecfgs: Vec<EncoderCfg> = encoder_cfgs.to_vec();
        let keep = cfg.keep_records;
        let slow = cfg.slow_worker;
        let max_panics = cfg.max_worker_panics;
        let fault = cfg.fault.clone();
        let wobs = cfg.obs.clone();
        // Serving always wires the tracer (the monitoring publisher
        // reads its live-worker gauge even with tracing off), so
        // presence no longer implies tracing: gate the per-batch clock
        // stamping on `enabled` separately. Retirement still goes to
        // `wobs` — the gauge must move regardless of sampling.
        let sobs = wobs.clone().filter(|o| o.enabled());
        let wsched = Arc::clone(&sched);
        let wspine_tx = spine_tx.clone();
        workers.push(thread::spawn(move || {
            let panic_guard = StopOnPanic(Arc::clone(&wsched));
            // Lazy per-model encoder cache: slot `m` is built from
            // `ecfgs[m].seed` the first time this worker encodes a batch
            // routed to model `m`. Tenants a worker never serves cost it
            // nothing; every build is counted in `encoder_builds`.
            let mut encs: Vec<Option<RecordEncoder>> =
                (0..ecfgs.len()).map(|_| None).collect();
            let mut panics_seen = 0u32;
            let mut stall_once =
                fault.stall_once.filter(|&(w, _)| w == wid).map(|(_, d)| d);
            // Pooled batch spines, refilled from the recycle channel.
            let mut enc_spines: Vec<Vec<Encoding>> = Vec::new();
            let mut label_spines: Vec<Vec<bool>> = Vec::new();
            loop {
                // Drain returned batches: encoding buffers go back into
                // the *routed model's* scratch pool (buffer width is
                // per-model — recycling across models would hand the
                // encoder wrong-dimension buffers), spines into the local
                // pools, record vectors onward to the reader.
                while let Ok(mut ret) = ret_rx.try_recv() {
                    if let Some(Some(enc)) = encs.get_mut(ret.model as usize) {
                        let n = ret.encodings.len() as u64;
                        enc.recycle_all(ret.encodings.drain(..));
                        wstats.buffers_recycled.fetch_add(n, Ordering::Relaxed);
                    } else {
                        // Batches are recycled to their origin worker, so
                        // the encoder is normally built; if not (defensive),
                        // the buffers just fall back to the allocator.
                        ret.encodings.clear();
                    }
                    enc_spines.push(ret.encodings);
                    ret.labels.clear();
                    label_spines.push(ret.labels);
                    if let Some(recs) = ret.records.take() {
                        let _ = wspine_tx.try_send(recs);
                    }
                }
                let Some((raw, stolen)) = wsched.pop(wid, &wstats) else { break };
                // Span stamps (tracing on): pop time + steal provenance
                // now, encode start/end around the catch_unwind body
                // below. Plain u64 fields on the batch — no allocation,
                // and three clock reads per *batch* when enabled.
                let mut stamps = crate::obs::BatchStamps::default();
                if let Some(obs) = sobs.as_deref() {
                    stamps.t_pop = obs.now_ns();
                    stamps.stolen = stolen;
                }
                if let Some((slow_wid, delay)) = slow {
                    if slow_wid == wid {
                        thread::sleep(delay);
                    }
                }
                if let Some(delay) = stall_once.take() {
                    thread::sleep(delay);
                }
                let n = raw.records.len() as u64;
                // Labels are captured BEFORE the fallible encode, so a
                // failed batch still tells its consumer how many
                // records/requests it covered (`labels.len()`).
                let mut labels = label_spines.pop().unwrap_or_default();
                labels.clear();
                labels.extend(raw.records.iter().map(|r| r.label));
                let mut encodings = enc_spines.pop().unwrap_or_default();
                // Resolve (lazily building) the routed model's encoder.
                let mid = raw.model as usize;
                if encs[mid].is_none() {
                    encs[mid] = Some(ecfgs[mid].build());
                    wstats.encoder_builds.fetch_add(1, Ordering::Relaxed);
                }
                let enc = encs[mid].as_mut().expect("encoder built above");
                // The whole encode body runs under catch_unwind: a panic
                // (injected via FaultPlan, or a genuine encoder bug on a
                // hostile record) must cost exactly this batch, not the
                // pipeline. No lock is held here, so no Mutex is ever
                // poisoned by an encode panic.
                if let Some(obs) = sobs.as_deref() {
                    stamps.t_encode_start = obs.now_ns();
                }
                let encode_ok = catch_unwind(AssertUnwindSafe(|| {
                    if fault.panic_on_seq.contains(&raw.seq) {
                        panic!("shdc injected fault: encode panic at seq {}", raw.seq);
                    }
                    let _t = ScopeTimer::new(&wstats.encode_ns);
                    enc.encode_batch_into(&raw.records, &mut encodings);
                }))
                .is_ok();
                if let Some(obs) = sobs.as_deref() {
                    // Captured panic or not: a failed batch's encode span
                    // covers entry→unwind, which is what its trace shows.
                    stamps.t_encode_end = obs.now_ns();
                }
                if encode_ok {
                    wstats.records_encoded.fetch_add(n, Ordering::Relaxed);
                } else {
                    wstats.worker_panics.fetch_add(1, Ordering::Relaxed);
                    wstats.batches_failed.fetch_add(1, Ordering::Relaxed);
                    panics_seen += 1;
                    // The panic may have unwound mid-encode: partial
                    // output and encoder scratch state are suspect.
                    // Drop the partial encodings and "respawn" the
                    // worker in place — rebuild the routed model's
                    // encoder from its seed (hash-defined state makes
                    // this exact and cheap: no codebook to restore, the
                    // paper's synchronization-free property); the other
                    // tenants' cached encoders are untouched.
                    encodings.clear();
                    encs[mid] = Some(ecfgs[mid].build());
                    wstats.encoder_builds.fetch_add(1, Ordering::Relaxed);
                }
                let records = if keep {
                    Some(raw.records)
                } else {
                    // Return the spine to the reader right away.
                    let _ = wspine_tx.try_send(raw.records);
                    None
                };
                let out = EncodedBatch {
                    seq: raw.seq,
                    model: raw.model,
                    encodings,
                    labels,
                    records,
                    origin: wid,
                    failed: !encode_ok,
                    stamps,
                };
                // The failed batch still ships downstream — it owns a
                // sequence slot, and the consumer must observe the
                // failure to fail the batch's requests explicitly.
                if send_counted(&tx, out, &wstats).is_err() {
                    // Consumer dropped the channel: stop the pipeline so
                    // the reader and parked siblings unwind too.
                    wsched.stop();
                    break;
                }
                if !encode_ok && panics_seen > max_panics {
                    // Panic budget exhausted: retire rather than risk an
                    // unbounded crash loop. The scheduler stops the
                    // pipeline once no live worker remains. (The
                    // live_workers gauge never underflows: stats are
                    // per-run and each worker retires at most once.)
                    wstats.workers_retired.fetch_add(1, Ordering::Relaxed);
                    wstats.live_workers.fetch_sub(1, Ordering::Relaxed);
                    if let Some(obs) = wobs.as_deref() {
                        obs.worker_retired();
                    }
                    wsched.retire();
                    break;
                }
            }
            drop(panic_guard);
        }));
    }
    drop(enc_tx); // consumers see EOF when all workers finish
    drop(spine_tx);

    // --- in-order consumption -------------------------------------------
    // Reorder-ring preallocation: the common-case gap is bounded by the
    // batches that can be in flight at once (deques + injector + one per
    // worker + the encoded channel); pathological stalls can exceed it
    // (the ring then grows), but steady state never reallocates.
    let ring_hint = 2 * n_workers * queue_depth + n_workers + queue_depth + 8;
    consume_in_order(enc_rx, &ret_txs, ring_hint, &stats, cfg.fault.drop_recycle, &mut consume);

    reader.join().expect("reader panicked");
    for w in workers {
        w.join().expect("worker panicked");
    }
    stats
}

/// Reorder batches by sequence number before invoking the consumer, then
/// ship the consumed shells back over the recycle channels. Returns early
/// (dropping the receiver, which unwinds the upstream stages via send
/// errors and `StealScheduler::stop`) if the consumer asks to stop.
///
/// Pending batches live in a ring indexed by `seq - next` — bounded by
/// the total in-flight batch count, so it stops allocating once warm
/// (a `BTreeMap` would pay a node allocation per out-of-order batch).
fn consume_in_order<F: FnMut(&mut EncodedBatch) -> bool>(
    rx: Receiver<EncodedBatch>,
    ret_txs: &[SyncSender<EncodedBatch>],
    ring_hint: usize,
    stats: &PipelineStats,
    drop_recycle: bool,
    consume: &mut F,
) {
    let mut next = 0u64;
    let mut ring: VecDeque<Option<EncodedBatch>> = VecDeque::with_capacity(ring_hint);
    loop {
        // Deliver the ready prefix in stream order.
        while matches!(ring.front(), Some(Some(_))) {
            let mut b = ring.pop_front().flatten().expect("front checked Some");
            next += 1;
            let keep = consume(&mut b);
            // Recycle the shell back to the worker that encoded it, so
            // each pool receives returns in proportion to its actual
            // encode rate (stealing makes that uneven across workers).
            let origin = b.origin;
            if drop_recycle || ret_txs[origin].try_send(b).is_err() {
                // `drop_recycle` (FaultPlan) simulates a lossy recycle
                // path: the pool must fall back to fresh allocations, not
                // starve. The batch drops here either way.
                stats.recycle_misses.fetch_add(1, Ordering::Relaxed);
            }
            if !keep {
                return;
            }
        }
        match rx.recv() {
            Ok(batch) => {
                let off = (batch.seq - next) as usize;
                if ring.len() <= off {
                    ring.resize_with(off + 1, || None);
                }
                ring[off] = Some(batch);
            }
            Err(_) => return, // all workers exited; ring prefix is empty
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic::SyntheticConfig, SyntheticStream};
    use crate::encoding::BundleMethod;

    fn small_cfg() -> EncoderCfg {
        EncoderCfg {
            cat: CatCfg::Bloom { d: 256, k: 2 },
            num: NumCfg::None,
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 1,
        }
    }

    #[test]
    fn processes_exactly_max_records_in_order() {
        let stream = SyntheticStream::new(SyntheticConfig::sampled(3));
        let mut seen = Vec::new();
        let stats = run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg {
                batch_size: 32,
                n_workers: 4,
                max_records: Some(1000),
                ..Default::default()
            },
            |b| { seen.push((b.seq, b.encodings.len())); true },
        );
        let total: usize = seen.iter().map(|(_, n)| n).sum();
        assert_eq!(total, 1000);
        let seqs: Vec<u64> = seen.iter().map(|(s, _)| *s).collect();
        let mut sorted = seqs.clone();
        sorted.sort();
        assert_eq!(seqs, sorted, "batches must arrive in stream order");
        assert_eq!(stats.snapshot().records_encoded, 1000);
        assert_eq!(stats.snapshot().records_read, 1000);
    }

    #[test]
    fn multi_worker_equals_single_worker() {
        let collect = |workers: usize| {
            let stream = SyntheticStream::new(SyntheticConfig::sampled(4));
            let mut encs = Vec::new();
            run_pipeline(
                stream,
                &small_cfg(),
                &CoordinatorCfg {
                    batch_size: 16,
                    n_workers: workers,
                    max_records: Some(200),
                    ..Default::default()
                },
                |b| { encs.extend(b.encodings.drain(..)); true },
            );
            encs
        };
        assert_eq!(collect(1), collect(6));
    }

    #[test]
    fn multi_worker_equals_single_worker_with_numeric_branch() {
        // Exercises the stealing dispatch with both encoder branches live
        // (numeric batch path + categorical scratch path).
        let enc_cfg = EncoderCfg {
            cat: CatCfg::Bloom { d: 256, k: 2 },
            num: NumCfg::Sjlt { d: 128, k: 4 },
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 9,
        };
        let collect = |workers: usize| {
            let stream = SyntheticStream::new(SyntheticConfig::sampled(9));
            let mut encs = Vec::new();
            run_pipeline(
                stream,
                &enc_cfg,
                &CoordinatorCfg {
                    batch_size: 16,
                    n_workers: workers,
                    max_records: Some(300),
                    ..Default::default()
                },
                |b| {
                    encs.extend(b.encodings.drain(..));
                    true
                },
            );
            encs
        };
        assert_eq!(collect(1), collect(4));
    }

    #[test]
    fn more_workers_than_batches() {
        // Idle workers (empty deques, nothing to steal) must park, wake
        // on EOF and join cleanly.
        let stream = SyntheticStream::new(SyntheticConfig::sampled(10));
        let mut total = 0usize;
        let stats = run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg {
                batch_size: 32,
                n_workers: 8,
                max_records: Some(64),
                ..Default::default()
            },
            |b| {
                total += b.encodings.len();
                true
            },
        );
        assert_eq!(total, 64);
        assert_eq!(stats.snapshot().records_encoded, 64);
    }

    #[test]
    fn keep_records_carries_raw_data() {
        let stream = SyntheticStream::new(SyntheticConfig::sampled(5));
        let mut n_rec = 0usize;
        run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg {
                batch_size: 10,
                n_workers: 2,
                keep_records: true,
                max_records: Some(50),
                ..Default::default()
            },
            |b| {
                let recs = b.records.as_ref().expect("records kept");
                assert_eq!(recs.len(), b.encodings.len());
                n_rec += recs.len();
                true
            },
        );
        assert_eq!(n_rec, 50);
    }

    #[test]
    fn backpressure_counted_with_slow_consumer() {
        let stream = SyntheticStream::new(SyntheticConfig::sampled(6));
        let stats = run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg {
                batch_size: 8,
                n_workers: 4,
                queue_depth: 1,
                max_records: Some(400),
                ..Default::default()
            },
            |_| { std::thread::sleep(std::time::Duration::from_micros(500)); true },
        );
        assert!(
            stats.snapshot().backpressure_events > 0,
            "tiny queue + slow consumer must trigger backpressure"
        );
    }

    #[test]
    fn consumer_can_stop_early() {
        let stream = SyntheticStream::new(SyntheticConfig::sampled(8));
        let mut batches = 0usize;
        run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg {
                batch_size: 8,
                n_workers: 3,
                max_records: Some(10_000),
                ..Default::default()
            },
            |_| {
                batches += 1;
                batches < 5
            },
        );
        assert_eq!(batches, 5, "pipeline must halt when consumer returns false");
    }

    #[test]
    fn labels_align_with_encodings() {
        let stream = SyntheticStream::new(SyntheticConfig::sampled(7));
        run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg { batch_size: 64, max_records: Some(128), ..Default::default() },
            |b| { assert_eq!(b.labels.len(), b.encodings.len()); true },
        );
    }

    #[test]
    fn slow_worker_forces_steals() {
        // Worker 0 sleeps 2ms per batch; its queued batches must be
        // stolen by idle siblings, and the output must not change.
        let collect = |slow: Option<(usize, Duration)>, workers: usize| {
            let stream = SyntheticStream::new(SyntheticConfig::sampled(12));
            let mut encs = Vec::new();
            let stats = run_pipeline(
                stream,
                &small_cfg(),
                &CoordinatorCfg {
                    batch_size: 8,
                    n_workers: workers,
                    queue_depth: 2,
                    max_records: Some(480),
                    slow_worker: slow,
                    ..Default::default()
                },
                |b| {
                    encs.extend(b.encodings.drain(..));
                    true
                },
            );
            (encs, stats.snapshot())
        };
        let (baseline, _) = collect(None, 1);
        let (stalled, snap) = collect(Some((0, Duration::from_millis(2))), 4);
        assert_eq!(baseline, stalled, "steals must not change output");
        assert!(
            snap.batches_stolen > 0,
            "a 2ms-per-batch worker must get robbed: {snap:?}"
        );
    }

    #[test]
    fn recycle_loop_returns_buffers() {
        // A consumer that leaves the batch intact sends every encoding
        // buffer back to a worker pool.
        let stream = SyntheticStream::new(SyntheticConfig::sampled(13));
        let stats = run_pipeline(
            stream,
            &small_cfg(),
            &CoordinatorCfg {
                batch_size: 16,
                n_workers: 2,
                max_records: Some(640),
                ..Default::default()
            },
            |b| { assert!(!b.encodings.is_empty()); true },
        );
        let snap = stats.snapshot();
        assert!(
            snap.buffers_recycled > 0,
            "recycle channel never round-tripped: {snap:?}"
        );
    }
}
