//! Record-level encoder: configuration + the composite (numeric ⊕
//! categorical) encoding of one [`Record`] (paper Fig. 6's two-branch
//! pipeline feeding a bundling operator).
//!
//! Configurations are plain data so experiments (Figs. 7–10) can sweep
//! them, and `build()` is deterministic from the seed so every worker
//! shard constructs identical encoders.

use crate::data::Record;
use crate::encoding::{
    bundle, bundle_with, BloomEncoder, BundleMethod, CategoricalEncoder, CodebookEncoder,
    DenseHashEncoder, DenseHashMode, DenseProjection, EncodeScratch, Encoding, NumericEncoder,
    PermutationEncoder, ProjectionMode, RelaxedSjlt, Sjlt, SparseProjection,
};
use crate::util::rng::Rng;

/// Categorical-encoder choice (paper Sec. 4).
#[derive(Clone, Debug, PartialEq)]
pub enum CatCfg {
    /// Sparse Bloom-filter hashing (the contribution), k Murmur3 fns.
    Bloom { d: usize, k: usize },
    /// Bloom with a 2s-independent polynomial family (Theorem 3 form).
    BloomPoly { d: usize, k: usize, independence: usize },
    /// Dense hashing baseline (Sec. 4.2.1).
    DenseHash { d: usize, literal: bool },
    /// Random-codebook baseline (Sec. 4.1); optional memory budget.
    Codebook { d: usize, budget_bytes: Option<usize> },
    /// Permutation/shift baseline (Remark 3).
    Permutation { d: usize, pool: usize, granularity: usize },
    /// No categorical branch.
    None,
}

/// Numeric-encoder choice (paper Sec. 5).
#[derive(Clone, Debug, PartialEq)]
pub enum NumCfg {
    /// Dense signed random projection (Eq. 4).
    DenseSign { d: usize },
    /// Sparse RP, exact top-k (Eq. 6).
    SparseTopK { d: usize, k: usize },
    /// Sparse RP, thresholded (Sec. 5.3).
    SparseThreshold { d: usize, t: f32 },
    /// Structured SJLT (Eq. 5).
    Sjlt { d: usize, k: usize },
    /// Relaxed ±1/0 SJLT (Sec. 7.2.3), optionally sign-quantized.
    RelaxedSjlt { d: usize, p: f64, quantize: bool },
    /// "No-Count": drop numeric features (Fig. 9 baseline).
    None,
}

#[derive(Clone, Debug, PartialEq)]
pub struct EncoderCfg {
    pub cat: CatCfg,
    pub num: NumCfg,
    pub bundle: BundleMethod,
    pub n_numeric: usize,
    pub seed: u64,
}

impl EncoderCfg {
    /// The paper's best streaming configuration (Sec. 7.5): Bloom d=10k
    /// k=4 for categorical, SJLT for numeric, concat bundling.
    pub fn paper_default(seed: u64) -> Self {
        EncoderCfg {
            cat: CatCfg::Bloom { d: 10_000, k: 4 },
            num: NumCfg::RelaxedSjlt { d: 10_000, p: 0.4, quantize: true },
            bundle: BundleMethod::Concat,
            n_numeric: crate::data::CRITEO_NUMERIC,
            seed,
        }
    }

    /// Output dimension after bundling.
    pub fn out_dim(&self) -> usize {
        let dc = match &self.cat {
            CatCfg::Bloom { d, .. }
            | CatCfg::BloomPoly { d, .. }
            | CatCfg::DenseHash { d, .. }
            | CatCfg::Codebook { d, .. }
            | CatCfg::Permutation { d, .. } => *d,
            CatCfg::None => 0,
        };
        let dn = match &self.num {
            NumCfg::DenseSign { d }
            | NumCfg::SparseTopK { d, .. }
            | NumCfg::SparseThreshold { d, .. }
            | NumCfg::Sjlt { d, .. }
            | NumCfg::RelaxedSjlt { d, .. } => *d,
            NumCfg::None => 0,
        };
        match (dc, dn) {
            (0, d) | (d, 0) => d,
            (dc, dn) => self.bundle.out_dim(dn, dc),
        }
    }

    /// Build the composite encoder. Deterministic from `seed`.
    pub fn build(&self) -> RecordEncoder {
        let mut rng = Rng::new(self.seed ^ ENCODER_SEED_KEY);
        let cat: Option<Box<dyn CategoricalEncoder>> = match &self.cat {
            CatCfg::Bloom { d, k } => Some(Box::new(BloomEncoder::new(*d, *k, &mut rng))),
            CatCfg::BloomPoly { d, k, independence } => {
                Some(Box::new(BloomEncoder::new_poly(*d, *k, *independence, &mut rng)))
            }
            CatCfg::DenseHash { d, literal } => Some(Box::new(DenseHashEncoder::new(
                *d,
                if *literal { DenseHashMode::Literal } else { DenseHashMode::Packed },
                &mut rng,
            ))),
            CatCfg::Codebook { d, budget_bytes } => Some(Box::new(match budget_bytes {
                Some(b) => CodebookEncoder::with_budget(*d, self.seed, *b),
                None => CodebookEncoder::new(*d, self.seed),
            })),
            CatCfg::Permutation { d, pool, granularity } => {
                Some(Box::new(PermutationEncoder::new(*d, *pool, *granularity, &mut rng)))
            }
            CatCfg::None => None,
        };
        let num: Option<Box<dyn NumericEncoder>> = match &self.num {
            NumCfg::DenseSign { d } => Some(Box::new(DenseProjection::new(
                *d,
                self.n_numeric,
                ProjectionMode::Sign,
                &mut rng,
            ))),
            NumCfg::SparseTopK { d, k } => {
                Some(Box::new(SparseProjection::new_topk(*d, self.n_numeric, *k, &mut rng)))
            }
            NumCfg::SparseThreshold { d, t } => Some(Box::new(SparseProjection::new_threshold(
                *d,
                self.n_numeric,
                *t,
                &mut rng,
            ))),
            NumCfg::Sjlt { d, k } => Some(Box::new(Sjlt::new(*d, self.n_numeric, *k, &mut rng))),
            NumCfg::RelaxedSjlt { d, p, quantize } => Some(Box::new(RelaxedSjlt::new(
                *d,
                self.n_numeric,
                *p,
                *quantize,
                &mut rng,
            ))),
            NumCfg::None => None,
        };
        RecordEncoder {
            cat,
            num,
            bundle: self.bundle,
            out_dim: self.out_dim(),
            scratch: EncodeScratch::new(),
            num_buf: Vec::new(),
            xflat: Vec::new(),
        }
    }
}

/// Key for deriving encoder randomness from the experiment seed (keeps
/// encoder draws decorrelated from data-stream draws under one seed).
const ENCODER_SEED_KEY: u64 = 0xe4c0_de00_5eed_0001;

/// The composite encoder for one record.
///
/// Owns an [`EncodeScratch`] so the batch path
/// ([`RecordEncoder::encode_batch_into`]) runs with zero steady-state
/// allocations for all intermediate work: hashed-coordinate staging,
/// dedup, the numeric branch's codes (recycled right after bundling) and
/// bundling temporaries. Output buffers are pooled too when the caller
/// returns consumed encodings via [`RecordEncoder::recycle`].
pub struct RecordEncoder {
    cat: Option<Box<dyn CategoricalEncoder>>,
    num: Option<Box<dyn NumericEncoder>>,
    bundle: BundleMethod,
    out_dim: usize,
    scratch: EncodeScratch,
    /// Reused numeric-branch batch output.
    num_buf: Vec<Encoding>,
    /// Reused row-major (batch × n) staging for the numeric inputs. The
    /// slice-based batch API needs a per-batch `Vec<&[f32]>`; copying
    /// the 13-wide rows into one flat reused buffer is cheaper than that
    /// allocation and keeps the worker hot loop allocation-free.
    xflat: Vec<f32>,
}

impl RecordEncoder {
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Encode one record (numeric branch ⊕ categorical branch).
    pub fn encode(&mut self, record: &Record) -> Encoding {
        let cat_code = self.cat.as_mut().map(|c| c.encode(&record.symbols));
        let num_code = self.num.as_ref().map(|n| n.encode(&record.numeric));
        match (num_code, cat_code) {
            (Some(n), Some(c)) => {
                // Bundle order: numeric first (matches the concat layout
                // the fused PJRT artifact expects: [phi_n | phi_c]).
                bundle(&n, &c, self.bundle)
            }
            (Some(n), None) => n,
            (None, Some(c)) => c,
            (None, None) => panic!("EncoderCfg with neither branch"),
        }
    }

    /// Encode a whole batch into a caller-reused vector (cleared first).
    ///
    /// This is the coordinator workers' hot path: the numeric branch runs
    /// its row-blocked batch encode (projection rows loaded once per
    /// batch, not per record), the categorical branch encodes through the
    /// scratch (pooled buffers, sort-free dedup), and every intermediate
    /// — including the numeric and categorical codes once bundled — is
    /// recycled. Bit-identical to per-record [`RecordEncoder::encode`].
    pub fn encode_batch_into(&mut self, records: &[Record], out: &mut Vec<Encoding>) {
        out.clear();
        out.reserve(records.len());
        let RecordEncoder { cat, num, bundle: method, scratch, num_buf, xflat, .. } = self;
        if let Some(n) = num {
            let nfeat = records.first().map_or(0, |r| r.numeric.len());
            if nfeat == 0 {
                // Degenerate width: nothing to stage; encode per record.
                // The width still must be uniform — a non-empty record
                // here would silently lose its features otherwise.
                num_buf.clear();
                for r in records {
                    assert_eq!(r.numeric.len(), 0, "ragged numeric widths");
                    num_buf.push(n.encode_with(&[], scratch));
                }
            } else {
                xflat.clear();
                xflat.reserve(records.len() * nfeat);
                for r in records {
                    // Hard assert: a ragged width would silently shift
                    // every subsequent flat row in a release build.
                    assert_eq!(r.numeric.len(), nfeat, "ragged numeric widths");
                    xflat.extend_from_slice(&r.numeric);
                }
                n.encode_batch_flat_with(xflat, nfeat, scratch, num_buf);
            }
        } else {
            num_buf.clear();
        }
        match (num.is_some(), cat) {
            (true, Some(cat)) => {
                for (r, ncode) in records.iter().zip(num_buf.drain(..)) {
                    let ccode = cat.encode_with(&r.symbols, scratch);
                    out.push(bundle_with(&ncode, &ccode, *method, scratch));
                    scratch.recycle(ncode);
                    scratch.recycle(ccode);
                }
            }
            (true, None) => out.extend(num_buf.drain(..)),
            (false, Some(cat)) => {
                out.extend(records.iter().map(|r| cat.encode_with(&r.symbols, scratch)));
            }
            (false, None) => panic!("EncoderCfg with neither branch"),
        }
    }

    /// Allocating convenience wrapper over
    /// [`RecordEncoder::encode_batch_into`].
    pub fn encode_batch(&mut self, records: &[Record]) -> Vec<Encoding> {
        let mut out = Vec::with_capacity(records.len());
        self.encode_batch_into(records, &mut out);
        out
    }

    /// Return a consumed encoding's buffer to the internal pool, making
    /// single-threaded encode→consume→recycle loops allocation-free.
    pub fn recycle(&mut self, enc: Encoding) {
        self.scratch.recycle(enc);
    }

    /// Recycle a whole batch of consumed encodings.
    pub fn recycle_all(&mut self, encs: impl IntoIterator<Item = Encoding>) {
        self.scratch.recycle_all(encs);
    }

    /// Encoder state size (the Fig. 7A memory axis).
    pub fn memory_bytes(&self) -> usize {
        self.cat.as_ref().map_or(0, |c| c.memory_bytes())
    }

    /// Only the categorical branch (used by the fused-PJRT path, which
    /// computes the numeric branch on-device).
    pub fn encode_categorical(&mut self, record: &Record) -> Option<Encoding> {
        self.cat.as_mut().map(|c| c.encode(&record.symbols))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic::SyntheticConfig, RecordStream, SyntheticStream};

    fn sample_record() -> Record {
        let mut s = SyntheticStream::new(SyntheticConfig::sampled(1));
        s.next_record().unwrap()
    }

    #[test]
    fn paper_default_builds_and_encodes() {
        let cfg = EncoderCfg::paper_default(1);
        let mut enc = cfg.build();
        let code = enc.encode(&sample_record());
        assert_eq!(code.dim(), 20_000);
        assert_eq!(cfg.out_dim(), 20_000);
    }

    #[test]
    fn deterministic_across_builds() {
        let cfg = EncoderCfg::paper_default(9);
        let r = sample_record();
        let a = cfg.build().encode(&r);
        let b = cfg.build().encode(&r);
        assert_eq!(a, b);
    }

    #[test]
    fn no_count_uses_cat_only() {
        let cfg = EncoderCfg {
            cat: CatCfg::Bloom { d: 512, k: 4 },
            num: NumCfg::None,
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 2,
        };
        let code = cfg.build().encode(&sample_record());
        assert_eq!(code.dim(), 512);
        assert!(matches!(code, Encoding::SparseBinary { .. }));
    }

    #[test]
    fn or_bundling_of_sparse_branches_stays_sparse() {
        let cfg = EncoderCfg {
            cat: CatCfg::Bloom { d: 1024, k: 4 },
            num: NumCfg::SparseThreshold { d: 1024, t: 1.0 },
            bundle: BundleMethod::ThresholdedSum,
            n_numeric: 13,
            seed: 3,
        };
        assert_eq!(cfg.out_dim(), 1024);
        let code = cfg.build().encode(&sample_record());
        assert!(matches!(code, Encoding::SparseBinary { .. }));
        assert_eq!(code.dim(), 1024);
    }

    #[test]
    fn all_cat_variants_encode() {
        for cat in [
            CatCfg::Bloom { d: 256, k: 2 },
            CatCfg::BloomPoly { d: 256, k: 2, independence: 8 },
            CatCfg::DenseHash { d: 256, literal: false },
            CatCfg::Codebook { d: 256, budget_bytes: None },
            CatCfg::Permutation { d: 256, pool: 2, granularity: 16 },
        ] {
            let cfg = EncoderCfg {
                cat: cat.clone(),
                num: NumCfg::None,
                bundle: BundleMethod::Concat,
                n_numeric: 13,
                seed: 4,
            };
            let code = cfg.build().encode(&sample_record());
            assert_eq!(code.dim(), 256, "{cat:?}");
        }
    }

    #[test]
    fn all_num_variants_encode() {
        for num in [
            NumCfg::DenseSign { d: 128 },
            NumCfg::SparseTopK { d: 128, k: 16 },
            NumCfg::SparseThreshold { d: 128, t: 0.5 },
            NumCfg::Sjlt { d: 128, k: 4 },
            NumCfg::RelaxedSjlt { d: 128, p: 0.4, quantize: true },
        ] {
            let cfg = EncoderCfg {
                cat: CatCfg::None,
                num: num.clone(),
                bundle: BundleMethod::Concat,
                n_numeric: 13,
                seed: 5,
            };
            let code = cfg.build().encode(&sample_record());
            assert_eq!(code.dim(), 128, "{num:?}");
        }
    }

    #[test]
    fn concat_layout_numeric_first() {
        let cfg = EncoderCfg {
            cat: CatCfg::Bloom { d: 64, k: 2 },
            num: NumCfg::DenseSign { d: 32 },
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 6,
        };
        let mut enc = cfg.build();
        let r = sample_record();
        let code = enc.encode(&r).to_dense();
        // first 32 coords are ±1 (numeric sign-projection), rest 0/1.
        assert!(code[..32].iter().all(|&x| x == 1.0 || x == -1.0));
        assert!(code[32..].iter().all(|&x| x == 0.0 || x == 1.0));
    }
}
