//! Sharded associative-memory scan: partition a read-only [`AmStore`]'s
//! prototype rows into contiguous class-id ranges and score the ranges
//! in parallel, merging per-shard candidates into results **exactly
//! equal** to the single-thread scan.
//!
//! # Why sharding cannot change a single result bit
//!
//! Every per-class score is one self-contained kernel call over that
//! class's prototype row plus the (per-scratch, deterministically
//! staged) query: `dot_f32`'s association order is fixed by the kernel
//! contract, and the int8/binary kernels are exact integer reductions.
//! Sharding therefore only partitions *which scratch scores which
//! class* — never any per-class arithmetic — so the full multiset of
//! (class, score) pairs is identical to the single scan's. What remains
//! is ordering, and the merge enforces exactly the single-scan contract:
//! **score descending, lowest class id first among equal scores.**
//!
//! * [`ShardedAmStore::top1`] merges shard winners with a strict `>` in
//!   ascending shard order. Shard ranges are contiguous and ascending,
//!   so "first shard attaining the maximum" ≡ "lowest class id attaining
//!   the maximum" — the same element [`AmStore::top1`]'s strict-`>` scan
//!   selects.
//! * [`ShardedAmStore::topk_into`] takes each shard's local top-k (built
//!   with the same insertion rule as [`AmStore::topk_into`], so each
//!   list is already (score desc, class asc)-sorted) and k-way merges by
//!   strict `>` over the shard heads in ascending shard order. Among
//!   equal scores the lowest shard — hence the lowest class id — wins,
//!   reproducing the global insertion order element for element.
//!
//! `tests/am_sharding.rs` pins this differentially across every
//! precision × shard count × class count, including ragged last shards,
//! `k` larger than a shard, and constructed score ties.
//!
//! # The scoped scorer pool
//!
//! Scoring fans out over at most [`ShardedAmStore::scorers`] scoped
//! threads (`std::thread::scope`), each scanning a contiguous run of
//! shards with its own [`AmScratch`] — no shared mutable state, no
//! locks, join at scope exit. The scorer count never affects results
//! (it only partitions the shard list). A single-shard store — the
//! serving default — skips the scope entirely and scores inline, which
//! keeps the zero-allocation serve window of `tests/alloc_regression.rs`
//! intact; multi-shard scans pay one scoped spawn per *batch* (the serve
//! consumer amortizes it via [`ShardedAmStore::top1_batch_into`]), the
//! right trade once the class scan, not encode, is the bottleneck.

use std::ops::Range;
use std::thread;

use super::{topk_insert, AmScratch, AmStore, Precision};
use crate::encoding::Encoding;

/// Default cap on scoped scorer threads (see [`ShardedAmStore::scorers`]).
const DEFAULT_SCORERS: usize = 8;

/// Reusable sharded-scan scratch: one [`AmScratch`] plus one candidate
/// staging buffer per shard, and the merge cursors. One per scoring
/// thread; recycling it keeps the sharded serve loop free of
/// steady-state allocations (single-shard stores allocate nothing at
/// all once warm; multi-shard stores allocate only the scoped spawns).
#[derive(Debug, Default)]
pub struct ShardScratch {
    /// Per-shard scoring scratch (disjoint across scorer threads).
    shards: Vec<AmScratch>,
    /// Per-shard candidates, global class ids: query-major winners for
    /// the batch top-1 path, a sorted top-k list for the top-k path.
    candidates: Vec<Vec<(u32, f32)>>,
    /// Per-shard read cursors for the k-way top-k merge.
    cursors: Vec<usize>,
}

impl ShardScratch {
    pub fn new() -> ShardScratch {
        ShardScratch::default()
    }

    fn ensure(&mut self, n_shards: usize) {
        while self.shards.len() < n_shards {
            self.shards.push(AmScratch::new());
            self.candidates.push(Vec::new());
        }
    }
}

/// A read-only [`AmStore`] partitioned into contiguous class-id ranges
/// for parallel scanning. Owns the store (no row is copied — shards are
/// index ranges over the store's row-major arrays) and exposes the same
/// scoring surface with results exactly equal to the single scan.
#[derive(Clone, Debug)]
pub struct ShardedAmStore {
    store: AmStore,
    /// Shard boundaries over the class-id space: shard `s` scans classes
    /// `bounds[s]..bounds[s + 1]`. `bounds[0] == 0`, last == n_classes,
    /// strictly increasing (every shard is non-empty).
    bounds: Vec<u32>,
    /// Scorer-thread cap: scoring fans out over `min(scorers, n_shards)`
    /// scoped threads, each scanning a contiguous run of shards. Purely
    /// a parallelism knob — results are independent of it.
    scorers: usize,
}

impl ShardedAmStore {
    /// Partition `store` into `n_shards` contiguous class ranges (as
    /// even as possible; the first `n_classes % n_shards` shards hold
    /// one extra class). `n_shards` is clamped to `[1, n_classes]`.
    pub fn new(store: AmStore, n_shards: usize) -> ShardedAmStore {
        ShardedAmStore::with_scorers(store, n_shards, DEFAULT_SCORERS)
    }

    /// [`ShardedAmStore::new`] with an explicit scorer-thread cap
    /// (clamped to `[1, n_shards]`). The cap partitions shards among
    /// scoped threads and never affects results.
    pub fn with_scorers(store: AmStore, n_shards: usize, scorers: usize) -> ShardedAmStore {
        let n = store.n_classes();
        let shards = n_shards.clamp(1, n);
        let base = n / shards;
        let extra = n % shards;
        let mut bounds = Vec::with_capacity(shards + 1);
        bounds.push(0u32);
        let mut at = 0usize;
        for s in 0..shards {
            at += base + usize::from(s < extra);
            bounds.push(at as u32);
        }
        debug_assert_eq!(at, n);
        ShardedAmStore { store, bounds, scorers: scorers.clamp(1, shards) }
    }

    /// The underlying single-scan store.
    pub fn store(&self) -> &AmStore {
        &self.store
    }

    /// Unwrap back into the single-scan store.
    pub fn into_store(self) -> AmStore {
        self.store
    }

    pub fn dim(&self) -> usize {
        self.store.dim()
    }

    pub fn n_classes(&self) -> usize {
        self.store.n_classes()
    }

    pub fn n_shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Global class-id range shard `s` owns.
    pub fn shard_range(&self, s: usize) -> Range<u32> {
        self.bounds[s]..self.bounds[s + 1]
    }

    /// Class count of every shard, in shard order (the per-shard gauge
    /// dimension used by serve's scan counters and obs snapshots).
    pub fn shard_sizes(&self) -> Vec<u32> {
        self.bounds.windows(2).map(|w| w[1] - w[0]).collect()
    }

    /// Run `scan(lo, hi, scratch, out)` for every shard, fanning the
    /// shard list out over at most `self.scorers` scoped threads (the
    /// last chunk runs on the caller). Single-scorer runs stay inline —
    /// no spawn, no allocation.
    fn for_each_shard<F>(&self, scratch: &mut ShardScratch, scan: F)
    where
        F: Fn(u32, u32, &mut AmScratch, &mut Vec<(u32, f32)>) + Sync,
    {
        let shards = self.n_shards();
        scratch.ensure(shards);
        let scorers = self.scorers.min(shards);
        if scorers <= 1 {
            for s in 0..shards {
                scan(
                    self.bounds[s],
                    self.bounds[s + 1],
                    &mut scratch.shards[s],
                    &mut scratch.candidates[s],
                );
            }
            return;
        }
        let base = shards / scorers;
        let extra = shards % scorers;
        let bounds = &self.bounds;
        let scan = &scan;
        thread::scope(|sc| {
            let mut rest_s = &mut scratch.shards[..shards];
            let mut rest_c = &mut scratch.candidates[..shards];
            let mut first = 0usize;
            for j in 0..scorers {
                let count = base + usize::from(j < extra);
                let (chunk_s, tail_s) = rest_s.split_at_mut(count);
                let (chunk_c, tail_c) = rest_c.split_at_mut(count);
                rest_s = tail_s;
                rest_c = tail_c;
                let lo_shard = first;
                first += count;
                let run = move || {
                    for (i, (sh_scratch, sh_out)) in
                        chunk_s.iter_mut().zip(chunk_c.iter_mut()).enumerate()
                    {
                        let s = lo_shard + i;
                        scan(bounds[s], bounds[s + 1], sh_scratch, sh_out);
                    }
                };
                if j + 1 == scorers {
                    run(); // the caller is the last scorer
                } else {
                    sc.spawn(run);
                }
            }
        });
    }

    /// Best class and score for each query in `encs`, written query-major
    /// into the caller-reused `out` — exactly equal, pair for pair, to
    /// [`AmStore::top1`] on each query. The serve consumer's hot path:
    /// one scorer fan-out amortized over the whole micro-batch.
    pub fn top1_batch_into(
        &self,
        encs: &[Encoding],
        prec: Precision,
        scratch: &mut ShardScratch,
        out: &mut Vec<(u32, f32)>,
    ) {
        out.clear();
        if encs.is_empty() {
            return;
        }
        let store = &self.store;
        self.for_each_shard(scratch, |lo, hi, sh, cand| {
            scan_top1(store, lo, hi, encs, prec, sh, cand)
        });
        let shards = self.n_shards();
        for q in 0..encs.len() {
            // Strict `>` in ascending shard order: contiguous ascending
            // ranges make "first shard attaining the max" the lowest
            // class id attaining it — the single-scan tie-break.
            let mut best = scratch.candidates[0][q];
            for s in 1..shards {
                let c = scratch.candidates[s][q];
                if c.1 > best.1 {
                    best = c;
                }
            }
            out.push(best);
        }
    }

    /// Best class and its score — exactly equal to [`AmStore::top1`]
    /// (ties break to the lowest class id).
    pub fn top1(&self, enc: &Encoding, prec: Precision, scratch: &mut ShardScratch) -> (u32, f32) {
        let store = &self.store;
        let encs = std::slice::from_ref(enc);
        self.for_each_shard(scratch, |lo, hi, sh, cand| {
            scan_top1(store, lo, hi, encs, prec, sh, cand)
        });
        let mut best = scratch.candidates[0][0];
        for s in 1..self.n_shards() {
            let c = scratch.candidates[s][0];
            if c.1 > best.1 {
                best = c;
            }
        }
        best
    }

    /// Top-k classes by score into the caller-reused `out` — exactly
    /// equal, element for element, to [`AmStore::topk_into`]: score
    /// descending, lowest class id first among equal scores, `k` clamped
    /// to `[1, n_classes]`.
    pub fn topk_into(
        &self,
        enc: &Encoding,
        prec: Precision,
        k: usize,
        scratch: &mut ShardScratch,
        out: &mut Vec<(u32, f32)>,
    ) {
        let store = &self.store;
        self.for_each_shard(scratch, |lo, hi, sh, cand| {
            scan_topk(store, lo, hi, enc, prec, k, sh, cand)
        });
        out.clear();
        let shards = self.n_shards();
        let k = k.min(self.n_classes()).max(1);
        let cursors = &mut scratch.cursors;
        cursors.clear();
        cursors.resize(shards, 0);
        // K-way merge over the per-shard sorted lists. Each list is
        // (score desc, class asc); picking the strictly-greatest head in
        // ascending shard order keeps equal scores in ascending class
        // order globally, because shard s's class ids all precede shard
        // s+1's.
        while out.len() < k {
            let mut best_shard = usize::MAX;
            let mut best_score = 0.0f32;
            for s in 0..shards {
                let cand = &scratch.candidates[s];
                let cur = cursors[s];
                if cur < cand.len() && (best_shard == usize::MAX || cand[cur].1 > best_score) {
                    best_shard = s;
                    best_score = cand[cur].1;
                }
            }
            if best_shard == usize::MAX {
                break; // fewer than k candidates exist (k was clamped, so only on empty shards)
            }
            out.push(scratch.candidates[best_shard][cursors[best_shard]]);
            cursors[best_shard] += 1;
        }
    }
}

/// Shard-local top-1 for every query, appended query-major with global
/// class ids: the same strict-`>` ascending scan as [`AmStore::top1`],
/// restricted to classes `lo..hi`.
fn scan_top1(
    store: &AmStore,
    lo: u32,
    hi: u32,
    encs: &[Encoding],
    prec: Precision,
    scratch: &mut AmScratch,
    out: &mut Vec<(u32, f32)>,
) {
    out.clear();
    for enc in encs {
        store.score_range_into(enc, prec, lo as usize, hi as usize, scratch);
        let mut best = 0usize;
        let mut best_score = scratch.scores[0];
        for (i, &s) in scratch.scores.iter().enumerate().skip(1) {
            if s > best_score {
                best = i;
                best_score = s;
            }
        }
        out.push((lo + best as u32, best_score));
    }
}

/// Shard-local top-k with global class ids: the same insertion rule as
/// [`AmStore::topk_into`] ([`topk_insert`]), restricted to `lo..hi`, so
/// the list comes out (score desc, class asc)-sorted.
#[allow(clippy::too_many_arguments)]
fn scan_topk(
    store: &AmStore,
    lo: u32,
    hi: u32,
    enc: &Encoding,
    prec: Precision,
    k: usize,
    scratch: &mut AmScratch,
    out: &mut Vec<(u32, f32)>,
) {
    store.score_range_into(enc, prec, lo as usize, hi as usize, scratch);
    out.clear();
    let k = k.min((hi - lo) as usize).max(1);
    for (i, &s) in scratch.scores.iter().enumerate() {
        topk_insert(out, k, lo + i as u32, s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_store(n_classes: usize, d: usize, seed: u64) -> AmStore {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f32>> = (0..n_classes)
            .map(|_| (0..d).map(|_| rng.normal_f32()).collect())
            .collect();
        AmStore::from_prototypes(d, &rows, None)
    }

    #[test]
    fn shard_ranges_partition_the_class_space() {
        let sharded = ShardedAmStore::new(random_store(10, 8, 1), 3);
        assert_eq!(sharded.n_shards(), 3);
        // 10 classes over 3 shards: 4 + 3 + 3.
        assert_eq!(sharded.shard_range(0), 0..4);
        assert_eq!(sharded.shard_range(1), 4..7);
        assert_eq!(sharded.shard_range(2), 7..10);
        assert_eq!(sharded.shard_sizes(), vec![4, 3, 3]);
    }

    #[test]
    fn shard_count_clamps_to_classes() {
        let sharded = ShardedAmStore::new(random_store(2, 8, 2), 64);
        assert_eq!(sharded.n_shards(), 2);
        let sharded = ShardedAmStore::new(random_store(5, 8, 3), 0);
        assert_eq!(sharded.n_shards(), 1);
    }

    #[test]
    fn sharded_top1_matches_single_scan() {
        let store = random_store(13, 32, 4);
        let sharded = ShardedAmStore::new(store.clone(), 4);
        let mut rng = Rng::new(5);
        let mut single = AmScratch::new();
        let mut scratch = ShardScratch::new();
        for _ in 0..10 {
            let q: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
            let enc = Encoding::Dense(q);
            for prec in Precision::ALL {
                let want = store.top1(&enc, prec, &mut single);
                let got = sharded.top1(&enc, prec, &mut scratch);
                assert_eq!(got, want, "{prec:?}");
            }
        }
    }

    #[test]
    fn batch_top1_matches_per_query_top1() {
        let store = random_store(9, 16, 6);
        let sharded = ShardedAmStore::with_scorers(store, 5, 2);
        let mut rng = Rng::new(7);
        let encs: Vec<Encoding> = (0..6)
            .map(|_| Encoding::Dense((0..16).map(|_| rng.normal_f32()).collect()))
            .collect();
        let mut scratch = ShardScratch::new();
        let mut out = Vec::new();
        sharded.top1_batch_into(&encs, Precision::F32, &mut scratch, &mut out);
        assert_eq!(out.len(), encs.len());
        for (enc, &got) in encs.iter().zip(&out) {
            assert_eq!(got, sharded.top1(enc, Precision::F32, &mut scratch));
        }
    }
}
