//! Associative memory (AM): the inference half of the HDC pipeline.
//!
//! Classical HDC inference is a nearest-prototype lookup — encode the
//! query, score it against one prototype hypervector per class, return
//! the best class ("Classification using Hyperdimensional Computing: A
//! Review"). The streaming encoders make the *featurization* cheap
//! enough for a serving hot path (the paper's whole point); this module
//! makes the *lookup* equally cheap: prototypes are stored row-major in
//! three precisions and scored with the branch-free similarity kernels
//! in [`crate::encoding::kernels`]:
//!
//! * **f32** — exact dot-product scoring ([`kernels::dot_f32`]); the
//!   reference precision, bit-compatible with offline
//!   [`LogisticModel`] scoring up to f32-vs-f64 accumulation.
//! * **int8** — symmetric per-class quantization ([`quantize_i8`]); 4×
//!   smaller, scored with the widening integer dot ([`kernels::dot_i8`])
//!   and rescaled once per class.
//! * **binary** — sign-binarized, bit-packed 64 coordinates per word;
//!   32× smaller than f32, scored with popcount-Hamming
//!   ([`kernels::hamming_packed`] for dense queries,
//!   [`kernels::and_popcount`] for sparse ones). "A Theoretical
//!   Perspective on Hyperdimensional Computing" shows sign quantization
//!   preserves the class-separation guarantees, which is why the tiny
//!   store still classifies.
//!
//! Stores are built either from a trained [`LogisticModel`]
//! ([`AmStore::from_logistic`] — two classes, ±θ) or by bundling
//! per-class encoding sums ([`AmBuilder`] — the classic HDC training
//! rule). Scoring is borrow-based: all staging lives in an
//! [`AmScratch`], so the serving loop scores with zero steady-state
//! allocations.
//!
//! # Sharded scan and distributed build
//!
//! Many-class workloads (the HDC classification literature is dominated
//! by them) turn the linear class scan into the serving bottleneck.
//! Two invariants make the store scale out without changing a single
//! result bit:
//!
//! * **Scan sharding partitions classes, never arithmetic.** Each
//!   per-class score is one self-contained kernel call, so scoring
//!   classes `lo..hi` on one thread ([`AmStore::score_range_into`]) and
//!   `hi..` on another produces the same multiset of (class, score)
//!   pairs as the single scan. [`ShardedAmStore`] partitions the class
//!   space into contiguous ranges, scans them on a scoped scorer pool,
//!   and merges with the same deterministic tie-break the single scan
//!   uses — **score descending, lowest class id wins on equal score** —
//!   so `top1`/`topk_into` are exactly equal to [`AmStore`]'s.
//! * **Class sums are commutative bundles.** [`AmBuilder`] prototypes
//!   are element-wise f32 sums of encoded examples, and IEEE-754
//!   addition commutes exactly (`a + b == b + a`, bit for bit), so
//!   [`AmBuilder::merge`] is the contract for distributed building:
//!   shard-local builders over any partition of an example stream merge
//!   to the same sums as one builder seeing the stream in order, as
//!   long as each class's examples keep their relative order across the
//!   merge sequence (partitioning *examples* arbitrarily is exact for
//!   integer-valued sums — e.g. sparse 0/1 encodings — while float
//!   bundles rely on the per-class order, since IEEE addition does not
//!   associate). `tests/prop_invariants.rs` pins both laws.

pub mod quantize;
pub mod shard;

pub use quantize::{pack_indices, pack_signs, quantize_i8, words_for};
pub use shard::{ShardScratch, ShardedAmStore};

use crate::encoding::kernels;
use crate::encoding::Encoding;
use crate::model::LogisticModel;

/// Which prototype representation a scoring call reads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    F32,
    Int8,
    Binary,
}

impl Precision {
    /// Every scoring precision, in report order — sweeps (benches, the
    /// perf snapshot, the multi-model registry tests) iterate this
    /// instead of hand-listing variants.
    pub const ALL: [Precision; 3] = [Precision::F32, Precision::Int8, Precision::Binary];

    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
            Precision::Binary => "binary",
        }
    }
}

/// Reusable scoring scratch: per-class score staging plus the quantized
/// views of the current query. One per scoring thread; recycling it
/// keeps the serve loop allocation-free after warmup.
#[derive(Debug, Default)]
pub struct AmScratch {
    /// Scores of the most recent [`AmStore::score_into`] call, one per
    /// class, in class order.
    pub scores: Vec<f32>,
    /// Packed query bits (dense sign rows or sparse active-coordinate
    /// rows, depending on the query representation).
    qbits: Vec<u64>,
    /// Int8-quantized dense query.
    q_i8: Vec<i8>,
}

impl AmScratch {
    pub fn new() -> AmScratch {
        AmScratch::default()
    }
}

/// Per-class prototype store, all three precisions materialized at
/// construction (the store is tiny next to the encoder state: C·d f32s
/// plus the int8 and packed-sign mirrors — for the paper's d=20k and a
/// binary task that is ~160 KiB + ~40 KiB + ~5 KiB).
#[derive(Clone, Debug)]
pub struct AmStore {
    d: usize,
    n_classes: usize,
    /// Row-major (n_classes × d) f32 prototypes.
    protos: Vec<f32>,
    /// Per-class additive bias, applied to f32 and int8 scores
    /// (logistic-derived stores carry ±bias; bundled stores carry 0).
    biases: Vec<f32>,
    /// Row-major (n_classes × d) symmetric int8 prototypes.
    protos_i8: Vec<i8>,
    /// Per-class int8 dequantization scales.
    scales: Vec<f32>,
    /// Row-major (n_classes × words_per_row) packed sign rows
    /// (bit set ⇔ coordinate negative).
    protos_bits: Vec<u64>,
    words_per_row: usize,
}

impl AmStore {
    /// Build a store from per-class f32 prototype rows (all of length
    /// `d`) and optional per-class biases. The int8 and binary mirrors
    /// are derived immediately.
    pub fn from_prototypes(d: usize, rows: &[Vec<f32>], biases: Option<&[f32]>) -> AmStore {
        let n_classes = rows.len();
        assert!(n_classes > 0, "AmStore needs at least one class");
        if let Some(b) = biases {
            assert_eq!(b.len(), n_classes, "one bias per class");
        }
        let words_per_row = words_for(d);
        let mut protos = Vec::with_capacity(n_classes * d);
        let mut protos_i8 = Vec::with_capacity(n_classes * d);
        let mut scales = Vec::with_capacity(n_classes);
        let mut protos_bits = Vec::with_capacity(n_classes * words_per_row);
        let mut qrow: Vec<i8> = Vec::with_capacity(d);
        let mut brow: Vec<u64> = Vec::with_capacity(words_per_row);
        for row in rows {
            assert_eq!(row.len(), d, "prototype row length != d");
            protos.extend_from_slice(row);
            scales.push(quantize_i8(row, &mut qrow));
            protos_i8.extend_from_slice(&qrow);
            pack_signs(row, &mut brow);
            protos_bits.extend_from_slice(&brow);
        }
        let biases = match biases {
            Some(b) => b.to_vec(),
            None => vec![0.0; n_classes],
        };
        AmStore { d, n_classes, protos, biases, protos_i8, scales, protos_bits, words_per_row }
    }

    /// A two-class store from a trained binary logistic model: class 1
    /// holds (+θ, +bias), class 0 holds (−θ, −bias), so f32 top-1 equals
    /// the sign of the offline score `θ·φ + b` (ties — score exactly
    /// zero — break to class 0; [`LogisticModel`] rounds them up to
    /// class 1, and f32-vs-f64 accumulation can differ in the last ulp,
    /// so callers comparing the two should margin-guard near-zero
    /// scores).
    pub fn from_logistic(m: &LogisticModel) -> AmStore {
        let neg: Vec<f32> = m.theta.iter().map(|t| -t).collect();
        AmStore::from_prototypes(
            m.dim(),
            &[neg, m.theta.clone()],
            Some(&[-m.bias, m.bias]),
        )
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Prototype bytes resident for one precision (the serving-memory
    /// axis: binary is 32× smaller than f32).
    pub fn memory_bytes(&self, prec: Precision) -> usize {
        match prec {
            Precision::F32 => self.protos.len() * 4 + self.biases.len() * 4,
            Precision::Int8 => self.protos_i8.len() + self.scales.len() * 4 + self.biases.len() * 4,
            Precision::Binary => self.protos_bits.len() * 8,
        }
    }

    /// Class `c`'s f32 prototype row (the reference representation the
    /// int8/binary mirrors are derived from). Exposed so distributed
    /// builds can assert bit-identity of finished stores.
    pub fn prototype(&self, c: usize) -> &[f32] {
        self.row_f32(c)
    }

    /// Class `c`'s additive bias.
    pub fn bias(&self, c: usize) -> f32 {
        self.biases[c]
    }

    #[inline]
    fn row_f32(&self, c: usize) -> &[f32] {
        &self.protos[c * self.d..(c + 1) * self.d]
    }

    #[inline]
    fn row_i8(&self, c: usize) -> &[i8] {
        &self.protos_i8[c * self.d..(c + 1) * self.d]
    }

    #[inline]
    fn row_bits(&self, c: usize) -> &[u64] {
        &self.protos_bits[c * self.words_per_row..(c + 1) * self.words_per_row]
    }

    /// Score `enc` against every class prototype at the requested
    /// precision, into `scratch.scores` (class order). Allocation-free
    /// once the scratch buffers are warm.
    ///
    /// Score semantics per precision:
    /// * `F32`: `dot(q, proto_c) + bias_c` (f32, lane-striped kernel).
    /// * `Int8`: `dot_i8(q8, p8_c) · scale_q · scale_c + bias_c` for
    ///   dense queries (the query is quantized once per call); sparse
    ///   0/1 queries skip query quantization and sum `p8_c` at the
    ///   active coordinates.
    /// * `Binary`: the ±1 dot `d − 2·hamming` for dense queries,
    ///   `nnz − 2·overlap(active, negative)` for sparse ones. No bias —
    ///   a Hamming count and an f32 bias live on different scales, and
    ///   binarized scoring is only meaningful as a ranking.
    pub fn score_into(&self, enc: &Encoding, prec: Precision, scratch: &mut AmScratch) {
        self.score_range_into(enc, prec, 0, self.n_classes, scratch);
    }

    /// [`AmStore::score_into`] restricted to classes `lo..hi`:
    /// `scratch.scores[i]` holds class `lo + i`'s score. The per-class
    /// arithmetic is identical to the full scan (one self-contained
    /// kernel call per class; query staging does not depend on the
    /// range), so a partitioned scan — the [`ShardedAmStore`] shard
    /// loop — reproduces the full scan's scores bit for bit.
    pub fn score_range_into(
        &self,
        enc: &Encoding,
        prec: Precision,
        lo: usize,
        hi: usize,
        scratch: &mut AmScratch,
    ) {
        assert_eq!(enc.dim(), self.d, "query dim != store dim");
        assert!(lo <= hi && hi <= self.n_classes, "class range out of bounds");
        scratch.scores.clear();
        match (prec, enc) {
            (Precision::F32, Encoding::Dense(q)) => {
                for c in lo..hi {
                    scratch.scores.push(kernels::dot_f32(q, self.row_f32(c)) + self.biases[c]);
                }
            }
            (Precision::F32, Encoding::SparseBinary { indices, .. }) => {
                for c in lo..hi {
                    let row = self.row_f32(c);
                    let mut acc = 0.0f32;
                    for &i in indices.iter() {
                        acc += row[i as usize];
                    }
                    scratch.scores.push(acc + self.biases[c]);
                }
            }
            (Precision::Int8, Encoding::Dense(q)) => {
                let qscale = quantize_i8(q, &mut scratch.q_i8);
                for c in lo..hi {
                    let dot = kernels::dot_i8(&scratch.q_i8, self.row_i8(c));
                    scratch.scores.push(dot as f32 * (qscale * self.scales[c]) + self.biases[c]);
                }
            }
            (Precision::Int8, Encoding::SparseBinary { indices, .. }) => {
                for c in lo..hi {
                    let row = self.row_i8(c);
                    let mut acc = 0i64;
                    for &i in indices.iter() {
                        acc += row[i as usize] as i64;
                    }
                    scratch.scores.push(acc as f32 * self.scales[c] + self.biases[c]);
                }
            }
            (Precision::Binary, Encoding::Dense(q)) => {
                pack_signs(q, &mut scratch.qbits);
                for c in lo..hi {
                    let h = kernels::hamming_packed(&scratch.qbits, self.row_bits(c));
                    scratch.scores.push(self.d as f32 - 2.0 * h as f32);
                }
            }
            (Precision::Binary, Encoding::SparseBinary { indices, d }) => {
                pack_indices(indices, *d, &mut scratch.qbits);
                for c in lo..hi {
                    let overlap = kernels::and_popcount(&scratch.qbits, self.row_bits(c));
                    scratch.scores.push(indices.len() as f32 - 2.0 * overlap as f32);
                }
            }
        }
    }

    /// Best class and its score. **Tie-break contract:** the strict `>`
    /// over the ascending class scan means the *lowest* class id wins on
    /// equal scores — the same rule [`ShardedAmStore`]'s merge enforces,
    /// which is what makes sharded results exactly equal. Pinned in
    /// `tests/am_sharding.rs`.
    pub fn top1(&self, enc: &Encoding, prec: Precision, scratch: &mut AmScratch) -> (u32, f32) {
        self.score_into(enc, prec, scratch);
        let mut best = 0usize;
        let mut best_score = scratch.scores[0];
        for (c, &s) in scratch.scores.iter().enumerate().skip(1) {
            if s > best_score {
                best = c;
                best_score = s;
            }
        }
        (best as u32, best_score)
    }

    /// Top-k classes by score into a caller-reused `out`. **Tie-break
    /// contract:** score descending, and among equal scores the lowest
    /// class id comes first (the `>=` insertion rule over the ascending
    /// class scan) — the explicit ordering [`ShardedAmStore::topk_into`]'s
    /// shard merge reproduces, pinned in `tests/am_sharding.rs`. O(C·k)
    /// insertion — class and k counts are small on the serving path.
    pub fn topk_into(
        &self,
        enc: &Encoding,
        prec: Precision,
        k: usize,
        scratch: &mut AmScratch,
        out: &mut Vec<(u32, f32)>,
    ) {
        self.score_into(enc, prec, scratch);
        out.clear();
        let k = k.min(self.n_classes).max(1);
        for (c, &s) in scratch.scores.iter().enumerate() {
            topk_insert(out, k, c as u32, s);
        }
    }
}

/// Insert `(class, s)` into the sorted top-k list `out` (score
/// descending, class ascending within equal scores — the order falls
/// out of the `>=` partition point **only when classes are inserted in
/// ascending class order**, which both the single scan and each shard's
/// local scan do).
pub(crate) fn topk_insert(out: &mut Vec<(u32, f32)>, k: usize, class: u32, s: f32) {
    // `>=` keeps earlier (lower-id) classes ahead of later equal scores.
    let pos = out.partition_point(|&(_, os)| os >= s);
    if pos < k {
        if out.len() == k {
            out.pop();
        }
        out.insert(pos, (class, s));
    }
}

/// Bundling-rule trainer: prototypes as per-class sums (optionally
/// means) of encoded examples — the classic one-pass HDC learning rule,
/// streamable and merge-able across shards (sums commute).
#[derive(Clone, Debug)]
pub struct AmBuilder {
    d: usize,
    /// Row-major (n_classes × d) running sums.
    sums: Vec<f32>,
    counts: Vec<u64>,
}

impl AmBuilder {
    pub fn new(d: usize, n_classes: usize) -> AmBuilder {
        assert!(n_classes > 0);
        AmBuilder { d, sums: vec![0.0; n_classes * d], counts: vec![0; n_classes] }
    }

    pub fn n_classes(&self) -> usize {
        self.counts.len()
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Row-major (n_classes × d) running sums — exposed so the
    /// distributed-build property tests can assert merge bit-identity
    /// without finishing a store.
    pub fn sums(&self) -> &[f32] {
        &self.sums
    }

    /// Per-class example counts accumulated so far.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Accumulate one encoded example into its class sum.
    pub fn add(&mut self, class: usize, enc: &Encoding) {
        assert_eq!(enc.dim(), self.d, "encoding dim != builder dim");
        let row = &mut self.sums[class * self.d..(class + 1) * self.d];
        match enc {
            Encoding::Dense(v) => kernels::axpy(row, v, 1.0),
            Encoding::SparseBinary { indices, .. } => {
                for &i in indices.iter() {
                    row[i as usize] += 1.0;
                }
            }
        }
        self.counts[class] += 1;
    }

    /// Merge another builder's sums — **the distributed-build
    /// contract**: class sums are commutative bundles, so shard-local
    /// builders over any split of an example stream merge to the same
    /// prototypes as one builder. Exactly commutative for all floats
    /// (IEEE addition commutes bit for bit); exactly associative — and
    /// hence order-free across any N-way merge tree — when the sums are
    /// integer-valued (e.g. sparse 0/1 encodings) and small enough to be
    /// exact in f32. Both laws are pinned in `tests/prop_invariants.rs`.
    pub fn merge(&mut self, other: &AmBuilder) {
        assert_eq!(self.d, other.d);
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, &b) in self.sums.iter_mut().zip(&other.sums) {
            *a += b;
        }
        for (a, &b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }

    /// Finish into a store. `normalize` divides each sum by its class
    /// count (mean prototypes — insensitive to class imbalance; raw sums
    /// favor frequent classes, which is sometimes what a CTR-style task
    /// wants).
    pub fn finish(self, normalize: bool) -> AmStore {
        let d = self.d;
        let rows: Vec<Vec<f32>> = self
            .sums
            .chunks_exact(d)
            .zip(&self.counts)
            .map(|(row, &n)| {
                if normalize && n > 0 {
                    let inv = 1.0f32 / n as f32;
                    row.iter().map(|&x| x * inv).collect()
                } else {
                    row.to_vec()
                }
            })
            .collect();
        AmStore::from_prototypes(d, &rows, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::sparse_from_indices;
    use crate::util::rng::Rng;

    fn dense(v: &[f32]) -> Encoding {
        Encoding::Dense(v.to_vec())
    }

    #[test]
    fn f32_scoring_matches_manual_dot() {
        let store = AmStore::from_prototypes(
            4,
            &[vec![1.0, 0.0, -1.0, 2.0], vec![0.5, 0.5, 0.5, 0.5]],
            Some(&[0.25, -0.25]),
        );
        let mut s = AmScratch::new();
        store.score_into(&dense(&[1.0, 2.0, 3.0, 4.0]), Precision::F32, &mut s);
        assert_eq!(s.scores.len(), 2);
        assert!((s.scores[0] - (1.0 - 3.0 + 8.0 + 0.25)).abs() < 1e-6);
        assert!((s.scores[1] - (5.0 - 0.25)).abs() < 1e-6);
        // Sparse query: sum of prototype coords at active indices.
        store.score_into(&sparse_from_indices(vec![0, 3], 4), Precision::F32, &mut s);
        assert!((s.scores[0] - (1.0 + 2.0 + 0.25)).abs() < 1e-6);
    }

    #[test]
    fn logistic_store_top1_matches_score_sign() {
        let mut rng = Rng::new(11);
        let d = 64;
        let mut m = LogisticModel::new(d);
        for t in m.theta.iter_mut() {
            *t = rng.normal_f32();
        }
        m.bias = 0.3;
        let store = AmStore::from_logistic(&m);
        let mut s = AmScratch::new();
        for _ in 0..100 {
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let enc = dense(&q);
            let z = m.score(&enc);
            if z.abs() < 1e-3 {
                continue; // margin-guard f32-vs-f64 accumulation
            }
            let (top, _) = store.top1(&enc, Precision::F32, &mut s);
            assert_eq!(top == 1, z > 0.0, "z={z}");
        }
    }

    #[test]
    fn binary_scoring_matches_naive_sign_dot() {
        let mut rng = Rng::new(12);
        let d = 130; // straddles two packed words + a tail
        let rows: Vec<Vec<f32>> =
            (0..3).map(|_| (0..d).map(|_| rng.normal_f32()).collect()).collect();
        let store = AmStore::from_prototypes(d, &rows, None);
        let mut s = AmScratch::new();
        for case in 0..20 {
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            store.score_into(&dense(&q), Precision::Binary, &mut s);
            for (c, row) in rows.iter().enumerate() {
                // Naive ±1 dot of the two sign vectors.
                let want: i64 = q
                    .iter()
                    .zip(row)
                    .map(|(&x, &p)| {
                        let sx = if x >= 0.0 { 1i64 } else { -1 };
                        let sp = if p >= 0.0 { 1i64 } else { -1 };
                        sx * sp
                    })
                    .sum();
                assert_eq!(s.scores[c], want as f32, "case {case} class {c}");
            }
        }
    }

    #[test]
    fn binary_sparse_scoring_matches_naive() {
        let mut rng = Rng::new(13);
        let d = 200;
        let rows: Vec<Vec<f32>> =
            (0..2).map(|_| (0..d).map(|_| rng.normal_f32()).collect()).collect();
        let store = AmStore::from_prototypes(d, &rows, None);
        let mut s = AmScratch::new();
        for _ in 0..20 {
            let idx: Vec<u32> = (0..30).map(|_| rng.below(d as u64) as u32).collect();
            let enc = sparse_from_indices(idx, d);
            store.score_into(&enc, Precision::Binary, &mut s);
            if let Encoding::SparseBinary { indices, .. } = &enc {
                for (c, row) in rows.iter().enumerate() {
                    let want: i64 = indices
                        .iter()
                        .map(|&i| if row[i as usize] >= 0.0 { 1i64 } else { -1 })
                        .sum();
                    assert_eq!(s.scores[c], want as f32, "class {c}");
                }
            }
        }
    }

    #[test]
    fn int8_scoring_matches_exact_formula() {
        let mut rng = Rng::new(14);
        let d = 50;
        let rows: Vec<Vec<f32>> =
            (0..2).map(|_| (0..d).map(|_| rng.normal_f32()).collect()).collect();
        let store = AmStore::from_prototypes(d, &rows, None);
        let mut s = AmScratch::new();
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        store.score_into(&dense(&q), Precision::Int8, &mut s);
        // Replicate the quantize + integer-dot + rescale pipeline.
        let mut q8 = Vec::new();
        let qscale = quantize_i8(&q, &mut q8);
        for (c, row) in rows.iter().enumerate() {
            let mut p8 = Vec::new();
            let pscale = quantize_i8(row, &mut p8);
            let dot: i64 = q8.iter().zip(&p8).map(|(&a, &b)| a as i64 * b as i64).sum();
            let want = dot as f32 * (qscale * pscale);
            assert_eq!(s.scores[c], want, "class {c}");
        }
    }

    #[test]
    fn builder_bundles_and_classifies_clustered_data() {
        // Two well-separated clusters of dense vectors; mean prototypes
        // must classify fresh samples from each cluster.
        let mut rng = Rng::new(15);
        let d = 256;
        let centers: Vec<Vec<f32>> =
            (0..2).map(|_| (0..d).map(|_| rng.normal_f32() * 2.0).collect()).collect();
        let sample = |rng: &mut Rng, c: usize| -> Vec<f32> {
            centers[c].iter().map(|&x| x + rng.normal_f32() * 0.5).collect()
        };
        let mut b = AmBuilder::new(d, 2);
        for _ in 0..50 {
            for c in 0..2 {
                b.add(c, &dense(&sample(&mut rng, c)));
            }
        }
        let store = b.finish(true);
        let mut s = AmScratch::new();
        let mut correct = 0;
        for _ in 0..40 {
            for c in 0..2 {
                let (top, _) = store.top1(&dense(&sample(&mut rng, c)), Precision::F32, &mut s);
                if top as usize == c {
                    correct += 1;
                }
            }
        }
        assert!(correct >= 72, "only {correct}/80 correct");
    }

    #[test]
    fn topk_orders_and_breaks_ties_by_class() {
        let store = AmStore::from_prototypes(
            2,
            &[vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 0.0]],
            None,
        );
        let mut s = AmScratch::new();
        let mut out = Vec::new();
        // Query [1, 0]: classes 0 and 2 tie at 1.0, class 1 scores 0.
        store.topk_into(&dense(&[1.0, 0.0]), Precision::F32, 3, &mut s, &mut out);
        assert_eq!(out.len(), 3);
        assert_eq!((out[0].0, out[1].0, out[2].0), (0, 2, 1));
        store.topk_into(&dense(&[1.0, 0.0]), Precision::F32, 1, &mut s, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, 0);
    }

    #[test]
    fn memory_accounting_orders_precisions() {
        let store = AmStore::from_prototypes(1000, &[vec![1.0; 1000]; 4], None);
        let f = store.memory_bytes(Precision::F32);
        let i = store.memory_bytes(Precision::Int8);
        let b = store.memory_bytes(Precision::Binary);
        assert!(b < i && i < f, "{b} {i} {f}");
        assert!(f >= 16_000);
        assert_eq!(b, 4 * 16 * 8); // 1000 bits -> 16 words per class
    }

    #[test]
    fn builder_merge_equals_single_builder() {
        let mut rng = Rng::new(16);
        let d = 32;
        let encs: Vec<(usize, Encoding)> = (0..20)
            .map(|i| {
                let idx: Vec<u32> = (0..5).map(|_| rng.below(d as u64) as u32).collect();
                (i % 2, sparse_from_indices(idx, d))
            })
            .collect();
        let mut whole = AmBuilder::new(d, 2);
        let mut a = AmBuilder::new(d, 2);
        let mut b = AmBuilder::new(d, 2);
        for (i, (c, e)) in encs.iter().enumerate() {
            whole.add(*c, e);
            if i % 2 == 0 { a.add(*c, e) } else { b.add(*c, e) }
        }
        a.merge(&b);
        assert_eq!(a.sums, whole.sums);
        assert_eq!(a.counts, whole.counts);
    }
}
