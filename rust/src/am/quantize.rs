//! Prototype quantization for the associative-memory store: symmetric
//! int8 and sign-binarized bit-packed forms of an f32 hypervector.
//!
//! Both are *lossy re-representations of the same prototype*, which is
//! exactly what the HDC theory permits: "A Theoretical Perspective on
//! Hyperdimensional Computing" shows the class-separation margins that
//! make AM lookup work survive coordinate-wise quantization down to
//! signs (the information lives in the high-dimensional direction, not
//! the per-coordinate magnitudes). The store therefore keeps all three
//! precisions and lets the serving layer pick its point on the
//! memory/accuracy curve.
//!
//! Conventions (shared with the kernel layer):
//! * int8 is **symmetric**: `scale = max|v| / 127` (1.0 for an all-zero
//!   or non-finite-max row), `q[i] = round(v[i] / scale)` clamped to
//!   ±127, dequantized as `q[i] · scale`.
//! * sign packing matches [`crate::encoding::kernels::sign_quantize`]:
//!   `sign(0) := +1` (both zero encodings), NaN compares false hence −1.
//!   A **set** bit means *negative*, so an all-zero row packs to all-zero
//!   words.

/// Symmetric int8 quantization of `v`, appended into `out` (cleared
/// first); returns the scale. `q · scale` reconstructs each coordinate
/// to within `scale / 2`.
pub fn quantize_i8(v: &[f32], out: &mut Vec<i8>) -> f32 {
    out.clear();
    out.reserve(v.len());
    let mut max_abs = 0.0f32;
    for &x in v {
        let a = x.abs();
        if a > max_abs {
            max_abs = a;
        }
    }
    // All-zero rows (and rows whose max is NaN/inf, which never occur
    // from finite encodings) quantize against scale 1.0: q = clamp(round(v)).
    let scale = if max_abs > 0.0 && max_abs.is_finite() { max_abs / 127.0 } else { 1.0 };
    for &x in v {
        let q = (x / scale).round();
        out.push(q.clamp(-127.0, 127.0) as i8);
    }
    scale
}

/// Number of packed u64 words a `d`-dimensional sign row occupies.
#[inline]
pub fn words_for(d: usize) -> usize {
    d.div_ceil(64)
}

/// Sign-binarize `v` into packed words appended to `out` (cleared
/// first): bit `i` of the row is set iff `v[i]` is negative under the
/// `sign(0) := +1` convention (NaN packs as negative, matching
/// `sign_quantize`). Trailing pad bits of the last word are zero.
pub fn pack_signs(v: &[f32], out: &mut Vec<u64>) {
    out.clear();
    out.resize(words_for(v.len()), 0);
    for (i, &x) in v.iter().enumerate() {
        if !(x >= 0.0) {
            out[i >> 6] |= 1u64 << (i & 63);
        }
    }
}

/// Pack a sparse-binary encoding's active coordinates into a `d`-wide
/// bit row (bit set ⇔ coordinate active), appended to `out` (cleared
/// first). Used to score sparse queries against packed sign rows via
/// `and_popcount`.
pub fn pack_indices(indices: &[u32], d: usize, out: &mut Vec<u64>) {
    out.clear();
    out.resize(words_for(d), 0);
    for &i in indices {
        debug_assert!((i as usize) < d);
        out[(i >> 6) as usize] |= 1u64 << (i & 63);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_round_trips_within_half_scale() {
        let v = vec![0.0f32, 1.0, -2.5, 127.0, -127.0, 0.3];
        let mut q = Vec::new();
        let scale = quantize_i8(&v, &mut q);
        assert_eq!(q.len(), v.len());
        for (&x, &qi) in v.iter().zip(&q) {
            let rec = qi as f32 * scale;
            assert!((x - rec).abs() <= scale / 2.0 + 1e-6, "{x} -> {qi} ({rec})");
        }
        // Extremes hit exactly ±127.
        assert_eq!(q[3], 127);
        assert_eq!(q[4], -127);
    }

    #[test]
    fn quantize_all_zero_row() {
        let mut q = Vec::new();
        let scale = quantize_i8(&[0.0, 0.0, -0.0], &mut q);
        assert_eq!(scale, 1.0);
        assert_eq!(q, vec![0, 0, 0]);
    }

    #[test]
    fn pack_signs_convention_and_padding() {
        let v = vec![1.0f32, -1.0, 0.0, -0.0, f32::NAN];
        let mut bits = Vec::new();
        pack_signs(&v, &mut bits);
        assert_eq!(bits.len(), 1);
        // -1.0 at bit 1; -0.0 is non-negative under >= 0; NaN packs set.
        assert_eq!(bits[0], (1 << 1) | (1 << 4));
        // 65 coords -> 2 words, pad bits clear.
        let v2 = vec![-1.0f32; 65];
        pack_signs(&v2, &mut bits);
        assert_eq!(bits.len(), 2);
        assert_eq!(bits[0], u64::MAX);
        assert_eq!(bits[1], 1);
    }

    #[test]
    fn pack_indices_sets_active_bits() {
        let mut bits = Vec::new();
        pack_indices(&[0, 63, 64, 100], 128, &mut bits);
        assert_eq!(bits.len(), 2);
        assert_eq!(bits[0], 1 | (1 << 63));
        assert_eq!(bits[1], 1 | (1 << 36));
        // Reused buffer is fully reset.
        pack_indices(&[], 64, &mut bits);
        assert_eq!(bits, vec![0]);
    }
}
