//! Live metrics exposition: the publisher/exporter half of the
//! monitoring subsystem (`crate::obs::health` holds the SLO judgment).
//!
//! # Dataflow
//!
//! ```text
//!  serve counters ──► MetricsPublisher thread (publish_interval tick)
//!  + histograms         │ one Sample per tick (counters are monotone,
//!  + tracer gauges      ▼  so consecutive samples subtract exactly)
//!                 SampleRing (preallocated, overwrite-oldest)
//!                      │ last two samples = one window
//!                      ▼
//!          WindowObs deltas ──► SloEvaluator ──► HealthReport
//!                      │                │
//!                      ▼                ▼ lifecycle events
//!               WindowRates        EventRing (bounded)
//!                      │                │
//!                      ▼                ▼
//!   listener thread (std::net::TcpListener, `ServeCfg::metrics_addr`)
//!       GET /metrics   Prometheus text exposition (see below)
//!       GET /health    {"health": verdict+rates, "events": [...]}
//!       GET /snapshot  the ObsSnapshot JSON (stage histograms, gauges)
//! ```
//!
//! Both threads are owned by the server: spawned at construction,
//! stopped and joined by `Server::run` on shutdown. Nothing here
//! touches the request hot path — `classify` never reads or writes the
//! hub, so the zero-allocation serve window holds with publishing
//! enabled (pinned by `tests/alloc_regression.rs`).
//!
//! # Scraping
//!
//! ```text
//! curl http://127.0.0.1:9464/metrics     # Prometheus text format
//! curl http://127.0.0.1:9464/health     # JSON verdict + recent events
//! curl http://127.0.0.1:9464/snapshot   # per-stage/per-model histograms
//! ```
//!
//! `/metrics` reads the live counters at scrape time (honest Prometheus
//! semantics: two scrapes subtract to exactly the traffic between
//! them); the windowed `shdc_window_*` and `shdc_slo_*` series come
//! from the publisher's last window. Every emitted line parses as
//! `name{labels} value` — [`parse_exposition`] is the checker the tests
//! and the `serve_bench --metrics-addr` smoke run against the real
//! output.
//!
//! The exporter is deliberately minimal HTTP/1.1: one connection served
//! at a time (inherently bounded), 4 KiB request cap, read/write
//! timeouts, `Connection: close` on every response. A scraper cannot
//! wedge the server — the worst a slow client can do is delay the next
//! scrape.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::obs::health::{
    EventKind, EventRing, HealthReport, ObsEvent, SloCfg, SloEvaluator, WindowObs,
};
use crate::obs::{json as obs_json, Stage};
use crate::serve::latency::HistBuckets;
use crate::serve::{HistSnapshot, ModelSnapshot, ServeHandle, ServeSnapshot};
use crate::util::json::Json;
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned};

/// Samples the publisher retains (~25 s of history at the default
/// 100 ms interval). Windows only ever need the last two; the rest is
/// scrape-time headroom and wraparound slack.
const RING_CAP: usize = 256;
/// Lifecycle events retained between drains.
const EVENT_CAP: usize = 256;
/// Accept-loop poll period while idle (stop-flag latency bound).
const ACCEPT_POLL: Duration = Duration::from_millis(2);

/// Publisher configuration, assembled by `Server::with_registry` from
/// the serve config.
#[derive(Clone, Debug)]
pub struct PublishCfg {
    /// Sampling interval (`ServeCfg::publish_interval`); one window per
    /// tick. Clamped to ≥ 1 ms.
    pub interval: Duration,
    /// SLO objectives (`ServeCfg::slo`, or defaults when only
    /// `metrics_addr` enabled publishing).
    pub slo: SloCfg,
    /// Worker-pool size for the liveness check.
    pub configured_workers: u64,
    /// Submission-queue capacity for saturation events.
    pub queue_cap: u64,
}

/// One timestamped capture of every monotone counter + histogram the
/// windowed derivation needs. Cloned only on the publisher thread.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Nanoseconds since the hub's epoch.
    pub t_ns: u64,
    pub serve: ServeSnapshot,
    /// Raw end-to-end latency buckets ([`HistBuckets::diff`] pairs).
    pub latency: HistBuckets,
    /// Raw per-stage buckets ([`Stage::ALL`] order; empty when tracing
    /// is disabled).
    pub stages: Vec<HistBuckets>,
    pub live_workers: u64,
    pub queue_depth: u64,
}

/// Preallocated overwrite-oldest ring of [`Sample`]s.
#[derive(Debug)]
pub struct SampleRing {
    cap: usize,
    buf: Vec<Sample>,
    /// Index of the oldest sample once the ring is full.
    at: usize,
    /// Samples ever pushed (wraparound accounting).
    total: u64,
}

impl SampleRing {
    pub fn new(cap: usize) -> SampleRing {
        let cap = cap.max(2); // a window needs two samples
        SampleRing { cap, buf: Vec::with_capacity(cap), at: 0, total: 0 }
    }

    pub fn push(&mut self, s: Sample) {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push(s);
        } else {
            self.buf[self.at] = s;
            self.at = (self.at + 1) % self.cap;
        }
    }

    /// Samples currently retained.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Samples ever pushed (≥ `len()`; the difference wrapped around).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Newest sample.
    pub fn latest(&self) -> Option<&Sample> {
        if self.buf.is_empty() {
            return None;
        }
        let newest = if self.buf.len() < self.cap {
            self.buf.len() - 1
        } else {
            (self.at + self.cap - 1) % self.cap
        };
        self.buf.get(newest)
    }

    /// The two newest samples, older first — one window.
    pub fn last_two(&self) -> Option<(&Sample, &Sample)> {
        if self.buf.len() < 2 {
            return None;
        }
        if self.buf.len() < self.cap {
            // Not yet wrapped: indices are dense 0..len in push order.
            let n = self.buf.len();
            Some((&self.buf[n - 2], &self.buf[n - 1]))
        } else {
            let newest = (self.at + self.cap - 1) % self.cap;
            let prev = (newest + self.cap - 1) % self.cap;
            Some((&self.buf[prev], &self.buf[newest]))
        }
    }
}

/// Windowed rates between two samples — exact counter deltas over the
/// wall-clock gap ([`ServeHandle::window_rates`], the `shdc_window_*`
/// exposition series, and the perf snapshot's windowed section).
#[derive(Clone, Debug)]
pub struct WindowRates {
    /// Window width, seconds.
    pub window_s: f64,
    pub submitted_per_s: f64,
    pub completed_per_s: f64,
    /// Overload sheds (`Shed` + admission timeouts) per second.
    pub shed_per_s: f64,
    /// Tenant-quota (policy) sheds per second.
    pub quota_shed_per_s: f64,
    /// Encode-batch failures (worker panics) per second.
    pub failed_per_s: f64,
    /// Deadline expiries per second.
    pub expired_per_s: f64,
    /// Distribution of exactly this window's latency samples.
    pub latency: HistSnapshot,
    /// Windowed per-stage distributions ([`Stage::ALL`] names); empty
    /// when tracing is disabled.
    pub stages: Vec<(&'static str, HistSnapshot)>,
}

impl WindowRates {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("window_s", Json::num(self.window_s)),
            ("submitted_per_s", Json::num(self.submitted_per_s)),
            ("completed_per_s", Json::num(self.completed_per_s)),
            ("shed_per_s", Json::num(self.shed_per_s)),
            ("quota_shed_per_s", Json::num(self.quota_shed_per_s)),
            ("failed_per_s", Json::num(self.failed_per_s)),
            ("expired_per_s", Json::num(self.expired_per_s)),
            ("latency", obs_json::hist_json(&self.latency)),
            (
                "stages",
                Json::Obj(
                    self.stages
                        .iter()
                        .map(|(name, h)| (name.to_string(), obs_json::hist_json(h)))
                        .collect(),
                ),
            ),
        ])
    }
}

fn delta(new: u64, old: u64) -> u64 {
    new.saturating_sub(old)
}

/// Derive the window's exact rates from two monotone samples (older
/// first). A zero-width window yields all-zero rates, never NaN.
pub fn rates_between(prev: &Sample, cur: &Sample) -> WindowRates {
    let dt_ns = delta(cur.t_ns, prev.t_ns);
    let dt_s = dt_ns as f64 / 1e9;
    let per = |d: u64| if dt_ns == 0 { 0.0 } else { d as f64 / dt_s };
    let stages = if cur.stages.len() == Stage::COUNT && prev.stages.len() == Stage::COUNT {
        Stage::ALL
            .iter()
            .zip(cur.stages.iter().zip(&prev.stages))
            .map(|(&s, (c, p))| (s.name(), c.diff(p)))
            .collect()
    } else {
        Vec::new()
    };
    WindowRates {
        window_s: dt_s,
        submitted_per_s: per(delta(cur.serve.submitted, prev.serve.submitted)),
        completed_per_s: per(delta(cur.serve.completed, prev.serve.completed)),
        shed_per_s: per(delta(
            cur.serve.shed + cur.serve.admission_timeouts,
            prev.serve.shed + prev.serve.admission_timeouts,
        )),
        quota_shed_per_s: per(delta(cur.serve.quota_shed, prev.serve.quota_shed)),
        failed_per_s: per(delta(cur.serve.failed, prev.serve.failed)),
        expired_per_s: per(delta(cur.serve.expired, prev.serve.expired)),
        latency: cur.latency.diff(&prev.latency),
        stages,
    }
}

/// The window observation the SLO evaluator consumes, from the same
/// sample pair the rates derive from.
fn window_between(prev: &Sample, cur: &Sample, queue_cap: u64) -> WindowObs {
    let lat = cur.latency.diff(&prev.latency);
    WindowObs {
        t_ns: cur.t_ns,
        window_s: delta(cur.t_ns, prev.t_ns) as f64 / 1e9,
        submitted_delta: delta(cur.serve.submitted, prev.serve.submitted),
        completed_delta: delta(cur.serve.completed, prev.serve.completed),
        shed_delta: delta(
            cur.serve.shed + cur.serve.admission_timeouts,
            prev.serve.shed + prev.serve.admission_timeouts,
        ),
        quota_shed_delta: delta(cur.serve.quota_shed, prev.serve.quota_shed),
        failed_delta: delta(cur.serve.failed, prev.serve.failed),
        expired_delta: delta(cur.serve.expired, prev.serve.expired),
        in_flight: cur.serve.submitted.saturating_sub(cur.serve.completed),
        queue_depth: cur.queue_depth,
        queue_cap,
        live_workers: cur.live_workers,
        p99_ns: lat.p99,
        latency_count: lat.count,
    }
}

/// Shared state of the monitoring threads: the sample ring, the SLO
/// evaluator + latest report, the event ring, and the stop signal. The
/// serve layer holds one `Arc<MetricsHub>` next to its `Shared`; the
/// publisher and listener threads hold clones.
///
/// Lock order: only [`MetricsHub::tick`] holds more than one lock at a
/// time (ring, then evaluator, then events, then health — strictly
/// nested, acquired in that fixed order); every other accessor takes a
/// single lock, so the graph is acyclic.
#[derive(Debug)]
pub struct MetricsHub {
    cfg: PublishCfg,
    /// Origin of every `t_ns` (hub construction).
    epoch: Instant,
    ring: Mutex<SampleRing>,
    evaluator: Mutex<SloEvaluator>,
    events: Mutex<EventRing>,
    health: Mutex<HealthReport>,
    stop: AtomicBool,
    /// Parking lot for the publisher's interval wait (condvar so stop
    /// interrupts a sleep instead of waiting it out).
    stop_mx: Mutex<()>,
    stop_cv: Condvar,
    /// Actual bound address of the listener (set after bind; `None`
    /// when no listener was configured). Lets `metrics_addr: "…:0"`
    /// report the kernel-assigned port.
    bound: Mutex<Option<SocketAddr>>,
}

impl MetricsHub {
    pub fn new(cfg: PublishCfg) -> Arc<MetricsHub> {
        let slo = cfg.slo;
        let workers = cfg.configured_workers;
        Arc::new(MetricsHub {
            cfg,
            epoch: Instant::now(),
            ring: Mutex::new(SampleRing::new(RING_CAP)),
            evaluator: Mutex::new(SloEvaluator::new(slo, workers)),
            events: Mutex::new(EventRing::new(EVENT_CAP)),
            health: Mutex::new(HealthReport::default()),
            stop: AtomicBool::new(false),
            stop_mx: Mutex::new(()),
            stop_cv: Condvar::new(),
            bound: Mutex::new(None),
        })
    }

    /// Nanoseconds since the hub's epoch, on the monotonic clock.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Ingest one sample: push it, and when it closes a window (a
    /// previous sample exists and time advanced), evaluate the SLOs and
    /// refresh the health report. Called by the publisher thread; also
    /// directly by tests.
    pub fn tick(&self, sample: Sample) {
        let mut ring = lock_unpoisoned(&self.ring);
        let prev = ring.latest().cloned();
        ring.push(sample.clone());
        // Holding the ring lock through evaluation keeps tick atomic
        // with respect to concurrent ticks (tests drive tick directly);
        // scrape-side readers take each lock singly and briefly.
        if let Some(prev) = prev {
            if sample.t_ns > prev.t_ns {
                let w = window_between(&prev, &sample, self.cfg.queue_cap);
                let mut evaluator = lock_unpoisoned(&self.evaluator);
                let mut events = lock_unpoisoned(&self.events);
                let report = evaluator.evaluate(&w, &mut events);
                drop(events);
                drop(evaluator);
                *lock_unpoisoned(&self.health) = report;
            }
        }
    }

    /// Signal both monitoring threads to exit. Idempotent — safe to
    /// call any number of times, from any thread.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
        let _g = lock_unpoisoned(&self.stop_mx);
        self.stop_cv.notify_all();
    }

    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }

    /// Park until the next tick is due or [`Self::stop`] fires; `false`
    /// means stopped.
    fn wait_for_tick(&self) -> bool {
        if self.stopped() {
            return false;
        }
        let g = lock_unpoisoned(&self.stop_mx);
        let interval = self.cfg.interval.max(Duration::from_millis(1));
        let (_g, _timeout) = wait_timeout_unpoisoned(&self.stop_cv, g, interval);
        !self.stopped()
    }

    /// Latest SLO report (default-healthy before the first window).
    pub fn health(&self) -> HealthReport {
        lock_unpoisoned(&self.health).clone()
    }

    /// Rates of the last closed window (None before two samples).
    pub fn window_rates(&self) -> Option<WindowRates> {
        let ring = lock_unpoisoned(&self.ring);
        let (prev, cur) = ring.last_two()?;
        Some(rates_between(prev, cur))
    }

    /// Drain the lifecycle event ring (oldest first, ring resets).
    pub fn drain_events(&self) -> Vec<ObsEvent> {
        lock_unpoisoned(&self.events).drain()
    }

    /// Clone the retained events without resetting (the `/health`
    /// endpoint — scrapes must not race consumer drains).
    pub fn peek_events(&self) -> Vec<ObsEvent> {
        lock_unpoisoned(&self.events).peek()
    }

    /// Cumulative event emissions per kind ([`EventKind::ALL`] order) —
    /// the monotone `shdc_events_total` series.
    pub fn event_counts(&self) -> Vec<(&'static str, u64)> {
        let counts = lock_unpoisoned(&self.events).counts();
        EventKind::ALL.iter().map(|k| k.name()).zip(counts).collect()
    }

    /// Samples ever taken / currently retained.
    pub fn sample_counts(&self) -> (u64, usize) {
        let ring = lock_unpoisoned(&self.ring);
        (ring.total(), ring.len())
    }

    /// Actual listener address once bound (supports port 0).
    pub fn bound_addr(&self) -> Option<SocketAddr> {
        *lock_unpoisoned(&self.bound)
    }
}

/// Spawn the `MetricsPublisher` thread: one [`MetricsHub::tick`] per
/// interval, a final closing tick on stop (so end-of-run deltas stay
/// observable), then exit. Joined by `Server::run`.
pub fn spawn_publisher(hub: Arc<MetricsHub>, handle: ServeHandle) -> JoinHandle<()> {
    thread::Builder::new()
        .name("shdc-metrics-pub".to_string())
        .spawn(move || {
            loop {
                let t = hub.now_ns();
                hub.tick(handle.obs_sample(t));
                if !hub.wait_for_tick() {
                    break;
                }
            }
            let t = hub.now_ns();
            hub.tick(handle.obs_sample(t));
        })
        .expect("spawn metrics publisher thread")
}

/// Bind `addr` and spawn the exporter listener thread. The actual
/// address (useful with port 0) is published via
/// [`MetricsHub::bound_addr`] before this returns. Joined by
/// `Server::run`; exit latency is bounded by the accept poll plus at
/// most one in-flight connection's timeouts.
pub fn spawn_listener(
    addr: &str,
    hub: Arc<MetricsHub>,
    handle: ServeHandle,
) -> io::Result<JoinHandle<()>> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    *lock_unpoisoned(&hub.bound) = Some(listener.local_addr()?);
    thread::Builder::new()
        .name("shdc-metrics-http".to_string())
        .spawn(move || {
            while !hub.stopped() {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // One connection at a time: inherently bounded,
                        // and a broken scraper costs at most its
                        // timeouts.
                        let _ = serve_conn(stream, &hub, &handle);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        thread::sleep(ACCEPT_POLL);
                    }
                    Err(_) => thread::sleep(ACCEPT_POLL),
                }
            }
        })
}

/// Handle one scrape connection: parse the request line, route, write
/// one `Connection: close` response.
fn serve_conn(
    mut stream: TcpStream,
    hub: &Arc<MetricsHub>,
    handle: &ServeHandle,
) -> io::Result<()> {
    // The accepted socket must block (the listener itself is
    // nonblocking; inheritance is platform-dependent).
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let mut buf = [0u8; 4096];
    let mut n = 0usize;
    loop {
        if n == buf.len() {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "request larger than 4KiB"));
        }
        let read = stream.read(&mut buf[n..])?;
        if read == 0 {
            break;
        }
        n += read;
        if buf[..n].windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
    }
    let req = String::from_utf8_lossy(&buf[..n]);
    let mut line = req.lines().next().unwrap_or("").split_whitespace();
    let method = line.next().unwrap_or("");
    let path = line.next().unwrap_or("");
    let (status, ctype, body) = if method != "GET" {
        (405, "text/plain; charset=utf-8", "method not allowed\n".to_string())
    } else {
        match path {
            "/metrics" => (
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                render_metrics(handle, hub),
            ),
            "/health" => (200, "application/json", health_body(hub)),
            "/snapshot" => (200, "application/json", handle.obs_snapshot().to_json().pretty()),
            _ => (404, "text/plain; charset=utf-8", "not found\n".to_string()),
        }
    };
    let reason = match status {
        200 => "OK",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// The `/health` response body: latest report + retained events.
fn health_body(hub: &MetricsHub) -> String {
    Json::obj(vec![
        ("health", hub.health().to_json()),
        ("events", Json::Arr(hub.peek_events().iter().map(ObsEvent::to_json).collect())),
    ])
    .pretty()
}

// --- Prometheus text rendering ------------------------------------------

/// Prometheus label-value escaping: `\` → `\\`, `"` → `\"`, newline →
/// `\n`.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Exposition-safe float: finite values verbatim (integers without a
/// trailing `.0`), non-finite clamped to 0 (we never mean NaN/Inf; a
/// poisoned series must not poison the scrape).
fn fmt_value(v: f64) -> String {
    if !v.is_finite() {
        return "0".to_string();
    }
    if v == v.trunc() && v.abs() < 1e15 {
        (v as i64).to_string()
    } else {
        v.to_string()
    }
}

fn type_line(out: &mut String, name: &str, kind: &str) {
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

/// One sample line: `name{labels} value`.
fn sample_line(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(&escape_label(v));
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    out.push_str(&fmt_value(value));
    out.push('\n');
}

/// A counter/gauge with its TYPE line and a single unlabeled sample.
fn scalar(out: &mut String, name: &str, kind: &str, value: f64) {
    type_line(out, name, kind);
    sample_line(out, name, &[], value);
}

/// Summary rendering of a histogram snapshot: quantile samples plus
/// `_count` and `_sum` (sum reconstructed as mean×count — the histogram
/// tracks an exact sum but snapshots carry the mean).
fn summary(out: &mut String, name: &str, labels: &[(&str, &str)], h: &HistSnapshot) {
    let mut q = Vec::with_capacity(labels.len() + 1);
    for &(quantile, v) in &[("0.5", h.p50), ("0.9", h.p90), ("0.99", h.p99)] {
        q.clear();
        q.extend_from_slice(labels);
        q.push(("quantile", quantile));
        sample_line(out, name, &q, v as f64);
    }
    sample_line(out, &format!("{name}_count"), labels, h.count as f64);
    sample_line(out, &format!("{name}_sum"), labels, h.mean * h.count as f64);
}

/// Per-model counter family: one TYPE line, one labeled sample per
/// registered model.
fn model_counter(
    out: &mut String,
    name: &str,
    kind: &str,
    models: &[ModelSnapshot],
    f: impl Fn(&ModelSnapshot) -> f64,
) {
    type_line(out, name, kind);
    for m in models {
        sample_line(out, name, &[("model", &m.name)], f(m));
    }
}

/// Render the full `/metrics` exposition from a **fresh** read of the
/// serve counters (scrape-time truth, so two scrapes reconcile exactly
/// with the traffic between them) plus the hub's windowed/SLO state.
pub fn render_metrics(handle: &ServeHandle, hub: &MetricsHub) -> String {
    let mut out = String::with_capacity(8192);
    let snap = handle.stats();
    let obs = handle.obs_snapshot();

    // --- global counters --------------------------------------------------
    for (name, v) in [
        ("shdc_serve_submitted_total", snap.submitted),
        ("shdc_serve_completed_total", snap.completed),
        ("shdc_serve_rejected_total", snap.rejected),
        ("shdc_serve_shed_total", snap.shed),
        ("shdc_serve_admission_timeouts_total", snap.admission_timeouts),
        ("shdc_serve_expired_total", snap.expired),
        ("shdc_serve_failed_total", snap.failed),
        ("shdc_serve_quota_shed_total", snap.quota_shed),
        ("shdc_serve_batches_total", snap.batches),
        ("shdc_serve_size_cuts_total", snap.size_cuts),
        ("shdc_serve_deadline_cuts_total", snap.deadline_cuts),
        ("shdc_serve_idle_cuts_total", snap.idle_cuts),
        ("shdc_serve_model_cuts_total", snap.model_cuts),
    ] {
        scalar(&mut out, name, "counter", v as f64);
    }

    // --- global distributions + gauges ------------------------------------
    type_line(&mut out, "shdc_serve_latency_ns", "summary");
    summary(&mut out, "shdc_serve_latency_ns", &[], &snap.latency_ns);
    type_line(&mut out, "shdc_serve_queue_depth_at_cut", "summary");
    summary(&mut out, "shdc_serve_queue_depth_at_cut", &[], &snap.queue_depth);
    for (gname, metric) in
        [("queue_depth", "shdc_serve_queue_depth"), ("in_flight", "shdc_serve_in_flight")]
    {
        if let Some((_, v)) = obs.gauges.iter().find(|(n, _)| n == gname) {
            scalar(&mut out, metric, "gauge", *v);
        }
    }
    scalar(&mut out, "shdc_live_workers", "gauge", obs.live_workers as f64);
    scalar(&mut out, "shdc_configured_workers", "gauge", hub.cfg.configured_workers as f64);

    // --- per-model series --------------------------------------------------
    let models = &snap.models;
    model_counter(&mut out, "shdc_model_submitted_total", "counter", models, |m| {
        m.submitted as f64
    });
    model_counter(&mut out, "shdc_model_completed_total", "counter", models, |m| {
        m.completed as f64
    });
    model_counter(&mut out, "shdc_model_rejected_total", "counter", models, |m| {
        m.rejected as f64
    });
    model_counter(&mut out, "shdc_model_shed_total", "counter", models, |m| m.shed as f64);
    model_counter(&mut out, "shdc_model_quota_shed_total", "counter", models, |m| {
        m.quota_shed as f64
    });
    model_counter(&mut out, "shdc_model_expired_total", "counter", models, |m| m.expired as f64);
    model_counter(&mut out, "shdc_model_failed_total", "counter", models, |m| m.failed as f64);
    model_counter(&mut out, "shdc_model_in_flight", "gauge", models, |m| m.in_flight as f64);
    type_line(&mut out, "shdc_model_latency_ns", "summary");
    for m in models {
        summary(&mut out, "shdc_model_latency_ns", &[("model", &m.name)], &m.latency_ns);
    }
    // --- per-shard series --------------------------------------------------
    type_line(&mut out, "shdc_shard_classes", "gauge");
    for m in models {
        for (s, shard) in m.shards.iter().enumerate() {
            let sid = s.to_string();
            sample_line(
                &mut out,
                "shdc_shard_classes",
                &[("model", &m.name), ("shard", &sid)],
                shard.classes as f64,
            );
        }
    }
    type_line(&mut out, "shdc_shard_scans_total", "counter");
    for m in models {
        for (s, shard) in m.shards.iter().enumerate() {
            let sid = s.to_string();
            sample_line(
                &mut out,
                "shdc_shard_scans_total",
                &[("model", &m.name), ("shard", &sid)],
                shard.scans as f64,
            );
        }
    }

    // --- per-stage / per-worker series (tracing only) ----------------------
    if handle.tracing_enabled() {
        type_line(&mut out, "shdc_stage_latency_ns", "summary");
        for st in &obs.stages {
            summary(&mut out, "shdc_stage_latency_ns", &[("stage", st.stage)], &st.hist);
        }
        type_line(&mut out, "shdc_worker_stage_latency_ns", "summary");
        for (w, stages) in handle.worker_stage_snapshots().iter().enumerate() {
            let wid = w.to_string();
            for st in stages {
                summary(
                    &mut out,
                    "shdc_worker_stage_latency_ns",
                    &[("worker", &wid), ("stage", st.stage)],
                    &st.hist,
                );
            }
        }
    }

    // --- windowed rates -----------------------------------------------------
    if let Some(r) = hub.window_rates() {
        scalar(&mut out, "shdc_window_seconds", "gauge", r.window_s);
        for (name, v) in [
            ("shdc_window_submitted_per_s", r.submitted_per_s),
            ("shdc_window_completed_per_s", r.completed_per_s),
            ("shdc_window_shed_per_s", r.shed_per_s),
            ("shdc_window_quota_shed_per_s", r.quota_shed_per_s),
            ("shdc_window_failed_per_s", r.failed_per_s),
            ("shdc_window_expired_per_s", r.expired_per_s),
        ] {
            scalar(&mut out, name, "gauge", v);
        }
        scalar(&mut out, "shdc_window_latency_count", "gauge", r.latency.count as f64);
        scalar(&mut out, "shdc_window_latency_p50_ns", "gauge", r.latency.p50 as f64);
        scalar(&mut out, "shdc_window_latency_p99_ns", "gauge", r.latency.p99 as f64);
        if !r.stages.is_empty() {
            type_line(&mut out, "shdc_window_stage_p50_ns", "gauge");
            for (stage, h) in &r.stages {
                let v = h.p50 as f64;
                sample_line(&mut out, "shdc_window_stage_p50_ns", &[("stage", stage)], v);
            }
            type_line(&mut out, "shdc_window_stage_p99_ns", "gauge");
            for (stage, h) in &r.stages {
                let v = h.p99 as f64;
                sample_line(&mut out, "shdc_window_stage_p99_ns", &[("stage", stage)], v);
            }
        }
    }

    // --- SLO / health -------------------------------------------------------
    let health = hub.health();
    scalar(&mut out, "shdc_slo_verdict", "gauge", health.verdict.severity() as f64);
    scalar(&mut out, "shdc_slo_burn_rate", "gauge", health.burn_rate);
    scalar(&mut out, "shdc_slo_budget_consumed", "gauge", health.budget_consumed);
    scalar(&mut out, "shdc_slo_error_rate", "gauge", health.error_rate);
    scalar(&mut out, "shdc_slo_shed_rate", "gauge", health.shed_rate);
    scalar(&mut out, "shdc_slo_stalled", "gauge", if health.stalled { 1.0 } else { 0.0 });
    scalar(&mut out, "shdc_slo_windows_total", "counter", health.windows as f64);

    // --- lifecycle events ---------------------------------------------------
    type_line(&mut out, "shdc_events_total", "counter");
    for (kind, n) in hub.event_counts() {
        sample_line(&mut out, "shdc_events_total", &[("kind", kind)], n as f64);
    }

    // --- publisher meta -----------------------------------------------------
    let (total, retained) = hub.sample_counts();
    scalar(&mut out, "shdc_publisher_samples_total", "counter", total as f64);
    scalar(&mut out, "shdc_publisher_ring_retained", "gauge", retained as f64);
    out
}

// --- Prometheus text parsing (the validity checker) ----------------------

/// One parsed exposition sample.
#[derive(Clone, Debug, PartialEq)]
pub struct ParsedSeries {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// Validate a Prometheus text exposition: every non-comment, non-blank
/// line must parse as `name{labels} value`. Returns the parsed series,
/// or the first offending line with its number. This is the in-binary
/// check `serve_bench --metrics-addr` runs against the live scrape, and
/// the format contract `tests/obs_export.rs` pins.
pub fn parse_exposition(text: &str) -> Result<Vec<ParsedSeries>, String> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim_end_matches('\r');
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        match parse_sample_line(line) {
            Ok(series) => out.push(series),
            Err(e) => return Err(format!("line {}: {e}: {line:?}", i + 1)),
        }
    }
    Ok(out)
}

fn is_name_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_' || c == ':'
}

fn is_name_char(c: char) -> bool {
    is_name_start(c) || c.is_ascii_digit()
}

fn parse_sample_line(line: &str) -> Result<ParsedSeries, String> {
    let chars: Vec<char> = line.chars().collect();
    let mut i = 0usize;
    // metric name
    if i >= chars.len() || !is_name_start(chars[i]) {
        return Err("expected metric name".to_string());
    }
    let start = i;
    while i < chars.len() && is_name_char(chars[i]) {
        i += 1;
    }
    let name: String = chars[start..i].iter().collect();
    // optional label set
    let mut labels = Vec::new();
    if i < chars.len() && chars[i] == '{' {
        i += 1;
        loop {
            if i >= chars.len() {
                return Err("unterminated label set".to_string());
            }
            if chars[i] == '}' {
                i += 1;
                break;
            }
            // label name
            if !is_name_start(chars[i]) || chars[i] == ':' {
                return Err("expected label name".to_string());
            }
            let ls = i;
            while i < chars.len() && is_name_char(chars[i]) && chars[i] != ':' {
                i += 1;
            }
            let lname: String = chars[ls..i].iter().collect();
            if i >= chars.len() || chars[i] != '=' {
                return Err("expected '=' after label name".to_string());
            }
            i += 1;
            if i >= chars.len() || chars[i] != '"' {
                return Err("expected '\"' opening label value".to_string());
            }
            i += 1;
            let mut lvalue = String::new();
            loop {
                if i >= chars.len() {
                    return Err("unterminated label value".to_string());
                }
                match chars[i] {
                    '"' => {
                        i += 1;
                        break;
                    }
                    '\\' => {
                        i += 1;
                        match chars.get(i) {
                            Some('\\') => lvalue.push('\\'),
                            Some('"') => lvalue.push('"'),
                            Some('n') => lvalue.push('\n'),
                            _ => return Err("bad escape in label value".to_string()),
                        }
                        i += 1;
                    }
                    c => {
                        lvalue.push(c);
                        i += 1;
                    }
                }
            }
            labels.push((lname, lvalue));
            match chars.get(i) {
                Some(',') => i += 1,
                Some('}') => {}
                _ => return Err("expected ',' or '}' after label".to_string()),
            }
        }
    }
    // whitespace, then the value; nothing may follow.
    if i >= chars.len() || !chars[i].is_ascii_whitespace() {
        return Err("expected whitespace before value".to_string());
    }
    while i < chars.len() && chars[i].is_ascii_whitespace() {
        i += 1;
    }
    let vstr: String = chars[i..].iter().collect();
    if vstr.is_empty() || vstr.contains(char::is_whitespace) {
        return Err("expected exactly one value token".to_string());
    }
    let value = match vstr.as_str() {
        "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        "NaN" => f64::NAN,
        s => s.parse::<f64>().map_err(|_| format!("bad value {s:?}"))?,
    };
    Ok(ParsedSeries { name, labels, value })
}

/// Minimal HTTP/1.1 GET over one blocking `TcpStream` (the scrape
/// helper used by `serve_bench` and the exporter tests). Returns
/// `(status, body)`; relies on the server's `Connection: close`.
pub fn http_get(addr: SocketAddr, path: &str, timeout: Duration) -> io::Result<(u16, String)> {
    let mut stream = TcpStream::connect_timeout(&addr, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: shdc\r\nConnection: close\r\n\r\n").as_bytes(),
    )?;
    let mut buf = String::new();
    stream.read_to_string(&mut buf)?;
    let (head, body) = buf
        .split_once("\r\n\r\n")
        .ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidData, "response missing header terminator")
        })?;
    let status = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|c| c.parse::<u16>().ok())
        .ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidData, "malformed status line")
        })?;
    Ok((status, body.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t_ns: u64, submitted: u64, completed: u64) -> Sample {
        Sample {
            t_ns,
            serve: ServeSnapshot { submitted, completed, ..ServeSnapshot::default() },
            latency: HistBuckets::empty(),
            stages: Vec::new(),
            live_workers: 2,
            queue_depth: 0,
        }
    }

    #[test]
    fn sample_ring_wraps_across_window_boundaries() {
        let mut ring = SampleRing::new(4);
        assert!(ring.latest().is_none());
        assert!(ring.last_two().is_none());
        for i in 0..10u64 {
            ring.push(sample(i * 1_000_000, i * 100, i * 90));
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.total(), 10);
        // The newest window straddles the wrapped region and still
        // subtracts exactly.
        let (prev, cur) = ring.last_two().expect("two samples retained");
        assert_eq!(prev.t_ns, 8_000_000);
        assert_eq!(cur.t_ns, 9_000_000);
        let r = rates_between(prev, cur);
        assert!((r.window_s - 0.001).abs() < 1e-12);
        // 100 submissions in 1 ms = 100k/s, derived from exact deltas.
        assert!((r.submitted_per_s - 100_000.0).abs() < 1e-6);
        assert!((r.completed_per_s - 90_000.0).abs() < 1e-6);
    }

    #[test]
    fn window_rates_reconcile_exactly_with_counter_deltas() {
        let prev = sample(1_000_000_000, 1_234, 1_200);
        let cur = sample(3_000_000_000, 5_678, 5_555);
        let r = rates_between(&prev, &cur);
        assert_eq!(r.window_s, 2.0);
        // rate × window width recovers the integer delta exactly.
        assert_eq!((r.submitted_per_s * r.window_s).round() as u64, 5_678 - 1_234);
        assert_eq!((r.completed_per_s * r.window_s).round() as u64, 5_555 - 1_200);
    }

    #[test]
    fn zero_width_window_has_finite_zero_rates() {
        let a = sample(42, 100, 100);
        let r = rates_between(&a, &a);
        assert_eq!(r.window_s, 0.0);
        for v in [r.submitted_per_s, r.completed_per_s, r.shed_per_s, r.failed_per_s] {
            assert!(v.is_finite() && v == 0.0, "zero-width rate must be 0.0, got {v}");
        }
    }

    #[test]
    fn hub_tick_evaluates_windows_and_stays_idempotent_on_stop() {
        let hub = MetricsHub::new(PublishCfg {
            interval: Duration::from_millis(10),
            slo: SloCfg::default(),
            configured_workers: 2,
            queue_cap: 16,
        });
        assert_eq!(hub.health().windows, 0);
        hub.tick(sample(1_000_000, 0, 0));
        assert!(hub.window_rates().is_none(), "one sample is not a window");
        hub.tick(sample(2_000_000, 50, 50));
        assert_eq!(hub.health().windows, 1);
        let r = hub.window_rates().expect("window closed");
        assert!((r.submitted_per_s * r.window_s).round() as u64 == 50);
        // Same-timestamp tick: pushed but never evaluated (no /0).
        hub.tick(sample(2_000_000, 60, 60));
        assert_eq!(hub.health().windows, 1);
        // stop is idempotent from any thread, any number of times.
        hub.stop();
        hub.stop();
        assert!(hub.stopped());
        assert!(!hub.wait_for_tick());
    }

    #[test]
    fn rendered_lines_parse_and_labels_round_trip() {
        let mut out = String::new();
        scalar(&mut out, "shdc_test_total", "counter", 42.0);
        sample_line(
            &mut out,
            "shdc_labeled",
            &[("model", "weird \"name\"\\with\nstuff"), ("shard", "3")],
            1.5,
        );
        let h = HistSnapshot { count: 10, mean: 2.5, p50: 2, p90: 4, p99: 5, max: 5, min: 1 };
        summary(&mut out, "shdc_lat", &[("stage", "encode")], &h);
        let series = parse_exposition(&out).expect("rendered text parses");
        assert_eq!(series[0].name, "shdc_test_total");
        assert_eq!(series[0].value, 42.0);
        let labeled = &series[1];
        assert_eq!(labeled.labels[0].1, "weird \"name\"\\with\nstuff");
        assert_eq!(labeled.labels[1], ("shard".to_string(), "3".to_string()));
        // summary emits 3 quantiles + _count + _sum
        assert_eq!(series.len(), 2 + 5);
        let sum = series.iter().find(|s| s.name == "shdc_lat_sum").unwrap();
        assert_eq!(sum.value, 25.0);
        assert_eq!(sum.labels, vec![("stage".to_string(), "encode".to_string())]);
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        for bad in [
            "9leading_digit 1",
            "name{unclosed=\"x\" 1",
            "name{k=bare} 1",
            "name",
            "name notanumber",
            "name 1 2",
        ] {
            assert!(parse_exposition(bad).is_err(), "must reject {bad:?}");
        }
        // Comments and blank lines are skipped; Inf/NaN literals parse.
        let ok = "# HELP x y\n\nx_total 3\nx_inf +Inf\n";
        let series = parse_exposition(ok).expect("valid text");
        assert_eq!(series.len(), 2);
        assert!(series[1].value.is_infinite());
    }

    #[test]
    fn fmt_value_guards_non_finite() {
        assert_eq!(fmt_value(f64::NAN), "0");
        assert_eq!(fmt_value(f64::INFINITY), "0");
        assert_eq!(fmt_value(3.0), "3");
        assert_eq!(fmt_value(0.25), "0.25");
    }
}
