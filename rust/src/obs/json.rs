//! Shared JSON emission for latency/depth histograms — the one
//! serializer behind the closed-loop, open-loop and per-model bench
//! report sections and every `stage_breakdown` section (bench reports,
//! the perf snapshot, [`super::ObsSnapshot::to_json`]). Keeping a
//! single shape here means offline tooling parses one histogram schema
//! everywhere.

use crate::serve::HistSnapshot;
use crate::util::json::Json;

/// JSON form of a histogram summary: `count`, `mean`, bucket-quantile
/// `p50`/`p90`/`p99`, and exact `max`.
pub fn hist_json(h: &HistSnapshot) -> Json {
    Json::obj(vec![
        ("count", Json::num(h.count as f64)),
        ("mean", Json::num(h.mean)),
        ("p50", Json::num(h.p50 as f64)),
        ("p90", Json::num(h.p90 as f64)),
        ("p99", Json::num(h.p99 as f64)),
        ("max", Json::num(h.max as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::Histogram;

    #[test]
    fn hist_json_has_the_stable_schema() {
        let h = Histogram::new();
        h.record(10);
        h.record(1000);
        let v = hist_json(&h.snapshot());
        for key in ["count", "mean", "p50", "p90", "p99", "max"] {
            assert!(v.get(key).is_some(), "missing key {key}");
        }
        assert_eq!(v.get("count").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("max").unwrap().as_f64(), Some(1000.0));
    }
}
