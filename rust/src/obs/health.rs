//! SLO evaluation and lifecycle events for the serving stack: the
//! judgment layer of the monitoring subsystem (`crate::obs::export`
//! holds the sampling/exposition plumbing; this module holds the
//! *verdict*).
//!
//! Once per publish window the [`SloEvaluator`] receives the window's
//! exact counter deltas ([`WindowObs`], derived from two monotone
//! snapshots) and produces a [`HealthReport`]:
//!
//! * **Latency** — windowed p99 (bucket-diff quantile over just this
//!   window's samples) against [`SloCfg::p99_target`].
//! * **Shedding** — the window's *overload* shed fraction (`Shed`
//!   refusals + admission timeouts over admission attempts) against
//!   [`SloCfg::max_shed_rate`]. Per-tenant quota refusals are policy,
//!   not overload: they are reported separately and never breach.
//! * **Error budget** — the window's bad fraction (encode failures +
//!   deadline expiries over terminal outcomes) divided by
//!   [`SloCfg::error_budget`] is the **burn rate**; > 1 means the
//!   budget is being consumed faster than allowed. Cumulative
//!   consumption is tracked across windows.
//! * **Pipeline stall** — `completed` unchanged while requests are in
//!   flight, for [`SloCfg::stall_windows`] consecutive windows.
//! * **Worker liveness** — the tracer's live-worker gauge against the
//!   configured pool (a shrunken pool degrades; it only breaches when
//!   it also stalls or blows another objective).
//!
//! State transitions and notable window deltas emit [`ObsEvent`]s into
//! a bounded overwrite-oldest [`EventRing`] — drained via
//! `ServeHandle::drain_events`, peeked by the `/health` endpoint, and
//! counted per kind for the `shdc_events_total` exposition series.
//!
//! A zero-traffic window is explicitly healthy: every rate in this
//! module guards its denominator, so idle servers report finite zeros,
//! never NaN (pinned by the unit tests below and
//! `tests/obs_export.rs`).

use std::time::Duration;

use crate::util::json::Json;

/// Service-level objectives, evaluated once per publish window
/// (`ServeCfg::slo`; `ServeCfg::publish_interval` sets the window).
#[derive(Clone, Copy, Debug)]
pub struct SloCfg {
    /// Windowed p99 end-to-end latency objective (checked only when the
    /// window recorded at least one latency sample).
    pub p99_target: Duration,
    /// Maximum fraction of admission attempts the server may refuse for
    /// *load* reasons (shed + admission timeouts) in one window. Quota
    /// (policy) refusals are accounted separately and never breach.
    pub max_shed_rate: f64,
    /// Allowed fraction of terminal outcomes that fail (encode failures
    /// + deadline expiries). The window's bad fraction over this budget
    /// is the burn rate; > 1 breaches.
    pub error_budget: f64,
    /// Consecutive no-progress windows (completed counter unchanged
    /// while requests are in flight) before the pipeline counts as
    /// stalled. Clamped to ≥ 1.
    pub stall_windows: u32,
}

impl Default for SloCfg {
    fn default() -> SloCfg {
        SloCfg {
            p99_target: Duration::from_millis(50),
            max_shed_rate: 0.05,
            error_budget: 0.001,
            stall_windows: 3,
        }
    }
}

/// The watchdog's judgment of one window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Every objective held.
    Healthy,
    /// No objective breached, but capacity is reduced (live workers
    /// below the configured pool).
    Degraded,
    /// At least one objective violated ([`HealthReport::reasons`]).
    Breach,
}

impl Verdict {
    pub fn name(self) -> &'static str {
        match self {
            Verdict::Healthy => "healthy",
            Verdict::Degraded => "degraded",
            Verdict::Breach => "breach",
        }
    }

    /// Numeric severity for the `shdc_slo_verdict` gauge (0/1/2).
    pub fn severity(self) -> u64 {
        match self {
            Verdict::Healthy => 0,
            Verdict::Degraded => 1,
            Verdict::Breach => 2,
        }
    }
}

/// One window's exact observation, handed to [`SloEvaluator::evaluate`]
/// by the metrics publisher. Deltas are computed from two monotone
/// counter snapshots, so they are exact; gauges are from the window's
/// closing sample.
#[derive(Clone, Copy, Debug, Default)]
pub struct WindowObs {
    /// Window close time, nanoseconds since the publisher's epoch.
    pub t_ns: u64,
    /// Window width in seconds (> 0 for any real window).
    pub window_s: f64,
    pub submitted_delta: u64,
    pub completed_delta: u64,
    /// Overload refusals this window: `Shed` + admission timeouts.
    pub shed_delta: u64,
    /// Policy (tenant-quota) refusals this window.
    pub quota_shed_delta: u64,
    /// Encode-batch failures (worker panics) this window.
    pub failed_delta: u64,
    /// Deadline expiries this window.
    pub expired_delta: u64,
    /// Requests outstanding at window close (submitted − completed).
    pub in_flight: u64,
    /// Submission-queue depth at window close.
    pub queue_depth: u64,
    /// Submission-queue capacity (for saturation detection).
    pub queue_cap: u64,
    /// Live encode workers at window close.
    pub live_workers: u64,
    /// Windowed end-to-end p99 (ns); meaningful when `latency_count`>0.
    pub p99_ns: u64,
    /// Latency samples recorded this window.
    pub latency_count: u64,
}

impl WindowObs {
    /// Admission attempts this window (admitted + every refusal class).
    pub fn attempts(&self) -> u64 {
        self.submitted_delta + self.shed_delta + self.quota_shed_delta
    }

    /// Overload shed fraction of this window's attempts (0.0 idle).
    pub fn shed_rate(&self) -> f64 {
        ratio(self.shed_delta, self.attempts())
    }

    /// Policy (quota) shed fraction of this window's attempts.
    pub fn quota_shed_rate(&self) -> f64 {
        ratio(self.quota_shed_delta, self.attempts())
    }

    /// Bad fraction of this window's terminal outcomes. Expiries can
    /// outnumber completions (admission-wait expiries are never
    /// admitted), so the denominator includes them explicitly.
    pub fn error_rate(&self) -> f64 {
        let bad = self.failed_delta + self.expired_delta;
        ratio(bad, self.completed_delta.max(bad))
    }
}

/// Guarded division: zero denominator → 0.0, never NaN/inf.
fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

/// Latest verdict plus everything behind it — the `/health` endpoint
/// body and `ServeHandle::health`.
#[derive(Clone, Debug)]
pub struct HealthReport {
    pub verdict: Verdict,
    /// Human-readable breach/degradation reasons; empty when healthy.
    pub reasons: Vec<String>,
    /// Windows evaluated so far (0 until the second publisher sample).
    pub windows: u64,
    /// Width of the evaluated window, seconds.
    pub window_s: f64,
    /// Windowed end-to-end p99 (ns; 0 on a zero-traffic window).
    pub p99_ns: u64,
    /// Windowed overload shed fraction.
    pub shed_rate: f64,
    /// Windowed policy (quota) shed fraction.
    pub quota_shed_rate: f64,
    /// Windowed bad fraction (failures + expiries over outcomes).
    pub error_rate: f64,
    /// `error_rate / error_budget` — > 1 burns faster than allowed.
    pub burn_rate: f64,
    /// Cumulative bad outcomes over cumulative allowed bad outcomes
    /// (`total_outcomes × budget`); > 1 means the lifetime budget is
    /// spent.
    pub budget_consumed: f64,
    /// The pipeline is currently considered stalled.
    pub stalled: bool,
    /// Consecutive no-progress windows observed so far.
    pub no_progress_windows: u32,
    pub live_workers: u64,
    pub configured_workers: u64,
}

impl Default for HealthReport {
    fn default() -> HealthReport {
        HealthReport {
            verdict: Verdict::Healthy,
            reasons: Vec::new(),
            windows: 0,
            window_s: 0.0,
            p99_ns: 0,
            shed_rate: 0.0,
            quota_shed_rate: 0.0,
            error_rate: 0.0,
            burn_rate: 0.0,
            budget_consumed: 0.0,
            stalled: false,
            no_progress_windows: 0,
            live_workers: 0,
            configured_workers: 0,
        }
    }
}

impl HealthReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("verdict", Json::str(self.verdict.name())),
            (
                "reasons",
                Json::Arr(self.reasons.iter().map(|r| Json::str(r.clone())).collect()),
            ),
            ("windows", Json::num(self.windows as f64)),
            ("window_s", Json::num(self.window_s)),
            ("p99_ns", Json::num(self.p99_ns as f64)),
            ("shed_rate", Json::num(self.shed_rate)),
            ("quota_shed_rate", Json::num(self.quota_shed_rate)),
            ("error_rate", Json::num(self.error_rate)),
            ("burn_rate", Json::num(self.burn_rate)),
            ("budget_consumed", Json::num(self.budget_consumed)),
            ("stalled", Json::Bool(self.stalled)),
            ("no_progress_windows", Json::num(self.no_progress_windows as f64)),
            ("live_workers", Json::num(self.live_workers as f64)),
            ("configured_workers", Json::num(self.configured_workers as f64)),
        ])
    }
}

/// Lifecycle event taxonomy. Kinds are closed (the exposition counts
/// them per kind), details ride on the event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// The live-worker gauge dropped (panic budget exhausted; the pool
    /// shrank permanently for this run).
    WorkerRetired,
    /// Encode-batch failures landed this window (worker panics that
    /// were absorbed and recovered).
    EncodeFailures,
    /// Tenant-quota refusals landed this window.
    QuotaShedBurst,
    /// The submission queue was at capacity at window close.
    QueueSaturated,
    /// The watchdog entered the breach verdict.
    SloBreach,
    /// The watchdog left the breach verdict.
    SloRecovered,
    /// No-progress windows crossed [`SloCfg::stall_windows`].
    PipelineStalled,
    /// A stalled pipeline completed requests again.
    PipelineResumed,
}

impl EventKind {
    pub const COUNT: usize = 8;
    pub const ALL: [EventKind; EventKind::COUNT] = [
        EventKind::WorkerRetired,
        EventKind::EncodeFailures,
        EventKind::QuotaShedBurst,
        EventKind::QueueSaturated,
        EventKind::SloBreach,
        EventKind::SloRecovered,
        EventKind::PipelineStalled,
        EventKind::PipelineResumed,
    ];

    pub fn name(self) -> &'static str {
        match self {
            EventKind::WorkerRetired => "worker_retired",
            EventKind::EncodeFailures => "encode_failures",
            EventKind::QuotaShedBurst => "quota_shed_burst",
            EventKind::QueueSaturated => "queue_saturated",
            EventKind::SloBreach => "slo_breach",
            EventKind::SloRecovered => "slo_recovered",
            EventKind::PipelineStalled => "pipeline_stalled",
            EventKind::PipelineResumed => "pipeline_resumed",
        }
    }

    fn index(self) -> usize {
        EventKind::ALL.iter().position(|&k| k == self).expect("kind listed in ALL")
    }
}

/// One structured lifecycle event.
#[derive(Clone, Debug)]
pub struct ObsEvent {
    /// Nanoseconds since the publisher's epoch.
    pub t_ns: u64,
    pub kind: EventKind,
    /// Kind-specific magnitude: workers lost, failures in the window,
    /// burn rate at breach…
    pub value: f64,
    /// Short human-readable detail.
    pub detail: String,
}

impl ObsEvent {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("t_ns", Json::num(self.t_ns as f64)),
            ("kind", Json::str(self.kind.name())),
            ("value", Json::num(self.value)),
            ("detail", Json::str(self.detail.clone())),
        ])
    }
}

/// Bounded overwrite-oldest event ring plus cumulative per-kind
/// counters (the counters survive drains — they feed the
/// `shdc_events_total{kind=…}` counter series, which must stay
/// monotone).
#[derive(Debug)]
pub struct EventRing {
    cap: usize,
    buf: Vec<ObsEvent>,
    /// Index of the oldest event once the ring is full.
    at: usize,
    /// Events overwritten (ring was full) or refused (cap 0).
    dropped: u64,
    emitted: [u64; EventKind::COUNT],
}

impl EventRing {
    pub fn new(cap: usize) -> EventRing {
        EventRing {
            cap,
            buf: Vec::with_capacity(cap),
            at: 0,
            dropped: 0,
            emitted: [0; EventKind::COUNT],
        }
    }

    pub fn push(&mut self, ev: ObsEvent) {
        self.emitted[ev.kind.index()] += 1;
        if self.cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.at] = ev;
            self.at = (self.at + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Retained events, oldest first; resets the ring (the per-kind
    /// counters stay cumulative).
    pub fn drain(&mut self) -> Vec<ObsEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        if self.buf.len() == self.cap && self.cap > 0 {
            out.extend_from_slice(&self.buf[self.at..]);
            out.extend_from_slice(&self.buf[..self.at]);
        } else {
            out.extend_from_slice(&self.buf);
        }
        self.buf.clear();
        self.at = 0;
        out
    }

    /// Clone of the retained events, oldest first, without resetting —
    /// the `/health` endpoint peeks so scrapes don't race drains.
    pub fn peek(&self) -> Vec<ObsEvent> {
        if self.buf.len() == self.cap && self.cap > 0 {
            let mut out = Vec::with_capacity(self.buf.len());
            out.extend_from_slice(&self.buf[self.at..]);
            out.extend_from_slice(&self.buf[..self.at]);
            out
        } else {
            self.buf.to_vec()
        }
    }

    /// Cumulative emissions per kind, [`EventKind::ALL`] order.
    pub fn counts(&self) -> [u64; EventKind::COUNT] {
        self.emitted
    }

    /// Events overwritten or refused since construction.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// The watchdog: folds one [`WindowObs`] at a time into verdict state,
/// emitting transition events into the caller's [`EventRing`]. Pure
/// arithmetic over the inputs — unit-testable without a server.
#[derive(Debug)]
pub struct SloEvaluator {
    cfg: SloCfg,
    configured_workers: u64,
    windows: u64,
    /// Consecutive windows with in-flight requests but no completions.
    no_progress: u32,
    stalled: bool,
    breached: bool,
    /// The pipeline has reported its full worker pool at least once;
    /// liveness is judged only after (before that, the gauge is just
    /// "pipeline not started yet", not degradation).
    pool_seen: bool,
    prev_live: Option<u64>,
    cum_bad: u64,
    cum_outcomes: u64,
}

impl SloEvaluator {
    pub fn new(cfg: SloCfg, configured_workers: u64) -> SloEvaluator {
        SloEvaluator {
            cfg,
            configured_workers,
            windows: 0,
            no_progress: 0,
            stalled: false,
            breached: false,
            pool_seen: false,
            prev_live: None,
            cum_bad: 0,
            cum_outcomes: 0,
        }
    }

    /// Evaluate one window. Emits lifecycle events for window deltas
    /// (failures, quota bursts, queue saturation, worker retirement)
    /// and for verdict/stall transitions, then returns the report.
    pub fn evaluate(&mut self, w: &WindowObs, events: &mut EventRing) -> HealthReport {
        self.windows += 1;

        // --- stall detection ------------------------------------------------
        if w.completed_delta == 0 && w.in_flight > 0 {
            self.no_progress = self.no_progress.saturating_add(1);
        } else {
            self.no_progress = 0;
        }
        let now_stalled = self.no_progress >= self.cfg.stall_windows.max(1);
        if now_stalled && !self.stalled {
            events.push(ObsEvent {
                t_ns: w.t_ns,
                kind: EventKind::PipelineStalled,
                value: w.in_flight as f64,
                detail: format!(
                    "no completions for {} windows with {} in flight",
                    self.no_progress, w.in_flight
                ),
            });
        }
        if !now_stalled && self.stalled {
            events.push(ObsEvent {
                t_ns: w.t_ns,
                kind: EventKind::PipelineResumed,
                value: w.completed_delta as f64,
                detail: format!("{} completions this window", w.completed_delta),
            });
        }
        self.stalled = now_stalled;

        // --- window-delta lifecycle events ----------------------------------
        if w.failed_delta > 0 {
            events.push(ObsEvent {
                t_ns: w.t_ns,
                kind: EventKind::EncodeFailures,
                value: w.failed_delta as f64,
                detail: format!("{} encode-batch failures", w.failed_delta),
            });
        }
        if w.quota_shed_delta > 0 {
            events.push(ObsEvent {
                t_ns: w.t_ns,
                kind: EventKind::QuotaShedBurst,
                value: w.quota_shed_delta as f64,
                detail: format!("{} quota refusals", w.quota_shed_delta),
            });
        }
        if w.queue_cap > 0 && w.queue_depth >= w.queue_cap {
            events.push(ObsEvent {
                t_ns: w.t_ns,
                kind: EventKind::QueueSaturated,
                value: w.queue_depth as f64,
                detail: format!("queue at capacity ({}/{})", w.queue_depth, w.queue_cap),
            });
        }
        if let Some(prev) = self.prev_live {
            if w.live_workers < prev {
                events.push(ObsEvent {
                    t_ns: w.t_ns,
                    kind: EventKind::WorkerRetired,
                    value: (prev - w.live_workers) as f64,
                    detail: format!("live workers {} -> {}", prev, w.live_workers),
                });
            }
        }
        self.prev_live = Some(w.live_workers);
        if w.live_workers >= self.configured_workers && self.configured_workers > 0 {
            self.pool_seen = true;
        }

        // --- rates and budget (all denominators guarded) --------------------
        let shed_rate = w.shed_rate();
        let quota_shed_rate = w.quota_shed_rate();
        let error_rate = w.error_rate();
        let budget = self.cfg.error_budget.max(f64::MIN_POSITIVE);
        let burn_rate = error_rate / budget;
        let bad = w.failed_delta + w.expired_delta;
        self.cum_bad += bad;
        self.cum_outcomes += w.completed_delta.max(bad);
        let allowed = self.cum_outcomes as f64 * budget;
        let budget_consumed = if allowed > 0.0 { self.cum_bad as f64 / allowed } else { 0.0 };

        // --- verdict ---------------------------------------------------------
        let mut reasons = Vec::new();
        if now_stalled {
            reasons.push(format!(
                "pipeline stalled: {} no-progress windows with {} in flight",
                self.no_progress, w.in_flight
            ));
        }
        let target_ns = self.cfg.p99_target.as_nanos() as u64;
        if w.latency_count > 0 && w.p99_ns > target_ns {
            reasons.push(format!("windowed p99 {}ns > target {}ns", w.p99_ns, target_ns));
        }
        if shed_rate > self.cfg.max_shed_rate {
            reasons.push(format!(
                "overload shed rate {:.4} > max {:.4}",
                shed_rate, self.cfg.max_shed_rate
            ));
        }
        if burn_rate > 1.0 {
            reasons.push(format!("error-budget burn rate {:.2} > 1", burn_rate));
        }
        let degraded = self.pool_seen && w.live_workers < self.configured_workers;
        let verdict = if !reasons.is_empty() {
            Verdict::Breach
        } else if degraded {
            reasons.push(format!(
                "degraded: {} of {} workers live",
                w.live_workers, self.configured_workers
            ));
            Verdict::Degraded
        } else {
            Verdict::Healthy
        };

        if verdict == Verdict::Breach && !self.breached {
            events.push(ObsEvent {
                t_ns: w.t_ns,
                kind: EventKind::SloBreach,
                value: burn_rate,
                detail: reasons.join("; "),
            });
        }
        if verdict != Verdict::Breach && self.breached {
            events.push(ObsEvent {
                t_ns: w.t_ns,
                kind: EventKind::SloRecovered,
                value: burn_rate,
                detail: "all objectives back within target".to_string(),
            });
        }
        self.breached = verdict == Verdict::Breach;

        HealthReport {
            verdict,
            reasons,
            windows: self.windows,
            window_s: w.window_s,
            p99_ns: w.p99_ns,
            shed_rate,
            quota_shed_rate,
            error_rate,
            burn_rate,
            budget_consumed,
            stalled: now_stalled,
            no_progress_windows: self.no_progress,
            live_workers: w.live_workers,
            configured_workers: self.configured_workers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring() -> EventRing {
        EventRing::new(64)
    }

    /// A quiet healthy window with `n` completions.
    fn window(t_ns: u64, completed: u64) -> WindowObs {
        WindowObs {
            t_ns,
            window_s: 0.1,
            submitted_delta: completed,
            completed_delta: completed,
            live_workers: 2,
            latency_count: completed,
            p99_ns: 1_000,
            ..WindowObs::default()
        }
    }

    fn evaluator() -> SloEvaluator {
        SloEvaluator::new(SloCfg::default(), 2)
    }

    #[test]
    fn zero_traffic_window_is_healthy_and_finite() {
        let mut ev = evaluator();
        let mut events = ring();
        let idle = WindowObs { t_ns: 1, window_s: 0.1, live_workers: 2, ..WindowObs::default() };
        let rep = ev.evaluate(&idle, &mut events);
        assert_eq!(rep.verdict, Verdict::Healthy, "reasons: {:?}", rep.reasons);
        for v in [
            rep.shed_rate,
            rep.quota_shed_rate,
            rep.error_rate,
            rep.burn_rate,
            rep.budget_consumed,
        ] {
            assert!(v.is_finite() && v == 0.0, "idle rate must be exactly 0.0, got {v}");
        }
        assert!(events.drain().is_empty());
    }

    #[test]
    fn liveness_not_judged_before_pipeline_start() {
        // live_workers 0 before the pipeline sets the gauge: not
        // degraded (the pool was never seen), and no retirement event.
        let mut ev = evaluator();
        let mut events = ring();
        let rep = ev.evaluate(
            &WindowObs { t_ns: 1, window_s: 0.1, ..WindowObs::default() },
            &mut events,
        );
        assert_eq!(rep.verdict, Verdict::Healthy);
        // Once the full pool has been seen, a shrink degrades.
        ev.evaluate(&window(2, 10), &mut events);
        let shrunk = WindowObs { live_workers: 1, ..window(3, 10) };
        let rep = ev.evaluate(&shrunk, &mut events);
        assert_eq!(rep.verdict, Verdict::Degraded);
        let kinds: Vec<EventKind> = events.drain().iter().map(|e| e.kind).collect();
        assert!(kinds.contains(&EventKind::WorkerRetired));
    }

    #[test]
    fn stall_needs_consecutive_windows_then_breaches_and_recovers() {
        let mut ev = evaluator();
        let mut events = ring();
        ev.evaluate(&window(1, 10), &mut events);
        let stalled = WindowObs {
            in_flight: 4,
            submitted_delta: 0,
            completed_delta: 0,
            latency_count: 0,
            ..window(2, 0)
        };
        // stall_windows = 3: two no-progress windows are not yet a stall.
        assert_eq!(ev.evaluate(&stalled, &mut events).verdict, Verdict::Healthy);
        assert_eq!(ev.evaluate(&stalled, &mut events).verdict, Verdict::Healthy);
        let rep = ev.evaluate(&stalled, &mut events);
        assert_eq!(rep.verdict, Verdict::Breach);
        assert!(rep.stalled);
        assert_eq!(rep.no_progress_windows, 3);
        // Progress resumes: verdict recovers, resume + recovery events.
        let rep = ev.evaluate(&window(5, 10), &mut events);
        assert_eq!(rep.verdict, Verdict::Healthy);
        assert!(!rep.stalled);
        let kinds: Vec<EventKind> = events.drain().iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::PipelineStalled,
                EventKind::SloBreach,
                EventKind::PipelineResumed,
                EventKind::SloRecovered,
            ]
        );
    }

    #[test]
    fn breach_and_recovery_events_fire_once_per_transition() {
        let mut ev = evaluator();
        let mut events = ring();
        let slow = WindowObs { p99_ns: 60_000_000, ..window(1, 10) }; // > 50ms target
        ev.evaluate(&slow, &mut events);
        ev.evaluate(&slow, &mut events); // still breached: no second event
        ev.evaluate(&window(3, 10), &mut events); // recovered
        let kinds: Vec<EventKind> = events.drain().iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec![EventKind::SloBreach, EventKind::SloRecovered]);
    }

    #[test]
    fn burn_rate_and_budget_accounting() {
        let mut ev = SloEvaluator::new(
            SloCfg { error_budget: 0.1, ..SloCfg::default() },
            2,
        );
        let mut events = ring();
        // 5 failures out of 100 outcomes: error rate 0.05, burn 0.5.
        let w = WindowObs { failed_delta: 5, ..window(1, 100) };
        let rep = ev.evaluate(&w, &mut events);
        assert_eq!(rep.verdict, Verdict::Healthy, "reasons: {:?}", rep.reasons);
        assert!((rep.error_rate - 0.05).abs() < 1e-12);
        assert!((rep.burn_rate - 0.5).abs() < 1e-12);
        assert!((rep.budget_consumed - 0.5).abs() < 1e-12);
        // 20 failures out of 100: burn 2.0 → breach; cumulative budget
        // consumed = 25 bad / (200 × 0.1) = 1.25.
        let w = WindowObs { failed_delta: 20, ..window(2, 100) };
        let rep = ev.evaluate(&w, &mut events);
        assert_eq!(rep.verdict, Verdict::Breach);
        assert!((rep.burn_rate - 2.0).abs() < 1e-12);
        assert!((rep.budget_consumed - 1.25).abs() < 1e-12);
    }

    #[test]
    fn overload_sheds_breach_but_quota_sheds_do_not() {
        let mut ev = evaluator();
        let mut events = ring();
        // 50 quota refusals on 100 attempts: policy, not overload.
        let quota = WindowObs { quota_shed_delta: 50, ..window(1, 50) };
        let rep = ev.evaluate(&quota, &mut events);
        assert_eq!(rep.verdict, Verdict::Healthy, "reasons: {:?}", rep.reasons);
        assert!((rep.quota_shed_rate - 0.5).abs() < 1e-12);
        assert_eq!(rep.shed_rate, 0.0);
        // The same fraction of overload sheds breaches max_shed_rate.
        let overload = WindowObs { shed_delta: 50, ..window(2, 50) };
        let rep = ev.evaluate(&overload, &mut events);
        assert_eq!(rep.verdict, Verdict::Breach);
        assert!((rep.shed_rate - 0.5).abs() < 1e-12);
    }

    #[test]
    fn event_ring_wraps_keeping_newest_and_counts_stay_cumulative() {
        let mut ring = EventRing::new(3);
        for i in 0..7u64 {
            ring.push(ObsEvent {
                t_ns: i,
                kind: EventKind::EncodeFailures,
                value: i as f64,
                detail: String::new(),
            });
        }
        assert_eq!(ring.dropped(), 4);
        let peeked: Vec<u64> = ring.peek().iter().map(|e| e.t_ns).collect();
        assert_eq!(peeked, vec![4, 5, 6]);
        let drained: Vec<u64> = ring.drain().iter().map(|e| e.t_ns).collect();
        assert_eq!(drained, vec![4, 5, 6]);
        assert!(ring.drain().is_empty());
        // Per-kind counters survive the drain.
        let idx = EventKind::ALL.iter().position(|&k| k == EventKind::EncodeFailures).unwrap();
        assert_eq!(ring.counts()[idx], 7);
    }

    #[test]
    fn health_report_json_parses() {
        let mut ev = evaluator();
        let mut events = ring();
        let rep = ev.evaluate(&window(1, 10), &mut events);
        let text = rep.to_json().pretty();
        let v = Json::parse(&text).expect("health json parses");
        assert_eq!(v.get("verdict").unwrap().as_str(), Some("healthy"));
        assert_eq!(v.get("windows").unwrap().as_usize(), Some(1));
        assert!(v.get("burn_rate").unwrap().as_f64().is_some());
    }
}
