//! Dependency-free observability for the serving stack: per-request
//! stage-span tracing, per-stage / per-model latency histograms, and
//! gauge snapshots — the decomposition layer behind the flat end-to-end
//! counters of [`crate::serve::ServeSnapshot`].
//!
//! # Span taxonomy
//!
//! A sampled request carries nine monotonic timestamps (nanoseconds
//! since the [`Tracer`]'s epoch) captured at the existing pipeline
//! seams; consecutive pairs telescope into seven stage spans that sum
//! *exactly* to the submit→complete wall time:
//!
//! ```text
//!  t_submit ──► t_enqueue ──► t_cut ──► (t_pop) ──► t_encode_start ──►
//!  [admission ]  [ queue   ]  [     dispatch     ]
//!  t_encode_end ──► t_scan_start ──► t_scan_end ──► t_complete
//!  [  encode  ]     [ reorder ]      [  scan   ]    [complete]
//! ```
//!
//! * **admission** — `classify` entry to queue insertion: quota checks,
//!   slot acquisition, and any admission-policy wait on a full queue.
//! * **queue** — queue insertion to the micro-batcher taking the
//!   request into a batch (the batch-cut wait).
//! * **dispatch** — batch cut to encode start: the rest of the gather,
//!   the deque push, and the worker's pop (steal scheduling). `t_pop`
//!   rides along inside this span as provenance detail.
//! * **encode** — the worker's encode body (the `catch_unwind` region).
//! * **reorder** — encode end to the consumer picking the batch up in
//!   stream order (seq-reorder wait + encoded-channel transit).
//! * **scan** — the AM class scan of the request's batch.
//! * **complete** — scan end to the completion slot being filled.
//!
//! Every edge is ordered by a happens-before relation (queue lock,
//! deque mutex, channel send) on the process-wide monotonic clock, so
//! the chain is monotone under any steal interleaving.
//!
//! # Sampling and cost
//!
//! [`ObsCfg::sample_every`] = 0 (the default) disables tracing: the
//! only residual cost is one plain-field branch per request and the
//! tracer allocates nothing — the zero-allocation serve window of
//! `tests/alloc_regression.rs` holds unchanged. With sampling enabled,
//! every `sample_every`-th submission (by global submission count, so
//! the sampled set is deterministic) carries a [`TraceCtx`] by value
//! through the pipeline; batch-level stamps ride on the encoded batch.
//! Completed traces land in preallocated per-worker rings
//! ([`ObsCfg::ring_cap`] records each, overwrite-oldest) and in
//! preallocated per-(worker × model) stage histograms — no allocation
//! per span, so the alloc window also holds with sampling on (pinned at
//! `sample_every: 16`).
//!
//! Aggregation is contention-free by construction: each worker's stage
//! histograms are written only by the single-threaded serve consumer
//! (keyed by the batch's origin worker) and merged on snapshot via
//! [`Histogram::merge`] — no shared atomic hot path across models.
//!
//! Failed batches (worker panic) deliver their traces with
//! [`TraceRecord::failed`] set and a zero-width scan span; they are
//! kept out of the stage histograms so per-stage quantiles describe
//! successful requests only. Requests expired at batch cut drop their
//! trace (they never reach the consumer); the sampled-trace count
//! therefore reconciles as `completed − failed_expired`-style
//! arithmetic pinned by `tests/obs_tracing.rs`.
//!
//! # Monitoring and SLOs
//!
//! Snapshots are point-in-time; *monitoring* is their derivative. The
//! [`export`] module runs a background publisher that captures the
//! serve counters and raw histogram buckets every
//! [`crate::serve::ServeCfg::publish_interval`] into a preallocated
//! ring; because every counter is monotone, consecutive captures
//! subtract into **exact** windowed rates (req/s, shed/s, failure rate)
//! and windowed latency quantiles (raw bucket-count diffs, not
//! approximations). Each closed window feeds the [`health`] watchdog:
//! an [`health::SloEvaluator`] judging p99/shed-rate/error-budget burn,
//! detecting pipeline stalls (completed counter frozen while requests
//! are in flight) and worker deaths (live-worker gauge vs configured
//! pool), and emitting lifecycle events into a bounded ring
//! ([`crate::serve::ServeHandle::drain_events`]).
//!
//! With [`crate::serve::ServeCfg::metrics_addr`] set, a dependency-free
//! exporter thread serves it all over HTTP:
//!
//! ```text
//! curl http://127.0.0.1:9464/metrics    # Prometheus text exposition
//! curl http://127.0.0.1:9464/health    # JSON verdict + recent events
//! curl http://127.0.0.1:9464/snapshot  # ObsSnapshot JSON (this module)
//! ```
//!
//! Every series parses as `name{labels} value` ([`export::parse_exposition`]
//! is the checker the tests and the `serve_bench --metrics-addr` smoke
//! run against the live output). The serve hot path never touches any
//! of this — publisher and listener threads own all sampling and
//! allocation, so the zero-alloc serve window holds with publishing on.

pub mod export;
pub mod health;
pub mod json;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::serve::latency::{HistBuckets, HistSnapshot, Histogram};
use crate::util::json::Json;
use crate::util::sync::lock_unpoisoned;

/// The seven telescoping stage spans of one served request, in
/// pipeline order. `sum(stage_ns) == t_complete − t_submit` exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Admission,
    Queue,
    Dispatch,
    Encode,
    Reorder,
    Scan,
    Complete,
}

impl Stage {
    pub const COUNT: usize = 7;
    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::Admission,
        Stage::Queue,
        Stage::Dispatch,
        Stage::Encode,
        Stage::Reorder,
        Stage::Scan,
        Stage::Complete,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::Queue => "queue",
            Stage::Dispatch => "dispatch",
            Stage::Encode => "encode",
            Stage::Reorder => "reorder",
            Stage::Scan => "scan",
            Stage::Complete => "complete",
        }
    }

    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }
}

/// Tracer configuration ([`crate::serve::ServeCfg::obs`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ObsCfg {
    /// Sample one request in `sample_every` (by global submission
    /// count; submission `i` is sampled iff `i % sample_every == 0`).
    /// `0` — the default — disables tracing entirely.
    pub sample_every: u64,
    /// Capacity of each per-worker trace ring (records; fixed-size,
    /// preallocated, overwrite-oldest). Ignored while disabled.
    pub ring_cap: usize,
}

impl Default for ObsCfg {
    fn default() -> ObsCfg {
        ObsCfg { sample_every: 0, ring_cap: 1024 }
    }
}

/// Per-request trace context carried *by value* through the pipeline
/// (inside the submission and its pending companion — no allocation).
/// Timestamps are nanoseconds since the tracer's epoch.
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceCtx {
    /// Global submission index at sampling time (unique per trace).
    pub req_id: u64,
    /// `classify` entry (latency measurement origin).
    pub t_submit: u64,
    /// Insertion into the bounded submission queue (admission done).
    pub t_enqueue: u64,
    /// The micro-batcher took the request into a batch (batch cut).
    pub t_cut: u64,
}

/// Batch-level span stamps captured by the encode worker; ride on the
/// encoded batch (every sampled request of the batch shares them).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStamps {
    /// Worker popped the raw batch from the steal scheduler.
    pub t_pop: u64,
    /// Encode body entry (just before the `catch_unwind` region).
    pub t_encode_start: u64,
    /// Encode body exit (panic or not).
    pub t_encode_end: u64,
    /// The batch was stolen from a sibling's deque (provenance).
    pub stolen: bool,
}

/// One completed request's full span chain — the trace-dump record
/// ([`Tracer::drain`], `serve_bench --trace-out`).
#[derive(Clone, Copy, Debug)]
pub struct TraceRecord {
    pub req_id: u64,
    pub model: u32,
    /// Worker that encoded the request's batch.
    pub worker: u32,
    /// The batch was stolen from a sibling worker's deque.
    pub stolen: bool,
    /// The encode batch failed (worker panic); the scan span is
    /// zero-width and the request resolved with an error.
    pub failed: bool,
    pub t_submit: u64,
    pub t_enqueue: u64,
    pub t_cut: u64,
    pub t_pop: u64,
    pub t_encode_start: u64,
    pub t_encode_end: u64,
    pub t_scan_start: u64,
    pub t_scan_end: u64,
    pub t_complete: u64,
}

impl TraceRecord {
    /// Width of one stage span (saturating, but zero-width only on a
    /// non-monotone clock — the chain is happens-before ordered).
    pub fn stage_ns(&self, s: Stage) -> u64 {
        match s {
            Stage::Admission => self.t_enqueue.saturating_sub(self.t_submit),
            Stage::Queue => self.t_cut.saturating_sub(self.t_enqueue),
            Stage::Dispatch => self.t_encode_start.saturating_sub(self.t_cut),
            Stage::Encode => self.t_encode_end.saturating_sub(self.t_encode_start),
            Stage::Reorder => self.t_scan_start.saturating_sub(self.t_encode_end),
            Stage::Scan => self.t_scan_end.saturating_sub(self.t_scan_start),
            Stage::Complete => self.t_complete.saturating_sub(self.t_scan_end),
        }
    }

    /// Sum of the seven stage spans; equals [`Self::end_to_end_ns`] on
    /// a monotone chain (the spans telescope).
    pub fn stages_sum_ns(&self) -> u64 {
        Stage::ALL.iter().map(|&s| self.stage_ns(s)).sum()
    }

    /// Submit→complete wall time of this request.
    pub fn end_to_end_ns(&self) -> u64 {
        self.t_complete.saturating_sub(self.t_submit)
    }

    /// One JSONL-ready object per trace (emit with
    /// [`Json::compact`]).
    pub fn to_json(&self) -> Json {
        let stages = Json::obj(
            Stage::ALL
                .iter()
                .map(|&s| (s.name(), Json::num(self.stage_ns(s) as f64)))
                .collect(),
        );
        Json::obj(vec![
            ("req_id", Json::num(self.req_id as f64)),
            ("model", Json::num(self.model as f64)),
            ("worker", Json::num(self.worker as f64)),
            ("stolen", Json::Bool(self.stolen)),
            ("failed", Json::Bool(self.failed)),
            ("t_submit_ns", Json::num(self.t_submit as f64)),
            ("t_complete_ns", Json::num(self.t_complete as f64)),
            ("stages_ns", stages),
            ("end_to_end_ns", Json::num(self.end_to_end_ns() as f64)),
        ])
    }
}

/// Fixed-capacity overwrite-oldest ring of trace records. Preallocated
/// once; `push` never allocates.
#[derive(Debug)]
struct TraceRing {
    cap: usize,
    buf: Vec<TraceRecord>,
    /// Index of the oldest record once the ring is full.
    at: usize,
    /// Records overwritten (ring was full) or refused (cap 0).
    dropped: u64,
}

impl TraceRing {
    fn new(cap: usize) -> TraceRing {
        TraceRing { cap, buf: Vec::with_capacity(cap), at: 0, dropped: 0 }
    }

    fn push(&mut self, rec: TraceRecord) {
        if self.cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(rec);
        } else {
            self.buf[self.at] = rec;
            self.at = (self.at + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Retained records, oldest first; resets the ring (not the
    /// `dropped` counter, which stays cumulative for the snapshot).
    fn drain(&mut self) -> Vec<TraceRecord> {
        let mut out = Vec::with_capacity(self.buf.len());
        if self.buf.len() == self.cap && self.cap > 0 {
            out.extend_from_slice(&self.buf[self.at..]);
            out.extend_from_slice(&self.buf[..self.at]);
        } else {
            out.extend_from_slice(&self.buf);
        }
        self.buf.clear();
        self.at = 0;
        out
    }
}

/// One histogram per stage ([`Stage::ALL`] order). Recording is one
/// atomic-add histogram insert per stage on preallocated tables.
#[derive(Debug)]
pub struct StageHistograms([Histogram; Stage::COUNT]);

impl StageHistograms {
    pub fn new() -> StageHistograms {
        StageHistograms(std::array::from_fn(|_| Histogram::new()))
    }

    pub fn record(&self, rec: &TraceRecord) {
        for s in Stage::ALL {
            self.0[s.index()].record(rec.stage_ns(s));
        }
    }

    /// Fold `other`'s counts into `self` (per-worker → per-model
    /// aggregation; see [`Histogram::merge`]).
    pub fn merge(&self, other: &StageHistograms) {
        for (a, b) in self.0.iter().zip(&other.0) {
            a.merge(b);
        }
    }

    pub fn stage(&self, s: Stage) -> &Histogram {
        &self.0[s.index()]
    }

    fn snapshot(&self) -> Vec<StageSnapshot> {
        Stage::ALL
            .iter()
            .map(|&s| StageSnapshot { stage: s.name(), hist: self.0[s.index()].snapshot() })
            .collect()
    }
}

impl Default for StageHistograms {
    fn default() -> StageHistograms {
        StageHistograms::new()
    }
}

/// The stage-span tracer: sampling decision, per-worker trace rings,
/// and the per-(worker × model) stage-histogram registry. One per
/// server, shared with the coordinator
/// ([`crate::coordinator::CoordinatorCfg::obs`]) for batch stamping.
#[derive(Debug)]
pub struct Tracer {
    cfg: ObsCfg,
    /// Origin of every timestamp (tracer construction).
    epoch: Instant,
    /// Global submission counter driving the 1-in-N sampling decision.
    submissions: AtomicU64,
    /// Live encode workers (set by the pipeline at start, decremented
    /// at retirement) — a gauge, meaningful while the pipeline runs.
    live_workers: AtomicU64,
    /// Completed traces, one ring per worker (indexed by the encoded
    /// batch's origin worker; written only by the serve consumer).
    rings: Vec<Mutex<TraceRing>>,
    /// Stage histograms per worker × model (outer: worker). Written
    /// only by the serve consumer; merged per model on snapshot, so
    /// recording never contends across workers' tables.
    stages: Vec<Vec<StageHistograms>>,
    n_models: usize,
}

impl Tracer {
    /// Construct for `n_workers` encode workers serving `n_models`
    /// registered models. Disabled configs allocate nothing.
    pub fn new(cfg: ObsCfg, n_workers: usize, n_models: usize) -> Tracer {
        let enabled = cfg.sample_every > 0;
        let ring_cap = if enabled { cfg.ring_cap.max(1) } else { 0 };
        let workers = if enabled { n_workers.max(1) } else { 0 };
        Tracer {
            cfg,
            epoch: Instant::now(),
            submissions: AtomicU64::new(0),
            live_workers: AtomicU64::new(0),
            rings: (0..workers).map(|_| Mutex::new(TraceRing::new(ring_cap))).collect(),
            stages: (0..workers)
                .map(|_| (0..n_models.max(1)).map(|_| StageHistograms::new()).collect())
                .collect(),
            n_models: n_models.max(1),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.cfg.sample_every > 0
    }

    /// Nanoseconds since the tracer's epoch, on the monotonic clock.
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.ns_since_epoch(Instant::now())
    }

    /// Epoch-relative nanoseconds of an already-captured instant.
    #[inline]
    pub fn ns_since_epoch(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_nanos() as u64
    }

    /// Sampling decision for the next submission: `Some(req_id)` when
    /// this request is traced. Disabled tracers take one plain-field
    /// branch and touch nothing else.
    #[inline]
    pub fn try_sample(&self) -> Option<u64> {
        if self.cfg.sample_every == 0 {
            return None;
        }
        let id = self.submissions.fetch_add(1, Ordering::Relaxed);
        (id % self.cfg.sample_every == 0).then_some(id)
    }

    /// Deliver one completed trace: into the origin worker's ring, and
    /// (non-failed only) into that worker's per-model stage
    /// histograms. No allocation — fixed-size record, preallocated
    /// ring and tables.
    pub fn record(&self, rec: TraceRecord) {
        let Some(ring) = self.rings.get(rec.worker as usize) else {
            return;
        };
        if !rec.failed {
            if let Some(sh) =
                self.stages.get(rec.worker as usize).and_then(|w| w.get(rec.model as usize))
            {
                sh.record(&rec);
            }
        }
        lock_unpoisoned(ring).push(rec);
    }

    /// Take every retained trace, across all rings, ordered by
    /// `req_id`. Off the hot path (allocates the result).
    pub fn drain(&self) -> Vec<TraceRecord> {
        let mut out: Vec<TraceRecord> = Vec::new();
        for ring in &self.rings {
            out.extend(lock_unpoisoned(ring).drain());
        }
        out.sort_by_key(|r| r.req_id);
        out
    }

    pub fn set_live_workers(&self, n: u64) {
        self.live_workers.store(n, Ordering::Relaxed);
    }

    pub fn worker_retired(&self) {
        let _ = self
            .live_workers
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
    }

    pub fn live_workers(&self) -> u64 {
        self.live_workers.load(Ordering::Relaxed)
    }

    /// Aggregate the per-worker tables into per-model and overall
    /// stage snapshots. Gauges start empty — the serve layer appends
    /// its queue/in-flight/shard gauges
    /// ([`crate::serve::ServeHandle::obs_snapshot`]).
    pub fn snapshot(&self) -> ObsSnapshot {
        let overall = StageHistograms::new();
        let per_model: Vec<StageHistograms> =
            (0..self.n_models).map(|_| StageHistograms::new()).collect();
        for worker in &self.stages {
            for (m, sh) in worker.iter().enumerate() {
                per_model[m].merge(sh);
                overall.merge(sh);
            }
        }
        let mut sampled = 0u64;
        let mut dropped = 0u64;
        for ring in &self.rings {
            let r = lock_unpoisoned(ring);
            sampled += r.buf.len() as u64 + r.dropped;
            dropped += r.dropped;
        }
        ObsSnapshot {
            sample_every: self.cfg.sample_every,
            sampled,
            dropped,
            live_workers: self.live_workers(),
            stages: overall.snapshot(),
            models: per_model
                .iter()
                .enumerate()
                .map(|(m, sh)| ObsModelSnapshot { model: m as u32, stages: sh.snapshot() })
                .collect(),
            gauges: Vec::new(),
        }
    }

    /// Raw per-stage histogram buckets, all workers and models merged,
    /// in [`Stage::ALL`] order — the publisher's windowed-stage capture
    /// ([`export::Sample::stages`]). Counts are monotone, so two
    /// consecutive captures subtract into exactly that window's stage
    /// distribution via [`HistBuckets::diff`]. Empty when tracing is
    /// disabled.
    pub fn stage_buckets(&self) -> Vec<HistBuckets> {
        if !self.enabled() {
            return Vec::new();
        }
        let merged = StageHistograms::new();
        for worker in &self.stages {
            for sh in worker {
                merged.merge(sh);
            }
        }
        Stage::ALL.iter().map(|&s| merged.stage(s).buckets()).collect()
    }

    /// Per-worker per-stage latency snapshots (models merged within
    /// each worker; outer order = worker pool order, inner =
    /// [`Stage::ALL`]) — the `shdc_worker_stage_latency_ns` exposition
    /// series. Empty when tracing is disabled.
    pub fn worker_stages(&self) -> Vec<Vec<StageSnapshot>> {
        self.stages
            .iter()
            .map(|worker| {
                let merged = StageHistograms::new();
                for sh in worker {
                    merged.merge(sh);
                }
                merged.snapshot()
            })
            .collect()
    }
}

/// One stage's latency distribution at snapshot time.
#[derive(Clone, Debug)]
pub struct StageSnapshot {
    pub stage: &'static str,
    pub hist: HistSnapshot,
}

/// Per-model stage breakdown ([`ObsSnapshot::models`], model-id order).
#[derive(Clone, Debug)]
pub struct ObsModelSnapshot {
    pub model: u32,
    pub stages: Vec<StageSnapshot>,
}

/// Point-in-time export of the tracer: stage histograms (overall and
/// per model), sampling accounting, and the gauges the serve layer
/// appends. `to_json` is the `stage_breakdown` section of the bench
/// reports and the perf snapshot.
#[derive(Clone, Debug)]
pub struct ObsSnapshot {
    pub sample_every: u64,
    /// Traces delivered to the rings (retained + overwritten).
    pub sampled: u64,
    /// Traces overwritten by ring wraparound.
    pub dropped: u64,
    /// Live encode workers at snapshot time.
    pub live_workers: u64,
    /// Overall per-stage latency distributions ([`Stage::ALL`] order).
    pub stages: Vec<StageSnapshot>,
    /// Per-model per-stage distributions, model-id order.
    pub models: Vec<ObsModelSnapshot>,
    /// Point-in-time gauges (queue depth, in-flight, per-shard scans…)
    /// appended by the owner of the runtime state.
    pub gauges: Vec<(String, f64)>,
}

fn stages_json(stages: &[StageSnapshot]) -> Json {
    Json::obj(stages.iter().map(|s| (s.stage, json::hist_json(&s.hist))).collect())
}

impl ObsSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sample_every", Json::num(self.sample_every as f64)),
            ("sampled", Json::num(self.sampled as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            ("live_workers", Json::num(self.live_workers as f64)),
            ("stages", stages_json(&self.stages)),
            (
                "models",
                Json::Arr(
                    self.models
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("model", Json::num(m.model as f64)),
                                ("stages", stages_json(&m.stages)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "gauges",
                Json::Obj(
                    self.gauges.iter().map(|(k, v)| (k.clone(), Json::num(*v))).collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic monotone chain with 1 ns between consecutive edges.
    fn chain(req_id: u64, base: u64) -> TraceRecord {
        TraceRecord {
            req_id,
            model: 0,
            worker: 0,
            stolen: false,
            failed: false,
            t_submit: base,
            t_enqueue: base + 1,
            t_cut: base + 3,
            t_pop: base + 4,
            t_encode_start: base + 6,
            t_encode_end: base + 10,
            t_scan_start: base + 11,
            t_scan_end: base + 15,
            t_complete: base + 16,
        }
    }

    #[test]
    fn stages_telescope_to_end_to_end() {
        let r = chain(0, 100);
        assert_eq!(r.stage_ns(Stage::Admission), 1);
        assert_eq!(r.stage_ns(Stage::Queue), 2);
        assert_eq!(r.stage_ns(Stage::Dispatch), 3);
        assert_eq!(r.stage_ns(Stage::Encode), 4);
        assert_eq!(r.stage_ns(Stage::Reorder), 1);
        assert_eq!(r.stage_ns(Stage::Scan), 4);
        assert_eq!(r.stage_ns(Stage::Complete), 1);
        assert_eq!(r.stages_sum_ns(), r.end_to_end_ns());
        assert_eq!(r.end_to_end_ns(), 16);
    }

    #[test]
    fn sampling_cadence_is_deterministic() {
        let t = Tracer::new(ObsCfg { sample_every: 4, ring_cap: 16 }, 1, 1);
        let ids: Vec<Option<u64>> = (0..12).map(|_| t.try_sample()).collect();
        for (i, id) in ids.iter().enumerate() {
            if i % 4 == 0 {
                assert_eq!(*id, Some(i as u64));
            } else {
                assert_eq!(*id, None);
            }
        }
    }

    #[test]
    fn disabled_tracer_allocates_and_records_nothing() {
        let t = Tracer::new(ObsCfg::default(), 4, 2);
        assert!(!t.enabled());
        assert!(t.try_sample().is_none());
        t.record(chain(0, 0)); // out-of-range worker ring: dropped
        assert!(t.drain().is_empty());
        let snap = t.snapshot();
        assert_eq!(snap.sampled, 0);
        assert_eq!(snap.stages.len(), Stage::COUNT);
        assert_eq!(snap.stages[0].hist.count, 0);
    }

    #[test]
    fn ring_wraparound_keeps_newest() {
        let t = Tracer::new(ObsCfg { sample_every: 1, ring_cap: 4 }, 1, 1);
        for i in 0..10 {
            t.record(chain(i, 100 * i));
        }
        let snap = t.snapshot();
        assert_eq!(snap.sampled, 10);
        assert_eq!(snap.dropped, 6);
        let ids: Vec<u64> = t.drain().iter().map(|r| r.req_id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
        // Histograms saw every record, not just the retained ones.
        let snap = t.snapshot();
        assert_eq!(snap.stages[Stage::Encode.index()].hist.count, 10);
    }

    #[test]
    fn drain_merges_workers_in_req_id_order() {
        let t = Tracer::new(ObsCfg { sample_every: 1, ring_cap: 8 }, 2, 1);
        let mut w1 = chain(1, 10);
        w1.worker = 1;
        t.record(chain(2, 20));
        t.record(w1);
        t.record(chain(0, 0));
        let ids: Vec<u64> = t.drain().iter().map(|r| r.req_id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert!(t.drain().is_empty(), "drain must reset the rings");
    }

    #[test]
    fn snapshot_merges_per_worker_tables_per_model() {
        let t = Tracer::new(ObsCfg { sample_every: 1, ring_cap: 8 }, 2, 2);
        // Worker 0 serves model 0 twice; worker 1 serves model 1 once.
        t.record(chain(0, 0));
        t.record(chain(1, 50));
        let mut r = chain(2, 100);
        r.worker = 1;
        r.model = 1;
        t.record(r);
        let snap = t.snapshot();
        assert_eq!(snap.stages[Stage::Encode.index()].hist.count, 3);
        assert_eq!(snap.models.len(), 2);
        assert_eq!(snap.models[0].stages[Stage::Encode.index()].hist.count, 2);
        assert_eq!(snap.models[1].stages[Stage::Encode.index()].hist.count, 1);
    }

    #[test]
    fn failed_traces_skip_stage_histograms() {
        let t = Tracer::new(ObsCfg { sample_every: 1, ring_cap: 8 }, 1, 1);
        let mut r = chain(0, 0);
        r.failed = true;
        t.record(r);
        t.record(chain(1, 50));
        let snap = t.snapshot();
        assert_eq!(snap.sampled, 2, "failed traces still land in the ring");
        assert_eq!(snap.stages[Stage::Encode.index()].hist.count, 1);
        let drained = t.drain();
        assert_eq!(drained.len(), 2);
        assert!(drained[0].failed && !drained[1].failed);
    }

    #[test]
    fn trace_json_round_trips_and_sums() {
        let r = chain(7, 1000);
        let line = r.to_json().compact();
        assert!(!line.contains('\n'), "JSONL records must be single-line");
        let v = Json::parse(&line).expect("trace json parses");
        let sum: f64 = Stage::ALL
            .iter()
            .map(|&s| v.get("stages_ns").unwrap().get(s.name()).unwrap().as_f64().unwrap())
            .sum();
        assert_eq!(sum, v.get("end_to_end_ns").unwrap().as_f64().unwrap());
        assert_eq!(v.get("req_id").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn obs_snapshot_json_parses() {
        let t = Tracer::new(ObsCfg { sample_every: 2, ring_cap: 8 }, 1, 1);
        t.record(chain(0, 0));
        let mut snap = t.snapshot();
        snap.gauges.push(("queue_depth".to_string(), 3.0));
        let text = snap.to_json().pretty();
        let v = Json::parse(&text).expect("snapshot json parses");
        assert_eq!(v.get("sample_every").unwrap().as_usize(), Some(2));
        assert!(v.get("stages").unwrap().get("encode").is_some());
        assert_eq!(
            v.get("gauges").unwrap().get("queue_depth").unwrap().as_f64(),
            Some(3.0)
        );
    }
}
