//! Online inference serving: a request micro-batching front end over the
//! streaming encode pipeline and the associative-memory class store.
//!
//! The ROADMAP north star is "serving heavy traffic from millions of
//! users"; the paper's contribution is that hash-defined streaming
//! encoders make per-request featurization cheap enough to sit on a
//! serving hot path (no codebook to ship, no state to synchronize).
//! This module closes the loop from encoded stream to *answered query*:
//!
//! ```text
//!  clients ──► bounded submission queue ──► RequestStream (size/idle/
//!     ▲            (backpressure)            deadline batch cut)
//!     │                                          │ raw batches
//!     │                                          ▼
//!     │                               run_pipeline: StealScheduler
//!     │                               encode workers + EncodeScratch
//!     │                               (the zero-alloc encode path)
//!     │                                          │ EncodedBatch, in order
//!     │        completion slots                  ▼
//!     └──── (preallocated, recycled) ◄── consumer: sharded AM scan
//!                                        (ShardedAmStore::top1_batch_into)
//!                                        latency/queue-depth stats
//! ```
//!
//! **Micro-batching.** Requests are cut into encode batches
//! adaptively, by size-or-deadline plus an idle cut:
//! * **size** — the batch holds `coordinator.batch_size` requests;
//! * **deadline** — `max_batch_delay` elapsed since the batch's first
//!   request was taken (a request never waits longer than this);
//! * **idle** — the queue is empty and every in-flight request is
//!   already in this batch, so *no* request can arrive before this
//!   batch's responses unblock the clients: waiting out the deadline
//!   would be pure added latency. This is what keeps closed-loop (and
//!   low-concurrency) traffic from paying the deadline on every batch.
//!
//! Under load the pipeline runs full batches (throughput); a lone
//! request is cut immediately (idle) or at worst at the deadline.
//!
//! **Reuse, not reimplementation.** The batcher *is* a
//! [`RecordStream`]: the coordinator's reader pulls request batches from
//! the submission queue exactly as it pulls synthetic batches, so
//! serving inherits the work-stealing dispatch, the scratch encode path,
//! cross-thread buffer recycling and the in-order reorderer untouched.
//! Record buffers are never copied — submission records are swapped into
//! the pipeline's pooled spines and the displaced spine travels back to
//! the client inside its [`Response`], so a closed-loop client rotates
//! buffers indefinitely with **zero steady-state allocations**
//! (extended `tests/alloc_regression.rs` pins this).
//!
//! **Correlation.** The stream emits one `Pending` per request, in batch
//! order, over a bounded channel; the in-order consumer pairs
//! `pending[i]` with `encodings[i]`. Stream order is restored by the
//! coordinator's seq reorderer, so the pairing is exact under any steal
//! interleaving (covered by `tests/serve_smoke.rs` with per-client
//! response checking under concurrency).
//!
//! # Overload and failure semantics
//!
//! Every submission terminates with a [`Response`] or an explicit
//! [`ServeError`] — never a hang, never a silently dropped request.
//!
//! **Admission control.** [`AdmissionPolicy`] decides what happens when
//! the server is saturated (no free completion slot, or the bounded
//! submission queue is full):
//! * `Block` — classic backpressure: park until space (the PR-5
//!   behavior). Parks are bounded slices that re-check `shutdown`, so a
//!   blocked client observes shutdown promptly instead of sleeping on a
//!   full queue forever.
//! * `Shed` — fail fast with [`ServeError::QueueFull`]; counted in
//!   `ServeStats::shed` and exposed as [`ServeSnapshot::shed_rate`].
//!   This is the open-loop overload answer: bounded latency for admitted
//!   work, explicit refusals for the rest.
//! * `TimedBackoff` — retry with jittered exponential backoff up to
//!   `max_wait`, then [`ServeError::AdmissionTimeout`]. Jitter
//!   decorrelates retry herds across clients (deterministic splitmix
//!   stream, no extra dependency).
//!
//! **Deadlines.** A request can carry a deadline (per call via
//! [`RequestOpts`], or [`ServeCfg::default_deadline`]). It is enforced
//! at *two* points: while waiting for admission (an expired request
//! stops waiting and returns [`ServeError::DeadlineExceeded`]) and at
//! batch-cut time (the batcher discards expired queue entries *before*
//! they reach an encode worker — an overloaded server stops paying
//! encode cost for answers nobody is waiting for). Expired requests
//! count in `ServeStats::expired` and still increment `completed` (the
//! idle-cut in-flight arithmetic counts terminal outcomes, not just
//! successes).
//!
//! **Worker failure.** An encode-worker panic is caught by the
//! coordinator ([`crate::coordinator::FaultPlan`] injects them in
//! tests); the batch arrives at the consumer with
//! `EncodedBatch::failed` set and its requests are failed with
//! [`ServeError::Internal`] (counted in `ServeStats::failed`) while the
//! worker rebuilds its encoder from the seed and keeps serving —
//! hash-defined encoder state makes respawn exact and cheap. All serve
//! locks use the uniform poisoned-lock recovery policy
//! ([`crate::util::sync`]), so a panic can never cascade into
//! `PoisonError` unwinds across client threads.
//!
//! # Multi-tenant routing and quotas
//!
//! One server hosts many models ([`ModelRegistry`]): each registered
//! tenant pairs an [`EncoderCfg`] with its [`AmStore`] and scoring
//! [`Precision`], and requests route by [`ModelId`]
//! ([`RequestOpts::model`], or the [`ServeHandle::classify_for`]
//! shorthand). The paper's hash-defined encoders are what make this
//! nearly free: per-model encoder state is just seeds, so **one**
//! work-stealing pool serves every tenant — workers cache encoder
//! instances per (worker × model), built lazily from the seed and
//! respawned from the seed after a panic without touching any other
//! tenant ([`crate::coordinator::run_pipeline_multi`]).
//!
//! The micro-batcher cuts **model-homogeneous** batches: a model switch
//! at the queue front closes the current batch (counted in
//! `ServeStats::model_cuts`), because encode workers hard-assert
//! uniform record widths and each batch is scored against exactly one
//! store. Response pairing is unchanged — pendings are emitted in batch
//! order, and `EncodedBatch::model` routes the consumer to the right
//! tenant's store, so interleaved multi-tenant traffic pairs exactly.
//!
//! **Per-tenant quotas** ([`TenantQuota`], fixed at registration) bound
//! what one tenant can take from the shared pool *before* it touches
//! the shared queue: an in-flight cap (concurrent outstanding
//! requests) and/or a token-bucket rate ([`RateLimit`]). Quota
//! refusals are always fail-fast [`ServeError::QuotaExceeded`] — they
//! are deliberately not subject to the [`AdmissionPolicy`], which
//! governs *server-wide* saturation — and are counted per model
//! (`quota_shed`), so a hostile tenant sheds visibly while quiet
//! tenants keep their latency (the fairness test in
//! `tests/serve_smoke.rs` pins this). Per-model counters and latency
//! histograms surface in [`ServeSnapshot::models`].
//!
//! # Many-class scoring: the sharded AM scan
//!
//! Each tenant's store is held as a [`ShardedAmStore`]
//! ([`ServeCfg::am_shards`], default 1 — a plain inline scan). For
//! many-class tenants (the Zipf-skewed workload in
//! [`crate::data::manyclass`]) the consumer's linear class scan, not
//! encode, is the serving bottleneck; with `am_shards > 1` the consumer
//! scores each model-homogeneous batch with one scoped scorer fan-out
//! over the shard ranges ([`ShardedAmStore::top1_batch_into`] — results
//! exactly equal to the single scan, see [`crate::am::shard`]) and
//! tallies one scan per request per shard into
//! [`ModelSnapshot::shards`], so per-shard scan counts reconcile with
//! the model's completed-minus-failed arithmetic. The single-shard
//! default keeps the consumer's zero steady-state allocations
//! (`tests/alloc_regression.rs`); sharded scoring pays scoped spawns
//! per batch by design.
//!
//! # Observability
//!
//! The flat counters above say *what* happened; the [`crate::obs`]
//! layer says *where the time went*. With [`ServeCfg::obs`] enabled
//! (`sample_every > 0`), every `sample_every`-th submission (by global
//! submission count — deterministic, not probabilistic) carries a
//! [`crate::obs::TraceCtx`] by value through the pipeline, and its
//! nine timestamps telescope into seven stage spans:
//!
//! ```text
//!  submit ─[admission]─► enqueue ─[queue]─► cut ─[dispatch]─► encode
//!  start ─[encode]─► encode end ─[reorder]─► scan start ─[scan]─►
//!  scan end ─[complete]─► complete     (Σ spans = end-to-end latency)
//! ```
//!
//! * the sampling decision runs at the enqueue site under the queue
//!   lock (one counter increment; disabled tracing is a single plain
//!   branch);
//! * the batcher stamps the cut edge as it places the request
//!   ([`RequestStream`]); requests *expired* at the cut drop their
//!   trace — they never reach the consumer;
//! * workers stamp pop/encode edges plus steal provenance onto the
//!   batch ([`crate::coordinator::EncodedBatch::stamps`]);
//! * the in-order consumer stamps scan and completion edges (the
//!   completion stamp is taken *before* the latency histogram's, so
//!   per-request stage sums are ≤ the recorded end-to-end latency) and
//!   assembles the [`crate::obs::TraceRecord`] into the origin
//!   worker's preallocated ring; failed batches deliver traces marked
//!   `failed` with a zero-width scan span, excluded from the stage
//!   histograms.
//!
//! Nothing on the sampled path allocates (Copy contexts, fixed-size
//! ring records, preallocated histograms), so the zero-alloc serve
//! window holds with tracing disabled **and** enabled — both pinned by
//! `tests/alloc_regression.rs`. Read the results via
//! [`ServeHandle::obs_snapshot`] (per-stage / per-model histograms +
//! queue/in-flight/live-worker/shard gauges, the `stage_breakdown`
//! JSON section of the bench reports) and
//! [`ServeHandle::drain_traces`] (the raw per-request records;
//! `serve_bench --trace-out` writes them as JSONL).
//!
//! Live *monitoring* builds on those snapshots: setting
//! [`ServeCfg::metrics_addr`] (and/or [`ServeCfg::slo`]) starts a
//! background publisher that samples the counters every
//! [`ServeCfg::publish_interval`] into a ring, derives windowed rates,
//! judges SLO health, and — with an address — serves Prometheus text on
//! `GET /metrics` plus `/health` and `/snapshot`. See
//! [`crate::obs::export`] for the dataflow and scrape examples, and
//! [`crate::obs::health`] for the verdict semantics. Shutdown joins
//! both threads after the pipeline drains.

pub mod bench;
pub mod latency;

pub use bench::{
    build_many_class_store, run_closed_loop, run_closed_loop_many_class, run_closed_loop_registry,
    run_open_loop, LoadCfg, ManyClassLoadCfg, OpenLoadCfg, OpenLoopReport, ServeBenchReport,
};
pub use latency::{HistSnapshot, Histogram};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::am::{AmScratch, AmStore, Precision, ShardScratch, ShardedAmStore};
use crate::coordinator::{
    run_pipeline_multi, CoordinatorCfg, EncodedBatch, EncoderCfg, PipelineStats,
};
use crate::data::{Record, RecordStream};
use crate::obs::export::{
    spawn_listener, spawn_publisher, MetricsHub, PublishCfg, Sample, WindowRates,
};
use crate::obs::health::{HealthReport, ObsEvent, SloCfg};
use crate::obs::{ObsCfg, ObsSnapshot, StageSnapshot, TraceCtx, TraceRecord, Tracer};
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned};

/// What `classify` does when the server is saturated (no free completion
/// slot, or the bounded submission queue is full). See the module docs
/// for the overload model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Park until space frees up (backpressure). Bounded wait slices keep
    /// shutdown observation prompt.
    #[default]
    Block,
    /// Refuse immediately with [`ServeError::QueueFull`] (load shedding).
    Shed,
    /// Retry with jittered exponential backoff for at most `max_wait`,
    /// then refuse with [`ServeError::AdmissionTimeout`].
    TimedBackoff { max_wait: Duration },
}

/// Identifies one registered model (tenant): the index handed back by
/// [`ModelRegistry::register`], carried on every request as its routing
/// key. `Default` is model 0 — the only model a [`Server::new`]
/// single-tenant server has, so existing single-model callers never
/// mention it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct ModelId(pub u32);

/// Token-bucket rate bound for one tenant: sustained `rps` with bursts
/// up to `burst` requests (the bucket starts full).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RateLimit {
    /// Sustained admissions per second (tokens refill at this rate).
    pub rps: f64,
    /// Bucket capacity: how many requests may be admitted back-to-back
    /// after an idle period.
    pub burst: f64,
}

/// Per-tenant admission quota, fixed at [`ModelRegistry::register`]
/// time. The default is unlimited (no cap, no rate). Quota refusals are
/// fail-fast [`ServeError::QuotaExceeded`] regardless of the
/// [`AdmissionPolicy`]: the policy answers "the *server* is full", a
/// quota answers "this *tenant* asked for more than its share" — a
/// hostile tenant must never convert its excess into queue occupancy
/// that other tenants wait behind.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TenantQuota {
    /// Concurrent outstanding requests this model may hold (slots +
    /// queue occupancy combined); `None` = unbounded.
    pub max_in_flight: Option<u64>,
    /// Token-bucket rate bound; `None` = unbounded.
    pub rate: Option<RateLimit>,
}

/// One registered tenant: its encoder seeds, its class store (held
/// sharded; a fresh registration starts at one shard and
/// [`Server::with_registry`] re-partitions to [`ServeCfg::am_shards`]),
/// the precision scoring reads, and its admission quota.
#[derive(Clone, Debug)]
struct ModelEntry {
    name: String,
    encoder: EncoderCfg,
    store: ShardedAmStore,
    precision: Precision,
    quota: TenantQuota,
}

/// The set of models one server hosts. Registration order defines the
/// [`ModelId`] space (id = index); the registry is sealed once handed
/// to [`Server::with_registry`] — per-model encoder state is just seeds
/// (the paper's scalability property), so re-registering to change a
/// tenant is cheap enough that live mutation isn't worth its locking.
#[derive(Clone, Debug, Default)]
pub struct ModelRegistry {
    models: Vec<ModelEntry>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Register a model; returns the [`ModelId`] requests will route
    /// with. Panics if the encoder's output dimensionality doesn't
    /// match the store (same invariant [`Server::new`] asserts).
    pub fn register(
        &mut self,
        name: &str,
        encoder: EncoderCfg,
        store: AmStore,
        precision: Precision,
        quota: TenantQuota,
    ) -> ModelId {
        assert_eq!(
            encoder.out_dim(),
            store.dim(),
            "encoder output dim must match the AM store (model {name:?})"
        );
        let id = ModelId(self.models.len() as u32);
        self.models.push(ModelEntry {
            name: name.to_string(),
            encoder,
            store: ShardedAmStore::new(store, 1),
            precision,
            quota,
        });
        id
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }
}

/// Per-request options for [`ServeHandle::classify_with`]. `None` fields
/// fall back to the server-wide [`ServeCfg`] defaults.
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestOpts {
    /// Which registered model serves this request (default: model 0,
    /// the [`Server::new`] single-tenant model).
    pub model: ModelId,
    /// Total submit→response budget. Enforced while waiting for
    /// admission *and* at batch-cut time; an expired request returns
    /// [`ServeError::DeadlineExceeded`] without paying encode cost.
    pub deadline: Option<Duration>,
    /// Admission policy override for this request.
    pub admission: Option<AdmissionPolicy>,
}

/// Serving configuration. `coordinator.batch_size` doubles as the
/// micro-batch size cut; `max_records` and `keep_records` are
/// overridden by the server (a serving pipeline runs until shutdown and
/// never needs raw records downstream).
#[derive(Clone, Debug)]
pub struct ServeCfg {
    pub encoder: EncoderCfg,
    pub coordinator: CoordinatorCfg,
    /// Deadline bound of the adaptive batch cut (a request never waits
    /// in the batcher longer than this; idle cuts usually ship sooner).
    pub max_batch_delay: Duration,
    /// Bounded submission-queue capacity (`submit` blocks when full —
    /// backpressure reaches the clients, same policy as the pipeline).
    pub queue_cap: usize,
    /// Preallocated completion slots = the maximum number of in-flight
    /// requests (each outstanding request holds one). Size it at or
    /// above the expected concurrent-client count.
    pub slots: usize,
    /// Which prototype representation scoring reads.
    pub precision: Precision,
    /// How many contiguous class-range shards each tenant's store is
    /// partitioned into for consumer scoring (clamped per model to its
    /// class count). 1 — the default — scans inline with zero
    /// steady-state allocations; raise it for many-class tenants, where
    /// the scan fans out over a scoped scorer pool with results exactly
    /// equal to the single scan (see [`crate::am::shard`]).
    pub am_shards: usize,
    /// Server-wide admission policy; overridable per request via
    /// [`RequestOpts::admission`].
    pub admission: AdmissionPolicy,
    /// Deadline applied to every request that doesn't carry its own
    /// ([`RequestOpts::deadline`]). `None` = no deadline.
    pub default_deadline: Option<Duration>,
    /// Stage-span tracing (see the module-level *Observability*
    /// section). Disabled by default (`sample_every: 0`) — costs one
    /// branch per submission and allocates nothing.
    pub obs: ObsCfg,
    /// Bind address for the metrics exporter (`"127.0.0.1:9464"`;
    /// port 0 picks a free port, readable back via
    /// [`ServeHandle::metrics_addr`]). `None` — the default — binds
    /// nothing. Setting it also starts the metrics publisher. The
    /// listener serves `GET /metrics` (Prometheus text), `/health`
    /// (JSON SLO verdict + lifecycle events) and `/snapshot`
    /// ([`ObsSnapshot`] JSON); see [`crate::obs::export`].
    pub metrics_addr: Option<String>,
    /// SLO objectives evaluated once per publish window by the
    /// watchdog ([`crate::obs::health`]). `Some` starts the publisher
    /// even without a listener (verdicts via [`ServeHandle::health`]);
    /// `None` with a `metrics_addr` still publishes, judging against
    /// [`SloCfg::default`].
    pub slo: Option<SloCfg>,
    /// Sampling interval of the metrics publisher — one windowed-rate /
    /// SLO evaluation per tick. Only meaningful when publishing is on
    /// (`metrics_addr` or `slo` set). Clamped to ≥ 1 ms.
    pub publish_interval: Duration,
}

impl ServeCfg {
    pub fn new(encoder: EncoderCfg) -> ServeCfg {
        ServeCfg {
            encoder,
            coordinator: CoordinatorCfg {
                batch_size: 64,
                n_workers: 2,
                queue_depth: 4,
                ..Default::default()
            },
            max_batch_delay: Duration::from_micros(500),
            queue_cap: 256,
            slots: 128,
            precision: Precision::F32,
            am_shards: 1,
            admission: AdmissionPolicy::Block,
            default_deadline: None,
            obs: ObsCfg::default(),
            metrics_addr: None,
            slo: None,
            publish_interval: Duration::from_millis(100),
        }
    }
}

/// What a completed request returns.
#[derive(Clone, Debug)]
pub struct Response {
    pub top_class: u32,
    pub score: f32,
    /// Submit-to-completion wall time (queueing + encode + score).
    pub latency: Duration,
    /// A recycled record buffer handed back for reuse — *not*
    /// necessarily the submitted allocation; closed-loop clients refill
    /// it for their next request to stay allocation-free.
    pub record: Record,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The server no longer accepts submissions.
    Shutdown,
    /// The request was accepted but the pipeline terminated before
    /// completing it (worker panic / forced stop).
    Aborted,
    /// The record's numeric width doesn't match the encoder's (the
    /// record is dropped; micro-batches mix requests from many clients,
    /// so one ragged width would panic an encode worker for everyone).
    InvalidNumericWidth { got: usize, want: usize },
    /// Shed at admission: the server is saturated and the request's
    /// [`AdmissionPolicy::Shed`] chose fail-fast over waiting.
    QueueFull,
    /// [`AdmissionPolicy::TimedBackoff`] retried for `max_wait` without
    /// the server ever having room.
    AdmissionTimeout,
    /// The request's deadline passed before a response was produced —
    /// while waiting for admission, or in the queue before its batch was
    /// cut (the batcher discards it without paying encode cost).
    DeadlineExceeded,
    /// The request was admitted but its encode batch failed (worker
    /// panic, recovered). The server stays up; retrying is reasonable.
    Internal,
    /// The request routed to a [`ModelId`] the server never registered.
    UnknownModel { model: ModelId },
    /// The tenant's own [`TenantQuota`] refused the request (in-flight
    /// cap hit, or the token bucket ran dry). Always fail-fast; the
    /// server itself may be far from saturated.
    QuotaExceeded,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Shutdown => write!(f, "server is shut down"),
            ServeError::Aborted => write!(f, "request aborted by pipeline shutdown"),
            ServeError::InvalidNumericWidth { got, want } => {
                write!(f, "record has {got} numeric features, encoder expects {want}")
            }
            ServeError::QueueFull => write!(f, "server saturated, request shed"),
            ServeError::AdmissionTimeout => write!(f, "admission retries timed out"),
            ServeError::DeadlineExceeded => write!(f, "request deadline exceeded"),
            ServeError::Internal => write!(f, "encode batch failed (worker panic, recovered)"),
            ServeError::UnknownModel { model } => {
                write!(f, "no model registered with id {}", model.0)
            }
            ServeError::QuotaExceeded => write!(f, "tenant quota exceeded, request shed"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Serve-path counters + distributions; shared, lock-free to record.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub submitted: AtomicU64,
    /// Admitted requests that reached a terminal outcome of *any* kind:
    /// a [`Response`], a batch-cut deadline expiry, or an encode-batch
    /// failure. The idle-cut arithmetic (`submitted − completed` = in
    /// flight) relies on every admitted request incrementing this
    /// exactly once.
    pub completed: AtomicU64,
    /// Submissions refused without entering the pipeline: the server was
    /// shutting down, or the record failed validation
    /// ([`ServeError::InvalidNumericWidth`]).
    pub rejected: AtomicU64,
    /// Submissions refused by [`AdmissionPolicy::Shed`]
    /// ([`ServeError::QueueFull`]).
    pub shed: AtomicU64,
    /// Submissions refused after [`AdmissionPolicy::TimedBackoff`]
    /// exhausted `max_wait` ([`ServeError::AdmissionTimeout`]).
    pub admission_timeouts: AtomicU64,
    /// Requests whose deadline passed before encode — failed with
    /// [`ServeError::DeadlineExceeded`] either while waiting for
    /// admission (never admitted) or at batch-cut time (admitted, so
    /// also counted in `completed`).
    pub expired: AtomicU64,
    /// Admitted requests failed with [`ServeError::Internal`] because
    /// their encode batch failed (worker panic). Counted in `completed`
    /// too.
    pub failed: AtomicU64,
    /// Submissions refused by the tenant's own [`TenantQuota`]
    /// ([`ServeError::QuotaExceeded`]) — never admitted, never queued.
    pub quota_shed: AtomicU64,
    pub batches: AtomicU64,
    /// Batches closed because they reached `batch_size`.
    pub size_cuts: AtomicU64,
    /// Batches closed by the deadline (or the shutdown drain).
    pub deadline_cuts: AtomicU64,
    /// Batches closed by the idle cut (queue empty, nothing else in
    /// flight anywhere — waiting could not add work).
    pub idle_cuts: AtomicU64,
    /// Batches closed because the next queued request routes to a
    /// different model (encode batches are model-homogeneous).
    pub model_cuts: AtomicU64,
    /// Per-request submit→complete latency, nanoseconds.
    pub latency_ns: Histogram,
    /// Submission-queue depth sampled at every batch cut.
    pub queue_depth: Histogram,
}

/// Per-model (tenant) counters; the same outcome taxonomy as the global
/// [`ServeStats`], tallied at the identical code sites so
/// `sum(models.*) == global.*` for every shared counter.
#[derive(Debug, Default)]
struct ModelStats {
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    /// Load refusals at admission for this tenant: `Shed` plus
    /// `TimedBackoff` exhaustion (the global stats split these two).
    shed: AtomicU64,
    quota_shed: AtomicU64,
    expired: AtomicU64,
    failed: AtomicU64,
    latency_ns: Histogram,
}

/// Per-shard scan statistics of one model's [`ShardedAmStore`]
/// ([`ModelSnapshot::shards`], in shard order). Every successfully
/// scored request scans *every* shard (the scan partitions classes, not
/// queries), so each shard's `scans` equals the model's scored-request
/// count — the reconciliation `tests/serve_smoke.rs` pins.
#[derive(Clone, Copy, Debug)]
pub struct ShardScanSnapshot {
    /// How many classes this shard's contiguous range holds.
    pub classes: u32,
    /// Requests scored against this shard.
    pub scans: u64,
}

/// Point-in-time per-model statistics ([`ServeSnapshot::models`], in
/// [`ModelId`] order).
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    /// Registration name of the tenant.
    pub name: String,
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Server-load refusals (shed + admission timeouts) for this tenant.
    pub shed: u64,
    /// Refusals by this tenant's own quota.
    pub quota_shed: u64,
    pub expired: u64,
    pub failed: u64,
    /// Requests currently outstanding (the gauge the in-flight quota
    /// caps).
    pub in_flight: u64,
    pub latency_ns: HistSnapshot,
    /// Per-shard scan stats of this model's sharded AM store, in shard
    /// order (one entry even at the single-shard default).
    pub shards: Vec<ShardScanSnapshot>,
}

/// Point-in-time serve statistics. (No longer `Copy`: it carries the
/// per-model snapshot vector.)
#[derive(Clone, Debug, Default)]
pub struct ServeSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub shed: u64,
    pub admission_timeouts: u64,
    pub expired: u64,
    pub failed: u64,
    pub quota_shed: u64,
    pub batches: u64,
    pub size_cuts: u64,
    pub deadline_cuts: u64,
    pub idle_cuts: u64,
    pub model_cuts: u64,
    pub latency_ns: HistSnapshot,
    pub queue_depth: HistSnapshot,
    /// Per-model breakdown in [`ModelId`] order. Populated by
    /// [`ServeHandle::stats`]; empty from a bare
    /// [`ServeStats::snapshot`].
    pub models: Vec<ModelSnapshot>,
}

impl ServeSnapshot {
    fn attempts(&self) -> u64 {
        self.submitted + self.shed + self.admission_timeouts + self.quota_shed
    }

    /// Fraction of admission attempts refused for *any* rationing
    /// reason — overload sheds (`shed + admission_timeouts`) **and**
    /// tenant-quota refusals (`quota_shed`) — over all attempts that
    /// reached admission. The aggregate saturation gauge for open-loop
    /// traffic: ~0 below capacity, climbing toward
    /// `1 − capacity/offered` above it. When the distinction matters
    /// (it does to the SLO watchdog), use [`Self::overload_shed_rate`]
    /// / [`Self::quota_shed_rate`], which partition this exactly:
    /// `shed_rate == overload_shed_rate + quota_shed_rate`.
    pub fn shed_rate(&self) -> f64 {
        let refused = self.shed + self.admission_timeouts + self.quota_shed;
        let attempts = self.attempts();
        if attempts == 0 {
            return 0.0;
        }
        refused as f64 / attempts as f64
    }

    /// Fraction of admission attempts refused because the *server* was
    /// overloaded: [`ServeError::QueueFull`] sheds plus
    /// [`ServeError::AdmissionTimeout`] backoff exhaustion. This is the
    /// rate the SLO evaluator judges against
    /// [`SloCfg::max_shed_rate`] — an overloaded server is the
    /// operator's problem.
    pub fn overload_shed_rate(&self) -> f64 {
        let attempts = self.attempts();
        if attempts == 0 {
            return 0.0;
        }
        (self.shed + self.admission_timeouts) as f64 / attempts as f64
    }

    /// Fraction of admission attempts refused by tenants' *own*
    /// [`TenantQuota`]s ([`ServeError::QuotaExceeded`]). Policy working
    /// as designed — never an SLO breach, however high it climbs
    /// (though bursts are surfaced as lifecycle events).
    pub fn quota_shed_rate(&self) -> f64 {
        let attempts = self.attempts();
        if attempts == 0 {
            return 0.0;
        }
        self.quota_shed as f64 / attempts as f64
    }
}

impl ServeStats {
    pub fn snapshot(&self) -> ServeSnapshot {
        ServeSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            admission_timeouts: self.admission_timeouts.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            quota_shed: self.quota_shed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            size_cuts: self.size_cuts.load(Ordering::Relaxed),
            deadline_cuts: self.deadline_cuts.load(Ordering::Relaxed),
            idle_cuts: self.idle_cuts.load(Ordering::Relaxed),
            model_cuts: self.model_cuts.load(Ordering::Relaxed),
            latency_ns: self.latency_ns.snapshot(),
            queue_depth: self.queue_depth.snapshot(),
            models: Vec::new(),
        }
    }
}

/// One queued request: its completion slot, its record, and when it
/// entered `classify` (latency starts at the user-visible boundary).
struct Submission {
    slot: usize,
    record: Record,
    t_submit: Instant,
    /// Registered model this request routes to (validated at classify,
    /// so always in range); the batcher cuts model-homogeneous batches
    /// on this field.
    model: u32,
    /// Absolute deadline; the batcher discards the request unencoded
    /// once this passes.
    deadline: Option<Instant>,
    /// Stage-span context when this submission was sampled for tracing
    /// (`Copy`, carried by value — no allocation). The batcher stamps
    /// the cut edge into it; expired submissions drop it unrecorded.
    trace: Option<TraceCtx>,
}

/// Completion-order companion to one in-flight request; paired with its
/// encoding by position (stream order == pending order).
struct Pending {
    slot: usize,
    t_submit: Instant,
    /// The buffer handed back to the client in its [`Response`].
    record: Record,
    /// Sampled trace context (cut edge stamped), completed by the
    /// consumer with scan/completion edges + the batch's worker stamps.
    trace: Option<TraceCtx>,
}

enum SlotState {
    Empty,
    Done(Response),
    /// Terminal failure delivered to the parked client: `Aborted`
    /// (pipeline died), `DeadlineExceeded` (expired at batch cut) or
    /// `Internal` (encode batch failed).
    Failed(ServeError),
}

/// A preallocated completion slot; clients park on `cv` until the
/// consumer fills `state`.
struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

/// Token-bucket state for one tenant's [`RateLimit`]; one small mutex
/// per *model* (not per server), touched only by that tenant's own
/// submissions.
struct TokenBucket {
    tokens: f64,
    last: Instant,
    rps: f64,
    burst: f64,
}

impl TokenBucket {
    fn new(rate: RateLimit) -> TokenBucket {
        TokenBucket {
            tokens: rate.burst,
            last: Instant::now(),
            rps: rate.rps,
            burst: rate.burst,
        }
    }

    /// Refill by elapsed time, then take one token; `false` = dry.
    fn try_take(&mut self, now: Instant) -> bool {
        let elapsed = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + elapsed * self.rps).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Runtime state of one registered model: validation width, quota
/// enforcement state, and the per-tenant counters.
struct ModelRuntime {
    name: String,
    /// Numeric width this model's submissions must carry (None when the
    /// encoder has no numeric branch): the encode workers hard-assert
    /// uniform widths, so one malformed request in a mixed batch would
    /// panic a worker — reject it at `classify` instead.
    expect_numeric: Option<usize>,
    /// In-flight cap from [`TenantQuota::max_in_flight`].
    max_in_flight: Option<u64>,
    /// Outstanding requests (admission attempt → terminal outcome).
    in_flight: AtomicU64,
    /// Token bucket from [`TenantQuota::rate`].
    bucket: Option<Mutex<TokenBucket>>,
    stats: ModelStats,
    /// Class count per shard of this model's [`ShardedAmStore`], fixed
    /// at server construction (shard order).
    shard_classes: Vec<u32>,
    /// Requests scored against each shard (shard order); bumped by the
    /// consumer once per request per shard.
    shard_scans: Vec<AtomicU64>,
}

impl ModelRuntime {
    fn snapshot(&self) -> ModelSnapshot {
        ModelSnapshot {
            name: self.name.clone(),
            submitted: self.stats.submitted.load(Ordering::Relaxed),
            completed: self.stats.completed.load(Ordering::Relaxed),
            rejected: self.stats.rejected.load(Ordering::Relaxed),
            shed: self.stats.shed.load(Ordering::Relaxed),
            quota_shed: self.stats.quota_shed.load(Ordering::Relaxed),
            expired: self.stats.expired.load(Ordering::Relaxed),
            failed: self.stats.failed.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Relaxed),
            latency_ns: self.stats.latency_ns.snapshot(),
            shards: self
                .shard_classes
                .iter()
                .zip(&self.shard_scans)
                .map(|(&classes, scans)| ShardScanSnapshot {
                    classes,
                    scans: scans.load(Ordering::Relaxed),
                })
                .collect(),
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<Submission>>,
    /// Batcher parks here for the next submission.
    nonempty_cv: Condvar,
    /// Submitters park here when the queue is full.
    space_cv: Condvar,
    /// Submitters park here when every slot is in flight.
    slot_cv: Condvar,
    free_slots: Mutex<Vec<usize>>,
    slots: Vec<Slot>,
    shutdown: AtomicBool,
    /// Raised by the coordinator ([`CoordinatorCfg::stop_flag`]) when
    /// the pipeline dies abnormally (worker panic, consumer gone); the
    /// batcher polls it with a bounded park so a dead pipeline can never
    /// strand the reader — and with it every client — forever.
    pipeline_stop: Arc<AtomicBool>,
    /// Runtime state per registered model, in [`ModelId`] order —
    /// validation width, quota state, per-tenant counters.
    models: Vec<ModelRuntime>,
    stats: ServeStats,
    queue_cap: usize,
    /// Server-wide admission policy ([`ServeCfg::admission`]).
    admission: AdmissionPolicy,
    /// Server-wide deadline default ([`ServeCfg::default_deadline`]).
    default_deadline: Option<Duration>,
    /// Splitmix counter feeding backoff jitter (deterministic, shared by
    /// all clients; see [`crate::util::rng::mix64`]).
    jitter: AtomicU64,
    /// Stage-span tracer ([`ServeCfg::obs`]); always present, inert
    /// (one plain branch per submission) when sampling is disabled.
    tracer: Arc<Tracer>,
    /// Monitoring hub (sample ring + SLO evaluator + event ring),
    /// present iff publishing is enabled (`metrics_addr` or `slo`).
    /// The request hot path never touches it — the publisher and
    /// listener threads own all sampling and allocation.
    hub: Option<Arc<MetricsHub>>,
}

/// Assemble a sampled request's full span chain: the context it carried
/// through the queue, the worker-side stamps riding on its batch, and
/// the consumer-side scan/completion edges captured by the caller.
fn trace_record(
    ctx: TraceCtx,
    batch: &EncodedBatch,
    scan: (u64, u64),
    t_complete: u64,
    failed: bool,
) -> TraceRecord {
    TraceRecord {
        req_id: ctx.req_id,
        model: batch.model,
        worker: batch.origin as u32,
        stolen: batch.stamps.stolen,
        failed,
        t_submit: ctx.t_submit,
        t_enqueue: ctx.t_enqueue,
        t_cut: ctx.t_cut,
        t_pop: batch.stamps.t_pop,
        t_encode_start: batch.stamps.t_encode_start,
        t_encode_end: batch.stamps.t_encode_end,
        t_scan_start: scan.0,
        t_scan_end: scan.1,
        t_complete,
    }
}

/// Deliver a terminal failure to the client parked on `slot`.
fn fail_slot(sh: &Shared, slot: usize, err: ServeError) {
    let s = &sh.slots[slot];
    let mut st = lock_unpoisoned(&s.state);
    *st = SlotState::Failed(err);
    s.cv.notify_one();
}

/// Jittered backoff wait for [`AdmissionPolicy::TimedBackoff`]: base
/// `50µs · 2^attempt`, capped at 2 ms, scaled by a deterministic factor
/// in [0.5, 1.5) so concurrent clients don't retry in lockstep.
fn backoff_step(sh: &Shared, attempt: u32) -> Duration {
    let base_us = 50u64.saturating_mul(1 << attempt.min(5)); // 50µs..1.6ms
    let x = sh.jitter.fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed);
    let frac = (crate::util::rng::mix64(x) >> 11) as f64 / (1u64 << 53) as f64;
    Duration::from_micros(base_us).mul_f64(0.5 + frac).min(Duration::from_millis(2))
}

fn empty_record() -> Record {
    Record { numeric: Vec::new(), symbols: Vec::new(), label: false }
}

/// Client handle: cheap to clone, one per client thread.
#[derive(Clone)]
pub struct ServeHandle {
    shared: Arc<Shared>,
}

/// One request's admission context, threaded through the slot-acquire
/// and enqueue retry loops: the resolved policy and deadline, the
/// backoff attempt counter, and the routed tenant's counters (every
/// refusal tallies globally *and* per model).
struct AdmitCtx<'a> {
    admission: AdmissionPolicy,
    deadline: Option<Instant>,
    t_submit: Instant,
    attempt: u32,
    model: &'a ModelStats,
}

/// Saturation wait shared by the slot-acquire and enqueue loops: apply
/// the admission policy (and deadline) once, returning the re-acquired
/// guard to retry, or the counted refusal error to bail. Every wait is a
/// *bounded* slice, so a party parked here observes `shutdown` promptly
/// on its next iteration no matter what wakes (or fails to wake) the
/// condvar — this is what fixes the classify/shutdown race on a full
/// queue.
fn admission_wait<'a, T>(
    sh: &Shared,
    cv: &Condvar,
    g: std::sync::MutexGuard<'a, T>,
    ctx: &mut AdmitCtx<'_>,
) -> Result<std::sync::MutexGuard<'a, T>, ServeError> {
    if let Some(dl) = ctx.deadline {
        if Instant::now() >= dl {
            sh.stats.expired.fetch_add(1, Ordering::Relaxed);
            ctx.model.expired.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::DeadlineExceeded);
        }
    }
    match ctx.admission {
        AdmissionPolicy::Block => {
            let (g, _) = wait_timeout_unpoisoned(cv, g, Duration::from_millis(5));
            Ok(g)
        }
        AdmissionPolicy::Shed => {
            sh.stats.shed.fetch_add(1, Ordering::Relaxed);
            ctx.model.shed.fetch_add(1, Ordering::Relaxed);
            Err(ServeError::QueueFull)
        }
        AdmissionPolicy::TimedBackoff { max_wait } => {
            if ctx.t_submit.elapsed() >= max_wait {
                sh.stats.admission_timeouts.fetch_add(1, Ordering::Relaxed);
                ctx.model.shed.fetch_add(1, Ordering::Relaxed);
                return Err(ServeError::AdmissionTimeout);
            }
            let step = backoff_step(sh, ctx.attempt);
            ctx.attempt = ctx.attempt.saturating_add(1);
            let (g, _) = wait_timeout_unpoisoned(cv, g, step);
            Ok(g)
        }
    }
}

/// RAII decrement of a tenant's in-flight gauge: created the moment the
/// quota admits the request, dropped when `classify_with` returns by
/// *any* path — success, refusal, expiry or abort — so no outcome can
/// leak a quota slot.
struct InFlightGuard<'a>(&'a AtomicU64);

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

impl ServeHandle {
    /// Classify one record with the server-default [`RequestOpts`]
    /// (closed-loop call: blocks per the server's admission policy until
    /// the response).
    pub fn classify(&self, record: Record) -> Result<Response, ServeError> {
        self.classify_with(record, RequestOpts::default())
    }

    /// Classify one record against a specific registered model, with the
    /// server-default admission and deadline.
    pub fn classify_for(&self, model: ModelId, record: Record) -> Result<Response, ServeError> {
        self.classify_with(record, RequestOpts { model, ..RequestOpts::default() })
    }

    /// Classify one record under explicit model/admission/deadline
    /// options. Always terminates with a [`Response`] or an explicit
    /// [`ServeError`]; see the module docs for the overload model.
    pub fn classify_with(
        &self,
        record: Record,
        opts: RequestOpts,
    ) -> Result<Response, ServeError> {
        let sh = &*self.shared;
        // Resolve the routed model; an unknown id is rejected before it
        // can touch any shared state.
        let Some(rt) = sh.models.get(opts.model.0 as usize) else {
            sh.stats.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::UnknownModel { model: opts.model });
        };
        // Reject malformed records before they can reach a shared
        // micro-batch (the encode workers assert uniform numeric widths).
        if let Some(want) = rt.expect_numeric {
            if record.numeric.len() != want {
                sh.stats.rejected.fetch_add(1, Ordering::Relaxed);
                rt.stats.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ServeError::InvalidNumericWidth {
                    got: record.numeric.len(),
                    want,
                });
            }
        }
        // Tenant quota, enforced before the request can occupy any
        // shared resource (slot or queue space). Fail-fast by design:
        // see the module docs. The in-flight gauge is incremented
        // check-and-set atomically and decremented by the RAII guard on
        // every return path below.
        let quota_refused = |err: ServeError| {
            sh.stats.quota_shed.fetch_add(1, Ordering::Relaxed);
            rt.stats.quota_shed.fetch_add(1, Ordering::Relaxed);
            Err(err)
        };
        let _in_flight = match rt.max_in_flight {
            Some(cap) => {
                let admitted = rt
                    .in_flight
                    .fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
                        if v < cap {
                            Some(v + 1)
                        } else {
                            None
                        }
                    })
                    .is_ok();
                if !admitted {
                    return quota_refused(ServeError::QuotaExceeded);
                }
                Some(InFlightGuard(&rt.in_flight))
            }
            None => None,
        };
        if let Some(bucket) = &rt.bucket {
            let dry = !lock_unpoisoned(bucket).try_take(Instant::now());
            if dry {
                // `_in_flight` refunds the gauge on this return.
                return quota_refused(ServeError::QuotaExceeded);
            }
        }
        let t_submit = Instant::now();
        let mut ctx = AdmitCtx {
            admission: opts.admission.unwrap_or(sh.admission),
            deadline: opts.deadline.or(sh.default_deadline).map(|d| t_submit + d),
            t_submit,
            attempt: 0,
            model: &rt.stats,
        };
        // Acquire a completion slot (saturation point #1: more
        // concurrent callers than slots).
        let slot = {
            let mut free = lock_unpoisoned(&sh.free_slots);
            loop {
                if sh.shutdown.load(Ordering::Acquire) {
                    sh.stats.rejected.fetch_add(1, Ordering::Relaxed);
                    rt.stats.rejected.fetch_add(1, Ordering::Relaxed);
                    return Err(ServeError::Shutdown);
                }
                if let Some(i) = free.pop() {
                    break i;
                }
                free = admission_wait(sh, &sh.slot_cv, free, &mut ctx)?;
            }
        };
        // Enqueue (saturation point #2: the bounded submission queue).
        {
            let mut q = lock_unpoisoned(&sh.queue);
            loop {
                if sh.shutdown.load(Ordering::Acquire) {
                    drop(q);
                    self.release_slot(slot);
                    sh.stats.rejected.fetch_add(1, Ordering::Relaxed);
                    rt.stats.rejected.fetch_add(1, Ordering::Relaxed);
                    return Err(ServeError::Shutdown);
                }
                if q.len() < sh.queue_cap {
                    // Counted under the queue lock, so the batcher's
                    // idle-cut read of (submitted − completed) — also
                    // under this lock — can never miss a request that
                    // is about to be pushed.
                    sh.stats.submitted.fetch_add(1, Ordering::Relaxed);
                    rt.stats.submitted.fetch_add(1, Ordering::Relaxed);
                    // Sampling decision (1-in-N by submission count;
                    // a single branch when tracing is disabled). The
                    // admission span runs submit→enqueue, covering
                    // quota checks and both saturation waits above.
                    let trace = sh.tracer.try_sample().map(|req_id| TraceCtx {
                        req_id,
                        t_submit: sh.tracer.ns_since_epoch(t_submit),
                        t_enqueue: sh.tracer.now_ns(),
                        t_cut: 0,
                    });
                    q.push_back(Submission {
                        slot,
                        record,
                        t_submit,
                        model: opts.model.0,
                        deadline: ctx.deadline,
                        trace,
                    });
                    sh.nonempty_cv.notify_one();
                    break;
                }
                match admission_wait(sh, &sh.space_cv, q, &mut ctx) {
                    Ok(g) => q = g,
                    Err(e) => {
                        self.release_slot(slot);
                        return Err(e);
                    }
                }
            }
        }
        // Park until the consumer (or the batcher's deadline expiry, or
        // the abort guard) resolves the slot. An admitted request is
        // guaranteed a terminal outcome, so this wait needs no timeout.
        let s = &sh.slots[slot];
        let mut st = lock_unpoisoned(&s.state);
        loop {
            match std::mem::replace(&mut *st, SlotState::Empty) {
                SlotState::Done(resp) => {
                    drop(st);
                    self.release_slot(slot);
                    return Ok(resp);
                }
                SlotState::Failed(err) => {
                    drop(st);
                    self.release_slot(slot);
                    return Err(err);
                }
                SlotState::Empty => st = wait_unpoisoned(&s.cv, st),
            }
        }
    }

    fn release_slot(&self, slot: usize) {
        let sh = &*self.shared;
        lock_unpoisoned(&sh.free_slots).push(slot);
        sh.slot_cv.notify_one();
    }

    /// Stop accepting submissions; queued requests still drain through
    /// the pipeline and complete, then [`Server::run`] returns.
    pub fn shutdown(&self) {
        let sh = &*self.shared;
        sh.shutdown.store(true, Ordering::Release);
        // Wake every parked party so it re-checks the flag.
        let _q = lock_unpoisoned(&sh.queue);
        sh.nonempty_cv.notify_all();
        sh.space_cv.notify_all();
        drop(_q);
        let _f = lock_unpoisoned(&sh.free_slots);
        sh.slot_cv.notify_all();
    }

    pub fn stats(&self) -> ServeSnapshot {
        let mut snap = self.shared.stats.snapshot();
        snap.models = self.shared.models.iter().map(ModelRuntime::snapshot).collect();
        snap
    }

    /// Is stage-span tracing on ([`ServeCfg::obs`], `sample_every > 0`)?
    pub fn tracing_enabled(&self) -> bool {
        self.shared.tracer.enabled()
    }

    /// Take every retained per-request trace (ring contents across all
    /// workers, `req_id` order) and reset the rings. Empty when tracing
    /// is disabled.
    pub fn drain_traces(&self) -> Vec<TraceRecord> {
        self.shared.tracer.drain()
    }

    /// Point-in-time observability export: per-stage and per-model
    /// latency histograms from the tracer plus the server's live gauges
    /// (submission-queue depth, global and per-model in-flight, live
    /// encode workers, per-shard scan counts). This is the
    /// `stage_breakdown` section of the bench reports.
    pub fn obs_snapshot(&self) -> ObsSnapshot {
        let sh = &*self.shared;
        let mut snap = sh.tracer.snapshot();
        let depth = lock_unpoisoned(&sh.queue).len();
        let submitted = sh.stats.submitted.load(Ordering::Relaxed);
        let completed = sh.stats.completed.load(Ordering::Relaxed);
        snap.gauges.push(("queue_depth".to_string(), depth as f64));
        snap.gauges
            .push(("in_flight".to_string(), submitted.saturating_sub(completed) as f64));
        for (m, rt) in sh.models.iter().enumerate() {
            snap.gauges.push((
                format!("model{m}_in_flight"),
                rt.in_flight.load(Ordering::Relaxed) as f64,
            ));
            for (s, scans) in rt.shard_scans.iter().enumerate() {
                snap.gauges.push((
                    format!("model{m}_shard{s}_scans"),
                    scans.load(Ordering::Relaxed) as f64,
                ));
            }
        }
        snap
    }

    /// Actual bound address of the metrics exporter — `Some` once
    /// [`ServeCfg::metrics_addr`] was set and the listener bound
    /// (immediately at construction), carrying the kernel-assigned port
    /// when the config said `:0`.
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.shared.hub.as_ref().and_then(|h| h.bound_addr())
    }

    /// Latest SLO verdict from the watchdog; `None` when publishing is
    /// off ([`ServeCfg::slo`] and [`ServeCfg::metrics_addr`] both
    /// unset), default-healthy before the first closed window.
    pub fn health(&self) -> Option<HealthReport> {
        self.shared.hub.as_ref().map(|h| h.health())
    }

    /// Windowed rates of the last closed publish window (`None` when
    /// publishing is off or fewer than two samples exist yet).
    pub fn window_rates(&self) -> Option<WindowRates> {
        self.shared.hub.as_ref().and_then(|h| h.window_rates())
    }

    /// Take every retained lifecycle event (worker retirements, shed
    /// bursts, queue saturation, SLO breach/recovery…), oldest first,
    /// resetting the ring. Empty when publishing is off. The `/health`
    /// endpoint *peeks* instead, so scrapes never race a consumer
    /// draining here.
    pub fn drain_events(&self) -> Vec<ObsEvent> {
        self.shared.hub.as_ref().map(|h| h.drain_events()).unwrap_or_default()
    }

    /// Render the full Prometheus text exposition from the live
    /// counters — exactly what `GET /metrics` serves; `None` when
    /// publishing is off.
    pub fn render_metrics(&self) -> Option<String> {
        self.shared.hub.as_ref().map(|h| crate::obs::export::render_metrics(self, h))
    }

    /// Per-worker per-stage latency snapshots ([`Stage::ALL`] order per
    /// worker, workers in pool order; the `shdc_worker_stage_latency_ns`
    /// series). Empty when tracing is disabled.
    ///
    /// [`Stage::ALL`]: crate::obs::Stage::ALL
    pub fn worker_stage_snapshots(&self) -> Vec<Vec<StageSnapshot>> {
        self.shared.tracer.worker_stages()
    }

    /// One publisher sample: every monotone counter + raw histogram
    /// bucket capture the windowed derivation subtracts. Called by the
    /// metrics publisher thread on its own interval; the only cost to
    /// the serve path is the relaxed atomic loads.
    pub fn obs_sample(&self, t_ns: u64) -> Sample {
        let sh = &*self.shared;
        let serve = self.stats();
        let latency = sh.stats.latency_ns.buckets();
        let stages = sh.tracer.stage_buckets();
        let queue_depth = lock_unpoisoned(&sh.queue).len() as u64;
        Sample {
            t_ns,
            serve,
            latency,
            stages,
            live_workers: sh.tracer.live_workers(),
            queue_depth,
        }
    }
}

/// The batcher side: a [`RecordStream`] over the submission queue.
struct RequestStream {
    shared: Arc<Shared>,
    pending_tx: SyncSender<Pending>,
    max_delay: Duration,
    /// Surplus records popped off recycled spines when a batch comes up
    /// shorter than its predecessor; reused as hand-back buffers so
    /// variable batch sizes never drop (deallocate) a record. Bounded by
    /// the records in circulation (slots + in-flight spines).
    spare: Vec<Record>,
    /// Model of the batch currently being gathered (set by the batch's
    /// first placed request) — reported to the coordinator through
    /// [`RecordStream::batch_model`]; the gather loop cuts the batch
    /// when the queue front routes elsewhere, keeping every encode batch
    /// model-homogeneous.
    current_model: u32,
    /// Fault injection ([`crate::coordinator::FaultPlan::stall_batcher`]):
    /// sleep this long before cutting the first batch, so tests can
    /// saturate the submission queue deterministically.
    stall_batcher: Option<Duration>,
}

impl RequestStream {
    /// Move one submission into the outgoing batch: swap its record with
    /// the recycled spine at `out[*filled]` (or push it when the spine
    /// pool is still cold) and forward the displaced buffer through the
    /// pending channel for hand-back at completion.
    fn place(&mut self, out: &mut Vec<Record>, filled: &mut usize, sub: Submission) {
        let Submission { slot, record, t_submit, model: _, deadline: _, mut trace } = sub;
        if let Some(t) = trace.as_mut() {
            // Cut edge: the request leaves the queue for an encode
            // batch. Queue span = t_cut − t_enqueue.
            t.t_cut = self.shared.tracer.now_ns();
        }
        let handback = if *filled < out.len() {
            std::mem::replace(&mut out[*filled], record)
        } else {
            out.push(record);
            self.spare.pop().unwrap_or_else(empty_record)
        };
        *filled += 1;
        // Capacity covers every slot, so this never blocks; a send error
        // means the consumer died — run() aborts the slot on drain.
        let _ = self.pending_tx.send(Pending { slot, t_submit, record: handback, trace });
    }

    /// Resolve an expired submission at batch-cut time: the client gets
    /// [`ServeError::DeadlineExceeded`] now instead of a late answer,
    /// and the pipeline never pays its encode cost. Terminal outcome ⇒
    /// `completed` moves (idle-cut arithmetic); the record buffer joins
    /// the spare pool for future hand-backs. A sampled trace is dropped
    /// with the submission — expired requests never reach the consumer,
    /// so trace counts reconcile against completed − expired.
    fn expire(&mut self, sub: Submission) {
        let sh = &*self.shared;
        sh.stats.expired.fetch_add(1, Ordering::Relaxed);
        sh.stats.completed.fetch_add(1, Ordering::Relaxed);
        if let Some(rt) = sh.models.get(sub.model as usize) {
            rt.stats.expired.fetch_add(1, Ordering::Relaxed);
            rt.stats.completed.fetch_add(1, Ordering::Relaxed);
        }
        fail_slot(sh, sub.slot, ServeError::DeadlineExceeded);
        self.spare.push(sub.record);
    }
}

/// Is this submission past its deadline?
fn is_expired(sub: &Submission, now: Instant) -> bool {
    matches!(sub.deadline, Some(dl) if now >= dl)
}

impl RecordStream for RequestStream {
    fn next_record(&mut self) -> Option<Record> {
        // The coordinator only calls `next_batch_into`; this exists for
        // trait completeness and single-record callers.
        let mut out = Vec::new();
        if RecordStream::next_batch_into(self, &mut out, 1) == 0 {
            None
        } else {
            out.pop()
        }
    }

    /// Route the batch just cut to its tenant's encoder
    /// ([`run_pipeline_multi`]); set by the batch's first placed request.
    fn batch_model(&mut self) -> u32 {
        self.current_model
    }

    fn next_batch_into(&mut self, out: &mut Vec<Record>, n: usize) -> usize {
        // Fault injection: a one-shot batcher stall lets tests fill the
        // bounded submission queue to exact capacity deterministically.
        if let Some(stall) = self.stall_batcher.take() {
            std::thread::sleep(stall);
        }
        let sh = &*self.shared;
        let mut filled = 0usize;
        let mut depth_sampled = false;
        // Block for the batch's first request — or EOF at shutdown, or
        // on the coordinator's stop flag. The park is *bounded* (not an
        // untimed wait) because the stop flag is raised by scheduler
        // paths that cannot reach our condvar (worker panic unwind): the
        // reader must never be strandable by a dead pipeline.
        {
            let mut q = lock_unpoisoned(&sh.queue);
            loop {
                if !q.is_empty() && !depth_sampled {
                    // Sample depth *before* the batch drains the queue:
                    // under saturation this observes the full
                    // `queue_cap`, which the post-gather sample never
                    // could.
                    sh.stats.queue_depth.record(q.len() as u64);
                    depth_sampled = true;
                }
                if let Some(sub) = q.pop_front() {
                    sh.space_cv.notify_one();
                    drop(q);
                    // Deadline point #2: expired queue entries resolve
                    // here, before any encode cost.
                    if is_expired(&sub, Instant::now()) {
                        self.expire(sub);
                        q = lock_unpoisoned(&sh.queue);
                        continue;
                    }
                    // The first placed request fixes the batch's model;
                    // the gather loop below only admits queue entries
                    // routed to the same model.
                    self.current_model = sub.model;
                    self.place(out, &mut filled, sub);
                    break;
                }
                if sh.shutdown.load(Ordering::Acquire)
                    || sh.pipeline_stop.load(Ordering::Acquire)
                {
                    out.clear();
                    return 0;
                }
                let (guard, _timeout) =
                    wait_timeout_unpoisoned(&sh.nonempty_cv, q, Duration::from_millis(5));
                q = guard;
            }
        }
        // Adaptive gather: size, model, idle or deadline cut, measured
        // from the first take.
        let deadline = Instant::now() + self.max_delay;
        let mut idle_cut = false;
        let mut model_cut = false;
        {
            let mut q = lock_unpoisoned(&sh.queue);
            loop {
                if filled >= n {
                    break;
                }
                // Model cut: the queue front routes to a different
                // tenant, and encode batches must stay model-homogeneous
                // (worker asserts uniform widths; one store per batch).
                // Ship what we have — the front (expired or not) opens
                // the next batch.
                if matches!(q.front(), Some(s) if s.model != self.current_model) {
                    model_cut = true;
                    break;
                }
                if let Some(sub) = q.pop_front() {
                    sh.space_cv.notify_one();
                    drop(q);
                    if is_expired(&sub, Instant::now()) {
                        self.expire(sub);
                    } else {
                        self.place(out, &mut filled, sub);
                    }
                    q = lock_unpoisoned(&sh.queue);
                    continue;
                }
                if sh.shutdown.load(Ordering::Acquire)
                    || sh.pipeline_stop.load(Ordering::Acquire)
                {
                    break; // drain cut: ship what we have
                }
                // Idle cut: `submitted` moves only under this queue lock
                // and `completed ≤ submitted` always, so if everything
                // in flight is already in this batch, no new request can
                // arrive before these responses unblock their clients —
                // waiting out the deadline would be pure latency.
                let in_flight = sh
                    .stats
                    .submitted
                    .load(Ordering::Relaxed)
                    .saturating_sub(sh.stats.completed.load(Ordering::Relaxed));
                if in_flight <= filled as u64 {
                    idle_cut = true;
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _timeout) =
                    wait_timeout_unpoisoned(&sh.nonempty_cv, q, deadline - now);
                q = guard;
            }
        }
        sh.stats.batches.fetch_add(1, Ordering::Relaxed);
        if filled >= n {
            sh.stats.size_cuts.fetch_add(1, Ordering::Relaxed);
        } else if model_cut {
            sh.stats.model_cuts.fetch_add(1, Ordering::Relaxed);
        } else if idle_cut {
            sh.stats.idle_cuts.fetch_add(1, Ordering::Relaxed);
        } else {
            sh.stats.deadline_cuts.fetch_add(1, Ordering::Relaxed);
        }
        // Stash (don't drop) surplus spine records from a larger
        // previous batch — they become future hand-back buffers.
        while out.len() > filled {
            self.spare.push(out.pop().expect("len checked"));
        }
        filled
    }
}

/// The serving engine: owns the model registry and drives the encode
/// pipeline until shutdown.
pub struct Server {
    cfg: ServeCfg,
    registry: ModelRegistry,
    shared: Arc<Shared>,
    pending_tx: SyncSender<Pending>,
    pending_rx: Receiver<Pending>,
    /// Monitoring threads (metrics publisher, exporter listener) when
    /// publishing is enabled; stopped and joined by [`Server::run`] on
    /// shutdown.
    obs_threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Single-tenant server: wraps `cfg.encoder` + `store` +
    /// `cfg.precision` into a one-model registry (model 0, name
    /// `"default"`, no quota) — the PR-5/6 API, unchanged for existing
    /// callers.
    pub fn new(cfg: ServeCfg, store: AmStore) -> (Server, ServeHandle) {
        let mut registry = ModelRegistry::new();
        registry.register(
            "default",
            cfg.encoder.clone(),
            store,
            cfg.precision,
            TenantQuota::default(),
        );
        Server::with_registry(cfg, registry)
    }

    /// Multi-tenant server over a sealed [`ModelRegistry`]. The
    /// registry's per-model `EncoderCfg`/`AmStore`/`Precision` are
    /// authoritative; `cfg.encoder` and `cfg.precision` are ignored
    /// (they only matter to the [`Server::new`] single-tenant
    /// constructor). Everything else in `cfg` — batching, queue and
    /// slot capacities, admission policy, deadlines — applies
    /// server-wide.
    pub fn with_registry(cfg: ServeCfg, mut registry: ModelRegistry) -> (Server, ServeHandle) {
        assert!(!registry.is_empty(), "a server needs at least one registered model");
        let slots = cfg.slots.max(1);
        // Re-partition every tenant's store to the configured shard
        // count (registration starts at 1; the per-model clamp to the
        // class count lives in ShardedAmStore::new).
        let shards = cfg.am_shards.max(1);
        if shards > 1 {
            registry.models = registry
                .models
                .into_iter()
                .map(|mut m| {
                    m.store = ShardedAmStore::new(m.store.into_store(), shards);
                    m
                })
                .collect();
        }
        let models = registry
            .models
            .iter()
            .map(|m| ModelRuntime {
                name: m.name.clone(),
                expect_numeric: match m.encoder.num {
                    crate::coordinator::NumCfg::None => None,
                    _ => Some(m.encoder.n_numeric),
                },
                max_in_flight: m.quota.max_in_flight,
                in_flight: AtomicU64::new(0),
                bucket: m.quota.rate.map(|r| Mutex::new(TokenBucket::new(r))),
                stats: ModelStats::default(),
                shard_classes: m.store.shard_sizes(),
                shard_scans: (0..m.store.n_shards()).map(|_| AtomicU64::new(0)).collect(),
            })
            .collect();
        // The tracer is sized to the worker pool (rings are indexed by
        // the encoded batch's origin worker) and the registered model
        // count; a disabled config allocates nothing.
        let tracer = Arc::new(Tracer::new(
            cfg.obs,
            cfg.coordinator.n_workers.max(1),
            registry.models.len(),
        ));
        // Monitoring is on when there is anyone to tell: a scrape
        // address, or SLO objectives to judge.
        let hub = (cfg.metrics_addr.is_some() || cfg.slo.is_some()).then(|| {
            MetricsHub::new(PublishCfg {
                interval: cfg.publish_interval,
                slo: cfg.slo.unwrap_or_default(),
                configured_workers: cfg.coordinator.n_workers.max(1) as u64,
                queue_cap: cfg.queue_cap.max(1) as u64,
            })
        });
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::with_capacity(cfg.queue_cap.max(1))),
            nonempty_cv: Condvar::new(),
            space_cv: Condvar::new(),
            slot_cv: Condvar::new(),
            free_slots: Mutex::new((0..slots).rev().collect()),
            slots: (0..slots)
                .map(|_| Slot { state: Mutex::new(SlotState::Empty), cv: Condvar::new() })
                .collect(),
            shutdown: AtomicBool::new(false),
            pipeline_stop: Arc::new(AtomicBool::new(false)),
            models,
            stats: ServeStats::default(),
            queue_cap: cfg.queue_cap.max(1),
            admission: cfg.admission,
            default_deadline: cfg.default_deadline,
            jitter: AtomicU64::new(registry.models[0].encoder.seed),
            tracer,
            hub,
        });
        // One pending per in-flight request; each holds a slot, so
        // `slots` bounds the channel and sends never block.
        let (pending_tx, pending_rx) = sync_channel::<Pending>(slots + 1);
        let handle = ServeHandle { shared: Arc::clone(&shared) };
        // Monitoring threads start now so the exporter answers (and the
        // publisher baselines its first sample) before any traffic;
        // `run()` stops and joins them after the pipeline drains.
        let mut obs_threads = Vec::new();
        if let Some(hub) = &shared.hub {
            obs_threads.push(spawn_publisher(Arc::clone(hub), handle.clone()));
            if let Some(addr) = &cfg.metrics_addr {
                let listener = spawn_listener(addr, Arc::clone(hub), handle.clone())
                    .unwrap_or_else(|e| panic!("bind metrics listener on {addr}: {e}"));
                obs_threads.push(listener);
            }
        }
        (Server { cfg, registry, shared, pending_tx, pending_rx, obs_threads }, handle)
    }

    /// Run the serve loop on the current thread until
    /// [`ServeHandle::shutdown`]; queued requests drain first. Returns
    /// the pipeline stats (spawn this on a dedicated thread and keep the
    /// [`ServeHandle`] for clients).
    pub fn run(self) -> Arc<PipelineStats> {
        let Server { cfg, registry, shared, pending_tx, pending_rx, obs_threads } = self;
        let stream = RequestStream {
            shared: Arc::clone(&shared),
            pending_tx,
            max_delay: cfg.max_batch_delay,
            spare: Vec::new(),
            current_model: 0,
            stall_batcher: cfg.coordinator.fault.stall_batcher,
        };
        // Whatever way this function exits — clean drain, or a panic
        // propagating out of `run_pipeline` after a worker died — every
        // parked client must be released. The guard rejects future
        // submissions and aborts all unanswered slots on drop.
        let _abort_guard = AbortOnDrop(Arc::clone(&shared));
        // Serving pipelines run until shutdown, never retain raw records,
        // score in the consumer below, and expose the scheduler's stop
        // flag so the batcher's park stays bounded (serve owns the flag,
        // like the two overrides).
        let coord = CoordinatorCfg {
            keep_records: false,
            max_records: None,
            stop_flag: Some(Arc::clone(&shared.pipeline_stop)),
            // Always wired: the tracer carries the live-worker gauge the
            // SLO watchdog's liveness check reads even when stage-span
            // sampling is off (the coordinator gates its per-batch
            // stamping on `Tracer::enabled` separately).
            obs: Some(Arc::clone(&shared.tracer)),
            ..cfg.coordinator.clone()
        };
        // One worker pool, every tenant: the registry's encoder configs
        // go to the coordinator (workers build/cache encoders lazily
        // per model), and the consumer routes each model-homogeneous
        // batch to its tenant's store by `EncodedBatch::model`.
        let encoder_cfgs: Vec<EncoderCfg> =
            registry.models.iter().map(|m| m.encoder.clone()).collect();
        let mut scratch = ShardScratch::new();
        let mut top1s: Vec<(u32, f32)> = Vec::new();
        let stats = run_pipeline_multi(stream, &encoder_cfgs, &coord, |batch| {
            let entry = &registry.models[batch.model as usize];
            let runtime = &shared.models[batch.model as usize];
            let mstats = &runtime.stats;
            let tracer = &shared.tracer;
            if batch.failed {
                // The encode worker panicked on this batch (and was
                // respawned in place). `labels` still holds one entry
                // per request, so exactly that many pendings pair with
                // it: fail each explicitly — the positional pairing for
                // every later batch stays exact.
                let t_fail = if tracer.enabled() { tracer.now_ns() } else { 0 };
                for _ in 0..batch.labels.len() {
                    let Ok(pending) = pending_rx.recv() else {
                        return false;
                    };
                    if let Some(ctx) = pending.trace {
                        // Failed requests never reach the scanner: record
                        // a zero-width scan span at consumer pickup so the
                        // chain still telescopes, marked `failed` (the
                        // tracer keeps these out of the stage histograms).
                        tracer.record(trace_record(
                            ctx,
                            batch,
                            (t_fail, t_fail),
                            tracer.now_ns(),
                            true,
                        ));
                    }
                    shared.stats.failed.fetch_add(1, Ordering::Relaxed);
                    shared.stats.completed.fetch_add(1, Ordering::Relaxed);
                    mstats.failed.fetch_add(1, Ordering::Relaxed);
                    mstats.completed.fetch_add(1, Ordering::Relaxed);
                    fail_slot(&shared, pending.slot, ServeError::Internal);
                }
                return true;
            }
            // One sharded scan for the whole model-homogeneous batch
            // (the scorer fan-out amortizes over every request in it);
            // results are exactly equal to per-query single-scan top1.
            let t_scan_start = if tracer.enabled() { tracer.now_ns() } else { 0 };
            entry.store.top1_batch_into(
                &batch.encodings,
                entry.precision,
                &mut scratch,
                &mut top1s,
            );
            let t_scan_end = if tracer.enabled() { tracer.now_ns() } else { 0 };
            // Every scored request scanned every shard of this model.
            for scans in runtime.shard_scans.iter() {
                scans.fetch_add(batch.encodings.len() as u64, Ordering::Relaxed);
            }
            for &(top_class, score) in top1s.iter() {
                let Ok(pending) = pending_rx.recv() else {
                    // Stream half dropped mid-batch: nothing left to pair.
                    return false;
                };
                if let Some(ctx) = pending.trace {
                    // The completion edge is stamped BEFORE the latency
                    // read below, so a trace's stage sum never exceeds
                    // the latency the histograms record for it.
                    tracer.record(trace_record(
                        ctx,
                        batch,
                        (t_scan_start, t_scan_end),
                        tracer.now_ns(),
                        false,
                    ));
                }
                let latency = pending.t_submit.elapsed();
                shared.stats.latency_ns.record(latency.as_nanos() as u64);
                shared.stats.completed.fetch_add(1, Ordering::Relaxed);
                mstats.latency_ns.record(latency.as_nanos() as u64);
                mstats.completed.fetch_add(1, Ordering::Relaxed);
                let slot = &shared.slots[pending.slot];
                let mut st = lock_unpoisoned(&slot.state);
                *st = SlotState::Done(Response {
                    top_class,
                    score,
                    latency,
                    record: pending.record,
                });
                slot.cv.notify_one();
            }
            true
        });
        // Stop the monitoring threads and wait them out: the publisher
        // takes one final closing sample (end-of-run deltas stay
        // observable), the listener finishes at most one in-flight
        // scrape. On the panic path these are not joined — AbortOnDrop
        // still stops the hub, so both exit promptly on their own.
        if let Some(hub) = &shared.hub {
            hub.stop();
        }
        for t in obs_threads {
            let _ = t.join();
        }
        stats
        // _abort_guard drops here (and on any panic path above): see
        // AbortOnDrop.
    }
}

/// Releases every parked client when [`Server::run`] exits by ANY path:
/// reject future submissions, drop still-queued requests, and mark every
/// unanswered slot `Aborted`. On a clean shutdown drain this is a no-op
/// beyond the flag (all slots are `Empty` in the free list, and stale
/// `Aborted` states are unreachable because `classify` rejects at slot
/// acquisition once `shutdown` is set); after an abnormal termination —
/// `run_pipeline` panicking on a dead worker — it is what turns a
/// wedged-forever client into a clean [`ServeError::Aborted`].
struct AbortOnDrop(Arc<Shared>);

impl Drop for AbortOnDrop {
    fn drop(&mut self) {
        let sh = &*self.0;
        sh.shutdown.store(true, Ordering::Release);
        // Signal the monitoring threads too (idempotent — run() already
        // did on the clean path): after an abnormal exit nobody joins
        // them, so the stop flag is what keeps them from spinning on.
        if let Some(hub) = &sh.hub {
            hub.stop();
        }
        {
            let mut q = lock_unpoisoned(&sh.queue);
            q.clear();
            sh.nonempty_cv.notify_all();
            sh.space_cv.notify_all();
        }
        // Every slot not currently answered is either free (harmless to
        // mark: shutdown already gates acquisition) or awaited by a
        // parked client that will now observe the abort.
        for slot in &sh.slots {
            let mut st = lock_unpoisoned(&slot.state);
            if matches!(*st, SlotState::Empty) {
                *st = SlotState::Failed(ServeError::Aborted);
            }
            drop(st);
            slot.cv.notify_one();
        }
        // Notify under the free-slots lock so a client between its
        // shutdown check and its park cannot miss the wakeup.
        let guard = lock_unpoisoned(&sh.free_slots);
        sh.slot_cv.notify_all();
        drop(guard);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CatCfg, NumCfg};
    use crate::data::synthetic::SyntheticConfig;
    use crate::data::SyntheticStream;
    use crate::encoding::BundleMethod;
    use std::thread;

    fn small_encoder(seed: u64) -> EncoderCfg {
        EncoderCfg {
            cat: CatCfg::Bloom { d: 256, k: 2 },
            num: NumCfg::None,
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed,
        }
    }

    fn small_store(d: usize) -> AmStore {
        // Deterministic 2-class store; scores differ for any non-empty code.
        let mut rng = crate::util::rng::Rng::new(99);
        let rows: Vec<Vec<f32>> =
            (0..2).map(|_| (0..d).map(|_| rng.normal_f32()).collect()).collect();
        AmStore::from_prototypes(d, &rows, None)
    }

    fn serve_round_trip(n_clients: usize, per_client: usize) -> ServeSnapshot {
        let cfg = ServeCfg {
            max_batch_delay: Duration::from_micros(200),
            queue_cap: 64,
            slots: 32,
            ..ServeCfg::new(small_encoder(5))
        };
        let store = small_store(256);
        let (server, handle) = Server::new(cfg, store);
        let server_thread = thread::spawn(move || server.run());
        let clients: Vec<_> = (0..n_clients)
            .map(|c| {
                let h = handle.clone();
                thread::spawn(move || {
                    let mut stream =
                        SyntheticStream::new(SyntheticConfig::sampled(1000 + c as u64));
                    let mut rec = stream.next_record().unwrap();
                    for _ in 0..per_client {
                        let resp = h.classify(rec).expect("classify");
                        assert!(resp.top_class < 2);
                        rec = resp.record;
                        if !stream.refill_record(&mut rec) {
                            panic!("synthetic stream ended");
                        }
                    }
                })
            })
            .collect();
        for c in clients {
            c.join().expect("client");
        }
        handle.shutdown();
        server_thread.join().expect("server");
        handle.stats()
    }

    #[test]
    fn single_client_round_trips() {
        let snap = serve_round_trip(1, 50);
        assert_eq!(snap.completed, 50);
        assert_eq!(snap.submitted, 50);
        assert!(snap.latency_ns.count == 50);
        assert!(snap.batches >= 1);
    }

    #[test]
    fn concurrent_clients_all_complete() {
        let snap = serve_round_trip(6, 40);
        assert_eq!(snap.completed, 240);
        assert!(snap.latency_ns.p99 >= snap.latency_ns.p50);
    }

    #[test]
    fn shutdown_rejects_new_submissions() {
        let cfg = ServeCfg::new(small_encoder(6));
        let store = small_store(256);
        let (server, handle) = Server::new(cfg, store);
        let t = thread::spawn(move || server.run());
        handle.shutdown();
        t.join().unwrap();
        let mut s = SyntheticStream::new(SyntheticConfig::sampled(7));
        let rec = s.next_record().unwrap();
        assert_eq!(handle.classify(rec).unwrap_err(), ServeError::Shutdown);
        assert_eq!(handle.stats().rejected, 1);
    }

    #[test]
    fn lone_requests_close_by_idle_cut_not_deadline() {
        // One closed-loop client with a large batch size and a deadline
        // long enough that paying it per request would be obvious: the
        // idle cut must ship each 1-request batch immediately (nothing
        // else is in flight), and every batch is accounted to exactly
        // one cut kind.
        let cfg = ServeCfg {
            coordinator: CoordinatorCfg { batch_size: 64, n_workers: 1, ..Default::default() },
            max_batch_delay: Duration::from_millis(200),
            ..ServeCfg::new(small_encoder(8))
        };
        let (server, handle) = Server::new(cfg, small_store(256));
        let t = thread::spawn(move || server.run());
        let mut s = SyntheticStream::new(SyntheticConfig::sampled(9));
        let mut rec = s.next_record().unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..10 {
            rec = handle.classify(rec).unwrap().record;
            s.refill_record(&mut rec);
        }
        let elapsed = t0.elapsed();
        handle.shutdown();
        t.join().unwrap();
        let snap = handle.stats();
        assert_eq!(snap.completed, 10);
        assert!(snap.idle_cuts >= 1, "{snap:?}");
        assert_eq!(
            snap.batches,
            snap.size_cuts + snap.deadline_cuts + snap.idle_cuts + snap.model_cuts
        );
        // 10 sequential requests must come nowhere near 10 deadlines.
        assert!(elapsed < Duration::from_millis(1000), "deadline paid per request: {elapsed:?}");
    }

    #[test]
    fn ragged_numeric_width_rejected_before_batching() {
        // Micro-batches mix clients, and the encode workers hard-assert
        // uniform numeric widths — a malformed record must be rejected
        // at classify (and must NOT wedge the server for anyone else).
        let enc = EncoderCfg {
            cat: CatCfg::Bloom { d: 128, k: 2 },
            num: NumCfg::Sjlt { d: 128, k: 2 },
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 12,
        };
        let (server, handle) = Server::new(ServeCfg::new(enc), small_store(256));
        let t = thread::spawn(move || server.run());
        let mut s = SyntheticStream::new(SyntheticConfig::sampled(13));
        let good = s.next_record().unwrap();
        let mut bad = good.clone();
        bad.numeric.pop();
        assert_eq!(
            handle.classify(bad).unwrap_err(),
            ServeError::InvalidNumericWidth { got: 12, want: 13 }
        );
        // The server is still healthy for well-formed traffic.
        let resp = handle.classify(good).expect("good record must serve");
        assert!(resp.top_class < 2);
        handle.shutdown();
        t.join().unwrap();
        let snap = handle.stats();
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn registry_assigns_sequential_ids() {
        let mut reg = ModelRegistry::new();
        assert!(reg.is_empty());
        let a = reg.register(
            "a",
            small_encoder(1),
            small_store(256),
            Precision::F32,
            TenantQuota::default(),
        );
        let b = reg.register(
            "b",
            small_encoder(2),
            small_store(256),
            Precision::Binary,
            TenantQuota { max_in_flight: Some(4), rate: None },
        );
        assert_eq!(a, ModelId(0));
        assert_eq!(b, ModelId(1));
        assert_eq!(reg.len(), 2);
    }

    #[test]
    #[should_panic(expected = "encoder output dim must match")]
    fn registry_rejects_dim_mismatch() {
        let mut reg = ModelRegistry::new();
        reg.register(
            "bad",
            small_encoder(1), // out_dim 256
            small_store(128),
            Precision::F32,
            TenantQuota::default(),
        );
    }

    #[test]
    fn unknown_model_rejected_without_touching_queue() {
        let (server, handle) = Server::new(ServeCfg::new(small_encoder(14)), small_store(256));
        let t = thread::spawn(move || server.run());
        let mut s = SyntheticStream::new(SyntheticConfig::sampled(15));
        let rec = s.next_record().unwrap();
        let err = handle
            .classify_with(rec, RequestOpts { model: ModelId(7), ..RequestOpts::default() })
            .unwrap_err();
        assert_eq!(err, ServeError::UnknownModel { model: ModelId(7) });
        handle.shutdown();
        t.join().unwrap();
        let snap = handle.stats();
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.submitted, 0);
        // The registered model's own counters never moved.
        assert_eq!(snap.models.len(), 1);
        assert_eq!(snap.models[0].rejected, 0);
    }

    #[test]
    fn token_bucket_refills_at_rate() {
        let mut b = TokenBucket::new(RateLimit { rps: 1000.0, burst: 2.0 });
        let t0 = Instant::now();
        assert!(b.try_take(t0));
        assert!(b.try_take(t0));
        assert!(!b.try_take(t0), "burst of 2 exhausted");
        // 5ms at 1000 rps refills 5 tokens, capped at burst (2).
        let t1 = t0 + Duration::from_millis(5);
        assert!(b.try_take(t1));
        assert!(b.try_take(t1));
        assert!(!b.try_take(t1), "refill is capped at burst");
    }

    #[test]
    fn in_flight_quota_sheds_excess_fail_fast() {
        // One model capped at 0 in-flight: every submission is
        // QuotaExceeded before touching slots or the queue, even under
        // the Block admission policy.
        let mut reg = ModelRegistry::new();
        reg.register(
            "capped",
            small_encoder(16),
            small_store(256),
            Precision::F32,
            TenantQuota { max_in_flight: Some(0), rate: None },
        );
        let (server, handle) =
            Server::with_registry(ServeCfg::new(small_encoder(16)), reg);
        let t = thread::spawn(move || server.run());
        let mut s = SyntheticStream::new(SyntheticConfig::sampled(17));
        for _ in 0..5 {
            let rec = s.next_record().unwrap();
            assert_eq!(handle.classify(rec).unwrap_err(), ServeError::QuotaExceeded);
        }
        handle.shutdown();
        t.join().unwrap();
        let snap = handle.stats();
        assert_eq!(snap.quota_shed, 5);
        assert_eq!(snap.submitted, 0);
        assert_eq!(snap.models[0].quota_shed, 5);
        assert_eq!(snap.models[0].in_flight, 0, "guard must refund the gauge");
        assert!(snap.shed_rate() > 0.99);
    }

    #[test]
    fn scores_match_offline_store_lookup() {
        // Every response's (class, score) must equal an offline lookup
        // of the same record — the correlation correctness check.
        let enc_cfg = small_encoder(10);
        let store = small_store(256);
        let offline_store = store.clone();
        let cfg = ServeCfg {
            coordinator: CoordinatorCfg {
                batch_size: 8,
                n_workers: 3,
                queue_depth: 2,
                ..Default::default()
            },
            max_batch_delay: Duration::from_micros(100),
            ..ServeCfg::new(enc_cfg.clone())
        };
        let (server, handle) = Server::new(cfg, store);
        let t = thread::spawn(move || server.run());
        let mut offline_enc = enc_cfg.build();
        let mut scratch = AmScratch::new();
        let mut s = SyntheticStream::new(SyntheticConfig::sampled(11));
        for _ in 0..200 {
            let rec = s.next_record().unwrap();
            let code = offline_enc.encode(&rec);
            let (want_class, want_score) =
                offline_store.top1(&code, Precision::F32, &mut scratch);
            let resp = handle.classify(rec).unwrap();
            assert_eq!(resp.top_class, want_class);
            assert_eq!(resp.score, want_score);
        }
        handle.shutdown();
        t.join().unwrap();
    }

    #[test]
    fn sharded_consumer_matches_single_scan() {
        // With am_shards > 1 the consumer scores through the scoped
        // scorer pool; every response must still equal the offline
        // single-thread scan, and the per-shard scan counters must each
        // equal the scored-request count.
        let enc_cfg = small_encoder(21);
        let mut rng = crate::util::rng::Rng::new(77);
        let rows: Vec<Vec<f32>> =
            (0..10).map(|_| (0..256).map(|_| rng.normal_f32()).collect()).collect();
        let store = AmStore::from_prototypes(256, &rows, None);
        let offline_store = store.clone();
        let cfg = ServeCfg {
            coordinator: CoordinatorCfg {
                batch_size: 8,
                n_workers: 2,
                queue_depth: 2,
                ..Default::default()
            },
            am_shards: 3,
            ..ServeCfg::new(enc_cfg.clone())
        };
        let (server, handle) = Server::new(cfg, store);
        let t = thread::spawn(move || server.run());
        let mut offline_enc = enc_cfg.build();
        let mut scratch = AmScratch::new();
        let mut s = SyntheticStream::new(SyntheticConfig::sampled(22));
        const N: u64 = 100;
        for _ in 0..N {
            let rec = s.next_record().unwrap();
            let code = offline_enc.encode(&rec);
            let (want_class, want_score) =
                offline_store.top1(&code, Precision::F32, &mut scratch);
            let resp = handle.classify(rec).unwrap();
            assert_eq!(resp.top_class, want_class);
            assert_eq!(resp.score, want_score);
        }
        handle.shutdown();
        t.join().unwrap();
        let snap = handle.stats();
        let shards = &snap.models[0].shards;
        assert_eq!(shards.len(), 3);
        // 10 classes over 3 shards: 4 + 3 + 3, every shard scanned once
        // per scored request.
        assert_eq!(shards.iter().map(|s| s.classes).collect::<Vec<_>>(), vec![4, 3, 3]);
        for sh in shards {
            assert_eq!(sh.scans, N);
        }
    }
}
