//! Lock-free log-bucketed histogram for serve-path latencies and queue
//! depths (HdrHistogram-lite; the real thing is not vendored offline).
//!
//! Values map to power-of-two octaves subdivided into 8 linear
//! sub-buckets, so quantile estimates carry ≤ ~6% relative error — ample
//! for p50/p99 latency reporting — while `record` is one atomic add on a
//! preallocated table (no allocation, no locks: safe to call from every
//! pipeline thread on the request hot path). Exact count / sum / max /
//! min ride alongside in dedicated atomics.

use std::sync::atomic::{AtomicU64, Ordering};

/// log2(sub-buckets per octave).
const SUB_BITS: u32 = 3;
const SUB: u64 = 1 << SUB_BITS;
/// Bucket table size: values 0..SUB exact, then (64 − SUB_BITS) octaves
/// of SUB sub-buckets each.
const BUCKETS: usize = (SUB + (64 - SUB_BITS as u64) * SUB) as usize;

fn index_of(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as u64; // >= SUB_BITS
    let shift = msb - SUB_BITS as u64;
    let sub = (v >> shift) & (SUB - 1);
    ((msb - SUB_BITS as u64) * SUB + SUB + sub) as usize
}

/// Lower edge of bucket `idx` (its representative value is the
/// midpoint of [lower, next lower)).
fn lower_of(idx: usize) -> u64 {
    if idx < SUB as usize {
        return idx as u64;
    }
    let octave = (idx as u64 - SUB) / SUB;
    let sub = (idx as u64 - SUB) % SUB;
    (SUB + sub) << octave
}

fn representative_of(idx: usize) -> u64 {
    if idx < SUB as usize {
        return idx as u64;
    }
    let octave = (idx as u64 - SUB) / SUB;
    let width = 1u64 << octave;
    lower_of(idx) + width / 2
}

/// Concurrent histogram; `record` from any thread, `snapshot` whenever.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    min: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[index_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        // Saturate rather than wrap: latencies near u64::MAX (absurd but
        // representable — e.g. a poisoned clock) must pin the running sum
        // at the ceiling, not wrap it to a small, plausible-looking mean.
        let _ = self
            .sum
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |s| Some(s.saturating_add(v)));
        self.max.fetch_max(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Fold `other`'s counts into `self`. Lock-free and safe against
    /// concurrent `record`s on either side (each field merges with the
    /// same atomics `record` uses), though the intended pattern is
    /// quiescent aggregation: per-worker histograms written by one
    /// thread each, merged at snapshot time (see `obs::Tracer`) — which
    /// keeps the record hot path free of cross-worker contention.
    pub fn merge(&self, other: &Histogram) {
        for (b, ob) in self.buckets.iter().zip(&other.buckets) {
            let v = ob.load(Ordering::Relaxed);
            if v != 0 {
                b.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        let os = other.sum.load(Ordering::Relaxed);
        let _ = self
            .sum
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |s| Some(s.saturating_add(os)));
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
        // An empty `other` holds the init sentinel u64::MAX, which
        // fetch_min ignores by construction.
        self.min.fetch_min(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Raw bucket-level capture for windowed diffs
    /// ([`HistBuckets::diff`]): every bucket count plus the running
    /// sum, loaded once each. Allocates (one `Vec` per capture) — call
    /// it from aggregation threads (the metrics publisher), never from
    /// the request hot path.
    pub fn buckets(&self) -> HistBuckets {
        HistBuckets {
            counts: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }

    /// Point-in-time summary. Quantiles are bucket representatives
    /// (≤ ~6% relative error); count/sum/max/min are exact.
    pub fn snapshot(&self) -> HistSnapshot {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count: u64 = counts.iter().sum();
        let quantile = |p: f64| -> u64 {
            if count == 0 {
                return 0;
            }
            let target = ((p * count as f64).ceil() as u64).clamp(1, count);
            let mut seen = 0u64;
            for (i, &c) in counts.iter().enumerate() {
                seen += c;
                if seen >= target {
                    return representative_of(i);
                }
            }
            representative_of(counts.len() - 1)
        };
        let sum = self.sum.load(Ordering::Relaxed);
        HistSnapshot {
            count,
            mean: if count == 0 { 0.0 } else { sum as f64 / count as f64 },
            p50: quantile(0.50),
            p90: quantile(0.90),
            p99: quantile(0.99),
            max: if count == 0 { 0 } else { self.max.load(Ordering::Relaxed) },
            min: if count == 0 { 0 } else { self.min.load(Ordering::Relaxed) },
        }
    }
}

/// Summary of a [`Histogram`] at one instant.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HistSnapshot {
    pub count: u64,
    pub mean: f64,
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
    pub max: u64,
    pub min: u64,
}

/// Raw bucket counts of a [`Histogram`] at one instant
/// ([`Histogram::buckets`]). Histograms are monotone (counts only ever
/// grow), so two captures of the same histogram subtract exactly:
/// [`HistBuckets::diff`] is the distribution of precisely the samples
/// recorded between the captures — the windowed-quantile primitive
/// behind `obs::export`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistBuckets {
    counts: Vec<u64>,
    sum: u64,
}

impl HistBuckets {
    /// The all-zero capture: `newer.diff(&HistBuckets::empty())` equals
    /// `newer`'s own summary. Also the placeholder when a window has no
    /// earlier capture yet.
    pub fn empty() -> HistBuckets {
        HistBuckets::default()
    }

    /// Samples captured (sum of bucket counts).
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Summarize the samples recorded after `older` was captured and
    /// before `self` was. Quantiles are bucket representatives as in
    /// [`Histogram::snapshot`]; windowed `max`/`min` are the
    /// highest/lowest *occupied-bucket* representatives (the exact
    /// extremes of a sub-window are not recoverable from monotone
    /// captures). Per-bucket subtraction saturates, so a capture pair
    /// torn by concurrent `record`s can skew a window by at most the
    /// in-flight samples — never underflow.
    pub fn diff(&self, older: &HistBuckets) -> HistSnapshot {
        let n = self.counts.len().max(older.counts.len());
        let delta = |i: usize| -> u64 {
            let new = self.counts.get(i).copied().unwrap_or(0);
            let old = older.counts.get(i).copied().unwrap_or(0);
            new.saturating_sub(old)
        };
        let count: u64 = (0..n).map(delta).sum();
        if count == 0 {
            return HistSnapshot { count: 0, mean: 0.0, p50: 0, p90: 0, p99: 0, max: 0, min: 0 };
        }
        let sum = self.sum.saturating_sub(older.sum);
        let quantile = |p: f64| -> u64 {
            let target = ((p * count as f64).ceil() as u64).clamp(1, count);
            let mut seen = 0u64;
            for i in 0..n {
                seen += delta(i);
                if seen >= target {
                    return representative_of(i);
                }
            }
            representative_of(n - 1)
        };
        let mut min_idx = usize::MAX;
        let mut max_idx = 0usize;
        for i in 0..n {
            if delta(i) > 0 {
                if min_idx == usize::MAX {
                    min_idx = i;
                }
                max_idx = i;
            }
        }
        HistSnapshot {
            count,
            mean: sum as f64 / count as f64,
            p50: quantile(0.50),
            p90: quantile(0.90),
            p99: quantile(0.99),
            max: representative_of(max_idx),
            min: representative_of(min_idx),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_is_monotone_and_contiguous() {
        let mut prev = 0usize;
        let mut v = 0u64;
        while v < 1 << 20 {
            let i = index_of(v);
            // Monotone, and the (≤ bucket-width) step never skips more
            // than one boundary.
            assert!(i >= prev && i <= prev + 2, "v={v}: {prev} -> {i}");
            assert!(lower_of(i) <= v, "v={v} idx={i} lower={}", lower_of(i));
            prev = i;
            v += 1 + v / 16; // dense near 0, sparse later
        }
        assert!(index_of(u64::MAX) < BUCKETS);
    }

    #[test]
    fn small_values_exact() {
        for v in 0..SUB {
            assert_eq!(representative_of(index_of(v)), v);
        }
    }

    #[test]
    fn quantiles_approximate_uniform_ramp() {
        let h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 10_000);
        assert_eq!(s.max, 10_000);
        assert_eq!(s.min, 1);
        let rel = |got: u64, want: f64| (got as f64 - want).abs() / want;
        assert!(rel(s.p50, 5_000.0) < 0.10, "p50={}", s.p50);
        assert!(rel(s.p99, 9_900.0) < 0.10, "p99={}", s.p99);
        assert!((s.mean - 5_000.5).abs() < 1.0);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, 0);
        assert_eq!(s.max, 0);
        assert_eq!(s.min, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn single_bucket_degenerate_distribution() {
        // All samples identical: every quantile must name that bucket's
        // representative, and the exact stats must be exact.
        let h = Histogram::new();
        for _ in 0..100 {
            h.record(7_777);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.min, 7_777);
        assert_eq!(s.max, 7_777);
        assert_eq!(s.p50, s.p90);
        assert_eq!(s.p90, s.p99);
        assert_eq!(s.p50, representative_of(index_of(7_777)));
        assert!((s.mean - 7_777.0).abs() < 1e-9);
    }

    #[test]
    fn overflow_values_saturate_sum_not_wrap() {
        // Two u64::MAX samples would wrap a naive sum to ~u64::MAX−1 and
        // report a plausible-looking tiny mean; the saturating sum must
        // pin at the ceiling instead, and indexing must stay in bounds.
        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX);
        h.record(0);
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.max, u64::MAX);
        assert_eq!(s.min, 0);
        // Saturated sum / 3: enormous, not ~half of one sample.
        assert!(s.mean > u64::MAX as f64 / 4.0, "mean wrapped: {}", s.mean);
        assert!(index_of(u64::MAX) < BUCKETS);
        assert!(s.p99 <= u64::MAX);
    }

    #[test]
    fn concurrent_record_and_snapshot_are_consistent() {
        // Snapshots taken *while* writers run must stay internally sane
        // (count never exceeds what's been written, quantiles in range);
        // the final snapshot must be exact.
        let h = std::sync::Arc::new(Histogram::new());
        let writers: Vec<_> = (0..2)
            .map(|t| {
                let h = std::sync::Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..2_000u64 {
                        h.record(t * 2_000 + i + 1);
                    }
                })
            })
            .collect();
        let reader = {
            let h = std::sync::Arc::clone(&h);
            std::thread::spawn(move || {
                for _ in 0..50 {
                    let s = h.snapshot();
                    assert!(s.count <= 4_000);
                    if s.count > 0 {
                        assert!(s.min >= 1 && s.max <= 4_000);
                        assert!(s.p50 <= s.p99.max(representative_of(index_of(4_000))));
                    }
                    std::thread::yield_now();
                }
            })
        };
        for t in writers {
            t.join().unwrap();
        }
        reader.join().unwrap();
        assert_eq!(h.snapshot().count, 4_000);
    }

    #[test]
    fn merge_equals_recording_into_one() {
        // merge(a, b) must be indistinguishable from having recorded
        // both sample sets into a single histogram.
        let a = Histogram::new();
        let b = Histogram::new();
        let all = Histogram::new();
        for v in [3u64, 17, 900, 12_345] {
            a.record(v);
            all.record(v);
        }
        for v in [1u64, 40, 7_777_777] {
            b.record(v);
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.snapshot(), all.snapshot());
    }

    #[test]
    fn merge_with_empty_is_identity_both_ways() {
        let a = Histogram::new();
        for v in [5u64, 500] {
            a.record(v);
        }
        let before = a.snapshot();
        a.merge(&Histogram::new());
        // Empty-other: the u64::MAX min sentinel must not leak in.
        assert_eq!(a.snapshot(), before);
        let empty = Histogram::new();
        empty.merge(&a);
        assert_eq!(empty.snapshot(), before);
    }

    #[test]
    fn merge_saturates_sum() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(u64::MAX);
        b.record(u64::MAX);
        a.merge(&b);
        let s = a.snapshot();
        assert_eq!(s.count, 2);
        assert!(s.mean > u64::MAX as f64 / 4.0, "sum wrapped: {}", s.mean);
    }

    #[test]
    fn bucket_diff_is_exactly_the_window_samples() {
        // Capture, record more, capture again: the diff must equal a
        // fresh histogram holding only the in-between samples.
        let h = Histogram::new();
        for v in [10u64, 200, 3_000] {
            h.record(v);
        }
        let older = h.buckets();
        let window_only = Histogram::new();
        for v in [5u64, 5, 70_000, 123, 123, 123] {
            h.record(v);
            window_only.record(v);
        }
        let d = h.buckets().diff(&older);
        let want = window_only.snapshot();
        assert_eq!(d.count, want.count);
        assert_eq!(d.p50, want.p50);
        assert_eq!(d.p90, want.p90);
        assert_eq!(d.p99, want.p99);
        assert!((d.mean - want.mean).abs() < 1e-9);
        // Windowed extremes carry bucket resolution, not exact values.
        assert_eq!(d.min, representative_of(index_of(5)));
        assert_eq!(d.max, representative_of(index_of(70_000)));
    }

    #[test]
    fn bucket_diff_empty_window_is_zeroed() {
        let h = Histogram::new();
        for v in [1u64, 2, 3] {
            h.record(v);
        }
        let cap = h.buckets();
        let d = cap.diff(&cap.clone());
        assert_eq!(d.count, 0);
        assert_eq!(d.mean, 0.0);
        assert_eq!((d.p50, d.p99, d.min, d.max), (0, 0, 0, 0));
        // Diff against the empty capture recovers the full summary.
        let full = cap.diff(&HistBuckets::empty());
        assert_eq!(full.count, 3);
        assert_eq!(full.p50, h.snapshot().p50);
    }

    #[test]
    fn bucket_diff_never_underflows_on_swapped_captures() {
        // Swapped operand order (older.diff(&newer)) models the worst
        // torn-capture case: every delta saturates to zero instead of
        // wrapping to ~u64::MAX counts.
        let h = Histogram::new();
        h.record(42);
        let older = h.buckets();
        h.record(42);
        let newer = h.buckets();
        let d = older.diff(&newer);
        assert_eq!(d.count, 0);
        assert_eq!(d.p99, 0);
    }

    #[test]
    fn concurrent_records_all_land() {
        let h = std::sync::Arc::new(Histogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = std::sync::Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1000 + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.snapshot().count, 4000);
    }
}
