//! Closed-loop synthetic load generation for the serving subsystem —
//! shared by the `serve_bench` binary and `perf::encode_snapshot` so
//! `BENCH_encode.json` carries serve-path latency distributions.
//!
//! Closed loop: each client thread submits one request, blocks for its
//! response, rotates the returned record buffer and submits again —
//! offered load self-regulates to the server's capacity (no coordinated
//! omission from a fixed-rate script outrunning the server), and
//! `clients` is the concurrency knob.

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::am::AmStore;
use crate::coordinator::StatsSnapshot;
use crate::data::synthetic::SyntheticConfig;
use crate::data::{RecordStream, SyntheticStream};
use crate::serve::{ServeCfg, ServeSnapshot, Server};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct LoadCfg {
    /// Concurrent closed-loop client threads.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: u64,
    /// The synthetic record distribution clients draw from (each client
    /// salts its own stream so requests differ across clients).
    pub data: SyntheticConfig,
}

impl LoadCfg {
    pub fn quick(seed: u64) -> LoadCfg {
        LoadCfg {
            clients: 4,
            requests_per_client: 1_000,
            data: SyntheticConfig::sampled(seed),
        }
    }
}

#[derive(Clone, Debug)]
pub struct ServeBenchReport {
    pub total_requests: u64,
    pub wall: Duration,
    pub throughput_rps: f64,
    pub serve: ServeSnapshot,
    pub pipeline: StatsSnapshot,
}

impl ServeBenchReport {
    /// Machine-readable form for `BENCH_encode.json`.
    pub fn to_json(&self) -> Json {
        let hist = |h: &crate::serve::HistSnapshot| {
            Json::obj(vec![
                ("count", Json::num(h.count as f64)),
                ("mean", Json::num(h.mean)),
                ("p50", Json::num(h.p50 as f64)),
                ("p90", Json::num(h.p90 as f64)),
                ("p99", Json::num(h.p99 as f64)),
                ("max", Json::num(h.max as f64)),
            ])
        };
        Json::obj(vec![
            ("total_requests", Json::num(self.total_requests as f64)),
            ("wall_s", Json::num(self.wall.as_secs_f64())),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("latency_ns", hist(&self.serve.latency_ns)),
            ("queue_depth", hist(&self.serve.queue_depth)),
            ("batches", Json::num(self.serve.batches as f64)),
            ("size_cuts", Json::num(self.serve.size_cuts as f64)),
            ("deadline_cuts", Json::num(self.serve.deadline_cuts as f64)),
            ("idle_cuts", Json::num(self.serve.idle_cuts as f64)),
            ("buffers_recycled", Json::num(self.pipeline.buffers_recycled as f64)),
            ("batches_stolen", Json::num(self.pipeline.batches_stolen as f64)),
        ])
    }

    /// The one-line human summary the bench binary prints per scenario.
    pub fn row(&self) -> String {
        format!(
            "{:>9.0} req/s  p50 {:>9} ns  p99 {:>9} ns  max {:>10} ns  \
             qdepth p50 {:>3}  ({} batches: {} size / {} idle / {} deadline cuts)",
            self.throughput_rps,
            self.serve.latency_ns.p50,
            self.serve.latency_ns.p99,
            self.serve.latency_ns.max,
            self.serve.queue_depth.p50,
            self.serve.batches,
            self.serve.size_cuts,
            self.serve.idle_cuts,
            self.serve.deadline_cuts,
        )
    }
}

/// Run a closed-loop load test against a freshly started server; returns
/// after every client finishes and the server drains.
pub fn run_closed_loop(cfg: ServeCfg, store: AmStore, load: &LoadCfg) -> ServeBenchReport {
    let (server, handle) = Server::new(cfg, store);
    let server_thread = thread::spawn(move || server.run());
    let total = load.clients as u64 * load.requests_per_client;
    let t0 = Instant::now();
    let clients: Vec<_> = (0..load.clients)
        .map(|c| {
            let h = handle.clone();
            let mut data = load.data.clone();
            data.stream_salt ^= 0x5e7e ^ ((c as u64) << 32);
            let per = load.requests_per_client;
            thread::spawn(move || {
                let mut stream = SyntheticStream::new(data);
                let mut rec = stream.next_record().expect("unbounded stream");
                for _ in 0..per {
                    let resp = h.classify(rec).expect("serve rejected mid-load");
                    rec = resp.record;
                    stream.refill_record(&mut rec);
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client thread");
    }
    let wall = t0.elapsed();
    handle.shutdown();
    let pipeline: Arc<_> = server_thread.join().expect("server thread");
    let serve = handle.stats();
    assert_eq!(serve.completed, total, "closed loop lost responses");
    ServeBenchReport {
        total_requests: total,
        wall,
        throughput_rps: total as f64 / wall.as_secs_f64(),
        serve,
        pipeline: pipeline.snapshot(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
    use crate::encoding::BundleMethod;
    use crate::util::rng::Rng;

    #[test]
    fn closed_loop_report_is_consistent() {
        let enc = EncoderCfg {
            cat: CatCfg::Bloom { d: 256, k: 2 },
            num: NumCfg::None,
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 21,
        };
        let mut rng = Rng::new(22);
        let rows: Vec<Vec<f32>> =
            (0..2).map(|_| (0..256).map(|_| rng.normal_f32()).collect()).collect();
        let store = crate::am::AmStore::from_prototypes(256, &rows, None);
        let cfg = ServeCfg {
            coordinator: CoordinatorCfg {
                batch_size: 16,
                n_workers: 2,
                ..Default::default()
            },
            ..ServeCfg::new(enc)
        };
        let load = LoadCfg {
            clients: 3,
            requests_per_client: 60,
            data: SyntheticConfig::sampled(23),
        };
        let report = run_closed_loop(cfg, store, &load);
        assert_eq!(report.total_requests, 180);
        assert_eq!(report.serve.completed, 180);
        assert!(report.throughput_rps > 0.0);
        assert!(report.serve.latency_ns.count == 180);
        // JSON form parses back.
        let s = report.to_json().pretty();
        assert!(crate::util::json::Json::parse(&s).is_ok());
    }
}
