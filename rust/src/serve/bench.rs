//! Synthetic load generation for the serving subsystem — shared by the
//! `serve_bench` binary and `perf::encode_snapshot` so
//! `BENCH_encode.json` carries serve-path latency distributions.
//!
//! Two generators:
//!
//! * **Closed loop** ([`run_closed_loop`]): each client thread submits
//!   one request, blocks for its response, rotates the returned record
//!   buffer and submits again — offered load self-regulates to the
//!   server's capacity (no coordinated omission from a fixed-rate script
//!   outrunning the server), and `clients` is the concurrency knob.
//!   Measures capacity and in-capacity latency; *cannot* observe
//!   overload.
//! * **Open loop** ([`run_open_loop`]): requests become due on a fixed
//!   global arrival schedule regardless of completions, so offered load
//!   is independent of the server — the only generator that can push
//!   past saturation. Run it with [`crate::serve::AdmissionPolicy::Shed`]
//!   (default here) or a deadline, and the report exposes the overload
//!   behavior: shed rate, expired count, tail-latency blowup.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::am::{AmBuilder, AmStore};
use crate::coordinator::{EncoderCfg, StatsSnapshot};
use crate::obs::json::hist_json;
use crate::obs::{ObsSnapshot, TraceRecord};
use crate::data::manyclass::ManyClassConfig;
use crate::data::synthetic::SyntheticConfig;
use crate::data::{ManyClassStream, RecordStream, SyntheticStream};
use crate::serve::{
    ModelId, ModelRegistry, RequestOpts, ServeCfg, ServeError, ServeHandle, ServeSnapshot, Server,
};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct LoadCfg {
    /// Concurrent closed-loop client threads.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: u64,
    /// Which model each client routes to: client `c` uses
    /// `model_cycle[c % len]`. Empty = every client hits model 0 (the
    /// single-tenant case, and the default).
    pub model_cycle: Vec<ModelId>,
    /// The synthetic record distribution clients draw from (each client
    /// salts its own stream so requests differ across clients).
    pub data: SyntheticConfig,
}

impl LoadCfg {
    pub fn quick(seed: u64) -> LoadCfg {
        LoadCfg {
            clients: 4,
            requests_per_client: 1_000,
            model_cycle: Vec::new(),
            data: SyntheticConfig::sampled(seed),
        }
    }
}

/// JSON form of the per-model section of a [`ServeSnapshot`].
fn models_json(serve: &ServeSnapshot) -> Json {
    Json::Arr(
        serve
            .models
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("name", Json::str(m.name.clone())),
                    ("submitted", Json::num(m.submitted as f64)),
                    ("completed", Json::num(m.completed as f64)),
                    ("rejected", Json::num(m.rejected as f64)),
                    ("shed", Json::num(m.shed as f64)),
                    ("quota_shed", Json::num(m.quota_shed as f64)),
                    ("expired", Json::num(m.expired as f64)),
                    ("failed", Json::num(m.failed as f64)),
                    ("latency_ns", hist_json(&m.latency_ns)),
                    (
                        "shards",
                        Json::Arr(
                            m.shards
                                .iter()
                                .map(|s| {
                                    Json::obj(vec![
                                        ("classes", Json::num(s.classes as f64)),
                                        ("scans", Json::num(s.scans as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

#[derive(Clone, Debug)]
pub struct ServeBenchReport {
    pub total_requests: u64,
    pub wall: Duration,
    pub throughput_rps: f64,
    pub serve: ServeSnapshot,
    pub pipeline: StatsSnapshot,
    /// Per-stage breakdown ([`ServeHandle::obs_snapshot`]); `None` when
    /// the run had tracing disabled ([`ServeCfg::obs`] default).
    pub obs: Option<ObsSnapshot>,
    /// Sampled traces drained after the run (empty when disabled).
    pub traces: Vec<TraceRecord>,
}

impl ServeBenchReport {
    /// Machine-readable form for `BENCH_encode.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::str("closed")),
            ("total_requests", Json::num(self.total_requests as f64)),
            ("wall_s", Json::num(self.wall.as_secs_f64())),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("latency_ns", hist_json(&self.serve.latency_ns)),
            ("queue_depth", hist_json(&self.serve.queue_depth)),
            ("batches", Json::num(self.serve.batches as f64)),
            ("size_cuts", Json::num(self.serve.size_cuts as f64)),
            ("deadline_cuts", Json::num(self.serve.deadline_cuts as f64)),
            ("idle_cuts", Json::num(self.serve.idle_cuts as f64)),
            ("model_cuts", Json::num(self.serve.model_cuts as f64)),
            ("shed", Json::num(self.serve.shed as f64)),
            ("quota_shed", Json::num(self.serve.quota_shed as f64)),
            ("expired", Json::num(self.serve.expired as f64)),
            ("failed", Json::num(self.serve.failed as f64)),
            ("shed_rate", Json::num(self.serve.shed_rate())),
            ("models", models_json(&self.serve)),
            ("buffers_recycled", Json::num(self.pipeline.buffers_recycled as f64)),
            ("batches_stolen", Json::num(self.pipeline.batches_stolen as f64)),
            ("worker_panics", Json::num(self.pipeline.worker_panics as f64)),
            ("encoder_builds", Json::num(self.pipeline.encoder_builds as f64)),
            (
                "stage_breakdown",
                match &self.obs {
                    Some(o) => o.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// The one-line human summary the bench binary prints per scenario.
    pub fn row(&self) -> String {
        format!(
            "{:>9.0} req/s  p50 {:>9} ns  p99 {:>9} ns  max {:>10} ns  \
             qdepth p50 {:>3}  ({} batches: {} size / {} idle / {} deadline cuts)",
            self.throughput_rps,
            self.serve.latency_ns.p50,
            self.serve.latency_ns.p99,
            self.serve.latency_ns.max,
            self.serve.queue_depth.p50,
            self.serve.batches,
            self.serve.size_cuts,
            self.serve.idle_cuts,
            self.serve.deadline_cuts,
        )
    }
}

/// Run a closed-loop load test against a freshly started single-tenant
/// server; returns after every client finishes and the server drains.
pub fn run_closed_loop(cfg: ServeCfg, store: AmStore, load: &LoadCfg) -> ServeBenchReport {
    let (server, handle) = Server::new(cfg, store);
    drive_closed_loop(server, handle, load)
}

/// Closed-loop load against a multi-tenant registry server: client `c`
/// routes every request to `load.model_cycle[c % len]`
/// ([`ServeHandle::classify_for`]), so a 2-model cycle interleaves
/// tenants through the one shared worker pool.
pub fn run_closed_loop_registry(
    cfg: ServeCfg,
    registry: ModelRegistry,
    load: &LoadCfg,
) -> ServeBenchReport {
    let (server, handle) = Server::with_registry(cfg, registry);
    drive_closed_loop(server, handle, load)
}

fn drive_closed_loop(server: Server, handle: ServeHandle, load: &LoadCfg) -> ServeBenchReport {
    let server_thread = thread::spawn(move || server.run());
    let total = load.clients as u64 * load.requests_per_client;
    let t0 = Instant::now();
    let clients: Vec<_> = (0..load.clients)
        .map(|c| {
            let h = handle.clone();
            let mut data = load.data.clone();
            data.stream_salt ^= 0x5e7e ^ ((c as u64) << 32);
            let per = load.requests_per_client;
            let model = if load.model_cycle.is_empty() {
                ModelId(0)
            } else {
                load.model_cycle[c % load.model_cycle.len()]
            };
            thread::spawn(move || {
                let mut stream = SyntheticStream::new(data);
                let mut rec = stream.next_record().expect("unbounded stream");
                for _ in 0..per {
                    let resp = h.classify_for(model, rec).expect("serve rejected mid-load");
                    rec = resp.record;
                    stream.refill_record(&mut rec);
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client thread");
    }
    finish_closed_loop(server_thread, handle, total, t0)
}

/// Shared closed-loop epilogue: drain, join, reconcile, report.
fn finish_closed_loop(
    server_thread: thread::JoinHandle<Arc<crate::coordinator::PipelineStats>>,
    handle: ServeHandle,
    total: u64,
    t0: Instant,
) -> ServeBenchReport {
    let wall = t0.elapsed();
    handle.shutdown();
    let pipeline: Arc<_> = server_thread.join().expect("server thread");
    let serve = handle.stats();
    assert_eq!(serve.completed, total, "closed loop lost responses");
    let (obs, traces) = drain_obs(&handle);
    ServeBenchReport {
        total_requests: total,
        wall,
        throughput_rps: total as f64 / wall.as_secs_f64(),
        serve,
        pipeline: pipeline.snapshot(),
        obs,
        traces,
    }
}

/// Pull the stage breakdown and sampled traces off a finished run (the
/// server has drained, so every sampled request's record has landed).
fn drain_obs(handle: &ServeHandle) -> (Option<ObsSnapshot>, Vec<TraceRecord>) {
    if handle.tracing_enabled() {
        (Some(handle.obs_snapshot()), handle.drain_traces())
    } else {
        (None, Vec::new())
    }
}

/// Closed-loop load over the many-class Zipf workload
/// ([`crate::data::manyclass`]) — the sharded-AM-scan regime, where the
/// class scan rather than encode dominates per-request cost.
#[derive(Clone, Debug)]
pub struct ManyClassLoadCfg {
    /// Concurrent closed-loop client threads.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: u64,
    /// The many-class record distribution (each client salts its own
    /// stream; all clients share the planted classes).
    pub data: ManyClassConfig,
}

/// Build the C-class AM store for a many-class workload: encode each
/// class's canonical noise-free record
/// ([`ManyClassConfig::class_record`]) and bundle it — one example per
/// class, the degenerate (and exactly reproducible) case of the HDC
/// bundling rule. Shared by `serve_bench`, the perf snapshot, and the
/// serve determinism test, so every consumer scores against the
/// identical prototypes.
pub fn build_many_class_store(enc: &EncoderCfg, data: &ManyClassConfig) -> AmStore {
    let mut encoder = enc.build();
    let mut builder = AmBuilder::new(enc.out_dim(), data.n_classes);
    for c in 0..data.n_classes {
        let code = encoder.encode(&data.class_record(c as u32));
        builder.add(c, &code);
    }
    builder.finish(false)
}

/// Run a closed-loop load test over the many-class workload against a
/// freshly started single-tenant server (score the store built by
/// [`build_many_class_store`]; set [`ServeCfg::am_shards`] to exercise
/// the sharded scan). Returns after every client finishes.
pub fn run_closed_loop_many_class(
    cfg: ServeCfg,
    store: AmStore,
    load: &ManyClassLoadCfg,
) -> ServeBenchReport {
    let (server, handle) = Server::new(cfg, store);
    let server_thread = thread::spawn(move || server.run());
    let total = load.clients as u64 * load.requests_per_client;
    let t0 = Instant::now();
    let clients: Vec<_> = (0..load.clients)
        .map(|c| {
            let h = handle.clone();
            let mut data = load.data.clone();
            data.stream_salt ^= 0xc1a5 ^ ((c as u64) << 32);
            let per = load.requests_per_client;
            thread::spawn(move || {
                let mut stream = ManyClassStream::new(data);
                let mut rec = stream.next_record().expect("unbounded stream");
                for _ in 0..per {
                    let resp = h.classify(rec).expect("serve rejected mid-load");
                    rec = resp.record;
                    stream.refill_record(&mut rec);
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client thread");
    }
    finish_closed_loop(server_thread, handle, total, t0)
}

/// Open-loop (fixed arrival rate) load configuration.
#[derive(Clone, Debug)]
pub struct OpenLoadCfg {
    /// Offered arrival rate, requests per second — independent of the
    /// server's completion rate (that independence is the whole point).
    pub rate_rps: f64,
    /// Total requests offered across all sender threads.
    pub total_requests: u64,
    /// Sender threads draining the shared arrival schedule. Each sender
    /// is synchronous (blocks per its admission policy), so this also
    /// bounds in-flight requests; size it generously above
    /// `rate / per-request service rate`.
    pub senders: usize,
    /// Per-request options (admission policy / deadline). With `Block`
    /// admission an over-capacity run would make senders lag the
    /// schedule instead of exposing overload — use `Shed`, backoff, or a
    /// deadline for saturation studies.
    pub opts: RequestOpts,
    /// The synthetic record distribution senders draw from.
    pub data: SyntheticConfig,
}

/// What came back from one open-loop run: outcome tallies as the
/// *clients* observed them (cross-checkable against [`ServeSnapshot`]).
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    pub offered: u64,
    pub offered_rps: f64,
    /// Completion rate of successful responses over the wall time.
    pub achieved_rps: f64,
    pub ok: u64,
    pub shed: u64,
    pub timed_out: u64,
    pub expired: u64,
    pub failed: u64,
    pub aborted: u64,
    pub rejected: u64,
    pub wall: Duration,
    pub serve: ServeSnapshot,
    pub pipeline: StatsSnapshot,
    /// Per-stage breakdown; `None` when the run had tracing disabled.
    pub obs: Option<ObsSnapshot>,
    /// Sampled traces drained after the run (empty when disabled).
    pub traces: Vec<TraceRecord>,
}

impl OpenLoopReport {
    /// Machine-readable form for `BENCH_encode.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::str("open")),
            ("offered", Json::num(self.offered as f64)),
            ("offered_rps", Json::num(self.offered_rps)),
            ("achieved_rps", Json::num(self.achieved_rps)),
            ("ok", Json::num(self.ok as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("quota_shed", Json::num(self.serve.quota_shed as f64)),
            ("timed_out", Json::num(self.timed_out as f64)),
            ("expired", Json::num(self.expired as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("shed_rate", Json::num(self.serve.shed_rate())),
            ("latency_ns", hist_json(&self.serve.latency_ns)),
            ("queue_depth", hist_json(&self.serve.queue_depth)),
            ("models", models_json(&self.serve)),
            ("worker_panics", Json::num(self.pipeline.worker_panics as f64)),
            (
                "stage_breakdown",
                match &self.obs {
                    Some(o) => o.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// The one-line human summary the bench binary prints per scenario.
    pub fn row(&self) -> String {
        format!(
            "offered {:>9.0} req/s  achieved {:>9.0} req/s  shed {:>5.1}%  \
             ok {:>7}  expired {:>6}  p99 {:>10} ns",
            self.offered_rps,
            self.achieved_rps,
            self.serve.shed_rate() * 100.0,
            self.ok,
            self.expired,
            self.serve.latency_ns.p99,
        )
    }
}

/// Run an open-loop load test: `total_requests` arrivals spaced
/// `1/rate_rps` apart on one shared schedule, drained by `senders`
/// threads. Always terminates — over capacity, the admission policy
/// (shed / backoff timeout / deadline) refuses the excess instead of
/// queueing it unboundedly, and that refusal rate is the measurement.
pub fn run_open_loop(cfg: ServeCfg, store: AmStore, load: &OpenLoadCfg) -> OpenLoopReport {
    assert!(load.rate_rps > 0.0, "open loop needs a positive arrival rate");
    let (server, handle) = Server::new(cfg, store);
    let server_thread = thread::spawn(move || server.run());
    let interval = Duration::from_secs_f64(1.0 / load.rate_rps);
    let next_arrival = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let senders: Vec<_> = (0..load.senders.max(1))
        .map(|c| {
            let h = handle.clone();
            let mut data = load.data.clone();
            data.stream_salt ^= 0x09e7 ^ ((c as u64) << 32);
            let next = Arc::clone(&next_arrival);
            let total = load.total_requests;
            let opts = load.opts;
            thread::spawn(move || {
                let mut stream = SyntheticStream::new(data);
                let mut rec = stream.next_record().expect("unbounded stream");
                // Tally: [ok, shed, timed_out, expired, failed, aborted, rejected]
                let mut tally = [0u64; 7];
                loop {
                    // Claim the next arrival on the shared schedule.
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    let due = t0 + interval.mul_f64(i as f64);
                    let now = Instant::now();
                    if due > now {
                        thread::sleep(due - now);
                    }
                    match h.classify_with(rec, opts) {
                        Ok(resp) => {
                            tally[0] += 1;
                            rec = resp.record;
                        }
                        Err(e) => {
                            match e {
                                ServeError::QueueFull => tally[1] += 1,
                                ServeError::AdmissionTimeout => tally[2] += 1,
                                ServeError::DeadlineExceeded => tally[3] += 1,
                                ServeError::Internal => tally[4] += 1,
                                ServeError::Aborted => tally[5] += 1,
                                _ => tally[6] += 1,
                            }
                            // The record moved into the server; draw a
                            // fresh buffer for the next arrival.
                            rec = stream.next_record().expect("unbounded stream");
                            continue;
                        }
                    }
                    stream.refill_record(&mut rec);
                }
                tally
            })
        })
        .collect();
    let mut tally = [0u64; 7];
    for s in senders {
        let t = s.join().expect("sender thread");
        for (acc, v) in tally.iter_mut().zip(t) {
            *acc += v;
        }
    }
    let wall = t0.elapsed();
    handle.shutdown();
    let pipeline: Arc<_> = server_thread.join().expect("server thread");
    let serve = handle.stats();
    let (obs, traces) = drain_obs(&handle);
    OpenLoopReport {
        offered: load.total_requests,
        offered_rps: load.rate_rps,
        achieved_rps: tally[0] as f64 / wall.as_secs_f64(),
        ok: tally[0],
        shed: tally[1],
        timed_out: tally[2],
        expired: tally[3],
        failed: tally[4],
        aborted: tally[5],
        rejected: tally[6],
        wall,
        serve,
        pipeline: pipeline.snapshot(),
        obs,
        traces,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
    use crate::encoding::BundleMethod;
    use crate::util::rng::Rng;

    #[test]
    fn closed_loop_report_is_consistent() {
        let enc = EncoderCfg {
            cat: CatCfg::Bloom { d: 256, k: 2 },
            num: NumCfg::None,
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 21,
        };
        let mut rng = Rng::new(22);
        let rows: Vec<Vec<f32>> =
            (0..2).map(|_| (0..256).map(|_| rng.normal_f32()).collect()).collect();
        let store = crate::am::AmStore::from_prototypes(256, &rows, None);
        let cfg = ServeCfg {
            coordinator: CoordinatorCfg {
                batch_size: 16,
                n_workers: 2,
                ..Default::default()
            },
            ..ServeCfg::new(enc)
        };
        let load = LoadCfg {
            clients: 3,
            requests_per_client: 60,
            data: SyntheticConfig::sampled(23),
            ..LoadCfg::quick(23)
        };
        let report = run_closed_loop(cfg, store, &load);
        assert_eq!(report.total_requests, 180);
        assert_eq!(report.serve.completed, 180);
        assert!(report.throughput_rps > 0.0);
        assert!(report.serve.latency_ns.count == 180);
        // JSON form parses back.
        let s = report.to_json().pretty();
        assert!(crate::util::json::Json::parse(&s).is_ok());
    }

    #[test]
    fn closed_loop_registry_interleaves_models() {
        use crate::am::Precision;
        use crate::serve::TenantQuota;
        let enc = |d: usize, seed: u64| EncoderCfg {
            cat: CatCfg::Bloom { d, k: 2 },
            num: NumCfg::None,
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed,
        };
        let store = |d: usize, seed: u64| {
            let mut rng = Rng::new(seed);
            let rows: Vec<Vec<f32>> =
                (0..2).map(|_| (0..d).map(|_| rng.normal_f32()).collect()).collect();
            crate::am::AmStore::from_prototypes(d, &rows, None)
        };
        let mut reg = ModelRegistry::new();
        let a = reg.register("a", enc(256, 41), store(256, 42), Precision::F32,
            TenantQuota::default());
        let b = reg.register("b", enc(512, 43), store(512, 44), Precision::Int8,
            TenantQuota::default());
        let cfg = ServeCfg {
            coordinator: CoordinatorCfg { batch_size: 8, n_workers: 2, ..Default::default() },
            ..ServeCfg::new(enc(256, 41))
        };
        let load = LoadCfg {
            clients: 4,
            requests_per_client: 50,
            model_cycle: vec![a, b],
            data: SyntheticConfig::sampled(45),
        };
        let report = run_closed_loop_registry(cfg, reg, &load);
        assert_eq!(report.serve.completed, 200);
        // 2 of 4 clients per model.
        assert_eq!(report.serve.models.len(), 2);
        assert_eq!(report.serve.models[0].completed, 100);
        assert_eq!(report.serve.models[1].completed, 100);
        assert_eq!(report.serve.models[0].name, "a");
        // Both tenants' encoders were built somewhere in the pool.
        assert!(report.pipeline.encoder_builds >= 2);
        let s = report.to_json().pretty();
        assert!(crate::util::json::Json::parse(&s).is_ok());
    }

    #[test]
    fn open_loop_under_capacity_completes_everything() {
        let enc = EncoderCfg {
            cat: CatCfg::Bloom { d: 256, k: 2 },
            num: NumCfg::None,
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 31,
        };
        let mut rng = Rng::new(32);
        let rows: Vec<Vec<f32>> =
            (0..2).map(|_| (0..256).map(|_| rng.normal_f32()).collect()).collect();
        let store = crate::am::AmStore::from_prototypes(256, &rows, None);
        let cfg = ServeCfg {
            coordinator: CoordinatorCfg { batch_size: 8, n_workers: 2, ..Default::default() },
            ..ServeCfg::new(enc)
        };
        let load = OpenLoadCfg {
            rate_rps: 2_000.0, // far below encode capacity for d=256
            total_requests: 100,
            senders: 4,
            opts: RequestOpts {
                admission: Some(crate::serve::AdmissionPolicy::Shed),
                ..RequestOpts::default()
            },
            data: SyntheticConfig::sampled(33),
        };
        let report = run_open_loop(cfg, store, &load);
        assert_eq!(report.offered, 100);
        assert_eq!(report.ok + report.shed + report.timed_out + report.expired
            + report.failed + report.aborted + report.rejected, 100);
        // Comfortably under capacity: nearly everything should succeed.
        assert!(report.ok > 0, "{report:?}");
        // Client-side tallies must agree with the server's counters.
        assert_eq!(report.shed + report.timed_out,
            report.serve.shed + report.serve.admission_timeouts);
        let s = report.to_json().pretty();
        assert!(crate::util::json::Json::parse(&s).is_ok());
    }

    #[test]
    fn many_class_closed_loop_reconciles_shard_scans() {
        let enc = EncoderCfg {
            cat: CatCfg::Bloom { d: 512, k: 2 },
            num: NumCfg::None,
            bundle: BundleMethod::Concat,
            n_numeric: 0,
            seed: 51,
        };
        let data = ManyClassConfig::classes(200, 52);
        let store = build_many_class_store(&enc, &data);
        assert_eq!(store.n_classes(), 200);
        let cfg = ServeCfg {
            coordinator: CoordinatorCfg { batch_size: 8, n_workers: 2, ..Default::default() },
            am_shards: 4,
            ..ServeCfg::new(enc)
        };
        let load = ManyClassLoadCfg { clients: 3, requests_per_client: 40, data };
        let report = run_closed_loop_many_class(cfg, store, &load);
        assert_eq!(report.serve.completed, 120);
        let shards = &report.serve.models[0].shards;
        assert_eq!(shards.len(), 4);
        assert_eq!(shards.iter().map(|s| s.classes as usize).sum::<usize>(), 200);
        for sh in shards {
            assert_eq!(sh.scans, 120, "every scored request scans every shard");
        }
        let s = report.to_json().pretty();
        assert!(crate::util::json::Json::parse(&s).is_ok());
    }
}
