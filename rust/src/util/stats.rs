//! Small statistics helpers shared by metrics, benches and reports.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Five-number summary used by the box-plot style reports (the paper
/// reports AUC distributions over 100k-sample chunks as box plots).
#[derive(Clone, Copy, Debug)]
pub struct BoxStats {
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
}

impl BoxStats {
    pub fn from(xs: &[f64]) -> BoxStats {
        BoxStats {
            min: percentile(xs, 0.0),
            q1: percentile(xs, 25.0),
            median: percentile(xs, 50.0),
            q3: percentile(xs, 75.0),
            max: percentile(xs, 100.0),
        }
    }

    /// Render as the compact row the report binaries print.
    pub fn row(&self) -> String {
        format!(
            "min={:.4} q1={:.4} med={:.4} q3={:.4} max={:.4}",
            self.min, self.q1, self.median, self.q3, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn box_stats_ordered() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        let b = BoxStats::from(&xs);
        assert!(b.min <= b.q1 && b.q1 <= b.median && b.median <= b.q3 && b.q3 <= b.max);
        assert_eq!(b.median, 50.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }
}
