//! Minimal JSON reader/writer.
//!
//! serde is not available in this offline image; the AOT artifact
//! manifest is the only JSON we consume and the bench snapshots
//! (`BENCH_encode.json`) the only JSON we emit, so a small
//! recursive-descent parser plus a pretty-printer suffice. The reader
//! supports the full JSON grammar minus exotic number forms we never
//! emit; the writer round-trips through the reader.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Object field lookup (None for missing key or non-object).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Convenience constructors for the writer side.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Pretty-print with 2-space indentation and a trailing newline —
    /// stable output (object keys are sorted by the BTreeMap), so
    /// regenerated snapshots diff cleanly.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    /// Single-line form, no trailing newline — one value per line for
    /// JSONL streams (trace dumps), same escaping and number formatting
    /// as [`Json::pretty`], so it round-trips through [`Json::parse`].
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => out.push_str(&fmt_number(*x)),
            Json::Str(s) => write_string(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_string(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => out.push_str(&fmt_number(*x)),
            Json::Str(s) => write_string(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    item.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_string(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }
}

fn push_indent(out: &mut String, levels: usize) {
    for _ in 0..levels {
        out.push_str("  ");
    }
}

fn fmt_number(x: f64) -> String {
    if !x.is_finite() {
        // JSON has no inf/nan; encode as null like most emitters.
        return "null".to_string();
    }
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        // Shortest round-trippable form rust gives us.
        format!("{x}")
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u hex"))?;
                            self.i += 4;
                            // Surrogate pairs are not needed for our manifest;
                            // map lone surrogates to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Re-interpret multi-byte UTF-8 sequences correctly: back
                    // up and consume the full char.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        self.i -= 1;
                        let s = std::str::from_utf8(&self.b[self.i..])
                            .map_err(|_| self.err("invalid utf8"))?;
                        let ch = s.chars().next().unwrap();
                        out.push(ch);
                        self.i += ch.len_utf8();
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn parses_manifest_shape() {
        let v = Json::parse(
            r#"{"artifacts": {"train_step__small": {"file": "t.hlo.txt",
               "inputs": [{"shape": [768], "dtype": "float32"}],
               "params": {"b": 32, "d_total": 768}}}}"#,
        )
        .unwrap();
        let art = v.get("artifacts").unwrap().get("train_step__small").unwrap();
        assert_eq!(art.get("file").unwrap().as_str(), Some("t.hlo.txt"));
        assert_eq!(
            art.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .as_usize(),
            Some(768)
        );
        assert_eq!(art.get("params").unwrap().get("b").unwrap().as_usize(), Some(32));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_strings() {
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn pretty_round_trips() {
        let v = Json::obj(vec![
            ("name", Json::str("bloom d=10k")),
            ("median_ns", Json::num(1234.5)),
            ("iters", Json::num(1_000_000.0)),
            ("tags", Json::Arr(vec![Json::str("a\"b"), Json::Null, Json::Bool(true)])),
            ("empty", Json::Arr(vec![])),
            ("nested", Json::obj(vec![("x", Json::num(-3.0))])),
        ]);
        let text = v.pretty();
        assert_eq!(Json::parse(&text).unwrap(), v);
        // Integral numbers print without a fraction.
        assert!(text.contains("\"iters\": 1000000"), "{text}");
    }

    #[test]
    fn compact_is_one_line_and_round_trips() {
        let v = Json::obj(vec![
            ("name", Json::str("bloom d=10k")),
            ("median_ns", Json::num(1234.5)),
            ("iters", Json::num(1_000_000.0)),
            ("tags", Json::Arr(vec![Json::str("a\"b"), Json::Null, Json::Bool(true)])),
            ("empty", Json::Arr(vec![])),
            ("nested", Json::obj(vec![("x", Json::num(-3.0))])),
        ]);
        let text = v.compact();
        assert!(!text.contains('\n'), "{text}");
        assert_eq!(Json::parse(&text).unwrap(), v);
        assert!(text.contains("\"iters\":1000000"), "{text}");
    }

    #[test]
    fn pretty_escapes_and_nonfinite() {
        let v = Json::obj(vec![
            ("s", Json::str("line\nbreak\ttab")),
            ("inf", Json::num(f64::INFINITY)),
        ]);
        let text = v.pretty();
        assert!(text.contains("\\n"));
        assert!(text.contains("\"inf\": null"));
        assert!(Json::parse(&text).is_ok());
    }
}
