//! Dependency-free utilities (this image is offline; see Cargo.toml):
//! deterministic RNG, minimal JSON, statistics, and a bench harness.

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
