//! Dependency-free utilities (this image is offline; see Cargo.toml):
//! deterministic RNG, minimal JSON, statistics, and a bench harness.

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
pub mod sync;

/// Parse a `u64` scale knob from the environment, falling back to
/// `default` when unset or malformed — shared by the bench entry points
/// (`perf::encode_snapshot`, `serve_bench`).
pub fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}
