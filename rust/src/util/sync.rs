//! Poisoned-lock recovery policy (shared by the coordinator and the
//! serving subsystem).
//!
//! `std::sync::Mutex` poisons itself when a thread panics while holding
//! the guard. The default `.lock().unwrap()` idiom turns that single
//! panic into a *cascade*: every other thread touching the same lock
//! unwinds with a `PoisonError`, which in a worker pool means one
//! injected (or real) panic takes down the reader, every sibling worker
//! and the consumer — exactly the failure amplification a fault-tolerant
//! serve path must not have.
//!
//! The uniform policy here is **recover and continue**: every lock and
//! condvar wait in the pipeline goes through these helpers, which strip
//! the poison flag (`PoisonError::into_inner`) and hand back the guard.
//! That is sound for this codebase because every critical section
//! maintains its invariants *before* any code that can panic runs —
//! the guarded state is plain queue/pool/slot data mutated by
//! single-call push/pop/replace operations, and the encode bodies
//! (the only panic-prone regions, and the ones `FaultPlan` injects
//! into) run outside all locks and behind their own `catch_unwind`.
//! A poisoned guard therefore protects data that is still consistent,
//! and recovering is strictly better than unwinding the whole pool.
//!
//! Keep this module dependency-free and tiny: it is on the serve hot
//! path (one branch over the raw lock).

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
#[inline]
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Wait on `cv`, recovering the re-acquired guard from poison.
#[inline]
pub fn wait_unpoisoned<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Bounded wait on `cv`; returns the re-acquired guard and whether the
/// wait timed out (poison recovered on both paths).
#[inline]
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(g, dur) {
        Ok((g, t)) => (g, t.timed_out()),
        Err(e) => {
            let (g, t) = e.into_inner();
            (g, t.timed_out())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        // Poison the mutex: panic while holding the guard.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        // The policy: recover the guard and keep using the data.
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7);
        *g = 8;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn wait_timeout_recovers_and_reports_timeout() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_unpoisoned(&m);
        let (_g, timed_out) = wait_timeout_unpoisoned(&cv, g, Duration::from_millis(1));
        assert!(timed_out, "nothing notifies: the bounded wait must time out");
    }
}
