//! Tiny wall-clock benchmark harness (criterion is not cached offline).
//!
//! Usage in a `harness = false` bench target:
//! ```ignore
//! let mut h = Harness::new("encode_scaling");
//! h.bench("bloom d=10000", || encoder.encode(&symbols));
//! h.finish();
//! ```
//! Each benchmark is warmed up, then timed over adaptively-chosen
//! iteration counts until `min_time` has elapsed; we report median /
//! p10 / p90 per-iteration latency and derived throughput.
//!
//! Results can be exported machine-readably ([`Harness::to_json`] /
//! [`Harness::write_json`]) so snapshots like `BENCH_encode.json` track
//! the perf trajectory PR over PR.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.median_ns
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("median_ns", Json::num(self.median_ns)),
            ("p10_ns", Json::num(self.p10_ns)),
            ("p90_ns", Json::num(self.p90_ns)),
            ("iters", Json::num(self.iters as f64)),
            ("per_sec", Json::num(self.per_sec())),
        ])
    }
}

pub struct Harness {
    pub group: String,
    pub min_time: Duration,
    pub results: Vec<BenchResult>,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Harness {
    pub fn new(group: &str) -> Harness {
        println!("\n== bench group: {group} ==");
        Harness {
            group: group.to_string(),
            min_time: Duration::from_millis(
                std::env::var("BENCH_MS")
                    .ok()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(300),
            ),
            results: Vec::new(),
        }
    }

    /// Time `f`, which should return something (black_box'd to foil DCE).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration: find an iteration count that takes >= ~5ms.
        let mut iters_per_sample = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            let dt = t0.elapsed();
            if dt >= Duration::from_millis(5) || iters_per_sample > (1 << 30) {
                break;
            }
            iters_per_sample *= 4;
        }
        // Sample until min_time.
        let mut samples_ns: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let t_start = Instant::now();
        while t_start.elapsed() < self.min_time || samples_ns.len() < 5 {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            let dt = t0.elapsed();
            samples_ns.push(dt.as_nanos() as f64 / iters_per_sample as f64);
            total_iters += iters_per_sample;
            if samples_ns.len() > 1000 {
                break;
            }
        }
        let res = BenchResult {
            name: name.to_string(),
            median_ns: stats::median(&samples_ns),
            p10_ns: stats::percentile(&samples_ns, 10.0),
            p90_ns: stats::percentile(&samples_ns, 90.0),
            iters: total_iters,
        };
        println!(
            "  {:<44} median {:>12}  p10 {:>12}  p90 {:>12}  ({} iters)",
            res.name,
            fmt_ns(res.median_ns),
            fmt_ns(res.p10_ns),
            fmt_ns(res.p90_ns),
            res.iters
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Print a throughput line derived from the last result.
    pub fn note_throughput(&self, items_per_iter: f64, unit: &str) {
        if let Some(r) = self.results.last() {
            let per_sec = items_per_iter * 1e9 / r.median_ns;
            println!("      -> {per_sec:.3e} {unit}/s");
        }
    }

    /// Median latency of a named result (None if it was never run).
    pub fn median_ns(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|r| r.name == name).map(|r| r.median_ns)
    }

    /// All results as a JSON array.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.results.iter().map(BenchResult::to_json).collect())
    }

    /// Write `doc` (typically assembled around [`Harness::to_json`]) to
    /// `path` as pretty JSON.
    pub fn write_json(path: &str, doc: &Json) -> std::io::Result<()> {
        std::fs::write(path, doc.pretty())?;
        println!("  wrote {path}");
        Ok(())
    }

    pub fn finish(&self) {
        println!("== {} done: {} benchmarks ==", self.group, self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_measures_something() {
        std::env::set_var("BENCH_MS", "20");
        let mut h = Harness::new("selftest");
        let r = h.bench("sum", || (0..1000u64).sum::<u64>());
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.p90_ns * 1.001);
    }
}
