//! Deterministic, dependency-free RNG: splitmix64 seeding + xoshiro256**.
//!
//! The paper's encoders are *defined* by random draws (codewords
//! `Unif({±1}^d)`, projection rows `Unif(S^{n-1})`, hash seeds). All of
//! those draws route through this module so that every experiment is
//! reproducible from a single `u64` seed. xoshiro256** passes BigCrush
//! and is far cheaper than anything crypto-grade, which matters because
//! the codebook *baseline* has to materialize millions of codewords.

/// splitmix64 step — used to seed xoshiro and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One-shot mix of a 64-bit value (stateless splitmix64 finalizer).
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut s = x;
    splitmix64(&mut s)
}

/// xoshiro256** by Blackman & Vigna — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller normal deviate.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a single u64 via splitmix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. per worker shard / per hash fn).
    pub fn fork(&self, stream: u64) -> Rng {
        // Mix the current state with the stream id; forked streams are
        // decorrelated by the splitmix64 avalanche.
        let mut sm = self.s[0] ^ mix64(stream ^ 0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in [0, bound) (Lemire rejection).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Random sign in {-1.0, +1.0}.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// A vector drawn uniformly from the unit sphere S^{n-1}
    /// (normalized gaussian) — the paper's projection-row distribution.
    pub fn unit_vector(&mut self, n: usize) -> Vec<f32> {
        loop {
            let v: Vec<f32> = (0..n).map(|_| self.normal_f32()).collect();
            let norm = v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt();
            if norm > 1e-12 {
                return v.iter().map(|x| (*x as f64 / norm) as f32).collect();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Zipf(α) sampler over {0, .., n-1} via rejection-inversion
/// (Hörmann & Derflinger). The paper's categorical alphabets are heavy-
/// tailed ("the total universe of products is vast" but views are
/// concentrated); Zipf is the standard model for that shape.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    alpha: f64,
    // Precomputed constants for rejection-inversion.
    hx0: f64,
    hxm: f64,
    s: f64,
}

impl Zipf {
    pub fn new(n: u64, alpha: f64) -> Self {
        assert!(n >= 1 && alpha > 0.0 && (alpha - 1.0).abs() > 1e-9,
            "alpha == 1 exactly is not supported; use e.g. 1.0001");
        let h = |x: f64| -> f64 { ((1.0 + x).powf(1.0 - alpha) - 1.0) / (1.0 - alpha) };
        let hx0 = h(0.5) - 1.0f64.min(1.0); // H(x0) - p(1)
        let hx0 = hx0 + 0.0; // keep shape explicit
        let hxm = h(n as f64 + 0.5);
        let s = 1.0 - Self::h_inv_static(alpha, h(1.5) - 1.0);
        Zipf { n, alpha, hx0, hxm, s }
    }

    fn h_inv_static(alpha: f64, x: f64) -> f64 {
        (1.0 + x * (1.0 - alpha)).powf(1.0 / (1.0 - alpha)) - 1.0
    }

    fn h(&self, x: f64) -> f64 {
        ((1.0 + x).powf(1.0 - self.alpha) - 1.0) / (1.0 - self.alpha)
    }

    fn h_inv(&self, x: f64) -> f64 {
        Self::h_inv_static(self.alpha, x)
    }

    /// Sample a rank in [0, n) (0 = most frequent symbol).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        loop {
            let u = self.hx0 + rng.next_f64() * (self.hxm - self.hx0);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().max(0.0).min((self.n - 1) as f64);
            // Acceptance test.
            if k - x <= self.s || u >= self.h(k + 0.5) - (1.0 + k).powf(-self.alpha) {
                return k as u64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fork_decorrelates() {
        let base = Rng::new(7);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(3);
        let mean: f64 = (0..100_000).map(|_| r.next_f64()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let v = r.below(10) as usize;
            counts[v] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..200_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn unit_vector_has_unit_norm() {
        let mut r = Rng::new(6);
        for n in [1usize, 2, 13, 100] {
            let v = r.unit_vector(n);
            let norm: f64 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-5, "n={n} norm={norm}");
        }
    }

    #[test]
    fn zipf_is_heavy_headed_and_in_range() {
        let z = Zipf::new(1000, 1.2);
        let mut r = Rng::new(8);
        let mut head = 0usize;
        for _ in 0..50_000 {
            let k = z.sample(&mut r);
            assert!(k < 1000);
            if k < 10 {
                head += 1;
            }
        }
        // With alpha=1.2 the top-10 ranks carry a large constant fraction.
        assert!(head > 20_000, "head={head}");
    }

    #[test]
    fn zipf_rank_frequencies_decrease() {
        let z = Zipf::new(100, 1.5);
        let mut r = Rng::new(9);
        let mut counts = vec![0usize; 100];
        for _ in 0..200_000 {
            counts[z.sample(&mut r) as usize] += 1;
        }
        assert!(counts[0] > counts[3] && counts[3] > counts[20]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
