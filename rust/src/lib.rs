//! # streaming-hdc
//!
//! Production-grade reproduction of *"Streaming Encoding Algorithms for
//! Scalable Hyperdimensional Computing"* (Thomas et al., 2022) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the streaming coordinator: hashing,
//!   sparse Bloom encoding, the synthetic Criteo-like stream, sharded
//!   encode workers with backpressure, sparse-SGD logistic training,
//!   metrics, and the FPGA / PIM hardware simulators.
//! * **Layer 2 (python/compile/model.py)** — the dense algebra (random
//!   projections, SJLT, fused logistic train step, MLP baseline) as
//!   jitted JAX functions AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the
//!   projection / SJLT / logistic hot-spots, lowered into the same HLO.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT (the
//! `xla` crate); python never runs on the request path.
//!
//! Inference serving lives in [`am`] (the quantized associative-memory
//! class store: f32 / int8 / sign-binarized prototypes scored by the
//! similarity kernels) and [`serve`] (request micro-batching over the
//! same work-stealing encode pipeline the trainer uses).
//!
//! Start with [`pipeline::TrainPipeline`] or the `examples/` directory.
//!
//! # Cargo features
//!
//! * `simd` — switch the encode kernel layer ([`encoding::kernels`]) to
//!   explicit portable `std::simd` implementations. Requires a nightly
//!   toolchain (`portable_simd` is unstable); the default scalar
//!   backend builds on stable and is bit-identical (enforced by
//!   `tests/kernel_equivalence.rs`).

// `portable_simd` is gated on the cargo feature so default builds stay
// on stable rustc; only `--features simd` (nightly) enables it.
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod am;
pub mod coordinator;
pub mod data;
pub mod encoding;
pub mod hash;
pub mod hw;
pub mod model;
pub mod obs;
pub mod perf;
pub mod pipeline;
pub mod runtime;
pub mod serve;
pub mod util;
