//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype in manifest: {other}"),
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(
            j.get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tensor spec missing dtype"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the artifacts directory.
    pub file: PathBuf,
    /// The L2 function this artifact lowers (e.g. "train_step").
    pub fn_name: String,
    /// Shape profile name (e.g. "small", "default").
    pub profile: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Semantic parameters: b, n, d_num, d_cat, d_total, sjlt_k.
    pub params: BTreeMap<String, usize>,
}

impl ArtifactSpec {
    pub fn param(&self, key: &str) -> Result<usize> {
        self.params
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("artifact {} missing param {key}", self.name))
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub mlp_widths: Vec<usize>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut artifacts = BTreeMap::new();
        let arts = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        for (name, j) in arts {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                j.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact {name} missing {key}"))?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect()
            };
            let mut params = BTreeMap::new();
            if let Some(p) = j.get("params").and_then(Json::as_obj) {
                for (k, v) in p {
                    if let Some(x) = v.as_usize() {
                        params.insert(k.clone(), x);
                    }
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: PathBuf::from(
                        j.get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("artifact {name} missing file"))?,
                    ),
                    fn_name: j
                        .get("fn")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    profile: j
                        .get("profile")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    params,
                },
            );
        }
        let mlp_widths = root
            .get("mlp_widths")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        Ok(Manifest { artifacts, mlp_widths })
    }

    /// Find the artifact for a function at a profile.
    pub fn find(&self, fn_name: &str, profile: &str) -> Result<&ArtifactSpec> {
        let key = format!("{fn_name}__{profile}");
        self.artifacts
            .get(&key)
            .ok_or_else(|| anyhow!("no artifact {key} in manifest"))
    }

    /// All profiles present.
    pub fn profiles(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .artifacts
            .values()
            .map(|a| a.profile.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "train_step__small": {
          "file": "train_step__small.hlo.txt",
          "fn": "train_step",
          "profile": "small",
          "inputs": [
            {"shape": [768], "dtype": "float32"},
            {"shape": [32, 768], "dtype": "float32"},
            {"shape": [32], "dtype": "float32"},
            {"shape": [1], "dtype": "float32"}
          ],
          "outputs": [
            {"shape": [768], "dtype": "float32"},
            {"shape": [], "dtype": "float32"}
          ],
          "params": {"b": 32, "d_total": 768, "n": 13}
        },
        "encode_sjlt__small": {
          "file": "encode_sjlt__small.hlo.txt",
          "fn": "encode_sjlt",
          "profile": "small",
          "inputs": [{"shape": [32, 13], "dtype": "float32"},
                     {"shape": [4, 13], "dtype": "int32"},
                     {"shape": [4, 13], "dtype": "float32"}],
          "outputs": [{"shape": [32, 256], "dtype": "float32"}],
          "params": {"b": 32, "sjlt_k": 4}
        }
      },
      "mlp_widths": [512, 256, 64, 16]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.mlp_widths, vec![512, 256, 64, 16]);
        let ts = m.find("train_step", "small").unwrap();
        assert_eq!(ts.inputs.len(), 4);
        assert_eq!(ts.inputs[1].shape, vec![32, 768]);
        assert_eq!(ts.inputs[1].dtype, DType::F32);
        assert_eq!(ts.outputs[1].shape, Vec::<usize>::new());
        assert_eq!(ts.param("b").unwrap(), 32);
        assert!(ts.param("nope").is_err());
    }

    #[test]
    fn dtype_i32_parsed() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let sj = m.find("encode_sjlt", "small").unwrap();
        assert_eq!(sj.inputs[1].dtype, DType::I32);
        assert_eq!(sj.inputs[1].elements(), 52);
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find("train_step", "default").is_err());
    }

    #[test]
    fn profiles_listed() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.profiles(), vec!["small".to_string()]);
    }
}
