//! PJRT runtime: loads the HLO-text artifacts AOT-lowered by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Python never runs here — the rust binary is self-contained once
//! `make artifacts` has produced `artifacts/`.

pub mod executor;
pub mod manifest;

pub use executor::{default_artifacts_dir, load_default, HostOutput, HostTensor, Runtime};
pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};
