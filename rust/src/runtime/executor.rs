//! PJRT execution of the AOT artifacts.
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Executables are compiled once on
//! first use and cached; the streaming hot loop then only pays host→
//! device literal transfer + execution.
//!
//! All code touching the `xla` crate is gated behind the `shdc_xla`
//! rustc cfg (enable with `RUSTFLAGS="--cfg shdc_xla"` after adding the
//! `xla` crate to `[dependencies]` — it is not vendored in the offline
//! image). A cfg rather than a cargo feature keeps `--all-features`
//! builds green while the dependency is absent. Without the cfg,
//! [`Runtime::load`] returns a descriptive error — the `PjrtFused`
//! backend fails cleanly and the runtime integration tests skip, exactly
//! as they do when artifacts are absent.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactSpec, DType, Manifest, TensorSpec};

/// A host-side tensor argument.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32(data, shape.to_vec())
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32(vec![v], vec![1])
    }

    fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::I32(..) => DType::I32,
        }
    }

    #[cfg_attr(not(shdc_xla), allow(dead_code))]
    fn matches(&self, spec: &TensorSpec) -> bool {
        self.dtype() == spec.dtype && self.shape() == spec.shape.as_slice()
    }

    #[cfg(shdc_xla)]
    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v, _) => xla::Literal::vec1(v),
            HostTensor::I32(v, _) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }
}

/// An output tensor pulled back to the host (always f32 in our models).
#[derive(Clone, Debug)]
pub struct HostOutput {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl HostOutput {
    pub fn scalar(&self) -> f32 {
        self.data[0]
    }
}

/// Compiled-executable cache keyed by artifact name.
pub struct Runtime {
    #[cfg(shdc_xla)]
    client: xla::PjRtClient,
    #[cfg_attr(not(shdc_xla), allow(dead_code))]
    dir: PathBuf,
    pub manifest: Manifest,
    #[cfg(shdc_xla)]
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative executions per artifact (metrics surface).
    pub exec_counts: HashMap<String, u64>,
}

impl Runtime {
    /// Open the artifacts directory (must contain manifest.json).
    #[cfg(shdc_xla)]
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new(), exec_counts: HashMap::new() })
    }

    /// Built without the `shdc_xla` cfg: always an error (callers treat it
    /// like missing artifacts and skip / fall back).
    #[cfg(not(shdc_xla))]
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        bail!(
            "PJRT runtime unavailable: shdc was built without the `shdc_xla` \
             cfg (artifacts dir: {dir:?}). Add the `xla` crate to \
             rust/Cargo.toml and build with RUSTFLAGS=\"--cfg shdc_xla\"."
        )
    }

    #[cfg(shdc_xla)]
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    #[cfg(not(shdc_xla))]
    pub fn platform(&self) -> String {
        "disabled (built without the shdc_xla cfg)".to_string()
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))
    }

    /// Compile (or fetch cached) an artifact's executable.
    #[cfg(not(shdc_xla))]
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        bail!("cannot prepare {name}: built without the `shdc_xla` cfg")
    }

    /// Compile (or fetch cached) an artifact's executable.
    #[cfg(shdc_xla)]
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let spec = self.spec(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with shape/dtype-checked inputs.
    #[cfg(not(shdc_xla))]
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostOutput>> {
        let _ = inputs;
        bail!("cannot execute {name}: built without the `shdc_xla` cfg")
    }

    /// Execute an artifact with shape/dtype-checked inputs.
    #[cfg(shdc_xla)]
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostOutput>> {
        self.prepare(name)?;
        let spec = self.spec(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {name} expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (inp, ispec)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if !inp.matches(ispec) {
                bail!(
                    "artifact {name} input {i}: expected {:?} {:?}, got {:?} {:?}",
                    ispec.dtype,
                    ispec.shape,
                    inp.dtype(),
                    inp.shape()
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<_>>()?;
        let exe = self.cache.get(name).expect("prepared above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        // aot.py lowers with return_tuple=True: one tuple output.
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {name}: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {name}: manifest promises {} outputs, runtime returned {}",
                spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, ospec)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output of {name} not f32: {e:?}"))?;
                if data.len() != ospec.elements() {
                    bail!(
                        "artifact {name}: output has {} elements, manifest says {}",
                        data.len(),
                        ospec.elements()
                    );
                }
                Ok(HostOutput { data, shape: ospec.shape.clone() })
            })
            .collect()
    }

    /// Executables currently compiled.
    #[cfg(shdc_xla)]
    pub fn compiled(&self) -> Vec<String> {
        let mut v: Vec<String> = self.cache.keys().cloned().collect();
        v.sort();
        v
    }

    /// Executables currently compiled (none without the `shdc_xla` cfg).
    #[cfg(not(shdc_xla))]
    pub fn compiled(&self) -> Vec<String> {
        Vec::new()
    }
}

/// Locate the artifacts directory: $SHDC_ARTIFACTS, else ./artifacts
/// relative to the workspace root.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SHDC_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Walk up from CWD looking for artifacts/manifest.json.
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// Convenience: load the runtime from the default location with a clear
/// error message if artifacts have not been built.
pub fn load_default() -> Result<Runtime> {
    let dir = default_artifacts_dir();
    Runtime::load(&dir).with_context(|| {
        format!(
            "could not load artifacts from {dir:?}; run `make artifacts` \
             (or set SHDC_ARTIFACTS)"
        )
    })
}
