//! Cycle-level model of the paper's FPGA design (Sec. 6.1, evaluated in
//! Table 2 / Fig. 11 / Sec. 7.4.1).
//!
//! The design is a dataflow pipeline of modules — categorical hash
//! encoding, numeric projection (p coarse partitions x R unrolled rows),
//! and the SGD update (score + gradient), all partitioned over the
//! embedding dimension. The paper's own cycle counts follow from the
//! partition structure; this model reconstructs them from that structure
//! plus small calibration constants (pipeline fill / handshake overheads)
//! fixed once against the published Table 2 and then *held constant
//! across every configuration*, so sweeps over (d, s, k, p, R) remain
//! predictive rather than fitted.
//!
//! We model an Alveo U280-class device (1157k LUT, 2384k FF, 2016 BRAM,
//! 9024 DSP, ~24 W idle).

use crate::encoding::BundleMethod;

/// Device envelope (Alveo U280, from the datasheet row in Fig. 11).
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub luts: u64,
    pub ffs: u64,
    pub brams: u64,
    pub dsps: u64,
    pub idle_watts: f64,
}

pub const ALVEO_U280: Device = Device {
    luts: 1_157_000,
    ffs: 2_384_000,
    brams: 2_016,
    dsps: 9_024,
    idle_watts: 24.0,
};

/// One FPGA build configuration (paper defaults: d=10k, p=5, R per mode).
#[derive(Clone, Debug)]
pub struct FpgaConfig {
    pub combine: BundleMethod,
    /// No-Count = categorical only (Fig. 9 / Table 2's fourth row).
    pub no_count: bool,
    /// Embedding dimension per branch.
    pub d: usize,
    /// Numeric features.
    pub n: usize,
    /// Categorical features.
    pub s: usize,
    /// Hash functions.
    pub k: usize,
    /// Coarse partitions.
    pub p: usize,
    /// Row-unroll per partition.
    pub r: usize,
    /// Achieved frequency in MHz (synthesis result; per-mode constants
    /// from Table 2).
    pub freq_mhz: f64,
}

impl FpgaConfig {
    /// The four Table 2 configurations at d = 10,000.
    pub fn paper(combine: BundleMethod, no_count: bool) -> FpgaConfig {
        let (r, freq) = if no_count {
            (128, 150.0)
        } else {
            match combine {
                BundleMethod::ThresholdedSum => (64, 130.0),
                BundleMethod::Sum => (64, 122.0),
                BundleMethod::Concat => (32, 150.0),
            }
        };
        FpgaConfig {
            combine,
            no_count,
            d: 10_000,
            n: 13,
            s: 26,
            k: 4,
            p: 5,
            r,
            freq_mhz: freq,
        }
    }

    pub fn label(&self) -> &'static str {
        if self.no_count {
            "No-Count"
        } else {
            match self.combine {
                BundleMethod::ThresholdedSum => "OR",
                BundleMethod::Sum => "SUM",
                BundleMethod::Concat => "Concat",
            }
        }
    }
}

/// Calibration constants (cycles), fixed against Table 2 once.
mod cal {
    /// Pipeline fill + FIFO handshake for the categorical hash unit.
    pub const CAT_PIPE: u64 = 10;
    /// Extra read-modify-write + hazard stalls for SUM's multi-bit
    /// categorical embedding (Table 2's OR-vs-SUM gap).
    pub const CAT_SUM_HAZARD: u64 = 15;
    /// Output-FIFO drain charged to the categorical stage in No-Count
    /// (Table 2 note: "the phi(x_c) column in case of No-Count").
    pub const CAT_PIPE_NOCOUNT: u64 = 12;
    /// Accumulator pipeline depth for the numeric dot-product tree.
    pub const NUM_PIPE: u64 = 16;
    /// Reduction tree latency for score / gradient stages.
    pub const DOT_PIPE: u64 = 4;
    pub const DOT_SUM_EXTRA: u64 = 5;
    pub const GRAD_PIPE: u64 = 3;
    /// Dataflow handshake inefficiency (fraction of the bottleneck stage).
    pub const HANDSHAKE: f64 = 0.12;
    /// Shift-materialization: cycles to rebuild one level vector from a
    /// DRAM-resident seed (Sec. 7.4.1: "~500 cycles").
    pub const SHIFT_MATERIALIZE: u64 = 500;
}

/// Per-module cycle counts (the Table 2 columns).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CycleBreakdown {
    pub cat_encode: u64,
    pub num_encode: Option<u64>,
    pub score: u64,
    pub gradient: u64,
}

impl CycleBreakdown {
    /// Dataflow latency: max of the encode phase and update phase, with
    /// the handshake factor.
    pub fn effective_cycles(&self) -> f64 {
        let encode = self.cat_encode + self.num_encode.unwrap_or(0);
        let update = self.score + self.gradient;
        (encode.max(update)) as f64 * (1.0 + cal::HANDSHAKE)
    }
}

#[derive(Clone, Debug)]
pub struct FpgaReport {
    pub config: FpgaConfig,
    pub cycles: CycleBreakdown,
    /// Inputs processed per second (encode + update, Table 2 rightmost).
    pub throughput: f64,
    pub utilization: Utilization,
    pub power_watts: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct Utilization {
    pub luts: f64,
    pub ffs: f64,
    pub brams: f64,
    pub dsps: f64,
}

/// Simulate one configuration.
pub fn simulate(cfg: &FpgaConfig) -> FpgaReport {
    let cycles = cycle_model(cfg);
    let eff = cycles.effective_cycles();
    let throughput = cfg.freq_mhz * 1e6 / eff;
    let utilization = resource_model(cfg);
    let power_watts = power_model(cfg, &utilization);
    FpgaReport { config: cfg.clone(), cycles, throughput, utilization, power_watts }
}

fn div_ceil(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

fn cycle_model(cfg: &FpgaConfig) -> CycleBreakdown {
    let (d, n, s, k, p, r) = (
        cfg.d as u64,
        cfg.n as u64,
        cfg.s as u64,
        cfg.k as u64,
        cfg.p as u64,
        cfg.r as u64,
    );
    let _ = n; // numeric width is fully unrolled (one row/cycle/partition)

    // Categorical: k hashes per symbol, p partitions absorb k/p writes in
    // parallel (Sec. 6.1: "s x k/p x t_psi cycles" at 1 hash/cycle).
    let cat_base = div_ceil(s * k, p);
    let cat_encode = if cfg.no_count {
        // Includes the output-FIFO write of the (partitioned) vector.
        cat_base + div_ceil(d, p * r) + cal::CAT_PIPE_NOCOUNT
    } else {
        match cfg.combine {
            BundleMethod::Sum => div_ceil(2 * s * k, p) + cal::CAT_SUM_HAZARD,
            _ => cat_base + cal::CAT_PIPE,
        }
    };

    // Numeric: p*R rows of Phi retire per cycle (inner loop fully
    // unrolled), plus accumulator pipeline fill.
    let num_encode = if cfg.no_count {
        None
    } else {
        Some(div_ceil(d, p * r) + cal::NUM_PIPE)
    };

    // Update: dot(theta, phi) over the bundled dimension, p*R lanes.
    // Concat halves work per lane because both halves run in parallel
    // (Sec. 7.4.1 discussion of Table 2).
    let lanes = p * r;
    let score_len = match (cfg.no_count, cfg.combine) {
        (true, _) => d,
        (false, BundleMethod::Concat) => d, // two d-halves in parallel
        (false, _) => d,
    };
    let score = div_ceil(score_len, lanes)
        + cal::DOT_PIPE
        + if !cfg.no_count && cfg.combine == BundleMethod::Sum {
            cal::DOT_SUM_EXTRA
        } else {
            0
        };
    let gradient = div_ceil(score_len, lanes) + cal::GRAD_PIPE;

    CycleBreakdown { cat_encode, num_encode, score, gradient }
}

/// Structural resource model. DSPs follow the multiply lanes; LUT/FF
/// follow partition plumbing and vector width; BRAM follows stored state
/// (Phi + theta + FIFOs).
fn resource_model(cfg: &FpgaConfig) -> Utilization {
    let dev = ALVEO_U280;
    let lanes = (cfg.p * cfg.r) as f64;
    let total_dim = match (cfg.no_count, cfg.combine) {
        (true, _) => cfg.d as f64,
        (false, BundleMethod::Concat) => 2.0 * cfg.d as f64,
        (false, _) => cfg.d as f64,
    };
    // DSPs: one MAC per unrolled numeric lane per feature-pair, plus the
    // update dot-product lanes; SUM needs wider categorical accumulate.
    let dsp = if cfg.no_count {
        lanes * 2.0
    } else {
        lanes * cfg.n as f64 * 0.55
            + lanes * 2.0
            + if cfg.combine == BundleMethod::Sum { lanes * 1.5 } else { 0.0 }
    };
    // LUT/FF: per-lane datapath + per-dim vector registers/muxing.
    // No-Count lanes carry no MAC datapath, so they are much cheaper
    // (the paper: "uses considerably less resources").
    let (lane_lut, lane_ff, base_lut, base_ff) = if cfg.no_count {
        (180.0, 300.0, 60_000.0, 80_000.0)
    } else {
        (420.0, 700.0, 150_000.0, 120_000.0)
    };
    let lut = base_lut + lanes * lane_lut + total_dim * 18.0;
    let ff = base_ff + lanes * lane_ff + total_dim * 26.0;
    // BRAM: Phi storage (d x n x 16b over p*R banks), theta, FIFOs.
    let bram = if cfg.no_count {
        120.0 + total_dim * 0.012
    } else {
        160.0 + (cfg.d * cfg.n) as f64 * 16.0 / 36_864.0 + total_dim * 0.012
    };
    Utilization {
        luts: (lut / dev.luts as f64).min(0.95),
        ffs: (ff / dev.ffs as f64).min(0.95),
        brams: (bram / dev.brams as f64).min(0.95),
        dsps: (dsp / dev.dsps as f64).min(0.95),
    }
}

/// Idle + dynamic power: dynamic scales with utilization x frequency
/// (lands in the paper's 26-31 W envelope for the Table 2 configs).
fn power_model(cfg: &FpgaConfig, u: &Utilization) -> f64 {
    let dev = ALVEO_U280;
    let activity = (u.luts + u.ffs + u.dsps + u.brams) / 4.0;
    dev.idle_watts + activity * (cfg.freq_mhz / 150.0) * 23.0
}

/// Sec. 7.4.1's shift-based materialization baseline: per input, each of
/// the s categorical features rebuilds a level vector from a seed
/// (~500 cycles incl. DRAM read), which bottlenecks every combine mode.
pub fn simulate_shift_baseline(cfg: &FpgaConfig) -> FpgaReport {
    let mut rep = simulate(cfg);
    let materialize = cfg.s as u64 * cal::SHIFT_MATERIALIZE;
    rep.cycles.cat_encode = materialize;
    let eff = rep.cycles.effective_cycles();
    rep.throughput = cfg.freq_mhz * 1e6 / eff;
    rep
}

/// The paper's Table 2 reference values (for tests / reports).
pub struct Table2Row {
    pub label: &'static str,
    pub freq_mhz: f64,
    pub cat: u64,
    pub num: Option<u64>,
    pub score: u64,
    pub grad: u64,
    pub throughput_m: f64,
}

pub const TABLE2_PAPER: [Table2Row; 4] = [
    Table2Row { label: "OR", freq_mhz: 130.0, cat: 31, num: Some(48), score: 35, grad: 34, throughput_m: 1.51 },
    Table2Row { label: "SUM", freq_mhz: 122.0, cat: 57, num: Some(48), score: 40, grad: 34, throughput_m: 1.08 },
    Table2Row { label: "Concat", freq_mhz: 150.0, cat: 31, num: Some(80), score: 67, grad: 66, throughput_m: 0.94 },
    Table2Row { label: "No-Count", freq_mhz: 150.0, cat: 49, num: None, score: 20, grad: 18, throughput_m: 2.69 },
];

/// All four paper configurations, simulated.
pub fn table2() -> Vec<FpgaReport> {
    vec![
        simulate(&FpgaConfig::paper(BundleMethod::ThresholdedSum, false)),
        simulate(&FpgaConfig::paper(BundleMethod::Sum, false)),
        simulate(&FpgaConfig::paper(BundleMethod::Concat, false)),
        simulate(&FpgaConfig::paper(BundleMethod::ThresholdedSum, true)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pct_err(got: f64, want: f64) -> f64 {
        (got - want).abs() / want
    }

    #[test]
    fn table2_cycle_counts_close_to_paper() {
        for (rep, want) in table2().iter().zip(&TABLE2_PAPER) {
            assert_eq!(rep.config.label(), want.label);
            assert!(
                pct_err(rep.cycles.cat_encode as f64, want.cat as f64) < 0.20,
                "{}: cat {} vs {}",
                want.label,
                rep.cycles.cat_encode,
                want.cat
            );
            if let (Some(gn), Some(wn)) = (rep.cycles.num_encode, want.num) {
                assert!(
                    pct_err(gn as f64, wn as f64) < 0.20,
                    "{}: num {gn} vs {wn}",
                    want.label
                );
            } else {
                assert_eq!(rep.cycles.num_encode.is_none(), want.num.is_none());
            }
            assert!(
                pct_err(rep.cycles.score as f64, want.score as f64) < 0.25,
                "{}: score {} vs {}",
                want.label,
                rep.cycles.score,
                want.score
            );
            assert!(
                pct_err(rep.cycles.gradient as f64, want.grad as f64) < 0.25,
                "{}: grad {} vs {}",
                want.label,
                rep.cycles.gradient,
                want.grad
            );
        }
    }

    #[test]
    fn table2_throughput_ordering_and_scale() {
        let reps = table2();
        let t: Vec<f64> = reps.iter().map(|r| r.throughput).collect();
        // Paper ordering: No-Count > OR > SUM > Concat.
        assert!(t[3] > t[0] && t[0] > t[1] && t[1] > t[2], "{t:?}");
        for (rep, want) in reps.iter().zip(&TABLE2_PAPER) {
            assert!(
                pct_err(rep.throughput, want.throughput_m * 1e6) < 0.35,
                "{}: {:.2}M vs {:.2}M",
                want.label,
                rep.throughput / 1e6,
                want.throughput_m
            );
        }
    }

    #[test]
    fn power_in_paper_envelope() {
        for rep in table2() {
            assert!(
                rep.power_watts > 25.0 && rep.power_watts < 32.0,
                "{}: {:.1} W",
                rep.config.label(),
                rep.power_watts
            );
        }
        // No-Count draws the least (paper: 26 W min), OR the most (31 W).
        let reps = table2();
        assert!(reps[3].power_watts < reps[0].power_watts);
    }

    #[test]
    fn utilization_sane_and_concat_uses_fewest_dsps() {
        let reps = table2();
        for r in &reps {
            let u = r.utilization;
            for v in [u.luts, u.ffs, u.brams, u.dsps] {
                assert!(v > 0.0 && v < 1.0);
            }
        }
        // Paper: Concat uses fewer DSPs (half parallelism), No-Count fewest.
        assert!(reps[2].utilization.dsps < reps[0].utilization.dsps);
        assert!(reps[3].utilization.dsps < reps[2].utilization.dsps);
    }

    #[test]
    fn shift_baseline_slowdown_matches_paper_ratios() {
        // Paper: 84x slower than Concat, 135x slower than OR.
        let or = simulate(&FpgaConfig::paper(BundleMethod::ThresholdedSum, false));
        let concat = simulate(&FpgaConfig::paper(BundleMethod::Concat, false));
        let shift_or = simulate_shift_baseline(&FpgaConfig::paper(BundleMethod::ThresholdedSum, false));
        let shift_concat = simulate_shift_baseline(&FpgaConfig::paper(BundleMethod::Concat, false));
        assert!(
            shift_or.throughput < 15_000.0,
            "shift throughput ~11.2k/s, got {:.0}",
            shift_or.throughput
        );
        let slow_or = or.throughput / shift_or.throughput;
        let slow_concat = concat.throughput / shift_concat.throughput;
        assert!(slow_or > 80.0 && slow_or < 200.0, "OR slowdown {slow_or:.0}");
        assert!(slow_concat > 50.0 && slow_concat < 130.0, "Concat slowdown {slow_concat:.0}");
        assert!(slow_or > slow_concat, "OR ratio must exceed Concat ratio");
    }

    #[test]
    fn throughput_scales_with_parallelism() {
        let base = FpgaConfig::paper(BundleMethod::ThresholdedSum, false);
        let mut wider = base.clone();
        wider.r = 128;
        assert!(simulate(&wider).throughput > simulate(&base).throughput);
        let mut narrower = base.clone();
        narrower.r = 16;
        assert!(simulate(&narrower).throughput < simulate(&base).throughput);
    }

    #[test]
    fn bigger_d_means_slower() {
        let base = FpgaConfig::paper(BundleMethod::Concat, false);
        let mut big = base.clone();
        big.d = 20_000;
        assert!(simulate(&big).throughput < simulate(&base).throughput);
    }
}
