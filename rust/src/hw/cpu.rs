//! CPU baseline measurement (the denominator in Figs. 12–13).
//!
//! The paper's CPU numbers come from an i7-8700K running its
//! TensorFlow + C-extension pipeline; ours come from actually running
//! this crate's encoders on the local machine. Reports therefore show
//! *measured* local throughput next to the paper's reference CPU
//! throughput (back-derived from its speedup ratios), and comparisons
//! are made on ratios, not absolute rates. A calibrated `paper_cpu`
//! constant keeps the FPGA/PIM-vs-CPU ratio reproduction honest about
//! which numbers are ours and which are the paper's.

use std::time::Instant;

use crate::coordinator::{CatCfg, EncoderCfg, NumCfg};
use crate::data::synthetic::SyntheticConfig;
use crate::data::{RecordStream, SyntheticStream};
use crate::encoding::BundleMethod;

/// Paper-reference CPU encoding throughput (inputs/sec), back-derived
/// from Sec. 7.4.3: FPGA is 81x CPU with numeric+categorical and 11x
/// without; FPGA encode-only rates are ~2.7M/s (OR cycle model).
pub const PAPER_CPU_FULL: f64 = 27_000.0;
pub const PAPER_CPU_NOCOUNT: f64 = 245_000.0;
/// Paper CPU power during encoding (Sec. 7.4.3).
pub const PAPER_CPU_WATTS: f64 = 88.0;

#[derive(Clone, Copy, Debug)]
pub struct CpuMeasurement {
    /// Measured single-thread encode throughput (records/sec).
    pub records_per_sec: f64,
    pub records: u64,
    pub elapsed_s: f64,
}

/// Measure this machine's single-thread encode throughput for a given
/// encoder configuration (the honest local "CPU" bar in Fig. 12).
pub fn measure_encode(cfg: &EncoderCfg, records: u64, seed: u64) -> CpuMeasurement {
    let data = SyntheticConfig {
        alphabet_size: 10_000_000,
        ..SyntheticConfig::sampled(seed)
    };
    let mut stream = SyntheticStream::new(data);
    let mut enc = cfg.build();
    // Pre-materialize records so stream generation is not measured.
    let recs: Vec<_> = (0..records).map(|_| stream.next_record().unwrap()).collect();
    let t0 = Instant::now();
    let mut sink = 0usize;
    for r in &recs {
        sink = sink.wrapping_add(enc.encode(r).nnz());
    }
    std::hint::black_box(sink);
    let dt = t0.elapsed().as_secs_f64();
    CpuMeasurement {
        records_per_sec: records as f64 / dt,
        records,
        elapsed_s: dt,
    }
}

/// The paper's two encode workloads (Fig. 12): full (numeric d=10k dense
/// projection + categorical bloom d=10k k=4) and No-Count.
pub fn paper_workload(no_count: bool, seed: u64) -> EncoderCfg {
    EncoderCfg {
        cat: CatCfg::Bloom { d: 10_000, k: 4 },
        num: if no_count { NumCfg::None } else { NumCfg::DenseSign { d: 10_000 } },
        bundle: BundleMethod::ThresholdedSum,
        n_numeric: 13,
        seed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_nonzero_throughput() {
        let cfg = EncoderCfg {
            cat: CatCfg::Bloom { d: 1_000, k: 4 },
            num: NumCfg::None,
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 1,
        };
        let m = measure_encode(&cfg, 2_000, 1);
        assert!(m.records_per_sec > 10_000.0, "suspiciously slow: {m:?}");
        assert_eq!(m.records, 2_000);
    }

    #[test]
    fn no_count_faster_than_full() {
        // Dropping the d=10k numeric projection must speed encoding up a
        // lot (the paper sees the same asymmetry on CPU).
        let full = measure_encode(&paper_workload(false, 2), 300, 2);
        let nc = measure_encode(&paper_workload(true, 2), 300, 2);
        assert!(
            nc.records_per_sec > 3.0 * full.records_per_sec,
            "no-count {:.0}/s vs full {:.0}/s",
            nc.records_per_sec,
            full.records_per_sec
        );
    }
}
