//! Hardware evaluation substrate (paper Sec. 6–7.4).
//!
//! The paper's hardware results come from an Alveo U280 FPGA and a
//! simulated ReRAM PIM chip; neither is present here, so both are
//! modeled as cycle-level simulators built from the papers' own
//! architectural formulas, with small calibration constants fixed once
//! against the published tables (see DESIGN.md §3). The CPU baseline is
//! *measured* on this machine using this crate's real encoders.
//!
//! * [`fpga`] — dataflow pipeline model (Table 2, Fig. 11, the Sec. 7.4.1
//!   shift-materialization baseline).
//! * [`pim`]  — crossbar/cluster/tile model (Tables 3–4).
//! * [`cpu`]  — local measurement + the paper's reference CPU constants
//!   (Figs. 12–13 ratios).

pub mod cpu;
pub mod fpga;
pub mod pim;

/// Fig. 12/13-style comparison row.
#[derive(Clone, Debug)]
pub struct PlatformRow {
    pub platform: String,
    pub throughput: f64,
    pub watts: f64,
}

impl PlatformRow {
    pub fn per_watt(&self) -> f64 {
        self.throughput / self.watts
    }
}

/// Render rows with speedup/efficiency ratios against the first row
/// (which is conventionally the CPU).
pub fn comparison_table(rows: &[PlatformRow]) -> String {
    let mut out = String::new();
    let base = &rows[0];
    out.push_str(&format!(
        "{:<12} {:>16} {:>10} {:>16} {:>12}\n",
        "platform", "inputs/s", "speedup", "inputs/s/W", "perf/W gain"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:>16.3e} {:>9.1}x {:>16.3e} {:>11.1}x\n",
            r.platform,
            r.throughput,
            r.throughput / base.throughput,
            r.per_watt(),
            r.per_watt() / base.per_watt(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comparison_table_formats_ratios() {
        let rows = vec![
            PlatformRow { platform: "CPU".into(), throughput: 1e5, watts: 88.0 },
            PlatformRow { platform: "FPGA".into(), throughput: 8.1e6, watts: 30.0 },
        ];
        let t = comparison_table(&rows);
        assert!(t.contains("81.0x"), "{t}");
        assert!(t.contains("CPU") && t.contains("FPGA"));
    }
}
