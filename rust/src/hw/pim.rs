//! Model of the paper's processing-in-memory architecture (Sec. 6.2,
//! evaluated in Tables 3–4 and Figs. 12–13).
//!
//! ReRAM crossbars of 128x128 cells, 8 vertical 16-bit lanes per
//! crossbar, 8 crossbars per cluster, 8 clusters per tile, 512 tiles
//! (32,768 crossbars / 512 Mbit). One memory cycle = 100 ns. Bit-serial
//! dot products take (input bits + 1) cycles per pass; bundling senses a
//! whole bitline per cycle. Component area/power are the paper's Table 3
//! constants (14 nm synthesis + scaled ADC); the hierarchy roll-ups are
//! *derived* here and checked against the paper's own totals in tests.

/// Geometry constants (Sec. 6.2 / 7.4.2).
pub const XBAR_ROWS: usize = 128;
pub const XBAR_COLS: usize = 128;
pub const LANES_PER_XBAR: usize = 8;
pub const LANE_BITS: usize = 16;
pub const XBARS_PER_CLUSTER: usize = 8;
pub const CLUSTERS_PER_TILE: usize = 8;
pub const TILES: usize = 512;
pub const MEMORY_CYCLE_NS: f64 = 100.0;
/// Input activations applied bit-serially at this precision (the paper's
/// numeric-encoding latency implies 8-bit inputs: (8+1) x 9 = 81 cycles).
pub const INPUT_BITS: usize = 8;

pub const TOTAL_XBARS: usize = XBARS_PER_CLUSTER * CLUSTERS_PER_TILE * TILES;

/// Table 3 component constants: (area um^2, power uW).
#[derive(Clone, Copy, Debug)]
pub struct Component {
    pub name: &'static str,
    pub area_um2: f64,
    pub power_uw: f64,
    pub count_per_xbar: f64,
}

/// Per-crossbar component inventory (Table 3 left+right columns).
pub const XBAR_COMPONENTS: [Component; 8] = [
    Component { name: "128x128 array", area_um2: 25.0, power_uw: 300.0, count_per_xbar: 1.0 },
    Component { name: "ADC", area_um2: 570.0, power_uw: 1451.0, count_per_xbar: 1.0 },
    Component { name: "DAC (x256)", area_um2: 136.0, power_uw: 5.4, count_per_xbar: 1.0 },
    Component { name: "S&H (x128)", area_um2: 5.0, power_uw: 1.0, count_per_xbar: 1.0 },
    Component { name: "Lane peripheral", area_um2: 310.0, power_uw: 3.1, count_per_xbar: 8.0 },
    Component { name: "Drive register (x2)", area_um2: 143.0, power_uw: 2.1, count_per_xbar: 2.0 },
    Component { name: "Hash", area_um2: 839.0, power_uw: 8.8, count_per_xbar: 0.125 },
    Component { name: "Decoder", area_um2: 26.0, power_uw: 0.02, count_per_xbar: 0.125 },
];

/// Cluster-level components (shared: registers, router).
pub const CLUSTER_COMPONENTS: [Component; 3] = [
    Component { name: "Output register", area_um2: 1646.0, power_uw: 634.0, count_per_xbar: 1.0 },
    Component { name: "Input register", area_um2: 2514.0, power_uw: 1011.0, count_per_xbar: 1.0 },
    Component { name: "Router", area_um2: 2209.0, power_uw: 459.0, count_per_xbar: 1.0 },
];

#[derive(Clone, Copy, Debug)]
pub struct AreaPower {
    pub area_mm2: f64,
    pub power_w: f64,
}

/// Roll up crossbar / cluster / tile / chip area+power (Table 3 bottom).
pub fn hierarchy() -> (AreaPower, AreaPower, AreaPower, AreaPower) {
    let xbar_um2: f64 = XBAR_COMPONENTS
        .iter()
        .map(|c| c.area_um2 * c.count_per_xbar)
        .sum();
    let xbar_uw: f64 = XBAR_COMPONENTS
        .iter()
        .map(|c| c.power_uw * c.count_per_xbar)
        .sum();
    let cluster_um2 = xbar_um2 * XBARS_PER_CLUSTER as f64
        + CLUSTER_COMPONENTS.iter().map(|c| c.area_um2).sum::<f64>();
    let cluster_uw = xbar_uw * XBARS_PER_CLUSTER as f64
        + CLUSTER_COMPONENTS.iter().map(|c| c.power_uw).sum::<f64>();
    let tile_um2 = cluster_um2 * CLUSTERS_PER_TILE as f64;
    let tile_uw = cluster_uw * CLUSTERS_PER_TILE as f64;
    let chip_um2 = tile_um2 * TILES as f64;
    let chip_uw = tile_uw * TILES as f64;
    (
        AreaPower { area_mm2: xbar_um2 / 1e6, power_w: xbar_uw / 1e6 },
        AreaPower { area_mm2: cluster_um2 / 1e6, power_w: cluster_uw / 1e6 },
        AreaPower { area_mm2: tile_um2 / 1e6, power_w: tile_uw / 1e6 },
        AreaPower { area_mm2: chip_um2 / 1e6, power_w: chip_uw / 1e6 },
    )
}

/// Workload parameters for the PIM encoding evaluation (paper defaults).
#[derive(Clone, Debug)]
pub struct PimWorkload {
    pub d: usize,
    pub n: usize,
    pub s: usize,
    /// Include the numeric branch (false = No-Count).
    pub numeric: bool,
    /// Crossbars allocated to categorical level vectors per input; the
    /// paper over-allocates (40 vs the minimal ~16) to balance against
    /// the numeric branch's 81 cycles. None = balance automatically.
    pub cat_xbars_override: Option<usize>,
}

impl PimWorkload {
    pub fn paper(numeric: bool) -> PimWorkload {
        PimWorkload { d: 10_000, n: 13, s: 26, numeric, cat_xbars_override: None }
    }
}

#[derive(Clone, Debug)]
pub struct PimReport {
    pub workload: PimWorkload,
    pub numeric_xbars: Option<usize>,
    pub cat_xbars: usize,
    pub numeric_utilization: Option<f64>,
    pub cat_utilization: f64,
    pub numeric_cycles: Option<u64>,
    pub cat_cycles: u64,
    /// End-to-end encode throughput using the whole chip (inputs/sec).
    pub throughput: f64,
    pub chip_power_w: f64,
}

/// Calibration constants, fixed once against Table 4.
mod cal {
    /// Hash/decoder pipeline fill + driver-register staging per encode
    /// (three-stage Murmur3 pipeline, row-driver setup; Sec. 6.2.3).
    pub const CAT_PIPE: u64 = 27;
    /// Output-register transfer charged to the categorical stage when it
    /// is not hidden behind the numeric branch (No-Count): one cycle per
    /// feature's bundled chunk.
    pub const NOCOUNT_DRAIN_PER_S: u64 = 1;
}

fn div_ceil(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Numeric branch: Phi is (d x n) 16-bit, one Phi row per lane segment of
/// n memory rows => floor(128/n) Phi rows per lane, 8 lanes per crossbar.
fn numeric_alloc(w: &PimWorkload) -> (usize, f64, u64) {
    let rows_per_lane = XBAR_ROWS / w.n; // Phi rows co-resident per lane
    let phi_rows_per_xbar = rows_per_lane * LANES_PER_XBAR;
    let xbars = div_ceil(w.d, phi_rows_per_xbar);
    // Paper allocates in cluster granularity (multiples of 8): 144 for
    // d=10k, n=13.
    let xbars = div_ceil(xbars, XBARS_PER_CLUSTER) * XBARS_PER_CLUSTER;
    let used_rows = w.n * rows_per_lane;
    let utilization = used_rows as f64 / XBAR_ROWS as f64;
    // Each co-resident Phi-row group needs its own bit-serial pass
    // (unwanted current aggregation otherwise): (bits+1) x groups.
    let cycles = ((INPUT_BITS + 1) * rows_per_lane) as u64;
    (xbars, utilization, cycles)
}

/// Categorical branch layout (paper Fig. 5): the d-bit level vectors are
/// split into chunks of 128 bits; a chunk-group is the same 128 positions
/// of all s vectors, interleaved on s consecutive rows so the same index
/// of different vectors shares a bitline (required for one-cycle
/// bundling). A crossbar holds `cpx` chunk-groups = `cpx * s` rows.
///
/// Returns (xbars, utilization, cycles) for a given chunks-per-crossbar.
fn cat_alloc(w: &PimWorkload, cpx: usize) -> (usize, f64, u64) {
    let chunks = div_ceil(w.d, XBAR_COLS);
    let cpx = cpx.max(1).min((XBAR_ROWS / w.s).max(1));
    let xbars = div_ceil(chunks, cpx);
    let rows_used = cpx * w.s;
    let utilization = rows_used as f64 / XBAR_ROWS as f64;
    // One cycle per used row to write the hashed bits (decoder drives one
    // one-hot write per partition; all crossbars in parallel), then one
    // bundling activation per chunk-group, plus the fixed pipeline.
    let mut cycles = rows_used as u64 + cpx as u64 + cal::CAT_PIPE;
    if !w.numeric {
        cycles += w.s as u64 * cal::NOCOUNT_DRAIN_PER_S;
    }
    (xbars, utilization, cycles)
}

pub fn simulate(w: &PimWorkload) -> PimReport {
    let (num_xbars, num_util, num_cycles) = if w.numeric {
        let (x, u, c) = numeric_alloc(w);
        (Some(x), Some(u), Some(c))
    } else {
        (None, None, None)
    };

    // Choose the chunk packing density: densest (fewest crossbars) by
    // default, loosened until the categorical latency fits at-or-below
    // the numeric latency (the paper's balancing rule), or derived from
    // an explicit crossbar override.
    let chunks = div_ceil(w.d, XBAR_COLS);
    let max_cpx = (XBAR_ROWS / w.s).max(1);
    let cpx = match (w.cat_xbars_override, num_cycles) {
        (Some(x), _) => div_ceil(chunks, x.max(1)),
        (None, None) => max_cpx,
        (None, Some(target)) => {
            let mut cpx = max_cpx;
            while cpx > 1 && cat_alloc(w, cpx).2 > target {
                cpx -= 1;
            }
            cpx
        }
    };
    let (cat_xbars, cat_util, cat_cycles) = cat_alloc(w, cpx);

    // Throughput: the chip processes floor(total / per-input) inputs
    // concurrently; latency is the slower branch (they run concurrently).
    let per_input = cat_xbars + num_xbars.unwrap_or(0);
    let concurrent = TOTAL_XBARS / per_input;
    let latency_cycles = cat_cycles.max(num_cycles.unwrap_or(0));
    let latency_s = latency_cycles as f64 * MEMORY_CYCLE_NS * 1e-9;
    let throughput = concurrent as f64 / latency_s;

    let (_, _, _, chip) = hierarchy();
    PimReport {
        workload: w.clone(),
        numeric_xbars: num_xbars,
        cat_xbars,
        numeric_utilization: num_util,
        cat_utilization: cat_util,
        numeric_cycles: num_cycles,
        cat_cycles,
        throughput,
        chip_power_w: chip.power_w,
    }
}

/// Paper Table 4 reference values.
pub struct Table4Row {
    pub label: &'static str,
    pub num_xbars: Option<usize>,
    pub cat_xbars: usize,
    pub num_util: Option<f64>,
    pub cat_util: f64,
    pub num_cycles: Option<u64>,
    pub cat_cycles: u64,
    pub throughput_m: f64,
}

pub const TABLE4_PAPER: [Table4Row; 2] = [
    Table4Row {
        label: "OR/SUM",
        num_xbars: Some(144),
        cat_xbars: 40,
        num_util: Some(0.91),
        cat_util: 0.41,
        num_cycles: Some(81),
        cat_cycles: 80,
        throughput_m: 21.97,
    },
    Table4Row {
        label: "No-Count",
        num_xbars: None,
        cat_xbars: 20,
        num_util: None,
        cat_util: 0.81,
        num_cycles: None,
        cat_cycles: 132,
        throughput_m: 103.41,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    fn pct(got: f64, want: f64) -> f64 {
        (got - want).abs() / want
    }

    #[test]
    fn table3_hierarchy_matches_paper() {
        let (xbar, cluster, tile, chip) = hierarchy();
        // Paper: crossbar 3502 um^2 / 1.79 mW.
        assert!(pct(xbar.area_mm2 * 1e6, 3502.0) < 0.10, "xbar area {}", xbar.area_mm2 * 1e6);
        assert!(pct(xbar.power_w * 1e3, 1.79) < 0.10, "xbar power {}", xbar.power_w * 1e3);
        // Cluster 33042 um^2 / 15.9 mW.
        assert!(pct(cluster.area_mm2 * 1e6, 33042.0) < 0.10, "cluster {}", cluster.area_mm2 * 1e6);
        assert!(pct(cluster.power_w * 1e3, 15.9) < 0.10, "cluster {}", cluster.power_w * 1e3);
        // Tile 0.264 mm^2 / 127.6 mW.
        assert!(pct(tile.area_mm2, 0.264) < 0.10, "tile {}", tile.area_mm2);
        assert!(pct(tile.power_w * 1e3, 127.6) < 0.10, "tile {}", tile.power_w * 1e3);
        // Chip 136 mm^2 / 65 W.
        assert!(pct(chip.area_mm2, 136.0) < 0.10, "chip {}", chip.area_mm2);
        assert!(pct(chip.power_w, 65.0) < 0.10, "chip {}", chip.power_w);
    }

    #[test]
    fn table4_or_sum_allocation() {
        let rep = simulate(&PimWorkload::paper(true));
        let want = &TABLE4_PAPER[0];
        assert!(
            pct(rep.numeric_xbars.unwrap() as f64, want.num_xbars.unwrap() as f64) < 0.10,
            "num xbars {}",
            rep.numeric_xbars.unwrap()
        );
        assert!(pct(rep.numeric_utilization.unwrap(), want.num_util.unwrap()) < 0.05);
        assert_eq!(rep.numeric_cycles.unwrap(), want.num_cycles.unwrap());
        assert!(
            pct(rep.cat_xbars as f64, want.cat_xbars as f64) < 0.25,
            "cat xbars {}",
            rep.cat_xbars
        );
        assert!(pct(rep.cat_utilization, want.cat_util) < 0.25, "cat util {}", rep.cat_utilization);
        assert!(pct(rep.cat_cycles as f64, want.cat_cycles as f64) < 0.15, "cat cycles {}", rep.cat_cycles);
        assert!(
            pct(rep.throughput, want.throughput_m * 1e6) < 0.20,
            "throughput {:.2}M vs {}M",
            rep.throughput / 1e6,
            want.throughput_m
        );
    }

    #[test]
    fn table4_no_count() {
        let rep = simulate(&PimWorkload::paper(false));
        let want = &TABLE4_PAPER[1];
        assert!(pct(rep.cat_xbars as f64, want.cat_xbars as f64) < 0.25, "cat xbars {}", rep.cat_xbars);
        assert!(pct(rep.cat_utilization, want.cat_util) < 0.10, "util {}", rep.cat_utilization);
        assert!(pct(rep.cat_cycles as f64, want.cat_cycles as f64) < 0.25, "cycles {}", rep.cat_cycles);
        assert!(
            pct(rep.throughput, want.throughput_m * 1e6) < 0.30,
            "throughput {:.2}M vs {}M",
            rep.throughput / 1e6,
            want.throughput_m
        );
    }

    #[test]
    fn no_count_much_faster_than_full() {
        let full = simulate(&PimWorkload::paper(true));
        let nc = simulate(&PimWorkload::paper(false));
        let ratio = nc.throughput / full.throughput;
        assert!(ratio > 3.0 && ratio < 8.0, "ratio {ratio}");
    }

    #[test]
    fn over_allocating_cat_reduces_cycles_but_not_throughput() {
        // Paper: "assigning more crossbars decreases the number of cycles,
        // but the overall throughput diminishes" (No-Count discussion).
        let base = simulate(&PimWorkload::paper(false));
        let mut w = PimWorkload::paper(false);
        w.cat_xbars_override = Some(base.cat_xbars * 4);
        let fat = simulate(&w);
        assert!(fat.cat_cycles < base.cat_cycles);
        assert!(fat.throughput < base.throughput);
    }

    #[test]
    fn total_xbar_count() {
        assert_eq!(TOTAL_XBARS, 32_768);
    }

    #[test]
    fn bigger_d_needs_more_crossbars() {
        let small = simulate(&PimWorkload { d: 5_000, ..PimWorkload::paper(true) });
        let big = simulate(&PimWorkload { d: 20_000, ..PimWorkload::paper(true) });
        assert!(big.numeric_xbars.unwrap() > small.numeric_xbars.unwrap());
        assert!(big.throughput < small.throughput);
    }
}
