//! Synthetic Criteo-like stream with a planted affine ground truth.
//!
//! Substitution for the proprietary Criteo CTR datasets (DESIGN.md §3).
//! The paper's Sec. 3 data model is
//!
//! ```text
//! y = sign( theta_n . x_n  +  theta_c . b(x_c)  +  nu )
//! ```
//!
//! and its theory ties encoder quality to the geometric margin gamma of
//! that affine rule. This generator *instantiates the data model
//! directly*: numeric features are correlated gaussians, each categorical
//! slot draws a symbol from its own Zipf-distributed alphabet (disjoint
//! alphabets, Sec. 3), symbol weights theta_c(a) are deterministic
//! pseudo-random values keyed by the symbol id, and the label is the
//! planted affine score plus logistic noise. Knobs: alphabet size m,
//! noise (margin), positive-class rate (the 1TB dataset's 96/4 skew,
//! Sec. 7.5), and the fraction of symbol mass that is informative.

use super::{Record, RecordStream, CRITEO_CATEGORICAL, CRITEO_NUMERIC};
use crate::util::rng::{mix64, Rng, Zipf};

#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    pub n_numeric: usize,
    pub s_categorical: usize,
    /// Total alphabet size m across all categorical slots.
    pub alphabet_size: u64,
    /// Zipf exponent for symbol popularity within each slot.
    pub zipf_alpha: f64,
    /// Scale of categorical symbol weights theta_c.
    pub cat_weight_scale: f32,
    /// Scale of numeric weights theta_n.
    pub num_weight_scale: f32,
    /// Logistic label-noise temperature (0 => hard labels, larger =>
    /// noisier / smaller effective margin).
    pub noise: f32,
    /// Target P(y=1); the intercept nu is calibrated to hit this.
    pub positive_rate: f64,
    /// Fraction of symbols with non-zero weight (irrelevant-feature mass).
    pub informative_fraction: f64,
    /// Seed of the *planted model* (weights, correlations, intercept).
    pub seed: u64,
    /// Salt for the record-sampling RNG only. Two streams with the same
    /// `seed` but different salts draw independent samples from the SAME
    /// ground truth — this is how train/validation/test splits are made.
    pub stream_salt: u64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            n_numeric: CRITEO_NUMERIC,
            s_categorical: CRITEO_CATEGORICAL,
            alphabet_size: 100_000,
            zipf_alpha: 1.2,
            cat_weight_scale: 1.0,
            num_weight_scale: 1.0,
            noise: 0.5,
            positive_rate: 0.25, // the 7-day dataset's ~75/25 skew
            informative_fraction: 0.8,
            seed: 0,
            stream_salt: 0,
        }
    }
}

impl SyntheticConfig {
    /// The "sampled" 7-day-scale config (Table 1 row 2, scaled alphabet).
    pub fn sampled(seed: u64) -> Self {
        SyntheticConfig { seed, ..Default::default() }
    }

    /// The "full" 1TB-scale config: bigger alphabet, 96% negatives
    /// (Sec. 7.5). Observation count is up to the caller — scalability
    /// depends only on (n, s, m) per the paper.
    pub fn full(seed: u64) -> Self {
        SyntheticConfig {
            alphabet_size: 4_000_000,
            positive_rate: 0.04,
            seed,
            ..Default::default()
        }
    }
}

#[derive(Clone)]
pub struct SyntheticStream {
    cfg: SyntheticConfig,
    rng: Rng,
    zipf: Zipf,
    /// Per-slot alphabet sizes and global id offsets (disjoint alphabets).
    slot_size: u64,
    theta_n: Vec<f32>,
    nu: f32,
    /// Cholesky-ish correlation mixer for numeric features (lower tri.).
    num_mix: Vec<f32>,
    /// Reused gaussian staging for [`SyntheticStream::fill_raw_features`]
    /// (keeps the in-place refill path allocation-free).
    g_buf: Vec<f32>,
    records_emitted: u64,
}

impl SyntheticStream {
    pub fn new(cfg: SyntheticConfig) -> Self {
        // Model parameters derive from `seed` alone; the record stream
        // additionally mixes in `stream_salt`.
        let mut rng = Rng::new(cfg.seed ^ 0x5eed_5eed);
        let slot_size = (cfg.alphabet_size / cfg.s_categorical as u64).max(1);
        let zipf = Zipf::new(slot_size, cfg.zipf_alpha);
        let theta_n: Vec<f32> = (0..cfg.n_numeric)
            .map(|_| rng.normal_f32() * cfg.num_weight_scale)
            .collect();
        // Mild feature correlation: x = L g with unit diagonal L.
        let n = cfg.n_numeric;
        let mut num_mix = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..=i {
                num_mix[i * n + j] = if i == j { 1.0 } else { 0.3 * rng.normal_f32() };
            }
        }
        let stream_rng = Rng::new(cfg.seed ^ mix64(cfg.stream_salt ^ 0x57a1_7000));
        let mut s = SyntheticStream {
            cfg,
            rng: stream_rng,
            zipf,
            slot_size,
            theta_n,
            nu: 0.0,
            num_mix,
            g_buf: Vec::new(),
            records_emitted: 0,
        };
        s.calibrate_intercept();
        s
    }

    /// Deterministic symbol weight theta_c(a): zero for the
    /// (1 - informative_fraction) mass, else N(0, scale^2)-ish.
    #[inline]
    pub fn symbol_weight(&self, symbol: u64) -> f32 {
        let h = mix64(symbol ^ mix64(self.cfg.seed ^ CAT_WEIGHT_KEY));
        // Informative gate from the high bits.
        let gate = (h >> 40) as f64 / (1u64 << 24) as f64;
        if gate >= self.cfg.informative_fraction {
            return 0.0;
        }
        // Map low 32 bits to an approximately-normal weight via the sum of
        // four uniforms (Irwin-Hall, std ~ sqrt(4/12)) — cheap and smooth.
        let u1 = (h & 0xffff) as f32 / 65536.0;
        let u2 = ((h >> 16) & 0xffff) as f32 / 65536.0;
        let u3 = ((h >> 32) & 0xff) as f32 / 256.0;
        let u4 = ((h >> 48) & 0xff) as f32 / 256.0;
        let ih = (u1 + u2 + u3 + u4 - 2.0) * (3.0f32).sqrt(); // ~N(0,1)
        ih * self.cfg.cat_weight_scale
    }

    /// Planted score f(x) = theta_n.x_n + sum_a theta_c(a) + nu.
    pub fn score(&self, numeric: &[f32], symbols: &[u64]) -> f32 {
        let num: f32 = numeric.iter().zip(&self.theta_n).map(|(x, w)| x * w).sum();
        let cat: f32 = symbols.iter().map(|&a| self.symbol_weight(a)).sum();
        num + cat + self.nu
    }

    /// Draw the next record's raw features into caller buffers (cleared
    /// first, capacity reused) — the allocation-free core shared by
    /// [`RecordStream::next_record`] and the in-place refill path. RNG
    /// consumption order is fixed (n gaussians, then one Zipf draw per
    /// categorical slot), so both entry points produce the identical
    /// stream.
    fn fill_raw_features(&mut self, numeric: &mut Vec<f32>, symbols: &mut Vec<u64>) {
        let n = self.cfg.n_numeric;
        // Correlated gaussians through the lower-triangular mixer.
        self.g_buf.clear();
        for _ in 0..n {
            let v = self.rng.normal_f32();
            self.g_buf.push(v);
        }
        numeric.clear();
        numeric.resize(n, 0.0);
        for i in 0..n {
            let mut acc = 0.0f32;
            for j in 0..=i {
                acc += self.num_mix[i * n + j] * self.g_buf[j];
            }
            numeric[i] = acc;
        }
        symbols.clear();
        for slot in 0..self.cfg.s_categorical as u64 {
            let rank = self.zipf.sample(&mut self.rng);
            symbols.push(slot * self.slot_size + rank);
        }
    }

    fn raw_features(&mut self) -> (Vec<f32>, Vec<u64>) {
        let mut numeric = Vec::new();
        let mut symbols = Vec::new();
        self.fill_raw_features(&mut numeric, &mut symbols);
        (numeric, symbols)
    }

    /// Overwrite `rec` with the next record, reusing its buffers.
    /// Identical RNG consumption (and therefore identical records) to
    /// [`RecordStream::next_record`].
    fn fill_record_in_place(&mut self, rec: &mut Record) {
        let Record { numeric, symbols, label } = rec;
        self.fill_raw_features(numeric, symbols);
        let f = self.score(numeric, symbols);
        *label = if self.cfg.noise <= 0.0 {
            f >= 0.0
        } else {
            let p = 1.0 / (1.0 + (-f / self.cfg.noise).exp());
            self.rng.bernoulli(p as f64)
        };
        self.records_emitted += 1;
    }

    /// Choose nu so that P(y=1) ~ positive_rate on a calibration sample.
    /// Uses a dedicated RNG keyed by `seed` only, so nu is identical for
    /// every stream_salt (the ground truth must not depend on the split).
    fn calibrate_intercept(&mut self) {
        self.nu = 0.0;
        let mut scores: Vec<f32> = Vec::with_capacity(2000);
        let saved = std::mem::replace(&mut self.rng, Rng::new(self.cfg.seed ^ 0xca11_b8a7e));
        for _ in 0..2000 {
            let (xn, xc) = self.raw_features();
            scores.push(self.score(&xn, &xc));
        }
        self.rng = saved;
        scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = ((1.0 - self.cfg.positive_rate) * (scores.len() - 1) as f64) as usize;
        self.nu = -scores[q];
    }

    /// Number of records generated so far.
    pub fn emitted(&self) -> u64 {
        self.records_emitted
    }

    /// Bayes-optimal probability for a record under the planted model
    /// (used by tests to bound achievable AUC).
    pub fn true_prob(&self, r: &Record) -> f64 {
        let f = self.score(&r.numeric, &r.symbols) as f64;
        if self.cfg.noise <= 0.0 {
            if f >= 0.0 {
                1.0
            } else {
                0.0
            }
        } else {
            1.0 / (1.0 + (-f / self.cfg.noise as f64).exp())
        }
    }

    pub fn config(&self) -> &SyntheticConfig {
        &self.cfg
    }
}

/// Namespacing key for symbol-weight hashing (avoids colliding with
/// other per-symbol derivations from the same seed).
const CAT_WEIGHT_KEY: u64 = 0xc473_a70b_5c41_e117;

impl RecordStream for SyntheticStream {
    fn next_record(&mut self) -> Option<Record> {
        let mut rec = Record { numeric: Vec::new(), symbols: Vec::new(), label: false };
        self.fill_record_in_place(&mut rec);
        Some(rec)
    }

    /// In-place refill: the stream is unbounded, so this always succeeds,
    /// and it never allocates once the record's buffers have grown to the
    /// schema width.
    fn refill_record(&mut self, rec: &mut Record) -> bool {
        self.fill_record_in_place(rec);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn take(stream: &mut SyntheticStream, n: usize) -> Vec<Record> {
        (0..n).map(|_| stream.next_record().unwrap()).collect()
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticStream::new(SyntheticConfig::sampled(7));
        let mut b = SyntheticStream::new(SyntheticConfig::sampled(7));
        assert_eq!(take(&mut a, 20), take(&mut b, 20));
    }

    #[test]
    fn refill_matches_next_record() {
        // The in-place path must emit the identical record stream, even
        // when refilling a stale record with mismatched buffer widths.
        let mut a = SyntheticStream::new(SyntheticConfig::sampled(9));
        let mut b = SyntheticStream::new(SyntheticConfig::sampled(9));
        let mut rec = Record { numeric: vec![0.5; 2], symbols: vec![1, 2, 3], label: true };
        for i in 0..50 {
            let want = a.next_record().unwrap();
            assert!(b.refill_record(&mut rec));
            assert_eq!(rec, want, "record {i}");
        }
        assert_eq!(a.emitted(), b.emitted());
    }

    #[test]
    fn schema_matches_config() {
        let mut s = SyntheticStream::new(SyntheticConfig::sampled(1));
        let r = s.next_record().unwrap();
        assert_eq!(r.numeric.len(), CRITEO_NUMERIC);
        assert_eq!(r.symbols.len(), CRITEO_CATEGORICAL);
    }

    #[test]
    fn slot_alphabets_disjoint() {
        let cfg = SyntheticConfig { alphabet_size: 26_000, ..SyntheticConfig::sampled(2) };
        let mut s = SyntheticStream::new(cfg);
        for _ in 0..200 {
            let r = s.next_record().unwrap();
            for (slot, &sym) in r.symbols.iter().enumerate() {
                let lo = slot as u64 * 1000;
                assert!(sym >= lo && sym < lo + 1000, "slot {slot} symbol {sym}");
            }
        }
    }

    #[test]
    fn positive_rate_calibrated() {
        for target in [0.25, 0.04] {
            let cfg = SyntheticConfig {
                positive_rate: target,
                ..SyntheticConfig::sampled(3)
            };
            let mut s = SyntheticStream::new(cfg);
            let recs = take(&mut s, 20_000);
            let rate = recs.iter().filter(|r| r.label).count() as f64 / recs.len() as f64;
            assert!((rate - target).abs() < 0.05, "target={target} rate={rate}");
        }
    }

    #[test]
    fn labels_correlate_with_planted_score() {
        let mut s = SyntheticStream::new(SyntheticConfig::sampled(4));
        let recs = take(&mut s, 5000);
        let mut pos_scores = Vec::new();
        let mut neg_scores = Vec::new();
        for r in &recs {
            let f = s.score(&r.numeric, &r.symbols) as f64;
            if r.label {
                pos_scores.push(f);
            } else {
                neg_scores.push(f);
            }
        }
        let mp = crate::util::stats::mean(&pos_scores);
        let mn = crate::util::stats::mean(&neg_scores);
        assert!(mp > mn + 0.3, "pos mean {mp} vs neg mean {mn}");
    }

    #[test]
    fn zipf_popularity_head_heavy() {
        let mut s = SyntheticStream::new(SyntheticConfig::sampled(5));
        let mut head = 0usize;
        let mut total = 0usize;
        for _ in 0..2000 {
            let r = s.next_record().unwrap();
            for (slot, &sym) in r.symbols.iter().enumerate() {
                let rank = sym - slot as u64 * s.slot_size;
                if rank < 10 {
                    head += 1;
                }
                total += 1;
            }
        }
        assert!(head as f64 / total as f64 > 0.3, "head frac {}", head as f64 / total as f64);
    }

    #[test]
    fn symbol_weights_deterministic_and_sparse() {
        let s = SyntheticStream::new(SyntheticConfig::sampled(6));
        assert_eq!(s.symbol_weight(12345), s.symbol_weight(12345));
        let zero = (0..10_000u64).filter(|&a| s.symbol_weight(a) == 0.0).count();
        let frac_zero = zero as f64 / 10_000.0;
        assert!((frac_zero - 0.2).abs() < 0.05, "zero frac {frac_zero}");
    }

    #[test]
    fn noiseless_labels_are_separable() {
        let cfg = SyntheticConfig { noise: 0.0, ..SyntheticConfig::sampled(8) };
        let mut s = SyntheticStream::new(cfg);
        for _ in 0..1000 {
            let r = s.next_record().unwrap();
            let f = s.score(&r.numeric, &r.symbols);
            assert_eq!(r.label, f >= 0.0);
        }
    }
}
