//! Data layer: the record model, the synthetic Criteo-like planted-model
//! stream (our substitution for the proprietary Criteo datasets — see
//! DESIGN.md §3), and a TSV reader for real Criteo-format data.

pub mod synthetic;
pub mod tsv;

pub use synthetic::{SyntheticConfig, SyntheticStream};
pub use tsv::TsvReader;

/// One observation: n numeric features, s categorical symbols (interned
/// to globally-unique u64 ids; feature slots have disjoint alphabets as
/// in Sec. 3), and a binary label.
#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    pub numeric: Vec<f32>,
    pub symbols: Vec<u64>,
    pub label: bool,
}

/// Schema constants for the Criteo task (Sec. 7: 13 numeric, 26
/// categorical features).
pub const CRITEO_NUMERIC: usize = 13;
pub const CRITEO_CATEGORICAL: usize = 26;

/// A stream of records — everything downstream (pipeline, benches,
/// examples) consumes this, so synthetic and file-backed sources are
/// interchangeable.
pub trait RecordStream: Send {
    /// Next record, or None when exhausted (synthetic streams are
    /// unbounded and never return None).
    fn next_record(&mut self) -> Option<Record>;

    /// Fill a batch; returns how many records were produced.
    fn next_batch(&mut self, out: &mut Vec<Record>, n: usize) -> usize {
        out.clear();
        for _ in 0..n {
            match self.next_record() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct CountStream(usize);

    impl RecordStream for CountStream {
        fn next_record(&mut self) -> Option<Record> {
            if self.0 == 0 {
                return None;
            }
            self.0 -= 1;
            Some(Record { numeric: vec![0.0], symbols: vec![1], label: true })
        }
    }

    #[test]
    fn batch_fills_until_exhausted() {
        let mut s = CountStream(5);
        let mut buf = Vec::new();
        assert_eq!(s.next_batch(&mut buf, 3), 3);
        assert_eq!(s.next_batch(&mut buf, 3), 2);
        assert_eq!(s.next_batch(&mut buf, 3), 0);
    }
}
