//! Data layer: the record model, the synthetic Criteo-like planted-model
//! stream (our substitution for the proprietary Criteo datasets — see
//! DESIGN.md §3), the many-class Zipf-skewed classification workload
//! (the sharded-AM serving regime), and a TSV reader for real
//! Criteo-format data.

pub mod manyclass;
pub mod synthetic;
pub mod tsv;

pub use manyclass::{ManyClassConfig, ManyClassStream};
pub use synthetic::{SyntheticConfig, SyntheticStream};
pub use tsv::TsvReader;

/// One observation: n numeric features, s categorical symbols (interned
/// to globally-unique u64 ids; feature slots have disjoint alphabets as
/// in Sec. 3), and a binary label.
#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    pub numeric: Vec<f32>,
    pub symbols: Vec<u64>,
    pub label: bool,
}

/// Schema constants for the Criteo task (Sec. 7: 13 numeric, 26
/// categorical features).
pub const CRITEO_NUMERIC: usize = 13;
pub const CRITEO_CATEGORICAL: usize = 26;

/// A stream of records — everything downstream (pipeline, benches,
/// examples) consumes this, so synthetic and file-backed sources are
/// interchangeable.
pub trait RecordStream: Send {
    /// Next record, or None when exhausted (synthetic streams are
    /// unbounded and never return None).
    fn next_record(&mut self) -> Option<Record>;

    /// Fill a batch; returns how many records were produced.
    fn next_batch(&mut self, out: &mut Vec<Record>, n: usize) -> usize {
        out.clear();
        for _ in 0..n {
            match self.next_record() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out.len()
    }

    /// Overwrite `rec` with the next record, reusing its buffers where
    /// the stream supports it; returns `false` when exhausted. The
    /// default materializes via [`RecordStream::next_record`] (correct
    /// but allocating); generators override it to refill in place —
    /// [`SyntheticStream`] does, which is what makes the coordinator's
    /// record-spine recycling allocation-free end to end.
    fn refill_record(&mut self, rec: &mut Record) -> bool {
        match self.next_record() {
            Some(r) => {
                *rec = r;
                true
            }
            None => false,
        }
    }

    /// Which model the batch most recently produced by
    /// [`RecordStream::next_batch_into`] routes to (an index into the
    /// encoder set passed to
    /// [`crate::coordinator::run_pipeline_multi`]). Single-model streams
    /// — every data-layer stream — keep the default `0`; the serve
    /// subsystem's request micro-batcher overrides it, because it cuts
    /// model-homogeneous batches from a multi-tenant submission queue
    /// and the pipeline must know which encoder each batch needs.
    fn batch_model(&mut self) -> u32 {
        0
    }

    /// Fill a batch reusing the records already in `out` (recycled
    /// spines from the coordinator's return path): the first
    /// `min(out.len(), n)` records are refilled in place, the rest
    /// pushed; surplus is truncated. Produces the identical record
    /// sequence as [`RecordStream::next_batch`].
    fn next_batch_into(&mut self, out: &mut Vec<Record>, n: usize) -> usize {
        let mut filled = 0;
        while filled < n {
            if filled < out.len() {
                if !self.refill_record(&mut out[filled]) {
                    break;
                }
            } else {
                match self.next_record() {
                    Some(r) => out.push(r),
                    None => break,
                }
            }
            filled += 1;
        }
        out.truncate(filled);
        filled
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct CountStream(usize);

    impl RecordStream for CountStream {
        fn next_record(&mut self) -> Option<Record> {
            if self.0 == 0 {
                return None;
            }
            self.0 -= 1;
            Some(Record { numeric: vec![0.0], symbols: vec![1], label: true })
        }
    }

    #[test]
    fn batch_fills_until_exhausted() {
        let mut s = CountStream(5);
        let mut buf = Vec::new();
        assert_eq!(s.next_batch(&mut buf, 3), 3);
        assert_eq!(s.next_batch(&mut buf, 3), 2);
        assert_eq!(s.next_batch(&mut buf, 3), 0);
    }

    #[test]
    fn batch_into_reuses_and_truncates() {
        let mut s = CountStream(5);
        // Pre-populated spine longer than the budget: refilled in place,
        // surplus truncated.
        let stale = Record { numeric: vec![9.0; 4], symbols: vec![7; 3], label: false };
        let mut buf = vec![stale.clone(), stale.clone(), stale.clone(), stale];
        assert_eq!(s.next_batch_into(&mut buf, 3), 3);
        assert_eq!(buf.len(), 3);
        assert!(buf.iter().all(|r| r.label && r.symbols == vec![1]));
        // Exhaustion mid-batch truncates to what was produced.
        assert_eq!(s.next_batch_into(&mut buf, 3), 2);
        assert_eq!(buf.len(), 2);
        assert_eq!(s.next_batch_into(&mut buf, 3), 0);
        assert!(buf.is_empty());
    }

    #[test]
    fn batch_into_matches_next_batch_sequence() {
        let mut a = CountStream(7);
        let mut b = CountStream(7);
        let mut va = Vec::new();
        let mut vb = vec![Record { numeric: vec![1.0], symbols: vec![], label: false }];
        a.next_batch(&mut va, 4);
        b.next_batch_into(&mut vb, 4);
        assert_eq!(va, vb);
    }
}
