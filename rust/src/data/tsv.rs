//! Reader for the Criteo TSV format, so the pipeline can run on the real
//! datasets when available: `label \t I1..I13 \t C1..C26`, where I* are
//! (possibly empty) integers and C* are (possibly empty) 8-hex-char
//! categorical tokens.
//!
//! Categorical tokens are interned to u64 on the fly by hashing the token
//! bytes with a per-slot salt — consistent with Sec. 3's disjoint
//! per-feature alphabets and with the streaming constraint that the
//! alphabet is not known in advance (no dictionary is ever built).
//! Numeric fields get the standard log(1+x) transform used throughout
//! the CTR literature; missing values become 0.

use std::io::BufRead;

use super::{Record, RecordStream, CRITEO_CATEGORICAL, CRITEO_NUMERIC};
use crate::hash::murmur3_32;

pub struct TsvReader<R: BufRead> {
    reader: R,
    line: String,
    pub skipped_malformed: u64,
}

impl<R: BufRead> TsvReader<R> {
    pub fn new(reader: R) -> Self {
        TsvReader { reader, line: String::new(), skipped_malformed: 0 }
    }

    /// Intern a categorical token into slot `slot`'s alphabet.
    pub fn intern(slot: usize, token: &str) -> u64 {
        // 64-bit id: slot in the top bits, two salted murmurs below —
        // collision probability ~ 2^-58 per pair within a slot.
        let h1 = murmur3_32(token.as_bytes(), 0x9747_b28c ^ slot as u32) as u64;
        let h2 = murmur3_32(token.as_bytes(), 0x1b87_3593 ^ slot as u32) as u64;
        ((slot as u64) << 58) | ((h1 << 26) ^ h2) & ((1u64 << 58) - 1)
    }

    fn parse_line(&mut self) -> Option<Record> {
        let fields: Vec<&str> = self.line.trim_end_matches('\n').split('\t').collect();
        if fields.len() != 1 + CRITEO_NUMERIC + CRITEO_CATEGORICAL {
            return None;
        }
        let label = match fields[0] {
            "0" => false,
            "1" => true,
            _ => return None,
        };
        let mut numeric = Vec::with_capacity(CRITEO_NUMERIC);
        for f in &fields[1..1 + CRITEO_NUMERIC] {
            let v = if f.is_empty() {
                0.0
            } else {
                match f.parse::<f64>() {
                    // log1p transform; Criteo ints can be slightly negative.
                    Ok(x) => (x.max(-1.0) + 1.0).ln() as f32,
                    Err(_) => return None,
                }
            };
            numeric.push(v);
        }
        let mut symbols = Vec::with_capacity(CRITEO_CATEGORICAL);
        for (slot, f) in fields[1 + CRITEO_NUMERIC..].iter().enumerate() {
            if !f.is_empty() {
                symbols.push(Self::intern(slot, f));
            }
        }
        Some(Record { numeric, symbols, label })
    }
}

impl<R: BufRead + Send> RecordStream for TsvReader<R> {
    fn next_record(&mut self) -> Option<Record> {
        loop {
            self.line.clear();
            match self.reader.read_line(&mut self.line) {
                Ok(0) => return None,
                Ok(_) => match self.parse_line() {
                    Some(r) => return Some(r),
                    None => self.skipped_malformed += 1,
                },
                Err(_) => return None,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn sample_line(label: u8) -> String {
        let ints: Vec<String> = (0..CRITEO_NUMERIC).map(|i| (i * 3).to_string()).collect();
        let cats: Vec<String> = (0..CRITEO_CATEGORICAL).map(|i| format!("{:08x}", i * 7 + 1)).collect();
        format!("{label}\t{}\t{}", ints.join("\t"), cats.join("\t"))
    }

    #[test]
    fn parses_well_formed_lines() {
        let data = format!("{}\n{}\n", sample_line(1), sample_line(0));
        let mut r = TsvReader::new(Cursor::new(data));
        let a = r.next_record().unwrap();
        assert!(a.label);
        assert_eq!(a.numeric.len(), CRITEO_NUMERIC);
        assert_eq!(a.symbols.len(), CRITEO_CATEGORICAL);
        // log1p(0) == 0 for the first numeric field
        assert_eq!(a.numeric[0], 0.0);
        let b = r.next_record().unwrap();
        assert!(!b.label);
        assert!(r.next_record().is_none());
    }

    #[test]
    fn missing_fields_tolerated() {
        // Empty numeric -> 0.0; empty categorical -> dropped.
        let mut fields = vec!["1".to_string()];
        fields.extend(std::iter::repeat(String::new()).take(CRITEO_NUMERIC));
        fields.extend(std::iter::repeat(String::new()).take(CRITEO_CATEGORICAL));
        let mut r = TsvReader::new(Cursor::new(fields.join("\t") + "\n"));
        let rec = r.next_record().unwrap();
        assert!(rec.numeric.iter().all(|&v| v == 0.0));
        assert!(rec.symbols.is_empty());
    }

    #[test]
    fn malformed_lines_skipped_and_counted() {
        let data = format!("garbage\n{}\nnot\tenough\tfields\n", sample_line(0));
        let mut r = TsvReader::new(Cursor::new(data));
        assert!(r.next_record().is_some());
        assert!(r.next_record().is_none());
        assert_eq!(r.skipped_malformed, 2);
    }

    #[test]
    fn interning_slot_disjoint_and_stable() {
        let a = TsvReader::<Cursor<&[u8]>>::intern(0, "deadbeef");
        let b = TsvReader::<Cursor<&[u8]>>::intern(1, "deadbeef");
        assert_ne!(a, b, "same token in different slots must differ");
        assert_eq!(a, TsvReader::<Cursor<&[u8]>>::intern(0, "deadbeef"));
        assert_eq!(a >> 58, 0);
        assert_eq!(b >> 58, 1);
    }
}
