//! Many-class synthetic workload with Zipf class skew.
//!
//! The HDC classification literature (Ge & Parhi review) is dominated
//! by many-class setups — the regime where the AM class scan, not
//! encode, is the serving bottleneck. This generator plants `C` classes
//! (1k–100k is the intended range) and emits records whose class is
//! Zipf-distributed (a few head classes dominate, a long tail is rare —
//! the shape real traffic has):
//!
//! * every class owns a small set of **deterministic class-keyed
//!   symbols** (disjoint across classes, disjoint from the noise
//!   alphabet), so a record's class is recoverable from its symbols;
//! * each record additionally draws random **noise symbols** from a
//!   shared alphabet, so classification is non-trivial;
//! * [`ManyClassConfig::class_record`] returns the canonical noise-free
//!   record of a class — bundling these through an encoder +
//!   [`crate::am::AmBuilder`] builds a store covering all `C` classes.
//!
//! [`Record::label`] is a `bool`, so it cannot carry a class id; the
//! stream exposes the drawn class out-of-band via
//! [`ManyClassStream::next_with_class`] (tests and benches that need
//! ground truth use it), and the label carries `class % 2 == 1` so
//! label-only consumers still see a deterministic signal.

use super::{Record, RecordStream};
use crate::util::rng::{mix64, Rng, Zipf};

#[derive(Clone, Debug)]
pub struct ManyClassConfig {
    /// Number of planted classes `C` (1k–100k intended).
    pub n_classes: usize,
    /// Zipf exponent of the class-popularity skew (rank 0 hottest).
    pub zipf_alpha: f64,
    /// Class-keyed symbols per record (the class signal).
    pub class_symbols: usize,
    /// Random shared-alphabet symbols per record (the noise).
    pub noise_symbols: usize,
    /// Shared noise-alphabet size; class-keyed symbol ids live *above*
    /// this range, so noise can never alias a class signal.
    pub alphabet: u64,
    /// Numeric features per record (0 for the pure-categorical
    /// workload; positive values draw standard gaussians).
    pub n_numeric: usize,
    /// Seed of the planted classes and the Zipf skew.
    pub seed: u64,
    /// Salt for the record-sampling RNG only: same `seed`, different
    /// salts = independent draws from the SAME planted classes (how
    /// per-client bench streams and train/test splits are made).
    pub stream_salt: u64,
}

impl Default for ManyClassConfig {
    fn default() -> Self {
        ManyClassConfig {
            n_classes: 1000,
            zipf_alpha: 1.1,
            class_symbols: 8,
            noise_symbols: 4,
            alphabet: 1_000_000,
            n_numeric: 0,
            seed: 0,
            stream_salt: 0,
        }
    }
}

impl ManyClassConfig {
    /// A `C`-class workload with the default shape.
    pub fn classes(n_classes: usize, seed: u64) -> Self {
        assert!(n_classes > 0);
        ManyClassConfig { n_classes, seed, ..Default::default() }
    }

    /// Deterministic symbol `j` of class `class` — the ids are offset
    /// above the noise alphabet and keyed by (seed, class, j), identical
    /// across every stream over this config.
    #[inline]
    pub fn class_symbol(&self, class: u32, j: usize) -> u64 {
        debug_assert!((class as usize) < self.n_classes && j < self.class_symbols);
        // Disjoint per-class blocks above the noise range; the mix only
        // decorrelates ids for hash-based encoders, injectively per
        // block (it perturbs ids within a 2^16 window smaller than the
        // 2^20 block stride).
        let base = self.alphabet + (class as u64) * CLASS_BLOCK + j as u64;
        base + (mix64(self.seed ^ CLASS_SYM_KEY ^ (class as u64 * 131 + j as u64)) & 0xffff)
    }

    /// The canonical noise-free record of `class`: its class-keyed
    /// symbols, zeroed numerics, the parity label. Encoding these per
    /// class is how many-class stores are built (perf snapshot,
    /// serve_bench, the serve determinism test).
    pub fn class_record(&self, class: u32) -> Record {
        let symbols = (0..self.class_symbols).map(|j| self.class_symbol(class, j)).collect();
        Record { numeric: vec![0.0; self.n_numeric], symbols, label: class % 2 == 1 }
    }
}

/// Per-class id stride for class-keyed symbols (must exceed
/// `class_symbols + 2^16`, the mix window).
const CLASS_BLOCK: u64 = 1 << 20;
/// Namespacing key for class-symbol hashing.
const CLASS_SYM_KEY: u64 = 0x9c1a_55e5_11a6_00e5;

#[derive(Clone)]
pub struct ManyClassStream {
    cfg: ManyClassConfig,
    rng: Rng,
    zipf: Zipf,
    records_emitted: u64,
}

impl ManyClassStream {
    pub fn new(cfg: ManyClassConfig) -> Self {
        assert!(cfg.n_classes > 0);
        let zipf = Zipf::new(cfg.n_classes as u64, cfg.zipf_alpha);
        let rng = Rng::new(cfg.seed ^ mix64(cfg.stream_salt ^ 0x3c1a_55e5));
        ManyClassStream { cfg, rng, zipf, records_emitted: 0 }
    }

    /// Overwrite `rec` with the next record and return its class. RNG
    /// consumption order is fixed (class draw, numerics, noise symbols),
    /// so every entry point — [`ManyClassStream::next_with_class`],
    /// [`RecordStream::next_record`], the in-place refill — produces the
    /// identical stream. Allocation-free once the record's buffers have
    /// grown to the schema width.
    fn fill_record_in_place(&mut self, rec: &mut Record) -> u32 {
        let class = self.zipf.sample(&mut self.rng) as u32;
        rec.numeric.clear();
        for _ in 0..self.cfg.n_numeric {
            let v = self.rng.normal_f32();
            rec.numeric.push(v);
        }
        rec.symbols.clear();
        for j in 0..self.cfg.class_symbols {
            rec.symbols.push(self.cfg.class_symbol(class, j));
        }
        for _ in 0..self.cfg.noise_symbols {
            let s = self.rng.below(self.cfg.alphabet);
            rec.symbols.push(s);
        }
        rec.label = class % 2 == 1;
        self.records_emitted += 1;
        class
    }

    /// The next record plus its ground-truth class (the label can only
    /// carry parity).
    pub fn next_with_class(&mut self) -> (Record, u32) {
        let mut rec = Record { numeric: Vec::new(), symbols: Vec::new(), label: false };
        let class = self.fill_record_in_place(&mut rec);
        (rec, class)
    }

    /// In-place variant of [`ManyClassStream::next_with_class`].
    pub fn refill_with_class(&mut self, rec: &mut Record) -> u32 {
        self.fill_record_in_place(rec)
    }

    /// Number of records generated so far.
    pub fn emitted(&self) -> u64 {
        self.records_emitted
    }

    pub fn config(&self) -> &ManyClassConfig {
        &self.cfg
    }
}

impl RecordStream for ManyClassStream {
    fn next_record(&mut self) -> Option<Record> {
        let mut rec = Record { numeric: Vec::new(), symbols: Vec::new(), label: false };
        self.fill_record_in_place(&mut rec);
        Some(rec)
    }

    /// In-place refill: the stream is unbounded, so this always
    /// succeeds and never allocates once the buffers are warm.
    fn refill_record(&mut self, rec: &mut Record) -> bool {
        self.fill_record_in_place(rec);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = ManyClassStream::new(ManyClassConfig::classes(500, 7));
        let mut b = ManyClassStream::new(ManyClassConfig::classes(500, 7));
        for i in 0..50 {
            let (ra, ca) = a.next_with_class();
            let (rb, cb) = b.next_with_class();
            assert_eq!((ra, ca), (rb, cb), "record {i}");
        }
    }

    #[test]
    fn refill_matches_next_record() {
        let mut a = ManyClassStream::new(ManyClassConfig::classes(100, 9));
        let mut b = ManyClassStream::new(ManyClassConfig::classes(100, 9));
        let mut rec = Record { numeric: vec![0.5; 2], symbols: vec![1, 2, 3], label: true };
        for i in 0..50 {
            let want = a.next_record().unwrap();
            assert!(b.refill_record(&mut rec));
            assert_eq!(rec, want, "record {i}");
        }
        assert_eq!(a.emitted(), b.emitted());
    }

    #[test]
    fn class_symbols_disjoint_from_noise_and_each_other() {
        let cfg = ManyClassConfig::classes(200, 3);
        let mut seen = std::collections::HashSet::new();
        for c in 0..200u32 {
            for j in 0..cfg.class_symbols {
                let s = cfg.class_symbol(c, j);
                assert!(s >= cfg.alphabet, "class symbol {s} inside noise alphabet");
                assert!(seen.insert(s), "class symbol {s} collides (class {c} j {j})");
            }
        }
    }

    #[test]
    fn record_symbols_start_with_class_signal() {
        let cfg = ManyClassConfig::classes(50, 4);
        let mut s = ManyClassStream::new(cfg.clone());
        for _ in 0..100 {
            let (rec, class) = s.next_with_class();
            assert_eq!(rec.symbols.len(), cfg.class_symbols + cfg.noise_symbols);
            assert_eq!(rec.label, class % 2 == 1);
            let canon = cfg.class_record(class);
            assert_eq!(&rec.symbols[..cfg.class_symbols], &canon.symbols[..]);
            for &n in &rec.symbols[cfg.class_symbols..] {
                assert!(n < cfg.alphabet, "noise symbol {n} outside noise alphabet");
            }
        }
    }

    #[test]
    fn class_skew_is_head_heavy() {
        let mut s = ManyClassStream::new(ManyClassConfig::classes(1000, 5));
        let mut head = 0usize;
        const N: usize = 5000;
        for _ in 0..N {
            let (_, class) = s.next_with_class();
            if class < 10 {
                head += 1;
            }
        }
        // Zipf(1.1) puts far more than uniform's 1% on the 10 head ranks.
        assert!(head as f64 / N as f64 > 0.2, "head frac {}", head as f64 / N as f64);
    }

    #[test]
    fn salted_streams_share_planted_classes() {
        let cfg = ManyClassConfig::classes(100, 6);
        let salted = ManyClassConfig { stream_salt: 1, ..cfg.clone() };
        let mut a = ManyClassStream::new(cfg.clone());
        let mut b = ManyClassStream::new(salted);
        let (ra, ca) = a.next_with_class();
        let (rb, cb) = b.next_with_class();
        // Different sample paths...
        assert!(ra != rb || ca != cb);
        // ...same planted class symbols.
        for c in [0u32, 17, 99] {
            assert_eq!(a.config().class_record(c), b.config().class_record(c));
            assert_eq!(cfg.class_record(c).symbols.len(), cfg.class_symbols);
        }
    }
}
