//! Sparse hash-based (Bloom filter) categorical encoder — the paper's
//! headline contribution (Sec. 4.2.2, Eq. 2–3, Theorem 3).
//!
//! Each symbol sets k hashed coordinates; a feature vector is the OR
//! (set union) of its symbols' codes. Encoding touches only `s·k`
//! coordinates regardless of alphabet size m and dimension d, and the
//! encoder's entire state is k hash seeds — nothing scales with m.
//!
//! The scratch path ([`BloomEncoder::encode_set_with`]) stages the `s·k`
//! hashed coordinates in a pooled buffer and dedups them through the
//! scratch bitset instead of `sort_unstable + dedup` — the sort was the
//! dominant non-hashing cost of a Bloom encode at paper scale (s=26,
//! k=4 → 104 coordinates per record).
//!
//! Both dedup paths terminate in [`crate::encoding::kernels`]: the
//! allocating path's sort+dedup is `kernels::sort_dedup` (via
//! [`sparse_from_indices`]) and the scratch path's bitset mark/sweep is
//! `kernels::bitset_mark` / `kernels::bitset_sweep` (via
//! [`EncodeScratch::sparse_from_staged`]), which gains a vectorized
//! zero-block skip under `--features simd` with bit-identical output.

use crate::encoding::scratch::EncodeScratch;
use crate::encoding::vector::{sparse_from_indices, Encoding};
use crate::encoding::CategoricalEncoder;
use crate::hash::{IndexHash, MurmurHash, PolyHash};
use crate::util::rng::Rng;

/// Bloom encoder generic over the hash family (Murmur3 in practice,
/// 2s-independent polynomials when validating Theorem 3).
#[derive(Clone, Debug)]
pub struct BloomEncoder<H: IndexHash = MurmurHash> {
    hashes: Vec<H>,
    d: usize,
}

impl BloomEncoder<MurmurHash> {
    /// The practical construction: k seeded Murmur3 functions.
    pub fn new(d: usize, k: usize, rng: &mut Rng) -> Self {
        BloomEncoder { hashes: MurmurHash::family(k, rng), d }
    }
}

impl BloomEncoder<PolyHash> {
    /// Theorem 3's construction: k functions from a p-independent
    /// polynomial family (p = 2s for sets of size s).
    pub fn new_poly(d: usize, k: usize, independence: usize, rng: &mut Rng) -> Self {
        BloomEncoder { hashes: PolyHash::family(k, independence, rng), d }
    }
}

impl<H: IndexHash> BloomEncoder<H> {
    pub fn with_hashes(d: usize, hashes: Vec<H>) -> Self {
        BloomEncoder { hashes, d }
    }

    pub fn k(&self) -> usize {
        self.hashes.len()
    }

    /// Append the k hashed coordinates of one symbol to `out`
    /// (unsorted, may contain duplicates). The zero-allocation hot path.
    #[inline]
    pub fn symbol_indices_into(&self, symbol: u64, out: &mut Vec<u32>) {
        for h in &self.hashes {
            out.push(h.index(symbol, self.d as u64) as u32);
        }
    }

    /// Encode one symbol (Eq. 2).
    pub fn encode_symbol(&self, symbol: u64) -> Encoding {
        let mut idx = Vec::with_capacity(self.k());
        self.symbol_indices_into(symbol, &mut idx);
        sparse_from_indices(idx, self.d)
    }

    /// Encode a feature vector (Eq. 3: element-wise max over symbols).
    /// Allocating reference path; the hot path is
    /// [`BloomEncoder::encode_set_with`].
    pub fn encode_set(&self, symbols: &[u64]) -> Encoding {
        let mut idx = Vec::with_capacity(symbols.len() * self.k());
        for &a in symbols {
            self.symbol_indices_into(a, &mut idx);
        }
        sparse_from_indices(idx, self.d)
    }

    /// Scratch-path [`BloomEncoder::encode_set`]: hashes stage in a pooled
    /// buffer, dedup goes through the scratch bitset (sort-free), and the
    /// output index buffer comes from the pool. Bit-identical to
    /// `encode_set`.
    pub fn encode_set_with(&self, symbols: &[u64], scratch: &mut EncodeScratch) -> Encoding {
        let mut staged = scratch.take_stage();
        for &a in symbols {
            self.symbol_indices_into(a, &mut staged);
        }
        let code = scratch.sparse_from_staged(&staged, self.d);
        scratch.put_stage(staged);
        code
    }

    /// Approximate membership query with Broder–Mitzenmacher semantics:
    /// `symbol` is deemed a member iff **all of its distinct hashed
    /// coordinates** are set in `set_code`.
    ///
    /// Two deliberate consequences of the sparse-vector formulation:
    ///
    /// * **No false negatives.** A member's coordinates are all set by
    ///   construction (union encoding), so the test cannot reject it.
    /// * **The threshold is `|distinct coords|`, not `k`.** When a
    ///   symbol's own k hashes collide (|φ(a)| = k' < k, probability
    ///   ≈ k(k−1)/2d per pair), the classical bit-array Bloom filter
    ///   tests exactly those k' distinct bits too — `dot ≥ k` would
    ///   instead *reject members* whose hashes collide, i.e. introduce
    ///   false negatives. The price is the standard one: such symbols
    ///   have slightly higher false-positive probability (fill^k' rather
    ///   than fill^k). `dot` can never exceed `|φ(a)|`, so `>=` here is
    ///   equality — the full-intersection test.
    pub fn query(&self, set_code: &Encoding, symbol: u64) -> bool {
        let code = self.encode_symbol(symbol);
        set_code.dot(&code) >= code.nnz() as f64
    }

    /// Allocation-free [`BloomEncoder::query`].
    pub fn query_with(
        &self,
        set_code: &Encoding,
        symbol: u64,
        scratch: &mut EncodeScratch,
    ) -> bool {
        let code = self.encode_set_with(std::slice::from_ref(&symbol), scratch);
        let hit = set_code.dot(&code) >= code.nnz() as f64;
        scratch.recycle(code);
        hit
    }
}

impl<H: IndexHash> CategoricalEncoder for BloomEncoder<H> {
    fn encode(&mut self, symbols: &[u64]) -> Encoding {
        self.encode_set(symbols)
    }

    fn encode_with(&mut self, symbols: &[u64], scratch: &mut EncodeScratch) -> Encoding {
        self.encode_set_with(symbols, scratch)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn memory_bytes(&self) -> usize {
        // k seeds / coefficient vectors — independent of both m and the
        // number of records processed. (32k bits for Murmur3, Sec. 7.1.)
        self.hashes.len() * std::mem::size_of::<H>()
    }

    fn name(&self) -> &'static str {
        "bloom"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enc(d: usize, k: usize, seed: u64) -> BloomEncoder {
        BloomEncoder::new(d, k, &mut Rng::new(seed))
    }

    #[test]
    fn at_most_sk_bits_set() {
        let e = enc(1000, 4, 1);
        let symbols: Vec<u64> = (0..26).collect();
        let code = e.encode_set(&symbols);
        assert!(code.nnz() <= 26 * 4);
        assert!(code.nnz() > 0);
        assert_eq!(code.dim(), 1000);
    }

    #[test]
    fn deterministic() {
        let e = enc(512, 3, 2);
        assert_eq!(e.encode_set(&[5, 9, 100]), e.encode_set(&[5, 9, 100]));
    }

    #[test]
    fn order_invariant() {
        let e = enc(512, 3, 3);
        assert_eq!(e.encode_set(&[1, 2, 3]), e.encode_set(&[3, 1, 2]));
    }

    #[test]
    fn scratch_path_bit_identical() {
        let e = enc(2048, 4, 11);
        let mut scratch = EncodeScratch::new();
        for s in 0..50u64 {
            let set: Vec<u64> = (s..s + 20).map(|i| i * 31 + 5).collect();
            let want = e.encode_set(&set);
            let got = e.encode_set_with(&set, &mut scratch);
            assert_eq!(got, want, "set seed {s}");
            scratch.recycle(got); // exercise pooled output buffers
        }
    }

    #[test]
    fn union_is_or_of_codes() {
        let e = enc(2048, 4, 4);
        let a = e.encode_set(&[10]);
        let b = e.encode_set(&[20]);
        let ab = e.encode_set(&[10, 20]);
        // every bit of a and of b appears in ab, and nothing else
        let mut want: Vec<u32> = Vec::new();
        if let (
            Encoding::SparseBinary { indices: ia, .. },
            Encoding::SparseBinary { indices: ib, .. },
        ) = (&a, &b)
        {
            want.extend(ia);
            want.extend(ib);
        }
        want.sort_unstable();
        want.dedup();
        match &ab {
            Encoding::SparseBinary { indices, .. } => assert_eq!(indices, &want),
            _ => panic!(),
        }
    }

    #[test]
    fn membership_no_false_negatives() {
        let e = enc(4096, 4, 5);
        let set: Vec<u64> = (0..30).map(|i| i * 13 + 7).collect();
        let code = e.encode_set(&set);
        for &a in &set {
            assert!(e.query(&code, a), "false negative for {a}");
        }
    }

    #[test]
    fn membership_no_false_negatives_under_self_collisions() {
        // Tiny d forces a symbol's own k hashes to collide (|φ(a)| < k).
        // The distinct-coordinate threshold must still accept all members
        // — a fixed `dot >= k` threshold would reject them.
        let e = enc(16, 8, 21);
        let set: Vec<u64> = (0..40).collect();
        let mut collided = 0usize;
        for &a in &set {
            if e.encode_symbol(a).nnz() < e.k() {
                collided += 1;
            }
        }
        assert!(collided > 0, "d=16, k=8 must produce self-collisions");
        let code = e.encode_set(&set);
        for &a in &set {
            assert!(e.query(&code, a), "false negative for colliding symbol {a}");
        }
    }

    #[test]
    fn query_threshold_is_distinct_coordinate_count() {
        // Construct a set code that misses exactly one of a symbol's
        // distinct coordinates: the query must reject (full intersection
        // required), demonstrating dot >= nnz is equality, not slack.
        let e = enc(8192, 4, 22);
        let sym = 12345u64;
        let code = e.encode_symbol(sym);
        if let Encoding::SparseBinary { indices, d } = &code {
            assert!(indices.len() >= 2);
            let partial = Encoding::SparseBinary {
                indices: indices[..indices.len() - 1].to_vec(),
                d: *d,
            };
            assert!(!e.query(&partial, sym), "partial match must not be a member");
            assert!(e.query(&code, sym), "full match must be a member");
        } else {
            panic!();
        }
    }

    #[test]
    fn query_with_matches_query() {
        let e = enc(4096, 4, 23);
        let set: Vec<u64> = (0..40).map(|i| i * 7 + 1).collect();
        let code = e.encode_set(&set);
        let mut scratch = EncodeScratch::new();
        for a in 0..500u64 {
            assert_eq!(
                e.query(&code, a),
                e.query_with(&code, a, &mut scratch),
                "symbol {a}"
            );
        }
    }

    #[test]
    fn membership_low_false_positive_rate() {
        let e = enc(8192, 4, 6);
        let set: Vec<u64> = (0..50).collect();
        let code = e.encode_set(&set);
        let fp = (1000u64..6000).filter(|&a| e.query(&code, a)).count();
        // d=8192, sk=200 set bits -> fill ~2.4%, fpr ~ (0.024)^4 ~ 3e-7
        assert!(fp < 5, "false positives: {fp}/5000");
    }

    #[test]
    fn dot_estimates_intersection() {
        // Theorem 3: (1/k) phi(x).phi(x') ~ |x ∩ x'| + s^2 k / 2d.
        let k = 4;
        let e = enc(65536, k, 7);
        let x: Vec<u64> = (0..26).collect();
        let y: Vec<u64> = (13..39).collect(); // overlap 13
        let fx = e.encode_set(&x);
        let fy = e.encode_set(&y);
        let est = fx.dot(&fy) / k as f64;
        assert!((est - 13.0).abs() < 3.0, "est={est}");
    }

    #[test]
    fn memory_independent_of_usage() {
        let mut e = enc(10_000, 4, 8);
        let before = e.memory_bytes();
        for batch in 0..50 {
            let symbols: Vec<u64> = (batch * 100..batch * 100 + 26).collect();
            let _ = e.encode(&symbols);
        }
        assert_eq!(e.memory_bytes(), before);
    }

    #[test]
    fn poly_family_variant_works() {
        let mut rng = Rng::new(9);
        let e = BloomEncoder::new_poly(4096, 4, 52, &mut rng);
        let code = e.encode_set(&(0..26).collect::<Vec<_>>());
        assert!(code.nnz() <= 26 * 4 && code.nnz() > 50);
    }

    #[test]
    fn empty_set_encodes_to_zero() {
        let e = enc(128, 4, 10);
        let code = e.encode_set(&[]);
        assert_eq!(code.nnz(), 0);
        let mut scratch = EncodeScratch::new();
        assert_eq!(e.encode_set_with(&[], &mut scratch), code);
    }
}
