//! Sparse hash-based (Bloom filter) categorical encoder — the paper's
//! headline contribution (Sec. 4.2.2, Eq. 2–3, Theorem 3).
//!
//! Each symbol sets k hashed coordinates; a feature vector is the OR
//! (set union) of its symbols' codes. Encoding touches only `s·k`
//! coordinates regardless of alphabet size m and dimension d, and the
//! encoder's entire state is k hash seeds — nothing scales with m.

use crate::encoding::vector::{sparse_from_indices, Encoding};
use crate::encoding::CategoricalEncoder;
use crate::hash::{IndexHash, MurmurHash, PolyHash};
use crate::util::rng::Rng;

/// Bloom encoder generic over the hash family (Murmur3 in practice,
/// 2s-independent polynomials when validating Theorem 3).
#[derive(Clone, Debug)]
pub struct BloomEncoder<H: IndexHash = MurmurHash> {
    hashes: Vec<H>,
    d: usize,
}

impl BloomEncoder<MurmurHash> {
    /// The practical construction: k seeded Murmur3 functions.
    pub fn new(d: usize, k: usize, rng: &mut Rng) -> Self {
        BloomEncoder { hashes: MurmurHash::family(k, rng), d }
    }
}

impl BloomEncoder<PolyHash> {
    /// Theorem 3's construction: k functions from a p-independent
    /// polynomial family (p = 2s for sets of size s).
    pub fn new_poly(d: usize, k: usize, independence: usize, rng: &mut Rng) -> Self {
        BloomEncoder { hashes: PolyHash::family(k, independence, rng), d }
    }
}

impl<H: IndexHash> BloomEncoder<H> {
    pub fn with_hashes(d: usize, hashes: Vec<H>) -> Self {
        BloomEncoder { hashes, d }
    }

    pub fn k(&self) -> usize {
        self.hashes.len()
    }

    /// Append the k hashed coordinates of one symbol to `out`
    /// (unsorted, may contain duplicates). The zero-allocation hot path.
    #[inline]
    pub fn symbol_indices_into(&self, symbol: u64, out: &mut Vec<u32>) {
        for h in &self.hashes {
            out.push(h.index(symbol, self.d as u64) as u32);
        }
    }

    /// Encode one symbol (Eq. 2).
    pub fn encode_symbol(&self, symbol: u64) -> Encoding {
        let mut idx = Vec::with_capacity(self.k());
        self.symbol_indices_into(symbol, &mut idx);
        sparse_from_indices(idx, self.d)
    }

    /// Encode a feature vector (Eq. 3: element-wise max over symbols).
    pub fn encode_set(&self, symbols: &[u64]) -> Encoding {
        let mut idx = Vec::with_capacity(symbols.len() * self.k());
        for &a in symbols {
            self.symbol_indices_into(a, &mut idx);
        }
        sparse_from_indices(idx, self.d)
    }

    /// Approximate membership query (Broder–Mitzenmacher): `a` is deemed
    /// a member iff all k of its coordinates are set.
    pub fn query(&self, set_code: &Encoding, symbol: u64) -> bool {
        let code = self.encode_symbol(symbol);
        // Thresholded dot product at k — but dedup means |code| can be < k.
        set_code.dot(&code) >= code.nnz() as f64
    }
}

impl<H: IndexHash> CategoricalEncoder for BloomEncoder<H> {
    fn encode(&mut self, symbols: &[u64]) -> Encoding {
        self.encode_set(symbols)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn memory_bytes(&self) -> usize {
        // k seeds / coefficient vectors — independent of both m and the
        // number of records processed. (32k bits for Murmur3, Sec. 7.1.)
        self.hashes.len() * std::mem::size_of::<H>()
    }

    fn name(&self) -> &'static str {
        "bloom"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enc(d: usize, k: usize, seed: u64) -> BloomEncoder {
        BloomEncoder::new(d, k, &mut Rng::new(seed))
    }

    #[test]
    fn at_most_sk_bits_set() {
        let e = enc(1000, 4, 1);
        let symbols: Vec<u64> = (0..26).collect();
        let code = e.encode_set(&symbols);
        assert!(code.nnz() <= 26 * 4);
        assert!(code.nnz() > 0);
        assert_eq!(code.dim(), 1000);
    }

    #[test]
    fn deterministic() {
        let e = enc(512, 3, 2);
        assert_eq!(e.encode_set(&[5, 9, 100]), e.encode_set(&[5, 9, 100]));
    }

    #[test]
    fn order_invariant() {
        let e = enc(512, 3, 3);
        assert_eq!(e.encode_set(&[1, 2, 3]), e.encode_set(&[3, 1, 2]));
    }

    #[test]
    fn union_is_or_of_codes() {
        let e = enc(2048, 4, 4);
        let a = e.encode_set(&[10]);
        let b = e.encode_set(&[20]);
        let ab = e.encode_set(&[10, 20]);
        // every bit of a and of b appears in ab, and nothing else
        let mut want: Vec<u32> = Vec::new();
        if let (Encoding::SparseBinary { indices: ia, .. }, Encoding::SparseBinary { indices: ib, .. }) =
            (&a, &b)
        {
            want.extend(ia);
            want.extend(ib);
        }
        want.sort_unstable();
        want.dedup();
        match &ab {
            Encoding::SparseBinary { indices, .. } => assert_eq!(indices, &want),
            _ => panic!(),
        }
    }

    #[test]
    fn membership_no_false_negatives() {
        let e = enc(4096, 4, 5);
        let set: Vec<u64> = (0..30).map(|i| i * 13 + 7).collect();
        let code = e.encode_set(&set);
        for &a in &set {
            assert!(e.query(&code, a), "false negative for {a}");
        }
    }

    #[test]
    fn membership_low_false_positive_rate() {
        let e = enc(8192, 4, 6);
        let set: Vec<u64> = (0..50).collect();
        let code = e.encode_set(&set);
        let fp = (1000u64..6000).filter(|&a| e.query(&code, a)).count();
        // d=8192, sk=200 set bits -> fill ~2.4%, fpr ~ (0.024)^4 ~ 3e-7
        assert!(fp < 5, "false positives: {fp}/5000");
    }

    #[test]
    fn dot_estimates_intersection() {
        // Theorem 3: (1/k) phi(x).phi(x') ~ |x ∩ x'| + s^2 k / 2d.
        let k = 4;
        let e = enc(65536, k, 7);
        let x: Vec<u64> = (0..26).collect();
        let y: Vec<u64> = (13..39).collect(); // overlap 13
        let fx = e.encode_set(&x);
        let fy = e.encode_set(&y);
        let est = fx.dot(&fy) / k as f64;
        assert!((est - 13.0).abs() < 3.0, "est={est}");
    }

    #[test]
    fn memory_independent_of_usage() {
        let mut e = enc(10_000, 4, 8);
        let before = e.memory_bytes();
        for batch in 0..50 {
            let symbols: Vec<u64> = (batch * 100..batch * 100 + 26).collect();
            let _ = e.encode(&symbols);
        }
        assert_eq!(e.memory_bytes(), before);
    }

    #[test]
    fn poly_family_variant_works() {
        let mut rng = Rng::new(9);
        let e = BloomEncoder::new_poly(4096, 4, 52, &mut rng);
        let code = e.encode_set(&(0..26).collect::<Vec<_>>());
        assert!(code.nnz() <= 26 * 4 && code.nnz() > 50);
    }

    #[test]
    fn empty_set_encodes_to_zero() {
        let e = enc(128, 4, 10);
        let code = e.encode_set(&[]);
        assert_eq!(code.nnz(), 0);
    }
}
