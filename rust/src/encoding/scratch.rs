//! Reusable encode scratch state — the zero-allocation hot path (§Perf).
//!
//! Every encoder's `encode` allocates its working and output buffers per
//! record: a `Vec<u32>` that gets sort+dedup'ed for the sparse encoders, a
//! `vec![0.0; d]` accumulator for the dense ones. On the streaming hot
//! path those allocations (and the sort) dominate encode latency and
//! serialize on the allocator under multi-worker load. [`EncodeScratch`]
//! removes them:
//!
//! * a **staging buffer** for unsorted hashed coordinates,
//! * a **bitset dedup table** that replaces `sort_unstable + dedup`: mark
//!   each coordinate's bit, then sweep words in order extracting set bits
//!   (naturally sorted, naturally unique, and cleared during the sweep so
//!   the table is all-zero again afterwards) — O(s·k + d/64) instead of
//!   O(s·k·log(s·k)), branch-free inner loop. Mark and sweep are the
//!   [`crate::encoding::kernels`] pair [`kernels::bitset_mark`] /
//!   [`kernels::bitset_sweep`] (the sweep gains a vectorized zero-block
//!   skip under `--features simd`; output is bit-identical),
//! * **buffer pools** for dense (`Vec<f32>`) and sparse-index (`Vec<u32>`)
//!   output buffers, refilled by [`EncodeScratch::recycle`],
//! * a **flat batch buffer** for row-blocked numeric batch encodes.
//!
//! A worker that recycles consumed encodings encodes indefinitely with
//! zero steady-state allocations. Outputs that cross a thread boundary
//! come back too: the coordinator's consumer→worker recycle channel
//! returns consumed batches to [`EncodeScratch::recycle_all`], so the
//! pools hold a mix of output capacities (bundled d=20k next to numeric
//! d=10k) — [`EncodeScratch::take_dense_raw`] picks a fitting buffer
//! instead of popping blindly, keeping the loop allocation-free
//! (pinned end-to-end by `tests/alloc_regression.rs`).
//!
//! The scratch paths are **bit-identical** to the allocating paths; the
//! property suite in `tests/scratch_equivalence.rs` enforces this for
//! every encoder.

use crate::encoding::kernels;
use crate::encoding::vector::Encoding;

/// Pooled scratch state shared by all encoders. Plain data (`Send`), one
/// per worker thread; never shared across threads.
#[derive(Debug, Default)]
pub struct EncodeScratch {
    /// Unsorted hashed-coordinate staging area (categorical encoders).
    stage: Vec<u32>,
    /// One bit per coordinate; all-zero between calls by invariant.
    bitset: Vec<u64>,
    /// Dense f32 output buffers returned by [`EncodeScratch::recycle`].
    dense_pool: Vec<Vec<f32>>,
    /// Sparse index output buffers returned by [`EncodeScratch::recycle`].
    index_pool: Vec<Vec<u32>>,
    /// Flat (batch × d) staging buffer for row-blocked batch encodes.
    flat: Vec<f32>,
}

impl EncodeScratch {
    pub fn new() -> EncodeScratch {
        EncodeScratch::default()
    }

    /// Buffers currently pooled (diagnostics / tests).
    pub fn pooled_buffers(&self) -> (usize, usize) {
        (self.dense_pool.len(), self.index_pool.len())
    }

    /// Take the staging buffer (cleared). Return it with
    /// [`EncodeScratch::put_stage`] when done — `std::mem::take` style so
    /// the caller can hold it while also borrowing the scratch.
    #[inline]
    pub fn take_stage(&mut self) -> Vec<u32> {
        let mut v = std::mem::take(&mut self.stage);
        v.clear();
        v
    }

    #[inline]
    pub fn put_stage(&mut self, v: Vec<u32>) {
        self.stage = v;
    }

    /// A cleared index buffer from the pool (or a fresh one with the
    /// requested capacity).
    #[inline]
    pub fn take_index(&mut self, capacity: usize) -> Vec<u32> {
        match self.index_pool.pop() {
            Some(mut v) => {
                v.clear();
                v
            }
            None => Vec::with_capacity(capacity),
        }
    }

    /// A dense buffer of length `d` with **unspecified contents** (callers
    /// that overwrite every element skip the zeroing cost).
    ///
    /// The pool holds mixed capacities once consumers recycle outputs
    /// across the coordinator (e.g. d=20k Concat bundles next to d=10k
    /// numeric codes), so this scans from the most recently pushed buffer
    /// for one that already fits: a too-small pop would either
    /// grow-realloc (memcpy of stale contents) or get dropped, and either
    /// way steady-state allocation churn comes back. The pool is
    /// round-trip bounded (a few dozen buffers), so the scan is a few
    /// pointer-sized compares against a ~40 KiB memset+alloc it avoids.
    #[inline]
    pub fn take_dense_raw(&mut self, d: usize) -> Vec<f32> {
        if let Some(pos) = self.dense_pool.iter().rposition(|v| v.capacity() >= d) {
            let mut v = self.dense_pool.swap_remove(pos);
            v.resize(d, 0.0);
            return v;
        }
        vec![0.0f32; d]
    }

    /// A dense all-zero buffer of length `d`.
    #[inline]
    pub fn take_dense_zeroed(&mut self, d: usize) -> Vec<f32> {
        let mut v = self.take_dense_raw(d);
        v.fill(0.0);
        v
    }

    /// The flat batch buffer resized to `len`, contents unspecified.
    /// Return it with [`EncodeScratch::put_flat`].
    #[inline]
    pub fn take_flat(&mut self, len: usize) -> Vec<f32> {
        let mut v = std::mem::take(&mut self.flat);
        // resize without a clear: only growth is zero-filled, retained
        // elements keep stale contents — the contract is "unspecified"
        // and every caller re-zeroes or fully overwrites, so a full
        // clear+resize would memset batch*d floats per batch for nothing.
        v.resize(len, 0.0);
        v
    }

    #[inline]
    pub fn put_flat(&mut self, v: Vec<f32>) {
        self.flat = v;
    }

    /// Return a consumed encoding's buffer to the pool.
    #[inline]
    pub fn recycle(&mut self, e: Encoding) {
        match e {
            Encoding::Dense(v) => self.dense_pool.push(v),
            Encoding::SparseBinary { indices, .. } => self.index_pool.push(indices),
        }
    }

    /// Return a whole batch of consumed encodings to the pool.
    pub fn recycle_all(&mut self, encs: impl IntoIterator<Item = Encoding>) {
        for e in encs {
            self.recycle(e);
        }
    }

    fn ensure_bitset(&mut self, d: usize) {
        let words = d.div_ceil(64);
        if self.bitset.len() < words {
            self.bitset.resize(words, 0);
        }
    }

    /// Build a sorted-unique sparse encoding from unsorted (possibly
    /// duplicated) staged coordinates — the allocation-free, sort-free
    /// replacement for [`crate::encoding::sparse_from_indices`]. Produces
    /// exactly the same index list (sorted ascending, deduplicated).
    pub fn sparse_from_staged(&mut self, staged: &[u32], d: usize) -> Encoding {
        debug_assert!(staged.iter().all(|&i| (i as usize) < d));
        self.ensure_bitset(d);
        let mut out = self.take_index(staged.len());
        if !staged.is_empty() {
            let (min_w, max_w) = kernels::bitset_mark(&mut self.bitset, staged);
            // Sweep in word order: emits sorted, unique indices and leaves
            // the bitset all-zero again.
            kernels::bitset_sweep(&mut self.bitset, min_w, max_w, &mut out);
        }
        Encoding::SparseBinary { indices: out, d }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::vector::sparse_from_indices;
    use crate::util::rng::Rng;

    #[test]
    fn staged_matches_sort_dedup() {
        let mut rng = Rng::new(1);
        let mut scratch = EncodeScratch::new();
        for case in 0..200 {
            let d = 1 + rng.below_usize(5_000);
            let n = rng.below_usize(300);
            let staged: Vec<u32> = (0..n).map(|_| rng.below(d as u64) as u32).collect();
            let want = sparse_from_indices(staged.clone(), d);
            let got = scratch.sparse_from_staged(&staged, d);
            assert_eq!(got, want, "case {case} d={d} n={n}");
            scratch.recycle(got);
        }
    }

    #[test]
    fn bitset_left_clean_between_calls() {
        let mut scratch = EncodeScratch::new();
        let a = scratch.sparse_from_staged(&[5, 5, 70, 3], 128);
        assert_eq!(
            a,
            Encoding::SparseBinary { indices: vec![3, 5, 70], d: 128 }
        );
        scratch.recycle(a);
        // A second call over the same domain must not see stale bits.
        let b = scratch.sparse_from_staged(&[9], 128);
        assert_eq!(b, Encoding::SparseBinary { indices: vec![9], d: 128 });
    }

    #[test]
    fn empty_staged_is_empty_code() {
        let mut scratch = EncodeScratch::new();
        let e = scratch.sparse_from_staged(&[], 64);
        assert_eq!(e.nnz(), 0);
        assert_eq!(e.dim(), 64);
    }

    #[test]
    fn pools_round_trip() {
        let mut scratch = EncodeScratch::new();
        scratch.recycle(Encoding::Dense(vec![7.0; 16]));
        let v = scratch.take_dense_zeroed(8);
        assert_eq!(v, vec![0.0; 8]);
        assert_eq!(scratch.pooled_buffers(), (0, 0));
        scratch.recycle(Encoding::Dense(v));
        assert_eq!(scratch.pooled_buffers(), (1, 0));
        let raw = scratch.take_dense_raw(4);
        assert_eq!(raw.len(), 4);
    }

    #[test]
    fn stage_round_trip_reuses_capacity() {
        let mut scratch = EncodeScratch::new();
        let mut s = scratch.take_stage();
        s.extend_from_slice(&[1, 2, 3]);
        let cap = s.capacity();
        scratch.put_stage(s);
        let s2 = scratch.take_stage();
        assert!(s2.is_empty());
        assert!(s2.capacity() >= cap.min(3));
    }
}
