//! The encode kernel layer: every hot inner loop of the encoders, behind
//! scalar and SIMD implementations selected by the `simd` cargo feature.
//!
//! PR 1 flattened the encode hot paths into contiguous-array loops; this
//! module centralizes those loops so there is exactly **one** place where
//! each inner loop lives, and so an explicit-SIMD variant can be swapped
//! in without touching any encoder. The callers:
//!
//! | kernel                        | caller(s)                                           | SIMD variant |
//! |-------------------------------|-----------------------------------------------------|--------------|
//! | [`scatter_signed`]            | `Sjlt::encode_into` (fused ±1 scatter, Eq. 5)       | yes          |
//! | [`bitset_sweep`] + [`bitset_mark`] | `EncodeScratch::sparse_from_staged` (Bloom dedup) | yes (sweep)  |
//! | [`unpack_sign_bits_accumulate`] | `DenseHashEncoder` packed mode (bit → ±1 unpack)  | yes          |
//! | [`axpy`], [`sign_quantize`]   | `DenseProjection` project / batch-project / finish  | yes          |
//! | [`signed_sum`]                | `RelaxedSjlt` CSR rows                              | no (see below) |
//! | [`sort_dedup`]                | `sparse_from_indices` (legacy allocating dedup)     | no (see below) |
//!
//! # Feature matrix
//!
//! * default (no features) — the `scalar` implementations are used. They
//!   are written autovectorization-friendly (contiguous slices, no
//!   index arithmetic in the inner loop, branch-free bodies where
//!   possible) and build on **stable** rustc.
//! * `--features simd` — the `simd` implementations are used, built on
//!   portable `std::simd` ([`LANES`] = 8 f32 lanes, i.e. 256-bit vectors;
//!   wider hardware executes two ops per vector, narrower hardware
//!   splits — portable SIMD legalizes either way). Requires a **nightly**
//!   toolchain (`portable_simd` is not stabilized); `lib.rs` enables the
//!   feature gate only when the cargo feature is on, so default builds
//!   stay on stable.
//!
//! Both backends are always *compiled* when the feature is on (`scalar`
//! is a plain module, the active backend is a re-export), which is what
//! makes differential testing possible: `tests/kernel_equivalence.rs`
//! asserts the active backend is **bit-identical** to `scalar` in the
//! same process, across randomized shapes, alignments and tail lengths.
//!
//! # Why bit-identity is required (not just numerical closeness)
//!
//! "A Theoretical Perspective on Hyperdimensional Computing" (Thomas et
//! al., 2020) shows the learning guarantees depend only on the encoding
//! map φ itself, not on how it is computed — *provided the map is
//! preserved exactly*. The repo leans on that: multi-worker pipelines are
//! asserted bit-identical to single-worker runs, scratch paths
//! bit-identical to allocating paths, and the PJRT artifacts are
//! cross-validated against these host implementations. A SIMD path that
//! changed results in the last ulp would silently break every one of
//! those equivalences. So each SIMD kernel is constructed to perform the
//! **same floating-point operations in the same per-element order** as
//! its scalar twin:
//!
//! * [`axpy`], [`sign_quantize`], [`unpack_sign_bits_accumulate`] are
//!   element-independent (one mul+add / compare+select per coordinate,
//!   never reassociated, never contracted into FMA — `std::simd` emits
//!   distinct mul and add ops), so lane-parallelism cannot change any
//!   result bit.
//! * [`scatter_signed`] computes the sign-applied values in vector lanes
//!   but performs the scatter-adds scalar, in ascending `j` order —
//!   colliding buckets accumulate in exactly the scalar order.
//! * [`bitset_sweep`] emits set bits in word order either way; the SIMD
//!   variant only adds a vectorized all-zero block skip.
//! * [`signed_sum`] is a *sequential reduction*: a lane-parallel version
//!   would reassociate the sum and change low bits, so it intentionally
//!   has no SIMD variant (both backends share the scalar loop). Same for
//!   [`sort_dedup`], which is the comparison-sort legacy reference with
//!   nothing to vectorize portably.

/// f32 lanes per vector op in the `simd` backend (256-bit vectors).
pub const LANES: usize = 8;

/// True when this build selected the `std::simd` backend.
pub const SIMD_ENABLED: bool = cfg!(feature = "simd");

/// Human-readable name of the active backend (lands in
/// `BENCH_encode.json` so snapshots record what they measured).
pub const BACKEND: &str = if SIMD_ENABLED { "simd" } else { "scalar" };

#[cfg(not(feature = "simd"))]
pub use scalar::{axpy, bitset_sweep, scatter_signed, sign_quantize, unpack_sign_bits_accumulate};
#[cfg(feature = "simd")]
pub use simd::{axpy, bitset_sweep, scatter_signed, sign_quantize, unpack_sign_bits_accumulate};

// ---------------------------------------------------------------------------
// Shared (backend-independent) kernels
// ---------------------------------------------------------------------------

/// Sequential signed gather-sum over one CSR row:
/// `Σ_j sign(signs[j]) · x[cols[j]]`, accumulated left to right.
///
/// Order-sensitive reduction — a lane-parallel version would reassociate
/// the f32 sum and break bit-identity, so both backends share this loop
/// (the gather itself is the memory-bound part and does not vectorize
/// portably anyway).
#[inline]
pub fn signed_sum(x: &[f32], cols: &[u32], signs: &[i8]) -> f32 {
    debug_assert_eq!(cols.len(), signs.len());
    let mut acc = 0.0f32;
    for (&j, &s) in cols.iter().zip(signs) {
        let v = x[j as usize];
        acc += if s >= 0 { v } else { -v };
    }
    acc
}

/// Sort + dedup an index buffer in place — the legacy allocating-path
/// dedup primitive (`sparse_from_indices` funnels through this, so the
/// legacy and scratch paths both terminate in this module). Comparison
/// sort; no SIMD variant.
#[inline]
pub fn sort_dedup(indices: &mut Vec<u32>) {
    indices.sort_unstable();
    indices.dedup();
}

/// Mark `staged` coordinates in the bitset (one bit per coordinate) and
/// return the inclusive `(min_word, max_word)` span touched. The sweep
/// half of the pair is [`bitset_sweep`]. Scatter of single bits — data-
/// dependent addresses, no SIMD variant.
///
/// `staged` must be non-empty (the returned span would be meaningless)
/// and every index must fall inside `bitset.len() * 64`.
#[inline]
pub fn bitset_mark(bitset: &mut [u64], staged: &[u32]) -> (usize, usize) {
    debug_assert!(!staged.is_empty());
    let mut min_w = usize::MAX;
    let mut max_w = 0usize;
    for &i in staged {
        let w = (i >> 6) as usize;
        bitset[w] |= 1u64 << (i & 63);
        min_w = min_w.min(w);
        max_w = max_w.max(w);
    }
    (min_w, max_w)
}

/// Emit the set bits of word `w` (ascending) into `out` and clear it.
#[inline(always)]
fn emit_word(bitset: &mut [u64], w: usize, out: &mut Vec<u32>) {
    let mut bits = bitset[w];
    if bits == 0 {
        return;
    }
    bitset[w] = 0;
    let base = (w as u32) << 6;
    while bits != 0 {
        out.push(base + bits.trailing_zeros());
        bits &= bits - 1;
    }
}

// ---------------------------------------------------------------------------
// Scalar backend — always compiled; the stable-toolchain default.
// ---------------------------------------------------------------------------

/// Scalar implementations of the vectorizable kernels. Always compiled
/// (even with `--features simd`) so the differential suite can compare
/// the active backend against these in one process.
pub mod scalar {
    /// `z[i] += col[i] * xv` for all i. One mul + one add per element,
    /// in element order; contiguous, so LLVM autovectorizes it on the
    /// stable toolchain.
    #[inline]
    pub fn axpy(z: &mut [f32], col: &[f32], xv: f32) {
        debug_assert_eq!(z.len(), col.len());
        for (zi, &c) in z.iter_mut().zip(col) {
            *zi += c * xv;
        }
    }

    /// `z[i] = if z[i] >= 0 { 1.0 } else { -1.0 }` (Eq. 4's sign with
    /// sign(0) := +1; NaN compares false, hence -1.0 — the SIMD backend
    /// matches both conventions exactly).
    #[inline]
    pub fn sign_quantize(z: &mut [f32]) {
        for zi in z.iter_mut() {
            *zi = if *zi >= 0.0 { 1.0 } else { -1.0 };
        }
    }

    /// The fused SJLT chunk scatter: `out[eta[j]] += ±x[j]` with the sign
    /// taken from `sigma[j]` (±1 as i8), for ascending j. Multiplication-
    /// free (Sec. 4.2.2 cost model): the sign is a select, the update an
    /// add.
    #[inline]
    pub fn scatter_signed(x: &[f32], eta: &[u32], sigma: &[i8], out: &mut [f32]) {
        debug_assert_eq!(x.len(), eta.len());
        debug_assert_eq!(x.len(), sigma.len());
        for j in 0..x.len() {
            let v = if sigma[j] >= 0 { x[j] } else { -x[j] };
            out[eta[j] as usize] += v;
        }
    }

    /// Dense-hash packed unpack: bit i of `word` becomes ±1 added to
    /// `acc[i]` (`0 → +1.0`, `1 → -1.0`). `acc.len() <= 32` selects how
    /// many bits are consumed.
    #[inline]
    pub fn unpack_sign_bits_accumulate(word: u32, acc: &mut [f32]) {
        debug_assert!(acc.len() <= 32);
        let mut w = word;
        for a in acc.iter_mut() {
            *a += if w & 1 == 0 { 1.0 } else { -1.0 };
            w >>= 1;
        }
    }

    /// Sweep bitset words `min_w..=max_w` in order, emitting set bits
    /// (sorted, unique by construction) into `out` and clearing each
    /// visited word — the sort-free Bloom dedup sweep.
    #[inline]
    pub fn bitset_sweep(bitset: &mut [u64], min_w: usize, max_w: usize, out: &mut Vec<u32>) {
        for w in min_w..=max_w {
            super::emit_word(bitset, w, out);
        }
    }
}

// ---------------------------------------------------------------------------
// SIMD backend — portable std::simd, compiled only with `--features simd`
// (nightly toolchain: lib.rs enables `portable_simd` under the feature).
// ---------------------------------------------------------------------------

/// Portable-SIMD implementations. Bit-identical to [`scalar`] by
/// construction (see the module docs); enforced by
/// `tests/kernel_equivalence.rs`.
#[cfg(feature = "simd")]
pub mod simd {
    use super::LANES;
    use std::simd::prelude::*;

    type F32s = Simd<f32, LANES>;
    type U32s = Simd<u32, LANES>;
    type I8s = Simd<i8, LANES>;

    /// Words per vectorized zero-skip block in [`bitset_sweep`].
    const SWEEP_BLOCK: usize = 4;

    /// See [`super::scalar::axpy`]. `zv + cv * xs` lowers to distinct
    /// vector mul and add ops (std::simd never contracts to FMA), so
    /// every element sees exactly the scalar arithmetic.
    #[inline]
    pub fn axpy(z: &mut [f32], col: &[f32], xv: f32) {
        debug_assert_eq!(z.len(), col.len());
        let xs = F32s::splat(xv);
        let mut zc = z.chunks_exact_mut(LANES);
        let mut cc = col.chunks_exact(LANES);
        for (zch, cch) in zc.by_ref().zip(cc.by_ref()) {
            let zv = F32s::from_slice(zch);
            let cv = F32s::from_slice(cch);
            (zv + cv * xs).copy_to_slice(zch);
        }
        for (zi, &c) in zc.into_remainder().iter_mut().zip(cc.remainder()) {
            *zi += c * xv;
        }
    }

    /// See [`super::scalar::sign_quantize`]. `simd_ge` follows IEEE
    /// compare semantics: `-0.0 >= 0.0` is true (→ +1.0), NaN compares
    /// false (→ -1.0) — identical to the scalar branch.
    #[inline]
    pub fn sign_quantize(z: &mut [f32]) {
        let zero = F32s::splat(0.0);
        let pos = F32s::splat(1.0);
        let neg = F32s::splat(-1.0);
        let mut zc = z.chunks_exact_mut(LANES);
        for chunk in zc.by_ref() {
            let v = F32s::from_slice(chunk);
            v.simd_ge(zero).select(pos, neg).copy_to_slice(chunk);
        }
        for zi in zc.into_remainder() {
            *zi = if *zi >= 0.0 { 1.0 } else { -1.0 };
        }
    }

    /// See [`super::scalar::scatter_signed`]. The sign select runs in
    /// vector lanes; the scatter-adds stay scalar in ascending j order,
    /// so colliding buckets accumulate in exactly the scalar order and
    /// the result is bit-identical.
    #[inline]
    pub fn scatter_signed(x: &[f32], eta: &[u32], sigma: &[i8], out: &mut [f32]) {
        debug_assert_eq!(x.len(), eta.len());
        debug_assert_eq!(x.len(), sigma.len());
        let n = x.len();
        let main = n - n % LANES;
        let mut vals = [0.0f32; LANES];
        let mut j = 0;
        while j < main {
            let xv = F32s::from_slice(&x[j..j + LANES]);
            let sg = I8s::from_slice(&sigma[j..j + LANES]).simd_ge(I8s::splat(0));
            sg.cast::<i32>().select(xv, -xv).copy_to_slice(&mut vals);
            for (l, &v) in vals.iter().enumerate() {
                out[eta[j + l] as usize] += v;
            }
            j += LANES;
        }
        for jj in j..n {
            let v = if sigma[jj] >= 0 { x[jj] } else { -x[jj] };
            out[eta[jj] as usize] += v;
        }
    }

    /// See [`super::scalar::unpack_sign_bits_accumulate`]. Each lane
    /// extracts its own bit of `word` (shift amounts stay < 32 because
    /// `acc.len() <= 32`) and adds ±1.0 to its own accumulator element —
    /// element-independent, hence bit-identical.
    #[inline]
    pub fn unpack_sign_bits_accumulate(word: u32, acc: &mut [f32]) {
        debug_assert!(acc.len() <= 32);
        let lane_idx = U32s::from_array({
            let mut a = [0u32; LANES];
            let mut i = 0;
            while i < LANES {
                a[i] = i as u32;
                i += 1;
            }
            a
        });
        let wv = U32s::splat(word);
        let one = U32s::splat(1);
        let zero = U32s::splat(0);
        let pos = F32s::splat(1.0);
        let neg = F32s::splat(-1.0);
        let mut base = 0u32;
        let mut chunks = acc.chunks_exact_mut(LANES);
        for chunk in chunks.by_ref() {
            let bits = (wv >> (lane_idx + U32s::splat(base))) & one;
            let delta = bits.simd_eq(zero).select(pos, neg);
            (F32s::from_slice(chunk) + delta).copy_to_slice(chunk);
            base += LANES as u32;
        }
        for (i, a) in chunks.into_remainder().iter_mut().enumerate() {
            *a += if (word >> (base + i as u32)) & 1 == 0 { 1.0 } else { -1.0 };
        }
    }

    /// See [`super::scalar::bitset_sweep`]. Identical output: the only
    /// difference is that runs of all-zero words are skipped
    /// [`SWEEP_BLOCK`] at a time with one vector reduce-or — sparse
    /// codes leave most of the span empty, which is exactly where the
    /// scalar sweep spends its time.
    #[inline]
    pub fn bitset_sweep(bitset: &mut [u64], min_w: usize, max_w: usize, out: &mut Vec<u32>) {
        let mut w = min_w;
        while w + SWEEP_BLOCK <= max_w + 1 {
            let v = Simd::<u64, SWEEP_BLOCK>::from_slice(&bitset[w..w + SWEEP_BLOCK]);
            if v.reduce_or() != 0 {
                for ww in w..w + SWEEP_BLOCK {
                    super::emit_word(bitset, ww, out);
                }
            }
            w += SWEEP_BLOCK;
        }
        while w <= max_w {
            super::emit_word(bitset, w, out);
            w += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_constants_consistent() {
        assert_eq!(SIMD_ENABLED, cfg!(feature = "simd"));
        assert_eq!(BACKEND, if SIMD_ENABLED { "simd" } else { "scalar" });
    }

    #[test]
    fn scalar_axpy_basic() {
        let mut z = vec![1.0f32, 2.0, 3.0];
        scalar::axpy(&mut z, &[10.0, 20.0, 30.0], 0.5);
        assert_eq!(z, vec![6.0, 12.0, 18.0]);
        // Empty slices are a no-op.
        scalar::axpy(&mut [], &[], 1.0);
    }

    #[test]
    fn scalar_sign_quantize_conventions() {
        let mut z = vec![0.0f32, -0.0, 1.5, -1.5, f32::NAN, f32::INFINITY, f32::NEG_INFINITY];
        scalar::sign_quantize(&mut z);
        // sign(0) := +1 for both zero encodings; NaN -> -1 (compare false).
        assert_eq!(z, vec![1.0, 1.0, 1.0, -1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn scalar_scatter_accumulates_collisions_in_order() {
        let x = [1.0f32, 2.0, 4.0];
        let eta = [1u32, 1, 0];
        let sigma = [1i8, -1, 1];
        let mut out = vec![0.0f32; 2];
        scalar::scatter_signed(&x, &eta, &sigma, &mut out);
        assert_eq!(out, vec![4.0, -1.0]);
    }

    #[test]
    fn scalar_unpack_low_bits() {
        // word 0b...0101: bit0=1 -> -1, bit1=0 -> +1, bit2=1 -> -1.
        let mut acc = vec![0.0f32; 3];
        scalar::unpack_sign_bits_accumulate(0b101, &mut acc);
        assert_eq!(acc, vec![-1.0, 1.0, -1.0]);
        // Full 32-bit width with an all-ones word.
        let mut acc = vec![0.0f32; 32];
        scalar::unpack_sign_bits_accumulate(u32::MAX, &mut acc);
        assert!(acc.iter().all(|&a| a == -1.0));
        scalar::unpack_sign_bits_accumulate(0, &mut []);
    }

    #[test]
    fn mark_sweep_round_trip_sorted_unique_and_clean() {
        let mut bs = vec![0u64; 4];
        let staged = [130u32, 5, 64, 5, 191, 0];
        let (lo, hi) = bitset_mark(&mut bs, &staged);
        assert_eq!((lo, hi), (0, 2));
        let mut out = Vec::new();
        scalar::bitset_sweep(&mut bs, lo, hi, &mut out);
        assert_eq!(out, vec![0, 5, 64, 130, 191]);
        assert!(bs.iter().all(|&w| w == 0), "sweep must clear the bitset");
    }

    #[test]
    fn signed_sum_sequential_order() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let cols = [3u32, 0, 2];
        let signs = [1i8, -1, 1];
        assert_eq!(signed_sum(&x, &cols, &signs), 4.0 - 1.0 + 3.0);
        assert_eq!(signed_sum(&x, &[], &[]), 0.0);
    }

    #[test]
    fn sort_dedup_matches_contract() {
        let mut v = vec![5u32, 1, 5, 3, 1];
        sort_dedup(&mut v);
        assert_eq!(v, vec![1, 3, 5]);
        let mut e: Vec<u32> = Vec::new();
        sort_dedup(&mut e);
        assert!(e.is_empty());
    }
}
