//! The encode kernel layer: every hot inner loop of the encoders, behind
//! scalar and SIMD implementations selected by the `simd` cargo feature.
//!
//! PR 1 flattened the encode hot paths into contiguous-array loops; this
//! module centralizes those loops so there is exactly **one** place where
//! each inner loop lives, and so an explicit-SIMD variant can be swapped
//! in without touching any encoder. The callers:
//!
//! | kernel                        | caller(s)                                           | SIMD variant |
//! |-------------------------------|-----------------------------------------------------|--------------|
//! | [`scatter_signed`]            | `Sjlt::encode_into` (fused ±1 scatter, Eq. 5)       | yes          |
//! | [`bitset_sweep`] + [`bitset_mark`] | `EncodeScratch::sparse_from_staged` (Bloom dedup) | yes (sweep)  |
//! | [`unpack_sign_bits_accumulate`] | `DenseHashEncoder` packed mode (bit → ±1 unpack)  | yes          |
//! | [`axpy`], [`sign_quantize`]   | `DenseProjection` project / batch-project / finish  | yes          |
//! | [`dot_f32`]                   | `am::AmStore` f32 prototype scoring                 | yes          |
//! | [`dot_i8`]                    | `am::AmStore` int8 prototype scoring                | yes          |
//! | [`hamming_packed`], [`and_popcount`] | `am::AmStore` binarized prototype scoring    | yes          |
//! | [`signed_sum`]                | `RelaxedSjlt` CSR rows                              | no (see below) |
//! | [`sort_dedup`]                | `sparse_from_indices` (legacy allocating dedup)     | no (see below) |
//!
//! # Feature matrix
//!
//! * default (no features) — the `scalar` implementations are used. They
//!   are written autovectorization-friendly (contiguous slices, no
//!   index arithmetic in the inner loop, branch-free bodies where
//!   possible) and build on **stable** rustc.
//! * `--features simd` — the `simd` implementations are used, built on
//!   portable `std::simd` ([`LANES`] = 8 f32 lanes, i.e. 256-bit vectors;
//!   wider hardware executes two ops per vector, narrower hardware
//!   splits — portable SIMD legalizes either way). Requires a **nightly**
//!   toolchain (`portable_simd` is not stabilized); `lib.rs` enables the
//!   feature gate only when the cargo feature is on, so default builds
//!   stay on stable.
//!
//! Both backends are always *compiled* when the feature is on (`scalar`
//! is a plain module, the active backend is a re-export), which is what
//! makes differential testing possible: `tests/kernel_equivalence.rs`
//! asserts the active backend is **bit-identical** to `scalar` in the
//! same process, across randomized shapes, alignments and tail lengths.
//!
//! # Why bit-identity is required (not just numerical closeness)
//!
//! "A Theoretical Perspective on Hyperdimensional Computing" (Thomas et
//! al., 2020) shows the learning guarantees depend only on the encoding
//! map φ itself, not on how it is computed — *provided the map is
//! preserved exactly*. The repo leans on that: multi-worker pipelines are
//! asserted bit-identical to single-worker runs, scratch paths
//! bit-identical to allocating paths, and the PJRT artifacts are
//! cross-validated against these host implementations. A SIMD path that
//! changed results in the last ulp would silently break every one of
//! those equivalences. So each SIMD kernel is constructed to perform the
//! **same floating-point operations in the same per-element order** as
//! its scalar twin:
//!
//! * [`axpy`], [`sign_quantize`], [`unpack_sign_bits_accumulate`] are
//!   element-independent (one mul+add / compare+select per coordinate,
//!   never reassociated, never contracted into FMA — `std::simd` emits
//!   distinct mul and add ops), so lane-parallelism cannot change any
//!   result bit.
//! * [`scatter_signed`] computes the sign-applied values in vector lanes
//!   but performs the scatter-adds scalar, in ascending `j` order —
//!   colliding buckets accumulate in exactly the scalar order.
//! * [`bitset_sweep`] emits set bits in word order either way; the SIMD
//!   variant only adds a vectorized all-zero block skip.
//! * [`signed_sum`] is a *sequential reduction*: a lane-parallel version
//!   would reassociate the sum and change low bits, so it intentionally
//!   has no SIMD variant (both backends share the scalar loop). Same for
//!   [`sort_dedup`], which is the comparison-sort legacy reference with
//!   nothing to vectorize portably.
//! * [`dot_f32`] *is* a reduction, but unlike [`signed_sum`] it gets a
//!   SIMD variant by fixing the association order in the kernel
//!   **contract**: both backends accumulate [`LANES`] lane-striped
//!   partial sums over the full chunks, fold them with the fixed tree
//!   [`fold_lanes`], and add a sequentially-accumulated tail. The scalar
//!   backend performs that exact schedule without vector ops, so the
//!   backends stay bit-identical (enforced like the rest of the suite).
//! * [`dot_i8`], [`hamming_packed`] and [`and_popcount`] are integer
//!   reductions — associative and exact — so the SIMD variants are free
//!   to reassociate and bit-identity is automatic.

/// f32 lanes per vector op in the `simd` backend (256-bit vectors).
pub const LANES: usize = 8;

/// True when this build selected the `std::simd` backend.
pub const SIMD_ENABLED: bool = cfg!(feature = "simd");

/// Human-readable name of the active backend (lands in
/// `BENCH_encode.json` so snapshots record what they measured).
pub const BACKEND: &str = if SIMD_ENABLED { "simd" } else { "scalar" };

#[cfg(not(feature = "simd"))]
pub use scalar::{
    and_popcount, axpy, bitset_sweep, dot_f32, dot_i8, hamming_packed, scatter_signed,
    sign_quantize, unpack_sign_bits_accumulate,
};
#[cfg(feature = "simd")]
pub use simd::{
    and_popcount, axpy, bitset_sweep, dot_f32, dot_i8, hamming_packed, scatter_signed,
    sign_quantize, unpack_sign_bits_accumulate,
};

// ---------------------------------------------------------------------------
// Shared (backend-independent) kernels
// ---------------------------------------------------------------------------

/// Sequential signed gather-sum over one CSR row:
/// `Σ_j sign(signs[j]) · x[cols[j]]`, accumulated left to right.
///
/// Order-sensitive reduction — a lane-parallel version would reassociate
/// the f32 sum and break bit-identity, so both backends share this loop
/// (the gather itself is the memory-bound part and does not vectorize
/// portably anyway).
#[inline]
pub fn signed_sum(x: &[f32], cols: &[u32], signs: &[i8]) -> f32 {
    debug_assert_eq!(cols.len(), signs.len());
    let mut acc = 0.0f32;
    for (&j, &s) in cols.iter().zip(signs) {
        let v = x[j as usize];
        acc += if s >= 0 { v } else { -v };
    }
    acc
}

/// Sort + dedup an index buffer in place — the legacy allocating-path
/// dedup primitive (`sparse_from_indices` funnels through this, so the
/// legacy and scratch paths both terminate in this module). Comparison
/// sort; no SIMD variant.
#[inline]
pub fn sort_dedup(indices: &mut Vec<u32>) {
    indices.sort_unstable();
    indices.dedup();
}

/// Mark `staged` coordinates in the bitset (one bit per coordinate) and
/// return the inclusive `(min_word, max_word)` span touched. The sweep
/// half of the pair is [`bitset_sweep`]. Scatter of single bits — data-
/// dependent addresses, no SIMD variant.
///
/// `staged` must be non-empty (the returned span would be meaningless)
/// and every index must fall inside `bitset.len() * 64`.
#[inline]
pub fn bitset_mark(bitset: &mut [u64], staged: &[u32]) -> (usize, usize) {
    debug_assert!(!staged.is_empty());
    let mut min_w = usize::MAX;
    let mut max_w = 0usize;
    for &i in staged {
        let w = (i >> 6) as usize;
        bitset[w] |= 1u64 << (i & 63);
        min_w = min_w.min(w);
        max_w = max_w.max(w);
    }
    (min_w, max_w)
}

/// The fixed fold tree both [`dot_f32`] backends use to combine their
/// [`LANES`] striped partial sums: pairwise, then pairwise again, then
/// one final add — the same shape a binary vector reduction performs, so
/// the SIMD backend can reuse it verbatim on the extracted lanes.
#[inline]
pub fn fold_lanes(acc: [f32; LANES]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Emit the set bits of word `w` (ascending) into `out` and clear it.
#[inline(always)]
fn emit_word(bitset: &mut [u64], w: usize, out: &mut Vec<u32>) {
    let mut bits = bitset[w];
    if bits == 0 {
        return;
    }
    bitset[w] = 0;
    let base = (w as u32) << 6;
    while bits != 0 {
        out.push(base + bits.trailing_zeros());
        bits &= bits - 1;
    }
}

// ---------------------------------------------------------------------------
// Scalar backend — always compiled; the stable-toolchain default.
// ---------------------------------------------------------------------------

/// Scalar implementations of the vectorizable kernels. Always compiled
/// (even with `--features simd`) so the differential suite can compare
/// the active backend against these in one process.
pub mod scalar {
    /// `z[i] += col[i] * xv` for all i. One mul + one add per element,
    /// in element order; contiguous, so LLVM autovectorizes it on the
    /// stable toolchain.
    #[inline]
    pub fn axpy(z: &mut [f32], col: &[f32], xv: f32) {
        debug_assert_eq!(z.len(), col.len());
        for (zi, &c) in z.iter_mut().zip(col) {
            *zi += c * xv;
        }
    }

    /// `z[i] = if z[i] >= 0 { 1.0 } else { -1.0 }` (Eq. 4's sign with
    /// sign(0) := +1; NaN compares false, hence -1.0 — the SIMD backend
    /// matches both conventions exactly).
    #[inline]
    pub fn sign_quantize(z: &mut [f32]) {
        for zi in z.iter_mut() {
            *zi = if *zi >= 0.0 { 1.0 } else { -1.0 };
        }
    }

    /// The fused SJLT chunk scatter: `out[eta[j]] += ±x[j]` with the sign
    /// taken from `sigma[j]` (±1 as i8), for ascending j. Multiplication-
    /// free (Sec. 4.2.2 cost model): the sign is a select, the update an
    /// add.
    #[inline]
    pub fn scatter_signed(x: &[f32], eta: &[u32], sigma: &[i8], out: &mut [f32]) {
        debug_assert_eq!(x.len(), eta.len());
        debug_assert_eq!(x.len(), sigma.len());
        for j in 0..x.len() {
            let v = if sigma[j] >= 0 { x[j] } else { -x[j] };
            out[eta[j] as usize] += v;
        }
    }

    /// Dense-hash packed unpack: bit i of `word` becomes ±1 added to
    /// `acc[i]` (`0 → +1.0`, `1 → -1.0`). `acc.len() <= 32` selects how
    /// many bits are consumed.
    #[inline]
    pub fn unpack_sign_bits_accumulate(word: u32, acc: &mut [f32]) {
        debug_assert!(acc.len() <= 32);
        let mut w = word;
        for a in acc.iter_mut() {
            *a += if w & 1 == 0 { 1.0 } else { -1.0 };
            w >>= 1;
        }
    }

    /// Sweep bitset words `min_w..=max_w` in order, emitting set bits
    /// (sorted, unique by construction) into `out` and clearing each
    /// visited word — the sort-free Bloom dedup sweep.
    #[inline]
    pub fn bitset_sweep(bitset: &mut [u64], min_w: usize, max_w: usize, out: &mut Vec<u32>) {
        for w in min_w..=max_w {
            super::emit_word(bitset, w, out);
        }
    }

    /// Lane-striped f32 dot product (the AM scoring primitive, one class
    /// prototype per call). The association order is part of the kernel
    /// contract — [`super::LANES`] striped partial sums over the full
    /// chunks, [`super::fold_lanes`] tree, sequential tail — so the SIMD
    /// twin performs the identical f32 ops in the identical order.
    #[inline]
    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0.0f32; super::LANES];
        let mut ac = a.chunks_exact(super::LANES);
        let mut bc = b.chunks_exact(super::LANES);
        for (av, bv) in ac.by_ref().zip(bc.by_ref()) {
            for l in 0..super::LANES {
                acc[l] += av[l] * bv[l];
            }
        }
        let mut tail = 0.0f32;
        for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
            tail += x * y;
        }
        super::fold_lanes(acc) + tail
    }

    /// Widening int8 dot product (quantized AM scoring): `Σ a[i]·b[i]`
    /// accumulated in i64 so no input length can overflow. Integer, hence
    /// exact under any association order.
    #[inline]
    pub fn dot_i8(a: &[i8], b: &[i8]) -> i64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = 0i64;
        for (&x, &y) in a.iter().zip(b) {
            acc += x as i64 * y as i64;
        }
        acc
    }

    /// Popcount-Hamming distance between two equal-length packed bit
    /// rows: `Σ popcount(a[w] ^ b[w])` — the binarized-AM scoring
    /// primitive (a ±1 dot product is `d - 2·hamming`).
    #[inline]
    pub fn hamming_packed(a: &[u64], b: &[u64]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = 0u64;
        for (&x, &y) in a.iter().zip(b) {
            acc += (x ^ y).count_ones() as u64;
        }
        acc
    }

    /// Popcount of the intersection `Σ popcount(a[w] & b[w])` — scores a
    /// packed *sparse* (0/1) query against a packed sign row: the ±1 dot
    /// is `nnz - 2·overlap` with the negative-coordinate mask.
    #[inline]
    pub fn and_popcount(a: &[u64], b: &[u64]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = 0u64;
        for (&x, &y) in a.iter().zip(b) {
            acc += (x & y).count_ones() as u64;
        }
        acc
    }
}

// ---------------------------------------------------------------------------
// SIMD backend — portable std::simd, compiled only with `--features simd`
// (nightly toolchain: lib.rs enables `portable_simd` under the feature).
// ---------------------------------------------------------------------------

/// Portable-SIMD implementations. Bit-identical to [`scalar`] by
/// construction (see the module docs); enforced by
/// `tests/kernel_equivalence.rs`.
#[cfg(feature = "simd")]
pub mod simd {
    use super::LANES;
    use std::simd::prelude::*;

    type F32s = Simd<f32, LANES>;
    type U32s = Simd<u32, LANES>;
    type I8s = Simd<i8, LANES>;

    /// Words per vectorized zero-skip block in [`bitset_sweep`].
    const SWEEP_BLOCK: usize = 4;

    /// See [`super::scalar::axpy`]. `zv + cv * xs` lowers to distinct
    /// vector mul and add ops (std::simd never contracts to FMA), so
    /// every element sees exactly the scalar arithmetic.
    #[inline]
    pub fn axpy(z: &mut [f32], col: &[f32], xv: f32) {
        debug_assert_eq!(z.len(), col.len());
        let xs = F32s::splat(xv);
        let mut zc = z.chunks_exact_mut(LANES);
        let mut cc = col.chunks_exact(LANES);
        for (zch, cch) in zc.by_ref().zip(cc.by_ref()) {
            let zv = F32s::from_slice(zch);
            let cv = F32s::from_slice(cch);
            (zv + cv * xs).copy_to_slice(zch);
        }
        for (zi, &c) in zc.into_remainder().iter_mut().zip(cc.remainder()) {
            *zi += c * xv;
        }
    }

    /// See [`super::scalar::sign_quantize`]. `simd_ge` follows IEEE
    /// compare semantics: `-0.0 >= 0.0` is true (→ +1.0), NaN compares
    /// false (→ -1.0) — identical to the scalar branch.
    #[inline]
    pub fn sign_quantize(z: &mut [f32]) {
        let zero = F32s::splat(0.0);
        let pos = F32s::splat(1.0);
        let neg = F32s::splat(-1.0);
        let mut zc = z.chunks_exact_mut(LANES);
        for chunk in zc.by_ref() {
            let v = F32s::from_slice(chunk);
            v.simd_ge(zero).select(pos, neg).copy_to_slice(chunk);
        }
        for zi in zc.into_remainder() {
            *zi = if *zi >= 0.0 { 1.0 } else { -1.0 };
        }
    }

    /// See [`super::scalar::scatter_signed`]. The sign select runs in
    /// vector lanes; the scatter-adds stay scalar in ascending j order,
    /// so colliding buckets accumulate in exactly the scalar order and
    /// the result is bit-identical.
    #[inline]
    pub fn scatter_signed(x: &[f32], eta: &[u32], sigma: &[i8], out: &mut [f32]) {
        debug_assert_eq!(x.len(), eta.len());
        debug_assert_eq!(x.len(), sigma.len());
        let n = x.len();
        let main = n - n % LANES;
        let mut vals = [0.0f32; LANES];
        let mut j = 0;
        while j < main {
            let xv = F32s::from_slice(&x[j..j + LANES]);
            let sg = I8s::from_slice(&sigma[j..j + LANES]).simd_ge(I8s::splat(0));
            sg.cast::<i32>().select(xv, -xv).copy_to_slice(&mut vals);
            for (l, &v) in vals.iter().enumerate() {
                out[eta[j + l] as usize] += v;
            }
            j += LANES;
        }
        for jj in j..n {
            let v = if sigma[jj] >= 0 { x[jj] } else { -x[jj] };
            out[eta[jj] as usize] += v;
        }
    }

    /// See [`super::scalar::unpack_sign_bits_accumulate`]. Each lane
    /// extracts its own bit of `word` (shift amounts stay < 32 because
    /// `acc.len() <= 32`) and adds ±1.0 to its own accumulator element —
    /// element-independent, hence bit-identical.
    #[inline]
    pub fn unpack_sign_bits_accumulate(word: u32, acc: &mut [f32]) {
        debug_assert!(acc.len() <= 32);
        let lane_idx = U32s::from_array({
            let mut a = [0u32; LANES];
            let mut i = 0;
            while i < LANES {
                a[i] = i as u32;
                i += 1;
            }
            a
        });
        let wv = U32s::splat(word);
        let one = U32s::splat(1);
        let zero = U32s::splat(0);
        let pos = F32s::splat(1.0);
        let neg = F32s::splat(-1.0);
        let mut base = 0u32;
        let mut chunks = acc.chunks_exact_mut(LANES);
        for chunk in chunks.by_ref() {
            let bits = (wv >> (lane_idx + U32s::splat(base))) & one;
            let delta = bits.simd_eq(zero).select(pos, neg);
            (F32s::from_slice(chunk) + delta).copy_to_slice(chunk);
            base += LANES as u32;
        }
        for (i, a) in chunks.into_remainder().iter_mut().enumerate() {
            *a += if (word >> (base + i as u32)) & 1 == 0 { 1.0 } else { -1.0 };
        }
    }

    /// See [`super::scalar::bitset_sweep`]. Identical output: the only
    /// difference is that runs of all-zero words are skipped
    /// [`SWEEP_BLOCK`] at a time with one vector reduce-or — sparse
    /// codes leave most of the span empty, which is exactly where the
    /// scalar sweep spends its time.
    #[inline]
    pub fn bitset_sweep(bitset: &mut [u64], min_w: usize, max_w: usize, out: &mut Vec<u32>) {
        let mut w = min_w;
        while w + SWEEP_BLOCK <= max_w + 1 {
            let v = Simd::<u64, SWEEP_BLOCK>::from_slice(&bitset[w..w + SWEEP_BLOCK]);
            if v.reduce_or() != 0 {
                for ww in w..w + SWEEP_BLOCK {
                    super::emit_word(bitset, ww, out);
                }
            }
            w += SWEEP_BLOCK;
        }
        while w <= max_w {
            super::emit_word(bitset, w, out);
            w += 1;
        }
    }

    /// See [`super::scalar::dot_f32`]. One vector accumulator holds the
    /// LANES striped partial sums (per-lane `acc + a*b` — distinct mul
    /// and add ops, never contracted to FMA, exactly the scalar per-lane
    /// schedule); the lanes are extracted and folded with the shared
    /// [`super::fold_lanes`] tree, and the tail accumulates sequentially.
    #[inline]
    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = F32s::splat(0.0);
        let mut ac = a.chunks_exact(LANES);
        let mut bc = b.chunks_exact(LANES);
        for (av, bv) in ac.by_ref().zip(bc.by_ref()) {
            acc = acc + F32s::from_slice(av) * F32s::from_slice(bv);
        }
        let mut tail = 0.0f32;
        for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
            tail += x * y;
        }
        super::fold_lanes(acc.to_array()) + tail
    }

    /// See [`super::scalar::dot_i8`]. i16-multiply widening dot: a
    /// 16-element block is two 8-lane i8 chunks widened to i16, where
    /// every lane product is exact (`|x·y| ≤ 128² = 16384 < 2^15`); the
    /// pair of products then widens to i32 *before* summing — the pair
    /// sum can reach `2·(−128)² = 32768`, one past `i16::MAX`, so it
    /// must not be taken in i16 — and accumulates into i64. Keeping the
    /// multiplies in i16 halves the widening work per block, which is
    /// what makes the int8 scan pull ahead of f32 at many-class scale.
    /// Integer arithmetic throughout, so any association order gives
    /// the exact scalar result (pinned in `tests/kernel_equivalence.rs`
    /// across the full i8 range, including ±127 and `i8::MIN`
    /// worst-case magnitudes).
    #[inline]
    pub fn dot_i8(a: &[i8], b: &[i8]) -> i64 {
        debug_assert_eq!(a.len(), b.len());
        const BLOCK: usize = 16;
        let mut acc = Simd::<i64, 8>::splat(0);
        let mut ac = a.chunks_exact(BLOCK);
        let mut bc = b.chunks_exact(BLOCK);
        for (av, bv) in ac.by_ref().zip(bc.by_ref()) {
            let a0 = Simd::<i8, 8>::from_slice(&av[..8]).cast::<i16>();
            let a1 = Simd::<i8, 8>::from_slice(&av[8..]).cast::<i16>();
            let b0 = Simd::<i8, 8>::from_slice(&bv[..8]).cast::<i16>();
            let b1 = Simd::<i8, 8>::from_slice(&bv[8..]).cast::<i16>();
            let pair = (a0 * b0).cast::<i32>() + (a1 * b1).cast::<i32>();
            acc += pair.cast::<i64>();
        }
        let mut tail = 0i64;
        for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
            tail += x as i64 * y as i64;
        }
        acc.reduce_sum() + tail
    }

    /// See [`super::scalar::hamming_packed`]. The xor runs in u64×4
    /// vectors; the per-word popcounts stay scalar (`count_ones` lowers
    /// to the hardware popcount and keeps us off the still-moving
    /// `std::simd` popcount API). Integer sum — exact in any order.
    #[inline]
    pub fn hamming_packed(a: &[u64], b: &[u64]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = 0u64;
        let mut ac = a.chunks_exact(POP_BLOCK);
        let mut bc = b.chunks_exact(POP_BLOCK);
        for (av, bv) in ac.by_ref().zip(bc.by_ref()) {
            let v = U64x4::from_slice(av) ^ U64x4::from_slice(bv);
            for w in v.to_array() {
                acc += w.count_ones() as u64;
            }
        }
        for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
            acc += (x ^ y).count_ones() as u64;
        }
        acc
    }

    /// See [`super::scalar::and_popcount`] — same schedule as
    /// [`hamming_packed`] with `&` in place of `^`.
    #[inline]
    pub fn and_popcount(a: &[u64], b: &[u64]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = 0u64;
        let mut ac = a.chunks_exact(POP_BLOCK);
        let mut bc = b.chunks_exact(POP_BLOCK);
        for (av, bv) in ac.by_ref().zip(bc.by_ref()) {
            let v = U64x4::from_slice(av) & U64x4::from_slice(bv);
            for w in v.to_array() {
                acc += w.count_ones() as u64;
            }
        }
        for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
            acc += (x & y).count_ones() as u64;
        }
        acc
    }

    /// Words per vector op in the packed-popcount kernels (256-bit).
    const POP_BLOCK: usize = 4;
    type U64x4 = Simd<u64, POP_BLOCK>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_constants_consistent() {
        assert_eq!(SIMD_ENABLED, cfg!(feature = "simd"));
        assert_eq!(BACKEND, if SIMD_ENABLED { "simd" } else { "scalar" });
    }

    #[test]
    fn scalar_axpy_basic() {
        let mut z = vec![1.0f32, 2.0, 3.0];
        scalar::axpy(&mut z, &[10.0, 20.0, 30.0], 0.5);
        assert_eq!(z, vec![6.0, 12.0, 18.0]);
        // Empty slices are a no-op.
        scalar::axpy(&mut [], &[], 1.0);
    }

    #[test]
    fn scalar_sign_quantize_conventions() {
        let mut z = vec![0.0f32, -0.0, 1.5, -1.5, f32::NAN, f32::INFINITY, f32::NEG_INFINITY];
        scalar::sign_quantize(&mut z);
        // sign(0) := +1 for both zero encodings; NaN -> -1 (compare false).
        assert_eq!(z, vec![1.0, 1.0, 1.0, -1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn scalar_scatter_accumulates_collisions_in_order() {
        let x = [1.0f32, 2.0, 4.0];
        let eta = [1u32, 1, 0];
        let sigma = [1i8, -1, 1];
        let mut out = vec![0.0f32; 2];
        scalar::scatter_signed(&x, &eta, &sigma, &mut out);
        assert_eq!(out, vec![4.0, -1.0]);
    }

    #[test]
    fn scalar_unpack_low_bits() {
        // word 0b...0101: bit0=1 -> -1, bit1=0 -> +1, bit2=1 -> -1.
        let mut acc = vec![0.0f32; 3];
        scalar::unpack_sign_bits_accumulate(0b101, &mut acc);
        assert_eq!(acc, vec![-1.0, 1.0, -1.0]);
        // Full 32-bit width with an all-ones word.
        let mut acc = vec![0.0f32; 32];
        scalar::unpack_sign_bits_accumulate(u32::MAX, &mut acc);
        assert!(acc.iter().all(|&a| a == -1.0));
        scalar::unpack_sign_bits_accumulate(0, &mut []);
    }

    #[test]
    fn mark_sweep_round_trip_sorted_unique_and_clean() {
        let mut bs = vec![0u64; 4];
        let staged = [130u32, 5, 64, 5, 191, 0];
        let (lo, hi) = bitset_mark(&mut bs, &staged);
        assert_eq!((lo, hi), (0, 2));
        let mut out = Vec::new();
        scalar::bitset_sweep(&mut bs, lo, hi, &mut out);
        assert_eq!(out, vec![0, 5, 64, 130, 191]);
        assert!(bs.iter().all(|&w| w == 0), "sweep must clear the bitset");
    }

    #[test]
    fn signed_sum_sequential_order() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let cols = [3u32, 0, 2];
        let signs = [1i8, -1, 1];
        assert_eq!(signed_sum(&x, &cols, &signs), 4.0 - 1.0 + 3.0);
        assert_eq!(signed_sum(&x, &[], &[]), 0.0);
    }

    #[test]
    fn dot_f32_striped_contract_and_empty() {
        // 10 elements = one full LANES chunk + a 2-element tail.
        let a: Vec<f32> = (1..=10).map(|i| i as f32).collect();
        let b: Vec<f32> = (1..=10).map(|i| (i as f32) * 0.5).collect();
        // Striped reference: acc[l] = a[l]*b[l] over the single chunk,
        // fold tree, then the sequential tail.
        let mut acc = [0.0f32; LANES];
        for l in 0..LANES {
            acc[l] += a[l] * b[l];
        }
        let want = fold_lanes(acc) + (a[8] * b[8] + a[9] * b[9]);
        assert_eq!(scalar::dot_f32(&a, &b).to_bits(), want.to_bits());
        assert_eq!(scalar::dot_f32(&[], &[]), 0.0);
        // Sub-lane input is tail-only (pure sequential accumulation).
        assert_eq!(scalar::dot_f32(&a[..3], &b[..3]), a[0] * b[0] + a[1] * b[1] + a[2] * b[2]);
    }

    #[test]
    fn dot_i8_widens_without_overflow() {
        let a = vec![127i8; 1000];
        let b = vec![-127i8; 1000];
        assert_eq!(scalar::dot_i8(&a, &b), -127i64 * 127 * 1000);
        assert_eq!(scalar::dot_i8(&[], &[]), 0);
        assert_eq!(scalar::dot_i8(&[3, -2], &[-4, 5]), -22);
    }

    #[test]
    fn packed_popcounts_basic() {
        let a = [0b1011u64, u64::MAX, 0];
        let b = [0b0001u64, 0, 0];
        assert_eq!(scalar::hamming_packed(&a, &b), 2 + 64);
        assert_eq!(scalar::and_popcount(&a, &b), 1);
        assert_eq!(scalar::hamming_packed(&[], &[]), 0);
        assert_eq!(scalar::and_popcount(&a, &a), 3 + 64);
    }

    #[test]
    fn sort_dedup_matches_contract() {
        let mut v = vec![5u32, 1, 5, 3, 1];
        sort_dedup(&mut v);
        assert_eq!(v, vec![1, 3, 5]);
        let mut e: Vec<u32> = Vec::new();
        sort_dedup(&mut e);
        assert!(e.is_empty());
    }
}
