//! Dense random-projection numeric encoder (paper Eq. 4, Sec. 5.1).
//!
//! `phi(x) = sign(Phi x)` with rows of Phi drawn `Unif(S^{n-1})`. This is
//! the rust mirror of the Pallas/PJRT artifact `encode_project_sign` —
//! the streaming pipeline uses the artifact for batched training, while
//! this implementation serves the hardware simulators, single-record
//! paths, and cross-validation tests (rust vs artifact numerics).

use crate::encoding::kernels;
use crate::encoding::scratch::EncodeScratch;
use crate::encoding::vector::{sparse_from_indices, Encoding};
use crate::encoding::NumericEncoder;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectionMode {
    /// Raw z = Phi x.
    Raw,
    /// Eq. 4: sign(Phi x), sign(0) := +1.
    Sign,
}

#[derive(Clone, Debug)]
pub struct DenseProjection {
    /// Row-major (d x n) — the layout the PJRT artifacts consume.
    pub phi: Vec<f32>,
    /// Transposed copy (n x d): the compute layout. The projection is an
    /// AXPY over contiguous d-length rows, which auto-vectorizes; the
    /// row-major layout's n=13-long inner products do not (§Perf).
    phi_t: Vec<f32>,
    pub d: usize,
    pub n: usize,
    pub mode: ProjectionMode,
}

impl DenseProjection {
    /// Rows ~ Unif(S^{n-1}).
    pub fn new(d: usize, n: usize, mode: ProjectionMode, rng: &mut Rng) -> Self {
        let mut phi = Vec::with_capacity(d * n);
        for _ in 0..d {
            phi.extend(rng.unit_vector(n));
        }
        let mut phi_t = vec![0.0f32; n * d];
        for i in 0..d {
            for j in 0..n {
                phi_t[j * d + i] = phi[i * n + j];
            }
        }
        DenseProjection { phi, phi_t, d, n, mode }
    }

    /// z = Phi x into a caller buffer (hot path: no allocation): n
    /// accumulating [`kernels::axpy`] passes over contiguous d-length
    /// rows of the transposed matrix (explicit SIMD under `--features
    /// simd`, autovectorized scalar otherwise — bit-identical results).
    pub fn project_into(&self, x: &[f32], z: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(z.len(), self.d);
        z.fill(0.0);
        for (j, &xv) in x.iter().enumerate() {
            kernels::axpy(z, &self.phi_t[j * self.d..(j + 1) * self.d], xv);
        }
    }

    pub fn encode_record(&self, x: &[f32]) -> Encoding {
        let mut z = vec![0.0f32; self.d];
        self.project_into(x, &mut z);
        self.finish(&mut z);
        Encoding::Dense(z)
    }

    /// Apply the mode (sign quantization) in place.
    #[inline]
    fn finish(&self, z: &mut [f32]) {
        if self.mode == ProjectionMode::Sign {
            kernels::sign_quantize(z);
        }
    }

    /// Scratch-path [`DenseProjection::encode_record`]: the output buffer
    /// comes from the pool (project_into zeroes it). Bit-identical.
    pub fn encode_record_with(&self, x: &[f32], scratch: &mut EncodeScratch) -> Encoding {
        let mut z = scratch.take_dense_raw(self.d);
        self.project_into(x, &mut z);
        self.finish(&mut z);
        Encoding::Dense(z)
    }

    /// Flattened Phi for feeding the PJRT artifact (same row-major layout).
    pub fn phi_flat(&self) -> &[f32] {
        &self.phi
    }
}

impl DenseProjection {
    /// Tiled batch projection core (§Perf): iterate d in L2-sized tiles;
    /// for each record-block the 13 transposed-Phi tile rows are reused,
    /// so Phi traffic per record drops by the block factor, and the inner
    /// loop stays a vectorizable contiguous AXPY. Generic over the input
    /// accessor so the slice-of-rows and flat-buffer entry points share
    /// one loop (identical op order → bit-identical outputs).
    fn project_batch_core<X: Fn(usize, usize) -> f32>(&self, bsz: usize, x: X, zs: &mut [f32]) {
        const TILE: usize = 4096; // 16 KiB of f32 per tile row
        const BLOCK: usize = 8; // records sharing one tile pass
        debug_assert_eq!(zs.len(), bsz * self.d);
        zs.fill(0.0);
        let mut tile_start = 0;
        while tile_start < self.d {
            let tile_len = TILE.min(self.d - tile_start);
            let mut b0 = 0;
            while b0 < bsz {
                let bend = (b0 + BLOCK).min(bsz);
                for (j, col_all) in self.phi_t.chunks_exact(self.d).enumerate() {
                    let col = &col_all[tile_start..tile_start + tile_len];
                    for b in b0..bend {
                        let xv = x(b, j);
                        let zrow =
                            &mut zs[b * self.d + tile_start..b * self.d + tile_start + tile_len];
                        kernels::axpy(zrow, col, xv);
                    }
                }
                b0 = bend;
            }
            tile_start += tile_len;
        }
    }

    /// Tiled batch projection over per-record slices.
    pub fn project_batch_into(&self, xs: &[&[f32]], zs: &mut [f32]) {
        self.project_batch_core(xs.len(), |b, j| xs[b][j], zs);
    }

    /// Tiled batch projection over a row-major flat input
    /// (`xs_flat.len() = batch · n`). Bit-identical to
    /// [`DenseProjection::project_batch_into`] over the same rows.
    pub fn project_batch_flat_into(&self, xs_flat: &[f32], zs: &mut [f32]) {
        debug_assert!(self.n > 0);
        debug_assert_eq!(xs_flat.len() % self.n, 0);
        let n = self.n;
        self.project_batch_core(xs_flat.len() / n, |b, j| xs_flat[b * n + j], zs);
    }
}

impl NumericEncoder for DenseProjection {
    fn encode(&self, x: &[f32]) -> Encoding {
        self.encode_record(x)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn name(&self) -> &'static str {
        match self.mode {
            ProjectionMode::Raw => "projection-raw",
            ProjectionMode::Sign => "projection-sign",
        }
    }

    fn encode_batch(&self, xs: &[&[f32]]) -> Vec<Encoding> {
        let bsz = xs.len();
        let mut zs = vec![0.0f32; bsz * self.d];
        self.project_batch_into(xs, &mut zs);
        zs.chunks_exact(self.d)
            .map(|z| {
                let mut buf = z.to_vec();
                if self.mode == ProjectionMode::Sign {
                    kernels::sign_quantize(&mut buf);
                }
                Encoding::Dense(buf)
            })
            .collect()
    }

    fn encode_with(&self, x: &[f32], scratch: &mut EncodeScratch) -> Encoding {
        self.encode_record_with(x, scratch)
    }

    fn encode_batch_with(
        &self,
        xs: &[&[f32]],
        scratch: &mut EncodeScratch,
        out: &mut Vec<Encoding>,
    ) {
        let mut zs = scratch.take_flat(xs.len() * self.d);
        self.project_batch_into(xs, &mut zs);
        self.finish_batch(&zs, scratch, out);
        scratch.put_flat(zs);
    }

    fn encode_batch_flat_with(
        &self,
        xs_flat: &[f32],
        n: usize,
        scratch: &mut EncodeScratch,
        out: &mut Vec<Encoding>,
    ) {
        assert!(n > 0, "encode_batch_flat_with needs a positive row width");
        assert_eq!(n, self.n, "row width must match the projection input dim");
        assert_eq!(xs_flat.len() % n, 0, "flat batch not a multiple of n={n}");
        let bsz = xs_flat.len() / n;
        let mut zs = scratch.take_flat(bsz * self.d);
        self.project_batch_flat_into(xs_flat, &mut zs);
        self.finish_batch(&zs, scratch, out);
        scratch.put_flat(zs);
    }
}

impl DenseProjection {
    /// Copy projected rows into pooled per-record buffers, applying the
    /// mode — the shared tail of both batch entry points.
    fn finish_batch(&self, zs: &[f32], scratch: &mut EncodeScratch, out: &mut Vec<Encoding>) {
        out.clear();
        for z in zs.chunks_exact(self.d) {
            let mut buf = scratch.take_dense_raw(self.d);
            buf.copy_from_slice(z);
            if self.mode == ProjectionMode::Sign {
                kernels::sign_quantize(&mut buf);
            }
            out.push(Encoding::Dense(buf));
        }
    }
}

/// Sparse random projection (paper Eq. 6 and Sec. 5.3): binarize z by
/// top-k or by a fixed threshold t with Pr(|z_i| >= t) ~ k/d.
#[derive(Clone, Debug)]
pub struct SparseProjection {
    pub proj: DenseProjection,
    pub rule: SparsifyRule,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SparsifyRule {
    /// Eq. 6: the k largest coordinates of z are set to 1.
    TopK(usize),
    /// Sec. 5.3: coordinates with |z_i| >= t are set to 1 (the
    /// sort-free variant used in the FPGA design).
    Threshold(f32),
}

impl SparseProjection {
    pub fn new_topk(d: usize, n: usize, k: usize, rng: &mut Rng) -> Self {
        SparseProjection {
            proj: DenseProjection::new(d, n, ProjectionMode::Raw, rng),
            rule: SparsifyRule::TopK(k),
        }
    }

    pub fn new_threshold(d: usize, n: usize, t: f32, rng: &mut Rng) -> Self {
        SparseProjection {
            proj: DenseProjection::new(d, n, ProjectionMode::Raw, rng),
            rule: SparsifyRule::Threshold(t),
        }
    }

    /// Calibrate t so that the expected activation count on the sample is
    /// ~k ("selecting a threshold t such that Pr(|Phi_i . x| >= t) = k/d").
    pub fn calibrate_threshold(
        d: usize,
        n: usize,
        k: usize,
        sample: &[Vec<f32>],
        rng: &mut Rng,
    ) -> Self {
        let proj = DenseProjection::new(d, n, ProjectionMode::Raw, rng);
        let mut mags: Vec<f32> = Vec::with_capacity(sample.len() * d);
        let mut z = vec![0.0f32; d];
        for x in sample {
            proj.project_into(x, &mut z);
            mags.extend(z.iter().map(|v| v.abs()));
        }
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let frac = (k as f64 / d as f64).clamp(0.0, 1.0);
        let idx = ((mags.len() as f64 * frac) as usize).min(mags.len().saturating_sub(1));
        let t = if mags.is_empty() { 0.0 } else { mags[idx] };
        SparseProjection { proj, rule: SparsifyRule::Threshold(t) }
    }

    pub fn encode_record(&self, x: &[f32]) -> Encoding {
        let mut z = vec![0.0f32; self.proj.d];
        self.proj.project_into(x, &mut z);
        self.sparsify(&z)
    }

    /// Scratch-path [`SparseProjection::encode_record`]: projection
    /// staging, top-k selection and the output index buffer all come from
    /// the pool. Bit-identical.
    pub fn encode_record_with(&self, x: &[f32], scratch: &mut EncodeScratch) -> Encoding {
        let mut z = scratch.take_flat(self.proj.d);
        self.proj.project_into(x, &mut z);
        let code = self.sparsify_with(&z, scratch);
        scratch.put_flat(z);
        code
    }
}

impl SparseProjection {
    fn sparsify(&self, z: &[f32]) -> Encoding {
        match self.rule {
            SparsifyRule::TopK(k) => {
                let k = k.min(z.len());
                let mut idx: Vec<u32> = (0..z.len() as u32).collect();
                idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
                    z[b as usize].partial_cmp(&z[a as usize]).unwrap()
                });
                idx.truncate(k);
                sparse_from_indices(idx, self.proj.d)
            }
            SparsifyRule::Threshold(t) => {
                let idx: Vec<u32> = z
                    .iter()
                    .enumerate()
                    .filter(|(_, v)| v.abs() >= t)
                    .map(|(i, _)| i as u32)
                    .collect();
                sparse_from_indices(idx, self.proj.d)
            }
        }
    }

    /// Pool-backed [`SparseProjection::sparsify`] — identical output.
    fn sparsify_with(&self, z: &[f32], scratch: &mut EncodeScratch) -> Encoding {
        match self.rule {
            SparsifyRule::TopK(k) => {
                let k = k.min(z.len());
                // Permutation working buffer from the pool; the selected
                // prefix dedups (a no-op on distinct indices) and sorts
                // through the scratch bitset.
                let mut idx = scratch.take_index(z.len());
                idx.extend(0..z.len() as u32);
                idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
                    z[b as usize].partial_cmp(&z[a as usize]).unwrap()
                });
                let code = scratch.sparse_from_staged(&idx[..k], self.proj.d);
                idx.clear();
                scratch.recycle(Encoding::SparseBinary { indices: idx, d: self.proj.d });
                code
            }
            SparsifyRule::Threshold(t) => {
                // Walking z in order yields sorted, unique indices
                // directly — no dedup pass needed.
                let mut idx = scratch.take_index(64);
                idx.extend(
                    z.iter()
                        .enumerate()
                        .filter(|(_, v)| v.abs() >= t)
                        .map(|(i, _)| i as u32),
                );
                Encoding::SparseBinary { indices: idx, d: self.proj.d }
            }
        }
    }
}

impl NumericEncoder for SparseProjection {
    fn encode(&self, x: &[f32]) -> Encoding {
        self.encode_record(x)
    }

    fn dim(&self) -> usize {
        self.proj.d
    }

    fn name(&self) -> &'static str {
        match self.rule {
            SparsifyRule::TopK(_) => "sparse-rp-topk",
            SparsifyRule::Threshold(_) => "sparse-rp-threshold",
        }
    }

    fn encode_batch(&self, xs: &[&[f32]]) -> Vec<Encoding> {
        let bsz = xs.len();
        let mut zs = vec![0.0f32; bsz * self.proj.d];
        self.proj.project_batch_into(xs, &mut zs);
        zs.chunks_exact(self.proj.d).map(|z| self.sparsify(z)).collect()
    }

    fn encode_with(&self, x: &[f32], scratch: &mut EncodeScratch) -> Encoding {
        self.encode_record_with(x, scratch)
    }

    fn encode_batch_with(
        &self,
        xs: &[&[f32]],
        scratch: &mut EncodeScratch,
        out: &mut Vec<Encoding>,
    ) {
        let mut zs = scratch.take_flat(xs.len() * self.proj.d);
        self.proj.project_batch_into(xs, &mut zs);
        out.clear();
        for z in zs.chunks_exact(self.proj.d) {
            out.push(self.sparsify_with(z, scratch));
        }
        scratch.put_flat(zs);
    }

    fn encode_batch_flat_with(
        &self,
        xs_flat: &[f32],
        n: usize,
        scratch: &mut EncodeScratch,
        out: &mut Vec<Encoding>,
    ) {
        assert!(n > 0, "encode_batch_flat_with needs a positive row width");
        assert_eq!(n, self.proj.n, "row width must match the projection input dim");
        assert_eq!(xs_flat.len() % n, 0, "flat batch not a multiple of n={n}");
        let bsz = xs_flat.len() / n;
        let mut zs = scratch.take_flat(bsz * self.proj.d);
        self.proj.project_batch_flat_into(xs_flat, &mut zs);
        out.clear();
        for z in zs.chunks_exact(self.proj.d) {
            out.push(self.sparsify_with(z, scratch));
        }
        scratch.put_flat(zs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(x: &[f32]) -> Vec<f32> {
        let n: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        x.iter().map(|v| v / n).collect()
    }

    #[test]
    fn rows_are_unit_norm() {
        let mut rng = Rng::new(1);
        let p = DenseProjection::new(50, 13, ProjectionMode::Sign, &mut rng);
        for i in 0..50 {
            let row = &p.phi[i * 13..(i + 1) * 13];
            let norm: f64 = row.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn sign_codes_are_pm_one() {
        let mut rng = Rng::new(2);
        let p = DenseProjection::new(64, 5, ProjectionMode::Sign, &mut rng);
        let e = p.encode(&[0.3, -1.0, 0.5, 2.0, 0.0]);
        if let Encoding::Dense(v) = e {
            assert!(v.iter().all(|&x| x == 1.0 || x == -1.0));
        } else {
            panic!();
        }
    }

    #[test]
    fn angle_estimation_eq4() {
        // (1/d) phi(x).phi(x') ~ 1 - 2 angle(x,x') / pi for unit vectors.
        let mut rng = Rng::new(3);
        let d = 20_000;
        let p = DenseProjection::new(d, 4, ProjectionMode::Sign, &mut rng);
        let x = unit(&[1.0, 0.0, 0.0, 0.0]);
        let y = unit(&[1.0, 1.0, 0.0, 0.0]); // 45 degrees
        let ex = p.encode(&x);
        let ey = p.encode(&y);
        let sim = ex.dot(&ey) / d as f64;
        let want = 1.0 - 2.0 * (std::f64::consts::PI / 4.0) / std::f64::consts::PI;
        assert!((sim - want).abs() < 0.03, "sim={sim} want={want}");
    }

    #[test]
    fn topk_sets_exactly_k() {
        let mut rng = Rng::new(4);
        let p = SparseProjection::new_topk(500, 13, 50, &mut rng);
        let x: Vec<f32> = (0..13).map(|i| (i as f32).sin()).collect();
        let e = p.encode(&x);
        assert_eq!(e.nnz(), 50);
    }

    #[test]
    fn topk_picks_largest() {
        let mut rng = Rng::new(5);
        let p = SparseProjection::new_topk(100, 8, 10, &mut rng);
        let x: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7).cos()).collect();
        let mut z = vec![0.0f32; 100];
        p.proj.project_into(&x, &mut z);
        let e = p.encode(&x);
        if let Encoding::SparseBinary { indices, .. } = &e {
            let min_sel = indices.iter().map(|&i| z[i as usize]).fold(f32::MAX, f32::min);
            let max_unsel = (0..100u32)
                .filter(|i| !indices.contains(i))
                .map(|i| z[i as usize])
                .fold(f32::MIN, f32::max);
            assert!(min_sel >= max_unsel, "min_sel={min_sel} max_unsel={max_unsel}");
        } else {
            panic!();
        }
    }

    #[test]
    fn threshold_calibration_hits_target_sparsity() {
        let mut rng = Rng::new(6);
        let sample: Vec<Vec<f32>> = (0..50)
            .map(|_| (0..13).map(|_| rng.normal_f32()).collect())
            .collect();
        let p = SparseProjection::calibrate_threshold(1000, 13, 100, &sample, &mut rng);
        let mut nnzs = 0usize;
        for x in &sample {
            nnzs += p.encode(x).nnz();
        }
        let avg = nnzs as f64 / sample.len() as f64;
        assert!((avg - 100.0).abs() < 40.0, "avg nnz = {avg}");
    }

    #[test]
    fn locality_similar_inputs_share_active_set() {
        let mut rng = Rng::new(7);
        let p = SparseProjection::new_topk(2000, 6, 100, &mut rng);
        let x = unit(&[1.0, 0.2, -0.4, 0.8, 0.1, -0.9]);
        let mut y = x.clone();
        y[0] += 0.01; // tiny perturbation
        let far = unit(&[-1.0, 0.5, 0.4, -0.8, 0.9, 0.2]);
        let ex = p.encode(&x);
        let ey = p.encode(&unit(&y));
        let ef = p.encode(&far);
        assert!(ex.dot(&ey) > 90.0, "near overlap {}", ex.dot(&ey));
        assert!(ex.dot(&ef) < 40.0, "far overlap {}", ex.dot(&ef));
    }

    #[test]
    fn raw_projection_is_linear() {
        let mut rng = Rng::new(8);
        let p = DenseProjection::new(64, 4, ProjectionMode::Raw, &mut rng);
        let a = [1.0f32, 2.0, -1.0, 0.5];
        let b = [0.3f32, -0.2, 0.9, 1.5];
        let ab: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        let ea = p.encode(&a).to_dense();
        let eb = p.encode(&b).to_dense();
        let eab = p.encode(&ab).to_dense();
        for i in 0..64 {
            assert!((eab[i] - ea[i] - eb[i]).abs() < 1e-4);
        }
    }
}
