//! Encoding layer: every encoder the paper defines or compares against.
//!
//! Categorical (Sec. 4): [`bloom`] (sparse hashing — the contribution),
//! [`dense_hash`] (Sec. 4.2.1 baseline), [`codebook`] (Sec. 4.1
//! conventional HDC baseline), [`permutation`] (Remark 3 / Sec. 7.4.1
//! hardware baseline).
//!
//! Numeric (Sec. 5): [`projection`] (dense signed RP + sparse top-k /
//! thresholded RP), [`sjlt`] (structured Eq. 5 + the relaxed ±1/0 form).
//!
//! [`bundle`] implements Sec. 5.4's three combination rules and
//! [`vector`] the shared dense/sparse HD vector type.
//!
//! # The scratch hot path
//!
//! Every encoder has two encode paths:
//!
//! * the **allocating path** (`encode`) — allocates its working and
//!   output buffers per record; simple, and the reference semantics;
//! * the **scratch path** (`encode_with` / `encode_batch_with`) — all
//!   working state comes from an [`EncodeScratch`] (pooled dense and
//!   index buffers, a bitset dedup table replacing sort+dedup, a flat
//!   batch buffer), so a caller that recycles consumed encodings via
//!   [`EncodeScratch::recycle`] encodes with **zero steady-state
//!   allocations**.
//!
//! The two paths are bit-identical by contract: `encode_with(x, s) ==
//! encode(x)` for every encoder, every input and any scratch state
//! (enforced by `tests/scratch_equivalence.rs`). Batch variants reuse
//! the caller's output `Vec` and are the coordinator workers' hot path.
//!
//! # The kernel layer
//!
//! Both paths' hot inner loops (SJLT scatter, Bloom bitset dedup,
//! dense-hash bit unpack, projection AXPY/quantize) live in [`kernels`],
//! which selects an explicit portable-SIMD backend under `--features
//! simd` (nightly) and an autovectorization-friendly scalar backend
//! otherwise. The backends are bit-identical — enforced by
//! `tests/kernel_equivalence.rs` — so every equivalence above holds
//! regardless of the feature.

pub mod bloom;
pub mod bundle;
pub mod codebook;
pub mod dense_hash;
pub mod kernels;
pub mod permutation;
pub mod projection;
pub mod scratch;
pub mod sjlt;
pub mod vector;

pub use bloom::BloomEncoder;
pub use bundle::{bundle, bundle_with, BundleMethod};
pub use codebook::{CodebookEncoder, CodebookOom};
pub use dense_hash::{DenseHashEncoder, DenseHashMode};
pub use permutation::PermutationEncoder;
pub use projection::{DenseProjection, ProjectionMode, SparseProjection, SparsifyRule};
pub use scratch::EncodeScratch;
pub use sjlt::{RelaxedSjlt, Sjlt};
pub use vector::{sparse_from_indices, Encoding};

/// A categorical-feature encoder: symbols (interned u64 ids) -> HD vector.
/// `&mut self` because the codebook baseline populates lazily.
pub trait CategoricalEncoder: Send {
    fn encode(&mut self, symbols: &[u64]) -> Encoding;

    /// Scratch-path encode: bit-identical to [`CategoricalEncoder::encode`],
    /// but working buffers (and, when the caller recycles outputs, the
    /// output buffer too) come from `scratch`. The default falls back to
    /// the allocating path; every in-tree encoder overrides it.
    fn encode_with(&mut self, symbols: &[u64], scratch: &mut EncodeScratch) -> Encoding {
        let _ = scratch;
        self.encode(symbols)
    }

    fn dim(&self) -> usize;
    /// Persistent encoder state in bytes — the paper's scalability axis.
    fn memory_bytes(&self) -> usize;
    fn name(&self) -> &'static str;
}

/// A numeric-feature encoder: x in R^n -> HD vector.
pub trait NumericEncoder: Send + Sync {
    fn encode(&self, x: &[f32]) -> Encoding;

    /// Scratch-path encode: bit-identical to [`NumericEncoder::encode`]
    /// with pooled buffers. Default falls back to the allocating path.
    fn encode_with(&self, x: &[f32], scratch: &mut EncodeScratch) -> Encoding {
        let _ = scratch;
        self.encode(x)
    }

    fn dim(&self) -> usize;
    fn name(&self) -> &'static str;

    /// Encode a batch (allocating). The default delegates per record;
    /// projection-style encoders override it with a row-blocked loop that
    /// loads each projection row once per *batch* instead of once per
    /// *record* — the encode hot path is memory-bound on the projection
    /// matrix, so this is the difference between flat and linear worker
    /// scaling (EXPERIMENTS.md §Perf).
    fn encode_batch(&self, xs: &[&[f32]]) -> Vec<Encoding> {
        xs.iter().map(|x| self.encode(x)).collect()
    }

    /// Scratch-path batch encode into a caller-reused `out` vector
    /// (cleared first). Bit-identical to [`NumericEncoder::encode_batch`].
    /// Row-blocked encoders override this to stage the whole batch in the
    /// scratch's flat buffer.
    fn encode_batch_with(
        &self,
        xs: &[&[f32]],
        scratch: &mut EncodeScratch,
        out: &mut Vec<Encoding>,
    ) {
        out.clear();
        for x in xs {
            out.push(self.encode_with(x, scratch));
        }
    }

    /// Scratch-path batch encode over a row-major flat input
    /// (`xs_flat.len() = batch · n`, `n > 0`). Bit-identical to
    /// [`NumericEncoder::encode_batch_with`] over the same rows; exists
    /// so callers can stage records into one reused flat buffer instead
    /// of building a per-batch `Vec<&[f32]>` — the last per-batch
    /// allocation on the coordinator's encode hot path. Row-blocked
    /// encoders override it with the same blocked loop as the slice
    /// variant (shared core, so the two stay bit-identical by
    /// construction).
    fn encode_batch_flat_with(
        &self,
        xs_flat: &[f32],
        n: usize,
        scratch: &mut EncodeScratch,
        out: &mut Vec<Encoding>,
    ) {
        assert!(n > 0, "encode_batch_flat_with needs a positive row width");
        assert_eq!(xs_flat.len() % n, 0, "flat batch not a multiple of n={n}");
        out.clear();
        for x in xs_flat.chunks_exact(n) {
            out.push(self.encode_with(x, scratch));
        }
    }
}
