//! Encoding layer: every encoder the paper defines or compares against.
//!
//! Categorical (Sec. 4): [`bloom`] (sparse hashing — the contribution),
//! [`dense_hash`] (Sec. 4.2.1 baseline), [`codebook`] (Sec. 4.1
//! conventional HDC baseline), [`permutation`] (Remark 3 / Sec. 7.4.1
//! hardware baseline).
//!
//! Numeric (Sec. 5): [`projection`] (dense signed RP + sparse top-k /
//! thresholded RP), [`sjlt`] (structured Eq. 5 + the relaxed ±1/0 form).
//!
//! [`bundle`] implements Sec. 5.4's three combination rules and
//! [`vector`] the shared dense/sparse HD vector type.

pub mod bloom;
pub mod bundle;
pub mod codebook;
pub mod dense_hash;
pub mod permutation;
pub mod projection;
pub mod sjlt;
pub mod vector;

pub use bloom::BloomEncoder;
pub use bundle::{bundle, BundleMethod};
pub use codebook::{CodebookEncoder, CodebookOom};
pub use dense_hash::{DenseHashEncoder, DenseHashMode};
pub use permutation::PermutationEncoder;
pub use projection::{DenseProjection, ProjectionMode, SparseProjection, SparsifyRule};
pub use sjlt::{RelaxedSjlt, Sjlt};
pub use vector::{sparse_from_indices, Encoding};

/// A categorical-feature encoder: symbols (interned u64 ids) -> HD vector.
/// `&mut self` because the codebook baseline populates lazily.
pub trait CategoricalEncoder: Send {
    fn encode(&mut self, symbols: &[u64]) -> Encoding;
    fn dim(&self) -> usize;
    /// Persistent encoder state in bytes — the paper's scalability axis.
    fn memory_bytes(&self) -> usize;
    fn name(&self) -> &'static str;
}

/// A numeric-feature encoder: x in R^n -> HD vector.
pub trait NumericEncoder: Send + Sync {
    fn encode(&self, x: &[f32]) -> Encoding;
    fn dim(&self) -> usize;
    fn name(&self) -> &'static str;

    /// Encode a batch. The default delegates per record; projection-style
    /// encoders override it with a row-blocked loop that loads each
    /// projection row once per *batch* instead of once per *record* —
    /// the encode hot path is memory-bound on the projection matrix, so
    /// this is the difference between flat and linear worker scaling
    /// (EXPERIMENTS.md §Perf).
    fn encode_batch(&self, xs: &[&[f32]]) -> Vec<Encoding> {
        xs.iter().map(|x| self.encode(x)).collect()
    }
}
