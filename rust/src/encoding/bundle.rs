//! Bundling: combining numeric and categorical embeddings (paper
//! Sec. 5.4, compared empirically in Fig. 10 / Table 2).
//!
//! * `Concat`         — final dim = d_num + d_cat; mixes precisions freely.
//! * `Sum`            — element-wise sum; dims must match; result may need
//!                      higher precision.
//! * `ThresholdedSum` — sum clamped at 1 ("OR"); for sparse binary inputs
//!                      this is the element-wise max / logical or, keeping
//!                      the result binary.

use crate::encoding::scratch::EncodeScratch;
use crate::encoding::vector::{sparse_from_indices, Encoding};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BundleMethod {
    Concat,
    Sum,
    ThresholdedSum,
}

impl BundleMethod {
    pub fn name(&self) -> &'static str {
        match self {
            BundleMethod::Concat => "concat",
            BundleMethod::Sum => "sum",
            BundleMethod::ThresholdedSum => "or",
        }
    }

    /// Output dimension for inputs of dims (dn, dc).
    pub fn out_dim(&self, dn: usize, dc: usize) -> usize {
        match self {
            BundleMethod::Concat => dn + dc,
            _ => {
                assert_eq!(dn, dc, "sum/or bundling needs equal dims");
                dn
            }
        }
    }
}

/// Bundle two encodings. Sparse results stay sparse where the math allows
/// (OR of two sparse-binary codes); everything else goes dense.
pub fn bundle(a: &Encoding, b: &Encoding, method: BundleMethod) -> Encoding {
    match method {
        BundleMethod::Concat => concat(a, b),
        BundleMethod::Sum => sum(a, b),
        BundleMethod::ThresholdedSum => or(a, b),
    }
}

/// Scratch-path [`bundle`]: the output buffer comes from the pool.
/// Bit-identical results (enforced by tests below and the equivalence
/// suite); the inputs themselves are typically recycled by the caller
/// right after bundling.
pub fn bundle_with(
    a: &Encoding,
    b: &Encoding,
    method: BundleMethod,
    scratch: &mut EncodeScratch,
) -> Encoding {
    match method {
        BundleMethod::Concat => match (a, b) {
            (
                Encoding::SparseBinary { indices: ia, d: da },
                Encoding::SparseBinary { indices: ib, d: db },
            ) => {
                let mut idx = scratch.take_index(ia.len() + ib.len());
                idx.extend_from_slice(ia);
                idx.extend(ib.iter().map(|&i| i + *da as u32));
                Encoding::SparseBinary { indices: idx, d: da + db }
            }
            _ => {
                let (da, db) = (a.dim(), b.dim());
                let mut out = scratch.take_dense_zeroed(da + db);
                a.scatter_into(&mut out[..da]);
                b.scatter_into(&mut out[da..]);
                Encoding::Dense(out)
            }
        },
        BundleMethod::Sum => {
            assert_eq!(a.dim(), b.dim(), "sum bundling needs equal dims");
            Encoding::Dense(sum_into_pooled(a, b, scratch))
        }
        BundleMethod::ThresholdedSum => {
            assert_eq!(a.dim(), b.dim(), "or bundling needs equal dims");
            match (a, b) {
                (
                    Encoding::SparseBinary { indices: ia, d },
                    Encoding::SparseBinary { indices: ib, .. },
                ) => {
                    let mut staged = scratch.take_stage();
                    staged.extend_from_slice(ia);
                    staged.extend_from_slice(ib);
                    let code = scratch.sparse_from_staged(&staged, *d);
                    scratch.put_stage(staged);
                    code
                }
                _ => {
                    // min(sum, 1): dense fallback, matching `or` exactly.
                    let mut out = sum_into_pooled(a, b, scratch);
                    for x in out.iter_mut() {
                        *x = if *x >= 1.0 { 1.0 } else { x.max(0.0).min(1.0) };
                    }
                    Encoding::Dense(out)
                }
            }
        }
    }
}

/// Element-wise sum into a pooled buffer; same arithmetic as [`sum`].
fn sum_into_pooled(a: &Encoding, b: &Encoding, scratch: &mut EncodeScratch) -> Vec<f32> {
    let d = a.dim();
    match (a, b) {
        (Encoding::Dense(va), Encoding::Dense(vb)) => {
            let mut out = scratch.take_dense_raw(d);
            for ((o, x), y) in out.iter_mut().zip(va).zip(vb) {
                *o = x + y;
            }
            out
        }
        (Encoding::Dense(v), Encoding::SparseBinary { indices, .. })
        | (Encoding::SparseBinary { indices, .. }, Encoding::Dense(v)) => {
            let mut out = scratch.take_dense_raw(d);
            out.copy_from_slice(v);
            for &i in indices {
                out[i as usize] += 1.0;
            }
            out
        }
        (Encoding::SparseBinary { indices: ia, .. }, Encoding::SparseBinary { indices: ib, .. }) => {
            let mut out = scratch.take_dense_zeroed(d);
            for &i in ia {
                out[i as usize] = 1.0;
            }
            for &i in ib {
                out[i as usize] += 1.0;
            }
            out
        }
    }
}

fn concat(a: &Encoding, b: &Encoding) -> Encoding {
    match (a, b) {
        (
            Encoding::SparseBinary { indices: ia, d: da },
            Encoding::SparseBinary { indices: ib, d: db },
        ) => {
            let mut idx = Vec::with_capacity(ia.len() + ib.len());
            idx.extend_from_slice(ia);
            idx.extend(ib.iter().map(|&i| i + *da as u32));
            // Already sorted: ia sorted, shifted ib sorted and disjoint.
            Encoding::SparseBinary { indices: idx, d: da + db }
        }
        _ => {
            let mut out = a.to_dense();
            out.extend(b.to_dense());
            Encoding::Dense(out)
        }
    }
}

fn sum(a: &Encoding, b: &Encoding) -> Encoding {
    assert_eq!(a.dim(), b.dim(), "sum bundling needs equal dims");
    match (a, b) {
        (Encoding::Dense(va), Encoding::Dense(vb)) => {
            Encoding::Dense(va.iter().zip(vb).map(|(x, y)| x + y).collect())
        }
        (Encoding::Dense(v), Encoding::SparseBinary { indices, .. })
        | (Encoding::SparseBinary { indices, .. }, Encoding::Dense(v)) => {
            let mut out = v.clone();
            for &i in indices {
                out[i as usize] += 1.0;
            }
            Encoding::Dense(out)
        }
        (Encoding::SparseBinary { .. }, Encoding::SparseBinary { .. }) => {
            let mut out = a.to_dense();
            if let Encoding::SparseBinary { indices, .. } = b {
                for &i in indices {
                    out[i as usize] += 1.0;
                }
            }
            Encoding::Dense(out)
        }
    }
}

fn or(a: &Encoding, b: &Encoding) -> Encoding {
    assert_eq!(a.dim(), b.dim(), "or bundling needs equal dims");
    match (a, b) {
        (
            Encoding::SparseBinary { indices: ia, d },
            Encoding::SparseBinary { indices: ib, .. },
        ) => {
            // Union of sorted index lists.
            let mut idx = Vec::with_capacity(ia.len() + ib.len());
            idx.extend_from_slice(ia);
            idx.extend_from_slice(ib);
            sparse_from_indices(idx, *d)
        }
        _ => {
            // min(sum, 1): dense fallback.
            let s = sum(a, b);
            match s {
                Encoding::Dense(v) => {
                    Encoding::Dense(v.iter().map(|&x| if x >= 1.0 { 1.0 } else { x.max(0.0).min(1.0) }).collect())
                }
                other => other,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(idx: &[u32], d: usize) -> Encoding {
        sparse_from_indices(idx.to_vec(), d)
    }

    #[test]
    fn concat_dims_add() {
        let a = Encoding::Dense(vec![1.0, 2.0]);
        let b = Encoding::Dense(vec![3.0]);
        let c = bundle(&a, &b, BundleMethod::Concat);
        assert_eq!(c.dim(), 3);
        assert_eq!(c.to_dense(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn concat_sparse_stays_sparse_and_sorted() {
        let a = sp(&[1, 5], 8);
        let b = sp(&[0, 7], 8);
        let c = bundle(&a, &b, BundleMethod::Concat);
        match &c {
            Encoding::SparseBinary { indices, d } => {
                assert_eq!(*d, 16);
                assert_eq!(indices, &vec![1, 5, 8, 15]);
            }
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn sum_matches_dense_math() {
        let a = sp(&[0, 2], 4);
        let b = Encoding::Dense(vec![0.5, 0.5, 0.5, 0.5]);
        let c = bundle(&a, &b, BundleMethod::Sum);
        assert_eq!(c.to_dense(), vec![1.5, 0.5, 1.5, 0.5]);
    }

    #[test]
    fn or_of_sparse_is_union() {
        let a = sp(&[1, 3], 6);
        let b = sp(&[3, 5], 6);
        let c = bundle(&a, &b, BundleMethod::ThresholdedSum);
        match &c {
            Encoding::SparseBinary { indices, .. } => assert_eq!(indices, &vec![1, 3, 5]),
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn or_clamps_dense_sum_at_one() {
        let a = Encoding::Dense(vec![1.0, 0.0, 1.0]);
        let b = sp(&[0, 1], 3);
        let c = bundle(&a, &b, BundleMethod::ThresholdedSum);
        assert_eq!(c.to_dense(), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn out_dim_accounting() {
        assert_eq!(BundleMethod::Concat.out_dim(10, 20), 30);
        assert_eq!(BundleMethod::Sum.out_dim(10, 10), 10);
        assert_eq!(BundleMethod::ThresholdedSum.out_dim(5, 5), 5);
    }

    #[test]
    #[should_panic]
    fn sum_dim_mismatch_panics() {
        let a = Encoding::Dense(vec![1.0]);
        let b = Encoding::Dense(vec![1.0, 2.0]);
        bundle(&a, &b, BundleMethod::Sum);
    }

    #[test]
    fn or_sparse_dot_sees_union_similarity() {
        // Sec. 5.4: with highly sparse inputs, OR ~ sum. Check dot against
        // a dense theta agrees between or-bundled and sum-bundled codes
        // when supports are disjoint.
        let a = sp(&[0, 2], 6);
        let b = sp(&[1, 4], 6);
        let theta: Vec<f32> = vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6];
        let or_code = bundle(&a, &b, BundleMethod::ThresholdedSum);
        let sum_code = bundle(&a, &b, BundleMethod::Sum);
        assert_eq!(or_code.dot_params(&theta), sum_code.dot_params(&theta));
    }
}
