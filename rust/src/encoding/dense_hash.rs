//! Dense hash-based categorical encoder (paper Sec. 4.2.1).
//!
//! "A trivial approach": d independent ±1 hash functions define
//! `phi(a)_i = psi_i(a)`, equivalent in distribution to sampling
//! `phi(a) ~ Unif({±1}^d)` — but computed on the fly, with no codebook.
//! The cost is d hash evaluations per symbol, which is exactly why the
//! paper calls it computationally burdensome (Fig. 7 excludes it as
//! "dramatically slower"). Feature vectors bundle by element-wise sum.
//!
//! Two faithfulness modes:
//! * [`DenseHashMode::Literal`] — one seeded Murmur3 evaluation per
//!   coordinate, the paper's construction verbatim.
//! * [`DenseHashMode::Packed`] — one evaluation per 32 coordinates,
//!   using each output bit as a sign. Statistically identical codes
//!   (each bit of Murmur3 is unbiased), ~32x faster; used where the
//!   experiment only needs the *codes*, not the baseline's slowness.
//!   The bit → ±1 unpack is [`kernels::unpack_sign_bits_accumulate`]
//!   (SIMD under `--features simd`, bit-identical either way); the
//!   Literal mode stays a plain hash loop — its cost is the d Murmur3
//!   evaluations, which is the point of the baseline.

use crate::encoding::kernels;
use crate::encoding::scratch::EncodeScratch;
use crate::encoding::vector::Encoding;
use crate::encoding::CategoricalEncoder;
use crate::hash::murmur3_u64;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DenseHashMode {
    Literal,
    Packed,
}

#[derive(Clone, Debug)]
pub struct DenseHashEncoder {
    /// Literal: one seed per coordinate (len d).
    /// Packed: one seed per 32-coordinate word (len ceil(d/32)).
    seeds: Vec<u32>,
    d: usize,
    mode: DenseHashMode,
}

impl DenseHashEncoder {
    pub fn new(d: usize, mode: DenseHashMode, rng: &mut Rng) -> Self {
        let n_seeds = match mode {
            DenseHashMode::Literal => d,
            DenseHashMode::Packed => d.div_ceil(32),
        };
        DenseHashEncoder {
            seeds: (0..n_seeds).map(|_| rng.next_u32()).collect(),
            d,
            mode,
        }
    }

    /// phi(a)_i in {+1,-1}, accumulated into `acc` (bundling by sum).
    pub fn accumulate_symbol(&self, symbol: u64, acc: &mut [f32]) {
        debug_assert_eq!(acc.len(), self.d);
        match self.mode {
            DenseHashMode::Literal => {
                for (i, &seed) in self.seeds.iter().enumerate() {
                    let bit = murmur3_u64(symbol, seed) & 1;
                    acc[i] += if bit == 0 { 1.0 } else { -1.0 };
                }
            }
            DenseHashMode::Packed => {
                for (w, &seed) in self.seeds.iter().enumerate() {
                    let word = murmur3_u64(symbol, seed);
                    let base = w * 32;
                    let n = (self.d - base).min(32);
                    kernels::unpack_sign_bits_accumulate(word, &mut acc[base..base + n]);
                }
            }
        }
    }

    /// Encode one symbol as its ±1 codeword.
    pub fn encode_symbol(&self, symbol: u64) -> Encoding {
        let mut acc = vec![0.0f32; self.d];
        self.accumulate_symbol(symbol, &mut acc);
        Encoding::Dense(acc)
    }

    /// Encode a feature vector: sum of the symbols' codewords (Eq. 1 with
    /// hashing in place of sampling).
    pub fn encode_set(&self, symbols: &[u64]) -> Encoding {
        let mut acc = vec![0.0f32; self.d];
        for &a in symbols {
            self.accumulate_symbol(a, &mut acc);
        }
        Encoding::Dense(acc)
    }

    /// Scratch-path [`DenseHashEncoder::encode_set`]: the accumulator is a
    /// pooled zeroed buffer. Bit-identical to `encode_set`.
    pub fn encode_set_with(&self, symbols: &[u64], scratch: &mut EncodeScratch) -> Encoding {
        let mut acc = scratch.take_dense_zeroed(self.d);
        for &a in symbols {
            self.accumulate_symbol(a, &mut acc);
        }
        Encoding::Dense(acc)
    }
}

impl CategoricalEncoder for DenseHashEncoder {
    fn encode(&mut self, symbols: &[u64]) -> Encoding {
        self.encode_set(symbols)
    }

    fn encode_with(&mut self, symbols: &[u64], scratch: &mut EncodeScratch) -> Encoding {
        self.encode_set_with(symbols, scratch)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn memory_bytes(&self) -> usize {
        self.seeds.len() * 4
    }

    fn name(&self) -> &'static str {
        match self.mode {
            DenseHashMode::Literal => "dense-hash",
            DenseHashMode::Packed => "dense-hash-packed",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_pm_one() {
        let mut rng = Rng::new(1);
        for mode in [DenseHashMode::Literal, DenseHashMode::Packed] {
            let e = DenseHashEncoder::new(100, mode, &mut rng);
            if let Encoding::Dense(v) = e.encode_symbol(42) {
                assert!(v.iter().all(|&x| x == 1.0 || x == -1.0), "{mode:?}");
            } else {
                panic!();
            }
        }
    }

    #[test]
    fn deterministic_and_symbol_dependent() {
        let mut rng = Rng::new(2);
        let e = DenseHashEncoder::new(64, DenseHashMode::Literal, &mut rng);
        assert_eq!(e.encode_symbol(7), e.encode_symbol(7));
        assert_ne!(e.encode_symbol(7), e.encode_symbol(8));
    }

    #[test]
    fn bundling_is_sum() {
        let mut rng = Rng::new(3);
        let e = DenseHashEncoder::new(32, DenseHashMode::Packed, &mut rng);
        let a = e.encode_symbol(1).to_dense();
        let b = e.encode_symbol(2).to_dense();
        let ab = e.encode_set(&[1, 2]).to_dense();
        for i in 0..32 {
            assert_eq!(ab[i], a[i] + b[i]);
        }
    }

    #[test]
    fn codes_look_balanced() {
        let mut rng = Rng::new(4);
        let e = DenseHashEncoder::new(4096, DenseHashMode::Packed, &mut rng);
        let v = e.encode_symbol(99).to_dense();
        let pos = v.iter().filter(|&&x| x > 0.0).count();
        assert!((pos as f64 - 2048.0).abs() < 200.0, "pos={pos}");
    }

    #[test]
    fn distinct_symbols_near_orthogonal() {
        // E[phi(a).phi(b)] = 0 with std sqrt(d): check |dot| << d.
        let mut rng = Rng::new(5);
        let e = DenseHashEncoder::new(4096, DenseHashMode::Packed, &mut rng);
        let a = e.encode_symbol(1);
        let b = e.encode_symbol(2);
        assert!(a.dot(&b).abs() < 6.0 * (4096f64).sqrt());
        assert_eq!(a.dot(&a), 4096.0);
    }

    #[test]
    fn modes_agree_statistically() {
        // Same *distribution*, not same values: check dot concentration.
        let mut rng = Rng::new(6);
        let lit = DenseHashEncoder::new(2048, DenseHashMode::Literal, &mut rng);
        let pak = DenseHashEncoder::new(2048, DenseHashMode::Packed, &mut rng);
        let set: Vec<u64> = (0..10).collect();
        let dl = lit.encode_set(&set);
        let dp = pak.encode_set(&set);
        // ||phi||^2 = s*d + cross terms ~ s*d ± O(s*sqrt(d))
        let want = 10.0 * 2048.0;
        assert!((dl.dot(&dl) - want).abs() < want * 0.25);
        assert!((dp.dot(&dp) - want).abs() < want * 0.25);
    }

    #[test]
    fn packed_handles_non_multiple_of_32() {
        let mut rng = Rng::new(7);
        let e = DenseHashEncoder::new(37, DenseHashMode::Packed, &mut rng);
        let v = e.encode_symbol(5).to_dense();
        assert_eq!(v.len(), 37);
        assert!(v.iter().all(|&x| x == 1.0 || x == -1.0));
    }
}
