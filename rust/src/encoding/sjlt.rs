//! Sparse Johnson-Lindenstrauss transform numeric encoder (paper Eq. 5,
//! plus the relaxed i.i.d. ±1/0 matrix of Sec. 7.2.3).
//!
//! Two constructions, both hash-defined so nothing scales with n beyond
//! the (k x n) hash tables:
//!
//! * [`Sjlt`] — the structured construction of Eq. 5: k chunks of size
//!   d/k, chunk c scatter-adds `sigma_c(j) x_j` at bucket `eta_c(j)`.
//!   Mirrors the Pallas kernel `kernels/sjlt.py` (cross-validated in the
//!   integration tests).
//! * [`RelaxedSjlt`] — the empirical-section variant: Phi_ij in
//!   {+1 w.p. p/2, 0 w.p. 1-p, -1 w.p. p/2}, stored in CSR form so
//!   encode cost is proportional to nnz(Phi). Optionally sign-quantized
//!   ("SJLT encodings are quantized using the sign function", Fig. 9).
//!
//! Layout (§Perf): both encoders keep their tables in flat row-major
//! arrays — `Vec<Vec<_>>` puts every row behind its own pointer, so the
//! per-record scatter loop chased pointers and missed caches. Signs are
//! stored as `i8` (±1), making the inner scatter an add/subtract with no
//! multiplication — exactly Sec. 4.2.2's multiplication-free cost model.
//! The inner loops themselves live in [`crate::encoding::kernels`]
//! ([`kernels::scatter_signed`] for the structured scatter,
//! [`kernels::signed_sum`] for the relaxed CSR rows), shared by the
//! legacy and scratch paths and SIMD-accelerated under `--features simd`.

use crate::encoding::kernels;
use crate::encoding::scratch::EncodeScratch;
use crate::encoding::vector::Encoding;
use crate::encoding::NumericEncoder;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Sjlt {
    /// Row-major (k, n): bucket of input j in chunk c at `eta[c*n + j]`,
    /// in [0, d/k).
    eta: Vec<u32>,
    /// Row-major (k, n): sign of input j in chunk c, stored ±1 as i8.
    sigma: Vec<i8>,
    pub d: usize,
    pub n: usize,
    k: usize,
}

impl Sjlt {
    pub fn new(d: usize, n: usize, k: usize, rng: &mut Rng) -> Self {
        assert!(d % k == 0, "d={d} must be divisible by k={k}");
        let dk = (d / k) as u64;
        // Draw order matches the original nested construction (all eta
        // rows, then all sigma rows) so seeds stay bit-compatible.
        let eta: Vec<u32> = (0..k * n).map(|_| rng.below(dk) as u32).collect();
        let sigma: Vec<i8> = (0..k * n).map(|_| rng.sign() as i8).collect();
        Sjlt { eta, sigma, d, n, k }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Bucket of input `j` in chunk `c` (tests / cross-validation).
    pub fn eta_at(&self, c: usize, j: usize) -> u32 {
        self.eta[c * self.n + j]
    }

    /// Sign of input `j` in chunk `c` as f32 (tests / cross-validation).
    pub fn sigma_at(&self, c: usize, j: usize) -> f32 {
        self.sigma[c * self.n + j] as f32
    }

    /// Scatter-add `x` into a zeroed output buffer of length d — one
    /// fused pass over the flat (k, n) tables; the inner op is add/sub
    /// (sign select), multiplication-free. Per-chunk scatter is
    /// [`kernels::scatter_signed`] (scalar or SIMD per the `simd`
    /// feature; bit-identical either way).
    pub fn encode_into(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(out.len(), self.d);
        let dk = self.d / self.k;
        for c in 0..self.k {
            let row = c * self.n;
            let base = c * dk;
            kernels::scatter_signed(
                x,
                &self.eta[row..row + self.n],
                &self.sigma[row..row + self.n],
                &mut out[base..base + dk],
            );
        }
    }

    pub fn encode_record(&self, x: &[f32]) -> Encoding {
        let mut out = vec![0.0f32; self.d];
        self.encode_into(x, &mut out);
        Encoding::Dense(out)
    }

    /// Scratch-path [`Sjlt::encode_record`]: the dense output comes from
    /// the pool (zeroed). Bit-identical.
    pub fn encode_record_with(&self, x: &[f32], scratch: &mut EncodeScratch) -> Encoding {
        let mut out = scratch.take_dense_zeroed(self.d);
        self.encode_into(x, &mut out);
        Encoding::Dense(out)
    }

    /// Hash tables flattened for the PJRT artifact `encode_sjlt`
    /// (row-major (k, n) i32 / f32).
    pub fn eta_flat(&self) -> Vec<i32> {
        self.eta.iter().map(|&v| v as i32).collect()
    }

    pub fn sigma_flat(&self) -> Vec<f32> {
        self.sigma.iter().map(|&s| s as f32).collect()
    }
}

impl NumericEncoder for Sjlt {
    fn encode(&self, x: &[f32]) -> Encoding {
        self.encode_record(x)
    }

    fn encode_with(&self, x: &[f32], scratch: &mut EncodeScratch) -> Encoding {
        self.encode_record_with(x, scratch)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn name(&self) -> &'static str {
        "sjlt"
    }
}

/// The relaxed construction used in the paper's experiments (Sec. 7.2.3),
/// stored as CSR: `row_ptr[i]..row_ptr[i+1]` spans row i's entries in
/// `cols` / `signs`.
#[derive(Clone, Debug)]
pub struct RelaxedSjlt {
    row_ptr: Vec<u32>,
    cols: Vec<u32>,
    signs: Vec<i8>,
    pub d: usize,
    pub n: usize,
    pub p: f64,
    pub quantize: bool,
}

impl RelaxedSjlt {
    pub fn new(d: usize, n: usize, p: f64, quantize: bool, rng: &mut Rng) -> Self {
        // Same draw order as the original per-row construction.
        let mut row_ptr = Vec::with_capacity(d + 1);
        let mut cols = Vec::new();
        let mut signs = Vec::new();
        row_ptr.push(0u32);
        for _ in 0..d {
            for j in 0..n as u32 {
                if rng.bernoulli(p) {
                    cols.push(j);
                    signs.push(rng.sign() as i8);
                }
            }
            row_ptr.push(cols.len() as u32);
        }
        RelaxedSjlt { row_ptr, cols, signs, d, n, p, quantize }
    }

    /// Fraction of non-zero entries in Phi (should be ~p).
    pub fn density(&self) -> f64 {
        self.cols.len() as f64 / (self.d * self.n) as f64
    }

    /// Row i's (column, sign) entries.
    #[inline]
    fn row(&self, i: usize) -> (&[u32], &[i8]) {
        let lo = self.row_ptr[i] as usize;
        let hi = self.row_ptr[i + 1] as usize;
        (&self.cols[lo..hi], &self.signs[lo..hi])
    }

    #[inline]
    fn finish(&self, acc: f32) -> f32 {
        if self.quantize {
            if acc >= 0.0 {
                1.0
            } else {
                -1.0
            }
        } else {
            acc
        }
    }

    /// Compute every output coordinate into a caller buffer of length d.
    /// Row accumulation is [`kernels::signed_sum`] — a sequential
    /// reduction in both backends (reassociating it would break
    /// bit-identity; see the kernels module docs).
    pub fn encode_into(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(out.len(), self.d);
        for i in 0..self.d {
            let (cols, signs) = self.row(i);
            out[i] = self.finish(kernels::signed_sum(x, cols, signs));
        }
    }

    pub fn encode_record(&self, x: &[f32]) -> Encoding {
        let mut out = vec![0.0f32; self.d];
        self.encode_into(x, &mut out);
        Encoding::Dense(out)
    }

    /// Scratch-path [`RelaxedSjlt::encode_record`] — every element is
    /// overwritten, so the pooled buffer needs no zeroing.
    pub fn encode_record_with(&self, x: &[f32], scratch: &mut EncodeScratch) -> Encoding {
        let mut out = scratch.take_dense_raw(self.d);
        self.encode_into(x, &mut out);
        Encoding::Dense(out)
    }

    /// Row-blocked batch core: walk each CSR row of Phi once per batch,
    /// staging through the flat scratch buffer, with records read via the
    /// accessor. Shared by the slice and flat batch entry points so the
    /// two loops (whose bit-identity the determinism suite pins) can
    /// never drift apart.
    fn encode_batch_core<'a, X: Fn(usize) -> &'a [f32]>(
        &self,
        bsz: usize,
        x: X,
        scratch: &mut EncodeScratch,
        out: &mut Vec<Encoding>,
    ) {
        let mut zs = scratch.take_flat(bsz * self.d);
        for i in 0..self.d {
            let (cols, signs) = self.row(i);
            for b in 0..bsz {
                zs[b * self.d + i] = self.finish(kernels::signed_sum(x(b), cols, signs));
            }
        }
        out.clear();
        for z in zs.chunks_exact(self.d) {
            let mut buf = scratch.take_dense_raw(self.d);
            buf.copy_from_slice(z);
            out.push(Encoding::Dense(buf));
        }
        scratch.put_flat(zs);
    }
}

impl NumericEncoder for RelaxedSjlt {
    fn encode(&self, x: &[f32]) -> Encoding {
        self.encode_record(x)
    }

    fn encode_with(&self, x: &[f32], scratch: &mut EncodeScratch) -> Encoding {
        self.encode_record_with(x, scratch)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn name(&self) -> &'static str {
        "sjlt-relaxed"
    }

    fn encode_batch(&self, xs: &[&[f32]]) -> Vec<Encoding> {
        // Row-blocked: each CSR row of Phi is walked once per batch.
        let bsz = xs.len();
        let mut outs = vec![vec![0.0f32; self.d]; bsz];
        for i in 0..self.d {
            let (cols, signs) = self.row(i);
            for (b, x) in xs.iter().enumerate() {
                outs[b][i] = self.finish(kernels::signed_sum(x, cols, signs));
            }
        }
        outs.into_iter().map(Encoding::Dense).collect()
    }

    fn encode_batch_with(
        &self,
        xs: &[&[f32]],
        scratch: &mut EncodeScratch,
        out: &mut Vec<Encoding>,
    ) {
        // Row-blocked core staged through the flat batch buffer so the
        // per-record outputs come from the pool.
        self.encode_batch_core(xs.len(), |b| xs[b], scratch, out);
    }

    fn encode_batch_flat_with(
        &self,
        xs_flat: &[f32],
        n: usize,
        scratch: &mut EncodeScratch,
        out: &mut Vec<Encoding>,
    ) {
        // Same core as the slice variant, reading records out of the
        // flat buffer — bit-identical by construction.
        assert!(n > 0, "encode_batch_flat_with needs a positive row width");
        assert_eq!(n, self.n, "row width must match the SJLT input dim");
        assert_eq!(xs_flat.len() % n, 0, "flat batch not a multiple of n={n}");
        let bsz = xs_flat.len() / n;
        self.encode_batch_core(bsz, |b| &xs_flat[b * n..(b + 1) * n], scratch, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structured_buckets_in_range() {
        let mut rng = Rng::new(1);
        let s = Sjlt::new(64, 13, 4, &mut rng);
        for c in 0..4 {
            for j in 0..13 {
                assert!(s.eta_at(c, j) < 16);
                let sg = s.sigma_at(c, j);
                assert!(sg == 1.0 || sg == -1.0);
            }
        }
    }

    #[test]
    fn linearity() {
        let mut rng = Rng::new(2);
        let s = Sjlt::new(32, 5, 4, &mut rng);
        let a = [1.0f32, -2.0, 0.5, 3.0, 0.0];
        let b = [0.2f32, 1.0, -0.5, 0.1, 2.0];
        let ab: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        let (ea, eb, eab) = (
            s.encode(&a).to_dense(),
            s.encode(&b).to_dense(),
            s.encode(&ab).to_dense(),
        );
        for i in 0..32 {
            assert!((eab[i] - ea[i] - eb[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn fused_pass_matches_chunked_reference() {
        // Reference implementation: the original two-level loop over
        // nested per-chunk tables. The fused flat pass must agree exactly.
        let mut rng = Rng::new(42);
        let (d, n, k) = (96, 13, 4);
        let s = Sjlt::new(d, n, k, &mut rng);
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).sin()).collect();
        let mut want = vec![0.0f32; d];
        let dk = d / k;
        for c in 0..k {
            for j in 0..n {
                want[c * dk + s.eta_at(c, j) as usize] += s.sigma_at(c, j) * x[j];
            }
        }
        assert_eq!(s.encode(&x).to_dense(), want);
    }

    #[test]
    fn scratch_path_bit_identical() {
        let mut rng = Rng::new(43);
        let s = Sjlt::new(128, 13, 4, &mut rng);
        let r = RelaxedSjlt::new(128, 13, 0.4, true, &mut rng);
        let mut scratch = EncodeScratch::new();
        for case in 0..20 {
            let x: Vec<f32> = (0..13).map(|i| ((case * 13 + i) as f32 * 0.3).cos()).collect();
            let a = s.encode(&x);
            let b = s.encode_with(&x, &mut scratch);
            assert_eq!(a, b, "sjlt case {case}");
            scratch.recycle(b);
            let a = r.encode(&x);
            let b = r.encode_with(&x, &mut scratch);
            assert_eq!(a, b, "relaxed case {case}");
            scratch.recycle(b);
        }
    }

    #[test]
    fn norm_preserved_in_expectation() {
        // E ||phi(x)||^2 = k ||x||^2 for the structured SJLT.
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..20).map(|i| ((i * 7 % 5) as f32) - 2.0).collect();
        let k = 4;
        let target = k as f64 * x.iter().map(|v| (*v as f64).powi(2)).sum::<f64>();
        let trials = 300;
        let mut acc = 0.0;
        for _ in 0..trials {
            let s = Sjlt::new(64 * k, 20, k, &mut rng);
            acc += s.encode(&x).norm_sq();
        }
        let meanv = acc / trials as f64;
        assert!((meanv - target).abs() / target < 0.15, "mean={meanv} want={target}");
    }

    #[test]
    fn dot_product_preserved_in_expectation() {
        // E[phi(x).phi(y)] = k x.y (Definition 2 with Delta -> 0 in mean).
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..10).map(|i| (i as f32 * 0.3).sin()).collect();
        let y: Vec<f32> = (0..10).map(|i| (i as f32 * 0.9).cos()).collect();
        let k = 2;
        let want = k as f64
            * x.iter().zip(&y).map(|(a, b)| (*a as f64) * (*b as f64)).sum::<f64>();
        let trials = 500;
        let mut acc = 0.0;
        for _ in 0..trials {
            let s = Sjlt::new(32 * k, 10, k, &mut rng);
            acc += s.encode(&x).dot(&s.encode(&y));
        }
        let meanv = acc / trials as f64;
        assert!((meanv - want).abs() < 0.2 * want.abs().max(1.0), "mean={meanv} want={want}");
    }

    #[test]
    fn flat_layouts_match() {
        let mut rng = Rng::new(5);
        let s = Sjlt::new(24, 7, 3, &mut rng);
        let ef = s.eta_flat();
        assert_eq!(ef.len(), 21);
        assert_eq!(ef[7], s.eta_at(1, 0) as i32);
        let sf = s.sigma_flat();
        assert_eq!(sf[14], s.sigma_at(2, 0));
    }

    #[test]
    fn relaxed_density_near_p() {
        let mut rng = Rng::new(6);
        for p in [0.1, 0.4, 0.8] {
            let s = RelaxedSjlt::new(500, 40, p, false, &mut rng);
            assert!((s.density() - p).abs() < 0.03, "p={p} density={}", s.density());
        }
    }

    #[test]
    fn relaxed_quantized_is_pm_one() {
        let mut rng = Rng::new(7);
        let s = RelaxedSjlt::new(64, 13, 0.4, true, &mut rng);
        let x: Vec<f32> = (0..13).map(|i| (i as f32).cos()).collect();
        if let Encoding::Dense(v) = s.encode(&x) {
            assert!(v.iter().all(|&z| z == 1.0 || z == -1.0));
        } else {
            panic!();
        }
    }

    #[test]
    fn relaxed_unquantized_linear() {
        let mut rng = Rng::new(8);
        let s = RelaxedSjlt::new(128, 6, 0.4, false, &mut rng);
        let a = [1.0f32, 0.0, -1.0, 0.5, 2.0, -0.3];
        let scaled: Vec<f32> = a.iter().map(|v| v * 2.0).collect();
        let ea = s.encode(&a).to_dense();
        let es = s.encode(&scaled).to_dense();
        for i in 0..128 {
            assert!((es[i] - 2.0 * ea[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn relaxed_batch_paths_agree() {
        let mut rng = Rng::new(9);
        let s = RelaxedSjlt::new(96, 8, 0.4, false, &mut rng);
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|b| (0..8).map(|j| ((b * 8 + j) as f32 * 0.11).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let per_record: Vec<Encoding> = refs.iter().map(|x| s.encode(x)).collect();
        let batched = s.encode_batch(&refs);
        assert_eq!(batched, per_record);
        let mut scratch = EncodeScratch::new();
        let mut out = Vec::new();
        s.encode_batch_with(&refs, &mut scratch, &mut out);
        assert_eq!(out, per_record);
    }
}
