//! Sparse Johnson-Lindenstrauss transform numeric encoder (paper Eq. 5,
//! plus the relaxed i.i.d. ±1/0 matrix of Sec. 7.2.3).
//!
//! Two constructions, both hash-defined so nothing scales with n beyond
//! the (k x n) hash tables:
//!
//! * [`Sjlt`] — the structured construction of Eq. 5: k chunks of size
//!   d/k, chunk c scatter-adds `sigma_c(j) x_j` at bucket `eta_c(j)`.
//!   Mirrors the Pallas kernel `kernels/sjlt.py` (cross-validated in the
//!   integration tests).
//! * [`RelaxedSjlt`] — the empirical-section variant: Phi_ij in
//!   {+1 w.p. p/2, 0 w.p. 1-p, -1 w.p. p/2}, stored in CSR-like form so
//!   encode cost is proportional to nnz(Phi). Optionally sign-quantized
//!   ("SJLT encodings are quantized using the sign function", Fig. 9).

use crate::encoding::vector::Encoding;
use crate::encoding::NumericEncoder;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Sjlt {
    /// eta[c][j]: bucket of input j in chunk c, in [0, d/k).
    pub eta: Vec<Vec<u32>>,
    /// sigma[c][j]: sign of input j in chunk c.
    pub sigma: Vec<Vec<f32>>,
    pub d: usize,
    pub n: usize,
}

impl Sjlt {
    pub fn new(d: usize, n: usize, k: usize, rng: &mut Rng) -> Self {
        assert!(d % k == 0, "d={d} must be divisible by k={k}");
        let dk = (d / k) as u64;
        let eta = (0..k)
            .map(|_| (0..n).map(|_| rng.below(dk) as u32).collect())
            .collect();
        let sigma = (0..k).map(|_| (0..n).map(|_| rng.sign()).collect()).collect();
        Sjlt { eta, sigma, d, n }
    }

    pub fn k(&self) -> usize {
        self.eta.len()
    }

    pub fn encode_record(&self, x: &[f32]) -> Encoding {
        debug_assert_eq!(x.len(), self.n);
        let k = self.k();
        let dk = self.d / k;
        let mut out = vec![0.0f32; self.d];
        for c in 0..k {
            let base = c * dk;
            let (eta, sigma) = (&self.eta[c], &self.sigma[c]);
            for j in 0..self.n {
                out[base + eta[j] as usize] += sigma[j] * x[j];
            }
        }
        Encoding::Dense(out)
    }

    /// Hash tables flattened for the PJRT artifact `encode_sjlt`
    /// (row-major (k, n) i32 / f32).
    pub fn eta_flat(&self) -> Vec<i32> {
        self.eta.iter().flatten().map(|&v| v as i32).collect()
    }

    pub fn sigma_flat(&self) -> Vec<f32> {
        self.sigma.iter().flatten().copied().collect()
    }
}

impl NumericEncoder for Sjlt {
    fn encode(&self, x: &[f32]) -> Encoding {
        self.encode_record(x)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn name(&self) -> &'static str {
        "sjlt"
    }
}

/// The relaxed construction used in the paper's experiments (Sec. 7.2.3).
#[derive(Clone, Debug)]
pub struct RelaxedSjlt {
    /// Per output row: (input index, sign) of non-zero entries.
    rows: Vec<Vec<(u32, f32)>>,
    pub d: usize,
    pub n: usize,
    pub p: f64,
    pub quantize: bool,
}

impl RelaxedSjlt {
    pub fn new(d: usize, n: usize, p: f64, quantize: bool, rng: &mut Rng) -> Self {
        let rows = (0..d)
            .map(|_| {
                (0..n as u32)
                    .filter_map(|j| {
                        if rng.bernoulli(p) {
                            Some((j, rng.sign()))
                        } else {
                            None
                        }
                    })
                    .collect()
            })
            .collect();
        RelaxedSjlt { rows, d, n, p, quantize }
    }

    /// Fraction of non-zero entries in Phi (should be ~p).
    pub fn density(&self) -> f64 {
        let nnz: usize = self.rows.iter().map(Vec::len).sum();
        nnz as f64 / (self.d * self.n) as f64
    }

    pub fn encode_record(&self, x: &[f32]) -> Encoding {
        debug_assert_eq!(x.len(), self.n);
        let mut out = vec![0.0f32; self.d];
        for (zi, row) in out.iter_mut().zip(&self.rows) {
            let mut acc = 0.0f32;
            for &(j, s) in row {
                acc += s * x[j as usize];
            }
            *zi = if self.quantize {
                if acc >= 0.0 {
                    1.0
                } else {
                    -1.0
                }
            } else {
                acc
            };
        }
        Encoding::Dense(out)
    }
}

impl NumericEncoder for RelaxedSjlt {
    fn encode(&self, x: &[f32]) -> Encoding {
        self.encode_record(x)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn name(&self) -> &'static str {
        "sjlt-relaxed"
    }

    fn encode_batch(&self, xs: &[&[f32]]) -> Vec<Encoding> {
        // Row-blocked: each CSR row of Phi is walked once per batch.
        let bsz = xs.len();
        let mut outs = vec![vec![0.0f32; self.d]; bsz];
        for (i, row) in self.rows.iter().enumerate() {
            for (b, x) in xs.iter().enumerate() {
                let mut acc = 0.0f32;
                for &(j, s) in row {
                    acc += s * x[j as usize];
                }
                outs[b][i] = if self.quantize {
                    if acc >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                } else {
                    acc
                };
            }
        }
        outs.into_iter().map(Encoding::Dense).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structured_buckets_in_range() {
        let mut rng = Rng::new(1);
        let s = Sjlt::new(64, 13, 4, &mut rng);
        for c in 0..4 {
            assert!(s.eta[c].iter().all(|&b| b < 16));
            assert!(s.sigma[c].iter().all(|&v| v == 1.0 || v == -1.0));
        }
    }

    #[test]
    fn linearity() {
        let mut rng = Rng::new(2);
        let s = Sjlt::new(32, 5, 4, &mut rng);
        let a = [1.0f32, -2.0, 0.5, 3.0, 0.0];
        let b = [0.2f32, 1.0, -0.5, 0.1, 2.0];
        let ab: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        let (ea, eb, eab) = (
            s.encode(&a).to_dense(),
            s.encode(&b).to_dense(),
            s.encode(&ab).to_dense(),
        );
        for i in 0..32 {
            assert!((eab[i] - ea[i] - eb[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn norm_preserved_in_expectation() {
        // E ||phi(x)||^2 = k ||x||^2 for the structured SJLT.
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..20).map(|i| ((i * 7 % 5) as f32) - 2.0).collect();
        let k = 4;
        let target = k as f64 * x.iter().map(|v| (*v as f64).powi(2)).sum::<f64>();
        let trials = 300;
        let mut acc = 0.0;
        for _ in 0..trials {
            let s = Sjlt::new(64 * k, 20, k, &mut rng);
            acc += s.encode(&x).norm_sq();
        }
        let meanv = acc / trials as f64;
        assert!((meanv - target).abs() / target < 0.15, "mean={meanv} want={target}");
    }

    #[test]
    fn dot_product_preserved_in_expectation() {
        // E[phi(x).phi(y)] = k x.y (Definition 2 with Delta -> 0 in mean).
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..10).map(|i| (i as f32 * 0.3).sin()).collect();
        let y: Vec<f32> = (0..10).map(|i| (i as f32 * 0.9).cos()).collect();
        let k = 2;
        let want = k as f64
            * x.iter().zip(&y).map(|(a, b)| (*a as f64) * (*b as f64)).sum::<f64>();
        let trials = 500;
        let mut acc = 0.0;
        for _ in 0..trials {
            let s = Sjlt::new(32 * k, 10, k, &mut rng);
            acc += s.encode(&x).dot(&s.encode(&y));
        }
        let meanv = acc / trials as f64;
        assert!((meanv - want).abs() < 0.2 * want.abs().max(1.0), "mean={meanv} want={want}");
    }

    #[test]
    fn flat_layouts_match() {
        let mut rng = Rng::new(5);
        let s = Sjlt::new(24, 7, 3, &mut rng);
        let ef = s.eta_flat();
        assert_eq!(ef.len(), 21);
        assert_eq!(ef[7], s.eta[1][0] as i32);
        let sf = s.sigma_flat();
        assert_eq!(sf[14], s.sigma[2][0]);
    }

    #[test]
    fn relaxed_density_near_p() {
        let mut rng = Rng::new(6);
        for p in [0.1, 0.4, 0.8] {
            let s = RelaxedSjlt::new(500, 40, p, false, &mut rng);
            assert!((s.density() - p).abs() < 0.03, "p={p} density={}", s.density());
        }
    }

    #[test]
    fn relaxed_quantized_is_pm_one() {
        let mut rng = Rng::new(7);
        let s = RelaxedSjlt::new(64, 13, 0.4, true, &mut rng);
        let x: Vec<f32> = (0..13).map(|i| (i as f32).cos()).collect();
        if let Encoding::Dense(v) = s.encode(&x) {
            assert!(v.iter().all(|&z| z == 1.0 || z == -1.0));
        } else {
            panic!();
        }
    }

    #[test]
    fn relaxed_unquantized_linear() {
        let mut rng = Rng::new(8);
        let s = RelaxedSjlt::new(128, 6, 0.4, false, &mut rng);
        let a = [1.0f32, 0.0, -1.0, 0.5, 2.0, -0.3];
        let scaled: Vec<f32> = a.iter().map(|v| v * 2.0).collect();
        let ea = s.encode(&a).to_dense();
        let es = s.encode(&scaled).to_dense();
        for i in 0..128 {
            assert!((es[i] - 2.0 * ea[i]).abs() < 1e-5);
        }
    }
}
