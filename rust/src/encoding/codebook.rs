//! Random-codebook categorical encoder — the conventional HDC baseline
//! (paper Sec. 4.1, Theorem 2).
//!
//! Each symbol gets a codeword sampled `Unif({±1}^d)`; feature vectors
//! bundle by element-wise sum. Codewords are generated *lazily* as new
//! symbols stream in (exactly the setup of Fig. 7A) and retained in an
//! item memory whose footprint grows linearly with the alphabet seen so
//! far — the scalability failure mode this paper exists to fix. The
//! encoder tracks its own memory use and can enforce a budget, turning
//! the paper's "at a certain point the codebook exceeds available RAM
//! and the program crashes" into a typed error.

use std::collections::HashMap;

use crate::encoding::scratch::EncodeScratch;
use crate::encoding::vector::Encoding;
use crate::encoding::CategoricalEncoder;
use crate::util::rng::{mix64, Rng};

/// Codeword precision: i8 keeps the codebook 4x smaller than f32 while
/// remaining faithful (codewords are ±1).
type Codeword = Vec<i8>;

#[derive(Debug)]
pub struct CodebookEncoder {
    codebook: HashMap<u64, Codeword>,
    d: usize,
    seed: u64,
    /// Optional cap on codebook bytes; `encode` returns an error past it.
    pub memory_budget: Option<usize>,
}

/// Raised when the item memory exceeds its budget (Fig. 7A's OOM point).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodebookOom {
    pub symbols: usize,
    pub bytes: usize,
}

impl std::fmt::Display for CodebookOom {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "codebook exceeded memory budget: {} symbols, {} bytes",
            self.symbols, self.bytes
        )
    }
}

impl std::error::Error for CodebookOom {}

impl CodebookEncoder {
    pub fn new(d: usize, seed: u64) -> Self {
        CodebookEncoder { codebook: HashMap::new(), d, seed, memory_budget: None }
    }

    pub fn with_budget(d: usize, seed: u64, budget_bytes: usize) -> Self {
        CodebookEncoder {
            codebook: HashMap::new(),
            d,
            seed,
            memory_budget: Some(budget_bytes),
        }
    }

    pub fn symbols_seen(&self) -> usize {
        self.codebook.len()
    }

    /// Deterministic codeword for a symbol: the draw is keyed by
    /// (global seed, symbol), so re-encoding after eviction or on another
    /// worker yields the identical codeword.
    fn gen_codeword(&self, symbol: u64) -> Codeword {
        let mut rng = Rng::new(mix64(self.seed ^ mix64(symbol)));
        // 64 signs per u64 draw.
        let mut out = Vec::with_capacity(self.d);
        let mut word = 0u64;
        for i in 0..self.d {
            if i % 64 == 0 {
                word = rng.next_u64();
            }
            out.push(if word & 1 == 0 { 1 } else { -1 });
            word >>= 1;
        }
        out
    }

    fn lookup_or_insert(&mut self, symbol: u64) -> &Codeword {
        if !self.codebook.contains_key(&symbol) {
            let cw = self.gen_codeword(symbol);
            self.codebook.insert(symbol, cw);
        }
        &self.codebook[&symbol]
    }

    /// Bundle `symbols`' codewords into a caller-provided zeroed
    /// accumulator (shared by the allocating and scratch paths).
    fn accumulate_set(&mut self, symbols: &[u64], acc: &mut [f32]) -> Result<(), CodebookOom> {
        for &a in symbols {
            let cw = self.lookup_or_insert(a);
            for (o, &c) in acc.iter_mut().zip(cw.iter()) {
                *o += c as f32;
            }
        }
        if let Some(budget) = self.memory_budget {
            let bytes = self.memory_bytes_now();
            if bytes > budget {
                return Err(CodebookOom { symbols: self.codebook.len(), bytes });
            }
        }
        Ok(())
    }

    /// Encode, returning an error if the memory budget is exhausted.
    pub fn try_encode(&mut self, symbols: &[u64]) -> Result<Encoding, CodebookOom> {
        let mut acc = vec![0.0f32; self.d];
        self.accumulate_set(symbols, &mut acc)?;
        Ok(Encoding::Dense(acc))
    }

    /// Scratch-path [`CodebookEncoder::try_encode`]: the accumulator is a
    /// pooled zeroed buffer (the buffer is recycled on error).
    pub fn try_encode_with(
        &mut self,
        symbols: &[u64],
        scratch: &mut EncodeScratch,
    ) -> Result<Encoding, CodebookOom> {
        let mut acc = scratch.take_dense_zeroed(self.d);
        match self.accumulate_set(symbols, &mut acc) {
            Ok(()) => Ok(Encoding::Dense(acc)),
            Err(e) => {
                scratch.recycle(Encoding::Dense(acc));
                Err(e)
            }
        }
    }

    fn memory_bytes_now(&self) -> usize {
        // codeword payloads + per-entry HashMap overhead (key + bucket).
        self.codebook.len() * (self.d + std::mem::size_of::<u64>() + 48)
    }
}

impl CategoricalEncoder for CodebookEncoder {
    /// Panics on budget exhaustion — mirroring the paper's observed crash.
    /// Use [`CodebookEncoder::try_encode`] to handle it gracefully.
    fn encode(&mut self, symbols: &[u64]) -> Encoding {
        self.try_encode(symbols).expect("codebook memory budget exceeded")
    }

    fn encode_with(&mut self, symbols: &[u64], scratch: &mut EncodeScratch) -> Encoding {
        self.try_encode_with(symbols, scratch)
            .expect("codebook memory budget exceeded")
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn memory_bytes(&self) -> usize {
        self.memory_bytes_now()
    }

    fn name(&self) -> &'static str {
        "codebook"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codewords_are_pm_one_and_deterministic() {
        let mut e = CodebookEncoder::new(256, 1);
        let a = e.try_encode(&[5]).unwrap().to_dense();
        assert!(a.iter().all(|&x| x == 1.0 || x == -1.0));
        let mut e2 = CodebookEncoder::new(256, 1);
        assert_eq!(e2.try_encode(&[5]).unwrap().to_dense(), a);
    }

    #[test]
    fn different_seed_different_codebook() {
        let mut e1 = CodebookEncoder::new(128, 1);
        let mut e2 = CodebookEncoder::new(128, 2);
        assert_ne!(
            e1.try_encode(&[9]).unwrap().to_dense(),
            e2.try_encode(&[9]).unwrap().to_dense()
        );
    }

    #[test]
    fn bundling_is_sum_of_codewords() {
        let mut e = CodebookEncoder::new(64, 3);
        let a = e.try_encode(&[1]).unwrap().to_dense();
        let b = e.try_encode(&[2]).unwrap().to_dense();
        let ab = e.try_encode(&[1, 2]).unwrap().to_dense();
        for i in 0..64 {
            assert_eq!(ab[i], a[i] + b[i]);
        }
    }

    #[test]
    fn memory_grows_linearly_with_alphabet() {
        let mut e = CodebookEncoder::new(1000, 4);
        let m0 = e.memory_bytes();
        e.try_encode(&(0..100).collect::<Vec<_>>()).unwrap();
        let m100 = e.memory_bytes();
        e.try_encode(&(100..300).collect::<Vec<_>>()).unwrap();
        let m300 = e.memory_bytes();
        assert!(m100 > m0);
        // 300 symbols ~ 3x the footprint of 100 symbols.
        let per1 = m100 as f64 / 100.0;
        let per3 = m300 as f64 / 300.0;
        assert!((per1 - per3).abs() / per1 < 0.05);
    }

    #[test]
    fn repeated_symbols_do_not_grow_memory() {
        let mut e = CodebookEncoder::new(500, 5);
        e.try_encode(&[1, 2, 3]).unwrap();
        let m = e.memory_bytes();
        for _ in 0..10 {
            e.try_encode(&[1, 2, 3]).unwrap();
        }
        assert_eq!(e.memory_bytes(), m);
        assert_eq!(e.symbols_seen(), 3);
    }

    #[test]
    fn budget_enforced() {
        let mut e = CodebookEncoder::with_budget(1000, 6, 200_000);
        let mut failed = false;
        for batch in 0..100 {
            let symbols: Vec<u64> = (batch * 10..batch * 10 + 10).collect();
            if e.try_encode(&symbols).is_err() {
                failed = true;
                break;
            }
        }
        assert!(failed, "budget never tripped");
    }

    #[test]
    fn dot_concentration_theorem2() {
        // (1/d) phi(x).phi(x') ~ |x ∩ x'| (Theorem 2): overlap-13 sets.
        let mut e = CodebookEncoder::new(32_768, 7);
        let x: Vec<u64> = (0..26).collect();
        let y: Vec<u64> = (13..39).collect();
        let fx = e.try_encode(&x).unwrap();
        let fy = e.try_encode(&y).unwrap();
        let est = fx.dot(&fy) / 32_768.0;
        assert!((est - 13.0).abs() < 2.0, "est={est}");
    }
}
