//! HD vector representations shared by all encoders.
//!
//! The paper contrasts *dense* encodings (random codewords, signed
//! projections — f32/i8 per coordinate) with *sparse binary* encodings
//! (Bloom filters, thresholded projections — a short sorted index list).
//! Sparse-binary is the scalability workhorse: inference against a dense
//! parameter vector degenerates to `k·s` lookups plus adds, with no
//! multiplications (Sec. 4.2.2), and the full d-dimensional embedding is
//! never materialized.

/// One encoded HD vector.
#[derive(Clone, Debug, PartialEq)]
pub enum Encoding {
    /// Dense f32 vector of length `d`.
    Dense(Vec<f32>),
    /// Sparse binary vector: sorted, deduplicated coordinates equal to 1.
    SparseBinary { indices: Vec<u32>, d: usize },
}

impl Encoding {
    /// Dimension of the HD space this vector lives in.
    pub fn dim(&self) -> usize {
        match self {
            Encoding::Dense(v) => v.len(),
            Encoding::SparseBinary { d, .. } => *d,
        }
    }

    /// Number of non-zero coordinates.
    pub fn nnz(&self) -> usize {
        match self {
            Encoding::Dense(v) => v.iter().filter(|x| **x != 0.0).count(),
            Encoding::SparseBinary { indices, .. } => indices.len(),
        }
    }

    /// Materialize as a dense f32 vector.
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            Encoding::Dense(v) => v.clone(),
            Encoding::SparseBinary { indices, d } => {
                let mut out = vec![0.0f32; *d];
                for &i in indices {
                    out[i as usize] = 1.0;
                }
                out
            }
        }
    }

    /// Scatter into a caller-provided dense buffer (must be zeroed by the
    /// caller or via [`Encoding::scatter_into_zeroed`]). Used to feed the
    /// PJRT artifacts, which take dense batches.
    pub fn scatter_into(&self, out: &mut [f32]) {
        match self {
            Encoding::Dense(v) => out[..v.len()].copy_from_slice(v),
            Encoding::SparseBinary { indices, .. } => {
                for &i in indices {
                    out[i as usize] = 1.0;
                }
            }
        }
    }

    /// Zero `out` then scatter; cheap for sparse codes (zeroing dominated
    /// by memset, touched coords are few).
    pub fn scatter_into_zeroed(&self, out: &mut [f32]) {
        out.fill(0.0);
        self.scatter_into(out);
    }

    /// Dot product between two encodings (Definition 2's similarity).
    pub fn dot(&self, other: &Encoding) -> f64 {
        assert_eq!(self.dim(), other.dim(), "dim mismatch");
        match (self, other) {
            (Encoding::Dense(a), Encoding::Dense(b)) => {
                a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
            }
            (Encoding::Dense(a), Encoding::SparseBinary { indices, .. })
            | (Encoding::SparseBinary { indices, .. }, Encoding::Dense(a)) => {
                indices.iter().map(|&i| a[i as usize] as f64).sum()
            }
            (
                Encoding::SparseBinary { indices: a, .. },
                Encoding::SparseBinary { indices: b, .. },
            ) => {
                // Both sorted: linear merge intersection count.
                let (mut i, mut j, mut acc) = (0usize, 0usize, 0u64);
                while i < a.len() && j < b.len() {
                    match a[i].cmp(&b[j]) {
                        std::cmp::Ordering::Less => i += 1,
                        std::cmp::Ordering::Greater => j += 1,
                        std::cmp::Ordering::Equal => {
                            acc += 1;
                            i += 1;
                            j += 1;
                        }
                    }
                }
                acc as f64
            }
        }
    }

    /// Dot product against a dense parameter vector theta — the inference
    /// primitive. For sparse codes this is the multiplication-free
    /// lookup-and-sum the paper highlights.
    pub fn dot_params(&self, theta: &[f32]) -> f64 {
        match self {
            Encoding::Dense(v) => {
                debug_assert_eq!(v.len(), theta.len());
                v.iter().zip(theta).map(|(x, t)| *x as f64 * *t as f64).sum()
            }
            Encoding::SparseBinary { indices, d } => {
                debug_assert_eq!(*d, theta.len());
                indices.iter().map(|&i| theta[i as usize] as f64).sum()
            }
        }
    }

    /// Squared L2 norm.
    pub fn norm_sq(&self) -> f64 {
        match self {
            Encoding::Dense(v) => v.iter().map(|x| (*x as f64) * (*x as f64)).sum(),
            Encoding::SparseBinary { indices, .. } => indices.len() as f64,
        }
    }

    /// Bytes needed to store this vector (Sec. 4.2.2's memory argument:
    /// sparse codes store k·s indices, not d values).
    pub fn storage_bytes(&self) -> usize {
        match self {
            Encoding::Dense(v) => v.len() * std::mem::size_of::<f32>(),
            Encoding::SparseBinary { indices, .. } => {
                indices.len() * std::mem::size_of::<u32>() + std::mem::size_of::<usize>()
            }
        }
    }
}

/// Sort + dedup an index buffer in place and wrap it as a sparse encoding.
/// All sparse encoders' allocating paths funnel through this so the
/// "sorted unique" invariant holds by construction; the dedup primitive
/// itself is [`crate::encoding::kernels::sort_dedup`] (the scratch paths
/// use the kernel layer's bitset mark/sweep pair instead).
pub fn sparse_from_indices(mut indices: Vec<u32>, d: usize) -> Encoding {
    crate::encoding::kernels::sort_dedup(&mut indices);
    debug_assert!(indices.last().map_or(true, |&i| (i as usize) < d));
    Encoding::SparseBinary { indices, d }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(idx: &[u32], d: usize) -> Encoding {
        sparse_from_indices(idx.to_vec(), d)
    }

    #[test]
    fn sparse_invariants() {
        let e = sp(&[5, 1, 5, 3, 1], 10);
        match &e {
            Encoding::SparseBinary { indices, d } => {
                assert_eq!(indices, &vec![1, 3, 5]);
                assert_eq!(*d, 10);
            }
            _ => panic!(),
        }
        assert_eq!(e.nnz(), 3);
        assert_eq!(e.dim(), 10);
    }

    #[test]
    fn to_dense_round_trip() {
        let e = sp(&[0, 4, 9], 10);
        let d = e.to_dense();
        assert_eq!(d.len(), 10);
        assert_eq!(d.iter().sum::<f32>(), 3.0);
        assert_eq!(d[0], 1.0);
        assert_eq!(d[9], 1.0);
    }

    #[test]
    fn dot_sparse_sparse_is_intersection() {
        let a = sp(&[1, 3, 5, 7], 10);
        let b = sp(&[3, 4, 5, 9], 10);
        assert_eq!(a.dot(&b), 2.0);
        assert_eq!(b.dot(&a), 2.0);
        assert_eq!(a.dot(&a), 4.0);
    }

    #[test]
    fn dot_mixed_matches_dense() {
        let a = sp(&[2, 4], 6);
        let b = Encoding::Dense(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.dot(&b), 3.0 + 5.0);
        assert_eq!(b.dot(&a), 8.0);
        // cross-check against fully dense
        let ad = Encoding::Dense(a.to_dense());
        assert_eq!(ad.dot(&b), 8.0);
    }

    #[test]
    fn dot_params_paths_agree() {
        let theta: Vec<f32> = (0..8).map(|i| i as f32 * 0.5).collect();
        let s = sp(&[1, 6], 8);
        let d = Encoding::Dense(s.to_dense());
        assert_eq!(s.dot_params(&theta), d.dot_params(&theta));
        assert_eq!(s.dot_params(&theta), 0.5 + 3.0);
    }

    #[test]
    fn scatter_into_zeroed() {
        let mut buf = vec![7.0f32; 6];
        sp(&[0, 5], 6).scatter_into_zeroed(&mut buf);
        assert_eq!(buf, vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn storage_accounting_favors_sparse() {
        let d = 10_000;
        let sparse = sp(&[1, 2, 3, 4], d);
        let dense = Encoding::Dense(vec![1.0; d]);
        assert!(sparse.storage_bytes() * 100 < dense.storage_bytes());
    }

    #[test]
    fn norm_sq() {
        assert_eq!(sp(&[1, 2, 3], 5).norm_sq(), 3.0);
        assert_eq!(Encoding::Dense(vec![3.0, 4.0]).norm_sq(), 25.0);
    }
}
