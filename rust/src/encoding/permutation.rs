//! Permutation / shift-based categorical encoder (paper Remark 3 and the
//! Sec. 7.4.1 "shift-based materialization" hardware baseline).
//!
//! A pool of seed vectors in {±1}^d is generated once; a symbol's
//! codeword is seed[psi1(a) % pool] cyclically rotated by
//! `(psi2(a) % (d/g)) * g` where g is the shift granularity (the paper's
//! FPGA comparison uses g=16 "bricks" to cut materialization latency).
//! Distinct rotations of a random ±1 vector are near-orthogonal, so this
//! imitates random codes while storing only `pool` vectors — but every
//! encode must *materialize* a rotated copy, which is the data-movement
//! bottleneck the paper measures (84–135x slower than hashing on FPGA).

use crate::encoding::scratch::EncodeScratch;
use crate::encoding::vector::Encoding;
use crate::encoding::CategoricalEncoder;
use crate::hash::{IndexHash, MurmurHash};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct PermutationEncoder {
    seeds: Vec<Vec<f32>>, // pool of ±1 seed vectors
    d: usize,
    granularity: usize,
    h_seed: MurmurHash, // selects the seed vector
    h_rot: MurmurHash,  // selects the rotation
}

impl PermutationEncoder {
    pub fn new(d: usize, pool: usize, granularity: usize, rng: &mut Rng) -> Self {
        assert!(pool >= 1 && granularity >= 1 && d % granularity == 0);
        let seeds = (0..pool)
            .map(|_| (0..d).map(|_| rng.sign()).collect())
            .collect();
        PermutationEncoder {
            seeds,
            d,
            granularity,
            h_seed: MurmurHash::new(rng.next_u32()),
            h_rot: MurmurHash::new(rng.next_u32()),
        }
    }

    /// Rotation amount for a symbol, in coordinates (multiple of g).
    fn rotation(&self, symbol: u64) -> usize {
        let steps = self.d / self.granularity;
        (self.h_rot.index(symbol, steps as u64) as usize) * self.granularity
    }

    /// Materialize the codeword of one symbol into `out` (the explicit
    /// copy the hardware baseline pays for).
    pub fn materialize_symbol(&self, symbol: u64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d);
        let seed = &self.seeds[self.h_seed.index(symbol, self.seeds.len() as u64) as usize];
        let rot = self.rotation(symbol);
        // out = seed rotated right by rot (brick-wise copy, Sec. 7.4.1).
        let tail = self.d - rot;
        out[rot..].copy_from_slice(&seed[..tail]);
        out[..rot].copy_from_slice(&seed[tail..]);
    }

    pub fn encode_set(&self, symbols: &[u64]) -> Encoding {
        let mut acc = vec![0.0f32; self.d];
        let mut tmp = vec![0.0f32; self.d];
        for &a in symbols {
            self.materialize_symbol(a, &mut tmp);
            for (o, t) in acc.iter_mut().zip(&tmp) {
                *o += *t;
            }
        }
        Encoding::Dense(acc)
    }

    /// Scratch-path [`PermutationEncoder::encode_set`]: accumulator and
    /// materialization temporary both come from the pool (the temporary is
    /// recycled before returning). Bit-identical to `encode_set`.
    pub fn encode_set_with(&self, symbols: &[u64], scratch: &mut EncodeScratch) -> Encoding {
        let mut acc = scratch.take_dense_zeroed(self.d);
        // materialize_symbol overwrites every element, so no zeroing.
        let mut tmp = scratch.take_dense_raw(self.d);
        for &a in symbols {
            self.materialize_symbol(a, &mut tmp);
            for (o, t) in acc.iter_mut().zip(&tmp) {
                *o += *t;
            }
        }
        scratch.recycle(Encoding::Dense(tmp));
        Encoding::Dense(acc)
    }
}

impl CategoricalEncoder for PermutationEncoder {
    fn encode(&mut self, symbols: &[u64]) -> Encoding {
        self.encode_set(symbols)
    }

    fn encode_with(&mut self, symbols: &[u64], scratch: &mut EncodeScratch) -> Encoding {
        self.encode_set_with(symbols, scratch)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn memory_bytes(&self) -> usize {
        self.seeds.len() * self.d * std::mem::size_of::<f32>()
    }

    fn name(&self) -> &'static str {
        "permutation"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn materialized_codes_are_rotations() {
        let mut rng = Rng::new(1);
        let e = PermutationEncoder::new(64, 1, 16, &mut rng);
        let mut a = vec![0.0; 64];
        e.materialize_symbol(123, &mut a);
        // Some rotation of the single seed must equal a.
        let seed = &e.seeds[0];
        let found = (0..4).any(|r| {
            let rot = r * 16;
            (0..64).all(|i| a[(i + rot) % 64] == seed[i])
        });
        assert!(found);
    }

    #[test]
    fn deterministic_and_order_invariant() {
        let mut rng = Rng::new(2);
        let e = PermutationEncoder::new(128, 4, 16, &mut rng);
        assert_eq!(e.encode_set(&[1, 2, 3]), e.encode_set(&[3, 2, 1]));
    }

    #[test]
    fn rotations_near_orthogonal() {
        let mut rng = Rng::new(3);
        let e = PermutationEncoder::new(4096, 2, 16, &mut rng);
        let a = e.encode_set(&[10]);
        let b = e.encode_set(&[999]);
        assert!(a.dot(&b).abs() < 6.0 * (4096f64).sqrt(), "dot={}", a.dot(&b));
    }

    #[test]
    fn alphabet_capacity_limited_by_d_and_pool() {
        // pool * d/g distinct codewords exist; larger alphabets collide.
        let mut rng = Rng::new(4);
        let e = PermutationEncoder::new(64, 1, 16, &mut rng);
        // only 4 distinct rotations: among 100 symbols some must share codes
        let mut codes = std::collections::HashSet::new();
        let mut buf = vec![0.0f32; 64];
        for sym in 0..100u64 {
            e.materialize_symbol(sym, &mut buf);
            codes.insert(buf.iter().map(|x| *x as i8).collect::<Vec<_>>());
        }
        assert!(codes.len() <= 4);
    }

    #[test]
    fn memory_scales_with_pool_not_alphabet() {
        let mut rng = Rng::new(5);
        let mut e = PermutationEncoder::new(1024, 8, 16, &mut rng);
        let m = e.memory_bytes();
        for batch in 0..20 {
            let symbols: Vec<u64> = (batch * 50..batch * 50 + 26).collect();
            let _ = e.encode(&symbols);
        }
        assert_eq!(e.memory_bytes(), m);
        assert_eq!(m, 8 * 1024 * 4);
    }
}
