//! `shdc` — the streaming-HDC leader binary.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! shdc train   [--records N] [--alphabet M] [--d-cat D] [--k K]
//!              [--backend rust|pjrt] [--profile small|default]
//!              [--workers W] [--batch B] [--lr LR] [--seed S]
//! shdc encode-bench [--records N] [--d-cat D] [--k K] [--workers W]
//! shdc hw-report
//! shdc artifacts-info
//! ```

use anyhow::{bail, Result};

use shdc::coordinator::{CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::data::SyntheticStream;
use shdc::encoding::BundleMethod;
use shdc::pipeline::{train, TrainBackend, TrainCfg};

/// Minimal `--key value` argument map.
pub struct Args {
    pub cmd: String,
    pairs: Vec<(String, String)>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        if argv.is_empty() {
            bail!("usage: shdc <train|encode-bench|hw-report|artifacts-info> [--key value ...]");
        }
        let cmd = argv[0].clone();
        let mut pairs = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got {}", argv[i]))?;
            let v = argv.get(i + 1).cloned().unwrap_or_default();
            pairs.push((k.to_string(), v));
            i += 2;
        }
        Ok(Args { cmd, pairs })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    match args.cmd.as_str() {
        "train" => cmd_train(&args),
        "encode-bench" => cmd_encode_bench(&args),
        "hw-report" => cmd_hw_report(&args),
        "artifacts-info" => cmd_artifacts_info(),
        "pjrt-bench" => cmd_pjrt_bench(&args),
        other => bail!("unknown subcommand {other}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let seed: u64 = args.num("seed", 0);
    let d_cat: usize = args.num("d-cat", 10_000);
    let d_num: usize = args.num("d-num", 2_048);
    let k: usize = args.num("k", 4);
    let backend = match args.get("backend").unwrap_or("rust") {
        "rust" => TrainBackend::RustSgd,
        "pjrt" => TrainBackend::PjrtFused {
            profile: args.get("profile").unwrap_or("default").to_string(),
        },
        other => bail!("unknown backend {other}"),
    };
    // The pjrt backend's artifact pins (b, d_num, d_cat); align defaults.
    let (d_cat, d_num) = if let TrainBackend::PjrtFused { profile } = &backend {
        match profile.as_str() {
            "small" => (512, 256),
            _ => (8_192, 2_048),
        }
    } else {
        (d_cat, d_num)
    };
    let data = SyntheticConfig {
        alphabet_size: args.num("alphabet", 1_000_000),
        positive_rate: args.num("positive-rate", 0.25),
        noise: args.num("noise", 0.5),
        seed,
        ..Default::default()
    };
    let cfg = TrainCfg {
        encoder: EncoderCfg {
            cat: CatCfg::Bloom { d: d_cat, k },
            num: NumCfg::DenseSign { d: d_num },
            bundle: BundleMethod::Concat,
            n_numeric: data.n_numeric,
            seed,
        },
        backend,
        lr: args.num("lr", 0.5),
        batch_size: args.num("batch", 256),
        n_workers: args.num("workers", 4),
        train_records: args.num("records", 200_000),
        val_records: args.num("val-records", 20_000),
        test_records: args.num("test-records", 40_000),
        validate_every: args.num("validate-every", 50_000),
        patience: 3,
        auc_chunk: args.num("auc-chunk", 10_000),
        seed,
    };
    eprintln!("training: {:?}", cfg.encoder);
    let report = train(&cfg, &data)?;
    println!("records_trained   {}", report.records_trained);
    println!("stopped_early     {}", report.stopped_early);
    println!("final_train_loss  {:.4}", report.final_train_loss);
    println!("final_val_loss    {:.4}", report.final_val_loss);
    println!("val_auc           {:.4}", report.val_auc);
    println!("test_auc          {}", report.auc_box().row());
    println!("trainable_params  {}", report.trainable_params);
    println!("wall              {:.2?}", report.wall);
    println!(
        "encode_throughput {:.0} rec/s/worker, train {:.0} rec/s, backpressure {}",
        report.stats.encode_throughput(),
        report.stats.train_throughput(),
        report.stats.backpressure_events,
    );
    Ok(())
}

fn cmd_encode_bench(args: &Args) -> Result<()> {
    let records: u64 = args.num("records", 500_000);
    let d: usize = args.num("d-cat", 10_000);
    let k: usize = args.num("k", 4);
    let workers: usize = args.num("workers", 4);
    let data = SyntheticConfig {
        alphabet_size: args.num("alphabet", 10_000_000),
        ..SyntheticConfig::sampled(args.num("seed", 0))
    };
    let n_numeric = data.n_numeric;
    let enc = EncoderCfg {
        cat: CatCfg::Bloom { d, k },
        num: NumCfg::None,
        bundle: BundleMethod::Concat,
        n_numeric,
        seed: args.num("seed", 0),
    };
    let stream = SyntheticStream::new(data);
    let t0 = std::time::Instant::now();
    let stats = shdc::coordinator::run_pipeline(
        stream,
        &enc,
        &CoordinatorCfg {
            batch_size: 4096,
            n_workers: workers,
            max_records: Some(records),
            ..Default::default()
        },
        |_| true,
    );
    let dt = t0.elapsed();
    let snap = stats.snapshot();
    println!(
        "encoded {} records (d={d}, k={k}, {workers} workers) in {dt:.2?} -> {:.0} rec/s wall, {:.0} rec/s encode-core",
        snap.records_encoded,
        snap.records_encoded as f64 / dt.as_secs_f64(),
        snap.encode_throughput(),
    );
    Ok(())
}

fn cmd_hw_report(_args: &Args) -> Result<()> {
    println!("run the per-table binaries: table2, table3, table4, fig11, fig12, fig13");
    Ok(())
}

fn cmd_artifacts_info() -> Result<()> {
    let rt = shdc::runtime::load_default()?;
    println!("platform: {}", rt.platform());
    for (name, a) in &rt.manifest.artifacts {
        println!(
            "  {name}: {} inputs, {} outputs, params {:?}",
            a.inputs.len(),
            a.outputs.len(),
            a.params
        );
    }
    Ok(())
}

/// Measure per-step latency of the fused train artifact (§Perf probe).
fn cmd_pjrt_bench(args: &Args) -> Result<()> {
    use shdc::runtime::HostTensor;
    let profile = args.get("profile").unwrap_or("default").to_string();
    let steps: usize = args.num("steps", 30);
    let mut rt = shdc::runtime::load_default()?;
    let name = format!("fused_train_sign_concat__{profile}");
    let spec = rt.spec(&name)?.clone();
    let (b, n) = (spec.param("b")?, spec.param("n")?);
    let (d_num, d_cat, d_total) =
        (spec.param("d_num")?, spec.param("d_cat")?, spec.param("d_total")?);
    let mut rng = shdc::util::rng::Rng::new(1);
    let theta = vec![0.0f32; d_total];
    let x: Vec<f32> = (0..b * n).map(|_| rng.normal_f32()).collect();
    let phi: Vec<f32> = (0..d_num * n).map(|_| rng.normal_f32()).collect();
    let phic: Vec<f32> = (0..b * d_cat)
        .map(|_| if rng.bernoulli(0.01) { 1.0 } else { 0.0 })
        .collect();
    let y: Vec<f32> = (0..b).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
    let inputs = vec![
        HostTensor::f32(theta, &[d_total]),
        HostTensor::f32(x, &[b, n]),
        HostTensor::f32(phi, &[d_num, n]),
        HostTensor::f32(phic, &[b, d_cat]),
        HostTensor::f32(y, &[b]),
        HostTensor::scalar_f32(0.1),
    ];
    rt.execute(&name, &inputs)?; // compile + warm
    let mut samples = Vec::new();
    for _ in 0..steps {
        let t0 = std::time::Instant::now();
        rt.execute(&name, &inputs)?;
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    println!(
        "{name}: median {:.2} ms/step  p10 {:.2}  p90 {:.2}  ({} steps, b={b})",
        shdc::util::stats::median(&samples),
        shdc::util::stats::percentile(&samples, 10.0),
        shdc::util::stats::percentile(&samples, 90.0),
        steps
    );
    println!("  -> {:.0} records/s through the train step", b as f64 * 1e3 / shdc::util::stats::median(&samples));
    Ok(())
}
