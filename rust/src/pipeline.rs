//! End-to-end training pipeline (paper Fig. 6): stream → encode →
//! logistic-regression SGD with periodic validation, early stopping, and
//! chunked AUC evaluation.
//!
//! Two interchangeable trainer backends:
//!
//! * [`TrainBackend::RustSgd`] — in-process sparse/dense SGD
//!   (`model::LogisticModel`). The sparse path is the paper's
//!   multiplication-free update; this backend handles any encoder
//!   configuration and any dimension.
//! * [`TrainBackend::PjrtFused`] — the production three-layer path: the
//!   rust coordinator computes the *categorical* (Bloom) embedding and
//!   feeds raw numerics + scattered categorical bits to the AOT-compiled
//!   `fused_train_sign_concat` artifact (Pallas sign-projection + concat
//!   + SGD step in one XLA module). Shapes are pinned by the artifact
//!   profile.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{
    run_pipeline, CatCfg, CoordinatorCfg, EncoderCfg, NumCfg, PipelineStats, StatsSnapshot,
};
use crate::data::{Record, RecordStream, SyntheticStream};
use crate::data::synthetic::SyntheticConfig;
use crate::encoding::{BundleMethod, DenseProjection, Encoding, ProjectionMode};
use crate::model::{auc, EarlyStopper, LogisticModel};
use crate::runtime::{HostTensor, Runtime};
use crate::util::rng::Rng;
use crate::util::stats::BoxStats;

#[derive(Clone, Debug)]
pub enum TrainBackend {
    RustSgd,
    /// Use the fused PJRT artifact at the given shape profile
    /// ("small" | "default"); requires `cat` = Bloom-ish sparse encoder
    /// with d_cat equal to the profile's, and ignores `num` (the
    /// artifact computes the sign-projection on device).
    PjrtFused { profile: String },
}

#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub encoder: EncoderCfg,
    pub backend: TrainBackend,
    pub lr: f32,
    pub batch_size: usize,
    pub n_workers: usize,
    /// Training record budget (early stopping may end sooner).
    pub train_records: u64,
    /// Held-out validation / test set sizes (materialized up front from
    /// independent seeds).
    pub val_records: usize,
    pub test_records: usize,
    /// Validate every this many training records (paper: 300k).
    pub validate_every: u64,
    /// Early-stop patience in validation rounds (paper: 3).
    pub patience: usize,
    /// AUC is reported over non-overlapping chunks of this many test
    /// records (paper: 100k).
    pub auc_chunk: usize,
    pub seed: u64,
}

impl TrainCfg {
    pub fn quick_test(seed: u64) -> TrainCfg {
        TrainCfg {
            encoder: EncoderCfg {
                cat: CatCfg::Bloom { d: 512, k: 4 },
                num: NumCfg::DenseSign { d: 256 },
                bundle: BundleMethod::Concat,
                n_numeric: 13,
                seed,
            },
            backend: TrainBackend::RustSgd,
            lr: 0.5,
            batch_size: 64,
            n_workers: 2,
            train_records: 20_000,
            val_records: 2_000,
            test_records: 4_000,
            validate_every: 5_000,
            patience: 3,
            auc_chunk: 1_000,
            seed,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    /// AUC per non-overlapping test chunk (the paper's box-plot data).
    pub test_auc_chunks: Vec<f64>,
    pub val_auc: f64,
    pub final_train_loss: f64,
    pub final_val_loss: f64,
    /// train-vs-validation loss gap (Fig. 7B's overfitting axis).
    pub train_val_gap: f64,
    pub records_trained: u64,
    pub stopped_early: bool,
    pub wall: Duration,
    pub stats: StatsSnapshot,
    pub trainable_params: usize,
    pub encoder_memory_bytes: usize,
}

impl TrainReport {
    pub fn auc_box(&self) -> BoxStats {
        BoxStats::from(&self.test_auc_chunks)
    }

    pub fn median_test_auc(&self) -> f64 {
        crate::util::stats::median(&self.test_auc_chunks)
    }
}

/// Materialize a held-out set from an independently-seeded stream.
fn held_out(data_cfg: &SyntheticConfig, salt: u64, n: usize) -> Vec<Record> {
    let mut cfg = data_cfg.clone();
    cfg.stream_salt = cfg.stream_salt ^ salt; // same planted model, new sample
    let mut s = SyntheticStream::new(cfg);
    (0..n).map(|_| s.next_record().expect("synthetic stream is unbounded")).collect()
}

/// Train on the synthetic stream described by `data_cfg`.
pub fn train(cfg: &TrainCfg, data_cfg: &SyntheticConfig) -> Result<TrainReport> {
    match &cfg.backend {
        TrainBackend::RustSgd => train_rust(cfg, data_cfg),
        TrainBackend::PjrtFused { profile } => train_pjrt(cfg, data_cfg, profile),
    }
}

// ---------------------------------------------------------------------------
// RustSgd backend
// ---------------------------------------------------------------------------

fn train_rust(cfg: &TrainCfg, data_cfg: &SyntheticConfig) -> Result<TrainReport> {
    let t0 = Instant::now();
    let val = held_out(data_cfg, 0xa1b2, cfg.val_records);
    let test = held_out(data_cfg, 0x7e57, cfg.test_records);
    // The first records of the training stream itself (same salt): used
    // to measure the train-vs-validation gap on equal footing (both
    // evaluated with the *final* parameters; Fig. 7B's metric).
    let train_sample = held_out(data_cfg, 0x77a1, cfg.val_records.min(4000));

    let dim = cfg.encoder.out_dim();
    let mut model = LogisticModel::new(dim);
    let mut stopper = EarlyStopper::new(cfg.patience);
    // Separate encoder instance for evaluation (identical by determinism),
    // plus reused eval staging: repeated validation rounds borrow the
    // same encoding/label/score buffers instead of collecting a fresh
    // pair vector per round (the last drain-style opt-out of recycling).
    let mut eval_enc = cfg.encoder.build();
    let mut eval_bufs = EvalBuffers::default();

    let mut stream_cfg = data_cfg.clone();
    stream_cfg.stream_salt = stream_cfg.stream_salt ^ 0x77a1;
    let stream = SyntheticStream::new(stream_cfg);

    let mut trained = 0u64;
    let mut next_validation = cfg.validate_every;
    let mut stopped_early = false;
    let mut recent_train_losses: Vec<f64> = Vec::new();
    let mut encoder_memory = 0usize;

    let coord = CoordinatorCfg {
        batch_size: cfg.batch_size,
        n_workers: cfg.n_workers,
        max_records: Some(cfg.train_records),
        ..Default::default()
    };
    let mut train_ns_local = 0u64;
    // Residual staging reused across steps; batches are *borrowed* from
    // the coordinator, so their encoding buffers recycle back to the
    // worker pools after every step (zero steady-state allocations).
    let mut errs: Vec<f32> = Vec::new();
    let stats: Arc<PipelineStats> = run_pipeline(stream, &cfg.encoder, &coord, |batch| {
        if batch.failed {
            // Worker panicked on this batch (recovered); no encodings to
            // train on. Skipping keeps label/encoding pairing exact.
            return true;
        }
        let t_step = Instant::now();
        let loss = model.sgd_step_parts(&batch.encodings, &batch.labels, cfg.lr, &mut errs);
        train_ns_local += t_step.elapsed().as_nanos() as u64;
        recent_train_losses.push(loss);
        if recent_train_losses.len() > 50 {
            recent_train_losses.remove(0);
        }
        trained += batch.encodings.len() as u64;
        if trained >= next_validation {
            next_validation += cfg.validate_every;
            let vloss = eval_loss(&mut eval_enc, &model, &val, &mut eval_bufs);
            if stopper.observe(vloss) {
                stopped_early = true;
                return false;
            }
        }
        true
    });
    encoder_memory = encoder_memory.max(eval_enc.memory_bytes());

    // Always recompute on the final parameters: the last in-training
    // validation can be a full validation period stale. The train-side
    // loss is measured on *seen* training records with the same final
    // parameters, so the gap isolates memorization (not convergence lag).
    let final_val_loss = eval_loss(&mut eval_enc, &model, &val, &mut eval_bufs);
    let final_train_loss = eval_loss(&mut eval_enc, &model, &train_sample, &mut eval_bufs);
    let _ = crate::util::stats::mean(&recent_train_losses);

    // Chunked AUC over the test set; validation AUC over the whole val set.
    let (test_auc_chunks, _) =
        eval_auc_chunks(&mut eval_enc, &model, &test, cfg.auc_chunk, &mut eval_bufs);
    let (_, val_auc) = eval_auc_chunks(&mut eval_enc, &model, &val, usize::MAX, &mut eval_bufs);

    let mut snap = stats.snapshot();
    snap.train_ns = train_ns_local; // trainer runs in the consumer thread
    snap.records_trained = trained;

    Ok(TrainReport {
        test_auc_chunks,
        val_auc,
        final_train_loss,
        final_val_loss,
        train_val_gap: final_val_loss - final_train_loss,
        records_trained: trained,
        stopped_early,
        wall: t0.elapsed(),
        stats: snap,
        trainable_params: dim + 1,
        encoder_memory_bytes: encoder_memory,
    })
}

/// Reused evaluation staging: encodings round-trip through the eval
/// encoder's scratch pools, labels and scores reuse their spines, so
/// every validation round after the first runs allocation-free — the
/// same borrow-based scoring discipline the coordinator consumers use
/// ([`LogisticModel::loss_parts`] / [`LogisticModel::predict_batch_into`]
/// replace the owned pair-vector collects).
#[derive(Default)]
struct EvalBuffers {
    encs: Vec<Encoding>,
    labels: Vec<bool>,
    scores: Vec<f64>,
}

fn eval_loss(
    enc: &mut crate::coordinator::RecordEncoder,
    model: &LogisticModel,
    records: &[Record],
    bufs: &mut EvalBuffers,
) -> f64 {
    enc.encode_batch_into(records, &mut bufs.encs);
    bufs.labels.clear();
    bufs.labels.extend(records.iter().map(|r| r.label));
    let loss = model.loss_parts(&bufs.encs, &bufs.labels);
    enc.recycle_all(bufs.encs.drain(..));
    loss
}

fn eval_auc_chunks(
    enc: &mut crate::coordinator::RecordEncoder,
    model: &LogisticModel,
    records: &[Record],
    chunk: usize,
    bufs: &mut EvalBuffers,
) -> (Vec<f64>, f64) {
    enc.encode_batch_into(records, &mut bufs.encs);
    model.predict_batch_into(&bufs.encs, &mut bufs.scores);
    enc.recycle_all(bufs.encs.drain(..));
    bufs.labels.clear();
    bufs.labels.extend(records.iter().map(|r| r.label));
    let overall = auc(&bufs.scores, &bufs.labels);
    let mut chunks = Vec::new();
    let chunk = chunk.max(1);
    let mut i = 0;
    while i < bufs.scores.len() {
        let j = (i + chunk).min(bufs.scores.len());
        if j - i >= 50 {
            chunks.push(auc(&bufs.scores[i..j], &bufs.labels[i..j]));
        }
        i = j;
    }
    if chunks.is_empty() {
        chunks.push(overall);
    }
    (chunks, overall)
}

// ---------------------------------------------------------------------------
// PjrtFused backend
// ---------------------------------------------------------------------------

fn train_pjrt(cfg: &TrainCfg, data_cfg: &SyntheticConfig, profile: &str) -> Result<TrainReport> {
    let t0 = Instant::now();
    let mut rt = crate::runtime::load_default()?;
    let train_art = format!("fused_train_sign_concat__{profile}");
    let pred_art = format!("fused_predict_sign_concat__{profile}");
    let spec = rt.spec(&train_art)?.clone();
    let b = spec.param("b")?;
    let n = spec.param("n")?;
    let d_num = spec.param("d_num")?;
    let d_cat = spec.param("d_cat")?;
    let d_total = spec.param("d_total")?;

    // The categorical encoder must produce exactly d_cat; check now.
    let enc_dcat = match &cfg.encoder.cat {
        CatCfg::Bloom { d, .. } | CatCfg::BloomPoly { d, .. } => *d,
        other => bail!("PjrtFused requires a Bloom categorical encoder, got {other:?}"),
    };
    if enc_dcat != d_cat {
        bail!("encoder d_cat={enc_dcat} but artifact {train_art} expects {d_cat}");
    }
    if cfg.encoder.n_numeric != n {
        bail!("encoder n={} but artifact expects {n}", cfg.encoder.n_numeric);
    }

    // Projection matrix for the on-device numeric branch, generated in
    // rust and passed as an input (row-major (d_num, n), matching aot.py).
    let mut rng = Rng::new(cfg.seed ^ 0x0f1a);
    let proj = DenseProjection::new(d_num, n, ProjectionMode::Sign, &mut rng);
    let phi_mat = HostTensor::f32(proj.phi_flat().to_vec(), &[d_num, n]);

    let val = held_out(data_cfg, 0xa1b2, cfg.val_records);
    let test = held_out(data_cfg, 0x7e57, cfg.test_records);

    let mut theta = vec![0.0f32; d_total];
    let mut stopper = EarlyStopper::new(cfg.patience);
    let mut eval_enc = cfg.encoder.build();

    let mut stream_cfg = data_cfg.clone();
    stream_cfg.stream_salt = stream_cfg.stream_salt ^ 0x77a1;
    let stream = SyntheticStream::new(stream_cfg);

    // Only the categorical branch runs in workers: drop the numeric cfg.
    let worker_enc = EncoderCfg { num: NumCfg::None, ..cfg.encoder.clone() };

    let coord = CoordinatorCfg {
        batch_size: b,
        n_workers: cfg.n_workers,
        keep_records: true,
        max_records: Some(cfg.train_records),
        ..Default::default()
    };

    let mut trained = 0u64;
    let mut next_validation = cfg.validate_every;
    let mut stopped_early = false;
    let mut recent_train_losses: Vec<f64> = Vec::new();
    let mut final_val_loss = f64::NAN;
    let mut exec_err: Option<anyhow::Error> = None;

    // Reusable host buffers.
    let mut xbuf = vec![0.0f32; b * n];
    let mut cbuf = vec![0.0f32; b * d_cat];
    let mut ybuf = vec![0.0f32; b];
    let mut train_ns_local = 0u64;

    let stats = run_pipeline(stream, &worker_enc, &coord, |batch| {
        if batch.encodings.len() < b {
            return true; // drop ragged tail batch (shapes are pinned)
        }
        let records = batch.records.as_ref().expect("keep_records");
        for (i, r) in records.iter().enumerate() {
            xbuf[i * n..(i + 1) * n].copy_from_slice(&r.numeric);
            ybuf[i] = if r.label { 1.0 } else { 0.0 };
        }
        cbuf.fill(0.0);
        for (i, e) in batch.encodings.iter().enumerate() {
            e.scatter_into(&mut cbuf[i * d_cat..(i + 1) * d_cat]);
        }
        let inputs = vec![
            HostTensor::f32(theta.clone(), &[d_total]),
            HostTensor::f32(xbuf.clone(), &[b, n]),
            phi_mat.clone(),
            HostTensor::f32(cbuf.clone(), &[b, d_cat]),
            HostTensor::f32(ybuf.clone(), &[b]),
            HostTensor::scalar_f32(cfg.lr),
        ];
        let t_step = Instant::now();
        match rt.execute(&train_art, &inputs) {
            Ok(outs) => {
                train_ns_local += t_step.elapsed().as_nanos() as u64;
                theta.copy_from_slice(&outs[0].data);
                recent_train_losses.push(outs[1].scalar() as f64);
                if recent_train_losses.len() > 50 {
                    recent_train_losses.remove(0);
                }
            }
            Err(e) => {
                exec_err = Some(e);
                return false;
            }
        }
        trained += b as u64;
        if trained >= next_validation {
            next_validation += cfg.validate_every;
            match pjrt_scores(&mut rt, &pred_art, &mut eval_enc, &theta, &phi_mat, &val, b, n, d_cat, d_total) {
                Ok((scores, labels)) => {
                    let vloss = crate::model::log_loss(&scores, &labels);
                    final_val_loss = vloss;
                    if stopper.observe(vloss) {
                        stopped_early = true;
                        return false;
                    }
                }
                Err(e) => {
                    exec_err = Some(e);
                    return false;
                }
            }
        }
        true
    });
    if let Some(e) = exec_err {
        return Err(e);
    }

    let (vscores, vlabels) =
        pjrt_scores(&mut rt, &pred_art, &mut eval_enc, &theta, &phi_mat, &val, b, n, d_cat, d_total)?;
    // Always recompute on the final parameters (in-loop value is stale).
    final_val_loss = crate::model::log_loss(&vscores, &vlabels);
    let val_auc = auc(&vscores, &vlabels);
    let (tscores, tlabels) =
        pjrt_scores(&mut rt, &pred_art, &mut eval_enc, &theta, &phi_mat, &test, b, n, d_cat, d_total)?;
    let mut test_auc_chunks = Vec::new();
    let chunk = cfg.auc_chunk.max(1);
    let mut i = 0;
    while i < tscores.len() {
        let j = (i + chunk).min(tscores.len());
        if j - i >= 50 {
            test_auc_chunks.push(auc(&tscores[i..j], &tlabels[i..j]));
        }
        i = j;
    }
    if test_auc_chunks.is_empty() {
        test_auc_chunks.push(auc(&tscores, &tlabels));
    }
    let final_train_loss = crate::util::stats::mean(&recent_train_losses);

    let mut snap = stats.snapshot();
    snap.train_ns = train_ns_local; // PJRT execute time (consumer thread)
    snap.records_trained = trained;

    Ok(TrainReport {
        test_auc_chunks,
        val_auc,
        final_train_loss,
        final_val_loss,
        train_val_gap: final_val_loss - final_train_loss,
        records_trained: trained,
        stopped_early,
        wall: t0.elapsed(),
        stats: snap,
        trainable_params: d_total,
        encoder_memory_bytes: eval_enc.memory_bytes(),
    })
}

/// Score a record set through the fused predict artifact (full batches;
/// the ragged tail is scored in a padded batch and truncated).
#[allow(clippy::too_many_arguments)]
fn pjrt_scores(
    rt: &mut Runtime,
    pred_art: &str,
    enc: &mut crate::coordinator::RecordEncoder,
    theta: &[f32],
    phi_mat: &HostTensor,
    records: &[Record],
    b: usize,
    n: usize,
    d_cat: usize,
    d_total: usize,
) -> Result<(Vec<f64>, Vec<bool>)> {
    let mut scores = Vec::with_capacity(records.len());
    let mut labels = Vec::with_capacity(records.len());
    let mut xbuf = vec![0.0f32; b * n];
    let mut cbuf = vec![0.0f32; b * d_cat];
    let mut start = 0usize;
    while start < records.len() {
        let end = (start + b).min(records.len());
        let m = end - start;
        xbuf.fill(0.0);
        cbuf.fill(0.0);
        for (i, r) in records[start..end].iter().enumerate() {
            xbuf[i * n..(i + 1) * n].copy_from_slice(&r.numeric);
            let code = enc
                .encode_categorical(r)
                .ok_or_else(|| anyhow!("fused path needs a categorical encoder"))?;
            code.scatter_into(&mut cbuf[i * d_cat..(i + 1) * d_cat]);
        }
        let outs = rt.execute(
            pred_art,
            &[
                HostTensor::f32(theta.to_vec(), &[d_total]),
                HostTensor::f32(xbuf.clone(), &[b, n]),
                phi_mat.clone(),
                HostTensor::f32(cbuf.clone(), &[b, d_cat]),
            ],
        )?;
        for i in 0..m {
            scores.push(outs[0].data[i] as f64);
            labels.push(records[start + i].label);
        }
        start = end;
    }
    Ok((scores, labels))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_backend_learns_easy_planted_problem() {
        let data = SyntheticConfig {
            alphabet_size: 2_000,
            noise: 0.2,
            ..SyntheticConfig::sampled(11)
        };
        let cfg = TrainCfg::quick_test(11);
        let report = train(&cfg, &data).expect("train");
        assert!(report.records_trained > 5_000);
        assert!(
            report.median_test_auc() > 0.80,
            "median AUC {} too low; report: {report:?}",
            report.median_test_auc()
        );
        assert!(report.trainable_params == cfg.encoder.out_dim() + 1);
    }

    #[test]
    fn early_stopping_fires_on_long_budget() {
        // Converges quickly; with a huge budget the stopper must fire.
        let data = SyntheticConfig {
            alphabet_size: 500,
            noise: 0.1,
            ..SyntheticConfig::sampled(12)
        };
        let mut cfg = TrainCfg::quick_test(12);
        cfg.train_records = 2_000_000; // would take ages without stopping
        cfg.validate_every = 2_000;
        cfg.patience = 2;
        let report = train(&cfg, &data).expect("train");
        assert!(report.stopped_early, "expected early stop: {report:?}");
        assert!(report.records_trained < 2_000_000);
    }

    #[test]
    fn no_count_trains_on_categorical_alone() {
        let data = SyntheticConfig {
            alphabet_size: 1_000,
            num_weight_scale: 0.0, // numeric carries no signal
            ..SyntheticConfig::sampled(13)
        };
        let mut cfg = TrainCfg::quick_test(13);
        cfg.encoder.num = NumCfg::None;
        let report = train(&cfg, &data).expect("train");
        assert!(report.median_test_auc() > 0.75, "{}", report.median_test_auc());
    }
}
