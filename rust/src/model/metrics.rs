//! Evaluation metrics: ROC-AUC (the paper's headline metric, better
//! suited to the imbalanced CTR task than accuracy) and logistic loss.

/// Area under the ROC curve via the rank-statistic (Mann-Whitney U)
/// formulation, with average ranks for tied scores. O(n log n).
pub fn auc(scores: &[f64], labels: &[bool]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&l| l).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5; // undefined; conventional fallback
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    // Assign average ranks over tie groups (1-based ranks).
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < order.len() {
        let mut j = i;
        while j + 1 < order.len() && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let avg_rank = (i + 1 + j + 1) as f64 / 2.0;
        for &idx in &order[i..=j] {
            if labels[idx] {
                rank_sum_pos += avg_rank;
            }
        }
        i = j + 1;
    }
    let u = rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// Mean negative log-likelihood for probabilities in (0,1).
pub fn log_loss(probs: &[f64], labels: &[bool]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    if probs.is_empty() {
        return 0.0;
    }
    let eps = 1e-12;
    let s: f64 = probs
        .iter()
        .zip(labels)
        .map(|(&p, &y)| {
            let p = p.clamp(eps, 1.0 - eps);
            if y {
                -p.ln()
            } else {
                -(1.0 - p).ln()
            }
        })
        .sum();
    s / probs.len() as f64
}

/// Classification accuracy at threshold 0.5 (reported alongside AUC).
pub fn accuracy(probs: &[f64], labels: &[bool]) -> f64 {
    if probs.is_empty() {
        return 0.0;
    }
    let correct = probs
        .iter()
        .zip(labels)
        .filter(|(&p, &y)| (p >= 0.5) == y)
        .count();
    correct as f64 / probs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// O(n^2) reference AUC: P(score_pos > score_neg) + 0.5 P(tie).
    fn auc_naive(scores: &[f64], labels: &[bool]) -> f64 {
        let mut wins = 0.0;
        let mut pairs = 0.0;
        for i in 0..scores.len() {
            if !labels[i] {
                continue;
            }
            for j in 0..scores.len() {
                if labels[j] {
                    continue;
                }
                pairs += 1.0;
                if scores[i] > scores[j] {
                    wins += 1.0;
                } else if scores[i] == scores[j] {
                    wins += 0.5;
                }
            }
        }
        wins / pairs
    }

    #[test]
    fn perfect_separation_is_one() {
        let scores = [0.9, 0.8, 0.2, 0.1];
        let labels = [true, true, false, false];
        assert_eq!(auc(&scores, &labels), 1.0);
        let flipped = [false, false, true, true];
        assert_eq!(auc(&scores, &flipped), 0.0);
    }

    #[test]
    fn random_scores_near_half() {
        let mut rng = Rng::new(1);
        let scores: Vec<f64> = (0..20_000).map(|_| rng.next_f64()).collect();
        let labels: Vec<bool> = (0..20_000).map(|_| rng.bernoulli(0.3)).collect();
        let a = auc(&scores, &labels);
        assert!((a - 0.5).abs() < 0.02, "auc={a}");
    }

    #[test]
    fn matches_naive_reference_with_ties() {
        let mut rng = Rng::new(2);
        for trial in 0..20 {
            let n = 50 + trial * 7;
            // Quantized scores force ties.
            let scores: Vec<f64> = (0..n).map(|_| (rng.next_f64() * 8.0).floor() / 8.0).collect();
            let labels: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.4)).collect();
            if labels.iter().all(|&l| l) || labels.iter().all(|&l| !l) {
                continue;
            }
            let fast = auc(&scores, &labels);
            let slow = auc_naive(&scores, &labels);
            assert!((fast - slow).abs() < 1e-12, "fast={fast} slow={slow}");
        }
    }

    #[test]
    fn degenerate_labels_return_half() {
        assert_eq!(auc(&[0.1, 0.9], &[true, true]), 0.5);
        assert_eq!(auc(&[0.1, 0.9], &[false, false]), 0.5);
    }

    #[test]
    fn log_loss_basics() {
        // Perfect confident predictions -> ~0; wrong confident -> large.
        assert!(log_loss(&[1.0 - 1e-12, 1e-12], &[true, false]) < 1e-9);
        assert!(log_loss(&[0.01], &[true]) > 4.0);
        // Uniform prediction -> ln 2.
        let l = log_loss(&[0.5, 0.5], &[true, false]);
        assert!((l - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn accuracy_threshold() {
        let probs = [0.9, 0.4, 0.6, 0.1];
        let labels = [true, false, false, true];
        assert_eq!(accuracy(&probs, &labels), 0.5);
    }
}
