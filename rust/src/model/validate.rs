//! Validation-driven early stopping (paper Sec. 7.1: "Models are
//! validated every 300,000 records, and we stop training if the loss
//! fails to decrease after 3 consecutive rounds of validation").

#[derive(Clone, Debug)]
pub struct EarlyStopper {
    pub patience: usize,
    best_loss: f64,
    rounds_without_improvement: usize,
    pub rounds_seen: usize,
}

impl EarlyStopper {
    pub fn new(patience: usize) -> Self {
        EarlyStopper {
            patience,
            best_loss: f64::INFINITY,
            rounds_without_improvement: 0,
            rounds_seen: 0,
        }
    }

    /// Report one validation loss; returns true if training should stop.
    pub fn observe(&mut self, val_loss: f64) -> bool {
        self.rounds_seen += 1;
        if val_loss < self.best_loss {
            self.best_loss = val_loss;
            self.rounds_without_improvement = 0;
        } else {
            self.rounds_without_improvement += 1;
        }
        self.rounds_without_improvement >= self.patience
    }

    pub fn best(&self) -> f64 {
        self.best_loss
    }
}

/// Train/validation/test split boundaries over a fixed-length stream,
/// following the paper: first 6/7 train, remaining 1/7 split evenly
/// between validation and test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Split {
    pub train: u64,
    pub validation: u64,
    pub test: u64,
}

impl Split {
    pub fn criteo(total: u64) -> Split {
        let train = total * 6 / 7;
        let rest = total - train;
        let validation = rest / 2;
        Split { train, validation, test: rest - validation }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stops_after_patience_rounds() {
        let mut es = EarlyStopper::new(3);
        assert!(!es.observe(1.0));
        assert!(!es.observe(0.9)); // improvement resets
        assert!(!es.observe(0.95));
        assert!(!es.observe(0.95));
        assert!(es.observe(0.99)); // third consecutive non-improvement
        assert_eq!(es.best(), 0.9);
    }

    #[test]
    fn improvement_resets_counter() {
        let mut es = EarlyStopper::new(2);
        assert!(!es.observe(1.0));
        assert!(!es.observe(1.1));
        assert!(!es.observe(0.5)); // reset
        assert!(!es.observe(0.6));
        assert!(es.observe(0.7));
    }

    #[test]
    fn split_proportions() {
        let s = Split::criteo(7_000_000);
        assert_eq!(s.train, 6_000_000);
        assert_eq!(s.validation, 500_000);
        assert_eq!(s.test, 500_000);
        assert_eq!(s.train + s.validation + s.test, 7_000_000);
        // Odd totals conserve mass too.
        let s2 = Split::criteo(1_000_001);
        assert_eq!(s2.train + s2.validation + s2.test, 1_000_001);
    }
}
