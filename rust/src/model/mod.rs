//! Learning layer (paper Sec. 7.1): logistic regression over HD
//! encodings with mini-batch SGD, ROC-AUC / log-loss metrics, and
//! validation-driven early stopping.

pub mod logistic;
pub mod metrics;
pub mod validate;

pub use logistic::{sigmoid, LogisticModel};
pub use metrics::{accuracy, auc, log_loss};
pub use validate::{EarlyStopper, Split};
