//! Logistic regression over HD encodings, trained with mini-batch SGD
//! (paper Sec. 7.1).
//!
//! Two update paths, matching the paper's computational story:
//! * **dense** — the full-gradient update, mirroring the PJRT
//!   `train_step` artifact (used to cross-validate rust vs XLA numerics).
//! * **sparse** — for sparse-binary encodings only the ~k·s active
//!   coordinates receive gradient ("only a tiny fraction ≈ ks/d of the
//!   model's parameters are updated by any given training example",
//!   Sec. 7.2.2 — the paper's implicit-regularization observation).

use crate::encoding::Encoding;

#[derive(Clone, Debug)]
pub struct LogisticModel {
    pub theta: Vec<f32>,
    pub bias: f32,
}

#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable NLL contribution: log(1+e^z) - y z.
#[inline]
fn nll(z: f64, y: bool) -> f64 {
    let yf = if y { 1.0 } else { 0.0 };
    // log1p(exp(z)) with the standard stabilization.
    let softplus = if z > 30.0 {
        z
    } else if z < -30.0 {
        0.0
    } else {
        (1.0 + z.exp()).ln()
    };
    softplus - yf * z
}

impl LogisticModel {
    pub fn new(d: usize) -> Self {
        LogisticModel { theta: vec![0.0; d], bias: 0.0 }
    }

    pub fn dim(&self) -> usize {
        self.theta.len()
    }

    /// Raw score z = theta . phi(x) + bias.
    pub fn score(&self, enc: &Encoding) -> f64 {
        enc.dot_params(&self.theta) + self.bias as f64
    }

    /// P(y = 1 | x).
    pub fn predict(&self, enc: &Encoding) -> f64 {
        sigmoid(self.score(&enc))
    }

    /// Mean NLL over a batch (no update).
    pub fn loss(&self, batch: &[(Encoding, bool)]) -> f64 {
        if batch.is_empty() {
            return 0.0;
        }
        batch.iter().map(|(e, y)| nll(self.score(e), *y)).sum::<f64>() / batch.len() as f64
    }

    /// One mini-batch SGD step; returns the batch mean NLL (pre-update).
    /// Synchronous mini-batch semantics: all residuals are computed at
    /// the batch-start parameters, then applied — bit-compatible (up to
    /// f32 rounding) with the PJRT `train_step` artifact. Each example
    /// routes through the sparse or dense accumulation path by
    /// representation; the math is identical.
    pub fn sgd_step(&mut self, batch: &[(Encoding, bool)], lr: f32) -> f64 {
        if batch.is_empty() {
            return 0.0;
        }
        let scale = lr / batch.len() as f32;
        let mut loss_acc = 0.0f64;
        let mut bias_grad = 0.0f32;
        // Pass 1: residuals at the current parameters.
        let errs: Vec<f32> = batch
            .iter()
            .map(|(enc, y)| {
                let z = self.score(enc);
                loss_acc += nll(z, *y);
                let err = (if *y { 1.0 } else { 0.0 } - sigmoid(z)) as f32;
                bias_grad += err;
                err
            })
            .collect();
        // Pass 2: apply the accumulated gradient.
        for ((enc, _), err) in batch.iter().zip(errs) {
            match enc {
                Encoding::Dense(v) => {
                    debug_assert_eq!(v.len(), self.theta.len());
                    for (t, &x) in self.theta.iter_mut().zip(v) {
                        *t += scale * err * x;
                    }
                }
                Encoding::SparseBinary { indices, .. } => {
                    for &i in indices {
                        self.theta[i as usize] += scale * err;
                    }
                }
            }
        }
        self.bias += scale * bias_grad;
        loss_acc / batch.len() as f64
    }

    /// One mini-batch SGD step over parallel slices, staging residuals in
    /// a caller-reused buffer — the allocation-free twin of
    /// [`LogisticModel::sgd_step`] for consumers that borrow batches from
    /// the coordinator (encodings + labels arrive as separate slices and
    /// go back to the worker pools afterwards). Same two-pass math, same
    /// accumulation order, bit-identical updates (asserted by
    /// `step_paths_agree` below).
    pub fn sgd_step_parts(
        &mut self,
        encs: &[Encoding],
        labels: &[bool],
        lr: f32,
        errs: &mut Vec<f32>,
    ) -> f64 {
        debug_assert_eq!(encs.len(), labels.len());
        if encs.is_empty() {
            return 0.0;
        }
        let scale = lr / encs.len() as f32;
        let mut loss_acc = 0.0f64;
        let mut bias_grad = 0.0f32;
        errs.clear();
        // Pass 1: residuals at the current parameters.
        for (enc, &y) in encs.iter().zip(labels) {
            let z = self.score(enc);
            loss_acc += nll(z, y);
            let err = (if y { 1.0 } else { 0.0 } - sigmoid(z)) as f32;
            bias_grad += err;
            errs.push(err);
        }
        // Pass 2: apply the accumulated gradient.
        for (enc, &err) in encs.iter().zip(errs.iter()) {
            match enc {
                Encoding::Dense(v) => {
                    debug_assert_eq!(v.len(), self.theta.len());
                    for (t, &x) in self.theta.iter_mut().zip(v) {
                        *t += scale * err * x;
                    }
                }
                Encoding::SparseBinary { indices, .. } => {
                    for &i in indices {
                        self.theta[i as usize] += scale * err;
                    }
                }
            }
        }
        self.bias += scale * bias_grad;
        loss_acc / encs.len() as f64
    }

    /// Scores for a batch (for AUC evaluation).
    pub fn predict_batch(&self, encs: &[Encoding]) -> Vec<f64> {
        let mut out = Vec::new();
        self.predict_batch_into(encs, &mut out);
        out
    }

    /// Batch prediction into a caller-reused buffer (cleared first) —
    /// the allocation-free twin of [`LogisticModel::predict_batch`] for
    /// the serving and repeated-eval paths, where a fresh `Vec<f64>` per
    /// round is pure churn. Identical values to the allocating form.
    pub fn predict_batch_into(&self, encs: &[Encoding], out: &mut Vec<f64>) {
        out.clear();
        out.reserve(encs.len());
        out.extend(encs.iter().map(|e| self.predict(e)));
    }

    /// Mean NLL over parallel slices — the borrow-based twin of
    /// [`LogisticModel::loss`] for consumers holding encodings and
    /// labels in separate (pooled, recyclable) buffers; building the
    /// owned `(Encoding, bool)` pair vector just to evaluate would
    /// re-introduce a per-round allocation.
    pub fn loss_parts(&self, encs: &[Encoding], labels: &[bool]) -> f64 {
        debug_assert_eq!(encs.len(), labels.len());
        if encs.is_empty() {
            return 0.0;
        }
        encs.iter().zip(labels).map(|(e, &y)| nll(self.score(e), y)).sum::<f64>()
            / encs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::sparse_from_indices;
    use crate::util::rng::Rng;

    #[test]
    fn sigmoid_stable_and_correct() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(100.0) > 0.999_999);
        assert!(sigmoid(-100.0) < 1e-6);
        assert!(sigmoid(1000.0).is_finite());
        assert!(sigmoid(-1000.0).is_finite());
    }

    #[test]
    fn dense_and_sparse_updates_agree() {
        // A sparse-binary batch must produce the same model whether
        // represented sparsely or densified.
        let d = 64;
        let mut rng = Rng::new(1);
        let batch_sparse: Vec<(Encoding, bool)> = (0..16)
            .map(|_| {
                let idx: Vec<u32> = (0..8).map(|_| rng.below(d as u64) as u32).collect();
                (sparse_from_indices(idx, d), rng.bernoulli(0.5))
            })
            .collect();
        let batch_dense: Vec<(Encoding, bool)> = batch_sparse
            .iter()
            .map(|(e, y)| (Encoding::Dense(e.to_dense()), *y))
            .collect();
        let mut ms = LogisticModel::new(d);
        let mut md = LogisticModel::new(d);
        let ls = ms.sgd_step(&batch_sparse, 0.3);
        let ld = md.sgd_step(&batch_dense, 0.3);
        assert!((ls - ld).abs() < 1e-9);
        for i in 0..d {
            assert!((ms.theta[i] - md.theta[i]).abs() < 1e-5, "coord {i}");
        }
        assert!((ms.bias - md.bias).abs() < 1e-6);
    }

    #[test]
    fn step_paths_agree() {
        // sgd_step (owned pairs) and sgd_step_parts (borrowed slices +
        // reused residual buffer) must produce bit-identical models.
        let d = 48;
        let mut rng = Rng::new(7);
        let mut ma = LogisticModel::new(d);
        let mut mb = LogisticModel::new(d);
        let mut errs = Vec::new();
        for round in 0..5 {
            let batch: Vec<(Encoding, bool)> = (0..12)
                .map(|_| {
                    if rng.bernoulli(0.5) {
                        let idx: Vec<u32> =
                            (0..6).map(|_| rng.below(d as u64) as u32).collect();
                        (sparse_from_indices(idx, d), rng.bernoulli(0.4))
                    } else {
                        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                        (Encoding::Dense(x), rng.bernoulli(0.4))
                    }
                })
                .collect();
            let encs: Vec<Encoding> = batch.iter().map(|(e, _)| e.clone()).collect();
            let labels: Vec<bool> = batch.iter().map(|(_, y)| *y).collect();
            let la = ma.sgd_step(&batch, 0.3);
            let lb = mb.sgd_step_parts(&encs, &labels, 0.3, &mut errs);
            assert_eq!(la, lb, "round {round}");
            assert_eq!(ma.theta, mb.theta, "round {round}");
            assert_eq!(ma.bias, mb.bias, "round {round}");
        }
    }

    #[test]
    fn sparse_update_touches_only_active_coords() {
        let d = 100;
        let mut m = LogisticModel::new(d);
        let batch = vec![(sparse_from_indices(vec![3, 50, 77], d), true)];
        m.sgd_step(&batch, 1.0);
        for i in 0..d as u32 {
            if [3, 50, 77].contains(&i) {
                assert!(m.theta[i as usize] != 0.0);
            } else {
                assert_eq!(m.theta[i as usize], 0.0);
            }
        }
    }

    #[test]
    fn learns_a_separable_problem() {
        let d = 32;
        let mut rng = Rng::new(2);
        let w: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut m = LogisticModel::new(d);
        let mut first_losses = Vec::new();
        let mut last_losses = Vec::new();
        for step in 0..200 {
            let batch: Vec<(Encoding, bool)> = (0..32)
                .map(|_| {
                    let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                    let y = x.iter().zip(&w).map(|(a, b)| a * b).sum::<f32>() > 0.0;
                    (Encoding::Dense(x), y)
                })
                .collect();
            let loss = m.sgd_step(&batch, 0.5);
            if step < 10 {
                first_losses.push(loss);
            }
            if step >= 190 {
                last_losses.push(loss);
            }
        }
        let f = crate::util::stats::mean(&first_losses);
        let l = crate::util::stats::mean(&last_losses);
        assert!(l < 0.5 * f, "first={f} last={l}");
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let d = 8;
        let mut rng = Rng::new(3);
        let mut m = LogisticModel::new(d);
        for t in m.theta.iter_mut() {
            *t = rng.normal_f32() * 0.2;
        }
        let batch: Vec<(Encoding, bool)> = (0..4)
            .map(|_| {
                let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                (Encoding::Dense(x), rng.bernoulli(0.5))
            })
            .collect();
        // Analytic gradient of mean NLL at theta: -(1/B) sum err_i x_i.
        let mut grad = vec![0.0f64; d];
        for (e, y) in &batch {
            let z = m.score(e);
            let err = (if *y { 1.0 } else { 0.0 }) - sigmoid(z);
            if let Encoding::Dense(v) = e {
                for (g, &x) in grad.iter_mut().zip(v) {
                    *g -= err * x as f64 / batch.len() as f64;
                }
            }
        }
        let eps = 1e-4;
        for j in 0..d {
            let mut up = m.clone();
            up.theta[j] += eps;
            let mut dn = m.clone();
            dn.theta[j] -= eps;
            let fd = (up.loss(&batch) - dn.loss(&batch)) / (2.0 * eps as f64);
            assert!((fd - grad[j]).abs() < 1e-3, "j={j} fd={fd} grad={}", grad[j]);
        }
    }

    #[test]
    fn loss_empty_batch_zero() {
        let m = LogisticModel::new(4);
        assert_eq!(m.loss(&[]), 0.0);
        let mut m2 = m.clone();
        assert_eq!(m2.sgd_step(&[], 0.1), 0.0);
        assert_eq!(m.loss_parts(&[], &[]), 0.0);
    }

    #[test]
    fn borrowing_eval_paths_match_owning_paths() {
        let d = 24;
        let mut rng = Rng::new(9);
        let mut m = LogisticModel::new(d);
        for t in m.theta.iter_mut() {
            *t = rng.normal_f32() * 0.5;
        }
        let batch: Vec<(Encoding, bool)> = (0..17)
            .map(|_| {
                let idx: Vec<u32> = (0..5).map(|_| rng.below(d as u64) as u32).collect();
                (sparse_from_indices(idx, d), rng.bernoulli(0.5))
            })
            .collect();
        let encs: Vec<Encoding> = batch.iter().map(|(e, _)| e.clone()).collect();
        let labels: Vec<bool> = batch.iter().map(|(_, y)| *y).collect();
        assert_eq!(m.loss(&batch), m.loss_parts(&encs, &labels));
        let want = m.predict_batch(&encs);
        let mut got = vec![99.0; 3]; // stale contents must be cleared
        m.predict_batch_into(&encs, &mut got);
        assert_eq!(want, got);
    }
}
