//! Table 1: dataset comparison (paper: Criteo full vs sampled).
//!
//! Our substitute streams are synthetic planted-model generators
//! (DESIGN.md §3); this report prints the paper's reference rows next to
//! the generator configurations standing in for them, plus measured
//! label-skew and alphabet-coverage statistics from an actual sample.

mod common;

use shdc::data::synthetic::SyntheticConfig;
use shdc::data::{RecordStream, SyntheticStream};
use std::collections::HashSet;

fn sample_stats(cfg: &SyntheticConfig, n: usize) -> (f64, usize) {
    let mut s = SyntheticStream::new(cfg.clone());
    let mut pos = 0usize;
    let mut seen: HashSet<u64> = HashSet::new();
    for _ in 0..n {
        let r = s.next_record().unwrap();
        if r.label {
            pos += 1;
        }
        seen.extend(r.symbols.iter());
    }
    (pos as f64 / n as f64, seen.len())
}

fn main() {
    common::header("Table 1", "dataset comparison (paper Criteo vs our synthetic stand-ins)");
    println!("\npaper reference:");
    println!("  {:<10} {:>16} {:>22} {:>14}", "dataset", "observations", "categorical alphabet", "size on disk");
    println!("  {:<10} {:>16} {:>22} {:>14}", "Full", "4.3e9", "1.9e8", "1 TB");
    println!("  {:<10} {:>16} {:>22} {:>14}", "Sampled", "4.6e7", "3.4e7", "10 GB");

    println!("\nsynthetic stand-ins (planted-model streams; unbounded observations,");
    println!("scalability depends only on (n, s, m) per paper Sec. 7):");
    let sample_n = if common::full_scale() { 500_000 } else { 50_000 };
    for (label, cfg) in [
        ("Full", SyntheticConfig::full(0)),
        ("Sampled", SyntheticConfig::sampled(0)),
    ] {
        let (pos_rate, distinct) = sample_stats(&cfg, sample_n);
        let bytes_per_record = cfg.n_numeric * 4 + cfg.s_categorical * 8 + 1;
        println!(
            "  {:<10} m={:<12} P(y=1)={:.3} (target {:.3})  distinct symbols in {} records: {}  ~{} B/record",
            label,
            cfg.alphabet_size,
            pos_rate,
            cfg.positive_rate,
            sample_n,
            distinct,
            bytes_per_record,
        );
    }
    println!("\nnote: 'Full' stand-in reproduces the 96/4 label skew of the 1TB set (Sec. 7.5).");
}
