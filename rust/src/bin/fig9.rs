//! Fig. 9: numeric-encoder comparison — dense signed RP, sparse RP
//! (top-k), SJLT at several densities p (sign-quantized), the MLP
//! baseline (via the PJRT `mlp_train_step` artifact), and No-Count.
//! Categorical branch fixed to Bloom (k=4).

mod common;

use shdc::coordinator::{CatCfg, EncoderCfg, NumCfg};
use shdc::encoding::BundleMethod;
use shdc::model::{auc, log_loss};
use shdc::runtime::{self, HostTensor};
use shdc::util::rng::Rng;

fn mk(num: NumCfg, seed: u64, d_cat: usize) -> EncoderCfg {
    EncoderCfg {
        cat: CatCfg::Bloom { d: d_cat, k: 4 },
        num,
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed,
    }
}

fn main() {
    common::header("Fig 9", "numeric encoding methods (cat = bloom, k=4, concat bundling)");
    let seed = 21;
    let (d_num, d_cat) = if common::full_scale() { (10_000, 10_000) } else { (2_048, 8_000) };

    println!();
    for (label, num) in [
        ("Dense (sign RP)", NumCfg::DenseSign { d: d_num }),
        ("Sparse (k=100)", NumCfg::SparseTopK { d: d_num, k: 100 .min(d_num / 4) }),
        ("Sparse (k=d/10)", NumCfg::SparseTopK { d: d_num, k: d_num / 10 }),
        ("SJLT (p=0.1)", NumCfg::RelaxedSjlt { d: d_num, p: 0.1, quantize: true }),
        ("SJLT (p=0.4)", NumCfg::RelaxedSjlt { d: d_num, p: 0.4, quantize: true }),
        ("SJLT (p=0.8)", NumCfg::RelaxedSjlt { d: d_num, p: 0.8, quantize: true }),
        ("SJLT structured", NumCfg::Sjlt { d: d_num, k: 4 }),
        ("No-Count", NumCfg::None),
    ] {
        let rep = common::sweep_train(mk(num, seed, d_cat), seed);
        common::print_auc_row(label, &rep);
    }

    // MLP baseline through the PJRT artifact (Sec. 7.2.3: 512x256x64x16).
    match run_mlp(seed) {
        Ok((auc_med, loss, params)) => println!(
            "  {:<28} AUC med={auc_med:.4} (val loss {loss:.4}, {params} params, PJRT mlp_train_step)",
            "MLP (PJRT artifact)"
        ),
        Err(e) => println!("  MLP (PJRT artifact): skipped — {e}"),
    }
    println!("\nshape check (paper): MLP ~ SJLT(p=0.4) best; dense RP slightly behind;");
    println!("sparse RP within ~0.005-0.007 AUC of SJLT; No-Count clearly worst.");
}

/// Train the MLP numeric-encoder baseline with the AOT artifact, using
/// the same synthetic workload as the rust sweeps (small profile shapes).
fn run_mlp(seed: u64) -> anyhow::Result<(f64, f64, usize)> {
    use shdc::data::{RecordStream, SyntheticStream};

    let mut rt = runtime::load_default()?;
    let profile = "small"; // b=32, d_cat=512 — fast enough for the report
    let train_art = format!("mlp_train_step__{profile}");
    let pred_art = format!("mlp_predict__{profile}");
    let spec = rt.spec(&train_art)?.clone();
    let b = spec.param("b")?;
    let n = spec.param("n")?;
    let d_cat = spec.param("d_cat")?;

    // Parameter shapes from the manifest (everything before x, phic, y, lr).
    let par_specs: Vec<_> = spec.inputs[..spec.inputs.len() - 4].to_vec();
    let mut rng = Rng::new(seed);
    // He init for weight matrices, zeros for biases and the head.
    let mut params: Vec<Vec<f32>> = par_specs
        .iter()
        .map(|s| {
            let scale = if s.shape.len() == 2 {
                (2.0 / s.shape[0] as f32).sqrt()
            } else {
                0.0
            };
            (0..s.elements()).map(|_| rng.normal_f32() * scale).collect()
        })
        .collect();

    let data = common::sweep_data(seed);
    let enc_cfg = mk(NumCfg::None, seed, d_cat);
    let mut enc = enc_cfg.build();
    let mut stream = SyntheticStream::new(data.clone());

    let steps = if common::full_scale() { 800 } else { 250 };
    let lr = HostTensor::scalar_f32(0.05);
    let mut xbuf = vec![0.0f32; b * n];
    let mut cbuf = vec![0.0f32; b * d_cat];
    let mut ybuf = vec![0.0f32; b];
    for _ in 0..steps {
        for i in 0..b {
            let r = stream.next_record().unwrap();
            xbuf[i * n..(i + 1) * n].copy_from_slice(&r.numeric);
            ybuf[i] = if r.label { 1.0 } else { 0.0 };
            let code = enc.encode_categorical(&r).unwrap();
            cbuf[i * d_cat..(i + 1) * d_cat].fill(0.0);
            code.scatter_into(&mut cbuf[i * d_cat..(i + 1) * d_cat]);
        }
        let mut inputs: Vec<HostTensor> = params
            .iter()
            .zip(&par_specs)
            .map(|(p, s)| HostTensor::f32(p.clone(), &s.shape))
            .collect();
        inputs.push(HostTensor::f32(xbuf.clone(), &[b, n]));
        inputs.push(HostTensor::f32(cbuf.clone(), &[b, d_cat]));
        inputs.push(HostTensor::f32(ybuf.clone(), &[b]));
        inputs.push(lr.clone());
        let outs = rt.execute(&train_art, &inputs)?;
        let n_params = params.len();
        for (p, o) in params.iter_mut().zip(&outs[..n_params]) {
            p.copy_from_slice(&o.data);
        }
    }

    // Evaluate on held-out records.
    let mut eval_stream = SyntheticStream::new({
        let mut d = data.clone();
        d.stream_salt ^= 0x7e57;
        d
    });
    let eval_n = if common::full_scale() { 200 } else { 60 };
    let mut scores = Vec::new();
    let mut labels = Vec::new();
    for _ in 0..eval_n {
        for i in 0..b {
            let r = eval_stream.next_record().unwrap();
            xbuf[i * n..(i + 1) * n].copy_from_slice(&r.numeric);
            labels.push(r.label);
            let code = enc.encode_categorical(&r).unwrap();
            cbuf[i * d_cat..(i + 1) * d_cat].fill(0.0);
            code.scatter_into(&mut cbuf[i * d_cat..(i + 1) * d_cat]);
        }
        let mut inputs: Vec<HostTensor> = params
            .iter()
            .zip(&par_specs)
            .map(|(p, s)| HostTensor::f32(p.clone(), &s.shape))
            .collect();
        inputs.push(HostTensor::f32(xbuf.clone(), &[b, n]));
        inputs.push(HostTensor::f32(cbuf.clone(), &[b, d_cat]));
        let outs = rt.execute(&pred_art, &inputs)?;
        scores.extend(outs[0].data.iter().map(|&v| v as f64));
    }
    let n_params: usize = params.iter().map(Vec::len).sum();
    Ok((auc(&scores, &labels), log_loss(&scores, &labels), n_params))
}
