//! Table 3: PIM component area/power and the crossbar→chip roll-up.

mod common;

use shdc::hw::pim::{self, CLUSTER_COMPONENTS, XBAR_COMPONENTS};

fn main() {
    common::header("Table 3", "PIM component specifications (14nm) and hierarchy roll-up");
    println!("\nper-crossbar components:");
    println!("  {:<22} {:>12} {:>12} {:>8}", "component", "area (um^2)", "power (uW)", "count");
    for c in XBAR_COMPONENTS {
        println!(
            "  {:<22} {:>12.1} {:>12.2} {:>8.3}",
            c.name, c.area_um2, c.power_uw, c.count_per_xbar
        );
    }
    println!("\nper-cluster shared components:");
    for c in CLUSTER_COMPONENTS {
        println!("  {:<22} {:>12.1} {:>12.2}", c.name, c.area_um2, c.power_uw);
    }

    let (xbar, cluster, tile, chip) = pim::hierarchy();
    println!("\nderived hierarchy (paper reference in parentheses):");
    println!(
        "  crossbar: {:>10.0} um^2 ({}), {:>8.2} mW ({})",
        xbar.area_mm2 * 1e6,
        "3502 um^2",
        xbar.power_w * 1e3,
        "1.79 mW"
    );
    println!(
        "  cluster:  {:>10.0} um^2 ({}), {:>8.1} mW ({})",
        cluster.area_mm2 * 1e6,
        "33042 um^2",
        cluster.power_w * 1e3,
        "15.9 mW"
    );
    println!(
        "  tile:     {:>10.3} mm^2 ({}), {:>8.1} mW ({})",
        tile.area_mm2, "0.264 mm^2", tile.power_w * 1e3, "127.6 mW"
    );
    println!(
        "  chip:     {:>10.1} mm^2 ({}), {:>8.1} W  ({})",
        chip.area_mm2, "136 mm^2", chip.power_w, "65 W"
    );
}
