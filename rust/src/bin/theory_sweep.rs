//! Theory validation: empirical dot-product-preservation error vs the
//! Theorem 2 (random codebook) and Theorem 3 (Bloom) bounds, swept over
//! d, k, and s. This regenerates the quantitative backbone behind the
//! paper's Sec. 4 analysis.

mod common;

use shdc::encoding::{BloomEncoder, CodebookEncoder};
use shdc::util::rng::Rng;

/// Max and mean absolute error of the (bias-corrected) similarity
/// estimator over `trials` random set pairs with overlap sweep.
fn bloom_error(d: usize, k: usize, s: usize, trials: usize, rng: &mut Rng) -> (f64, f64) {
    let mut maxe = 0.0f64;
    let mut sume = 0.0f64;
    for t in 0..trials {
        let enc = BloomEncoder::new(d, k, rng);
        let overlap = t % (s + 1);
        let base = (t * 1_000_003) as u64;
        let x: Vec<u64> = (0..s as u64).map(|i| base + i).collect();
        let y: Vec<u64> = (0..s as u64)
            .map(|i| if (i as usize) < overlap { base + i } else { base + 10_000 + i })
            .collect();
        let fx = enc.encode_set(&x);
        let fy = enc.encode_set(&y);
        // Theorem 3 estimator: phi(x).phi(y)/k - s^2 k/(2d) bias term.
        let est = fx.dot(&fy) / k as f64 - (s * s * k) as f64 / (2.0 * d as f64);
        let err = (est - overlap as f64).abs();
        maxe = maxe.max(err);
        sume += err;
    }
    (maxe, sume / trials as f64)
}

fn codebook_error(d: usize, s: usize, trials: usize, rng: &mut Rng) -> (f64, f64) {
    let mut maxe = 0.0f64;
    let mut sume = 0.0f64;
    for t in 0..trials {
        let mut enc = CodebookEncoder::new(d, rng.next_u64());
        let overlap = t % (s + 1);
        let base = (t * 1_000_003) as u64;
        let x: Vec<u64> = (0..s as u64).map(|i| base + i).collect();
        let y: Vec<u64> = (0..s as u64)
            .map(|i| if (i as usize) < overlap { base + i } else { base + 10_000 + i })
            .collect();
        let fx = enc.try_encode(&x).unwrap();
        let fy = enc.try_encode(&y).unwrap();
        let est = fx.dot(&fy) / d as f64;
        let err = (est - overlap as f64).abs();
        maxe = maxe.max(err);
        sume += err;
    }
    (maxe, sume / trials as f64)
}

fn main() {
    common::header(
        "Theory sweep",
        "dot-product preservation error vs (d, k, s): Theorems 2 and 3",
    );
    let trials = if common::full_scale() { 400 } else { 120 };
    let mut rng = Rng::new(99);
    let s = 26;

    println!("\nTheorem 2 (codebook, error ~ sqrt(s^3 log m / d) scaled 1/sqrt(d)):");
    println!("  {:>8} {:>12} {:>12} {:>18}", "d", "max err", "mean err", "mean*sqrt(d) (flat?)");
    for d in [1_000usize, 4_000, 16_000, 64_000] {
        let (maxe, meane) = codebook_error(d, s, trials, &mut rng);
        println!(
            "  {:>8} {:>12.3} {:>12.3} {:>18.2}",
            d,
            maxe,
            meane,
            meane * (d as f64).sqrt()
        );
    }

    println!("\nTheorem 3 (bloom, k=4; same 1/sqrt(d) law after bias correction):");
    println!("  {:>8} {:>12} {:>12} {:>18}", "d", "max err", "mean err", "mean*sqrt(d) (flat?)");
    for d in [1_000usize, 4_000, 16_000, 64_000] {
        let (maxe, meane) = bloom_error(d, 4, s, trials, &mut rng);
        println!(
            "  {:>8} {:>12.3} {:>12.3} {:>18.2}",
            d,
            maxe,
            meane,
            meane * (d as f64).sqrt()
        );
    }

    println!("\nTheorem 3, error vs k at d = 16,000 (bigger k -> bigger s^2k/2d bias, more collisions):");
    println!("  {:>8} {:>12} {:>12}", "k", "max err", "mean err");
    for k in [1usize, 2, 4, 8, 16, 32] {
        let (maxe, meane) = bloom_error(16_000, k, s, trials, &mut rng);
        println!("  {:>8} {:>12.3} {:>12.3}", k, maxe, meane);
    }

    println!("\nTheorem 3, error vs s at d = 16,000, k = 4:");
    println!("  {:>8} {:>12} {:>12}", "s", "max err", "mean err");
    for s in [5usize, 13, 26, 52, 104] {
        let (maxe, meane) = bloom_error(16_000, 4, s, trials, &mut rng);
        println!("  {:>8} {:>12.3} {:>12.3}", s, maxe, meane);
    }
}
