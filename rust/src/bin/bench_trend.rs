//! CI bench-trend gate: compare a freshly measured `BENCH_encode.json`
//! against the previously committed snapshot and **fail** when any
//! encode median regresses beyond the tolerance.
//!
//! ```text
//! cargo run --release --bin bench_trend -- <baseline.json> <candidate.json>
//! ```
//!
//! * Benchmarks are matched by `name` across the two snapshots; names
//!   present in only one side are reported but not compared (new or
//!   retired benchmarks must not fail the gate).
//! * Tolerance defaults to 15% slower (`ratio > 1.15`) and can be
//!   overridden with `SHDC_TREND_TOL` (e.g. `0.25` for 25%).
//! * **Skips cleanly** (exit 0, with a message) when the baseline is
//!   missing, unparsable, or holds no measured results — i.e. the
//!   committed file is still the nulls-only schema placeholder from a
//!   container without a Rust toolchain.
//!
//! Wall-clock medians are host-dependent; this gate is meant for a CI
//! host comparing against a snapshot measured on the same class of
//! machine, which is why the tolerance is wide and only *regressions*
//! fail (improvements simply become the new baseline when committed).

use std::collections::BTreeMap;
use std::process::ExitCode;

use shdc::util::json::Json;

/// Extract `(name, median_ns)` pairs from a snapshot's `results` array,
/// dropping entries without a finite median.
fn medians(doc: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    if let Some(results) = doc.get("results").and_then(Json::as_arr) {
        for r in results {
            let name = r.get("name").and_then(Json::as_str);
            let median = r.get("median_ns").and_then(Json::as_f64);
            if let (Some(name), Some(m)) = (name, median) {
                if m.is_finite() && m > 0.0 {
                    out.push((name.to_string(), m));
                }
            }
        }
    }
    out
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().collect();
    if args.len() != 3 {
        eprintln!("usage: bench_trend <baseline.json> <candidate.json>");
        return ExitCode::from(2);
    }
    let tol: f64 = std::env::var("SHDC_TREND_TOL")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.15);

    // Baseline problems skip (the gate has nothing to compare against);
    // candidate problems fail (the snapshot we just generated must parse).
    let base_doc = match std::fs::read_to_string(&args[1]) {
        Ok(text) => match Json::parse(&text) {
            Ok(doc) => doc,
            Err(e) => {
                println!("bench-trend: baseline {} unparsable ({e}) — skipping", args[1]);
                return ExitCode::SUCCESS;
            }
        },
        Err(_) => {
            println!("bench-trend: no baseline at {} — skipping", args[1]);
            return ExitCode::SUCCESS;
        }
    };
    let base = medians(&base_doc);
    if base.is_empty() {
        println!(
            "bench-trend: baseline {} holds no measured results (nulls-only schema \
             placeholder) — skipping",
            args[1]
        );
        return ExitCode::SUCCESS;
    }

    let cand_text = match std::fs::read_to_string(&args[2]) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("bench-trend: cannot read candidate {}: {e}", args[2]);
            return ExitCode::from(2);
        }
    };
    let cand_doc = match Json::parse(&cand_text) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("bench-trend: candidate {} unparsable: {e}", args[2]);
            return ExitCode::from(2);
        }
    };
    let cand: BTreeMap<String, f64> = medians(&cand_doc).into_iter().collect();

    // Every benchmark — the "kernel ... active" pairs AND the encoder
    // scratch paths that route through the active kernel backend —
    // measures whichever backend the build selected, so a simd-built
    // baseline vs a scalar-built candidate (or vice versa) is not a
    // regression comparison at all. Skip the whole gate on mismatch
    // (same contract as the nulls placeholder: nothing comparable to
    // gate against).
    let backend = |doc: &Json| {
        doc.get("kernel_backend")
            .and_then(Json::as_str)
            .unwrap_or("scalar")
            .to_string()
    };
    let (base_backend, cand_backend) = (backend(&base_doc), backend(&cand_doc));
    if base_backend != cand_backend {
        println!(
            "bench-trend: kernel_backend differs (baseline {base_backend}, candidate \
             {cand_backend}) — snapshots measure different kernel builds; skipping. \
             Regenerate the committed baseline with this build's features to re-arm \
             the gate."
        );
        return ExitCode::SUCCESS;
    }

    let mut compared = 0usize;
    let mut regressions: Vec<String> = Vec::new();
    for (name, b) in &base {
        match cand.get(name) {
            Some(&c) => {
                compared += 1;
                let ratio = c / b;
                let flag = if ratio > 1.0 + tol {
                    regressions.push(format!("{name}: {b:.0} ns -> {c:.0} ns (x{ratio:.3})"));
                    "  << REGRESSION"
                } else {
                    ""
                };
                println!("  {name:<48} {b:>12.0} -> {c:>12.0} ns  x{ratio:.3}{flag}");
            }
            None => println!("  {name:<48} (retired: not in candidate)"),
        }
    }
    for name in cand.keys() {
        if !base.iter().any(|(n, _)| n == name) {
            println!("  {name:<48} (new: no baseline)");
        }
    }

    println!(
        "bench-trend: compared {compared} benchmarks at {:.0}% tolerance — {}",
        tol * 100.0,
        if regressions.is_empty() { "OK" } else { "FAIL" }
    );
    if regressions.is_empty() {
        ExitCode::SUCCESS
    } else {
        eprintln!("bench-trend: encode medians regressed beyond {:.0}%:", tol * 100.0);
        for r in &regressions {
            eprintln!("  {r}");
        }
        ExitCode::FAILURE
    }
}
