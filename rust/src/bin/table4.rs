//! Table 4: PIM allocation, utilization, encoding cycles, throughput.

mod common;

use shdc::hw::pim::{self, PimWorkload, TABLE4_PAPER};

fn main() {
    common::header("Table 4", "PIM performance details (d = 10,000)");
    println!(
        "\n{:<10} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>14}",
        "mode", "num-xbar", "cat-xbar", "num-util", "cat-util", "num-cyc", "cat-cyc", "throughput"
    );
    for (w, paper) in [PimWorkload::paper(true), PimWorkload::paper(false)]
        .into_iter()
        .zip(&TABLE4_PAPER)
    {
        let rep = pim::simulate(&w);
        println!(
            "{:<10} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>11.2} M/s   (paper {:>6.2} M/s)",
            paper.label,
            rep.numeric_xbars.map(|v| v.to_string()).unwrap_or_else(|| "-".into()),
            rep.cat_xbars,
            rep.numeric_utilization
                .map(|v| format!("{:.0}%", v * 100.0))
                .unwrap_or_else(|| "-".into()),
            format!("{:.0}%", rep.cat_utilization * 100.0),
            rep.numeric_cycles.map(|v| v.to_string()).unwrap_or_else(|| "-".into()),
            rep.cat_cycles,
            rep.throughput / 1e6,
            paper.throughput_m,
        );
    }
    println!("\n(100 ns memory cycle; 32,768 crossbars; numeric and categorical run concurrently;");
    println!(" categorical allocation auto-balanced against the numeric branch per the paper.)");
}
