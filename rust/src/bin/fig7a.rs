//! Fig. 7A: encode time per batch vs volume processed — random-codebook
//! generation vs sparse (Bloom) hashing, across encoding dimensions.
//!
//! The paper's plot shows codebook latency and memory climbing with the
//! number of batches processed (alphabet grows with volume) until RAM is
//! exhausted, while hash-based encoding stays flat. We reproduce the
//! shape with a growing-alphabet stream and a memory-budgeted codebook.

mod common;

use std::time::Instant;

use shdc::data::{Record, RecordStream, SyntheticStream};
use shdc::data::synthetic::SyntheticConfig;
use shdc::encoding::{BloomEncoder, CategoricalEncoder, CodebookEncoder};
use shdc::util::rng::Rng;

fn batches(stream: &mut SyntheticStream, n_batches: usize, batch: usize) -> Vec<Vec<Record>> {
    (0..n_batches)
        .map(|_| (0..batch).map(|_| stream.next_record().unwrap()).collect())
        .collect()
}

fn main() {
    common::header(
        "Fig 7A",
        "encode time per batch vs batches processed: codebook vs sparse hashing",
    );
    let (batch, n_batches) = if common::full_scale() { (100_000, 10) } else { (10_000, 8) };
    // Alphabet sized so the codebook keeps meeting new symbols every batch
    // (Criteo-like: alphabet scales with observation count).
    let data = SyntheticConfig {
        alphabet_size: 50_000_000,
        zipf_alpha: 1.05,
        ..SyntheticConfig::sampled(1)
    };
    // A budget that trips mid-run, reproducing the paper's OOM point
    // without actually exhausting RAM.
    let budget = if common::full_scale() { 2_000_000_000 } else { 150_000_000 };

    for d in [500usize, 2_000, 10_000] {
        let mut stream = SyntheticStream::new(data.clone());
        let data_batches = batches(&mut stream, n_batches, batch);

        let mut bloom = BloomEncoder::new(d, 4, &mut Rng::new(7));
        let mut codebook = CodebookEncoder::with_budget(d, 7, budget);
        println!("\nd = {d} (batch = {batch} records; codebook budget = {} MB)", budget / 1_000_000);
        println!(
            "  {:>6} {:>16} {:>16} {:>18} {:>14}",
            "batch", "bloom (s)", "codebook (s)", "codebook mem (MB)", "symbols seen"
        );
        let mut oom = false;
        for (i, db) in data_batches.iter().enumerate() {
            let t0 = Instant::now();
            for r in db {
                std::hint::black_box(bloom.encode(&r.symbols));
            }
            let t_bloom = t0.elapsed().as_secs_f64();

            let (t_code, mem, seen) = if oom {
                (f64::NAN, f64::NAN, codebook.symbols_seen())
            } else {
                let t0 = Instant::now();
                let mut failed = false;
                for r in db {
                    match codebook.try_encode(&r.symbols) {
                        Ok(e) => {
                            std::hint::black_box(e);
                        }
                        Err(_) => {
                            failed = true;
                            break;
                        }
                    }
                }
                let t = t0.elapsed().as_secs_f64();
                if failed {
                    oom = true;
                }
                (
                    t,
                    codebook.memory_bytes() as f64 / 1e6,
                    codebook.symbols_seen(),
                )
            };
            println!(
                "  {:>6} {:>16.4} {:>16} {:>18} {:>14}{}",
                i + 1,
                t_bloom,
                if t_code.is_nan() { "OOM".to_string() } else { format!("{t_code:.4}") },
                if mem.is_nan() { "-".to_string() } else { format!("{mem:.1}") },
                seen,
                if oom && !t_code.is_nan() { "  <-- memory budget exceeded" } else { "" },
            );
        }
        println!(
            "  bloom encoder state: {} bytes (constant; paper: 32k bits = {} bytes)",
            CategoricalEncoder::memory_bytes(&mut bloom),
            4 * 4
        );
    }
    println!("\nshape check: bloom column flat; codebook memory grows ~linearly until the budget trips.");
}
