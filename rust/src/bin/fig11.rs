//! Fig. 11: FPGA resource utilization and power per combining method.

mod common;

use shdc::hw::fpga::{self, ALVEO_U280};

fn main() {
    common::header("Fig 11", "FPGA resource utilization + power per combining method (d = 10,000)");
    println!(
        "\ndevice: Alveo U280 ({}K LUT, {}K FF, {} BRAM, {} DSP, idle ~{:.0} W)\n",
        ALVEO_U280.luts / 1000,
        ALVEO_U280.ffs / 1000,
        ALVEO_U280.brams,
        ALVEO_U280.dsps,
        ALVEO_U280.idle_watts
    );
    println!(
        "{:<10} {:>8} {:>8} {:>8} {:>8} {:>10}",
        "mode", "LUT%", "FF%", "BRAM%", "DSP%", "power (W)"
    );
    for rep in fpga::table2() {
        let u = rep.utilization;
        println!(
            "{:<10} {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}% {:>10.1}",
            rep.config.label(),
            u.luts * 100.0,
            u.ffs * 100.0,
            u.brams * 100.0,
            u.dsps * 100.0,
            rep.power_watts
        );
    }
    println!("\nshape check (paper): OR/SUM similar; SUM slightly more DSPs; Concat fewer DSPs");
    println!("but similar LUT/FF (double vector length at half parallelism); No-Count least;");
    println!("power hovers 26-31 W on a ~24 W idle floor.");
}
