//! Regenerate `BENCH_encode.json` deterministically (fixed seeds; only
//! wall-clock numbers vary with the host):
//!
//! ```text
//! cargo run --release --bin bench_snapshot
//! BENCH_MS=1000 SHDC_BENCH_RECORDS=200000 BENCH_OUT=BENCH_encode.json \
//!     cargo run --release --bin bench_snapshot
//! ```
//!
//! See also `scripts/bench_snapshot.sh`.

fn main() {
    shdc::perf::write_encode_snapshot().expect("writing BENCH_encode.json");
}
