//! Table 2: FPGA frequency, per-module cycle counts, and throughput for
//! the four combining modes, plus the Sec. 7.4.1 shift-materialization
//! baseline (`--shift` style report always included).

mod common;

use shdc::encoding::BundleMethod;
use shdc::hw::fpga::{self, FpgaConfig, TABLE2_PAPER};

fn main() {
    common::header("Table 2", "FPGA cycles + throughput per combining mode (d = 10,000)");
    println!("\n{:<10} {:>6} {:>9} {:>9} {:>9} {:>9} {:>14}  | paper M/s", "mode", "MHz", "phi(xc)", "phi(xn)", "theta.phi", "grad", "throughput");
    for (rep, paper) in fpga::table2().iter().zip(&TABLE2_PAPER) {
        println!(
            "{:<10} {:>6.0} {:>9} {:>9} {:>9} {:>9} {:>11.2} M/s  | {:>6.2}",
            rep.config.label(),
            rep.config.freq_mhz,
            rep.cycles.cat_encode,
            rep.cycles
                .num_encode
                .map(|c| c.to_string())
                .unwrap_or_else(|| "-".into()),
            rep.cycles.score,
            rep.cycles.gradient,
            rep.throughput / 1e6,
            paper.throughput_m,
        );
    }

    println!("\nSec 7.4.1 — shift-based materialization baseline:");
    let or = fpga::simulate(&FpgaConfig::paper(BundleMethod::ThresholdedSum, false));
    let concat = fpga::simulate(&FpgaConfig::paper(BundleMethod::Concat, false));
    let shift = fpga::simulate_shift_baseline(&FpgaConfig::paper(BundleMethod::ThresholdedSum, false));
    println!(
        "  shift throughput: {:.1}k inputs/s (paper ~11.2k)",
        shift.throughput / 1e3
    );
    println!(
        "  slowdown vs hash-OR: {:.0}x (paper 135x); vs hash-Concat: {:.0}x (paper 84x)",
        or.throughput / shift.throughput,
        concat.throughput / shift.throughput,
    );
}
