//! Load generator for the online serving subsystem: stands up the full
//! stack (submission queue → size-or-deadline micro-batcher →
//! work-stealing encode workers → associative-memory scoring) and
//! drives it two ways:
//!
//! 1. a **closed-loop** sweep over store precision × client concurrency
//!    (offered load self-regulates to capacity → honest in-capacity
//!    latency, no coordinated omission), then
//! 2. an **open-loop** pair at ~0.5× and ~2.5× the measured closed-loop
//!    capacity with `Shed` admission and a deadline — the only way to
//!    observe overload behavior: shed rate, expired requests, and
//!    tail-latency blowup instead of a hang, then
//! 3. a **multi-tenant** closed-loop leg: two registry models with
//!    different dimensionality, seeds and store precisions, clients
//!    alternating between them through the one shared worker pool
//!    (model-homogeneous batch cuts; per-model counters printed), then
//! 4. a **many-class** closed-loop leg: `SHDC_SERVE_CLASSES` (default
//!    1000) Zipf-skewed classes through a pure-categorical encoder —
//!    the regime where the AM class scan dominates — scored
//!    single-shard and through the sharded scan (`am_shards` > 1),
//!    with per-shard scan counters printed and reconciled.
//!
//! With `--trace-out PATH` a fifth leg runs a traced closed-loop plus a
//! traced over-capacity open-loop (stage-span sampling 1-in-4), dumps
//! every sampled trace as one JSON object per line (JSONL) to `PATH`,
//! then re-reads the file and checks each line parses and its stage
//! spans telescope to its end-to-end time.
//!
//! With `--metrics-addr ADDR` (e.g. `127.0.0.1:0`) a sixth leg stands
//! up a server with the live metrics exporter + SLO watchdog enabled,
//! scrapes `GET /metrics` over HTTP while clients are still submitting,
//! verifies every exposition line parses as `name{labels} value`,
//! scrapes again after the load drains and checks the counters moved
//! monotonically to exactly the offered totals, and fetches `/health`
//! and `/snapshot` as JSON.
//!
//! ```text
//! cargo run --release --bin serve_bench
//! SHDC_SERVE_REQUESTS=200000 SHDC_SERVE_CLIENTS=16 \
//!     cargo run --release --bin serve_bench
//! SHDC_SERVE_OPEN_REQUESTS=2000 cargo run --release --bin serve_bench
//! SHDC_SERVE_CLASSES=100000 cargo run --release --bin serve_bench
//! cargo run --release --bin serve_bench -- --trace-out traces.jsonl
//! cargo run --release --bin serve_bench -- --metrics-addr 127.0.0.1:0
//! ```

use std::time::Duration;

use shdc::am::{AmBuilder, AmStore, Precision};
use shdc::coordinator::{CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::data::{ManyClassConfig, RecordStream};
use shdc::encoding::BundleMethod;
use shdc::obs::export::{http_get, parse_exposition, ParsedSeries};
use shdc::obs::health::SloCfg;
use shdc::obs::ObsCfg;
use shdc::serve::{
    build_many_class_store, run_closed_loop, run_closed_loop_many_class,
    run_closed_loop_registry, run_open_loop, AdmissionPolicy, LoadCfg, ManyClassLoadCfg,
    ModelRegistry, OpenLoadCfg, RequestOpts, ServeCfg, Server, TenantQuota,
};
use shdc::util::env_u64;
use shdc::util::json::Json;

/// A 2-class bundled store for `enc` (content is irrelevant to
/// throughput; shape — dim, class count, precision — is what's
/// measured).
fn bundle_store(enc: &EncoderCfg, data_seed: u64) -> AmStore {
    let mut b = AmBuilder::new(enc.out_dim(), 2);
    let mut renc = enc.build();
    let mut stream = shdc::data::SyntheticStream::new(SyntheticConfig::sampled(data_seed));
    for _ in 0..512 {
        let rec = stream.next_record().unwrap();
        b.add(rec.label as usize, &renc.encode(&rec));
    }
    b.finish(true)
}

fn serve_cfg(enc: &EncoderCfg, clients: usize, precision: Precision) -> ServeCfg {
    ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 64,
            n_workers: 2,
            queue_depth: 4,
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(500),
        queue_cap: 256,
        slots: (2 * clients).max(16),
        precision,
        ..ServeCfg::new(enc.clone())
    }
}

fn main() {
    let total_requests = env_u64("SHDC_SERVE_REQUESTS", 50_000);
    let max_clients = env_u64("SHDC_SERVE_CLIENTS", 8) as usize;
    let open_requests = env_u64("SHDC_SERVE_OPEN_REQUESTS", 10_000);
    let n_classes = env_u64("SHDC_SERVE_CLASSES", 1_000) as usize;
    let mut trace_out: Option<String> = None;
    let mut metrics_addr: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--trace-out" => match args.next() {
                Some(p) => trace_out = Some(p),
                None => {
                    eprintln!("--trace-out needs a path");
                    std::process::exit(2);
                }
            },
            "--metrics-addr" => match args.next() {
                Some(addr) => metrics_addr = Some(addr),
                None => {
                    eprintln!("--metrics-addr needs a bind address (e.g. 127.0.0.1:0)");
                    std::process::exit(2);
                }
            },
            other => {
                eprintln!(
                    "unknown argument: {other} \
                     (supported: --trace-out PATH, --metrics-addr ADDR)"
                );
                std::process::exit(2);
            }
        }
    }

    let enc = EncoderCfg {
        cat: CatCfg::Bloom { d: 10_000, k: 4 },
        num: NumCfg::Sjlt { d: 10_000, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 31,
    };
    // The paper's d=20k concat shape.
    let store = bundle_store(&enc, 32);
    let data = SyntheticConfig { alphabet_size: 1_000_000, ..SyntheticConfig::sampled(33) };

    println!("== serve_bench: closed-loop synthetic load ==");
    println!(
        "   encoder bloom d=10k k=4 + sjlt d=10k k=4 (concat, d=20k); \
         {total_requests} requests per scenario"
    );
    println!(
        "   store: 2 classes — f32 {} B, int8 {} B, binary {} B",
        store.memory_bytes(Precision::F32),
        store.memory_bytes(Precision::Int8),
        store.memory_bytes(Precision::Binary),
    );

    // Capacity estimate for the open-loop phase: the concurrent
    // closed-loop f32 scenario's throughput.
    let mut capacity_rps = 0.0f64;
    for precision in Precision::ALL {
        for clients in [1usize, max_clients.max(1)] {
            let cfg = serve_cfg(&enc, clients, precision);
            let load = LoadCfg {
                clients,
                requests_per_client: (total_requests / clients as u64).max(1),
                model_cycle: Vec::new(),
                data: data.clone(),
            };
            let report = run_closed_loop(cfg, store.clone(), &load);
            println!("  {:<7} {clients:>3} client(s): {}", precision.name(), report.row());
            if precision == Precision::F32 && clients > 1 {
                capacity_rps = report.throughput_rps;
            }
        }
    }

    println!("== serve_bench: open-loop fixed-rate load (f32) ==");
    println!(
        "   admission: shed on saturation; deadline 50 ms; \
         capacity estimate {capacity_rps:.0} req/s; {open_requests} arrivals per scenario"
    );
    let opts = RequestOpts {
        admission: Some(AdmissionPolicy::Shed),
        deadline: Some(Duration::from_millis(50)),
        ..RequestOpts::default()
    };
    for factor in [0.5f64, 2.5] {
        let rate = (capacity_rps * factor).max(1_000.0);
        let cfg = serve_cfg(&enc, max_clients.max(1), Precision::F32);
        let load = OpenLoadCfg {
            rate_rps: rate,
            total_requests: open_requests,
            senders: (2 * max_clients).max(8),
            opts,
            data: data.clone(),
        };
        let report = run_open_loop(cfg, store.clone(), &load);
        println!("  {factor:>4.1}x capacity: {}", report.row());
    }

    // Two tenants with different encode dims and store precisions behind
    // one registry, served by the same worker pool: clients alternate
    // models, so the micro-batcher's model-homogeneous cuts and the
    // per-worker encoder caches are both on the hot path.
    println!("== serve_bench: multi-tenant closed-loop (f32 d=20k + int8 d=8k) ==");
    let enc_b = EncoderCfg {
        cat: CatCfg::Bloom { d: 4_096, k: 4 },
        num: NumCfg::Sjlt { d: 4_096, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 37,
    };
    let store_b = bundle_store(&enc_b, 38);
    let mut registry = ModelRegistry::new();
    let a = registry.register(
        "f32-d20k",
        enc.clone(),
        store,
        Precision::F32,
        TenantQuota::default(),
    );
    let b = registry.register(
        "int8-d8k",
        enc_b,
        store_b,
        Precision::Int8,
        TenantQuota::default(),
    );
    let clients = max_clients.max(2);
    let load = LoadCfg {
        clients,
        requests_per_client: (total_requests / clients as u64).max(1),
        model_cycle: vec![a, b],
        data: data.clone(),
    };
    let report =
        run_closed_loop_registry(serve_cfg(&enc, clients, Precision::F32), registry, &load);
    println!("  multi   {clients:>3} client(s): {}", report.row());
    println!(
        "          {} model cuts, {} encoder builds across the shared pool",
        report.serve.model_cuts, report.pipeline.encoder_builds,
    );
    for m in &report.serve.models {
        println!(
            "    model {:<9} submitted {:>7}  completed {:>7}  p50 {:>9} ns  p99 {:>9} ns",
            m.name, m.submitted, m.completed, m.latency_ns.p50, m.latency_ns.p99,
        );
    }

    // Many-class: the AM scan dominates once the class count is large,
    // so this leg uses a small pure-categorical encoder and sweeps the
    // shard count — shards=1 is the single-thread baseline, shards=4
    // the sharded scan whose results are bit-identical to it.
    println!("== serve_bench: many-class closed-loop ({n_classes} classes, Zipf skew, f32) ==");
    let enc_mc = EncoderCfg {
        cat: CatCfg::Bloom { d: 2_048, k: 4 },
        num: NumCfg::None,
        bundle: BundleMethod::Concat,
        n_numeric: 0,
        seed: 41,
    };
    let mc_data = ManyClassConfig::classes(n_classes, 42);
    let mc_clients = max_clients.max(2);
    let mc_load = ManyClassLoadCfg {
        clients: mc_clients,
        requests_per_client: (total_requests / mc_clients as u64).max(1),
        data: mc_data.clone(),
    };
    for shards in [1usize, 4] {
        let store = build_many_class_store(&enc_mc, &mc_data);
        let cfg = ServeCfg {
            am_shards: shards,
            ..serve_cfg(&enc_mc, mc_clients, Precision::F32)
        };
        let report = run_closed_loop_many_class(cfg, store, &mc_load);
        println!("  shards={shards} {mc_clients:>3} client(s): {}", report.row());
        for m in &report.serve.models {
            let scans: u64 = m.shards.iter().map(|s| s.scans).sum();
            let classes: u64 = m.shards.iter().map(|s| u64::from(s.classes)).sum();
            assert_eq!(classes as usize, n_classes, "shard partition must cover every class");
            println!(
                "    {} shard(s): {} classes, {} scans total ({} per shard-column)",
                m.shards.len(),
                classes,
                scans,
                m.completed,
            );
        }
    }

    if let Some(path) = trace_out {
        dump_traces(&path, &enc, &data, total_requests, open_requests, max_clients, capacity_rps);
    }

    if let Some(addr) = metrics_addr {
        metrics_leg(&addr, &enc, &data, max_clients.max(2), total_requests.min(20_000));
    }
}

/// Pull an unlabeled series' value out of a parsed exposition.
fn series_value(series: &[ParsedSeries], name: &str) -> f64 {
    series
        .iter()
        .find(|s| s.name == name && s.labels.is_empty())
        .unwrap_or_else(|| panic!("exposition is missing series {name}"))
        .value
}

/// The `--metrics-addr` leg: a closed-loop run against a server with
/// the metrics exporter and SLO watchdog live. Scrapes `/metrics` while
/// the clients are still submitting and validates every line of the
/// exposition parses as `name{labels} value`; scrapes again after the
/// load drains and checks the counters moved monotonically to exactly
/// the offered totals; fetches `/health` and `/snapshot` and checks
/// both parse as JSON.
fn metrics_leg(
    addr: &str,
    enc: &EncoderCfg,
    data: &SyntheticConfig,
    clients: usize,
    requests: u64,
) {
    println!("== serve_bench: live metrics exposition (--metrics-addr {addr}) ==");
    let cfg = ServeCfg {
        obs: ObsCfg { sample_every: 4, ring_cap: 4096 },
        metrics_addr: Some(addr.to_string()),
        slo: Some(SloCfg::default()),
        publish_interval: Duration::from_millis(10),
        ..serve_cfg(enc, clients, Precision::F32)
    };
    let (server, handle) = Server::new(cfg, bundle_store(enc, 32));
    let server = std::thread::spawn(move || server.run());
    let bound = handle.metrics_addr().expect("exporter bound at construction");
    let timeout = Duration::from_secs(5);
    println!("   exporter live on http://{bound}  (/metrics /health /snapshot)");

    let per_client = (requests / clients as u64).max(1);
    let mut load_threads = Vec::new();
    for _ in 0..clients {
        let h = handle.clone();
        let data = data.clone();
        load_threads.push(std::thread::spawn(move || {
            let mut stream = shdc::data::SyntheticStream::new(data);
            let mut ok = 0u64;
            for _ in 0..per_client {
                let rec = stream.next_record().expect("synthetic stream is infinite");
                if h.classify(rec).is_ok() {
                    ok += 1;
                }
            }
            ok
        }));
    }

    // Scrape #1 lands while the closed loop is still running: the
    // exposition must be valid mid-flight, not just at rest.
    let (status, body) = http_get(bound, "/metrics", timeout).expect("mid-run scrape");
    assert_eq!(status, 200, "/metrics must answer 200");
    let mid = parse_exposition(&body)
        .unwrap_or_else(|e| panic!("mid-run exposition has an invalid line: {e}"));
    let mid_completed = series_value(&mid, "shdc_serve_completed_total");
    println!(
        "   mid-run scrape: {} series, all lines parse; completed so far: {}",
        mid.len(),
        mid_completed,
    );

    let completed_by_clients: u64 = load_threads
        .into_iter()
        .map(|t| t.join().expect("client thread"))
        .sum();

    // Scrape #2 after the load drained: counters are monotone and must
    // land exactly on the offered totals (closed loop, Block admission
    // — nothing sheds, nothing expires).
    let (status, body) = http_get(bound, "/metrics", timeout).expect("end-of-run scrape");
    assert_eq!(status, 200);
    let fin = parse_exposition(&body)
        .unwrap_or_else(|e| panic!("end-of-run exposition has an invalid line: {e}"));
    let fin_completed = series_value(&fin, "shdc_serve_completed_total");
    let fin_submitted = series_value(&fin, "shdc_serve_submitted_total");
    assert!(
        fin_completed >= mid_completed,
        "completed_total moved backwards between scrapes ({mid_completed} -> {fin_completed})"
    );
    assert_eq!(
        fin_completed as u64, completed_by_clients,
        "end-of-run completed_total must equal the clients' completions"
    );
    assert_eq!(
        fin_submitted as u64,
        clients as u64 * per_client,
        "end-of-run submitted_total must equal the offered load"
    );

    let (status, health) = http_get(bound, "/health", timeout).expect("health fetch");
    assert_eq!(status, 200);
    let health = Json::parse(&health).expect("/health parses as JSON");
    let verdict = health
        .get("health")
        .and_then(|h| h.get("verdict"))
        .and_then(Json::as_str)
        .expect("health verdict")
        .to_string();
    let (status, snap) = http_get(bound, "/snapshot", timeout).expect("snapshot fetch");
    assert_eq!(status, 200);
    Json::parse(&snap).expect("/snapshot parses as JSON");
    let (status, _) = http_get(bound, "/nope", timeout).expect("404 fetch");
    assert_eq!(status, 404, "unknown paths must 404");

    handle.shutdown();
    server.join().expect("server thread");
    println!(
        "   end-of-run scrape: {} series; completed {} / submitted {}; verdict {verdict}",
        fin.len(),
        fin_completed,
        fin_submitted,
    );
    println!("   metrics leg OK: exposition valid mid-run and at rest, counters reconcile");
}

/// The `--trace-out` leg: one traced closed-loop run and one traced
/// over-capacity open-loop run (sampling 1-in-4), dumped as JSONL —
/// one compact JSON object per sampled trace — then re-read and
/// verified line by line: every line parses, every trace's stage spans
/// sum to its end-to-end time, and no trace exceeds its run's recorded
/// latency maximum.
fn dump_traces(
    path: &str,
    enc: &EncoderCfg,
    data: &SyntheticConfig,
    total_requests: u64,
    open_requests: u64,
    max_clients: usize,
    capacity_rps: f64,
) {
    println!("== serve_bench: traced runs (--trace-out {path}) ==");
    let obs = ObsCfg { sample_every: 4, ring_cap: 8192 };
    let clients = max_clients.max(1);

    let closed_cfg = ServeCfg { obs, ..serve_cfg(enc, clients, Precision::F32) };
    let load = LoadCfg {
        clients,
        requests_per_client: (total_requests.min(20_000) / clients as u64).max(1),
        model_cycle: Vec::new(),
        data: data.clone(),
    };
    let closed = run_closed_loop(closed_cfg, bundle_store(enc, 32), &load);
    let obs_snap = closed.obs.as_ref().expect("tracing was enabled");
    println!(
        "  closed traced: {}  ({} spans sampled, {} dropped)",
        closed.row(),
        obs_snap.sampled,
        obs_snap.dropped,
    );

    let open_cfg = ServeCfg { obs, ..serve_cfg(enc, clients, Precision::F32) };
    let open_load = OpenLoadCfg {
        rate_rps: (2.5 * capacity_rps).max(1_000.0),
        total_requests: open_requests,
        senders: (2 * max_clients).max(8),
        opts: RequestOpts {
            admission: Some(AdmissionPolicy::Shed),
            deadline: Some(Duration::from_millis(50)),
            ..RequestOpts::default()
        },
        data: data.clone(),
    };
    let open = run_open_loop(open_cfg, bundle_store(enc, 32), &open_load);
    println!("  open traced (2.5x capacity): {}", open.row());

    // Per-run tail check while the traces are still attached to their
    // run: completion edges are stamped before the latency read, so no
    // successful trace can exceed its run's recorded maximum.
    for (traces, max_ns, label) in [
        (&closed.traces, closed.serve.latency_ns.max, "closed"),
        (&open.traces, open.serve.latency_ns.max, "open"),
    ] {
        let worst = traces.iter().filter(|t| !t.failed).map(|t| t.end_to_end_ns()).max();
        if let Some(worst) = worst {
            assert!(
                worst <= max_ns,
                "{label}: traced end-to-end {worst} ns exceeds run max {max_ns} ns"
            );
        }
    }

    let mut out = String::new();
    let mut n_traces = 0u64;
    for t in closed.traces.iter().chain(open.traces.iter()) {
        out.push_str(&t.to_json().compact());
        out.push('\n');
        n_traces += 1;
    }
    std::fs::write(path, &out).expect("write trace file");

    let text = std::fs::read_to_string(path).expect("re-read trace file");
    let mut n_lines = 0u64;
    for line in text.lines() {
        let v = Json::parse(line).expect("every trace line parses as JSON");
        let e2e = v.get("end_to_end_ns").and_then(Json::as_f64).expect("end_to_end_ns") as u64;
        let stages = v.get("stages_ns").and_then(|s| s.as_obj()).expect("stages_ns");
        let sum: u64 = stages.values().map(|s| s.as_f64().unwrap_or(0.0) as u64).sum();
        assert!(sum <= e2e, "stage spans ({sum} ns) exceed end-to-end ({e2e} ns): {line}");
        n_lines += 1;
    }
    assert_eq!(n_lines, n_traces, "trace file line count");
    println!("  wrote {n_lines} traces to {path}; all lines parse and telescope");
}
