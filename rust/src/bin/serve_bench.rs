//! Closed-loop load generator for the online serving subsystem: stands
//! up the full stack (submission queue → size-or-deadline micro-batcher
//! → work-stealing encode workers → associative-memory scoring) and
//! drives it from closed-loop synthetic clients, sweeping store
//! precision and client concurrency.
//!
//! ```text
//! cargo run --release --bin serve_bench
//! SHDC_SERVE_REQUESTS=200000 SHDC_SERVE_CLIENTS=16 \
//!     cargo run --release --bin serve_bench
//! ```
//!
//! Closed-loop means each client submits, blocks for the response, and
//! immediately submits again — offered load self-regulates to server
//! capacity, so the reported latency distribution is honest (no
//! coordinated omission from an open-loop script outrunning the server).

use std::time::Duration;

use shdc::am::{AmBuilder, Precision};
use shdc::coordinator::{CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::encoding::BundleMethod;
use shdc::serve::{run_closed_loop, LoadCfg, ServeCfg};
use shdc::util::env_u64;

fn main() {
    let total_requests = env_u64("SHDC_SERVE_REQUESTS", 50_000);
    let max_clients = env_u64("SHDC_SERVE_CLIENTS", 8) as usize;

    let enc = EncoderCfg {
        cat: CatCfg::Bloom { d: 10_000, k: 4 },
        num: NumCfg::Sjlt { d: 10_000, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 31,
    };
    // A 2-class bundled store (content is irrelevant to throughput;
    // shape is the paper's d=20k concat).
    let store = {
        let mut b = AmBuilder::new(enc.out_dim(), 2);
        let mut renc = enc.build();
        let mut stream =
            shdc::data::SyntheticStream::new(SyntheticConfig::sampled(32));
        use shdc::data::RecordStream;
        for _ in 0..512 {
            let rec = stream.next_record().unwrap();
            b.add(rec.label as usize, &renc.encode(&rec));
        }
        b.finish(true)
    };

    println!("== serve_bench: closed-loop synthetic load ==");
    println!(
        "   encoder bloom d=10k k=4 + sjlt d=10k k=4 (concat, d=20k); \
         {total_requests} requests per scenario"
    );
    println!(
        "   store: 2 classes — f32 {} B, int8 {} B, binary {} B",
        store.memory_bytes(Precision::F32),
        store.memory_bytes(Precision::Int8),
        store.memory_bytes(Precision::Binary),
    );

    for precision in [Precision::F32, Precision::Int8, Precision::Binary] {
        for clients in [1usize, max_clients.max(1)] {
            let cfg = ServeCfg {
                coordinator: CoordinatorCfg {
                    batch_size: 64,
                    n_workers: 2,
                    queue_depth: 4,
                    ..Default::default()
                },
                max_batch_delay: Duration::from_micros(500),
                queue_cap: 256,
                slots: (2 * clients).max(16),
                precision,
                ..ServeCfg::new(enc.clone())
            };
            let load = LoadCfg {
                clients,
                requests_per_client: (total_requests / clients as u64).max(1),
                data: SyntheticConfig {
                    alphabet_size: 1_000_000,
                    ..SyntheticConfig::sampled(33)
                },
            };
            let report = run_closed_loop(cfg, store.clone(), &load);
            println!("  {:<7} {clients:>3} client(s): {}", precision.name(), report.row());
        }
    }
}
