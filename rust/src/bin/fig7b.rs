//! Fig. 7B: train-vs-validation loss gap for dense vs sparse hash
//! encodings as d_cat grows — the paper's overfitting/implicit-
//! regularization comparison (dense overfits increasingly with d_cat;
//! sparse Bloom codes barely do).

mod common;

use shdc::coordinator::{CatCfg, EncoderCfg, NumCfg};
use shdc::encoding::BundleMethod;

fn main() {
    common::header(
        "Fig 7B",
        "train-validation loss gap vs d_cat: dense hashing vs sparse (Bloom) hashing",
    );
    let d_cats: &[usize] = if common::full_scale() {
        &[500, 2_000, 10_000, 20_000]
    } else {
        &[500, 2_000, 8_000]
    };
    println!(
        "\n{:>8} {:>22} {:>22}",
        "d_cat", "sparse gap (val-train)", "dense gap (val-train)"
    );
    for &d in d_cats {
        let mk = |cat: CatCfg| EncoderCfg {
            cat,
            num: NumCfg::DenseSign { d: 2_048 },
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 42,
        };
        let sparse = common::sweep_train(mk(CatCfg::Bloom { d, k: 4 }), 42);
        let dense = common::sweep_train(mk(CatCfg::DenseHash { d, literal: false }), 42);
        println!(
            "{:>8} {:>22.4} {:>22.4}",
            d, sparse.train_val_gap, dense.train_val_gap
        );
    }
    println!("\nshape check: dense gap grows with d_cat; sparse gap stays near flat");
    println!("(paper Sec. 7.2.2: only ~ks/d of parameters update per example — dropout-like).");
}
