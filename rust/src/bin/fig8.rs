//! Fig. 8: effect of (A) the number of hash functions k and (B) the
//! categorical encoding dimension d_cat on model AUC, for the Bloom
//! encoder (B also compares the dense-hash baseline).

mod common;

use shdc::coordinator::{CatCfg, EncoderCfg, NumCfg};
use shdc::encoding::BundleMethod;

fn mk(cat: CatCfg, seed: u64) -> EncoderCfg {
    EncoderCfg {
        cat,
        // Paper: numeric branch fixed to dense random projection d=10k;
        // scaled to 2048 at sweep scale.
        num: NumCfg::DenseSign { d: if common::full_scale() { 10_000 } else { 2_048 } },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed,
    }
}

fn main() {
    common::header("Fig 8", "AUC vs number of hash functions (A) and encoding dimension (B)");

    let d_fixed = if common::full_scale() { 10_000 } else { 8_000 };
    println!("\n(A) d_cat = {d_fixed}, varying k (paper: k=4 best by a hair, all close):");
    let ks: &[usize] = if common::full_scale() { &[1, 2, 4, 20, 100] } else { &[1, 2, 4, 20] };
    for &k in ks {
        let rep = common::sweep_train(mk(CatCfg::Bloom { d: d_fixed, k }, 8), 8);
        common::print_auc_row(&format!("bloom k={k}"), &rep);
    }

    println!("\n(B) k = 4, varying d_cat (paper: AUC rises, saturates ~10k; bloom >= dense at large d):");
    let ds: &[usize] = if common::full_scale() {
        &[500, 2_000, 10_000, 20_000]
    } else {
        &[500, 2_000, 8_000]
    };
    for &d in ds {
        let bloom = common::sweep_train(mk(CatCfg::Bloom { d, k: 4 }, 9), 9);
        common::print_auc_row(&format!("bloom  d={d}"), &bloom);
        let dense = common::sweep_train(mk(CatCfg::DenseHash { d, literal: false }, 9), 9);
        common::print_auc_row(&format!("dense  d={d}"), &dense);
    }
}
