//! Shared plumbing for the per-table/figure report binaries.
//!
//! Every binary prints a self-describing header, the paper's reference
//! values where applicable, and our measured/simulated values, so the
//! outputs in EXPERIMENTS.md read as paper-vs-measured tables. Scale
//! knobs come from env vars so `cargo bench`/CI stay fast:
//! `SHDC_SCALE=full` runs paper-scale sweeps.

// Each report binary uses the subset it needs.
#![allow(dead_code)]

use shdc::coordinator::EncoderCfg;
use shdc::data::synthetic::SyntheticConfig;
use shdc::pipeline::{train, TrainBackend, TrainCfg, TrainReport};

/// true => slower, closer-to-paper-scale sweeps.
pub fn full_scale() -> bool {
    std::env::var("SHDC_SCALE").map(|v| v == "full").unwrap_or(false)
}

pub fn header(id: &str, title: &str) {
    println!("=======================================================================");
    println!("{id}: {title}");
    println!("=======================================================================");
}

/// The standard sweep workload: planted Criteo-like stream at moderate
/// alphabet, sized so a RustSgd run finishes in seconds in release mode.
pub fn sweep_data(seed: u64) -> SyntheticConfig {
    SyntheticConfig {
        alphabet_size: if full_scale() { 5_000_000 } else { 200_000 },
        noise: 0.6,
        ..SyntheticConfig::sampled(seed)
    }
}

/// Per-method learning rate (the paper tunes hyper-parameters on the
/// validation set per configuration): encoders that bundle by sum have
/// O(s)-magnitude coordinates and need a much smaller step than binary
/// sparse codes.
pub fn lr_for(encoder: &EncoderCfg) -> f32 {
    use shdc::coordinator::{CatCfg, NumCfg};
    // Sum-bundled dense categorical codes have O(s)-magnitude coords.
    if matches!(
        encoder.cat,
        CatCfg::DenseHash { .. } | CatCfg::Codebook { .. } | CatCfg::Permutation { .. }
    ) {
        return 0.005;
    }
    // Dense ±1 numeric codes put unit mass on every coordinate.
    if matches!(
        encoder.num,
        NumCfg::DenseSign { .. } | NumCfg::RelaxedSjlt { quantize: true, .. }
    ) {
        return 0.05;
    }
    // Sparse binary paths tolerate (and need) a large step.
    0.5
}

/// Train one encoder config on the sweep workload and return the report.
pub fn sweep_train(encoder: EncoderCfg, seed: u64) -> TrainReport {
    let data = sweep_data(seed);
    let (train_records, val, test) = if full_scale() {
        (600_000, 20_000, 100_000)
    } else {
        (60_000, 4_000, 20_000)
    };
    let lr = lr_for(&encoder);
    let cfg = TrainCfg {
        encoder,
        backend: TrainBackend::RustSgd,
        lr,
        batch_size: 256,
        n_workers: 4,
        train_records,
        val_records: val,
        test_records: test,
        validate_every: (train_records / 8).max(1),
        patience: 3,
        auc_chunk: test / 8,
        seed,
    };
    train(&cfg, &data).expect("training failed")
}

pub fn print_auc_row(label: &str, report: &TrainReport) {
    println!(
        "  {:<28} AUC {}  (gap {:+.4}, params {}, {} records, {:.1}s)",
        label,
        report.auc_box().row(),
        report.train_val_gap,
        report.trainable_params,
        report.records_trained,
        report.wall.as_secs_f64(),
    );
}
