//! Fig. 13: end-to-end (encode + SGD update) throughput and
//! throughput/Watt, CPU vs FPGA, per combining method. PIM is excluded
//! from learning, as in the paper (write-heavy backprop).
//!
//! The CPU bar is measured by running this crate's full training
//! pipeline (encode workers + sparse SGD) on the paper workload shape.

mod common;

use shdc::coordinator::{run_pipeline, CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::data::SyntheticStream;
use shdc::encoding::BundleMethod;
use shdc::hw::cpu::PAPER_CPU_WATTS;
use shdc::hw::fpga;
use shdc::hw::{comparison_table, PlatformRow};
use shdc::model::LogisticModel;

/// Measured end-to-end CPU throughput (records/sec) for one bundling mode.
fn cpu_train_throughput(bundle: BundleMethod, no_count: bool, records: u64) -> f64 {
    let d = 10_000;
    let cfg = EncoderCfg {
        cat: CatCfg::Bloom { d, k: 4 },
        num: if no_count {
            NumCfg::None
        } else {
            match bundle {
                // Threshold keeps OR/SUM dims compatible and sparse.
                BundleMethod::Concat => NumCfg::DenseSign { d },
                _ => NumCfg::SparseThreshold { d, t: 1.2 },
            }
        },
        bundle,
        n_numeric: 13,
        seed: 6,
    };
    let mut model = LogisticModel::new(cfg.out_dim());
    let data = SyntheticConfig { alphabet_size: 1_000_000, ..SyntheticConfig::sampled(6) };
    let stream = SyntheticStream::new(data);
    let t0 = std::time::Instant::now();
    let mut errs: Vec<f32> = Vec::new();
    run_pipeline(
        stream,
        &cfg,
        &CoordinatorCfg {
            batch_size: 256,
            n_workers: 4,
            max_records: Some(records),
            ..Default::default()
        },
        |batch| {
            if batch.failed {
                return true; // worker panicked (recovered); nothing to train on
            }
            // Borrow the batch; its buffers recycle back to the workers.
            model.sgd_step_parts(&batch.encodings, &batch.labels, 0.3, &mut errs);
            true
        },
    );
    records as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    common::header("Fig 13", "end-to-end (encode + learn) throughput: CPU vs FPGA");
    let records: u64 = if common::full_scale() { 100_000 } else { 10_000 };
    let modes = [
        ("OR", BundleMethod::ThresholdedSum, false),
        ("SUM", BundleMethod::Sum, false),
        ("Concat", BundleMethod::Concat, false),
        ("No-Count", BundleMethod::ThresholdedSum, true),
    ];
    let paper_speedups = [155.0, 115.0, 163.0, 147.0];
    for ((label, bundle, no_count), paper_x) in modes.into_iter().zip(paper_speedups) {
        println!("\n--- {label} ---");
        let cpu_tp = cpu_train_throughput(bundle, no_count, records);
        let f = fpga::simulate(&fpga::FpgaConfig::paper(bundle, no_count));
        let rows = vec![
            PlatformRow { platform: "CPU (ours)".into(), throughput: cpu_tp, watts: PAPER_CPU_WATTS },
            PlatformRow { platform: "FPGA (sim)".into(), throughput: f.throughput, watts: f.power_watts },
        ];
        print!("{}", comparison_table(&rows));
        println!("paper speedup for {label}: {paper_x:.0}x");
    }
    println!("\nnote: our rust CPU pipeline is much faster than the paper's TF+C CPU baseline,");
    println!("so measured speedups land below the paper's; the ordering of modes is preserved.");
}
