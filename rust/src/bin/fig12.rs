//! Fig. 12: encoding throughput and throughput/Watt — CPU vs FPGA vs PIM,
//! with and without the numeric branch (No-Count).
//!
//! CPU bars are *measured* on this machine with this crate's encoders;
//! FPGA/PIM bars come from the cycle models. Ratios are reported against
//! both our measured CPU and the paper's reference CPU (back-derived
//! from its published speedups) so absolute-hardware differences stay
//! visible. Our CPU wattage is assumed at the paper's measured 88 W.

mod common;

use shdc::encoding::BundleMethod;
use shdc::hw::cpu::{self, PAPER_CPU_FULL, PAPER_CPU_NOCOUNT, PAPER_CPU_WATTS};
use shdc::hw::fpga::{self, FpgaConfig};
use shdc::hw::pim::{self, PimWorkload};
use shdc::hw::{comparison_table, PlatformRow};

fn main() {
    common::header("Fig 12", "encoding throughput and throughput/Watt: CPU vs FPGA vs PIM");
    let records = if common::full_scale() { 20_000 } else { 3_000 };

    for no_count in [false, true] {
        let title = if no_count { "No-Count (categorical only)" } else { "numeric + categorical" };
        println!("\n--- {title} ---");
        let cpu_m = cpu::measure_encode(&cpu::paper_workload(no_count, 5), records, 5);
        // FPGA encode-only: bottleneck encode stage at the OR config.
        let f = fpga::simulate(&FpgaConfig::paper(BundleMethod::ThresholdedSum, no_count));
        let enc_cycles = f.cycles.cat_encode + f.cycles.num_encode.unwrap_or(0);
        let fpga_tp = f.config.freq_mhz * 1e6 / (enc_cycles as f64 * 1.12);
        let p = pim::simulate(&PimWorkload::paper(!no_count));
        let rows = vec![
            PlatformRow {
                platform: "CPU (ours)".into(),
                throughput: cpu_m.records_per_sec,
                watts: PAPER_CPU_WATTS,
            },
            PlatformRow { platform: "FPGA (sim)".into(), throughput: fpga_tp, watts: f.power_watts },
            PlatformRow { platform: "PIM (sim)".into(), throughput: p.throughput, watts: p.chip_power_w },
        ];
        print!("{}", comparison_table(&rows));
        let paper_cpu = if no_count { PAPER_CPU_NOCOUNT } else { PAPER_CPU_FULL };
        println!(
            "paper-reference ratios (paper CPU ~{:.0}/s @ {:.0} W): FPGA {:.0}x, PIM {:.0}x   (paper: {} / {})",
            paper_cpu,
            PAPER_CPU_WATTS,
            fpga_tp / paper_cpu,
            p.throughput / paper_cpu,
            if no_count { "11x" } else { "81x" },
            if no_count { "414x" } else { "1177x" },
        );
    }
}
