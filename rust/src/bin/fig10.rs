//! Fig. 10: bundling methods (concat / sum / thresholded-sum "OR") —
//! paper finds all three nearly equivalent in AUC, with OR preferred
//! computationally. Cat = Bloom (k=4), num = sparse RP (Eq. 6).

mod common;

use shdc::coordinator::{CatCfg, EncoderCfg, NumCfg};
use shdc::encoding::BundleMethod;

fn main() {
    common::header("Fig 10", "bundling methods: concat vs sum vs thresholded-sum (OR)");
    let seed = 31;
    let d = if common::full_scale() { 10_000 } else { 4_096 };
    let k_sparse = if common::full_scale() { 100 } else { 64 };
    println!("\n(cat = bloom d={d} k=4; num = sparse RP d={d} k={k_sparse})\n");
    for (label, bundle) in [
        ("Concat", BundleMethod::Concat),
        ("Sum", BundleMethod::Sum),
        ("OR (thresholded sum)", BundleMethod::ThresholdedSum),
    ] {
        let cfg = EncoderCfg {
            cat: CatCfg::Bloom { d, k: 4 },
            num: NumCfg::SparseTopK { d, k: k_sparse },
            bundle,
            n_numeric: 13,
            seed,
        };
        let rep = common::sweep_train(cfg, seed);
        common::print_auc_row(label, &rep);
    }
    println!("\nshape check (paper): all three within noise of each other;");
    println!("OR keeps the embedding binary and the dimension unchanged.");
}
