//! p-independent hash families (paper Definition 1).
//!
//! Theorem 3's analysis of the Bloom encoder requires the hash functions
//! to be drawn from a *2s-independent* family. The classical construction
//! is a degree-(p-1) polynomial over a prime field evaluated at the key:
//!
//! ```text
//! psi(a) = (c_{p-1} a^{p-1} + ... + c_1 a + c_0  mod P)  mod d
//! ```
//!
//! with i.i.d. uniform coefficients c_i in [0, P). We use the Mersenne
//! prime P = 2^61 - 1, whose modular reduction needs only shifts/adds on
//! the 128-bit product. Storage is O(p log m) as in Sec. 4.2.3.
//!
//! The paper's *practical* choice is plain seeded Murmur3 (justified via
//! the Leftover Hash Lemma / randomness extraction, Sec. 4.2.3); both
//! implement the same `IndexHash` trait so encoders can swap them, and
//! the theory-validation suite uses the polynomial family where the
//! independence assumption must actually hold.

use super::murmur3::murmur3_u64;
use crate::util::rng::Rng;

/// Mersenne prime 2^61 - 1.
pub const MERSENNE_P: u64 = (1u64 << 61) - 1;

/// Reduce a 128-bit value mod 2^61 - 1.
#[inline(always)]
fn mod_mersenne(x: u128) -> u64 {
    // x = hi * 2^61 + lo, and 2^61 ≡ 1 (mod P).
    let lo = (x as u64) & MERSENNE_P;
    let hi = (x >> 61) as u64;
    let mut s = lo + hi;
    if s >= MERSENNE_P {
        s -= MERSENNE_P;
    }
    // hi can be up to 2^67, so fold once more.
    let hi2 = s >> 61;
    if hi2 > 0 {
        s = (s & MERSENNE_P) + hi2;
        if s >= MERSENNE_P {
            s -= MERSENNE_P;
        }
    }
    s
}

#[inline(always)]
fn mul_mod(a: u64, b: u64) -> u64 {
    mod_mersenne((a as u128) * (b as u128))
}

#[inline(always)]
fn add_mod(a: u64, b: u64) -> u64 {
    let s = a + b; // both < 2^61, no overflow
    if s >= MERSENNE_P {
        s - MERSENNE_P
    } else {
        s
    }
}

/// A hash function mapping u64 symbol ids into [0, d).
pub trait IndexHash: Send + Sync {
    fn index(&self, key: u64, d: u64) -> u64;

    /// A ±1 hash derived from the same function (used by dense-hash
    /// encodings and the SJLT's sigma).
    fn sign(&self, key: u64) -> f32 {
        if self.index(key ^ 0x5bf0_3635, 2) == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

/// Seeded Murmur3: the paper's practical hash (32-bit seed each).
#[derive(Clone, Copy, Debug)]
pub struct MurmurHash {
    pub seed: u32,
}

impl MurmurHash {
    pub fn new(seed: u32) -> Self {
        MurmurHash { seed }
    }

    /// Draw k functions with independent random seeds (32k bits of state,
    /// exactly the paper's accounting).
    pub fn family(k: usize, rng: &mut Rng) -> Vec<MurmurHash> {
        (0..k).map(|_| MurmurHash::new(rng.next_u32())).collect()
    }
}

impl IndexHash for MurmurHash {
    #[inline(always)]
    fn index(&self, key: u64, d: u64) -> u64 {
        // 32-bit output is plenty: d <= ~10^6 in all experiments. Map by
        // multiply-shift to avoid modulo bias at tiny d.
        let h = murmur3_u64(key, self.seed) as u64;
        (h * d) >> 32
    }
}

/// Degree-(p-1) polynomial over GF(2^61 - 1): a p-independent family.
#[derive(Clone, Debug)]
pub struct PolyHash {
    /// coefficients c_0 .. c_{p-1}, all < P.
    coeffs: Vec<u64>,
}

impl PolyHash {
    /// Draw one function from the p-independent family.
    pub fn new(p: usize, rng: &mut Rng) -> Self {
        assert!(p >= 1);
        let coeffs = (0..p).map(|_| rng.below(MERSENNE_P)).collect();
        PolyHash { coeffs }
    }

    /// Draw k independent functions, each p-independent.
    pub fn family(k: usize, p: usize, rng: &mut Rng) -> Vec<PolyHash> {
        (0..k).map(|_| PolyHash::new(p, rng)).collect()
    }

    /// Independence degree p (number of coefficients).
    pub fn degree(&self) -> usize {
        self.coeffs.len()
    }

    /// Raw polynomial evaluation in [0, P) via Horner's rule.
    #[inline]
    pub fn eval(&self, key: u64) -> u64 {
        let x = mod_mersenne(key as u128);
        let mut acc = 0u64;
        for &c in self.coeffs.iter().rev() {
            acc = add_mod(mul_mod(acc, x), c);
        }
        acc
    }

    /// Storage in bits: p coefficients of 61 bits (Sec. 4.2.3's
    /// O(p log m) accounting).
    pub fn storage_bits(&self) -> usize {
        self.coeffs.len() * 61
    }
}

impl IndexHash for PolyHash {
    #[inline]
    fn index(&self, key: u64, d: u64) -> u64 {
        // (eval * d) / P maps near-uniformly for d << P.
        ((self.eval(key) as u128 * d as u128) >> 61) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mersenne_arithmetic() {
        assert_eq!(mod_mersenne(MERSENNE_P as u128), 0);
        assert_eq!(mod_mersenne((MERSENNE_P as u128) + 5), 5);
        assert_eq!(mul_mod(MERSENNE_P - 1, 2), MERSENNE_P - 2);
        assert_eq!(add_mod(MERSENNE_P - 1, 1), 0);
        // (P-1)^2 mod P = 1
        assert_eq!(mul_mod(MERSENNE_P - 1, MERSENNE_P - 1), 1);
    }

    #[test]
    fn poly_eval_matches_naive() {
        let mut rng = Rng::new(1);
        let h = PolyHash::new(4, &mut rng);
        // Naive O(p^2) evaluation with u128 arithmetic.
        for key in [0u64, 1, 7, 1_000_003, u64::MAX] {
            let x = (key as u128 % MERSENNE_P as u128) as u64;
            let mut want: u128 = 0;
            let mut xp: u128 = 1;
            for &c in &h.coeffs {
                want = (want + c as u128 * xp) % MERSENNE_P as u128;
                xp = (xp * x as u128) % MERSENNE_P as u128;
            }
            assert_eq!(h.eval(key), want as u64, "key={key}");
        }
    }

    #[test]
    fn index_in_range() {
        let mut rng = Rng::new(2);
        let ph = PolyHash::new(6, &mut rng);
        let mh = MurmurHash::new(rng.next_u32());
        for d in [1u64, 2, 10, 997, 10_000] {
            for key in 0..1000 {
                assert!(ph.index(key, d) < d);
                assert!(mh.index(key, d) < d);
            }
        }
    }

    #[test]
    fn pairwise_independence_empirical() {
        // For a 2-independent family, Pr[h(a)=i, h(b)=j] ~ 1/d^2. Check
        // collision rate of pairs over many function draws.
        let mut rng = Rng::new(3);
        let d = 16u64;
        let trials = 20_000;
        let mut joint = vec![0usize; (d * d) as usize];
        for _ in 0..trials {
            let h = PolyHash::new(2, &mut rng);
            let ia = h.index(11, d);
            let ib = h.index(77, d);
            joint[(ia * d + ib) as usize] += 1;
        }
        let expect = trials as f64 / (d * d) as f64;
        for &c in &joint {
            assert!(
                (c as f64 - expect).abs() < expect * 0.6 + 8.0,
                "joint cell {c} vs {expect}"
            );
        }
    }

    #[test]
    fn sign_hash_balanced() {
        let h = MurmurHash::new(77);
        let pos = (0..10_000u64).filter(|&k| h.sign(k) > 0.0).count();
        assert!((pos as f64 - 5000.0).abs() < 300.0, "pos={pos}");
    }

    #[test]
    fn murmur_family_distinct_seeds() {
        let mut rng = Rng::new(4);
        let fam = MurmurHash::family(64, &mut rng);
        let mut seeds: Vec<u32> = fam.iter().map(|h| h.seed).collect();
        seeds.sort();
        seeds.dedup();
        assert_eq!(seeds.len(), 64);
    }

    #[test]
    fn storage_accounting() {
        let mut rng = Rng::new(5);
        let h = PolyHash::new(52, &mut rng); // 2s for s=26
        assert_eq!(h.storage_bits(), 52 * 61);
    }
}
