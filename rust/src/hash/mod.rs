//! Hashing substrate (paper Sec. 2.2 / 4.2.3).
//!
//! * [`murmur3`] — the paper's practical hash (Murmur3 x86_32), with a
//!   fast fixed-width path for interned u64 symbols.
//! * [`family`]  — p-independent polynomial families over GF(2^61-1)
//!   (Definition 1), used where Theorem 3's independence assumptions
//!   must hold exactly, plus the seeded-Murmur3 family used in practice.

pub mod family;
pub mod murmur3;

pub use family::{IndexHash, MurmurHash, PolyHash, MERSENNE_P};
pub use murmur3::{murmur3_32, murmur3_u64};
