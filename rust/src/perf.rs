//! The encode perf snapshot suite — shared by `benches/encode_scaling.rs`
//! and the `bench_snapshot` binary so `BENCH_encode.json` regenerates
//! identically from either entry point.
//!
//! Everything is seeded (data seed 1, encoder seeds drawn from one Rng),
//! so the *work measured* is deterministic run-to-run; only wall-clock
//! numbers vary with the host. The snapshot compares the scratch hot
//! path against faithful re-implementations of the pre-refactor paths
//! ([`LegacySjlt`], and `BloomEncoder::encode_set`, which *is* the
//! pre-refactor allocating sort+dedup path) and reports speedups, plus
//! coordinator worker-scaling throughput — the two acceptance axes of
//! the zero-allocation/batching PR.
//!
//! The snapshot also emits **kernel-layer pairs**: each vectorizable
//! kernel (`axpy`, SJLT scatter, dense-hash bit unpack, Bloom bitset
//! sweep) is measured once through the always-compiled scalar backend
//! and once through the *active* backend — `std::simd` when built with
//! `--features simd`, scalar otherwise. The `kernel_backend` /
//! `simd_feature` fields record which pairing a given snapshot measured,
//! so scalar-vs-SIMD comparisons read directly out of
//! `BENCH_encode.json`.
//!
//! The snapshot's **serve** section runs the closed-loop load generator
//! ([`crate::serve::bench::run_closed_loop`]) against the full serving
//! stack — submission queue → micro-batcher → work-stealing encode →
//! AM scoring — once per store precision (f32, int8 and binary),
//! recording end-to-end request latency p50/p99, queue-depth
//! distribution, batch-cut mix and the overload counters
//! (shed/expired/failed), then one **open-loop** overload scenario
//! ([`crate::serve::bench::run_open_loop`]) at ~2× the measured f32
//! closed-loop capacity with `Shed` admission and a 50 ms deadline, so
//! the snapshot pins saturation behavior (shed rate, expired count)
//! next to the in-capacity latency medians, one **multi-tenant**
//! closed-loop run ([`crate::serve::bench::run_closed_loop_registry`])
//! interleaving two registry models of different dimensionality and
//! precision through the shared pool (per-model counters, `model_cuts`),
//! and the **many-class** rows
//! ([`crate::serve::bench::run_closed_loop_many_class`]): a 1k-class
//! Zipf-skewed tenant scored single-shard and through the sharded AM
//! scan, with per-shard scan stats in each report's `models[].shards`.
//! A final **windowed** run (`serve_windowed`) repeats the f32
//! closed loop with the metrics publisher and SLO watchdog enabled
//! (10 ms publish interval) and records the last window's exact
//! counter-delta rates, the end-of-run health verdict and lifecycle
//! events under the snapshot's `serve_windowed` key.
//!
//! Knobs: `BENCH_MS` (per-measurement budget, default 300),
//! `SHDC_BENCH_RECORDS` (pipeline-scaling record budget, default 60000),
//! `SHDC_BENCH_SERVE_REQUESTS` (closed-loop serve budget per precision,
//! default 20000), `BENCH_OUT` (snapshot path, default
//! `BENCH_encode.json`).

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::am::{AmBuilder, AmStore, Precision};
use crate::coordinator::{run_pipeline, CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
use crate::data::synthetic::SyntheticConfig;
use crate::data::{ManyClassConfig, Record, RecordStream, SyntheticStream};
use crate::encoding::kernels;
use crate::encoding::{
    BloomEncoder, BundleMethod, CategoricalEncoder, CodebookEncoder, DenseHashEncoder,
    DenseHashMode, DenseProjection, EncodeScratch, Encoding, NumericEncoder, PermutationEncoder,
    ProjectionMode, RelaxedSjlt, Sjlt, SparseProjection,
};
use crate::util::bench::Harness;
use crate::util::env_u64;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// The pre-refactor structured SJLT: nested per-chunk `Vec<Vec<_>>` hash
/// tables, f32 sigma, a fresh `vec![0.0; d]` per record, and the
/// chunk-by-chunk scatter loop — kept verbatim as the bench baseline so
/// the speedup reported in `BENCH_encode.json` measures the refactor,
/// not a strawman. Tables are copied from a [`Sjlt`] so both paths hash
/// identically.
pub struct LegacySjlt {
    eta: Vec<Vec<u32>>,
    sigma: Vec<Vec<f32>>,
    d: usize,
    n: usize,
}

impl LegacySjlt {
    pub fn mirror(s: &Sjlt) -> LegacySjlt {
        let k = s.k();
        let eta = (0..k)
            .map(|c| (0..s.n).map(|j| s.eta_at(c, j)).collect())
            .collect();
        let sigma = (0..k)
            .map(|c| (0..s.n).map(|j| s.sigma_at(c, j)).collect())
            .collect();
        LegacySjlt { eta, sigma, d: s.d, n: s.n }
    }

    pub fn encode_record(&self, x: &[f32]) -> Encoding {
        debug_assert_eq!(x.len(), self.n);
        let k = self.eta.len();
        let dk = self.d / k;
        let mut out = vec![0.0f32; self.d];
        for c in 0..k {
            let base = c * dk;
            let (eta, sigma) = (&self.eta[c], &self.sigma[c]);
            for j in 0..self.n {
                out[base + eta[j] as usize] += sigma[j] * x[j];
            }
        }
        Encoding::Dense(out)
    }
}

fn sample_records(n: usize) -> Vec<Record> {
    let data = SyntheticConfig { alphabet_size: 10_000_000, ..SyntheticConfig::sampled(1) };
    let mut stream = SyntheticStream::new(data);
    (0..n).map(|_| stream.next_record().unwrap()).collect()
}

/// Encode-only pipeline throughput (records/s) at a worker count, plus
/// the run's counter snapshot (steals, recycles, backpressure) —
/// exercises the work-stealing coordinator end to end.
fn pipeline_records_per_sec(
    workers: usize,
    records: u64,
) -> (f64, crate::coordinator::StatsSnapshot) {
    let data = SyntheticConfig { alphabet_size: 1_000_000, ..SyntheticConfig::sampled(3) };
    let enc = EncoderCfg {
        cat: CatCfg::Bloom { d: 10_000, k: 4 },
        num: NumCfg::Sjlt { d: 10_000, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 3,
    };
    let stream = SyntheticStream::new(data);
    let coord = CoordinatorCfg {
        batch_size: 256,
        n_workers: workers,
        max_records: Some(records),
        ..Default::default()
    };
    let t0 = Instant::now();
    let mut sink = 0usize;
    let stats = run_pipeline(stream, &enc, &coord, |b| {
        sink += b.encodings.len();
        true
    });
    let dt = t0.elapsed().as_secs_f64();
    let snap = stats.snapshot();
    assert_eq!(sink as u64, snap.records_encoded);
    (records as f64 / dt, snap)
}

fn serve_encoder() -> EncoderCfg {
    EncoderCfg {
        cat: CatCfg::Bloom { d: 10_000, k: 4 },
        num: NumCfg::Sjlt { d: 10_000, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 17,
    }
}

/// Bundle a 2-class store from a few hundred encoded records — the
/// classic AM rule. Store *content* is irrelevant to the timing; shape
/// (d, class count, precision) is what's measured.
fn serve_store(enc: &EncoderCfg) -> AmStore {
    let mut builder = AmBuilder::new(enc.out_dim(), 2);
    let mut renc = enc.build();
    for rec in sample_records(256) {
        builder.add(rec.label as usize, &renc.encode(&rec));
    }
    builder.finish(true)
}

fn serve_cfg(enc: EncoderCfg, precision: Precision) -> crate::serve::ServeCfg {
    crate::serve::ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 64,
            n_workers: 2,
            queue_depth: 4,
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(500),
        queue_cap: 256,
        slots: 64,
        precision,
        ..crate::serve::ServeCfg::new(enc)
    }
}

/// One closed-loop serve scenario at paper-shaped encode dims; returns
/// the JSON record for the snapshot's `serve` array plus the measured
/// throughput (feeds the open-loop scenario's rate derivation).
fn serve_scenario(precision: Precision, requests: u64) -> (Json, f64) {
    use crate::serve::{run_closed_loop, LoadCfg};
    let enc = serve_encoder();
    let store = serve_store(&enc);
    let clients = 8usize;
    let load = LoadCfg {
        clients,
        requests_per_client: (requests / clients as u64).max(1),
        model_cycle: Vec::new(),
        data: SyntheticConfig { alphabet_size: 1_000_000, ..SyntheticConfig::sampled(18) },
    };
    let report = run_closed_loop(serve_cfg(enc, precision), store, &load);
    println!("  serve {:<7} {}", precision.name(), report.row());
    let json = Json::obj(vec![
        ("precision", Json::str(precision.name())),
        ("clients", Json::num(clients as f64)),
        ("report", report.to_json()),
    ]);
    (json, report.throughput_rps)
}

/// The serve section of the snapshot: every store precision — f32
/// (reference), int8 (4× smaller) and binary (the 32×-smaller popcount
/// store) — under identical closed-loop load, then one open-loop
/// overload scenario at ~2× the f32 closed-loop capacity (shed
/// admission + 50 ms deadline) so the snapshot records saturation
/// behavior, one **multi-tenant** closed-loop run: two registry models
/// with different dimensionality, seeds and store precisions
/// interleaved through the one shared worker pool, pinning the cost of
/// model-homogeneous batch cuts (`model_cuts`) and the per-model
/// counter section next to the single-tenant rows — and finally the
/// **many-class** rows: a 1k-class Zipf-skewed tenant (the regime where
/// the AM class scan, not encode, dominates) scored single-shard, then
/// through the sharded scan (`am_shards` > 1, f32 and the
/// i16-accumulation int8 dot), with per-shard scan stats in the JSON.
fn serve_scenarios(requests: u64) -> Vec<Json> {
    use crate::serve::{
        build_many_class_store, run_closed_loop_many_class, run_closed_loop_registry,
        run_open_loop, AdmissionPolicy, LoadCfg, ManyClassLoadCfg, ModelRegistry, OpenLoadCfg,
        RequestOpts, TenantQuota,
    };
    let mut f32_rps = 0.0f64;
    let mut out: Vec<Json> = Vec::new();
    for p in Precision::ALL {
        let (json, rps) = serve_scenario(p, requests);
        if p == Precision::F32 {
            f32_rps = rps;
        }
        out.push(json);
    }
    let enc = serve_encoder();
    let store = serve_store(&enc);
    let rate = (2.0 * f32_rps).max(1_000.0);
    let load = OpenLoadCfg {
        rate_rps: rate,
        total_requests: requests.clamp(1, 10_000),
        senders: 16,
        opts: RequestOpts {
            admission: Some(AdmissionPolicy::Shed),
            deadline: Some(Duration::from_millis(50)),
            ..RequestOpts::default()
        },
        data: SyntheticConfig { alphabet_size: 1_000_000, ..SyntheticConfig::sampled(19) },
    };
    let report = run_open_loop(serve_cfg(enc, Precision::F32), store, &load);
    println!("  serve open    {}", report.row());
    out.push(Json::obj(vec![
        ("precision", Json::str(Precision::F32.name())),
        ("senders", Json::num(load.senders as f64)),
        ("report", report.to_json()),
    ]));

    // Multi-tenant: one f32 d=20k model and one int8 d=8k model behind
    // the same registry, clients alternating between them.
    let enc_a = serve_encoder();
    let enc_b = EncoderCfg {
        cat: CatCfg::Bloom { d: 4_096, k: 4 },
        num: NumCfg::Sjlt { d: 4_096, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 29,
    };
    let store_a = serve_store(&enc_a);
    let store_b = serve_store(&enc_b);
    let mut registry = ModelRegistry::new();
    let a = registry.register(
        "f32-d20k",
        enc_a.clone(),
        store_a,
        Precision::F32,
        TenantQuota::default(),
    );
    let b = registry.register(
        "int8-d8k",
        enc_b,
        store_b,
        Precision::Int8,
        TenantQuota::default(),
    );
    let clients = 8usize;
    let load = LoadCfg {
        clients,
        requests_per_client: (requests / clients as u64).max(1),
        model_cycle: vec![a, b],
        data: SyntheticConfig { alphabet_size: 1_000_000, ..SyntheticConfig::sampled(20) },
    };
    let report = run_closed_loop_registry(serve_cfg(enc_a, Precision::F32), registry, &load);
    println!("  serve multi×2 {}", report.row());
    out.push(Json::obj(vec![
        ("precision", Json::str("multi")),
        ("clients", Json::num(clients as f64)),
        ("report", report.to_json()),
    ]));

    // Many-class: 1k Zipf-skewed classes through a pure-categorical
    // Bloom encoder — the regime where the AM scan dominates encode.
    // One single-shard baseline row, then the sharded scan at f32 and
    // int8 (the i16-accumulation widening dot is what makes the int8
    // row competitive at this class count). Each report carries the
    // per-shard scan stats via `models[].shards`.
    let enc_mc = EncoderCfg {
        cat: CatCfg::Bloom { d: 2_048, k: 4 },
        num: NumCfg::None,
        bundle: BundleMethod::Concat,
        n_numeric: 0,
        seed: 37,
    };
    let data = ManyClassConfig::classes(1_000, 38);
    let clients = 8usize;
    let mc_requests = (requests / 2).max(clients as u64);
    let load = ManyClassLoadCfg {
        clients,
        requests_per_client: (mc_requests / clients as u64).max(1),
        data: data.clone(),
    };
    for (shards, precision) in [(1usize, Precision::F32), (8, Precision::F32), (8, Precision::Int8)]
    {
        let store = build_many_class_store(&enc_mc, &data);
        let cfg = crate::serve::ServeCfg {
            am_shards: shards,
            ..serve_cfg(enc_mc.clone(), precision)
        };
        let report = run_closed_loop_many_class(cfg, store, &load);
        println!("  serve 1k-class {:<5} shards={shards} {}", precision.name(), report.row());
        out.push(Json::obj(vec![
            ("precision", Json::str(precision.name())),
            ("scenario", Json::str("manyclass")),
            ("classes", Json::num(data.n_classes as f64)),
            ("am_shards", Json::num(shards as f64)),
            ("clients", Json::num(clients as f64)),
            ("report", report.to_json()),
        ]));
    }
    out
}

/// One traced closed-loop run (sampling 1-in-4) whose per-stage
/// breakdown lands in the snapshot's `serve_stage_breakdown` key: the
/// queue/dispatch/encode/scan split behind the latency histograms the
/// `serve` rows already carry. Tracing is off in every other scenario,
/// so those rows stay comparable across snapshot versions.
fn serve_stage_breakdown(requests: u64) -> Json {
    use crate::serve::{run_closed_loop, LoadCfg};
    let enc = serve_encoder();
    let store = serve_store(&enc);
    let clients = 8usize;
    let load = LoadCfg {
        clients,
        requests_per_client: (requests / clients as u64).max(1),
        model_cycle: Vec::new(),
        data: SyntheticConfig { alphabet_size: 1_000_000, ..SyntheticConfig::sampled(21) },
    };
    let cfg = crate::serve::ServeCfg {
        obs: crate::obs::ObsCfg { sample_every: 4, ..Default::default() },
        ..serve_cfg(enc, Precision::F32)
    };
    let report = run_closed_loop(cfg, store, &load);
    let obs = report.obs.expect("tracing was enabled");
    println!(
        "  serve traced  {}  ({} spans sampled, {} dropped)",
        report.row(),
        obs.sampled,
        obs.dropped,
    );
    obs.to_json()
}

/// One closed-loop run with the metrics publisher + SLO watchdog live
/// (no HTTP listener — the snapshot reads the handle directly): the
/// snapshot's `serve_windowed` key records the last closed window's
/// exact counter-delta rates, the watchdog's end-of-run health report,
/// and the lifecycle-event counts — the monitoring layer's numbers
/// pinned next to the point-in-time sections it derives from.
fn serve_windowed(requests: u64) -> Json {
    let enc = serve_encoder();
    let store = serve_store(&enc);
    let clients = 8usize;
    let cfg = crate::serve::ServeCfg {
        obs: crate::obs::ObsCfg { sample_every: 4, ..Default::default() },
        slo: Some(crate::obs::health::SloCfg::default()),
        publish_interval: Duration::from_millis(10),
        ..serve_cfg(enc, Precision::F32)
    };
    let (server, handle) = crate::serve::Server::new(cfg, store);
    let server = std::thread::spawn(move || server.run());
    let per_client = (requests / clients as u64).max(1);
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut stream = SyntheticStream::new(SyntheticConfig {
                    alphabet_size: 1_000_000,
                    ..SyntheticConfig::sampled(22 + c as u64)
                });
                for _ in 0..per_client {
                    let rec = stream.next_record().expect("synthetic stream is infinite");
                    let _ = h.classify(rec);
                }
            })
        })
        .collect();
    for t in threads {
        t.join().expect("client thread");
    }
    // Read the monitoring surfaces while the publisher is still live;
    // shutdown joins it afterwards.
    let rates = handle.window_rates().map(|r| r.to_json()).unwrap_or(Json::Null);
    let health = handle.health().expect("publishing was enabled");
    let events = handle.drain_events();
    println!(
        "  serve windowed: verdict {} after {} windows ({} lifecycle events)",
        health.verdict.name(),
        health.windows,
        events.len(),
    );
    handle.shutdown();
    server.join().expect("server thread");
    Json::obj(vec![
        ("publish_interval_ms", Json::num(10.0)),
        ("requests", Json::num(clients as f64 * per_client as f64)),
        ("last_window_rates", rates),
        ("health", health.to_json()),
        ("events", Json::Arr(events.iter().map(|e| e.to_json()).collect())),
    ])
}

/// Run the full encode snapshot; returns the machine-readable document
/// written to `BENCH_encode.json`.
pub fn encode_snapshot() -> Json {
    let mut h = Harness::new("encode_scaling");
    let mut rng = Rng::new(1);
    let records = sample_records(512);
    let d = 10_000;
    let mut scratch = EncodeScratch::new();
    let mut i = 0usize;

    // --- the two headline pairs: legacy vs scratch ------------------------
    let bloom = BloomEncoder::new(d, 4, &mut rng);
    h.bench("bloom d=10k k=4 legacy (alloc+sort)", || {
        i = (i + 1) % records.len();
        bloom.encode_set(&records[i].symbols)
    });
    h.note_throughput(1.0, "records");
    h.bench("bloom d=10k k=4 scratch", || {
        i = (i + 1) % records.len();
        let e = bloom.encode_set_with(&records[i].symbols, &mut scratch);
        black_box(&e);
        scratch.recycle(e);
    });
    h.note_throughput(1.0, "records");

    let sj = Sjlt::new(d, 13, 4, &mut rng);
    let sj_legacy = LegacySjlt::mirror(&sj);
    h.bench("SJLT d=10k k=4 legacy (nested tables)", || {
        i = (i + 1) % records.len();
        sj_legacy.encode_record(&records[i].numeric)
    });
    h.note_throughput(1.0, "records");
    h.bench("SJLT d=10k k=4 scratch (flat tables)", || {
        i = (i + 1) % records.len();
        let e = sj.encode_record_with(&records[i].numeric, &mut scratch);
        black_box(&e);
        scratch.recycle(e);
    });
    h.note_throughput(1.0, "records");

    // --- coverage of the remaining encoders (scratch path) ----------------
    for k in [1usize, 8] {
        let b = BloomEncoder::new(d, k, &mut rng);
        h.bench(&format!("bloom d=10k k={k} scratch"), || {
            i = (i + 1) % records.len();
            let e = b.encode_set_with(&records[i].symbols, &mut scratch);
            black_box(&e);
            scratch.recycle(e);
        });
    }

    let dh = DenseHashEncoder::new(d, DenseHashMode::Packed, &mut rng);
    h.bench("dense-hash packed d=10k scratch", || {
        i = (i + 1) % records.len();
        let e = dh.encode_set_with(&records[i].symbols, &mut scratch);
        black_box(&e);
        scratch.recycle(e);
    });
    let dh_lit = DenseHashEncoder::new(500, DenseHashMode::Literal, &mut rng);
    h.bench("dense-hash literal d=500 (paper's slow baseline)", || {
        i = (i + 1) % records.len();
        dh_lit.encode_set(&records[i].symbols)
    });

    let mut cb = CodebookEncoder::new(d, 3);
    for r in &records {
        let _ = cb.try_encode(&r.symbols);
    }
    h.bench("codebook d=10k (warm) scratch", || {
        i = (i + 1) % records.len();
        let e = cb.encode_with(&records[i].symbols, &mut scratch);
        black_box(&e);
        scratch.recycle(e);
    });

    let perm = PermutationEncoder::new(d, 16, 16, &mut rng);
    h.bench("permutation d=10k pool=16 scratch", || {
        i = (i + 1) % records.len();
        let e = perm.encode_set_with(&records[i].symbols, &mut scratch);
        black_box(&e);
        scratch.recycle(e);
    });

    let dp = DenseProjection::new(d, 13, ProjectionMode::Sign, &mut rng);
    h.bench("dense sign-RP d=10k n=13 scratch", || {
        i = (i + 1) % records.len();
        let e = dp.encode_with(&records[i].numeric, &mut scratch);
        black_box(&e);
        scratch.recycle(e);
    });
    h.note_throughput(1.0, "records");

    let sp = SparseProjection::new_topk(d, 13, 100, &mut rng);
    h.bench("sparse RP top-k d=10k k=100 scratch", || {
        i = (i + 1) % records.len();
        let e = sp.encode_with(&records[i].numeric, &mut scratch);
        black_box(&e);
        scratch.recycle(e);
    });
    let st = SparseProjection::new_threshold(d, 13, 1.0, &mut rng);
    h.bench("sparse RP threshold d=10k scratch", || {
        i = (i + 1) % records.len();
        let e = st.encode_with(&records[i].numeric, &mut scratch);
        black_box(&e);
        scratch.recycle(e);
    });

    let rsj = RelaxedSjlt::new(d, 13, 0.4, true, &mut rng);
    h.bench("SJLT relaxed d=10k p=0.4 scratch", || {
        i = (i + 1) % records.len();
        let e = rsj.encode_with(&records[i].numeric, &mut scratch);
        black_box(&e);
        scratch.recycle(e);
    });

    // --- kernel layer: scalar backend vs active backend -------------------
    // "active" is std::simd when built with --features simd, scalar
    // otherwise (see the kernel_backend field); the pair quantifies the
    // explicit-SIMD win per kernel on this host. Workloads mirror the
    // encoders' call shapes at paper dimensions.
    {
        let mut krng = Rng::new(0x6b65); // "ke"(rnel)
        // axpy: one projection column pass at d=10k.
        let col: Vec<f32> = (0..d).map(|_| krng.normal_f32()).collect();
        let mut z = vec![0.0f32; d];
        h.bench("kernel axpy d=10k scalar", || {
            kernels::scalar::axpy(&mut z, &col, 1.000_001);
            black_box(z[0])
        });
        h.bench("kernel axpy d=10k active", || {
            kernels::axpy(&mut z, &col, 1.000_001);
            black_box(z[0])
        });

        // sign_quantize: one full-record finish at d=10k.
        h.bench("kernel sign-quantize d=10k scalar", || {
            kernels::scalar::sign_quantize(&mut z);
            black_box(z[0])
        });
        h.bench("kernel sign-quantize d=10k active", || {
            kernels::sign_quantize(&mut z);
            black_box(z[0])
        });

        // SJLT scatter: one full record (k=4 chunks, n=13) at d=10k.
        let (kchunks, n) = (4usize, 13usize);
        let dk = d / kchunks;
        let eta: Vec<u32> =
            (0..kchunks * n).map(|_| krng.below(dk as u64) as u32).collect();
        let sigma: Vec<i8> = (0..kchunks * n).map(|_| krng.sign() as i8).collect();
        let x: Vec<f32> = (0..n).map(|_| krng.normal_f32()).collect();
        let mut sj_out = vec![0.0f32; d];
        h.bench("kernel sjlt-scatter d=10k k=4 scalar", || {
            for c in 0..kchunks {
                kernels::scalar::scatter_signed(
                    &x,
                    &eta[c * n..(c + 1) * n],
                    &sigma[c * n..(c + 1) * n],
                    &mut sj_out[c * dk..(c + 1) * dk],
                );
            }
            black_box(sj_out[0])
        });
        h.bench("kernel sjlt-scatter d=10k k=4 active", || {
            for c in 0..kchunks {
                kernels::scatter_signed(
                    &x,
                    &eta[c * n..(c + 1) * n],
                    &sigma[c * n..(c + 1) * n],
                    &mut sj_out[c * dk..(c + 1) * dk],
                );
            }
            black_box(sj_out[0])
        });

        // Dense-hash bit unpack: one full packed record at d=10k.
        let words: Vec<u32> = (0..d.div_ceil(32)).map(|_| krng.next_u32()).collect();
        let mut acc = vec![0.0f32; d];
        h.bench("kernel bit-unpack d=10k scalar", || {
            for (w, &word) in words.iter().enumerate() {
                let base = w * 32;
                let nn = (d - base).min(32);
                kernels::scalar::unpack_sign_bits_accumulate(word, &mut acc[base..base + nn]);
            }
            black_box(acc[0])
        });
        h.bench("kernel bit-unpack d=10k active", || {
            for (w, &word) in words.iter().enumerate() {
                let base = w * 32;
                let nn = (d - base).min(32);
                kernels::unpack_sign_bits_accumulate(word, &mut acc[base..base + nn]);
            }
            black_box(acc[0])
        });

        // Bloom bitset mark+sweep: one paper-scale record (s·k = 104
        // staged coordinates) at d=10k. The sweep clears the bitset, so
        // every iteration starts clean.
        let staged: Vec<u32> = (0..104).map(|_| krng.below(d as u64) as u32).collect();
        let mut bs = vec![0u64; d.div_ceil(64)];
        let mut swept: Vec<u32> = Vec::with_capacity(staged.len());
        h.bench("kernel bloom-sweep d=10k sk=104 scalar", || {
            swept.clear();
            let (lo, hi) = kernels::bitset_mark(&mut bs, &staged);
            kernels::scalar::bitset_sweep(&mut bs, lo, hi, &mut swept);
            swept.len()
        });
        h.bench("kernel bloom-sweep d=10k sk=104 active", || {
            swept.clear();
            let (lo, hi) = kernels::bitset_mark(&mut bs, &staged);
            kernels::bitset_sweep(&mut bs, lo, hi, &mut swept);
            swept.len()
        });

        // AM similarity kernels: one class-prototype row scan at the
        // paper's bundled d=20k (serving's per-class scoring unit).
        let ds = 2 * d;
        let qa: Vec<f32> = (0..ds).map(|_| krng.normal_f32()).collect();
        let qb: Vec<f32> = (0..ds).map(|_| krng.normal_f32()).collect();
        h.bench("kernel dot-f32 d=20k scalar", || kernels::scalar::dot_f32(&qa, &qb));
        h.bench("kernel dot-f32 d=20k active", || kernels::dot_f32(&qa, &qb));

        let ia: Vec<i8> = (0..ds).map(|_| krng.next_u32() as i8).collect();
        let ib: Vec<i8> = (0..ds).map(|_| krng.next_u32() as i8).collect();
        h.bench("kernel dot-i8 d=20k scalar", || kernels::scalar::dot_i8(&ia, &ib));
        h.bench("kernel dot-i8 d=20k active", || kernels::dot_i8(&ia, &ib));

        let wa: Vec<u64> = (0..ds.div_ceil(64)).map(|_| krng.next_u64()).collect();
        let wb: Vec<u64> = (0..ds.div_ceil(64)).map(|_| krng.next_u64()).collect();
        h.bench("kernel hamming d=20k scalar", || kernels::scalar::hamming_packed(&wa, &wb));
        h.bench("kernel hamming d=20k active", || kernels::hamming_packed(&wa, &wb));
    }

    // --- batched encode through RecordEncoder -----------------------------
    let cfg = EncoderCfg {
        cat: CatCfg::Bloom { d, k: 4 },
        num: NumCfg::Sjlt { d, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 7,
    };
    let mut renc = cfg.build();
    let mut batch_out: Vec<Encoding> = Vec::new();
    let batch = &records[..256];
    h.bench("record-encoder batch=256 bloom+sjlt concat", || {
        renc.encode_batch_into(batch, &mut batch_out);
        black_box(&batch_out);
        let n = batch_out.len();
        renc.recycle_all(batch_out.drain(..));
        n
    });
    h.note_throughput(256.0, "records");

    // --- serving: closed-loop latency per store precision ------------------
    let serve_requests = env_u64("SHDC_BENCH_SERVE_REQUESTS", 20_000);
    let serve_results = serve_scenarios(serve_requests);
    let stage_breakdown = serve_stage_breakdown(serve_requests.clamp(1, 10_000));
    let windowed = serve_windowed(serve_requests.clamp(1, 10_000));

    // --- coordinator worker scaling ---------------------------------------
    let scale_records = env_u64("SHDC_BENCH_RECORDS", 60_000);
    let mut scaling = Vec::new();
    let mut rps1 = 0.0f64;
    for workers in [1usize, 2, 4] {
        let (rps, snap) = pipeline_records_per_sec(workers, scale_records);
        if workers == 1 {
            rps1 = rps;
        }
        println!(
            "  pipeline {workers} worker(s): {rps:.3e} records/s  (x{:.2} vs 1 worker, \
             {} stolen, {} recycled, {} recycle misses)",
            rps / rps1,
            snap.batches_stolen,
            snap.buffers_recycled,
            snap.recycle_misses,
        );
        scaling.push(Json::obj(vec![
            ("workers", Json::num(workers as f64)),
            ("records_per_sec", Json::num(rps)),
            ("speedup_vs_1", Json::num(rps / rps1)),
            ("batches_stolen", Json::num(snap.batches_stolen as f64)),
            ("injector_batches", Json::num(snap.injector_batches as f64)),
            ("buffers_recycled", Json::num(snap.buffers_recycled as f64)),
            ("recycle_misses", Json::num(snap.recycle_misses as f64)),
            ("backpressure_events", Json::num(snap.backpressure_events as f64)),
        ]));
    }

    h.finish();

    let speedup = |legacy: &str, new: &str| -> Json {
        match (h.median_ns(legacy), h.median_ns(new)) {
            (Some(l), Some(n)) if n > 0.0 => Json::num(l / n),
            _ => Json::Null,
        }
    };
    let bloom_speedup = speedup("bloom d=10k k=4 legacy (alloc+sort)", "bloom d=10k k=4 scratch");
    let sjlt_speedup = speedup(
        "SJLT d=10k k=4 legacy (nested tables)",
        "SJLT d=10k k=4 scratch (flat tables)",
    );
    println!("  speedup bloom d=10k k=4: {bloom_speedup:?}");
    println!("  speedup SJLT  d=10k k=4: {sjlt_speedup:?}");
    // Active-backend kernel speedups vs the scalar twins (≈1.0 in a
    // default build; the SIMD win when built with --features simd).
    let kernel_pair = |work: &str| {
        speedup(&format!("kernel {work} scalar"), &format!("kernel {work} active"))
    };
    let kernel_speedups = Json::obj(vec![
        ("axpy_d10k", kernel_pair("axpy d=10k")),
        ("sign_quantize_d10k", kernel_pair("sign-quantize d=10k")),
        ("sjlt_scatter_d10k_k4", kernel_pair("sjlt-scatter d=10k k=4")),
        ("bit_unpack_d10k", kernel_pair("bit-unpack d=10k")),
        ("bloom_sweep_d10k_sk104", kernel_pair("bloom-sweep d=10k sk=104")),
        ("dot_f32_d20k", kernel_pair("dot-f32 d=20k")),
        ("dot_i8_d20k", kernel_pair("dot-i8 d=20k")),
        ("hamming_d20k", kernel_pair("hamming d=20k")),
    ]);
    println!("  kernel active-vs-scalar ({}): {kernel_speedups:?}", kernels::BACKEND);

    Json::obj(vec![
        ("group", Json::str("encode")),
        ("kernel_backend", Json::str(kernels::BACKEND)),
        ("simd_feature", Json::Bool(kernels::SIMD_ENABLED)),
        (
            "config",
            Json::obj(vec![
                ("data_seed", Json::num(1.0)),
                ("alphabet_size", Json::num(10_000_000.0)),
                ("d", Json::num(d as f64)),
                ("sample_records", Json::num(records.len() as f64)),
                ("pipeline_records", Json::num(scale_records as f64)),
            ]),
        ),
        ("results", h.to_json()),
        (
            "speedup",
            Json::obj(vec![
                ("bloom_d10k_k4", bloom_speedup),
                ("sjlt_d10k_k4", sjlt_speedup),
            ]),
        ),
        ("kernel_speedup_active_vs_scalar", kernel_speedups),
        ("pipeline_scaling", Json::Arr(scaling)),
        ("serve", Json::Arr(serve_results)),
        ("serve_stage_breakdown", stage_breakdown),
        ("serve_windowed", windowed),
    ])
}

/// Write the snapshot to `$BENCH_OUT` (default `BENCH_encode.json`).
pub fn write_encode_snapshot() -> std::io::Result<()> {
    let doc = encode_snapshot();
    let path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_encode.json".to_string());
    Harness::write_json(&path, &doc)
}
