//! Hardware-model benchmarks: regenerate Tables 2-4 data and sweep the
//! simulators across the design space (the ablation surface DESIGN.md
//! calls out: partitions p, row-unroll R, dimension d).

use shdc::encoding::BundleMethod;
use shdc::hw::fpga::{self, FpgaConfig};
use shdc::hw::pim::{self, PimWorkload};
use shdc::util::bench::Harness;

fn main() {
    let mut h = Harness::new("hw_tables");

    // The simulators themselves are cheap; benchmark to keep them honest.
    h.bench("fpga::table2 (4 configs)", fpga::table2);
    h.bench("pim::simulate (paper full)", || {
        pim::simulate(&PimWorkload::paper(true))
    });

    println!("\n  FPGA ablation: throughput vs (p, R) at d=10k OR:");
    for p in [2usize, 5, 10] {
        for r in [32usize, 64, 128] {
            let mut cfg = FpgaConfig::paper(BundleMethod::ThresholdedSum, false);
            cfg.p = p;
            cfg.r = r;
            let rep = fpga::simulate(&cfg);
            println!(
                "    p={p:<3} R={r:<4} -> {:>8.2} M/s  (DSP {:>4.1}%)",
                rep.throughput / 1e6,
                rep.utilization.dsps * 100.0
            );
        }
    }

    println!("\n  FPGA ablation: throughput vs d (OR config):");
    for d in [2_000usize, 10_000, 20_000, 50_000] {
        let mut cfg = FpgaConfig::paper(BundleMethod::ThresholdedSum, false);
        cfg.d = d;
        let rep = fpga::simulate(&cfg);
        println!("    d={d:<6} -> {:>8.2} M/s", rep.throughput / 1e6);
    }

    println!("\n  PIM ablation: throughput vs d (full workload):");
    for d in [2_000usize, 10_000, 20_000, 50_000] {
        let rep = pim::simulate(&PimWorkload { d, ..PimWorkload::paper(true) });
        println!(
            "    d={d:<6} -> {:>8.2} M/s  ({} + {} xbars/input)",
            rep.throughput / 1e6,
            rep.numeric_xbars.unwrap_or(0),
            rep.cat_xbars
        );
    }

    h.finish();
}
