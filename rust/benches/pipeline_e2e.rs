//! End-to-end pipeline benchmarks: streaming encode throughput under the
//! coordinator (worker scaling, backpressure) and full encode+train
//! throughput for both trainer paths (the Fig. 13 CPU bars).

use shdc::coordinator::{run_pipeline, CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::data::SyntheticStream;
use shdc::encoding::BundleMethod;
use shdc::model::LogisticModel;
use shdc::util::bench::Harness;

fn encoder(no_count: bool) -> EncoderCfg {
    EncoderCfg {
        cat: CatCfg::Bloom { d: 10_000, k: 4 },
        num: if no_count { NumCfg::None } else { NumCfg::DenseSign { d: 10_000 } },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 3,
    }
}

fn pipeline_throughput(workers: usize, records: u64, no_count: bool, train: bool) -> f64 {
    let data = SyntheticConfig { alphabet_size: 1_000_000, ..SyntheticConfig::sampled(3) };
    let cfg = encoder(no_count);
    let mut model = LogisticModel::new(cfg.out_dim());
    let stream = SyntheticStream::new(data);
    let t0 = std::time::Instant::now();
    let mut errs: Vec<f32> = Vec::new();
    run_pipeline(
        stream,
        &cfg,
        &CoordinatorCfg {
            batch_size: 256,
            n_workers: workers,
            max_records: Some(records),
            ..Default::default()
        },
        |batch| {
            if train {
                // Borrowed batch: buffers recycle back to the workers.
                model.sgd_step_parts(&batch.encodings, &batch.labels, 0.3, &mut errs);
            }
            true
        },
    );
    records as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let h = Harness::new("pipeline_e2e");
    let records: u64 = std::env::var("BENCH_RECORDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);

    println!("  (one-shot wall-clock measurements, {records} records each)");
    println!("\n  encode-only worker scaling (bloom 10k/4 + dense RP 10k, No-Count=false):");
    let base = pipeline_throughput(1, records, false, false);
    println!("    1 worker : {base:>12.0} rec/s");
    for w in [2usize, 4, 8] {
        let tp = pipeline_throughput(w, records, false, false);
        println!("    {w} workers: {tp:>12.0} rec/s  ({:.2}x)", tp / base);
    }

    println!("\n  encode-only No-Count (categorical only):");
    let nc = pipeline_throughput(4, records * 4, true, false);
    println!("    4 workers: {nc:>12.0} rec/s");

    println!("\n  encode + sparse-SGD train (Fig. 13 CPU bar, concat):");
    let tr = pipeline_throughput(4, records, false, true);
    println!("    4 workers: {tr:>12.0} rec/s");
    let trnc = pipeline_throughput(4, records * 2, true, true);
    println!("    4 workers (No-Count): {trnc:>12.0} rec/s");

    h.finish();
}
