//! Fig. 7A's benchmark twin: per-record encode latency for every
//! categorical and numeric encoder at paper-like dimensions, comparing
//! the pre-refactor allocating paths against the scratch hot path,
//! kernel-layer scalar-vs-active pairs (the active backend is
//! `std::simd` under `cargo bench --features simd`, scalar otherwise —
//! the `kernel_backend` field in the snapshot records which), plus
//! coordinator worker-scaling throughput.
//!
//! Thin wrapper over [`shdc::perf::encode_snapshot`] (shared with the
//! `bench_snapshot` binary) so `cargo bench --bench encode_scaling` and
//! `cargo run --release --bin bench_snapshot` produce the same
//! `BENCH_encode.json` (path override: `BENCH_OUT`).

fn main() {
    shdc::perf::write_encode_snapshot().expect("writing BENCH_encode.json");
}
