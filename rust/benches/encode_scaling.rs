//! Fig. 7A's benchmark twin: per-record encode latency for every
//! categorical and numeric encoder at paper-like dimensions, plus the
//! codebook-vs-bloom scaling contrast.

use shdc::data::{RecordStream, SyntheticStream};
use shdc::data::synthetic::SyntheticConfig;
use shdc::encoding::{
    BloomEncoder, CategoricalEncoder, CodebookEncoder, DenseHashEncoder, DenseHashMode,
    DenseProjection, NumericEncoder, PermutationEncoder, ProjectionMode, RelaxedSjlt, Sjlt,
    SparseProjection,
};
use shdc::util::bench::Harness;
use shdc::util::rng::Rng;

fn main() {
    let mut h = Harness::new("encode_scaling");
    let mut rng = Rng::new(1);
    let data = SyntheticConfig { alphabet_size: 10_000_000, ..SyntheticConfig::sampled(1) };
    let mut stream = SyntheticStream::new(data);
    let records: Vec<_> = (0..512).map(|_| stream.next_record().unwrap()).collect();
    let d = 10_000;

    // --- categorical encoders at d = 10k --------------------------------
    let bloom = BloomEncoder::new(d, 4, &mut rng);
    let mut i = 0usize;
    h.bench("bloom d=10k k=4 (per record)", || {
        i = (i + 1) % records.len();
        bloom.encode_set(&records[i].symbols)
    });
    h.note_throughput(1.0, "records");

    for k in [1usize, 8, 100] {
        let b = BloomEncoder::new(d, k, &mut rng);
        h.bench(&format!("bloom d=10k k={k}"), || {
            i = (i + 1) % records.len();
            b.encode_set(&records[i].symbols)
        });
    }

    let dh = DenseHashEncoder::new(d, DenseHashMode::Packed, &mut rng);
    h.bench("dense-hash packed d=10k", || {
        i = (i + 1) % records.len();
        dh.encode_set(&records[i].symbols)
    });
    let dh_lit = DenseHashEncoder::new(500, DenseHashMode::Literal, &mut rng);
    h.bench("dense-hash literal d=500 (paper's slow baseline)", || {
        i = (i + 1) % records.len();
        dh_lit.encode_set(&records[i].symbols)
    });

    let mut cb = CodebookEncoder::new(d, 3);
    // Pre-populate with the sample's symbols so we measure lookup+bundle.
    for r in &records {
        let _ = cb.try_encode(&r.symbols);
    }
    h.bench("codebook d=10k (warm)", || {
        i = (i + 1) % records.len();
        cb.encode(&records[i].symbols)
    });

    let perm = PermutationEncoder::new(d, 16, 16, &mut rng);
    h.bench("permutation d=10k pool=16", || {
        i = (i + 1) % records.len();
        perm.encode_set(&records[i].symbols)
    });

    // --- numeric encoders at d = 10k -------------------------------------
    let dp = DenseProjection::new(d, 13, ProjectionMode::Sign, &mut rng);
    h.bench("dense sign-RP d=10k n=13", || {
        i = (i + 1) % records.len();
        dp.encode(&records[i].numeric)
    });
    h.note_throughput(1.0, "records");

    let sp = SparseProjection::new_topk(d, 13, 100, &mut rng);
    h.bench("sparse RP top-k d=10k k=100", || {
        i = (i + 1) % records.len();
        sp.encode(&records[i].numeric)
    });
    let st = SparseProjection::new_threshold(d, 13, 1.0, &mut rng);
    h.bench("sparse RP threshold d=10k", || {
        i = (i + 1) % records.len();
        st.encode(&records[i].numeric)
    });

    let sj = Sjlt::new(d, 13, 4, &mut rng);
    h.bench("SJLT structured d=10k k=4", || {
        i = (i + 1) % records.len();
        sj.encode(&records[i].numeric)
    });
    let rsj = RelaxedSjlt::new(d, 13, 0.4, true, &mut rng);
    h.bench("SJLT relaxed d=10k p=0.4", || {
        i = (i + 1) % records.len();
        rsj.encode(&records[i].numeric)
    });

    h.finish();
}
