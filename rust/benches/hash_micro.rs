//! Microbenchmarks for the hashing substrate — the innermost hot path of
//! the paper's streaming encoders (a Bloom encode is s*k of these).

use shdc::hash::{murmur3_u64, IndexHash, MurmurHash, PolyHash};
use shdc::util::bench::Harness;
use shdc::util::rng::Rng;

fn main() {
    let mut h = Harness::new("hash_micro");
    let mut rng = Rng::new(1);

    let mut key = 0u64;
    h.bench("murmur3_u64 single", || {
        key = key.wrapping_add(1);
        murmur3_u64(key, 0x9747b28c)
    });
    h.note_throughput(1.0, "hashes");

    let mh = MurmurHash::new(42);
    h.bench("murmur index d=10000", || mh.index(key.wrapping_add(7), 10_000));

    for p in [2usize, 8, 52] {
        let ph = PolyHash::new(p, &mut rng);
        h.bench(&format!("poly({p}-indep) index d=10000"), || {
            ph.index(key.wrapping_add(3), 10_000)
        });
    }

    // A full symbol set: 26 symbols x 4 hashes (the per-record cost).
    let mhs = MurmurHash::family(4, &mut rng);
    let symbols: Vec<u64> = (0..26).collect();
    let mut sink = 0u64;
    h.bench("26 symbols x k=4 murmur (per record)", || {
        for &s in &symbols {
            for f in &mhs {
                sink = sink.wrapping_add(f.index(s, 10_000));
            }
        }
        sink
    });
    h.note_throughput(104.0, "hashes");

    h.finish();
}
