//! End-to-end contract tests for the stage-span tracing pipeline
//! (`shdc::obs` wired through the serving stack):
//!
//! * disabled tracing is the default and records nothing;
//! * sampled traces carry a monotone nine-edge timestamp chain whose
//!   seven stage spans telescope exactly to the submit→complete time,
//!   and never exceed the run's recorded latency maximum;
//! * 1-in-N sampling is deterministic by global submission index;
//! * per-worker trace rings wrap around keeping the newest records
//!   while the sampled/dropped accounting stays exact;
//! * per-model stage histograms reconcile with the per-model completion
//!   counters of [`ServeSnapshot`];
//! * injected worker panics deliver failed-marked traces (zero-width
//!   scan span) that stay out of the stage histograms, and no sampled
//!   request's trace is orphaned.

use std::sync::Once;
use std::time::Duration;

use shdc::am::AmStore;
use shdc::coordinator::{CatCfg, CoordinatorCfg, EncoderCfg, FaultPlan, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::data::{RecordStream, SyntheticStream};
use shdc::encoding::BundleMethod;
use shdc::obs::ObsCfg;
use shdc::serve::{ModelRegistry, ServeCfg, ServeError, ServeHandle, Server, TenantQuota};
use shdc::util::rng::Rng;

/// Injected panics are part of the plan, not noise: suppress their
/// backtrace spew (and only theirs) so a green run has a readable log.
fn quiet_injected_panics() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.contains("shdc injected fault"))
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

fn encoder_cfg(seed: u64) -> EncoderCfg {
    EncoderCfg {
        cat: CatCfg::Bloom { d: 256, k: 2 },
        num: NumCfg::None,
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed,
    }
}

fn small_store(d: usize, seed: u64) -> AmStore {
    let mut rng = Rng::new(seed);
    let rows: Vec<Vec<f32>> =
        (0..2).map(|_| (0..d).map(|_| rng.normal_f32()).collect()).collect();
    AmStore::from_prototypes(d, &rows, None)
}

fn serve_cfg_obs(obs: ObsCfg, seed: u64, n_workers: usize, batch_size: usize) -> ServeCfg {
    ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size,
            n_workers,
            queue_depth: 2,
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(200),
        queue_cap: 64,
        slots: 32,
        obs,
        ..ServeCfg::new(encoder_cfg(seed))
    }
}

/// Drive `n` sequential classify calls from one client thread (fully
/// deterministic submission order — submission index == request order).
fn run_sequential(handle: &ServeHandle, data_seed: u64, n: u64) {
    let mut stream = SyntheticStream::new(SyntheticConfig::sampled(data_seed));
    let mut rec = stream.next_record().expect("unbounded stream");
    for _ in 0..n {
        let resp = handle.classify(rec).expect("in-capacity classify");
        rec = resp.record;
        stream.refill_record(&mut rec);
    }
}

#[test]
fn disabled_by_default_records_nothing() {
    let cfg = serve_cfg_obs(ObsCfg::default(), 60, 2, 8);
    assert_eq!(cfg.obs.sample_every, 0, "tracing must be opt-in");
    let (server, handle) = Server::new(cfg, small_store(256, 61));
    let server_thread = std::thread::spawn(move || server.run());
    run_sequential(&handle, 62, 50);
    handle.shutdown();
    server_thread.join().expect("server");
    assert!(!handle.tracing_enabled());
    assert!(handle.drain_traces().is_empty());
    let snap = handle.obs_snapshot();
    assert_eq!(snap.sampled, 0);
    assert_eq!(snap.dropped, 0);
    for s in &snap.stages {
        assert_eq!(s.hist.count, 0, "stage {} must be empty", s.stage);
    }
}

#[test]
fn span_chain_is_monotone_and_telescopes() {
    let obs = ObsCfg { sample_every: 1, ring_cap: 256 };
    let (server, handle) = Server::new(serve_cfg_obs(obs, 63, 2, 8), small_store(256, 64));
    let server_thread = std::thread::spawn(move || server.run());
    run_sequential(&handle, 65, 40);
    handle.shutdown();
    server_thread.join().expect("server");

    let serve = handle.stats();
    let traces = handle.drain_traces();
    assert_eq!(traces.len(), 40, "every request sampled, none orphaned");
    for t in &traces {
        assert!(!t.failed);
        // The nine edges are ordered by happens-before relations on the
        // one monotonic clock, under any steal interleaving.
        let edges = [
            t.t_submit,
            t.t_enqueue,
            t.t_cut,
            t.t_pop,
            t.t_encode_start,
            t.t_encode_end,
            t.t_scan_start,
            t.t_scan_end,
            t.t_complete,
        ];
        for w in edges.windows(2) {
            assert!(w[0] <= w[1], "non-monotone span chain: {t:?}");
        }
        // Telescoping: the seven spans partition submit→complete.
        assert_eq!(t.stages_sum_ns(), t.end_to_end_ns(), "{t:?}");
        // The completion edge is stamped before the latency histogram's
        // measurement, so no trace can exceed the recorded maximum.
        assert!(t.end_to_end_ns() <= serve.latency_ns.max, "{t:?}");
    }
}

#[test]
fn sampling_cadence_is_deterministic() {
    let obs = ObsCfg { sample_every: 8, ring_cap: 256 };
    let (server, handle) = Server::new(serve_cfg_obs(obs, 66, 2, 8), small_store(256, 67));
    let server_thread = std::thread::spawn(move || server.run());
    run_sequential(&handle, 68, 64);
    handle.shutdown();
    server_thread.join().expect("server");

    let snap = handle.obs_snapshot();
    assert_eq!(snap.sample_every, 8);
    assert_eq!(snap.sampled, 8, "64 sequential submissions, 1-in-8");
    assert_eq!(snap.dropped, 0);
    let traces = handle.drain_traces();
    let ids: Vec<u64> = traces.iter().map(|t| t.req_id).collect();
    // One sequential client: submission index == request order, so the
    // sampled set is exactly every 8th submission starting at 0.
    assert_eq!(ids, vec![0, 8, 16, 24, 32, 40, 48, 56]);
}

#[test]
fn ring_wraparound_keeps_newest_traces() {
    // One worker so every trace lands in the same 4-slot ring.
    let obs = ObsCfg { sample_every: 1, ring_cap: 4 };
    let (server, handle) = Server::new(serve_cfg_obs(obs, 69, 1, 8), small_store(256, 70));
    let server_thread = std::thread::spawn(move || server.run());
    run_sequential(&handle, 71, 100);
    handle.shutdown();
    server_thread.join().expect("server");

    // Snapshot before draining: `sampled` counts retained + overwritten.
    let snap = handle.obs_snapshot();
    assert_eq!(snap.sampled, 100);
    assert_eq!(snap.dropped, 96);
    // The histograms saw every trace, not just the retained window.
    for s in &snap.stages {
        assert_eq!(s.hist.count, 100, "stage {}", s.stage);
    }
    let traces = handle.drain_traces();
    let ids: Vec<u64> = traces.iter().map(|t| t.req_id).collect();
    assert_eq!(ids, vec![96, 97, 98, 99], "overwrite-oldest keeps the newest");
}

#[test]
fn per_model_stage_histograms_reconcile_with_serve_counters() {
    use shdc::am::Precision;
    let obs = ObsCfg { sample_every: 1, ring_cap: 512 };
    let mut registry = ModelRegistry::new();
    let a = registry.register(
        "a",
        encoder_cfg(72),
        small_store(256, 73),
        Precision::F32,
        TenantQuota::default(),
    );
    let b = registry.register(
        "b",
        encoder_cfg(74),
        small_store(256, 75),
        Precision::Int8,
        TenantQuota::default(),
    );
    let cfg = serve_cfg_obs(obs, 72, 2, 8);
    let (server, handle) = Server::with_registry(cfg, registry);
    let server_thread = std::thread::spawn(move || server.run());
    // One sequential client alternating tenants: 30 requests each.
    let mut stream = SyntheticStream::new(SyntheticConfig::sampled(76));
    let mut rec = stream.next_record().expect("unbounded stream");
    for i in 0..60u32 {
        let model = if i % 2 == 0 { a } else { b };
        let resp = handle.classify_for(model, rec).expect("in-capacity classify");
        rec = resp.record;
        stream.refill_record(&mut rec);
    }
    handle.shutdown();
    server_thread.join().expect("server");

    let serve = handle.stats();
    let snap = handle.obs_snapshot();
    assert_eq!(serve.completed, 60);
    assert_eq!(snap.sampled, 60);
    assert_eq!(snap.models.len(), 2);
    // Every stage histogram of model m counted exactly m's completions
    // (clean run: nothing failed, expired, or shed).
    for (m, ms) in snap.models.iter().enumerate() {
        let completed = serve.models[m].completed;
        assert_eq!(completed, 30);
        for s in &ms.stages {
            assert_eq!(
                s.hist.count, completed,
                "model {m} stage {} vs serve counter",
                s.stage
            );
        }
    }
    // And the overall table is their aggregate.
    for s in &snap.stages {
        assert_eq!(s.hist.count, serve.completed, "overall stage {}", s.stage);
    }
}

#[test]
fn injected_panic_delivers_failed_traces_and_keeps_them_out_of_histograms() {
    quiet_injected_panics();
    // batch_size 1 → each request is its own batch; seq 3 panics, so
    // exactly one request fails. Everything is sampled.
    let obs = ObsCfg { sample_every: 1, ring_cap: 64 };
    let cfg = ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 1,
            n_workers: 1,
            queue_depth: 2,
            fault: FaultPlan { panic_on_seq: vec![3], ..FaultPlan::default() },
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(200),
        queue_cap: 64,
        slots: 32,
        obs,
        ..ServeCfg::new(encoder_cfg(77))
    };
    let (server, handle) = Server::new(cfg, small_store(256, 78));
    let server_thread = std::thread::spawn(move || server.run());
    let mut stream = SyntheticStream::new(SyntheticConfig::sampled(79));
    let mut rec = stream.next_record().expect("unbounded stream");
    let mut client_failed = 0u64;
    for _ in 0..20 {
        match handle.classify(rec) {
            Ok(resp) => {
                rec = resp.record;
                stream.refill_record(&mut rec);
            }
            Err(ServeError::Internal) => {
                client_failed += 1;
                // The record moved into the server; draw a fresh one.
                rec = stream.next_record().expect("unbounded stream");
            }
            Err(e) => panic!("unexpected terminal outcome: {e:?}"),
        }
    }
    handle.shutdown();
    server_thread.join().expect("server");

    let serve = handle.stats();
    assert_eq!(client_failed, 1, "seq 3 fails exactly its one-request batch");
    assert_eq!(serve.failed, 1);
    assert_eq!(serve.completed, 20, "failed requests still complete explicitly");

    let snap = handle.obs_snapshot();
    let traces = handle.drain_traces();
    // No orphans: every sampled request's trace was delivered — the
    // failed one included — with unique ids.
    assert_eq!(traces.len(), 20);
    let mut ids: Vec<u64> = traces.iter().map(|t| t.req_id).collect();
    ids.dedup();
    assert_eq!(ids.len(), 20, "req_ids must be unique");
    let failed: Vec<_> = traces.iter().filter(|t| t.failed).collect();
    assert_eq!(failed.len(), 1, "failed-marked traces match the injected plan");
    // Failed requests never reach the scanner: zero-width scan span,
    // but the chain still telescopes to the end-to-end time.
    let ft = failed[0];
    assert_eq!(ft.t_scan_start, ft.t_scan_end);
    assert_eq!(ft.stages_sum_ns(), ft.end_to_end_ns());
    // Stage histograms describe successful requests only.
    for s in &snap.stages {
        assert_eq!(s.hist.count, 19, "stage {} must exclude the failed trace", s.stage);
    }
}
