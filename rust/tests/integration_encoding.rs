//! Cross-module integration: encoders + bundling + data streams + model,
//! exercising the combinations the figures sweep.

use shdc::coordinator::{CatCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::data::{RecordStream, SyntheticStream, TsvReader};
use shdc::encoding::{BundleMethod, Encoding};
use shdc::model::LogisticModel;
use std::io::Cursor;

fn stream(seed: u64) -> SyntheticStream {
    SyntheticStream::new(SyntheticConfig {
        alphabet_size: 50_000,
        ..SyntheticConfig::sampled(seed)
    })
}

#[test]
fn every_encoder_combination_roundtrips_through_the_model() {
    let cats = [
        CatCfg::Bloom { d: 512, k: 4 },
        CatCfg::DenseHash { d: 512, literal: false },
        CatCfg::Codebook { d: 512, budget_bytes: None },
        CatCfg::Permutation { d: 512, pool: 4, granularity: 16 },
    ];
    let nums = [
        NumCfg::DenseSign { d: 512 },
        NumCfg::SparseTopK { d: 512, k: 50 },
        NumCfg::Sjlt { d: 512, k: 4 },
        NumCfg::RelaxedSjlt { d: 512, p: 0.4, quantize: true },
    ];
    let mut s = stream(1);
    let records: Vec<_> = (0..64).map(|_| s.next_record().unwrap()).collect();
    for cat in &cats {
        for num in &nums {
            for bundle in [BundleMethod::Concat, BundleMethod::Sum, BundleMethod::ThresholdedSum] {
                let cfg = EncoderCfg {
                    cat: cat.clone(),
                    num: num.clone(),
                    bundle,
                    n_numeric: 13,
                    seed: 7,
                };
                let mut enc = cfg.build();
                let mut model = LogisticModel::new(cfg.out_dim());
                let batch: Vec<(Encoding, bool)> =
                    records.iter().map(|r| (enc.encode(r), r.label)).collect();
                for (e, _) in &batch {
                    assert_eq!(e.dim(), cfg.out_dim(), "{cat:?}/{num:?}/{bundle:?}");
                }
                let l0 = model.loss(&batch);
                // Tiny step: encodings that bundle-by-sum have O(s)
                // magnitude coordinates (worst case: permutation pools
                // with colliding codewords), so a large lr overshoots.
                model.sgd_step(&batch, 0.003);
                let l1 = model.loss(&batch);
                assert!(
                    l1 < l0,
                    "one SGD step on its own batch must reduce loss: {cat:?}/{num:?}/{bundle:?} {l0} -> {l1}"
                );
            }
        }
    }
}

#[test]
fn tsv_and_synthetic_streams_are_interchangeable() {
    // Build a TSV text from synthetic-like data, parse it back, and feed
    // both through the same encoder.
    let mut lines = String::new();
    for i in 0..50 {
        let ints: Vec<String> = (0..13).map(|j| ((i * j) % 40).to_string()).collect();
        let cats: Vec<String> = (0..26).map(|j| format!("{:08x}", i * 31 + j)).collect();
        lines.push_str(&format!("{}\t{}\t{}\n", i % 2, ints.join("\t"), cats.join("\t")));
    }
    let mut tsv = TsvReader::new(Cursor::new(lines));
    let cfg = EncoderCfg {
        cat: CatCfg::Bloom { d: 1024, k: 4 },
        num: NumCfg::DenseSign { d: 256 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 9,
    };
    let mut enc = cfg.build();
    let mut n = 0;
    while let Some(r) = tsv.next_record() {
        let e = enc.encode(&r);
        assert_eq!(e.dim(), 1280);
        n += 1;
    }
    assert_eq!(n, 50);
}

#[test]
fn bloom_encodings_separate_planted_classes_better_than_chance() {
    // End-to-end sanity on raw encodings: planted-class centroid distance
    // in HD space exceeds within-class spread.
    let mut s = SyntheticStream::new(SyntheticConfig {
        alphabet_size: 5_000,
        noise: 0.0,
        ..SyntheticConfig::sampled(3)
    });
    let cfg = EncoderCfg {
        cat: CatCfg::Bloom { d: 4096, k: 4 },
        num: NumCfg::None,
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 3,
    };
    let mut enc = cfg.build();
    let mut pos = vec![0.0f64; 4096];
    let mut neg = vec![0.0f64; 4096];
    let (mut np, mut nn) = (0usize, 0usize);
    for _ in 0..2000 {
        let r = s.next_record().unwrap();
        let e = enc.encode(&r).to_dense();
        let acc = if r.label { &mut pos } else { &mut neg };
        for (a, v) in acc.iter_mut().zip(&e) {
            *a += *v as f64;
        }
        if r.label {
            np += 1
        } else {
            nn += 1
        }
    }
    assert!(np > 100 && nn > 100);
    for v in pos.iter_mut() {
        *v /= np as f64;
    }
    for v in neg.iter_mut() {
        *v /= nn as f64;
    }
    let dist: f64 = pos.iter().zip(&neg).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    assert!(dist > 0.05, "class centroids indistinguishable: {dist}");
}

#[test]
fn memory_contrast_bloom_vs_codebook_on_stream() {
    let mut s = SyntheticStream::new(SyntheticConfig {
        alphabet_size: 1_000_000,
        zipf_alpha: 1.05,
        ..SyntheticConfig::sampled(4)
    });
    let records: Vec<_> = (0..3_000).map(|_| s.next_record().unwrap()).collect();
    use shdc::encoding::{BloomEncoder, CategoricalEncoder, CodebookEncoder};
    use shdc::util::rng::Rng;
    let mut bloom = BloomEncoder::new(10_000, 4, &mut Rng::new(1));
    let mut codebook = CodebookEncoder::new(10_000, 1);
    for r in &records {
        let _ = CategoricalEncoder::encode(&mut bloom, &r.symbols);
        let _ = codebook.try_encode(&r.symbols).unwrap();
    }
    let bm = CategoricalEncoder::memory_bytes(&mut bloom);
    let cm = CategoricalEncoder::memory_bytes(&mut codebook);
    assert!(
        cm > 1000 * bm,
        "codebook ({cm} B) must dwarf bloom ({bm} B) after {} records",
        records.len()
    );
}
