//! Quantitative validation of the paper's theory (Sec. 3-4):
//! Δ(d)-dot-product preservation for the codebook (Theorem 2) and Bloom
//! (Theorem 3) encoders, the derived linear-separability transfer
//! (Theorem 1), and the predicted error scalings in d, k, and s.

use shdc::encoding::{BloomEncoder, CodebookEncoder, Encoding};
use shdc::model::LogisticModel;
use shdc::util::rng::Rng;

/// Two sets of size s with the given overlap, disjoint tails.
fn set_pair(base: u64, s: usize, overlap: usize) -> (Vec<u64>, Vec<u64>) {
    let x: Vec<u64> = (0..s as u64).map(|i| base + i).collect();
    let y: Vec<u64> = (0..s as u64)
        .map(|i| if (i as usize) < overlap { base + i } else { base + 1_000_000 + i })
        .collect();
    (x, y)
}

#[test]
fn theory_theorem2_codebook_preserves_intersections() {
    // (1/d) phi(x).phi(x') must track |x ∩ x'| within ~4 sqrt(2 s^3/d ln m).
    let mut rng = Rng::new(1);
    let (d, s) = (32_768usize, 26usize);
    let mut worst = 0.0f64;
    for trial in 0..30 {
        let mut enc = CodebookEncoder::new(d, rng.next_u64());
        let overlap = trial % (s + 1);
        let (x, y) = set_pair(trial as u64 * 7_777, s, overlap);
        let fx = enc.try_encode(&x).unwrap();
        let fy = enc.try_encode(&y).unwrap();
        let est = fx.dot(&fy) / d as f64;
        worst = worst.max((est - overlap as f64).abs());
    }
    // Loose empirical ceiling well below the theorem's (conservative) bound.
    let bound = 4.0 * ((2.0 * (s as f64).powi(3) / d as f64) * (1000.0f64).ln()).sqrt();
    assert!(worst < bound, "worst {worst} vs bound {bound}");
    assert!(worst < 5.0, "empirical error should be small: {worst}");
}

#[test]
fn theory_theorem3_bloom_bias_corrected_estimator() {
    // (1/k) phi.phi' - s^2 k/2d estimates the intersection.
    let mut rng = Rng::new(2);
    let (d, s, k) = (32_768usize, 26usize, 4usize);
    let mut worst = 0.0f64;
    for trial in 0..30 {
        let enc = BloomEncoder::new(d, k, &mut rng);
        let overlap = trial % (s + 1);
        let (x, y) = set_pair(trial as u64 * 9_999, s, overlap);
        let est = enc.encode_set(&x).dot(&enc.encode_set(&y)) / k as f64
            - (s * s * k) as f64 / (2.0 * d as f64);
        worst = worst.max((est - overlap as f64).abs());
    }
    assert!(worst < 5.0, "worst error {worst}");
}

#[test]
fn theory_error_scales_inverse_sqrt_d() {
    // Mean |error| should shrink ~1/sqrt(d) for both encoders (Thm 2/3).
    let mut rng = Rng::new(3);
    let s = 26;
    let mean_err = |d: usize, rng: &mut Rng| -> f64 {
        let mut acc = 0.0;
        let trials = 60;
        for t in 0..trials {
            let enc = BloomEncoder::new(d, 4, rng);
            let overlap = t % (s + 1);
            let (x, y) = set_pair(t as u64 * 13, s, overlap);
            let est = enc.encode_set(&x).dot(&enc.encode_set(&y)) / 4.0
                - (s * s * 4) as f64 / (2.0 * d as f64);
            acc += (est - overlap as f64).abs();
        }
        acc / trials as f64
    };
    let e_small = mean_err(2_000, &mut rng);
    let e_big = mean_err(32_000, &mut rng);
    // 16x dimension => ~4x error reduction; accept >= 2.2x.
    assert!(
        e_small / e_big > 2.2,
        "error ratio {:.2} (small {e_small:.3}, big {e_big:.3})",
        e_small / e_big
    );
}

#[test]
fn theory_larger_s_needs_larger_d() {
    // At fixed d, bigger sets estimate worse (the s^3/d law).
    let mut rng = Rng::new(4);
    let d = 8_000;
    let mean_err = |s: usize, rng: &mut Rng| -> f64 {
        let mut acc = 0.0;
        let trials = 50;
        for t in 0..trials {
            let enc = BloomEncoder::new(d, 4, rng);
            let overlap = (t % (s + 1)).min(s);
            let (x, y) = set_pair(t as u64 * 31, s, overlap);
            let est = enc.encode_set(&x).dot(&enc.encode_set(&y)) / 4.0
                - (s * s * 4) as f64 / (2.0 * d as f64);
            acc += (est - overlap as f64).abs();
        }
        acc / trials as f64
    };
    let e13 = mean_err(13, &mut rng);
    let e104 = mean_err(104, &mut rng);
    assert!(e104 > 2.0 * e13, "s=104 err {e104:.3} vs s=13 err {e13:.3}");
}

#[test]
fn theory_theorem1_separability_transfers_to_hd_space() {
    // Construct two symbol-set classes with margin in the s-hot space;
    // a linear model on Bloom encodings must separate them (Thm 1 + 3).
    let mut rng = Rng::new(5);
    let d = 16_384;
    let enc = BloomEncoder::new(d, 4, &mut rng);
    let s = 20;
    // Class A draws from symbols [0, 400); class B from [400, 800) — the
    // s-hot representations are exactly separated (gamma = 2s).
    let gen = |rng: &mut Rng, lo: u64| -> Vec<u64> {
        (0..s).map(|_| lo + rng.below(400)).collect()
    };
    let mut model = LogisticModel::new(d);
    for _ in 0..150 {
        let batch: Vec<(Encoding, bool)> = (0..16)
            .map(|_| {
                let is_a = rng.bernoulli(0.5);
                let set = gen(&mut rng, if is_a { 0 } else { 400 });
                (enc.encode_set(&set), is_a)
            })
            .collect();
        model.sgd_step(&batch, 0.5);
    }
    // Evaluate.
    let mut correct = 0;
    let total = 400;
    for _ in 0..total / 2 {
        let a = enc.encode_set(&gen(&mut rng, 0));
        let b = enc.encode_set(&gen(&mut rng, 400));
        if model.predict(&a) > 0.5 {
            correct += 1;
        }
        if model.predict(&b) < 0.5 {
            correct += 1;
        }
    }
    let acc = correct as f64 / total as f64;
    assert!(acc > 0.97, "separable classes must classify near-perfectly: {acc}");
}

#[test]
fn theory_remark2_parameter_count_logarithmic_in_m() {
    // The point of the whole construction: d ~ s^2 log m parameters
    // suffice, even as m explodes. Train on two alphabet sizes 100x apart
    // with the same d and check accuracy holds (both problems planted
    // with the same geometry).
    use shdc::coordinator::{CatCfg, EncoderCfg, NumCfg};
    use shdc::data::synthetic::SyntheticConfig;
    use shdc::encoding::BundleMethod;
    use shdc::pipeline::{train, TrainBackend, TrainCfg};

    let mut aucs = Vec::new();
    for m in [20_000u64, 2_000_000] {
        let data = SyntheticConfig {
            alphabet_size: m,
            noise: 0.3,
            ..SyntheticConfig::sampled(6)
        };
        let cfg = TrainCfg {
            encoder: EncoderCfg {
                cat: CatCfg::Bloom { d: 4_096, k: 4 },
                num: NumCfg::DenseSign { d: 512 },
                bundle: BundleMethod::Concat,
                n_numeric: 13,
                seed: 6,
            },
            backend: TrainBackend::RustSgd,
            lr: 0.5,
            batch_size: 128,
            n_workers: 2,
            train_records: 30_000,
            val_records: 2_000,
            test_records: 6_000,
            validate_every: 10_000,
            patience: 3,
            auc_chunk: 2_000,
            seed: 6,
        };
        let rep = train(&cfg, &data).unwrap();
        aucs.push(rep.median_test_auc());
    }
    assert!(aucs[0] > 0.72, "small-m AUC {}", aucs[0]);
    // Larger m sees each tail symbol less often — allow some drop, but the
    // encoder itself must not collapse: 100x the alphabet at the SAME d
    // must cost at most a bounded AUC drop.
    assert!(aucs[1] > 0.65, "large-m AUC {}", aucs[1]);
    assert!(aucs[1] > aucs[0] - 0.12, "collapse with m: {aucs:?}");
}
