//! End-to-end contract tests for the monitoring subsystem
//! (`shdc::obs::export` + `shdc::obs::health` wired through the
//! serving stack):
//!
//! * a zero-traffic publishing window is explicitly healthy — every
//!   reported rate is a finite zero, never NaN;
//! * publisher/listener shutdown is idempotent (double `shutdown`,
//!   post-join `shutdown`, repeated event drains) and the monitoring
//!   surfaces stay readable after the threads are joined;
//! * the `/metrics` exposition parses line-for-line as Prometheus text
//!   and two scrapes reconcile *exactly* with the requests issued
//!   between them (counters are monotone, deltas exact);
//! * `/health` and `/snapshot` serve valid JSON, unknown paths 404,
//!   non-GET methods 405;
//! * an injected worker stall ([`FaultPlan::stall_once`]) flips the
//!   watchdog to `breach` with a `pipeline_stalled` event, and the
//!   verdict recovers (with `pipeline_resumed` + `slo_recovered`)
//!   once the worker wakes and completes the backlog.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use shdc::am::AmStore;
use shdc::coordinator::{CatCfg, CoordinatorCfg, EncoderCfg, FaultPlan, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::data::{RecordStream, SyntheticStream};
use shdc::encoding::BundleMethod;
use shdc::obs::export::{http_get, parse_exposition, ParsedSeries};
use shdc::obs::health::{EventKind, SloCfg, Verdict};
use shdc::serve::{ServeCfg, ServeHandle, Server};
use shdc::util::rng::Rng;

fn encoder_cfg(seed: u64) -> EncoderCfg {
    EncoderCfg {
        cat: CatCfg::Bloom { d: 256, k: 2 },
        num: NumCfg::None,
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed,
    }
}

fn small_store(d: usize, seed: u64) -> AmStore {
    let mut rng = Rng::new(seed);
    let rows: Vec<Vec<f32>> =
        (0..2).map(|_| (0..d).map(|_| rng.normal_f32()).collect()).collect();
    AmStore::from_prototypes(d, &rows, None)
}

/// A serving config with the monitoring stack enabled: SLO watchdog
/// always, HTTP exporter when `metrics_addr` is set. Lenient latency
/// target so slow CI hosts never trip the p99 objective by accident —
/// the stall test is the only one that *wants* a breach.
fn monitored_cfg(
    seed: u64,
    n_workers: usize,
    metrics_addr: Option<&str>,
    slo: SloCfg,
) -> ServeCfg {
    ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 8,
            n_workers,
            queue_depth: 2,
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(200),
        queue_cap: 64,
        slots: 32,
        metrics_addr: metrics_addr.map(str::to_string),
        slo: Some(slo),
        publish_interval: Duration::from_millis(10),
        ..ServeCfg::new(encoder_cfg(seed))
    }
}

/// Latency objective no real request will miss; everything else default.
fn lenient_slo() -> SloCfg {
    SloCfg { p99_target: Duration::from_secs(10), ..SloCfg::default() }
}

/// Drive `n` sequential classify calls from one client thread.
fn run_sequential(handle: &ServeHandle, data_seed: u64, n: u64) {
    let mut stream = SyntheticStream::new(SyntheticConfig::sampled(data_seed));
    let mut rec = stream.next_record().expect("unbounded stream");
    for _ in 0..n {
        let resp = handle.classify(rec).expect("in-capacity classify");
        rec = resp.record;
        stream.refill_record(&mut rec);
    }
}

/// Poll `cond` against the live health report until it holds or the
/// deadline passes; panics with the last report on timeout.
fn wait_for_health(
    handle: &ServeHandle,
    what: &str,
    deadline: Duration,
    cond: impl Fn(&shdc::obs::health::HealthReport) -> bool,
) -> shdc::obs::health::HealthReport {
    let start = Instant::now();
    loop {
        let report = handle.health().expect("publishing enabled");
        if cond(&report) {
            return report;
        }
        assert!(
            start.elapsed() < deadline,
            "timed out waiting for {what}; last report: {report:?}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn series_value(series: &[ParsedSeries], name: &str) -> f64 {
    series
        .iter()
        .find(|s| s.name == name && s.labels.is_empty())
        .unwrap_or_else(|| panic!("series {name} missing from exposition"))
        .value
}

#[test]
fn zero_traffic_windows_are_healthy_and_finite() {
    let cfg = monitored_cfg(80, 2, None, lenient_slo());
    let (server, handle) = Server::new(cfg, small_store(256, 81));
    let server_thread = std::thread::spawn(move || server.run());

    // No traffic at all: the publisher must still close windows, and
    // every window must be a finite-zero, healthy one.
    let report =
        wait_for_health(&handle, "3 idle windows", Duration::from_secs(10), |r| r.windows >= 3);
    assert_eq!(report.verdict, Verdict::Healthy, "idle is healthy: {report:?}");
    assert!(!report.stalled);
    assert!(report.reasons.is_empty(), "{:?}", report.reasons);
    for (name, v) in [
        ("window_s", report.window_s),
        ("shed_rate", report.shed_rate),
        ("quota_shed_rate", report.quota_shed_rate),
        ("error_rate", report.error_rate),
        ("burn_rate", report.burn_rate),
        ("budget_consumed", report.budget_consumed),
    ] {
        assert!(v.is_finite(), "{name} must be finite on an idle window, got {v}");
    }
    assert_eq!(report.shed_rate, 0.0);
    assert_eq!(report.error_rate, 0.0);

    let rates = handle.window_rates().expect("two samples have landed");
    for (name, v) in [
        ("submitted_per_s", rates.submitted_per_s),
        ("completed_per_s", rates.completed_per_s),
        ("shed_per_s", rates.shed_per_s),
        ("quota_shed_per_s", rates.quota_shed_per_s),
        ("failed_per_s", rates.failed_per_s),
        ("expired_per_s", rates.expired_per_s),
    ] {
        assert!(v.is_finite(), "{name} finite on idle window, got {v}");
        assert_eq!(v, 0.0, "{name} must be zero with no traffic");
    }
    assert_eq!(rates.latency.count, 0, "no latency samples without traffic");

    handle.shutdown();
    server_thread.join().expect("server");
}

#[test]
fn publisher_shutdown_is_idempotent_and_surfaces_outlive_the_threads() {
    let cfg = monitored_cfg(82, 2, None, lenient_slo());
    let (server, handle) = Server::new(cfg, small_store(256, 83));
    let server_thread = std::thread::spawn(move || server.run());
    run_sequential(&handle, 84, 40);
    // Let at least one window close over the traffic so the evaluator
    // has judged something before we tear down.
    wait_for_health(&handle, "first window", Duration::from_secs(10), |r| r.windows >= 1);

    handle.shutdown();
    handle.shutdown(); // second call must be a no-op
    server_thread.join().expect("server");
    handle.shutdown(); // post-join call must also be a no-op

    // The hub outlives its threads: every read surface still answers.
    let report = handle.health().expect("hub retained after join");
    assert!(report.windows >= 1);
    let text = handle.render_metrics().expect("renderer works after stop");
    let series = parse_exposition(&text).expect("valid exposition after stop");
    assert_eq!(series_value(&series, "shdc_serve_completed_total"), 40.0);

    // Draining is idempotent too: whatever was left comes out once.
    let first = handle.drain_events();
    let second = handle.drain_events();
    assert!(second.is_empty(), "second drain must be empty, got {second:?}");
    drop(first);
}

#[test]
fn scrapes_parse_and_reconcile_exactly_with_counter_deltas() {
    let cfg = monitored_cfg(85, 2, Some("127.0.0.1:0"), lenient_slo());
    let (server, handle) = Server::new(cfg, small_store(256, 86));
    let server_thread = std::thread::spawn(move || server.run());
    let addr = handle.metrics_addr().expect("listener bound at construction");
    let timeout = Duration::from_secs(2);

    // First batch of traffic, then scrape. classify is synchronous, so
    // at scrape time exactly 40 requests have completed.
    run_sequential(&handle, 87, 40);
    let (status, body) = http_get(addr, "/metrics", timeout).expect("scrape 1");
    assert_eq!(status, 200);
    let first = parse_exposition(&body).expect("every line parses");
    assert_eq!(series_value(&first, "shdc_serve_submitted_total"), 40.0);
    assert_eq!(series_value(&first, "shdc_serve_completed_total"), 40.0);
    assert!(series_value(&first, "shdc_configured_workers") >= 2.0);
    assert!(series_value(&first, "shdc_publisher_samples_total") >= 1.0);

    // Second batch: the two scrapes must reconcile exactly — counters
    // are monotone and the renderer reads them live.
    run_sequential(&handle, 88, 25);
    let (status, body) = http_get(addr, "/metrics", timeout).expect("scrape 2");
    assert_eq!(status, 200);
    let second = parse_exposition(&body).expect("every line parses");
    let c1 = series_value(&first, "shdc_serve_completed_total");
    let c2 = series_value(&second, "shdc_serve_completed_total");
    assert_eq!(c2 - c1, 25.0, "scrape delta must equal requests issued between scrapes");
    assert_eq!(series_value(&second, "shdc_serve_submitted_total"), 65.0);

    // Per-model series carry labels and agree with the global counter.
    let model_completed: f64 = second
        .iter()
        .filter(|s| s.name == "shdc_model_completed_total")
        .map(|s| s.value)
        .sum();
    assert_eq!(model_completed, 65.0, "per-model series sum to the global counter");

    // The other endpoints hold up their contracts.
    let (status, body) = http_get(addr, "/health", timeout).expect("/health");
    assert_eq!(status, 200);
    let health = shdc::util::json::Json::parse(&body).expect("valid JSON");
    let verdict = health
        .get("health")
        .and_then(|h| h.get("verdict"))
        .and_then(|v| v.as_str())
        .expect("verdict string");
    assert!(["healthy", "degraded", "breach"].contains(&verdict));

    let (status, body) = http_get(addr, "/snapshot", timeout).expect("/snapshot");
    assert_eq!(status, 200);
    shdc::util::json::Json::parse(&body).expect("snapshot is valid JSON");

    let (status, _) = http_get(addr, "/nope", timeout).expect("unknown path");
    assert_eq!(status, 404);

    // Non-GET methods are refused with 405 (raw request: http_get only
    // speaks GET).
    let mut conn = TcpStream::connect_timeout(&addr, timeout).expect("connect");
    conn.set_read_timeout(Some(timeout)).expect("timeout");
    conn.write_all(b"POST /metrics HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .expect("send");
    let mut resp = String::new();
    conn.read_to_string(&mut resp).expect("read");
    assert!(
        resp.starts_with("HTTP/1.1 405"),
        "POST must get 405, got {:?}",
        resp.lines().next()
    );

    handle.shutdown();
    server_thread.join().expect("server");
}

#[test]
fn stalled_worker_flips_health_to_breach_and_recovers() {
    // One worker that sleeps 400 ms before its first encode: with a
    // 10 ms publish window and stall_windows = 3, the watchdog must see
    // the no-progress run long before the worker wakes. The latency,
    // shed and error objectives are made unmissable so the stall is the
    // only possible breach reason.
    let slo = SloCfg {
        p99_target: Duration::from_secs(10),
        max_shed_rate: 1.1,
        error_budget: 1.0,
        stall_windows: 3,
    };
    let cfg = ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 1,
            n_workers: 1,
            queue_depth: 2,
            fault: FaultPlan {
                stall_once: Some((0, Duration::from_millis(400))),
                ..FaultPlan::default()
            },
            ..Default::default()
        },
        ..monitored_cfg(89, 1, None, slo)
    };
    let (server, handle) = Server::new(cfg, small_store(256, 90));
    let server_thread = std::thread::spawn(move || server.run());

    // The client blocks inside classify while the worker sleeps — that
    // is exactly the stall signature: in-flight > 0, completed frozen.
    let client = {
        let h = handle.clone();
        std::thread::spawn(move || run_sequential(&h, 91, 30))
    };

    let breach = wait_for_health(&handle, "stall breach", Duration::from_secs(10), |r| {
        r.stalled && r.verdict == Verdict::Breach
    });
    assert!(
        breach.reasons.iter().any(|r| r.contains("stalled")),
        "breach must cite the stall: {:?}",
        breach.reasons
    );

    client.join().expect("client");
    let recovered = wait_for_health(&handle, "recovery", Duration::from_secs(10), |r| {
        !r.stalled && r.verdict == Verdict::Healthy
    });
    assert!(recovered.reasons.is_empty(), "{:?}", recovered.reasons);

    // The transition events landed in order: stalled → breach while the
    // worker slept, resumed → recovered once it completed the backlog.
    let events = handle.drain_events();
    let kinds: Vec<EventKind> = events.iter().map(|e| e.kind).collect();
    for kind in [
        EventKind::PipelineStalled,
        EventKind::SloBreach,
        EventKind::PipelineResumed,
        EventKind::SloRecovered,
    ] {
        assert!(kinds.contains(&kind), "missing {kind:?} in {kinds:?}");
    }
    let stalled_at = kinds.iter().position(|&k| k == EventKind::PipelineStalled).unwrap();
    let resumed_at = kinds.iter().position(|&k| k == EventKind::PipelineResumed).unwrap();
    assert!(stalled_at < resumed_at, "stall precedes resume: {kinds:?}");

    handle.shutdown();
    server_thread.join().expect("server");

    // After recovery and drain, the report stays healthy and readable.
    let final_report = handle.health().expect("hub retained");
    assert!(!final_report.stalled);
}
