//! Allocation-regression harness for the coordinator's zero-allocation
//! claim: after warmup, the full reader → encode-worker → reorder →
//! consume loop — including the cross-thread buffer recycling added with
//! the work-stealing dispatch — must run **without a single heap
//! allocation per batch**.
//!
//! A counting `#[global_allocator]` wraps the system allocator; since it
//! is process-global it observes every pipeline thread, not just the
//! consumer. The consumer callback snapshots the counter once the
//! pipeline is warm (pools populated, recycle loops primed, every thread
//! past its first blocking park) and again a few hundred batches later;
//! the delta must be exactly zero. Any regression in the recycling loop
//! — a dropped return channel, a pool that stops fitting its buffers, a
//! reintroduced per-batch `Vec` — shows up here as a nonzero count.
//!
//! The whole file is one `#[test]` on purpose: libtest runs tests
//! concurrently and the allocator counter is global, so independent
//! tests would pollute each other's windows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use shdc::am::{AmStore, Precision};
use shdc::coordinator::{run_pipeline, CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::data::{RecordStream, SyntheticStream};
use shdc::encoding::BundleMethod;
use shdc::obs::health::SloCfg;
use shdc::obs::ObsCfg;
use shdc::serve::{ServeCfg, Server};

/// System allocator wrapper counting every allocation-ish event
/// (alloc, alloc_zeroed, realloc) and every dealloc.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static DEALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        DEALLOCS.fetch_add(1, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn counts() -> (u64, u64) {
    (ALLOCS.load(Ordering::SeqCst), DEALLOCS.load(Ordering::SeqCst))
}

/// Paper-shaped (scaled-down) encoder: sparse Bloom categorical +
/// structured SJLT numeric, concat-bundled — exercises the index pool,
/// the dense pool at two capacities (numeric codes vs bundled outputs)
/// and the flat numeric staging.
fn enc_cfg(seed: u64) -> EncoderCfg {
    EncoderCfg {
        cat: CatCfg::Bloom { d: 2048, k: 4 },
        num: NumCfg::Sjlt { d: 512, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed,
    }
}

/// Run `total` batches through the pipeline and return the allocation /
/// deallocation deltas observed between consumer-side batch `warmup` and
/// batch `warmup + window`.
///
/// During the first `stall` batches the consumer sleeps briefly: that
/// forces the encoded channel to fill at least once, so every worker
/// takes its first blocking-send park (the lazily initialized per-thread
/// channel context) inside the warmup, not inside the window.
fn measure(workers: usize, queue_depth: usize, warmup: u64, window: u64, total: u64) -> (u64, u64) {
    let batch_size = 48usize;
    let stream = SyntheticStream::new(SyntheticConfig::sampled(workers as u64));
    let stall = warmup / 3;
    let mut batches = 0u64;
    let mut start = (0u64, 0u64);
    let mut end = (0u64, 0u64);
    run_pipeline(
        stream,
        &enc_cfg(42),
        &CoordinatorCfg {
            batch_size,
            n_workers: workers,
            queue_depth,
            max_records: Some(batch_size as u64 * total),
            ..Default::default()
        },
        |b| {
            assert_eq!(b.encodings.len(), b.labels.len());
            batches += 1;
            if batches < stall {
                std::thread::sleep(Duration::from_micros(100));
            }
            if batches == warmup {
                start = counts();
            }
            if batches == warmup + window {
                end = counts();
            }
            true
        },
    );
    assert!(
        batches >= warmup + window,
        "pipeline ended before the measurement window ({batches} batches)"
    );
    (end.0 - start.0, end.1 - start.1)
}

/// Assert a clean (zero-alloc, zero-dealloc) window, retrying up to
/// three runs. A genuine per-batch regression allocates on *every* batch
/// of *every* window (hundreds of counts), so retries cannot mask it;
/// they only absorb one-off scheduler noise (e.g. a descheduled worker
/// forcing a single reorder-ring growth past its preallocated hint).
fn assert_alloc_free(label: &str, workers: usize, queue_depth: usize) {
    let mut observed = Vec::new();
    for attempt in 0..3 {
        let (allocs, deallocs) = measure(workers, queue_depth, 300, 200, 620);
        if allocs == 0 && deallocs == 0 {
            return;
        }
        observed.push((attempt, allocs, deallocs));
    }
    panic!(
        "{label}: every steady-state window allocated — per-batch \
         allocation has regressed (attempt, allocs, deallocs): {observed:?}"
    );
}

/// Closed-loop serve phase: one client rotates record buffers through
/// `classify` while the allocation counters watch every thread — the
/// submission queue, slot machinery, micro-batcher swap path, encode
/// workers, AM scoring scratch and response hand-back must all run
/// without per-request heap traffic once warm. The `obs` config is
/// threaded through so the same window pins the tracer's claims:
/// disabled tracing adds nothing, and *enabled* sampling stays
/// heap-free too (Copy contexts, preallocated rings and histograms).
/// `slo` likewise: enabling the metrics publisher must not put a single
/// allocation on the request path — the publisher thread owns all
/// snapshot/ring/report allocation, and `classify` never touches the
/// hub.
fn measure_serve(
    obs: ObsCfg,
    slo: Option<SloCfg>,
    warmup: u64,
    window: u64,
    total: u64,
) -> (u64, u64) {
    // 2-class prototype store at the encoder's output dim (2048 + 512).
    let d = 2048 + 512;
    let mut rng = shdc::util::rng::Rng::new(7);
    let rows: Vec<Vec<f32>> =
        (0..2).map(|_| (0..d).map(|_| rng.normal_f32()).collect()).collect();
    let store = AmStore::from_prototypes(d, &rows, None);
    let cfg = ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 4,
            n_workers: 2,
            queue_depth: 4,
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(50),
        queue_cap: 16,
        slots: 8,
        precision: Precision::Binary, // exercises query packing too
        // The zero-alloc window is pinned to the single-shard scan: with
        // one shard the sharded store scores inline on the consumer (no
        // scoped scorer spawns), so the whole serve loop stays heap-free.
        // Multi-shard scans trade one spawn per micro-batch for scan
        // parallelism and are exercised in tests/serve_smoke.rs instead.
        am_shards: 1,
        obs,
        slo,
        // Long enough that no publisher tick lands inside the measured
        // window: the phase pins that *enabling* publishing leaves the
        // request path untouched (ticks themselves run — and allocate —
        // on the publisher thread, outside the window by construction;
        // the spawn tick precedes warmup, the closing tick follows the
        // window).
        publish_interval: Duration::from_secs(10),
        ..ServeCfg::new(enc_cfg(43))
    };
    let (server, handle) = Server::new(cfg, store);
    let server_thread = std::thread::spawn(move || server.run());
    let mut stream = SyntheticStream::new(SyntheticConfig::sampled(44));
    let mut rec = stream.next_record().expect("unbounded");
    let mut start = (0u64, 0u64);
    let mut end = (0u64, 0u64);
    for i in 1..=total {
        let resp = handle.classify(rec).expect("serve");
        rec = resp.record;
        stream.refill_record(&mut rec);
        if i == warmup {
            start = counts();
        }
        if i == warmup + window {
            end = counts();
        }
    }
    handle.shutdown();
    server_thread.join().expect("server");
    (end.0 - start.0, end.1 - start.1)
}

fn assert_serve_alloc_free(label: &str, obs: ObsCfg, slo: Option<SloCfg>) {
    let mut observed = Vec::new();
    for attempt in 0..3 {
        let (allocs, deallocs) = measure_serve(obs, slo, 400, 300, 720);
        if allocs == 0 && deallocs == 0 {
            return;
        }
        observed.push((attempt, allocs, deallocs));
    }
    panic!(
        "{label}: every steady-state window allocated — per-request \
         allocation has regressed (attempt, allocs, deallocs): {observed:?}"
    );
}

#[test]
fn steady_state_pipeline_is_allocation_free() {
    // Phase 1: single worker — the fully deterministic baseline.
    assert_alloc_free("single-worker", 1, 8);
    // Phase 2: multi-worker with stealing and cross-thread recycling
    // live. Same contract: once warm, not one allocation per batch.
    assert_alloc_free("3-worker stealing", 3, 4);
    // Phase 3: the serving loop — submit → micro-batch → encode → AM
    // score → respond — is allocation-free per request once warm.
    assert_serve_alloc_free("closed-loop serve", ObsCfg::default(), None);
    // Phase 4: same loop with stage-span tracing live (1-in-16
    // sampling). Sampled requests carry Copy contexts and land in
    // preallocated rings/histograms, so the window must still be clean.
    assert_serve_alloc_free(
        "closed-loop serve traced",
        ObsCfg { sample_every: 16, ring_cap: 512 },
        None,
    );
    // Phase 5: same loop with the SLO watchdog / metrics publisher
    // enabled. All publishing allocation belongs to the publisher
    // thread (spawn tick before warmup, closing tick after the window);
    // the request path must stay exactly as clean as phase 3.
    assert_serve_alloc_free(
        "closed-loop serve publishing",
        ObsCfg::default(),
        Some(SloCfg::default()),
    );
}
