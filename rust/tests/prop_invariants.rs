//! Property-based invariant tests (hand-rolled: proptest is not cached
//! offline). Each property runs across a seeded sweep of random cases
//! with shrink-free but reproducible failure reporting (the case seed is
//! in the assertion message).

use shdc::encoding::kernels::{self, scalar};
use shdc::encoding::{
    bundle, sparse_from_indices, BloomEncoder, BundleMethod, CodebookEncoder, DenseHashEncoder,
    DenseHashMode, Encoding, Sjlt,
};
use shdc::hash::{IndexHash, MurmurHash, PolyHash};
use shdc::model::{auc, LogisticModel};
use shdc::util::rng::Rng;

/// Run `prop` over `cases` seeded random cases.
fn forall(cases: u64, mut prop: impl FnMut(u64, &mut Rng)) {
    for case in 0..cases {
        let mut rng = Rng::new(0x9e3779b97f4a7c15 ^ case.wrapping_mul(0x2545F4914F6CDD1D));
        prop(case, &mut rng);
    }
}

fn random_set(rng: &mut Rng, max_s: usize, universe: u64) -> Vec<u64> {
    let s = 1 + rng.below_usize(max_s);
    let mut v: Vec<u64> = (0..s).map(|_| rng.below(universe)).collect();
    v.sort_unstable();
    v.dedup();
    v
}

#[test]
fn prop_bloom_encoding_invariants() {
    forall(60, |case, rng| {
        let d = 64 + rng.below_usize(4000);
        let k = 1 + rng.below_usize(8);
        let enc = BloomEncoder::new(d, k, rng);
        let set = random_set(rng, 40, 1 << 40);
        let code = enc.encode_set(&set);
        // (1) dimension, (2) nnz bound, (3) sorted unique indices.
        assert_eq!(code.dim(), d, "case {case}");
        assert!(code.nnz() <= set.len() * k, "case {case}");
        if let Encoding::SparseBinary { indices, .. } = &code {
            let mut sorted = indices.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(&sorted, indices, "case {case}: not sorted-unique");
            assert!(indices.iter().all(|&i| (i as usize) < d), "case {case}");
        } else {
            panic!("case {case}: bloom must be sparse");
        }
        // (4) permutation invariance.
        let mut shuffled = set.clone();
        rng.shuffle(&mut shuffled);
        assert_eq!(code, enc.encode_set(&shuffled), "case {case}");
        // (5) monotonicity: adding symbols never clears bits.
        let mut bigger = set.clone();
        bigger.push(rng.below(1 << 40));
        let code2 = enc.encode_set(&bigger);
        assert!(code2.dot(&code) as usize == code.nnz(), "case {case}: superset lost bits");
    });
}

#[test]
fn prop_bloom_membership_complete() {
    // No false negatives, ever (the Bloom filter's defining guarantee).
    forall(40, |case, rng| {
        let d = 512 + rng.below_usize(8192);
        let k = 1 + rng.below_usize(6);
        let enc = BloomEncoder::new(d, k, rng);
        let set = random_set(rng, 30, 1 << 30);
        let code = enc.encode_set(&set);
        for &a in &set {
            assert!(enc.query(&code, a), "case {case}: false negative {a}");
        }
    });
}

#[test]
fn prop_sparse_vector_dot_symmetry_and_bounds() {
    forall(80, |case, rng| {
        let d = 16 + rng.below_usize(2000);
        let a = sparse_from_indices(
            (0..rng.below_usize(50)).map(|_| rng.below(d as u64) as u32).collect(),
            d,
        );
        let b = sparse_from_indices(
            (0..rng.below_usize(50)).map(|_| rng.below(d as u64) as u32).collect(),
            d,
        );
        let ab = a.dot(&b);
        assert_eq!(ab, b.dot(&a), "case {case}: dot asymmetric");
        assert!(ab <= a.nnz().min(b.nnz()) as f64, "case {case}");
        assert!(ab >= 0.0, "case {case}");
        // Densified agreement.
        let da = Encoding::Dense(a.to_dense());
        let db = Encoding::Dense(b.to_dense());
        assert_eq!(ab, da.dot(&db), "case {case}: sparse/dense dot mismatch");
    });
}

#[test]
fn prop_bundle_or_is_union_sum_is_sum() {
    forall(60, |case, rng| {
        let d = 8 + rng.below_usize(512);
        let mk = |rng: &mut Rng| {
            sparse_from_indices(
                (0..rng.below_usize(30)).map(|_| rng.below(d as u64) as u32).collect(),
                d,
            )
        };
        let a = mk(rng);
        let b = mk(rng);
        let or = bundle(&a, &b, BundleMethod::ThresholdedSum).to_dense();
        let sum = bundle(&a, &b, BundleMethod::Sum).to_dense();
        let cat = bundle(&a, &b, BundleMethod::Concat).to_dense();
        let (da, db) = (a.to_dense(), b.to_dense());
        for i in 0..d {
            assert_eq!(or[i], da[i].max(db[i]), "case {case} OR coord {i}");
            assert_eq!(sum[i], da[i] + db[i], "case {case} SUM coord {i}");
            assert_eq!(cat[i], da[i], "case {case} concat low half");
            assert_eq!(cat[d + i], db[i], "case {case} concat high half");
        }
    });
}

#[test]
fn prop_hash_families_uniform_and_deterministic() {
    forall(20, |case, rng| {
        let d = 2 + rng.below(500);
        let mh = MurmurHash::new(rng.next_u32());
        let ph = PolyHash::new(2 + rng.below_usize(6), rng);
        let mut counts = vec![0usize; d as usize];
        let n = 4000u64;
        for key in 0..n {
            let i = mh.index(key, d);
            let j = ph.index(key, d);
            assert_eq!(i, mh.index(key, d), "case {case}: murmur nondeterministic");
            assert_eq!(j, ph.index(key, d), "case {case}: poly nondeterministic");
            assert!(i < d && j < d, "case {case}: out of range");
            counts[i as usize] += 1;
        }
        // Rough uniformity: no bucket more than 5x expectation (d small
        // enough that expectation >= 8).
        let expect = n as f64 / d as f64;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) < 5.0 * expect + 10.0,
                "case {case}: bucket {i} has {c} (expect {expect})"
            );
        }
    });
}

#[test]
fn prop_codebook_bundling_linear() {
    forall(25, |case, rng| {
        let d = 32 + rng.below_usize(500);
        let mut enc = CodebookEncoder::new(d, rng.next_u64());
        let a = random_set(rng, 10, 1000);
        let b: Vec<u64> = random_set(rng, 10, 1000).iter().map(|x| x + 2000).collect();
        let ea = enc.try_encode(&a).unwrap().to_dense();
        let eb = enc.try_encode(&b).unwrap().to_dense();
        let mut both = a.clone();
        both.extend(&b);
        let eab = enc.try_encode(&both).unwrap().to_dense();
        for i in 0..d {
            assert_eq!(eab[i], ea[i] + eb[i], "case {case} coord {i}");
        }
    });
}

#[test]
fn prop_dense_hash_codes_deterministic_pm1() {
    forall(25, |case, rng| {
        let d = 16 + rng.below_usize(300);
        let mode = if rng.bernoulli(0.5) { DenseHashMode::Literal } else { DenseHashMode::Packed };
        let enc = DenseHashEncoder::new(d, mode, rng);
        let sym = rng.below(1 << 40);
        let a = enc.encode_symbol(sym).to_dense();
        assert_eq!(a, enc.encode_symbol(sym).to_dense(), "case {case}");
        assert!(a.iter().all(|&x| x == 1.0 || x == -1.0), "case {case}");
    });
}

#[test]
fn prop_sjlt_norm_bounded_by_k_normsq() {
    // ||phi(x)||^2 <= k ||x||^2 always (each chunk is a partition sum).
    forall(40, |case, rng| {
        let n = 2 + rng.below_usize(30);
        let k = 1 + rng.below_usize(4);
        let dk = 4 + rng.below_usize(60);
        let s = Sjlt::new(dk * k, n, k, rng);
        let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let normsq: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
        let e = s.encode_record(&x);
        // Cauchy-Schwarz within buckets can only lose mass to cancellation.
        assert!(
            e.norm_sq() <= k as f64 * normsq * n as f64 + 1e-6,
            "case {case}: {} > {}",
            e.norm_sq(),
            k as f64 * normsq * n as f64
        );
    });
}

/// The active kernel backend (std::simd under `--features simd`, scalar
/// otherwise) is bit-identical to the scalar backend on random shapes —
/// including empty inputs and tails that are not a multiple of the SIMD
/// lane width. The deeper structured suites (alignment sweeps, IEEE edge
/// values, encoder-level wiring) live in tests/kernel_equivalence.rs.
#[test]
fn prop_kernels_bit_identical_to_scalar() {
    forall(80, |case, rng| {
        let len = rng.below_usize(300);
        // axpy
        let col: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        let base: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        let xv = rng.normal_f32();
        let (mut za, mut zb) = (base.clone(), base.clone());
        scalar::axpy(&mut za, &col, xv);
        kernels::axpy(&mut zb, &col, xv);
        assert!(
            za.iter().zip(&zb).all(|(a, b)| a.to_bits() == b.to_bits()),
            "case {case}: axpy len={len} diverged"
        );
        // sign_quantize
        let (mut qa, mut qb) = (base.clone(), base.clone());
        scalar::sign_quantize(&mut qa);
        kernels::sign_quantize(&mut qb);
        assert_eq!(qa, qb, "case {case}: sign_quantize len={len}");
        // scatter_signed (collision-heavy small output)
        let out_len = 1 + rng.below_usize(1 + len);
        let eta: Vec<u32> = (0..len).map(|_| rng.below(out_len as u64) as u32).collect();
        let sigma: Vec<i8> = (0..len).map(|_| rng.sign() as i8).collect();
        let (mut sa, mut sb) = (vec![0.0f32; out_len], vec![0.0f32; out_len]);
        scalar::scatter_signed(&base, &eta, &sigma, &mut sa);
        kernels::scatter_signed(&base, &eta, &sigma, &mut sb);
        assert!(
            sa.iter().zip(&sb).all(|(a, b)| a.to_bits() == b.to_bits()),
            "case {case}: scatter len={len} out={out_len} diverged"
        );
        // unpack_sign_bits_accumulate
        let word = rng.next_u32();
        let ulen = rng.below_usize(33);
        let (mut ua, mut ub) = (vec![0.0f32; ulen], vec![0.0f32; ulen]);
        scalar::unpack_sign_bits_accumulate(word, &mut ua);
        kernels::unpack_sign_bits_accumulate(word, &mut ub);
        assert_eq!(ua, ub, "case {case}: unpack len={ulen}");
    });
}

/// The bitset mark/sweep dedup (Bloom scratch path) equals the legacy
/// sort+dedup kernel on the same staged coordinates, and leaves the
/// bitset all-zero — for the active backend, whichever it is.
#[test]
fn prop_bitset_sweep_matches_sort_dedup() {
    forall(60, |case, rng| {
        let d = 1 + rng.below_usize(4096);
        let n = rng.below_usize(200);
        let staged: Vec<u32> = (0..n).map(|_| rng.below(d as u64) as u32).collect();
        let mut bs = vec![0u64; d.div_ceil(64)];
        let mut swept: Vec<u32> = Vec::new();
        if !staged.is_empty() {
            let (lo, hi) = kernels::bitset_mark(&mut bs, &staged);
            kernels::bitset_sweep(&mut bs, lo, hi, &mut swept);
        }
        let mut want = staged.clone();
        kernels::sort_dedup(&mut want);
        assert_eq!(swept, want, "case {case}: d={d} n={n}");
        assert!(bs.iter().all(|&w| w == 0), "case {case}: dirty bitset");
    });
}

#[test]
fn prop_sgd_sparse_dense_equivalence() {
    forall(20, |case, rng| {
        let d = 16 + rng.below_usize(200);
        let batch_sparse: Vec<(Encoding, bool)> = (0..8)
            .map(|_| {
                let idx: Vec<u32> =
                    (0..1 + rng.below_usize(10)).map(|_| rng.below(d as u64) as u32).collect();
                (sparse_from_indices(idx, d), rng.bernoulli(0.5))
            })
            .collect();
        let batch_dense: Vec<(Encoding, bool)> = batch_sparse
            .iter()
            .map(|(e, y)| (Encoding::Dense(e.to_dense()), *y))
            .collect();
        let mut ms = LogisticModel::new(d);
        let mut md = LogisticModel::new(d);
        for _ in 0..3 {
            ms.sgd_step(&batch_sparse, 0.4);
            md.sgd_step(&batch_dense, 0.4);
        }
        for i in 0..d {
            assert!(
                (ms.theta[i] - md.theta[i]).abs() < 1e-4,
                "case {case}: coord {i} diverged"
            );
        }
    });
}

#[test]
fn prop_auc_invariant_to_monotone_transform() {
    forall(30, |case, rng| {
        let n = 20 + rng.below_usize(300);
        let scores: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.4)).collect();
        if labels.iter().all(|&l| l) || labels.iter().all(|&l| !l) {
            return;
        }
        let a1 = auc(&scores, &labels);
        // Monotone transforms preserve ranks hence AUC.
        let t: Vec<f64> = scores.iter().map(|&s| (s * 0.5).exp() + 3.0).collect();
        let a2 = auc(&t, &labels);
        assert!((a1 - a2).abs() < 1e-12, "case {case}: {a1} vs {a2}");
        // Label flip mirrors AUC.
        let flipped: Vec<bool> = labels.iter().map(|&l| !l).collect();
        let a3 = auc(&scores, &flipped);
        assert!((a1 + a3 - 1.0).abs() < 1e-9, "case {case}");
    });
}

#[test]
fn prop_sign_binarize_round_trip() {
    // The AM store's sign binarization must agree with sign_quantize's
    // convention on every coordinate, and with the mathematical sign on
    // every non-zero coordinate — so binarized prototypes preserve
    // exactly the information the theory says they must.
    use shdc::am::{pack_signs, words_for};
    forall(60, |case, rng| {
        let d = 1 + rng.below_usize(600);
        let v: Vec<f32> = (0..d)
            .map(|i| {
                if rng.bernoulli(0.15) {
                    [0.0f32, -0.0, f32::MIN_POSITIVE, -f32::MIN_POSITIVE][i % 4]
                } else {
                    rng.normal_f32()
                }
            })
            .collect();
        let mut bits = Vec::new();
        pack_signs(&v, &mut bits);
        assert_eq!(bits.len(), words_for(d), "case {case}");
        // Unpack and compare against the sign_quantize reference.
        let mut sq = v.clone();
        kernels::sign_quantize(&mut sq);
        for (i, (&orig, &s)) in v.iter().zip(&sq).enumerate() {
            let bit = (bits[i >> 6] >> (i & 63)) & 1;
            let unpacked = if bit == 1 { -1.0f32 } else { 1.0 };
            assert_eq!(unpacked, s, "case {case}: coord {i} of {orig:?}");
            if orig != 0.0 {
                // Non-zero coords: binarized sign == mathematical sign.
                assert_eq!(unpacked > 0.0, orig > 0.0, "case {case}: coord {i}");
            }
        }
        // Pad bits of the last word stay clear.
        if d % 64 != 0 {
            let pad = bits[d >> 6] >> (d & 63);
            assert_eq!(pad, 0, "case {case}: dirty pad bits");
        }
    });
}

#[test]
fn prop_int8_quantize_round_trip() {
    // Symmetric int8 quantization: reconstruction within scale/2 on
    // every coordinate, and sign(q) agrees with sign(v) whenever the
    // coordinate doesn't round to zero.
    use shdc::am::quantize_i8;
    forall(60, |case, rng| {
        let d = 1 + rng.below_usize(400);
        let amp = (rng.normal() * 2.0).exp() as f32; // sweep dynamic range
        let v: Vec<f32> = (0..d)
            .map(|_| if rng.bernoulli(0.1) { 0.0 } else { rng.normal_f32() * amp })
            .collect();
        let mut q = Vec::new();
        let scale = quantize_i8(&v, &mut q);
        assert!(scale > 0.0, "case {case}");
        assert_eq!(q.len(), d);
        for (i, (&x, &qi)) in v.iter().zip(&q).enumerate() {
            let rec = qi as f32 * scale;
            assert!(
                (x - rec).abs() <= scale * 0.5 + scale * 1e-4,
                "case {case}: coord {i}: {x} -> {qi} ({rec}), scale {scale}"
            );
            if qi != 0 {
                assert_eq!((qi > 0), (x > 0.0), "case {case}: coord {i} sign flip");
            }
        }
        // The extreme coordinate saturates the int8 range (symmetric
        // quantization uses the full ±127 span).
        if v.iter().any(|&x| x != 0.0) {
            assert!(q.iter().any(|&qi| qi.abs() == 127), "case {case}: range unused");
        }
    });
}

/// [`shdc::am::AmBuilder::merge`] is **commutative bit for bit on any
/// floats**: merged sums are coordinate-wise `a + b`, and IEEE-754
/// addition commutes exactly (`a + b == b + a` for every pair, including
/// signed zeros produced by summing normals). This is the half of the
/// distributed-build contract that holds unconditionally.
#[test]
fn prop_am_builder_merge_commutative_on_any_floats() {
    use shdc::am::AmBuilder;
    forall(30, |case, rng| {
        let d = 4 + rng.below_usize(120);
        let n_classes = 1 + rng.below_usize(6);
        let mut a = AmBuilder::new(d, n_classes);
        let mut b = AmBuilder::new(d, n_classes);
        for builder in [&mut a, &mut b] {
            for _ in 0..rng.below_usize(20) {
                let class = rng.below_usize(n_classes);
                let v: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 3.0).collect();
                builder.add(class, &Encoding::Dense(v));
            }
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.counts(), ba.counts(), "case {case}: counts not commutative");
        assert!(
            ab.sums().iter().zip(ba.sums()).all(|(x, y)| x.to_bits() == y.to_bits()),
            "case {case}: merge not bitwise commutative (d={d}, classes={n_classes})"
        );
    });
}

/// The full distributed-build contract: with **integer-valued sums**
/// (sparse 0/1 encodings, counts far below 2^24 so every partial sum is
/// exact in f32), an N-way shard-split build — examples scattered across
/// N shard-local builders, merged in *any* association — is bit-identical
/// to the single-builder build, through to the finished store's
/// prototypes and biases. Left-fold and pairwise-tree merge orders are
/// both checked against the sequential reference.
#[test]
fn prop_am_builder_shard_split_build_bit_identical() {
    use shdc::am::AmBuilder;
    forall(25, |case, rng| {
        let d = 8 + rng.below_usize(200);
        let n_classes = 1 + rng.below_usize(5);
        let n_shards = 1 + rng.below_usize(6);
        let n_examples = rng.below_usize(60);
        let examples: Vec<(usize, Encoding)> = (0..n_examples)
            .map(|_| {
                let class = rng.below_usize(n_classes);
                let idx: Vec<u32> =
                    (0..rng.below_usize(16)).map(|_| rng.below(d as u64) as u32).collect();
                (class, sparse_from_indices(idx, d))
            })
            .collect();

        // Sequential reference build.
        let mut single = AmBuilder::new(d, n_classes);
        for (class, enc) in &examples {
            single.add(*class, enc);
        }

        // Shard-local builders, examples scattered round-robin.
        let mut shards: Vec<AmBuilder> =
            (0..n_shards).map(|_| AmBuilder::new(d, n_classes)).collect();
        for (i, (class, enc)) in examples.iter().enumerate() {
            shards[i % n_shards].add(*class, enc);
        }

        // Left fold: (((s0 + s1) + s2) + ...).
        let mut folded = shards[0].clone();
        for shard in &shards[1..] {
            folded.merge(shard);
        }
        // Pairwise tree: merge adjacent pairs until one remains — a
        // different association of the same sums.
        let mut level = shards.clone();
        while level.len() > 1 {
            let mut next = Vec::with_capacity(level.len().div_ceil(2));
            for pair in level.chunks(2) {
                let mut m = pair[0].clone();
                if let Some(rhs) = pair.get(1) {
                    m.merge(rhs);
                }
                next.push(m);
            }
            level = next;
        }
        let tree = level.pop().unwrap();

        for (name, merged) in [("left-fold", &folded), ("pairwise-tree", &tree)] {
            assert_eq!(merged.counts(), single.counts(), "case {case}: {name} counts");
            assert!(
                merged.sums().iter().zip(single.sums()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "case {case}: {name} sums diverged (d={d}, shards={n_shards})"
            );
        }

        // Bit-identity survives finish() into the served store.
        let normalize = rng.bernoulli(0.5);
        let ref_store = single.finish(normalize);
        let merged_store = folded.finish(normalize);
        for c in 0..n_classes {
            assert!(
                ref_store
                    .prototype(c)
                    .iter()
                    .zip(merged_store.prototype(c))
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "case {case}: finished prototype {c} diverged"
            );
            assert_eq!(
                ref_store.bias(c).to_bits(),
                merged_store.bias(c).to_bits(),
                "case {case}: finished bias {c} diverged"
            );
        }
    });
}

#[test]
fn prop_am_precisions_rank_consistently_on_separated_classes() {
    // End-to-end AM property: when class prototypes are well separated,
    // every precision (f32, int8, binary) must put a query drawn near a
    // prototype into that prototype's class.
    use shdc::am::{AmScratch, AmStore, Precision};
    forall(20, |case, rng| {
        let d = 128 + rng.below_usize(256);
        let n_classes = 2 + rng.below_usize(4);
        let rows: Vec<Vec<f32>> = (0..n_classes)
            .map(|_| (0..d).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect())
            .collect();
        let store = AmStore::from_prototypes(d, &rows, None);
        let mut scratch = AmScratch::new();
        for (c, row) in rows.iter().enumerate() {
            // Query = prototype + small noise (flip ~5% of signs).
            let q: Vec<f32> = row
                .iter()
                .map(|&x| if rng.bernoulli(0.05) { -x } else { x })
                .collect();
            let enc = Encoding::Dense(q);
            for prec in [Precision::F32, Precision::Int8, Precision::Binary] {
                let (top, _) = store.top1(&enc, prec, &mut scratch);
                assert_eq!(
                    top as usize, c,
                    "case {case}: {prec:?} misclassified a near-prototype query \
                     (d={d}, classes={n_classes})"
                );
            }
        }
    });
}
