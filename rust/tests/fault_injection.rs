//! Fault-injection matrix for the overload-control / fault-tolerance
//! layer (ISSUE 6 tentpole): drive the coordinator and the serving stack
//! through [`FaultPlan`]-injected worker panics, worker stalls, a
//! stalled batcher (queue saturation) and a lossy recycle path, and
//! assert the bounded-degradation contract:
//!
//! * the server/pipeline never deadlocks — every run terminates;
//! * every submitted request reaches a terminal outcome: a [`Response`]
//!   or an explicit [`ServeError`] — never a stranded client;
//! * surviving results are bit-identical to a no-fault run (panics cost
//!   exactly their batch, nothing leaks across);
//! * the shed/expired/failed/panic counters in [`ServeSnapshot`] /
//!   [`StatsSnapshot`] match the injected plan and the client-observed
//!   outcome tallies.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Once};
use std::thread;
use std::time::{Duration, Instant};

use shdc::am::{AmScratch, AmStore, Precision};
use shdc::coordinator::{
    run_pipeline, CatCfg, CoordinatorCfg, EncoderCfg, FaultPlan, NumCfg,
};
use shdc::data::synthetic::SyntheticConfig;
use shdc::data::{RecordStream, SyntheticStream};
use shdc::encoding::{BundleMethod, Encoding};
use shdc::serve::{
    run_open_loop, AdmissionPolicy, OpenLoadCfg, RequestOpts, ServeCfg, ServeError, Server,
};
use shdc::util::rng::Rng;

/// Injected panics are part of the plan, not noise: suppress their
/// backtrace spew (and only theirs) so a green run has a readable log.
fn quiet_injected_panics() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.contains("shdc injected fault"))
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

fn encoder_cfg(seed: u64) -> EncoderCfg {
    EncoderCfg {
        cat: CatCfg::Bloom { d: 256, k: 2 },
        num: NumCfg::None,
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed,
    }
}

fn small_store(d: usize) -> AmStore {
    let mut rng = Rng::new(99);
    let rows: Vec<Vec<f32>> =
        (0..2).map(|_| (0..d).map(|_| rng.normal_f32()).collect()).collect();
    AmStore::from_prototypes(d, &rows, None)
}

/// Each delivered batch's `(seq, failed, encodings)`.
type BatchLog = Vec<(u64, bool, Vec<Encoding>)>;

/// Run the encode pipeline over a fixed synthetic prefix, collecting
/// every delivered batch.
fn collect_batches(
    fault: FaultPlan,
    max_panics: u32,
) -> (BatchLog, shdc::coordinator::StatsSnapshot) {
    let data = SyntheticConfig::sampled(7);
    let stream = SyntheticStream::new(data);
    let coord = CoordinatorCfg {
        batch_size: 16,
        n_workers: 3,
        queue_depth: 2,
        max_records: Some(640),
        max_worker_panics: max_panics,
        fault,
        ..Default::default()
    };
    let mut out: BatchLog = Vec::new();
    let stats = run_pipeline(stream, &encoder_cfg(7), &coord, |batch| {
        out.push((batch.seq, batch.failed, batch.encodings.drain(..).collect()));
        true
    });
    (out, stats.snapshot())
}

#[test]
fn injected_panic_fails_exactly_one_batch_others_bit_identical() {
    quiet_injected_panics();
    let (clean, clean_stats) = collect_batches(FaultPlan::default(), 3);
    let fault = FaultPlan { panic_on_seq: vec![3], ..FaultPlan::default() };
    let (faulted, stats) = collect_batches(fault, 3);

    assert_eq!(clean.len(), 40, "640 records / batch 16");
    assert_eq!(faulted.len(), clean.len(), "failed batch must still occupy its seq slot");
    for ((cs, cf, ce), (fs, ff, fe)) in clean.iter().zip(faulted.iter()) {
        assert_eq!(cs, fs, "stream order preserved");
        assert!(!cf, "no-fault run must not fail batches");
        if *fs == 3 {
            assert!(*ff, "injected seq must arrive failed");
            assert!(fe.is_empty(), "failed batch carries no encodings");
        } else {
            assert!(!ff, "panic must cost exactly its batch");
            assert_eq!(ce, fe, "surviving batch {fs} must be bit-identical");
        }
    }
    assert_eq!(stats.worker_panics, 1);
    assert_eq!(stats.batches_failed, 1);
    assert_eq!(stats.workers_retired, 0, "budget 3 absorbs one panic");
    assert_eq!(stats.records_encoded, clean_stats.records_encoded - 16);
}

#[test]
fn panic_budget_exhaustion_retires_workers_and_stops_cleanly() {
    quiet_injected_panics();
    // One worker, zero panic budget: the first injected panic retires it,
    // which (last live worker) must stop the whole pipeline instead of
    // leaving the reader parked behind a deque nobody will drain.
    let data = SyntheticConfig::sampled(8);
    let stream = SyntheticStream::new(data);
    let coord = CoordinatorCfg {
        batch_size: 16,
        n_workers: 1,
        queue_depth: 2,
        max_records: Some(320),
        max_worker_panics: 0,
        fault: FaultPlan { panic_on_seq: vec![0], ..FaultPlan::default() },
        ..Default::default()
    };
    let mut seen: Vec<(u64, bool)> = Vec::new();
    let stats = run_pipeline(stream, &encoder_cfg(8), &coord, |batch| {
        seen.push((batch.seq, batch.failed));
        true
    });
    let snap = stats.snapshot();
    assert_eq!(snap.worker_panics, 1);
    assert_eq!(snap.workers_retired, 1);
    assert!(!seen.is_empty() && seen[0] == (0, true), "failed batch still delivered: {seen:?}");
    // Everything delivered was in-order from seq 0; the run simply ends
    // early instead of hanging (reaching this line is the real assert).
    for (i, (seq, _)) in seen.iter().enumerate() {
        assert_eq!(*seq, i as u64);
    }
}

#[test]
fn drop_recycle_falls_back_to_allocator_with_identical_output() {
    let (clean, _) = collect_batches(FaultPlan::default(), 3);
    let fault = FaultPlan { drop_recycle: true, ..FaultPlan::default() };
    let (dropped, stats) = collect_batches(fault, 3);
    assert_eq!(clean.len(), dropped.len());
    for ((cs, _, ce), (ds, df, de)) in clean.iter().zip(dropped.iter()) {
        assert_eq!(cs, ds);
        assert!(!df);
        assert_eq!(ce, de, "lossy recycle path must not change results");
    }
    assert_eq!(stats.recycle_misses, clean.len() as u64, "every shell dropped");
    assert_eq!(stats.buffers_recycled, 0, "nothing flows back through the recycle channel");
}

fn serve_cfg_with(fault: FaultPlan, seed: u64) -> ServeCfg {
    ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 8,
            n_workers: 2,
            queue_depth: 2,
            fault,
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(200),
        queue_cap: 64,
        slots: 32,
        ..ServeCfg::new(encoder_cfg(seed))
    }
}

#[test]
fn serve_survives_worker_panic_failing_requests_explicitly() {
    quiet_injected_panics();
    let enc_cfg = encoder_cfg(50);
    let store = small_store(256);
    let offline_store = store.clone();
    let fault = FaultPlan { panic_on_seq: vec![1, 4], ..FaultPlan::default() };
    let (server, handle) = Server::new(serve_cfg_with(fault, 50), store);
    let server_thread = thread::spawn(move || server.run());

    let ok = Arc::new(AtomicU64::new(0));
    let failed = Arc::new(AtomicU64::new(0));
    let clients: Vec<_> = (0..4)
        .map(|c| {
            let h = handle.clone();
            let ok = Arc::clone(&ok);
            let failed = Arc::clone(&failed);
            let offline_store = offline_store.clone();
            let enc_cfg = enc_cfg.clone();
            thread::spawn(move || {
                let mut offline_enc = enc_cfg.build();
                let mut scratch = AmScratch::new();
                let mut stream =
                    SyntheticStream::new(SyntheticConfig::sampled(600 + c as u64));
                for _ in 0..50 {
                    let rec = stream.next_record().unwrap();
                    let code = offline_enc.encode(&rec);
                    let (want_class, want_score) =
                        offline_store.top1(&code, Precision::F32, &mut scratch);
                    match h.classify(rec) {
                        Ok(resp) => {
                            // Surviving responses stay bit-identical to
                            // the offline reference — the panic didn't
                            // corrupt its worker's rebuilt encoder.
                            assert_eq!(resp.top_class, want_class);
                            assert_eq!(resp.score, want_score);
                            ok.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ServeError::Internal) => {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => panic!("unexpected terminal outcome: {e:?}"),
                    }
                }
            })
        })
        .collect();
    for cthread in clients {
        cthread.join().expect("client must terminate");
    }
    handle.shutdown();
    let pipeline = server_thread.join().expect("server").snapshot();
    let snap = handle.stats();

    let (ok, failed) = (ok.load(Ordering::Relaxed), failed.load(Ordering::Relaxed));
    assert_eq!(ok + failed, 200, "every request reached a terminal outcome");
    assert!(failed > 0, "two injected panics must fail at least one request");
    assert_eq!(snap.failed, failed, "server-side failed counter matches clients");
    assert_eq!(snap.completed, snap.submitted, "no admitted request was stranded");
    assert_eq!(pipeline.worker_panics, 2, "both injected seqs panicked");
    assert_eq!(pipeline.batches_failed, 2);
    assert_eq!(pipeline.workers_retired, 0);
}

#[test]
fn stalled_worker_expires_deadlined_requests_instead_of_hanging() {
    quiet_injected_panics();
    // One worker that hard-stalls before its first encode; per-request
    // deadlines far shorter than the stall. Requests dispatched before
    // the stall resolve late but OK; requests still queued must expire
    // at batch cut — nobody waits out the full stall × queue length.
    let fault = FaultPlan {
        stall_once: Some((0, Duration::from_millis(300))),
        ..FaultPlan::default()
    };
    let cfg = ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 1,
            n_workers: 1,
            queue_depth: 1,
            fault,
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(200),
        queue_cap: 64,
        slots: 32,
        default_deadline: Some(Duration::from_millis(50)),
        ..ServeCfg::new(encoder_cfg(51))
    };
    let (server, handle) = Server::new(cfg, small_store(256));
    let server_thread = thread::spawn(move || server.run());

    let ok = Arc::new(AtomicU64::new(0));
    let expired = Arc::new(AtomicU64::new(0));
    let clients: Vec<_> = (0..8)
        .map(|c| {
            let h = handle.clone();
            let ok = Arc::clone(&ok);
            let expired = Arc::clone(&expired);
            thread::spawn(move || {
                let mut stream =
                    SyntheticStream::new(SyntheticConfig::sampled(700 + c as u64));
                let rec = stream.next_record().unwrap();
                match h.classify(rec) {
                    Ok(_) => {
                        ok.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(ServeError::DeadlineExceeded) => {
                        expired.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => panic!("unexpected terminal outcome: {e:?}"),
                }
            })
        })
        .collect();
    let t0 = Instant::now();
    for cthread in clients {
        cthread.join().expect("client must terminate");
    }
    let wall = t0.elapsed();
    handle.shutdown();
    server_thread.join().expect("server");
    let snap = handle.stats();

    let (ok, expired) = (ok.load(Ordering::Relaxed), expired.load(Ordering::Relaxed));
    assert_eq!(ok + expired, 8, "every request reached a terminal outcome");
    assert!(expired >= 1, "50ms deadlines must expire behind a 300ms stall");
    assert_eq!(snap.expired, expired, "server-side expired counter matches clients");
    assert_eq!(snap.completed, snap.submitted);
    // The whole run is bounded by ~one stall, not stall × requests.
    assert!(wall < Duration::from_secs(3), "requests serialized behind the stall: {wall:?}");
}

#[test]
fn saturated_queue_sheds_and_queue_depth_observes_capacity() {
    quiet_injected_panics();
    // Stall the batcher so nothing drains, fill the bounded queue to
    // exact capacity, and check: (a) the next Shed submission fails fast
    // with QueueFull, (b) once the batcher wakes, everything queued
    // completes, (c) the pre-pop depth sample saw the *full* queue.
    let queue_cap = 8usize;
    let fault = FaultPlan {
        stall_batcher: Some(Duration::from_millis(400)),
        ..FaultPlan::default()
    };
    let cfg = ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 8,
            n_workers: 2,
            queue_depth: 2,
            fault,
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(200),
        queue_cap,
        slots: 32,
        admission: AdmissionPolicy::Shed,
        ..ServeCfg::new(encoder_cfg(52))
    };
    let (server, handle) = Server::new(cfg, small_store(256));
    let server_thread = thread::spawn(move || server.run());

    let fillers: Vec<_> = (0..queue_cap)
        .map(|c| {
            let h = handle.clone();
            thread::spawn(move || {
                let mut stream =
                    SyntheticStream::new(SyntheticConfig::sampled(800 + c as u64));
                let rec = stream.next_record().unwrap();
                h.classify(rec).expect("queued within capacity must complete")
            })
        })
        .collect();
    // Wait until all fillers are actually enqueued (the batcher is
    // asleep, so they can only be in the queue).
    let t0 = Instant::now();
    while handle.stats().submitted < queue_cap as u64 {
        assert!(t0.elapsed() < Duration::from_millis(300), "fillers failed to enqueue");
        thread::yield_now();
    }
    // Capacity reached: one more Shed submission must fail fast.
    let mut stream = SyntheticStream::new(SyntheticConfig::sampled(900));
    let rec = stream.next_record().unwrap();
    let t_shed = Instant::now();
    assert_eq!(handle.classify(rec).unwrap_err(), ServeError::QueueFull);
    assert!(t_shed.elapsed() < Duration::from_millis(100), "shed must not wait for the stall");
    for f in fillers {
        let resp = f.join().expect("filler");
        assert!(resp.top_class < 2);
    }
    handle.shutdown();
    server_thread.join().expect("server");
    let snap = handle.stats();
    assert_eq!(snap.shed, 1);
    assert_eq!(snap.completed, queue_cap as u64);
    assert!(snap.shed_rate() > 0.0);
    assert_eq!(
        snap.queue_depth.max, queue_cap as u64,
        "pre-pop depth sampling must observe exact-capacity saturation"
    );
}

#[test]
fn shutdown_unblocks_classify_parked_on_full_queue() {
    quiet_injected_panics();
    // Regression for the classify/shutdown race: a client parked in the
    // Block admission path on a *full* queue must observe shutdown
    // promptly — not sleep until the batcher frees space (it never will:
    // it's stalled), and not hang forever.
    let queue_cap = 2usize;
    let fault = FaultPlan {
        stall_batcher: Some(Duration::from_millis(500)),
        ..FaultPlan::default()
    };
    let cfg = ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 4,
            n_workers: 1,
            queue_depth: 2,
            fault,
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(200),
        queue_cap,
        slots: 8,
        ..ServeCfg::new(encoder_cfg(53))
    };
    let (server, handle) = Server::new(cfg, small_store(256));
    let server_thread = thread::spawn(move || server.run());

    // Fill the queue (batcher asleep, so these park awaiting responses).
    let fillers: Vec<_> = (0..queue_cap)
        .map(|c| {
            let h = handle.clone();
            thread::spawn(move || {
                let mut stream =
                    SyntheticStream::new(SyntheticConfig::sampled(1000 + c as u64));
                let rec = stream.next_record().unwrap();
                h.classify(rec)
            })
        })
        .collect();
    let t0 = Instant::now();
    while handle.stats().submitted < queue_cap as u64 {
        assert!(t0.elapsed() < Duration::from_millis(300), "fillers failed to enqueue");
        thread::yield_now();
    }
    // This one blocks in the enqueue loop (queue full, Block admission).
    let blocked = {
        let h = handle.clone();
        thread::spawn(move || {
            let mut stream = SyntheticStream::new(SyntheticConfig::sampled(1100));
            let rec = stream.next_record().unwrap();
            let t = Instant::now();
            (h.classify(rec), t.elapsed())
        })
    };
    thread::sleep(Duration::from_millis(50));
    handle.shutdown();
    let (result, blocked_for) = blocked.join().expect("blocked client must return");
    assert_eq!(result.unwrap_err(), ServeError::Shutdown);
    assert!(
        blocked_for < Duration::from_millis(300),
        "shutdown must interrupt the bounded park promptly, took {blocked_for:?}"
    );
    // The queued fillers resolve once the batcher wakes into the
    // shutdown drain: aborted (queue cleared) — terminal either way.
    for f in fillers {
        let r = f.join().expect("filler must terminate");
        assert!(
            matches!(r, Ok(_) | Err(ServeError::Aborted)),
            "filler must get a terminal outcome, got {r:?}"
        );
    }
    server_thread.join().expect("server");
}

#[test]
fn open_loop_over_capacity_sheds_instead_of_hanging() {
    quiet_injected_panics();
    // Throttle capacity hard (single worker, 2ms per batch) and offer
    // ~10x more than it can serve with Shed admission: the run must
    // terminate with a nonzero shed rate — the overload answer is an
    // explicit refusal, not an unbounded queue or a hang.
    let cfg = ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 16,
            n_workers: 1,
            queue_depth: 1,
            slow_worker: Some((0, Duration::from_millis(2))),
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(200),
        queue_cap: 16,
        slots: 64,
        ..ServeCfg::new(encoder_cfg(54))
    };
    // Sustainable: ~16 records / 2ms = 8k rps. Offered: 80k rps.
    let load = OpenLoadCfg {
        rate_rps: 80_000.0,
        total_requests: 2_000,
        senders: 8,
        opts: RequestOpts {
            admission: Some(AdmissionPolicy::Shed),
            deadline: Some(Duration::from_millis(100)),
            ..RequestOpts::default()
        },
        data: SyntheticConfig::sampled(55),
    };
    let report = run_open_loop(cfg, small_store(256), &load);
    assert_eq!(
        report.ok + report.shed + report.timed_out + report.expired
            + report.failed + report.aborted + report.rejected,
        2_000,
        "every offered arrival reached a terminal outcome: {report:?}"
    );
    assert!(report.ok > 0, "an overloaded server still serves at capacity");
    assert!(
        report.shed + report.expired > 0,
        "10x overload must shed or expire: {report:?}"
    );
    assert!(report.serve.shed_rate() > 0.0 || report.expired > 0);
    // Client tallies and server counters agree.
    assert_eq!(report.shed, report.serve.shed);
    assert_eq!(report.expired, report.serve.expired);
}
