//! Property suite for the zero-allocation refactor: the scratch/batch
//! encode paths must be **bit-identical** to the allocating per-record
//! `encode` reference for every categorical and numeric encoder, under
//! heavy scratch reuse (pooled buffers recycled across cases), and the
//! multi-worker pipeline must equal the single-worker pipeline after the
//! per-worker-channel refactor.

use shdc::coordinator::{run_pipeline, CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::data::{Record, RecordStream, SyntheticStream};
use shdc::encoding::{
    bundle, bundle_with, sparse_from_indices, BloomEncoder, BundleMethod, CategoricalEncoder,
    CodebookEncoder, DenseHashEncoder, DenseHashMode, DenseProjection, EncodeScratch, Encoding,
    NumericEncoder, PermutationEncoder, ProjectionMode, RelaxedSjlt, Sjlt, SparseProjection,
};
use shdc::util::rng::Rng;

/// Run `prop` over `cases` seeded random cases.
fn forall(cases: u64, mut prop: impl FnMut(u64, &mut Rng)) {
    for case in 0..cases {
        let mut rng = Rng::new(0x5c4a7c8_u64 ^ case.wrapping_mul(0x9E3779B97F4A7C15));
        prop(case, &mut rng);
    }
}

fn random_symbols(rng: &mut Rng, max_s: usize) -> Vec<u64> {
    let s = rng.below_usize(max_s + 1);
    (0..s).map(|_| rng.below(1u64 << 40)).collect()
}

fn random_numeric(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

/// Assert scratch == allocating for one categorical encoder, recycling
/// outputs so later cases hit pooled buffers.
fn check_categorical(enc: &mut dyn CategoricalEncoder, cases: u64, max_s: usize) {
    let mut scratch = EncodeScratch::new();
    forall(cases, |case, rng| {
        let symbols = random_symbols(rng, max_s);
        let want = enc.encode(&symbols);
        let got = enc.encode_with(&symbols, &mut scratch);
        assert_eq!(got, want, "{} case {case} s={}", enc.name(), symbols.len());
        scratch.recycle(got);
    });
}

#[test]
fn bloom_scratch_matches_encode() {
    let mut rng = Rng::new(1);
    let mut e = BloomEncoder::new(4096, 4, &mut rng);
    check_categorical(&mut e, 60, 40);
}

#[test]
fn bloom_poly_scratch_matches_encode() {
    let mut rng = Rng::new(2);
    let mut e = BloomEncoder::new_poly(1024, 3, 8, &mut rng);
    check_categorical(&mut e, 40, 30);
}

#[test]
fn bloom_tiny_d_with_collisions_scratch_matches_encode() {
    // Tiny dimension: heavy hash collisions stress the bitset dedup.
    let mut rng = Rng::new(3);
    let mut e = BloomEncoder::new(64, 8, &mut rng);
    check_categorical(&mut e, 60, 50);
}

#[test]
fn dense_hash_scratch_matches_encode() {
    let mut rng = Rng::new(4);
    for mode in [DenseHashMode::Literal, DenseHashMode::Packed] {
        let mut e = DenseHashEncoder::new(257, mode, &mut rng);
        check_categorical(&mut e, 30, 12);
    }
}

#[test]
fn codebook_scratch_matches_encode() {
    let mut e = CodebookEncoder::new(512, 5);
    check_categorical(&mut e, 40, 20);
}

#[test]
fn permutation_scratch_matches_encode() {
    let mut rng = Rng::new(6);
    let mut e = PermutationEncoder::new(512, 4, 16, &mut rng);
    check_categorical(&mut e, 40, 15);
}

/// Assert scratch (per-record and batch) == allocating per-record encode
/// for one numeric encoder.
fn check_numeric(enc: &dyn NumericEncoder, cases: u64, n: usize) {
    let mut scratch = EncodeScratch::new();
    forall(cases, |case, rng| {
        let x = random_numeric(rng, n);
        let want = enc.encode(&x);
        let got = enc.encode_with(&x, &mut scratch);
        assert_eq!(got, want, "{} case {case}", enc.name());
        scratch.recycle(got);
    });
    // Batch paths: allocating batch, scratch batch, per-record reference.
    let mut rng = Rng::new(0xbeef);
    let xs: Vec<Vec<f32>> = (0..17).map(|_| random_numeric(&mut rng, n)).collect();
    let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
    let want: Vec<Encoding> = refs.iter().map(|x| enc.encode(x)).collect();
    assert_eq!(enc.encode_batch(&refs), want, "{} encode_batch", enc.name());
    let mut out = Vec::new();
    enc.encode_batch_with(&refs, &mut scratch, &mut out);
    assert_eq!(out, want, "{} encode_batch_with", enc.name());
    // Second round over recycled buffers.
    scratch.recycle_all(out.drain(..));
    enc.encode_batch_with(&refs, &mut scratch, &mut out);
    assert_eq!(out, want, "{} encode_batch_with (recycled)", enc.name());
    // Flat path (the coordinator's staging layout): same rows, one
    // contiguous buffer — must stay bit-identical to the slice path.
    let mut flat: Vec<f32> = Vec::with_capacity(xs.len() * n);
    for x in &xs {
        flat.extend_from_slice(x);
    }
    scratch.recycle_all(out.drain(..));
    enc.encode_batch_flat_with(&flat, n, &mut scratch, &mut out);
    assert_eq!(out, want, "{} encode_batch_flat_with", enc.name());
}

#[test]
fn dense_projection_scratch_matches_encode() {
    let mut rng = Rng::new(7);
    for mode in [ProjectionMode::Raw, ProjectionMode::Sign] {
        let e = DenseProjection::new(300, 13, mode, &mut rng);
        check_numeric(&e, 30, 13);
    }
}

#[test]
fn sparse_projection_scratch_matches_encode() {
    let mut rng = Rng::new(8);
    let topk = SparseProjection::new_topk(400, 13, 37, &mut rng);
    check_numeric(&topk, 30, 13);
    let thr = SparseProjection::new_threshold(400, 13, 0.8, &mut rng);
    check_numeric(&thr, 30, 13);
}

#[test]
fn sjlt_scratch_matches_encode() {
    let mut rng = Rng::new(9);
    let e = Sjlt::new(512, 13, 4, &mut rng);
    check_numeric(&e, 30, 13);
}

#[test]
fn relaxed_sjlt_scratch_matches_encode() {
    let mut rng = Rng::new(10);
    for quantize in [false, true] {
        let e = RelaxedSjlt::new(256, 13, 0.4, quantize, &mut rng);
        check_numeric(&e, 30, 13);
    }
}

#[test]
fn bundle_with_matches_bundle() {
    let mut rng = Rng::new(11);
    let mut scratch = EncodeScratch::new();
    let d = 96usize;
    let mk_sparse = |rng: &mut Rng| {
        let s = rng.below_usize(20);
        let idx: Vec<u32> = (0..s).map(|_| rng.below(d as u64) as u32).collect();
        sparse_from_indices(idx, d)
    };
    let mk_dense = |rng: &mut Rng| {
        Encoding::Dense((0..d).map(|_| rng.normal_f32()).collect::<Vec<f32>>())
    };
    for case in 0..60 {
        let a = if rng.bernoulli(0.5) { mk_sparse(&mut rng) } else { mk_dense(&mut rng) };
        let b = if rng.bernoulli(0.5) { mk_sparse(&mut rng) } else { mk_dense(&mut rng) };
        for method in [BundleMethod::Concat, BundleMethod::Sum, BundleMethod::ThresholdedSum] {
            let want = bundle(&a, &b, method);
            let got = bundle_with(&a, &b, method, &mut scratch);
            assert_eq!(got, want, "case {case} {method:?}");
            scratch.recycle(got);
        }
    }
}

/// RecordEncoder's batched scratch path vs the per-record reference,
/// across encoder/bundle combinations.
#[test]
fn record_encoder_batch_matches_per_record() {
    let combos = vec![
        EncoderCfg {
            cat: CatCfg::Bloom { d: 512, k: 4 },
            num: NumCfg::Sjlt { d: 256, k: 4 },
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 21,
        },
        EncoderCfg {
            cat: CatCfg::DenseHash { d: 128, literal: false },
            num: NumCfg::DenseSign { d: 128 },
            bundle: BundleMethod::Sum,
            n_numeric: 13,
            seed: 22,
        },
        EncoderCfg {
            cat: CatCfg::Bloom { d: 256, k: 3 },
            num: NumCfg::SparseThreshold { d: 256, t: 1.0 },
            bundle: BundleMethod::ThresholdedSum,
            n_numeric: 13,
            seed: 23,
        },
        EncoderCfg {
            cat: CatCfg::Codebook { d: 128, budget_bytes: None },
            num: NumCfg::RelaxedSjlt { d: 64, p: 0.4, quantize: true },
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 24,
        },
        EncoderCfg {
            cat: CatCfg::Permutation { d: 128, pool: 2, granularity: 16 },
            num: NumCfg::None,
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 25,
        },
        EncoderCfg {
            cat: CatCfg::None,
            num: NumCfg::SparseTopK { d: 256, k: 25 },
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 26,
        },
    ];
    for cfg in combos {
        let mut stream = SyntheticStream::new(SyntheticConfig::sampled(cfg.seed));
        let records: Vec<_> = (0..48).map(|_| stream.next_record().unwrap()).collect();
        // Reference: a fresh encoder, per-record allocating path.
        let mut ref_enc = cfg.build();
        let want: Vec<Encoding> = records.iter().map(|r| ref_enc.encode(r)).collect();
        // Batched scratch path, run twice so round 2 uses pooled buffers.
        let mut enc = cfg.build();
        let mut out = Vec::new();
        for round in 0..2 {
            enc.encode_batch_into(&records, &mut out);
            assert_eq!(out, want, "cfg {:?}/{:?} round {round}", cfg.cat, cfg.num);
            enc.recycle_all(out.drain(..));
        }
    }
}

#[test]
fn pipeline_output_worker_count_invariant() {
    // After the per-worker-channel refactor, 1/2/4-worker runs must be
    // bit-identical (seq reorderer + deterministic encoders).
    let enc_cfg = EncoderCfg {
        cat: CatCfg::Bloom { d: 512, k: 4 },
        num: NumCfg::Sjlt { d: 256, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 31,
    };
    let collect = |workers: usize| {
        let stream = SyntheticStream::new(SyntheticConfig::sampled(31));
        let mut encs = Vec::new();
        let mut labels = Vec::new();
        run_pipeline(
            stream,
            &enc_cfg,
            &CoordinatorCfg {
                batch_size: 32,
                n_workers: workers,
                max_records: Some(512),
                ..Default::default()
            },
            |b| {
                encs.extend(b.encodings.drain(..));
                labels.extend(b.labels.drain(..));
                true
            },
        );
        (encs, labels)
    };
    let single = collect(1);
    assert_eq!(single, collect(2));
    assert_eq!(single, collect(4));
}

/// Deterministic stream with *heavily ragged* categorical sets: every
/// 16th record is a whale (hundreds of symbols), the rest carry 0–3.
/// With a small batch size, whole batches end up orders of magnitude
/// more expensive than their neighbors, so round-robin dispatch leaves
/// some workers far behind others — the skew regime that motivates the
/// planned work-stealing change.
struct RaggedStream {
    i: u64,
    remaining: u64,
}

impl RecordStream for RaggedStream {
    fn next_record(&mut self) -> Option<Record> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let i = self.i;
        self.i += 1;
        let s = if i % 16 == 0 { 350 } else { (i % 4) as usize };
        let symbols: Vec<u64> = (0..s as u64)
            .map(|j| shdc::util::rng::mix64(i.wrapping_mul(1_000_003) ^ j))
            .collect();
        let numeric: Vec<f32> =
            (0..13u64).map(|j| (((i * 13 + j) % 97) as f32) * 0.11 - 5.0).collect();
        Some(Record { numeric, symbols, label: i % 3 == 0 })
    }
}

/// Regression guard for the round-robin coordinator under skew: ragged
/// batches must not change output vs a single worker — batches may
/// *finish* wildly out of order, but the seq reorderer plus
/// deterministic encoders must keep the consumer's view bit-identical.
/// (Any future work-stealing dispatch must keep this green.)
#[test]
fn pipeline_ragged_skew_worker_count_invariant() {
    let enc_cfg = EncoderCfg {
        cat: CatCfg::Bloom { d: 1024, k: 4 },
        num: NumCfg::Sjlt { d: 256, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 77,
    };
    let collect = |workers: usize| {
        let stream = RaggedStream { i: 0, remaining: 600 };
        let mut encs = Vec::new();
        let mut labels = Vec::new();
        run_pipeline(
            stream,
            &enc_cfg,
            &CoordinatorCfg {
                batch_size: 8,
                n_workers: workers,
                queue_depth: 2,
                max_records: Some(600),
                ..Default::default()
            },
            |b| {
                encs.extend(b.encodings.drain(..));
                labels.extend(b.labels.drain(..));
                true
            },
        );
        (encs, labels)
    };
    let single = collect(1);
    assert_eq!(single.0.len(), 600, "stream must deliver every record");
    assert_eq!(single, collect(3), "3-worker skewed run diverged from single-worker");
    assert_eq!(single, collect(8), "8-worker skewed run diverged from single-worker");
}
