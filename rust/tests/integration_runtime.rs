//! PJRT runtime integration: load the AOT artifacts and cross-validate
//! XLA numerics against the rust implementations. Requires
//! `make artifacts` (tests skip with a warning when absent, so plain
//! `cargo test` still passes pre-build).

use shdc::encoding::{DenseProjection, ProjectionMode, Sjlt};
use shdc::model::LogisticModel;
use shdc::runtime::{self, HostTensor, Runtime};
use shdc::util::rng::Rng;

fn runtime_or_skip(test: &str) -> Option<Runtime> {
    match runtime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP {test}: {e}");
            None
        }
    }
}

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn artifact_manifest_lists_small_profile() {
    let Some(rt) = runtime_or_skip("artifact_manifest_lists_small_profile") else {
        return;
    };
    assert!(rt.manifest.profiles().contains(&"small".to_string()));
    let ts = rt.manifest.find("train_step", "small").unwrap();
    assert_eq!(ts.inputs.len(), 4);
}

#[test]
fn projection_artifact_matches_rust_encoder() {
    let Some(mut rt) = runtime_or_skip("projection_artifact_matches_rust_encoder") else {
        return;
    };
    let spec = rt.spec("encode_project_sign__small").unwrap().clone();
    let (b, n, d) = (spec.param("b").unwrap(), spec.param("n").unwrap(), spec.param("d_num").unwrap());
    let mut rng = Rng::new(1);
    let proj = DenseProjection::new(d, n, ProjectionMode::Sign, &mut rng);
    let x: Vec<f32> = (0..b * n).map(|_| rng.normal_f32()).collect();
    let outs = rt
        .execute(
            "encode_project_sign__small",
            &[
                HostTensor::f32(x.clone(), &[b, n]),
                HostTensor::f32(proj.phi_flat().to_vec(), &[d, n]),
                HostTensor::scalar_f32(0.0),
            ],
        )
        .unwrap();
    assert_eq!(outs[0].shape, vec![b, d]);
    for i in 0..b {
        let enc = proj.encode_record(&x[i * n..(i + 1) * n]).to_dense();
        for j in 0..d {
            let got = outs[0].data[i * d + j];
            // sign() can disagree only at |z| ~ 0 float noise.
            if !close(got, enc[j], 1e-4) {
                let mut z = 0.0f32;
                for t in 0..n {
                    z += proj.phi_flat()[j * n + t] * x[i * n + t];
                }
                assert!(z.abs() < 1e-4, "row {i} col {j}: xla {got} rust {} z {z}", enc[j]);
            }
        }
    }
}

#[test]
fn sjlt_artifact_matches_rust_encoder() {
    let Some(mut rt) = runtime_or_skip("sjlt_artifact_matches_rust_encoder") else {
        return;
    };
    let spec = rt.spec("encode_sjlt__small").unwrap().clone();
    let (b, n, d, k) = (
        spec.param("b").unwrap(),
        spec.param("n").unwrap(),
        spec.param("d_num").unwrap(),
        spec.param("sjlt_k").unwrap(),
    );
    let mut rng = Rng::new(2);
    let sj = Sjlt::new(d, n, k, &mut rng);
    let x: Vec<f32> = (0..b * n).map(|_| rng.normal_f32()).collect();
    let outs = rt
        .execute(
            "encode_sjlt__small",
            &[
                HostTensor::f32(x.clone(), &[b, n]),
                HostTensor::i32(sj.eta_flat(), &[k, n]),
                HostTensor::f32(sj.sigma_flat(), &[k, n]),
            ],
        )
        .unwrap();
    for i in 0..b {
        let enc = sj.encode_record(&x[i * n..(i + 1) * n]).to_dense();
        for j in 0..d {
            assert!(
                close(outs[0].data[i * d + j], enc[j], 1e-4),
                "({i},{j}): xla {} rust {}",
                outs[0].data[i * d + j],
                enc[j]
            );
        }
    }
}

#[test]
fn train_step_artifact_matches_rust_sgd() {
    let Some(mut rt) = runtime_or_skip("train_step_artifact_matches_rust_sgd") else {
        return;
    };
    let spec = rt.spec("train_step__small").unwrap().clone();
    let (b, d) = (spec.param("b").unwrap(), spec.param("d_total").unwrap());
    let mut rng = Rng::new(3);
    let theta: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.05).collect();
    let phi: Vec<f32> = (0..b * d).map(|_| if rng.bernoulli(0.1) { 1.0 } else { 0.0 }).collect();
    let y: Vec<f32> = (0..b).map(|_| if rng.bernoulli(0.4) { 1.0 } else { 0.0 }).collect();
    let lr = 0.3f32;
    let outs = rt
        .execute(
            "train_step__small",
            &[
                HostTensor::f32(theta.clone(), &[d]),
                HostTensor::f32(phi.clone(), &[b, d]),
                HostTensor::f32(y.clone(), &[b]),
                HostTensor::scalar_f32(lr),
            ],
        )
        .unwrap();

    // rust reference: dense SGD step without bias.
    let mut model = LogisticModel::new(d);
    model.theta.copy_from_slice(&theta);
    let batch: Vec<(shdc::encoding::Encoding, bool)> = (0..b)
        .map(|i| {
            (
                shdc::encoding::Encoding::Dense(phi[i * d..(i + 1) * d].to_vec()),
                y[i] > 0.5,
            )
        })
        .collect();
    let loss_ref = model.loss(&batch);
    // Zero out the bias update by replicating the math manually: the
    // artifact has no bias term, and LogisticModel's bias starts at 0 and
    // does not affect theta's gradient on the first step.
    model.sgd_step(&batch, lr);
    for j in 0..d {
        assert!(
            close(outs[0].data[j], model.theta[j], 1e-4),
            "theta[{j}]: xla {} rust {}",
            outs[0].data[j],
            model.theta[j]
        );
    }
    assert!(
        close(outs[1].scalar(), loss_ref as f32, 1e-4),
        "loss: xla {} rust {}",
        outs[1].scalar(),
        loss_ref
    );
}

#[test]
fn predict_artifact_outputs_probabilities() {
    let Some(mut rt) = runtime_or_skip("predict_artifact_outputs_probabilities") else {
        return;
    };
    let spec = rt.spec("predict__small").unwrap().clone();
    let (b, d) = (spec.param("b").unwrap(), spec.param("d_total").unwrap());
    let mut rng = Rng::new(4);
    let theta: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.1).collect();
    let phi: Vec<f32> = (0..b * d).map(|_| rng.normal_f32()).collect();
    let outs = rt
        .execute(
            "predict__small",
            &[HostTensor::f32(theta.clone(), &[d]), HostTensor::f32(phi.clone(), &[b, d])],
        )
        .unwrap();
    for (i, &p) in outs[0].data.iter().enumerate() {
        assert!(p > 0.0 && p < 1.0, "prob[{i}]={p}");
        // Spot-check against rust sigmoid(theta.phi).
        let z: f32 = (0..d).map(|j| theta[j] * phi[i * d + j]).sum();
        let want = 1.0 / (1.0 + (-z).exp());
        assert!(close(p, want, 1e-3), "prob[{i}]: xla {p} rust {want}");
    }
}

#[test]
fn fused_pjrt_training_learns() {
    let Some(_) = runtime_or_skip("fused_pjrt_training_learns") else {
        return;
    };
    use shdc::coordinator::{CatCfg, EncoderCfg, NumCfg};
    use shdc::data::synthetic::SyntheticConfig;
    use shdc::encoding::BundleMethod;
    use shdc::pipeline::{train, TrainBackend, TrainCfg};

    let data = SyntheticConfig {
        alphabet_size: 5_000,
        noise: 0.3,
        ..SyntheticConfig::sampled(31)
    };
    let cfg = TrainCfg {
        encoder: EncoderCfg {
            cat: CatCfg::Bloom { d: 512, k: 4 }, // matches small profile d_cat
            num: NumCfg::DenseSign { d: 256 },   // ignored by the fused path
            bundle: BundleMethod::Concat,
            n_numeric: 13,
            seed: 31,
        },
        backend: TrainBackend::PjrtFused { profile: "small".into() },
        lr: 0.5,
        batch_size: 32,
        n_workers: 2,
        train_records: 6_000,
        val_records: 600,
        test_records: 1_200,
        validate_every: 2_000,
        patience: 3,
        auc_chunk: 600,
        seed: 31,
    };
    let rep = train(&cfg, &data).expect("pjrt training");
    assert!(rep.records_trained >= 5_000);
    assert!(
        rep.median_test_auc() > 0.75,
        "fused PJRT path should learn the planted problem: AUC {}",
        rep.median_test_auc()
    );
    assert_eq!(rep.trainable_params, 768);
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(mut rt) = runtime_or_skip("executable_cache_reuses_compilations") else {
        return;
    };
    let spec = rt.spec("predict__small").unwrap().clone();
    let (b, d) = (spec.param("b").unwrap(), spec.param("d_total").unwrap());
    let theta = vec![0.0f32; d];
    let phi = vec![0.0f32; b * d];
    let args = [HostTensor::f32(theta, &[d]), HostTensor::f32(phi, &[b, d])];
    rt.execute("predict__small", &args).unwrap();
    let t0 = std::time::Instant::now();
    for _ in 0..3 {
        rt.execute("predict__small", &args).unwrap();
    }
    // Cached executions must be far faster than a fresh compile (~100ms+).
    assert!(t0.elapsed().as_millis() < 1_000);
    assert_eq!(rt.exec_counts["predict__small"], 4);
    assert!(rt.compiled().contains(&"predict__small".to_string()));
}
